"""Exhaustive f32 seam sweeps, adjudicated by the beyond-f64 oracle.

The seam registry lives WITH the algorithms
(:func:`repro.core.ffmath.reduction_seams`) and is built from the live
reduction constants, so retuning a constant moves the swept
neighborhoods with it.  This module turns a :class:`SeamSpec` into
points, runs the real jitted ``ff.math`` raw-limb path (``E = CORE`` —
the jnp implementation the registry dispatches; the Pallas twin is
pinned bitwise-equal elsewhere), and checks the contract in two passes:

1. **f64 screen** (numpy, vectorized): fast relative error against the
   f64 reference for every point.  f64's 2^-53 noise sits ~11 bits below
   the 2^-42-class bounds, so a generous :data:`SCREEN_MARGIN` makes the
   screen conservative, never lenient.
2. **oracle adjudication** (mpmath, per point): every point the screen
   flags — plus a fixed random subsample as an always-on cross-check of
   the screen itself — is re-judged at >= 60 bits
   (:func:`repro.verify.oracle.rel_errors`).  Only adjudicated points
   can be violations.

The tolerance model per point (documented in ``docs/VERIFY.md``):
``bound`` relative normally; 2^-23 where the true result lies in the
lo-flush band [2^-126, 2^-82) (the lo limb is itself subnormal there);
one subnormal quantum absolute below 2^-126; saturation to ``inf``
accepted iff the true value overflows binary32.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional

import numpy as np

from repro.core import ffmath
from repro.verify import oracle

DEFAULT_BUDGET = 1 << 16          # points per seam (CI quick tier)
FULL_BUDGET = 1 << 20             # the acceptance target per seam
CHUNK = 1 << 16                   # fixed jit shape
SCREEN_MARGIN = 0.25              # adjudicate when screen_err > margin*tol
ADJUDICATE_SAMPLE = 128           # always-on random oracle cross-check
SWEEP_PREC_BITS = 80              # oracle precision (contract: >= 60)

_MAX_FINITE_IDX = 0x7F7F0000 + 0xFFFF   # ordered index of f32 max finite

# inputs outside a function's verified domain (paper §6.1: EFT claims
# hold on normal-or-zero).  log's frexp bit surgery has no subnormal
# path and the x == 0 float compare is itself flush-sensitive (the PR 7
# guard finding), so subnormal inputs are excluded and counted, not
# judged.
_DOMAIN_EXCLUDED_CLASSES: Dict[str, tuple] = {
    "log": ("subnormal",),
}


# ---------------------------------------------------------------------------
# f32 grid walking: a monotone integer index over the finite floats
# ---------------------------------------------------------------------------

def ordered_index(x) -> np.ndarray:
    """Monotone int64 index of f32 values (consecutive integers are
    consecutive floats; both zeros map to 0)."""
    b = np.asarray(x, np.float32).view(np.uint32).astype(np.int64)
    return np.where(b & 0x80000000, 0x80000000 - b, b)


def from_index(idx) -> np.ndarray:
    idx = np.asarray(idx, np.int64)
    bits = np.where(idx < 0, 0x80000000 - idx, idx).astype(np.uint32)
    return bits.view(np.float32)


def neighborhood(center: float, n: int) -> np.ndarray:
    """The n consecutive f32 values centered on fl32(center), clipped to
    the finite range."""
    c = int(ordered_index(np.float32(center)))
    lo = max(c - n // 2, -_MAX_FINITE_IDX)
    hi = min(lo + n, _MAX_FINITE_IDX + 1)
    return from_index(np.arange(lo, hi, dtype=np.int64))


def window_points(lo: float, hi: float, n: int, seed: int = 0) -> np.ndarray:
    """Points covering [lo, hi]: full f32 enumeration when the window
    holds <= n floats, else exhaustive edges + uniform coverage of the
    representable floats in between (uniform in index space == log-
    uniform in magnitude)."""
    ilo = int(ordered_index(np.float32(lo)))
    ihi = int(ordered_index(np.float32(hi)))
    count = ihi - ilo + 1
    if count <= n:
        return from_index(np.arange(ilo, ihi + 1, dtype=np.int64))
    edge = n // 4
    rng = np.random.default_rng(seed)
    mid = rng.integers(ilo + edge, ihi - edge, size=n - 2 * edge)
    idx = np.concatenate([
        np.arange(ilo, ilo + edge, dtype=np.int64),
        np.arange(ihi - edge + 1, ihi + 1, dtype=np.int64),
        np.sort(mid),
    ])
    return from_index(np.unique(idx))


def enumerate_points(spec: ffmath.SeamSpec, budget: int,
                     seed: int = 0) -> np.ndarray:
    """The sweep grid for one seam at a given per-seam point budget."""
    if spec.kind == "points":
        return np.asarray(spec.data, np.float32)
    if spec.kind == "centers":
        per = max(budget // len(spec.data), 32)
        pts = np.concatenate([neighborhood(c, per) for c in spec.data])
        return from_index(np.unique(ordered_index(pts)))
    if spec.kind == "window":
        lo, hi = spec.data
        return window_points(lo, hi, budget, seed)
    raise ValueError(f"unknown seam kind {spec.kind!r}")


# ---------------------------------------------------------------------------
# evaluation: the real jitted raw-limb path, fixed-shape chunks
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _jitted(fn: str):
    import jax

    def run(xh, xl):
        return ffmath.UNARY22[fn](xh, xl, ffmath.CORE)

    return jax.jit(run)

def evaluate(fn: str, xs: np.ndarray, chunk: int = CHUNK):
    """(hi, lo) = ff.math fn over the grid, via the jitted CORE path.
    Pads to a fixed chunk shape so one compilation serves every seam."""
    f = _jitted(fn)
    n = xs.size
    pad = (-n) % chunk
    xp = np.concatenate([xs, np.ones(pad, np.float32)])
    hs, ls = [], []
    zeros = np.zeros(chunk, np.float32)
    for i in range(0, xp.size, chunk):
        h, l = f(xp[i:i + chunk], zeros)
        hs.append(np.asarray(h))
        ls.append(np.asarray(l))
    return np.concatenate(hs)[:n], np.concatenate(ls)[:n]


# ---------------------------------------------------------------------------
# tolerance model + two-pass checking
# ---------------------------------------------------------------------------

def _f64_ref(fn: str, xs64: np.ndarray) -> np.ndarray:
    with np.errstate(all="ignore"):
        if fn == "exp":
            return np.exp(xs64)
        if fn == "expm1":
            return np.expm1(xs64)
        if fn == "log":
            return np.log(xs64)
        if fn == "log1p":
            return np.log1p(xs64)
        if fn == "tanh":
            return np.tanh(xs64)
        if fn == "sigmoid":
            return 1.0 / (1.0 + np.exp(-xs64))
        if fn == "erf":
            return np.vectorize(math.erf)(xs64)
        if fn == "gelu":
            return xs64 / 2 * (1 + np.vectorize(math.erf)(xs64 / np.sqrt(2)))
        if fn == "silu":
            return xs64 / (1.0 + np.exp(-xs64))
    raise ValueError(f"no f64 screen for {fn!r}")


def tolerances(want64: np.ndarray, bound: float) -> np.ndarray:
    """Per-point relative tolerance (the documented degradation bands)."""
    aw = np.abs(want64)
    tol = np.full(want64.shape, bound)
    lo_flush = (aw >= 2.0 ** -126) & (aw < 2.0 ** -82)
    tol[lo_flush] = 2.0 ** -23
    with np.errstate(divide="ignore"):
        subn = (aw > 0) & (aw < 2.0 ** -126)
        tol[subn] = np.maximum(bound, (2.0 ** -149) / aw[subn])
    return tol


@dataclasses.dataclass
class SeamResult:
    seam: str
    fn: str
    check: str
    points: int
    excluded: int            # out-of-domain inputs (counted, not judged)
    adjudicated: int         # points the oracle re-judged
    violations: int
    worst_rel: float         # worst oracle-adjudicated relative error
    worst_points: list       # up to 8 (x, rel_err, tol) triples
    note: str = ""

    @property
    def ok(self) -> bool:
        return self.violations == 0


def _check_identity(spec, xs, got_h, got_l) -> SeamResult:
    bh = got_h.view(np.uint32)
    bx = xs.view(np.uint32)
    bad = (bh != bx) | (got_l.view(np.uint32) != np.zeros_like(bx))
    idx = np.nonzero(bad)[0]
    worst = [(float(xs[i]), float(got_h[i]), float(got_l[i]))
             for i in idx[:8]]
    return SeamResult(spec.name, spec.fn, spec.check, xs.size, 0, xs.size,
                      int(bad.sum()), 0.0, worst, spec.note)


def run_seam(spec: ffmath.SeamSpec, budget: int = DEFAULT_BUDGET,
             prec_bits: int = SWEEP_PREC_BITS, seed: int = 0) -> SeamResult:
    xs = enumerate_points(spec, budget, seed)
    got_h, got_l = evaluate(spec.fn, xs)

    if spec.check == "identity":
        return _check_identity(spec, xs, got_h, got_l)

    # domain exclusion by bit class (never a float compare)
    excluded = np.zeros(xs.size, bool)
    for cls in _DOMAIN_EXCLUDED_CLASSES.get(spec.fn, ()):
        excluded |= np.fromiter(
            (oracle.classify_bits(int(b)) == cls
             for b in xs.view(np.uint32)), bool, xs.size)
    keep = ~excluded
    xs_k, gh_k, gl_k = xs[keep], got_h[keep], got_l[keep]

    xs64 = xs_k.astype(np.float64)
    want64 = _f64_ref(spec.fn, xs64)
    with np.errstate(all="ignore"):
        got64 = gh_k.astype(np.float64) + gl_k.astype(np.float64)
        aw = np.abs(want64)
    # flush-to-zero hardware (the paper's §6.1 model; XLA:CPU does this
    # too): a subnormal true result may come back as an exact zero —
    # accepted alongside the correctly-rounded subnormal an IEEE backend
    # would produce.  docs/VERIFY.md documents the two-way contract.
    ftz_ok = (aw < 2.0 ** -126) & (gh_k == 0) & (gl_k == 0)

    if spec.check == "special" or xs_k.size == 0:
        tol = np.full(xs_k.size, spec.bound)
        flagged = np.nonzero(~ftz_ok)[0]
    else:
        tol = tolerances(want64, spec.bound)
        with np.errstate(all="ignore"):
            screen = np.abs(got64 - want64) / aw
        finite = (np.isfinite(want64) & np.isfinite(got64)
                  & (want64 != 0.0) & np.isfinite(xs64))
        # saturation agreement passes the screen outright
        sat_ok = (~np.isfinite(got64)) & (
            aw >= float(oracle.OVERFLOW_THRESHOLD))
        suspect = np.ones(xs_k.size, bool)
        # in the degraded-tolerance bands (tol >= 2^-40) the f64 screen's
        # own 2^-52 noise is negligible — a 0.9 margin is still strictly
        # conservative and keeps the mpmath adjudication set small
        margin = np.where(tol >= 2.0 ** -40, 0.9, SCREEN_MARGIN)
        suspect[finite] = screen[finite] > margin[finite] * tol[finite]
        suspect[sat_ok] = False
        rng = np.random.default_rng(seed + 1)
        sample = rng.choice(xs_k.size,
                            size=min(ADJUDICATE_SAMPLE, xs_k.size),
                            replace=False)
        suspect[sample] = True
        suspect &= ~ftz_ok
        flagged = np.nonzero(suspect)[0]

    rel = oracle.rel_errors(spec.fn, xs_k[flagged], gh_k[flagged],
                            gl_k[flagged], prec_bits)
    viol = rel > tol[flagged]
    order = np.argsort(-np.where(np.isfinite(rel), rel, np.inf))
    worst = [(float(xs_k[flagged[i]]), float(rel[i]), float(tol[flagged[i]]))
             for i in order[:8] if viol[i]]
    worst_rel = float(np.max(rel[np.isfinite(rel)], initial=0.0))
    if np.any(viol & ~np.isfinite(rel)):
        worst_rel = math.inf
    return SeamResult(spec.name, spec.fn, spec.check, int(xs.size),
                      int(excluded.sum()), int(flagged.size),
                      int(viol.sum()), worst_rel, worst, spec.note)


def run_all(budget: int = DEFAULT_BUDGET,
            fns: Optional[tuple] = None,
            prec_bits: int = SWEEP_PREC_BITS) -> List[SeamResult]:
    out = []
    for spec in ffmath.reduction_seams():
        if fns is not None and spec.fn not in fns:
            continue
        out.append(run_seam(spec, budget, prec_bits))
    return out
