"""SMT proof obligations over z3 Float32 terms (QF_FP).

Every obligation is built by symbolically executing the LIVE raw-limb
code path (:mod:`repro.verify.symtrace`) — the same function objects the
dispatch registry runs — and asserting the NEGATION of the contract.
``unsat`` therefore proves the contract for *all* binary32 inputs in the
stated domain.

Encoding notes (details in ``docs/VERIFY.md``):

* **Exactness via wide formats.**  "``s + r == a + b`` exactly" is
  encoded in an auxiliary FP sort wide enough that every conversion and
  the compared additions are themselves exact: Float64 for TwoProd (a
  product of two binary32 values always fits in 53 bits), and a
  320-bit-significand sort for TwoSum (the exact sum of two binary32
  values spans at most 24 + 276 bits over the full exponent range).
* **Domain.**  Every recorded intermediate (inputs included) is
  constrained to *normal-or-zero* — the paper §6.1 domain where EFT
  exactness is claimed and where IEEE semantics (what z3 models) and
  the flush-to-zero hardware agree.  Bound obligations additionally pin
  the hi limbs to one binade WLOG: Add22/Mul22/div22/sqrt22 commute
  exactly with scaling by powers of two (every constant in the
  sequences is scale-free except the Dekker split, which also commutes
  barring over/underflow — excluded by the domain constraints), so a
  one-binade proof extends to the full normal range.
* **Vacuity guard.**  ``prove()`` first checks the domain constraints
  ALONE are satisfiable — a contradictory domain would make any negated
  goal "unsat" vacuously.

z3 is optional: :func:`have_z3` gates everything, tests skip cleanly.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from repro.verify import symtrace

# wide enough for the exact sum of any two finite binary32 values:
# exponent span (127 - (-149)) + 24 significand bits = 300 < 320
_WIDE_SB = 320
_WIDE_EB = 19
# error-bound obligations pin hi limbs to one binade; 200 bits cover the
# exact multi-limb sums/products there with room to spare
_BOUND_SB = 200

DEFAULT_TIMEOUT_MS = 600_000


def have_z3() -> bool:
    try:
        import z3  # noqa: F401
        return True
    except ImportError:
        return False


@dataclasses.dataclass
class Result:
    name: str
    namespace: str
    status: str                 # proved | counterexample | unknown | skipped
    seconds: float = 0.0
    detail: str = ""

    @property
    def ok(self) -> bool:
        return self.status in ("proved", "skipped")


class _Ctx:
    """Shared z3 scaffolding for one obligation build."""

    def __init__(self):
        import z3
        self.z3 = z3
        self.be = symtrace.Z3Backend(z3)
        self.f32 = self.be.sort
        self.f64 = z3.FPSort(11, 53)
        self.wide = z3.FPSort(_WIDE_EB, _WIDE_SB)
        self.bound = z3.FPSort(_WIDE_EB, _BOUND_SB)
        self.rm = z3.RNE()
        self.constraints: List = []

    def vars(self, *names):
        return [self.be.lift(n) for n in names]

    def to(self, sort, x):
        return self.z3.fpToFP(self.rm, x, sort)

    def add(self, sort, a, b):
        return self.z3.fpAdd(self.rm, a, b)

    def finish_domain(self, extra=()):
        self.constraints.extend(self.be.domain_constraints())
        self.constraints.extend(extra)

    def pow2(self, sort, k: int):
        return self.z3.FPVal(2.0 ** k, sort)

    def abs_between(self, x, lo_pow: int, hi_pow: int, or_zero=False):
        """2^lo <= |x| <= 2^hi (optionally allowing exact zero)."""
        z3, ax = self.z3, self.z3.fpAbs(x)
        c = z3.And(z3.fpGEQ(ax, self.pow2(self.f32, lo_pow)),
                   z3.fpLEQ(ax, self.pow2(self.f32, hi_pow)))
        return z3.Or(c, z3.fpIsZero(x)) if or_zero else c

    def in_binade(self, x):
        """1 <= x < 2 (the WLOG pin for scale-invariant bound proofs)."""
        z3 = self.z3
        one = z3.FPVal(1.0, self.f32)
        two = z3.FPVal(2.0, self.f32)
        return z3.And(z3.fpGEQ(x, one), z3.fpLT(x, two))

    def normalized_pair(self, hi, lo):
        """|lo| <= 2^-24 |hi| — the multiplicative surrogate of the FF
        normalization invariant (a superset of exactly-normalized pairs,
        so bounds proved here are strictly stronger)."""
        z3 = self.z3
        bound = z3.fpMul(self.rm, self.pow2(self.f32, -24), z3.fpAbs(hi))
        return z3.Or(z3.fpLEQ(z3.fpAbs(lo), bound), z3.fpIsZero(lo))


def _exact_sum(ctx: _Ctx, sort, terms):
    """Fold f32 terms into ``sort``; exact when the sort is wide enough
    for the term span (asserted by construction per obligation)."""
    acc = ctx.to(sort, terms[0])
    for t in terms[1:]:
        acc = ctx.z3.fpAdd(ctx.rm, acc, ctx.to(sort, t))
    return acc


# ---------------------------------------------------------------------------
# obligation builders: each returns (constraints, negated_goal_formula)
# ---------------------------------------------------------------------------

def _ob_two_sum_exact(ctx: _Ctx, namespace: str, fast: bool):
    z3 = ctx.z3
    a, b = ctx.vars("a", "b")
    fn = "fast_two_sum" if fast else "two_sum"
    s, r = symtrace.run_traced(namespace, fn, ctx.be, [a, b])
    extra = []
    if fast:
        extra.append(z3.Or(z3.fpGEQ(z3.fpAbs(a.val), z3.fpAbs(b.val)),
                           z3.fpIsZero(a.val)))
    ctx.finish_domain(extra)
    lhs = ctx.z3.fpAdd(ctx.rm, ctx.to(ctx.wide, s), ctx.to(ctx.wide, r))
    rhs = ctx.z3.fpAdd(ctx.rm, ctx.to(ctx.wide, a.val),
                       ctx.to(ctx.wide, b.val))
    return ctx.constraints, z3.Not(z3.fpEQ(lhs, rhs))


def _ob_two_prod_exact(ctx: _Ctx, namespace: str):
    z3 = ctx.z3
    a, b = ctx.vars("a", "b")
    x, y = symtrace.run_traced(namespace, "two_prod", ctx.be, [a, b])
    # Dekker window (transforms.py domain note): split residues and the
    # halves' products must stay normal; the recorded-intermediate
    # constraints enforce that mechanically, the input window documents it
    ctx.finish_domain([
        ctx.abs_between(a.val, -100, 115, or_zero=True),
        ctx.abs_between(b.val, -100, 115, or_zero=True),
    ])
    # a*b is exact in f64 (24+24 <= 53 bits); x + y spans <= 49 bits
    lhs = z3.fpAdd(ctx.rm, ctx.to(ctx.f64, x), ctx.to(ctx.f64, y))
    rhs = z3.fpMul(ctx.rm, ctx.to(ctx.f64, a.val), ctx.to(ctx.f64, b.val))
    return ctx.constraints, z3.Not(z3.fpEQ(lhs, rhs))


def _eq22(ctx: _Ctx, fn: str):
    """kernels and core namespaces compute identical limbs (the bitwise
    jnp == pallas contract, as a theorem instead of a sample)."""
    z3 = ctx.z3
    nargs = 2 if fn in ("two_sum", "fast_two_sum", "two_prod") else 4
    names = ["a", "b", "c", "d"][:nargs]
    xs = ctx.vars(*names)
    h1, l1 = symtrace.run_traced("kernels", fn, ctx.be, xs)
    h2, l2 = symtrace.run_traced("core", fn, ctx.be, xs)
    extra = []
    if fn == "fast_two_sum":
        extra.append(z3.Or(z3.fpGEQ(z3.fpAbs(xs[0].val),
                                    z3.fpAbs(xs[1].val)),
                           z3.fpIsZero(xs[0].val)))
    ctx.finish_domain(extra)
    same = z3.And(z3.fpEQ(h1, h2), z3.fpEQ(l1, l2))
    return ctx.constraints, z3.Not(same)


def _bound_goal(ctx: _Ctx, res_h, res_l, exact_wide, eps_pow: int,
                floor_terms=None):
    """|(res_h + res_l) - exact| <= 2^eps_pow * |exact|   (wide compare;
    with ``floor_terms`` the RHS becomes the Add22 Thm-5 max() form:
    max(2^-24 |sum(floor_terms)|, 2^eps_pow |exact|))."""
    z3 = ctx.z3
    got = z3.fpAdd(ctx.rm, ctx.to(ctx.bound, res_h), ctx.to(ctx.bound, res_l))
    err = z3.fpAbs(z3.fpSub(ctx.rm, got, exact_wide))
    rel = z3.fpMul(ctx.rm, ctx.pow2(ctx.bound, eps_pow), z3.fpAbs(exact_wide))
    if floor_terms is not None:
        lo_mag = z3.fpAbs(_exact_sum(ctx, ctx.bound, floor_terms))
        alt = z3.fpMul(ctx.rm, ctx.pow2(ctx.bound, -24), lo_mag)
        rel = z3.If(z3.fpGT(alt, rel), alt, rel)
    return z3.Not(z3.fpLEQ(err, rel))


def _pair_domain(ctx: _Ctx, hi, lo, binade=True, lo_window=(-60, 1)):
    """Input-pair constraints for bound obligations: hi in [1,2) (WLOG,
    scale invariance) or a bounded window; lo normalized-or-zero."""
    cs = [ctx.normalized_pair(hi.val, lo.val)]
    if binade:
        cs.append(ctx.in_binade(hi.val))
    else:
        cs.append(ctx.abs_between(hi.val, *lo_window, or_zero=True))
    return cs


def _ob_add22_bound(ctx: _Ctx, namespace: str, accurate: bool):
    z3 = ctx.z3
    ah, al, bh, bl = ctx.vars("ah", "al", "bh", "bl")
    fn = "add22_accurate" if accurate else "add22"
    rh, rl = symtrace.run_traced(namespace, fn, ctx.be, [ah, al, bh, bl])
    # WLOG ah in [1,2) (global scaling is exact); b bounded so the 200-bit
    # accumulator holds the 4-limb sum exactly — cancellation included
    ctx.finish_domain(
        _pair_domain(ctx, ah, al)
        + _pair_domain(ctx, bh, bl, binade=False, lo_window=(-40, 40)))
    exact_sum = _exact_sum(ctx, ctx.bound, [ah.val, al.val, bh.val, bl.val])
    if accurate:
        # documented: <= 2 ulp_FF ~ 2^-44 relative, always
        goal = _bound_goal(ctx, rh, rl, exact_sum, -44)
    else:
        # paper Thm 5: delta <= max(2^-24 |al + bl|, 2^-44 |a + b|)
        goal = _bound_goal(ctx, rh, rl, exact_sum, -44,
                           floor_terms=[al.val, bl.val])
    return ctx.constraints, goal


def _ob_mul22_bound(ctx: _Ctx, namespace: str):
    z3 = ctx.z3
    ah, al, bh, bl = ctx.vars("ah", "al", "bh", "bl")
    rh, rl = symtrace.run_traced(namespace, "mul22", ctx.be, [ah, al, bh, bl])
    ctx.finish_domain(_pair_domain(ctx, ah, al) + _pair_domain(ctx, bh, bl))
    # exact product of two 2-limb values in the 200-bit accumulator
    terms = []
    for u in (ah.val, al.val):
        for v in (bh.val, bl.val):
            terms.append(z3.fpMul(ctx.rm, ctx.to(ctx.bound, u),
                                  ctx.to(ctx.bound, v)))
    exact_prod = terms[0]
    for t in terms[1:]:
        exact_prod = z3.fpAdd(ctx.rm, exact_prod, t)
    return ctx.constraints, _bound_goal(ctx, rh, rl, exact_prod, -44)


def _ob_div22_bound(ctx: _Ctx, namespace: str):
    z3 = ctx.z3
    ah, al, bh, bl = ctx.vars("ah", "al", "bh", "bl")
    rh, rl = symtrace.run_traced(namespace, "div22", ctx.be, [ah, al, bh, bl])
    ctx.finish_domain(_pair_domain(ctx, ah, al) + _pair_domain(ctx, bh, bl))
    num = _exact_sum(ctx, ctx.bound, [ah.val, al.val])
    den = _exact_sum(ctx, ctx.bound, [bh.val, bl.val])
    # the wide quotient rounds at 2^-200 relative — absorbed by the
    # bound's own slack (documented 2^-43 class vs ~2^-44.5 true)
    q = z3.fpDiv(ctx.rm, num, den)
    return ctx.constraints, _bound_goal(ctx, rh, rl, q, -43)


def _ob_sqrt22_bound(ctx: _Ctx, namespace: str):
    z3 = ctx.z3
    ah, al = ctx.vars("ah", "al")
    rh, rl = symtrace.run_traced(namespace, "sqrt22", ctx.be, [ah, al])
    # WLOG one even-exponent binade: sqrt commutes with 2^2k scaling
    one = z3.FPVal(1.0, ctx.f32)
    four = z3.FPVal(4.0, ctx.f32)
    ctx.finish_domain([ctx.normalized_pair(ah.val, al.val),
                       z3.And(z3.fpGEQ(ah.val, one), z3.fpLT(ah.val, four))])
    v = _exact_sum(ctx, ctx.bound, [ah.val, al.val])
    root = z3.fpSqrt(ctx.rm, v)        # wide rounding absorbed by slack
    return ctx.constraints, _bound_goal(ctx, rh, rl, root, -44)


def _ob_false_canary(ctx: _Ctx, namespace: str):
    """Deliberately FALSE claim (TwoSum residual is always zero): must
    come back ``counterexample``.  Guards the whole encoding against
    vacuous-unsat bugs in domains or conversions."""
    z3 = ctx.z3
    a, b = ctx.vars("a", "b")
    _s, r = symtrace.run_traced(namespace, "two_sum", ctx.be, [a, b])
    ctx.finish_domain()
    return ctx.constraints, z3.Not(z3.fpIsZero(r))


@dataclasses.dataclass(frozen=True)
class Obligation:
    name: str
    namespace: str
    build: Callable
    expect: str = "proved"          # the canary expects "counterexample"
    heavy: bool = False             # excluded from the quick CI tier


def _obligations() -> List[Obligation]:
    obs: List[Obligation] = []
    for ns in symtrace.NAMESPACES:
        obs += [
            Obligation("two_sum_residual_exact", ns,
                       lambda c, ns=ns: _ob_two_sum_exact(c, ns, False)),
            Obligation("fast_two_sum_residual_exact", ns,
                       lambda c, ns=ns: _ob_two_sum_exact(c, ns, True)),
            Obligation("two_prod_residual_exact", ns,
                       lambda c, ns=ns: _ob_two_prod_exact(c, ns)),
            Obligation("mul22_rel_bound_2pow44", ns,
                       lambda c, ns=ns: _ob_mul22_bound(c, ns)),
            Obligation("add22_sloppy_thm5_bound", ns,
                       lambda c, ns=ns: _ob_add22_bound(c, ns, False)),
            Obligation("div22_rel_bound_2pow43", ns,
                       lambda c, ns=ns: _ob_div22_bound(c, ns), heavy=True),
            Obligation("sqrt22_rel_bound_2pow44", ns,
                       lambda c, ns=ns: _ob_sqrt22_bound(c, ns), heavy=True),
        ]
    # accurate Add22 exists only on the core path (registry "accurate")
    obs.append(Obligation(
        "add22_accurate_rel_bound_2pow44", "core",
        lambda c: _ob_add22_bound(c, "core", True)))
    # cross-namespace bitwise equivalence (jnp == pallas as a theorem)
    for fn in ("two_sum", "fast_two_sum", "two_prod", "add22", "mul22"):
        obs.append(Obligation(f"{fn}_kernels_equals_core", "both",
                              lambda c, fn=fn: _eq22(c, fn)))
    obs.append(Obligation("canary_two_sum_residual_nonzero", "kernels",
                          lambda c: _ob_false_canary(c, "kernels"),
                          expect="counterexample"))
    return obs


OBLIGATIONS: Dict[str, Obligation] = {
    f"{o.name}[{o.namespace}]": o for o in _obligations()}


def prove(key: str, timeout_ms: int = DEFAULT_TIMEOUT_MS,
          check_vacuity: bool = True) -> Result:
    """Discharge one obligation.  Returns status:

    * ``proved``          — negated goal unsat (or, for the canary, sat)
    * ``counterexample``  — the contract FAILS; detail carries the model
    * ``unknown``         — solver timeout/unknown (NOT a failure; the
      sweep tier still covers the claim empirically)
    * ``skipped``         — z3 not installed
    """
    ob = OBLIGATIONS[key]
    if not have_z3():
        return Result(ob.name, ob.namespace, "skipped", 0.0, "z3 not installed")
    import z3
    t0 = time.monotonic()
    ctx = _Ctx()
    constraints, negated = ob.build(ctx)

    if check_vacuity:
        s0 = z3.Solver()
        s0.set("timeout", min(timeout_ms, 120_000))
        s0.add(*constraints)
        if s0.check() != z3.sat:
            return Result(ob.name, ob.namespace, "unknown",
                          time.monotonic() - t0,
                          f"domain vacuity check: {s0.check()} (expected sat)")

    s = z3.Solver()
    s.set("timeout", timeout_ms)
    s.add(*constraints)
    s.add(negated)
    res = s.check()
    dt = time.monotonic() - t0
    if ob.expect == "counterexample":
        if res == z3.sat:
            return Result(ob.name, ob.namespace, "proved", dt,
                          "canary: counterexample found as required")
        return Result(ob.name, ob.namespace, "counterexample", dt,
                      f"canary came back {res} — encoding is vacuous")
    if res == z3.unsat:
        return Result(ob.name, ob.namespace, "proved", dt)
    if res == z3.sat:
        return Result(ob.name, ob.namespace, "counterexample", dt,
                      f"model: {s.model()}")
    return Result(ob.name, ob.namespace, "unknown", dt, str(res))


def prove_all(timeout_ms: int = DEFAULT_TIMEOUT_MS,
              include_heavy: bool = False) -> List[Result]:
    out = []
    for key, ob in OBLIGATIONS.items():
        if ob.heavy and not include_heavy:
            continue
        out.append(prove(key, timeout_ms))
    return out
