"""Executable pins of the known XLA compiler hazards.

Both hazards were discovered empirically (PR 5) and are mitigated by
load-bearing code shapes rather than by flags — which means a compiler
upgrade can silently re-break them.  This corpus makes each hazard a
first-class, per-backend regression check with two independent probes:

* ``mitigated``      — the SHIPPED code shape still produces exact /
  in-contract results.  This is the gate: ``ok`` is ``mitigated``.
* ``hazard_present`` — the RAW (un-mitigated) shape still reproduces the
  miscompilation.  Informational only: if a future XLA stops folding,
  the pin reports it (the mitigation comment can then be retired) but
  does not fail.

Hazard 1 — **constant-folded TwoSum residual**: under jit, XLA's
algebraic simplifier rewrites ``(c + x) - c -> x`` for a constant
operand ``c``, zeroing the TwoSum residual — the paper's §5 compiler
hazard resurfacing through constant folding.  The ``(x, c)`` argument
orientation survives; ``ffmath.log1p22``'s far branch depends on it.

Hazard 2 — **x64-scope literal canonicalization**: python-float (and
``jnp.float64``) literals inside a trace-scoped ``enable_x64`` are
constant-folded at trace time and canonicalized back to f32 under the
ambient x64-off config, silently poisoning the f64 graph.  The shipped
``repro.ff.dispatch`` f64 tier derives every constant from traced values
(``one = jnp.exp(x - x)``) instead.

Expected values come from :mod:`repro.verify.oracle` (exact rational
residuals), never from another float path.
"""

from __future__ import annotations

import dataclasses
import math as _math
from typing import List, Optional

import numpy as np

from repro.verify import oracle

HAZARDS = ("constant_fold_two_sum", "x64_literal_canonicalization")
MODES = ("jit", "eager")


@dataclasses.dataclass
class HazardReport:
    hazard: str
    backend: str
    mode: str
    mitigated: bool
    hazard_present: Optional[bool]    # None when the probe can't run
    detail: str = ""

    @property
    def ok(self) -> bool:
        return self.mitigated


def _probe_grid() -> np.ndarray:
    """x with guaranteed-nonzero TwoSum residual against 1.0: magnitudes
    2^-25..2^-45 with odd significands (below 0.5 ulp(1), well above the
    residual floor)."""
    rng = np.random.default_rng(20260809)
    e = rng.integers(-45, -25, 256)
    m = rng.integers(1, 1 << 23, 256) | 1
    x = (m.astype(np.float64) / (1 << 23) + 1.0) * np.exp2(e.astype(np.float64))
    s = np.where(rng.integers(0, 2, 256) == 0, -1.0, 1.0)
    return (x * s).astype(np.float32)


def check_constant_fold_two_sum(mode: str = "jit") -> HazardReport:
    """Residual of ``two_sum(x, <constant 1>)`` must equal the exact
    rational residual bitwise (the shipped orientation); the reversed
    ``two_sum(<constant 1>, x)`` probes whether XLA still folds."""
    import jax
    import jax.numpy as jnp

    import repro.core.transforms as T

    xs = _probe_grid()
    want = np.array([oracle.round_f32(oracle.two_sum_residual(1.0, x))
                     for x in xs], np.float32)
    assert (want != 0).all()          # the grid construction guarantees it

    def shipped(x):                   # the log1p22 far-branch shape
        s, r = T.two_sum(x, jnp.ones_like(x))
        return s, r

    def raw(x):                       # the hazard shape
        s, r = T.two_sum(jnp.ones_like(x), x)
        return s, r

    if mode == "jit":
        shipped = jax.jit(shipped)
        raw = jax.jit(raw)
    _s, got = shipped(jnp.asarray(xs))
    got = np.asarray(got)
    mitigated = bool((got.view(np.uint32) == want.view(np.uint32)).all())
    _s, rgot = raw(jnp.asarray(xs))
    hazard_present = bool((np.asarray(rgot) == 0).all())
    n_bad = int((got.view(np.uint32) != want.view(np.uint32)).sum())
    return HazardReport(
        "constant_fold_two_sum", _backend(), mode, mitigated, hazard_present,
        f"{n_bad}/{xs.size} shipped-orientation residuals wrong; "
        f"raw orientation folds: {hazard_present}")


def check_x64_literal_canonicalization(mode: str = "jit") -> HazardReport:
    """The shipped f64 dispatch tier must stay in its <= 2^-47 class
    (traced-value-derived constants) without leaking x64 into the ambient
    config; the raw probe re-builds the literal-in-scope shape and asks
    whether it still canonicalizes to f32."""
    import jax
    import jax.experimental
    import jax.numpy as jnp
    from jax import lax

    import repro.ff as ff
    from repro.core.ff import FF

    rng = np.random.default_rng(42)
    x64 = rng.uniform(-4.0, 4.0, 2048)
    hi = x64.astype(np.float32)
    lo = (x64 - hi.astype(np.float64)).astype(np.float32)
    a = FF(jnp.asarray(hi), jnp.asarray(lo))
    # the f64 tier jits internally; "eager" exercises the same entry
    # point without an outer jit wrapper
    out = ff.sigmoid(a, impl="f64")
    if mode == "jit":
        out = jax.jit(lambda p: ff.sigmoid(FF(p[0], p[1]), impl="f64"))(
            (a.hi, a.lo))
    got = (np.asarray(out.hi, np.float64) + np.asarray(out.lo, np.float64))
    want = 1.0 / (1.0 + np.exp(-x64))
    rel = np.abs(got - want) / np.abs(want)
    mitigated = bool(rel.max() <= 2.0 ** -47)
    leaked = bool(jax.config.jax_enable_x64) or (
        jnp.asarray(1.0).dtype != jnp.float32)
    mitigated = mitigated and not leaked

    # raw probe: bare python-float constants inside the x64 scope (the
    # spelled-out gelu shape the dispatch comment warns about).  Today
    # the canonicalized f32 constant makes the f64 graph fail StableHLO
    # verification (mixed f32*f64 multiply) — a hard error rather than
    # silent wrongness, but proof the canonicalization still happens.
    @jax.jit
    def raw(h, l):
        with jax.experimental.enable_x64():
            x = (lax.convert_element_type(h, jnp.float64)
                 + lax.convert_element_type(l, jnp.float64))
            r = 0.5 * x * (1.0 + lax.erf(x / jnp.sqrt(jnp.asarray(2.0))))
            rhi = lax.convert_element_type(r, jnp.float32)
            rlo = lax.convert_element_type(
                r - lax.convert_element_type(rhi, jnp.float64), jnp.float32)
        return rhi, rlo

    gelu_want = (x64 / 2.0
                 * (1.0 + np.vectorize(_math.erf)(x64 / np.sqrt(2.0))))
    try:
        rh, rl = raw(a.hi, a.lo)
        rgot = np.asarray(rh, np.float64) + np.asarray(rl, np.float64)
        rrel = (np.abs(rgot - gelu_want)
                / np.maximum(np.abs(gelu_want), 1e-300))
        hazard_present = bool(rrel.max() > 2.0 ** -40)
        raw_note = f"raw literal shape rel={rrel.max():.2e}"
    except ValueError as e:
        # canonicalized constant -> type-mismatched graph: hazard alive
        hazard_present = True
        raw_note = f"raw literal shape fails lowering ({str(e)[:60]}...)"
    except Exception as e:                    # probe is best-effort
        hazard_present = None
        raw_note = f"raw probe failed: {e!r}"
    return HazardReport(
        "x64_literal_canonicalization", _backend(), mode, mitigated,
        hazard_present,
        f"shipped f64 tier rel={rel.max():.2e} (<= 2^-47 required), "
        f"x64 leak={leaked}; {raw_note}")


def _backend() -> str:
    import jax
    return jax.default_backend()


def run_corpus(modes=MODES) -> List[HazardReport]:
    out = []
    for mode in modes:
        out.append(check_constant_fold_two_sum(mode))
        out.append(check_x64_literal_canonicalization(mode))
    return out
