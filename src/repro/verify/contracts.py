"""The proof-status registry: every machine-checked contract, one place.

``docs/VERIFY.md`` embeds :func:`render_table` output between marker
comments and ``tests/test_docs.py`` re-renders and compares — the doc
cannot drift from this registry.  The same test gates the proof-status
column of ``docs/NUMERICS.md`` against :data:`NUMERICS_STATUS`.

Status vocabulary (weakest claim wins when tiers disagree):

* ``proved``  — an SMT obligation over all binary32 inputs in the stated
  domain discharges UNSAT (:mod:`repro.verify.smt`); the traced formula
  comes from the live code path, and tier-1 pins that path bitwise even
  when z3 is absent.
* ``swept``   — every documented seam/boundary input class is enumerated
  exhaustively on the f32 grid and adjudicated against the beyond-f64
  oracle (:mod:`repro.verify.sweeps`).
* ``sampled`` — randomized/property testing only (hypothesis + fixed
  rng grids in tier-1).
* ``pinned``  — an executable regression pin of a known-hazard behavior
  (:mod:`repro.verify.hazards`), not a correctness bound.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

BEGIN = "<!-- BEGIN VERIFY CONTRACTS (generated: repro.verify.contracts) -->"
END = "<!-- END VERIFY CONTRACTS -->"

STATUSES = ("proved", "swept", "sampled", "pinned")


@dataclasses.dataclass(frozen=True)
class Contract:
    name: str          # e.g. "two_sum.residual_exact"
    claim: str         # one-line statement of the obligation
    domain: str        # input domain the claim holds on
    status: str        # proved | swept | sampled | pinned
    checked_by: str    # module/obligation/seam keys discharging it

    def __post_init__(self):
        assert self.status in STATUSES, self.status


def _c(name, claim, domain, status, checked_by):
    return Contract(name, claim, domain, status, checked_by)


CONTRACTS: List[Contract] = [
    # --- EFT exactness (SMT tier; both namespaces) ---------------------
    _c("two_sum.residual_exact",
       "s + r == a + b exactly (Knuth 6-flop TwoSum)",
       "all intermediates normal-or-zero (paper §6.1)",
       "proved", "smt:two_sum_residual_exact[kernels|core]"),
    _c("fast_two_sum.residual_exact",
       "s + r == a + b exactly (Dekker 3-flop, |a| >= |b|)",
       "|a| >= |b| or a == 0; intermediates normal-or-zero",
       "proved", "smt:fast_two_sum_residual_exact[kernels|core]"),
    _c("two_prod.residual_exact",
       "x + y == a * b exactly (Dekker split product)",
       "|a|,|b| in [2^-100, 2^115] or zero; intermediates normal-or-zero",
       "proved", "smt:two_prod_residual_exact[kernels|core]"),
    # --- FF algorithm error bounds (SMT tier) --------------------------
    _c("add22.sloppy_thm5_bound",
       "delta <= max(2^-24 |al+bl|, 2^-44 |a+b|)  (paper Thm 5)",
       "normalized pairs, hi WLOG in [1,2) by scale invariance",
       "proved", "smt:add22_sloppy_thm5_bound[kernels|core]"),
    _c("add22_accurate.rel_bound",
       "relative error <= 2^-44 unconditionally",
       "normalized pairs, hi WLOG in [1,2)",
       "proved", "smt:add22_accurate_rel_bound_2pow44[core]"),
    _c("mul22.rel_bound",
       "relative error <= 2^-44  (paper Thm 6 class)",
       "normalized pairs, hi WLOG in [1,2)",
       "proved", "smt:mul22_rel_bound_2pow44[kernels|core]"),
    _c("div22.rel_bound",
       "relative error <= 2^-43 class",
       "normalized pairs, hi WLOG in [1,2)",
       "proved", "smt:div22_rel_bound_2pow43[kernels|core] (heavy tier)"),
    _c("sqrt22.rel_bound",
       "relative error <= 2^-44 class",
       "normalized pair, hi WLOG in [1,4) (even-binade scaling)",
       "proved", "smt:sqrt22_rel_bound_2pow44[kernels|core] (heavy tier)"),
    _c("eft.kernels_equals_core",
       "barrier-free kernel limbs == barrier-carrying core limbs, bitwise",
       "intermediates normal-or-zero",
       "proved", "smt:*_kernels_equals_core[both]"),
    # --- ff.math seam coverage (sweep tier; beyond-f64 oracle) ---------
    _c("ffmath.exp.seams",
       "|rel err| <= 2^-42 on every Cody-Waite k-boundary, clip edge, "
       "lo-flush band, identity band, tiny/subnormal class, and specials",
       "exhaustive f32 neighborhoods per seam (oracle >= 60 bits)",
       "swept", "sweeps:exp/* (registry: ffmath.reduction_seams)"),
    _c("ffmath.log.seams",
       "|rel err| <= 2^-42 on binade boundaries, sqrt(2)-fold points, "
       "near-one cancellation band, and specials",
       "exhaustive f32 neighborhoods per seam (oracle >= 60 bits)",
       "swept", "sweeps:log/*"),
    _c("ffmath.tanh.seams",
       "|rel err| <= 2^-41 on the 0.35 small/large seam, expm1 "
       "k-boundaries, saturation window, identity band, and specials",
       "exhaustive f32 neighborhoods per seam (oracle >= 60 bits)",
       "swept", "sweeps:tanh/*"),
    _c("ffmath.other.bounds",
       "documented full-domain bounds for expm1/log1p/sigmoid/erf/"
       "gelu/silu",
       "fixed rng grids + hypothesis adversarial-limb strategies",
       "sampled", "tests:test_ff_math.py, test_property_ff.py"),
    # --- executable hazard pins ----------------------------------------
    _c("hazard.constant_fold_two_sum",
       "two_sum(literal, x) residual constant-folds to zero under jit; "
       "the (x, literal) orientation survives",
       "per backend, jit and eager",
       "pinned", "hazards:constant_fold_two_sum"),
    _c("hazard.x64_literal_canonicalization",
       "python-float literals inside trace-scoped enable_x64 canonicalize "
       "to f32; traced-value-derived constants survive (f64 impl <= 2^-47)",
       "per backend, jit and eager",
       "pinned", "hazards:x64_literal_canonicalization"),
    _c("guard.subnormal_lo_census",
       "guard_probe's bit-level denormal-lo counter agrees with the "
       "oracle's DAZ-immune classification",
       "bit-constructed subnormal/normal/zero grid",
       "pinned", "tests:test_verify_oracle.py::test_guard_census_matches_oracle"),
]

# NUMERICS.md contract-table rows (matched by the literal first-cell
# token) must carry exactly this status in their proof-status column;
# tests/test_docs.py enforces the pairing line by line.
NUMERICS_STATUS: Dict[str, str] = {
    "`ff.two_sum(a, b)`": "proved",
    "`ff.two_prod(a, b)`": "proved",
    "`ff.add` (`jnp`/`pallas`, sloppy Add22)": "proved",
    "`ff.add` (`accurate`)": "proved",
    "`ff.mul` (Mul22)": "proved",
    "`ff.div`": "proved",
    "`ff.sqrt`": "proved",
    "`ff.exp`": "swept",
    "`ff.log`": "swept",
    "`ff.tanh`": "swept",
    "`ff.expm1`": "sampled",
    "`ff.log1p`": "sampled",
    "`ff.sigmoid`": "sampled",
    "`ff.erf`": "sampled",
    "`ff.gelu`": "sampled",
    "`ff.silu`": "sampled",
    "`ff.pow`": "sampled",
}


def render_table() -> str:
    """The markdown table embedded in docs/VERIFY.md (between markers)."""
    lines = [
        "| contract | claim | domain | status | checked by |",
        "|---|---|---|---|---|",
    ]
    for c in CONTRACTS:
        lines.append(
            f"| `{c.name}` | {c.claim} | {c.domain} | **{c.status}** "
            f"| `{c.checked_by}` |")
    return "\n".join(lines)


def extract_table(doc_text: str) -> str:
    m = re.search(re.escape(BEGIN) + r"\n(.*?)\n" + re.escape(END),
                  doc_text, re.S)
    if not m:
        raise ValueError("VERIFY contract markers not found in document")
    return m.group(1).strip()


def check_doc(doc_text: str) -> Tuple[bool, str]:
    """True iff the doc's embedded table matches the registry exactly."""
    try:
        got = extract_table(doc_text)
    except ValueError as e:
        return False, str(e)
    want = render_table()
    if got != want:
        return False, ("embedded table is stale — regenerate with "
                       "python -c \"from repro.verify.contracts import "
                       "render_table; print(render_table())\"")
    return True, "ok"


def summary() -> Dict[str, int]:
    out = {s: 0 for s in STATUSES}
    for c in CONTRACTS:
        out[c.status] += 1
    return out
