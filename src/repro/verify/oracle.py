"""Beyond-f64 oracle: exact f32 bit semantics + extended-precision math.

Two layers, both pure Python (no jax — the oracle must not share a
single rounding path with the code under test):

  * **Exact integer layer** — ``fractions.Fraction`` values of f32/f64
    bit patterns, correct round-to-nearest-even ``round_f32`` (scale-and
    -round on integer significands: NO double rounding through f64), and
    bit-level classification (zero/subnormal/normal/inf/nan) that a DAZ
    backend cannot flush, because it never compares floats.  The EFT
    residual ground truths (``two_sum``/``two_prod`` residuals are
    *definitionally* exact rationals) live here.
  * **mpmath layer** — elementary-function references at >= 60 bits
    (default 120) with exactly-converted f32 inputs, for contracts the
    f64 oracle cannot resolve: the 2^-47-class claims sit only ~6 bits
    above f64's own 2^-52 noise floor.

The existing f64 oracle (numpy) remains the fast *screen* in
``repro.verify.sweeps``; every decision within ``SCREEN_MARGIN`` of a
contract boundary is re-adjudicated here.
"""

from __future__ import annotations

import math
from fractions import Fraction
from typing import Iterable, Tuple

import numpy as np

# f32 format constants (paper §4: binary32, p = 24)
F32_PREC = 24
F32_EMAX = 127
F32_EMIN = -126                       # minimum normal exponent
MIN_NORMAL = Fraction(2) ** -126
MIN_SUBNORMAL = Fraction(2) ** -149
MAX_FINITE = (Fraction(2) - Fraction(2) ** -23) * Fraction(2) ** 127
# IEEE RN overflow threshold: |x| >= 2^128 - 2^103 rounds to inf
OVERFLOW_THRESHOLD = Fraction(2) ** 128 - Fraction(2) ** 103

DEFAULT_PREC = 120                    # bits; contract requires >= 60


def _mp(prec_bits: int):
    """mpmath with a local precision context (lazy import so the package
    imports even where mpmath is missing; callers get a clear error)."""
    try:
        import mpmath
    except ImportError as e:                      # pragma: no cover
        raise ImportError(
            "repro.verify.oracle needs mpmath for extended-precision "
            "references (the exact integer layer works without it)") from e
    return mpmath


# ---------------------------------------------------------------------------
# exact integer layer
# ---------------------------------------------------------------------------

def f32_bits(x) -> int:
    """The raw bit pattern of a binary32 value, via numpy view (never a
    float compare — subnormal limbs survive DAZ hardware)."""
    return int(np.float32(x).view(np.uint32))


def bits_f32(bits: int) -> np.float32:
    return np.uint32(bits).view(np.float32)


def classify_bits(bits: int) -> str:
    """'zero' | 'subnormal' | 'normal' | 'inf' | 'nan' from the bit
    pattern alone."""
    e = (bits >> 23) & 0xFF
    m = bits & 0x7FFFFF
    if e == 0xFF:
        return "nan" if m else "inf"
    if e == 0:
        return "subnormal" if m else "zero"
    return "normal"


def classify_f32(x) -> str:
    return classify_bits(f32_bits(x))


def exact(x) -> Fraction:
    """The exact rational value of a finite float (f32 or f64 — both are
    dyadic rationals; Fraction(float) is exact by construction)."""
    xf = float(x)
    if not math.isfinite(xf):
        raise ValueError(f"exact() is defined for finite values, got {x!r}")
    return Fraction(xf)


def ff_exact(hi, lo) -> Fraction:
    """The exact value represented by an FF pair (unevaluated hi + lo)."""
    return exact(hi) + exact(lo)


def ulp32(x) -> Fraction:
    """ulp of the binade containing finite nonzero x (2^(e - 23); the
    subnormal range shares 2^-149)."""
    fx = abs(float(x))
    if fx == 0.0 or fx < float(MIN_NORMAL):
        return MIN_SUBNORMAL
    e = math.floor(math.log2(fx))
    # guard the binade edge: log2 can land one off at powers of two
    if Fraction(2) ** e > Fraction(fx):
        e -= 1
    elif Fraction(2) ** (e + 1) <= Fraction(fx):
        e += 1
    return Fraction(2) ** (e - 23)


def round_f32(value: Fraction) -> float:
    """Correct IEEE-754 binary32 round-to-nearest-even of an exact
    rational, on integer significands — ``np.float32(float(v))`` would
    double-round through binary64 and is wrong on (rare) f64 midpoints.

    Returns a python float (exactly representing the f32 result, or
    +-inf on overflow)."""
    if value == 0:
        return 0.0
    sign = -1.0 if value < 0 else 1.0
    v = abs(value)
    # exponent e with 2^e <= v < 2^(e+1)
    e = v.numerator.bit_length() - v.denominator.bit_length()
    if Fraction(2) ** e > v:
        e -= 1
    elif Fraction(2) ** (e + 1) <= v:
        e += 1
    # quantum: normal binades carry 2^(e-23); below 2^-126 it is fixed
    q_exp = max(e - 23, -149)
    scaled = v / (Fraction(2) ** q_exp)          # significand in quanta
    n, r = divmod(scaled.numerator, scaled.denominator)
    half = Fraction(r, scaled.denominator)       # fractional part in [0,1)
    if half > Fraction(1, 2) or (half == Fraction(1, 2) and n % 2 == 1):
        n += 1
    result = Fraction(n) * Fraction(2) ** q_exp
    if result >= OVERFLOW_THRESHOLD:
        return math.inf * sign
    return sign * float(result)                  # dyadic, exact in f64


def two_sum_residual(a, b) -> Fraction:
    """The exact TwoSum residual a + b - fl32(a + b) (Møller/Knuth: it is
    itself f32-representable, which the SMT tier proves; here it is just
    exact rational arithmetic)."""
    s = round_f32(exact(a) + exact(b))
    if not math.isfinite(s):
        raise OverflowError("two_sum residual undefined at overflow")
    return exact(a) + exact(b) - Fraction(s)


def two_prod_residual(a, b) -> Fraction:
    """The exact TwoProd residual a * b - fl32(a * b)."""
    p = round_f32(exact(a) * exact(b))
    if not math.isfinite(p):
        raise OverflowError("two_prod residual undefined at overflow")
    return exact(a) * exact(b) - Fraction(p)


def nearest_ff(value: Fraction) -> Tuple[float, float]:
    """The FF pair (hi, lo) nearest an exact value: hi = fl32(v),
    lo = fl32(v - hi) — the representability floor every FF contract is
    measured against."""
    hi = round_f32(value)
    if not math.isfinite(hi):
        return hi, 0.0
    lo = round_f32(value - Fraction(hi))
    return hi, lo


# ---------------------------------------------------------------------------
# mpmath layer: elementary references beyond f64
# ---------------------------------------------------------------------------

def math_ref(fn: str, x, prec_bits: int = DEFAULT_PREC):
    """Reference value of an ``ff.math`` unary at >= ``prec_bits`` bits.

    ``x`` may be a float or an exact Fraction (FF inputs: pass
    ``ff_exact(hi, lo)``).  Returns an mpmath mpf computed with
    ``prec_bits + 10`` working bits (so the returned value is good to
    ``prec_bits``)."""
    mp = _mp(prec_bits)
    with mp.workprec(prec_bits + 10):
        if isinstance(x, Fraction):
            v = mp.mpf(x.numerator) / mp.mpf(x.denominator)
        else:
            v = mp.mpf(float(x))
        if fn == "exp":
            return mp.exp(v)
        if fn == "expm1":
            return mp.expm1(v)
        if fn == "log":
            # stay on the real line: mpmath.log(-1) is complex pi*i
            if v < 0:
                return mp.nan
            return mp.mpf("-inf") if v == 0 else mp.log(v)
        if fn == "log1p":
            if v < -1:
                return mp.nan
            return mp.mpf("-inf") if v == -1 else mp.log1p(v)
        if fn == "tanh":
            return mp.tanh(v)
        if fn == "sigmoid":
            return 1 / (1 + mp.exp(-v))
        if fn == "erf":
            return mp.erf(v)
        if fn == "gelu":
            return v / 2 * (1 + mp.erf(v / mp.sqrt(2)))
        if fn == "silu":
            return v / (1 + mp.exp(-v))
        raise ValueError(f"no oracle for ff.math fn {fn!r}")


def rel_errors(fn: str, xs, got_hi, got_lo,
               prec_bits: int = DEFAULT_PREC) -> np.ndarray:
    """Relative error |(hi + lo) - f(x)| / |f(x)| per point, with the
    difference taken at ``prec_bits`` working precision (the FF value
    enters exactly; only the final quotient rounds — the result is an
    f64 array of error *magnitudes*, where f64 resolution costs nothing).

    Points where the reference is 0, or non-finite (input or reference)
    yield: 0.0 when the FF value matches the reference bit-class (same
    nan-ness / same infinity / both zero), inf otherwise."""
    mp = _mp(prec_bits)
    xs = np.asarray(xs)
    got_hi = np.asarray(got_hi, np.float64)
    got_lo = np.asarray(got_lo, np.float64)
    out = np.empty(xs.shape, np.float64)
    with mp.workprec(prec_bits + 10):
        for i in np.ndindex(xs.shape):
            x = float(xs[i])
            gh, gl = got_hi[i], got_lo[i]
            if not math.isfinite(x):
                if math.isnan(x):
                    out[i] = 0.0 if math.isnan(gh) else math.inf
                    continue
                want = _INF_LIMITS[fn][0 if x < 0 else 1]
                if math.isnan(want):
                    out[i] = 0.0 if math.isnan(gh) else math.inf
                else:
                    out[i] = _special_err(gh, gl, want)
                continue
            w = math_ref(fn, x, prec_bits)
            wf = float(w)
            if math.isnan(wf):
                out[i] = 0.0 if math.isnan(gh) else math.inf
                continue
            if math.isinf(wf):                    # e.g. log(+-0) -> -inf
                out[i] = _special_err(gh, gl, wf)
                continue
            if not (math.isfinite(gh) and math.isfinite(gl)):
                # overflow saturation is checked by the caller's
                # classification pass; an inf against a finite want is a
                # violation unless want itself rounds to inf in f32
                out[i] = 0.0 if (math.isinf(gh) and abs(float(w)) >=
                                 float(OVERFLOW_THRESHOLD)) else math.inf
                continue
            if w == 0:
                out[i] = 0.0 if (gh == 0.0 and gl == 0.0) else math.inf
                continue
            err = (mp.mpf(gh) + mp.mpf(gl)) - w
            out[i] = abs(float(err / w))
    return out


# f(-inf), f(+inf) limits per ff.math unary (nan = IEEE domain error)
_INF_LIMITS = {
    "exp": (0.0, math.inf), "expm1": (-1.0, math.inf),
    "log": (math.nan, math.inf), "log1p": (math.nan, math.inf),
    "tanh": (-1.0, 1.0), "sigmoid": (0.0, 1.0), "erf": (-1.0, 1.0),
    "gelu": (0.0, math.inf), "silu": (0.0, math.inf),
}


def _special_err(gh: float, gl: float, want: float) -> float:
    if math.isinf(want):
        return 0.0 if (math.isinf(gh) and math.copysign(1, gh) ==
                       math.copysign(1, want)) else math.inf
    if want == 0.0:
        return 0.0 if gh == 0.0 else math.inf
    return abs((gh + gl) - want) / abs(want)


def self_check(prec_bits: int = DEFAULT_PREC) -> dict:
    """Certify the oracle against itself at double precision-budget and
    against closed-form constants; returns the measured agreement (used
    by ``python -m repro.verify`` and the oracle tests)."""
    mp = _mp(prec_bits)
    probes = {"exp": 0.5, "log": 1.5, "tanh": 0.35, "erf": 0.75}
    worst = 0.0
    for fn, x in probes.items():
        a = math_ref(fn, x, prec_bits)
        b = math_ref(fn, x, 2 * prec_bits)
        with mp.workprec(2 * prec_bits):
            d = abs(float((mp.mpf(a) - mp.mpf(b)) / mp.mpf(b)))
        worst = max(worst, d)
    with mp.workprec(prec_bits + 10):
        e_err = abs(float(math_ref("exp", 1.0, prec_bits) - mp.e))
    return {"prec_bits": prec_bits,
            "cross_prec_rel": worst,
            "exp1_vs_e_abs": e_err,
            "certified_bits": math.inf if worst == 0 else -math.log2(worst)}


def count_classes(values: Iterable) -> dict:
    """Bit-level class census of a vector (the guard_probe cross-check:
    ``denormal_lo`` must equal ``counts['subnormal']`` on any grid,
    DAZ or not)."""
    counts = {"zero": 0, "subnormal": 0, "normal": 0, "inf": 0, "nan": 0}
    arr = np.asarray(values, np.float32).ravel()
    for b in arr.view(np.uint32):
        counts[classify_bits(int(b))] += 1
    return counts
