"""Symbolic execution of the LIVE raw-limb EFT code paths.

The SMT obligations in :mod:`repro.verify.smt` are not transcriptions of
the paper's algorithms — they are built by *running the very functions
the dispatch registry executes* over a pluggable scalar type:

  * ``repro.kernels.eft`` — the barrier-free raw-limb primitives Pallas
    kernel bodies use;
  * ``repro.core.transforms`` / ``repro.core.ff`` — the barrier-carrying
    twins behind every ``jnp`` implementation.

Editing a kernel sequence therefore changes the generated formula, and
the proof (or the always-on bitwise cross-check) re-adjudicates the
edit; there is no copy to go stale.

Two backends share one tracer:

  * :class:`NumpyBackend` — values are f32 numpy scalars/arrays; every
    traced op rounds exactly as the EFT-safe ISA contract demands (IEEE
    round-to-nearest, no FMA).  Always available: tier-1 pins the traced
    path bitwise against the real jnp execution
    (``tests/test_verify_smt.py::test_traced_path_matches_live``).
  * :class:`Z3Backend` — values are z3 Float32 terms (QF_FP, RNE); every
    op also records its term, so obligations can restrict the domain to
    the paper's all-intermediates-normal-or-zero region (§6.1 — the
    range where IEEE and flush-to-zero semantics coincide).

The tracer works by swapping the ``jnp``/``lax`` module bindings of the
traced modules for proxies inside a context manager (single-threaded
use only, like the rest of the test tier).  ``Sym`` sets
``__array_ufunc__ = None`` so numpy scalars defer to its reflected
operators instead of coercing it into an object array.
"""

from __future__ import annotations

import contextlib
from typing import Callable, Dict, Tuple

import numpy as np

__all__ = ["Sym", "NumpyBackend", "Z3Backend", "live_paths", "eft_fns",
           "run_traced", "NAMESPACES", "RAW_LIMB_OPS"]

# the raw-limb entry points under proof, per namespace
RAW_LIMB_OPS = ("two_sum", "fast_two_sum", "two_prod", "add22",
                "add22_accurate", "mul22", "div22", "sqrt22")
NAMESPACES = ("kernels", "core")


class Sym:
    """A scalar flowing through the live EFT code: wraps a backend value
    and funnels every arithmetic op through the backend's rounded
    primitives."""

    __slots__ = ("val", "be")
    __array_ufunc__ = None            # numpy scalars must defer to us
    __array_priority__ = 1000

    def __init__(self, val, be):
        self.val = val
        self.be = be

    @property
    def dtype(self):                  # satisfies transforms._f32's check
        import jax.numpy as jnp
        return jnp.float32

    def _lift(self, other):
        if isinstance(other, Sym):
            return other.val
        return self.be.const(other)

    def __add__(self, other):
        return Sym(self.be.add(self.val, self._lift(other)), self.be)

    def __radd__(self, other):
        return Sym(self.be.add(self._lift(other), self.val), self.be)

    def __sub__(self, other):
        return Sym(self.be.sub(self.val, self._lift(other)), self.be)

    def __rsub__(self, other):
        return Sym(self.be.sub(self._lift(other), self.val), self.be)

    def __mul__(self, other):
        return Sym(self.be.mul(self.val, self._lift(other)), self.be)

    def __rmul__(self, other):
        return Sym(self.be.mul(self._lift(other), self.val), self.be)

    def __truediv__(self, other):
        return Sym(self.be.div(self.val, self._lift(other)), self.be)

    def __rtruediv__(self, other):
        return Sym(self.be.div(self._lift(other), self.val), self.be)

    def __neg__(self):
        return Sym(self.be.neg(self.val), self.be)

    def __repr__(self):
        return f"Sym({self.val!r})"


class NumpyBackend:
    """Concrete f32 semantics: numpy scalar/array ops ARE IEEE RN without
    contraction — the reference the bitwise cross-check runs on."""

    name = "numpy"

    @staticmethod
    def _f32(r):
        return np.asarray(r, np.float32)    # scalar -> 0-d, arrays pass

    def const(self, v):
        return np.float32(v)

    @classmethod
    def add(cls, a, b):
        with np.errstate(all="ignore"):
            return cls._f32(a + b)

    @classmethod
    def sub(cls, a, b):
        with np.errstate(all="ignore"):
            return cls._f32(a - b)

    @classmethod
    def mul(cls, a, b):
        with np.errstate(all="ignore"):
            return cls._f32(a * b)

    @classmethod
    def div(cls, a, b):
        with np.errstate(all="ignore"):
            return cls._f32(a / b)

    @classmethod
    def neg(cls, a):
        return cls._f32(-a)

    @classmethod
    def sqrt(cls, a):
        with np.errstate(all="ignore"):
            return cls._f32(np.sqrt(a))

    def lift(self, arr):
        return Sym(np.asarray(arr, np.float32), self)


class Z3Backend:
    """z3 Float32 (QF_FP) semantics under RNE.  Records every rounded
    intermediate in ``trace`` so obligations can constrain the whole
    evaluation to the normal-or-zero domain (and to finiteness)."""

    name = "z3"

    def __init__(self, z3):
        self.z3 = z3
        self.sort = z3.FPSort(8, 24)
        self.rm = z3.RNE()
        self.trace = []

    def _rec(self, t):
        self.trace.append(t)
        return t

    def var(self, name: str):
        """A fresh Float32 input variable (recorded: inputs must satisfy
        the domain constraints too)."""
        return self._rec(self.z3.FP(name, self.sort))

    def const(self, v):
        return self.z3.FPVal(float(v), self.sort)

    def add(self, a, b):
        return self._rec(self.z3.fpAdd(self.rm, a, b))

    def sub(self, a, b):
        return self._rec(self.z3.fpSub(self.rm, a, b))

    def mul(self, a, b):
        return self._rec(self.z3.fpMul(self.rm, a, b))

    def div(self, a, b):
        return self._rec(self.z3.fpDiv(self.rm, a, b))

    def neg(self, a):
        return self.z3.fpNeg(a)      # sign flip: exact, not flushed

    def sqrt(self, a):
        return self._rec(self.z3.fpSqrt(self.rm, a))

    def lift(self, name):
        return Sym(self.var(name), self)

    def domain_constraints(self):
        """normal-or-zero for every recorded value: the paper §6.1 domain
        where EFT exactness is claimed AND where IEEE semantics (what z3
        models) coincide with the flush-to-zero hardware."""
        z3 = self.z3
        return [z3.Or(z3.fpIsZero(t), z3.fpIsNormal(t)) for t in self.trace]


class _ModuleProxy:
    """Forwards attribute access to a real module, with Sym-aware
    overrides for the few entry points the raw-limb code paths touch."""

    def __init__(self, real, overrides):
        self._real = real
        self._overrides = overrides

    def __getattr__(self, name):
        if name in self._overrides:
            return self._overrides[name]
        return getattr(self._real, name)


def _sym_sqrt(real_sqrt):
    def sqrt(x):
        if isinstance(x, Sym):
            return Sym(x.be.sqrt(x.val), x.be)
        return real_sqrt(x)
    return sqrt


def _sym_asarray(real_asarray):
    def asarray(x, *a, **kw):
        if isinstance(x, Sym):
            return x
        return real_asarray(x, *a, **kw)
    return asarray


def _sym_barrier(real_barrier):
    def optimization_barrier(x):
        # symbolically each op is individually rounded already — the
        # barrier's only job (pinning fl(a*b) against fusion) is a no-op
        if isinstance(x, Sym):
            return x
        return real_barrier(x)
    return optimization_barrier


@contextlib.contextmanager
def live_paths():
    """Patch the jnp/lax bindings of the modules under trace so their
    UNMODIFIED function bodies execute over Sym scalars; restores on
    exit.  Not thread-safe (test/report tier only)."""
    import jax.numpy as jnp
    from jax import lax

    import repro.core.ff as core_ff
    import repro.core.transforms as T
    import repro.kernels.eft as KE

    jnp_proxy = _ModuleProxy(jnp, {
        "sqrt": _sym_sqrt(jnp.sqrt),
        "asarray": _sym_asarray(jnp.asarray),
    })
    lax_proxy = _ModuleProxy(lax, {
        "optimization_barrier": _sym_barrier(lax.optimization_barrier),
    })
    saved = [(KE, "jnp", KE.jnp), (T, "jnp", T.jnp), (T, "lax", T.lax),
             (core_ff, "jnp", core_ff.jnp)]
    try:
        KE.jnp = jnp_proxy
        T.jnp = jnp_proxy
        T.lax = lax_proxy
        core_ff.jnp = jnp_proxy
        yield
    finally:
        for mod, attr, val in saved:
            setattr(mod, attr, val)


def eft_fns(namespace: str) -> Dict[str, Callable]:
    """The live raw-limb callables per namespace, uniform signature
    ``fn(*limbs) -> (hi, lo)``.

    ``kernels`` — ``repro.kernels.eft`` (what Pallas kernel bodies run).
    ``core``    — ``repro.core.transforms`` EFTs + the ``core.ff``
    algorithms (what every jnp impl runs).  ``add22_accurate`` only
    exists in core (the registry's ``accurate`` add impl)."""
    import repro.core.ff as core_ff
    import repro.core.transforms as T
    import repro.kernels.eft as KE

    if namespace == "kernels":
        return {
            "two_sum": KE.two_sum,
            "fast_two_sum": KE.fast_two_sum,
            "two_prod": KE.two_prod,
            "add22": KE.add22,
            "mul22": KE.mul22,
            "div22": KE.div22,
            "sqrt22": lambda ah, al: KE.sqrt22(ah, al),
        }
    if namespace == "core":
        def _ff2(fn):
            def call(ah, al, bh, bl):
                r = fn(core_ff.FF(ah, al), core_ff.FF(bh, bl))
                return r.hi, r.lo
            return call

        return {
            "two_sum": T.two_sum,
            "fast_two_sum": T.fast_two_sum,
            "two_prod": T.two_prod,
            "add22": _ff2(core_ff.add22),
            "add22_accurate": _ff2(core_ff.add22_accurate),
            "mul22": _ff2(core_ff.mul22),
            "div22": _ff2(core_ff.div22),
            "sqrt22": lambda ah, al: (lambda r: (r.hi, r.lo))(
                core_ff.sqrt22(core_ff.FF(ah, al))),
        }
    raise ValueError(f"unknown namespace {namespace!r}")


def run_traced(namespace: str, fn_name: str, backend, args) -> Tuple:
    """Execute the live ``namespace.fn_name`` body over backend scalars.

    ``args``: backend values (or things ``backend.lift`` accepts when a
    plain array/name is given).  Returns the tuple of raw output values
    (unwrapped from Sym)."""
    fns = eft_fns(namespace)
    syms = [a if isinstance(a, Sym) else backend.lift(a) for a in args]
    with live_paths():
        out = fns[fn_name](*syms)
    return tuple(o.val if isinstance(o, Sym) else o for o in out)
