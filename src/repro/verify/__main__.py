"""``python -m repro.verify`` — run every verification tier, emit a report.

Exit status is nonzero only on a genuine contract failure (an SMT
counterexample, a sweep violation, a hazard-mitigation regression, or a
failed trace pin).  Missing optional dependencies (z3) downgrade the
affected tier to ``skipped`` — the CI ``verify`` job exercises that path
explicitly to prove skip-not-fail.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys


def _trace_pins() -> dict:
    """The always-on bitwise pin: the symbolically-traced formulas come
    from the live code (NumpyBackend vs real jnp execution)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.verify import symtrace

    rng = np.random.default_rng(20260809)
    n = 512
    a = (rng.standard_normal(n) * np.exp2(rng.integers(-20, 20, n))
         ).astype(np.float32)
    b = (rng.standard_normal(n) * np.exp2(rng.integers(-20, 20, n))
         ).astype(np.float32)
    al = (a * np.float32(2 ** -25)).astype(np.float32)
    bl = (b * np.float32(2 ** -25)).astype(np.float32)
    be = symtrace.NumpyBackend()
    out = {}
    for ns in symtrace.NAMESPACES:
        fns = symtrace.eft_fns(ns)
        for name, fn in fns.items():
            if name == "sqrt22":
                args = [np.abs(a) + np.float32(0.5), al]
            elif name in ("two_sum", "fast_two_sum", "two_prod"):
                if name == "fast_two_sum":
                    hi = np.where(np.abs(a) >= np.abs(b), a, b)
                    lo = np.where(np.abs(a) >= np.abs(b), b, a)
                    args = [hi, lo]
                else:
                    args = [a, b]
            else:
                args = [a, al, b, bl]
            traced = symtrace.run_traced(ns, name, be, args)
            live = fn(*[jnp.asarray(x) for x in args])
            ok = all(
                bool(np.all((np.asarray(t, np.float32).view(np.uint32)
                             == np.asarray(l, np.float32).view(np.uint32))
                            | (np.isnan(np.asarray(t, np.float32))
                               & np.isnan(np.asarray(l, np.float32)))))
                for t, l in zip(traced, live))
            out[f"{ns}.{name}"] = "ok" if ok else "MISMATCH"
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.verify")
    ap.add_argument("--out", default=None, help="write the JSON report here")
    ap.add_argument("--budget", type=int, default=1 << 16,
                    help="sweep points per seam (default 2^16)")
    ap.add_argument("--smt-timeout-ms", type=int, default=None,
                    help="per-obligation solver timeout "
                         "(default: VERIFY_SMT_TIMEOUT_MS or 600000)")
    ap.add_argument("--heavy", action="store_true",
                    help="include the heavy SMT obligations (div/sqrt)")
    ap.add_argument("--skip-smt", action="store_true")
    ap.add_argument("--skip-sweeps", action="store_true")
    ap.add_argument("--skip-hazards", action="store_true")
    args = ap.parse_args(argv)

    import os

    import jax
    import numpy as np

    from repro.verify import contracts, hazards, oracle, smt, sweeps

    report = {
        "env": {
            "python": platform.python_version(),
            "jax": jax.__version__,
            "numpy": np.__version__,
            "backend": jax.default_backend(),
            "z3": smt.have_z3(),
        },
        "contracts": {c.name: c.status for c in contracts.CONTRACTS},
    }
    failures = []

    try:
        report["oracle_self_check"] = oracle.self_check()
        if report["oracle_self_check"]["certified_bits"] < 60:
            failures.append("oracle certified below 60 bits")
    except ImportError as e:
        report["oracle_self_check"] = {"skipped": str(e)}

    report["trace_pins"] = _trace_pins()
    bad_pins = [k for k, v in report["trace_pins"].items() if v != "ok"]
    if bad_pins:
        failures.append(f"trace pins mismatch: {bad_pins}")

    if not args.skip_smt:
        timeout = args.smt_timeout_ms or int(
            os.environ.get("VERIFY_SMT_TIMEOUT_MS", smt.DEFAULT_TIMEOUT_MS))
        results = smt.prove_all(timeout, include_heavy=args.heavy)
        report["smt"] = [
            {"obligation": r.name, "namespace": r.namespace,
             "status": r.status, "seconds": round(r.seconds, 2),
             "detail": r.detail}
            for r in results]
        bad = [r for r in results if r.status == "counterexample"]
        if bad:
            failures.append(
                f"SMT counterexamples: {[r.name for r in bad]}")

    if not args.skip_hazards:
        reports = hazards.run_corpus()
        report["hazards"] = [dataclass_dict(r) for r in reports]
        bad = [r for r in reports if not r.ok]
        if bad:
            failures.append(
                f"hazard mitigations regressed: "
                f"{[(r.hazard, r.mode) for r in bad]}")

    if not args.skip_sweeps:
        results = sweeps.run_all(budget=args.budget)
        report["sweeps"] = [dataclass_dict(r) for r in results]
        bad = [r for r in results if not r.ok]
        if bad:
            failures.append(
                f"sweep violations: {[(r.seam, r.violations) for r in bad]}")

    report["failures"] = failures
    report["ok"] = not failures

    text = json.dumps(report, indent=2, default=str)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)
    if failures:
        print(f"\nFAILED: {failures}", file=sys.stderr)
        return 1
    return 0


def dataclass_dict(obj) -> dict:
    import dataclasses
    d = dataclasses.asdict(obj)
    return {k: (v if not isinstance(v, float) or v == v else "nan")
            for k, v in d.items()}


if __name__ == "__main__":
    raise SystemExit(main())
