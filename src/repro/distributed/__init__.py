"""repro.distributed substrate."""
