"""``repro.distributed`` — the SPMD substrate.

Two complementary layers:

* **Sharding rules** (:mod:`repro.distributed.sharding`,
  :mod:`repro.distributed.act_sharding`): per-tensor-kind parameter /
  batch / cache partition specs (DP/FSDP + TP + EP + pod axis) and
  activation sharding constraints — how XLA SPMD lays tensors out.
* **Mesh-partitioned FF ops** (:mod:`repro.ff.sharded`, routed via
  ``ff.on_mesh``): how FF *computation* crosses the mesh with compensated
  cross-device combining instead of naive f32 ``psum``s — see
  ``docs/DESIGN_sharded.md``.
"""

from repro.distributed.sharding import (  # noqa: F401
    batch_shardings, cache_shardings, dp_axes, dp_size, opt_state_shardings,
    param_shardings, param_spec, tp_size, validate_spec,
)
from repro.distributed.act_sharding import (  # noqa: F401
    activation_sharding, constrain, constrain_hidden,
)
