"""Per-tensor-kind sharding rules: DP/FSDP + TP + EP + pod axis.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  Conventions:

* **DP**: batch over ``("pod", "data")`` (when divisible).
* **FSDP**: every weight's d_model-like dim over ``"data"`` — XLA SPMD
  all-gathers per scan step and reduce-scatters grads (ZeRO-3 pattern).
* **TP**: head/ffn/expert dims over ``"model"``:
    - attention q/o projections TP'd iff num_heads %% tp == 0,
      k/v iff num_kv_heads %% tp == 0 (else replicated over 'model' —
      they are small precisely when kv count is small);
    - MLP d_ff over 'model';
    - MoE experts over 'model' (EP);
    - vocab over 'model' (turns the logits loss reduction into
      reduce-scatter + all-gather instead of a fat all-reduce).
* **Caches**: KV cache sequence dim over 'model' (head counts are rarely
  divisible), batch over DP when divisible; SSD state heads over 'model'.

Every rule is a function of (leaf path, leaf, config, mesh) so new
architectures compose without per-model hacks.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

Params = Any


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tp_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------

# name -> (base_ndim, spec builder)
def _param_rule(name: str, ndim: int, cfg: ModelConfig, tp: int):
    heads_tp = cfg.num_heads % tp == 0
    kv_tp = (cfg.num_kv_heads % tp == 0) and cfg.num_kv_heads >= tp
    ssd_tp = cfg.ssm_d_inner % (tp * cfg.ssm_head_dim) == 0

    table: Dict[str, Tuple[int, Tuple]] = {
        # embeddings
        "tok": (2, ("model", "data")),
        "unembed": (2, ("data", "model")),
        "patch_proj": (2, ("data", None)),
        # attention
        "wq": (2, ("data", "model") if heads_tp else ("data", None)),
        "wo": (2, ("model", "data") if heads_tp else (None, "data")),
        "wk": (2, ("data", "model") if kv_tp else ("data", None)),
        "wv": (2, ("data", "model") if kv_tp else ("data", None)),
        # MLA
        "wq_a": (2, ("data", None)),
        "wq_b": (2, (None, "model") if heads_tp else (None, None)),
        "wkv_a": (2, ("data", None)),
        "wk_b": (2, (None, "model") if heads_tp else (None, None)),
        "wv_b": (2, (None, "model") if heads_tp else (None, None)),
        # dense mlp (2D) / moe experts (3D)
        "w_gate": (2, ("data", "model")),
        "w_up": (2, ("data", "model")),
        "w_down": (2, ("model", "data")),
        "router": (2, ("data", None)),
        # ssd
        "w_z": (2, ("data", "model") if ssd_tp else ("data", None)),
        "w_x": (2, ("data", "model") if ssd_tp else ("data", None)),
        "w_bc": (2, ("data", None)),
        "w_dt": (2, ("data", None)),
        "out_proj": (2, ("model", "data") if ssd_tp else (None, "data")),
    }
    # NOTE: MoE expert tensors (E,d,f) are routed in param_spec (which can
    # check the path for ffn_moe/router siblings); returning a MoE spec here
    # based on ndim alone mis-sharded stacked dense (L,d,f) weights.
    return table.get(name, None)


def _leading_pad(spec: Tuple, leaf_ndim: int, mesh: Optional[Mesh] = None) -> P:
    base = len(spec)
    pad = leaf_ndim - base
    if pad < 0:
        # scalar-ish leaf (e.g. rank cut by vmap) — replicate
        return P()
    spec = tuple(spec)
    if mesh is not None:
        # FSDP spans ALL data-parallel axes (pod included): at 405B-scale the
        # f32 master+moments only fit when ZeRO-sharded over the full DP set.
        dpa = dp_axes(mesh)
        spec = tuple((dpa if s == "data" and len(dpa) > 1 else s)
                     for s in spec)
    return P(*((None,) * pad + spec))


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def validate_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on any dim the mesh axes don't divide (odd vocab sizes,
    head counts, raggeds) — correctness first; the roofline shows the cost
    of the resulting replication."""
    out = []
    for i, entry in enumerate(spec):
        if i >= len(shape) or entry is None:
            out.append(None)
            continue
        out.append(entry if shape[i] % _axis_size(mesh, entry) == 0 else None)
    return P(*out)


def param_spec(path, leaf, cfg: ModelConfig, mesh: Mesh) -> P:
    tp = tp_size(mesh)
    name = None
    for k in reversed(path):
        if hasattr(k, "key"):
            name = str(k.key)
            break
    if name is None:
        return P()
    ndim = np.ndim(leaf)

    # replicated small tensors
    if name in ("conv_w", "conv_b", "A_log", "D", "dt_bias", "norm_w",
                "q_norm", "kv_norm", "final_norm", "enc_final_norm") or \
            name.startswith("ln"):
        return P()

    # MoE expert tensors: path contains an 'ffn'/'ffn_moe'/'shared' marker
    in_moe = any(getattr(k, "key", None) in ("ffn", "ffn_moe") for k in path)
    in_shared = any(getattr(k, "key", None) == "shared" for k in path)
    if in_moe and not in_shared and name in ("w_gate", "w_up", "w_down") \
            and cfg.moe_num_experts and cfg.moe_num_experts % tp == 0:
        spec = {"w_gate": ("model", "data", None),
                "w_up": ("model", "data", None),
                "w_down": ("model", None, "data")}[name]
        return _leading_pad(spec, ndim, mesh)

    rule = _param_rule(name, ndim, cfg, tp)
    if rule is None:
        return P()
    _, spec = rule
    return _leading_pad(tuple(spec), ndim, mesh)


def param_shardings(params: Params, cfg: ModelConfig, mesh: Mesh) -> Params:
    def one(path, leaf):
        spec = validate_spec(param_spec(path, leaf, cfg, mesh),
                             np.shape(leaf), mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params)


# --------------------------------------------------------------------------
# batch / cache rules
# --------------------------------------------------------------------------

def _dp_for_batch(batch_size: int, mesh: Mesh) -> Optional[Tuple[str, ...]]:
    axes = dp_axes(mesh)
    if not axes:
        return None
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if batch_size % n == 0:
        return axes
    # try data only
    if "data" in axes and batch_size % mesh.shape["data"] == 0:
        return ("data",)
    return None


def batch_shardings(batch: Dict[str, Any], mesh: Mesh) -> Dict[str, Any]:
    def one(leaf):
        b = np.shape(leaf)[0]
        axes = _dp_for_batch(b, mesh)
        spec = P(axes) if axes else P()
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map(one, batch)


def cache_spec(path, leaf, cfg: ModelConfig, mesh: Mesh, batch: int) -> P:
    """KV caches: (L, B, S, KV, hd) -> B over DP (if divisible), S over
    'model'.  SSD state (L, B, H, P, N) -> H over 'model' when divisible.
    conv cache / small leaves replicated."""
    name = None
    for k in reversed(path):
        if hasattr(k, "key"):
            name = str(k.key)
            break
    ndim = np.ndim(leaf)
    tp = tp_size(mesh)
    daxes = _dp_for_batch(batch, mesh)
    bspec = daxes if daxes else None

    if name in ("k", "v") and ndim >= 4:
        shape = np.shape(leaf)
        s_ok = shape[-3] % tp == 0
        spec = (bspec, "model" if s_ok else None, None, None)
        return _leading_pad(spec, ndim)
    if name == "c_kv" or name == "k_rope":
        shape = np.shape(leaf)
        s_ok = shape[-2] % tp == 0
        spec = (bspec, "model" if s_ok else None, None)
        return _leading_pad(spec, ndim)
    if name == "ssm" and ndim >= 4:
        shape = np.shape(leaf)
        h_ok = shape[-3] % tp == 0
        spec = (bspec, "model" if h_ok else None, None, None)
        return _leading_pad(spec, ndim)
    if name == "conv" and ndim >= 3:
        spec = (bspec, None, None)
        return _leading_pad(spec, ndim)
    return P()


def cache_shardings(cache: Params, cfg: ModelConfig, mesh: Mesh,
                    batch: int) -> Params:
    def one(path, leaf):
        spec = validate_spec(cache_spec(path, leaf, cfg, mesh, batch),
                             np.shape(leaf), mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, cache)


# --------------------------------------------------------------------------
# activation constraint helper (used inside model code when mesh is set)
# --------------------------------------------------------------------------

def opt_state_shardings(opt_state, params_shardings) -> Any:
    """AdamW state mirrors param shardings (count replicated)."""
    from repro.optim.adamw import AdamWState
    mesh = jax.tree_util.tree_leaves(params_shardings)[0].mesh
    return AdamWState(
        count=NamedSharding(mesh, P()),
        master_lo=params_shardings,
        m=params_shardings,
        v=params_shardings,
    )
