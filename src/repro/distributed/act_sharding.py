"""Activation sharding constraints for model internals.

Model code is mesh-agnostic; the launcher/trainer wraps lowering in
``activation_sharding(mesh, cfg, batch)`` and layer code calls
``constrain_hidden(x)`` on its (B, S, d) carries.  Without the constraint
XLA may keep scan carries replicated over 'model', blowing the activation
memory floor by the TP factor.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_act_sharding", default=None)


class _ActCtx:
    def __init__(self, mesh: Mesh, dp: Optional[Tuple[str, ...]], tp_ok: bool):
        self.mesh = mesh
        self.dp = dp
        self.tp_ok = tp_ok


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, d_model: int, batch_size: int):
    from repro.distributed.sharding import _dp_for_batch, tp_size
    dp = _dp_for_batch(batch_size, mesh)
    tp_ok = d_model % tp_size(mesh) == 0
    token = _CTX.set(_ActCtx(mesh, dp, tp_ok))
    try:
        yield
    finally:
        _CTX.reset(token)


def constrain_hidden(x):
    """(B, S, d) activations between blocks -> P(dp, 'model', None).

    Sequence-parallel (Megatron-SP) layout: S sharded over the TP axis at
    block boundaries.  This (a) divides the remat scan-carry memory floor by
    the TP degree, and (b) keeps the contracting dim (d) UNSHARDED so the
    SPMD partitioner lowers FSDP weights as all-gather-weights (ZeRO-3)
    instead of partial-sum all-reducing f32 activations (measured: d-dim
    sharding produced (B,S,d_ff) f32 all-reduces dominating the collective
    roofline term)."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    if x.ndim != 3:
        return x
    b_entry = ctx.dp if (ctx.dp and x.shape[0] % _n(ctx.mesh, ctx.dp) == 0) \
        else None
    import os
    mode = os.environ.get("REPRO_ACT_SHARDING", "batch")
    tp = ctx.mesh.shape.get("model", 1)
    if mode == "seq":
        s_entry = "model" if x.shape[1] % tp == 0 and x.shape[1] >= tp else None
        spec = P(b_entry, s_entry, None)
    elif mode == "dmodel":
        d_entry = "model" if x.shape[-1] % tp == 0 else None
        spec = P(b_entry, None, d_entry)
    else:  # batch-only
        spec = P(b_entry, None, None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def _n(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def constrain(x, *entries):
    """Generic validated sharding constraint using the active context.
    entries: one per dim — None, 'model', or 'dp' (data axes)."""
    ctx = _CTX.get()
    if ctx is None or x.ndim != len(entries):
        return x
    tp = ctx.mesh.shape.get("model", 1)
    spec = []
    for dim, e in zip(x.shape, entries):
        if e == "model":
            spec.append("model" if dim % tp == 0 else None)
        elif e == "dp":
            n = _n(ctx.mesh, ctx.dp) if ctx.dp else 1
            spec.append(ctx.dp if (ctx.dp and dim % n == 0) else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))
