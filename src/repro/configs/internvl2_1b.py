"""InternVL2-1B [arXiv:2404.16821; hf] — VLM backbone.

LM trunk (Qwen2-0.5B-like): 24L, d_model=896, 14 heads (GQA kv=2),
d_ff=4864, vocab=151655.  InternViT frontend is a STUB: input_specs()
provides precomputed patch embeddings (assignment rule).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655, head_dim=64,
    num_patches=256,
)
