"""OLMoE-1B-7B [arXiv:2409.02060; hf] — 64 experts top-8.

16L, d_model=2048, 16 heads (MHA kv=16), per-expert d_ff=1024,
vocab=50304.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab_size=50304, head_dim=128,
    moe_num_experts=64, moe_top_k=8, moe_d_ff=1024,
)
