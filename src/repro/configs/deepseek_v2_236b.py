"""DeepSeek-V2 (236B) [arXiv:2405.04434; hf] — MLA + fine-grained MoE.

60L, d_model=5120, 128 heads, MLA kv_lora_rank=512 (q_lora 1536,
qk_nope 128 + qk_rope 64, v_head 128), MoE: 2 shared + 160 routed top-6,
per-expert d_ff=1536, vocab=102400.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=12288, vocab_size=102400,
    use_mla=True, kv_lora_rank=512, q_lora_rank=1536,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    moe_num_experts=160, moe_top_k=6, moe_d_ff=1536, moe_shared_experts=2,
)
