"""Mamba2-370M [arXiv:2405.21060; unverified] — SSD, attention-free.

48L, d_model=1024, ssm_state=128, vocab=50280.  d_inner = 2*d_model,
head_dim=64 -> 32 SSD heads.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2,
)
