"""Assigned architecture configs (exact, from public literature) + shapes.

``get_config(arch_id)`` returns the full-size ModelConfig;
``SHAPES`` defines the assigned input-shape set;
``cell_applicable(cfg, shape)`` implements the skip rules
(full-attention archs skip long_500k; decoder-less archs skip decode —
none here; see DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

from repro.models.config import ModelConfig

ARCHS = (
    "minitron_4b", "phi3_medium_14b", "llama3_405b", "granite_3_2b",
    "internvl2_1b", "jamba_1_5_large_398b", "deepseek_v2_236b",
    "olmoe_1b_7b", "whisper_medium", "mamba2_370m",
)

# canonical ids (with dashes) -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCHS}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def sub_quadratic(cfg: ModelConfig) -> bool:
    """True if the arch has a sub-quadratic long-context path."""
    return cfg.family in ("ssm", "hybrid")


def cell_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, Optional[str]]:
    spec = SHAPES[shape]
    if spec.name == "long_500k" and not sub_quadratic(cfg):
        return False, "full-attention arch: 524k dense-attention decode is quadratic by construction (DESIGN.md §6)"
    return True, None


def all_cells():
    """Yield (arch, shape, applicable, reason)."""
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, reason = cell_applicable(cfg, shape)
            yield arch, shape, ok, reason
