"""Whisper-medium [arXiv:2212.04356; unverified] — encoder-decoder.

24L decoder + 24L encoder, d_model=1024, 16 heads (MHA), d_ff=4096,
vocab=51865.  Conv audio frontend is a STUB: input_specs() provides
precomputed frame embeddings (1500 frames).  Decode shapes use the
assigned 32k decoder-side lengths (exceeds Whisper's 448-token reality;
noted in DESIGN.md §6, still lowered).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, encoder_layers=24, d_model=1024, num_heads=16,
    num_kv_heads=16, d_ff=4096, vocab_size=51865, head_dim=64,
    encoder_seq=1500,
)
