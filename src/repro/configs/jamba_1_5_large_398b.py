"""Jamba-1.5-Large (398B) [arXiv:2403.19887; hf] — hybrid Mamba+attn 1:7, MoE.

72L, d_model=8192, 64 heads (GQA kv=8), d_ff=24576, vocab=65536,
MoE 16 experts top-2.  Period structure: 1 attention layer per 8 layers
(attn at period index 3 per the Jamba paper figure), MoE every 2nd layer.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=24576, vocab_size=65536, head_dim=128,
    moe_num_experts=16, moe_top_k=2, moe_d_ff=24576, moe_every=2,
    attn_every=8, attn_index=3,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2,
)
