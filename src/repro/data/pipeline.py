"""Synthetic-but-deterministic data pipeline.

Production posture without external data: a seeded Zipfian token stream with
injected n-gram structure (so models actually learn and loss curves are
meaningful), sharded per host (``host_id/num_hosts``) the same way a real
multi-pod input pipeline would shard files.

Determinism: batch ``i`` is a pure function of (seed, host_id, i) — a
restarted/elastic job resumes mid-epoch with no duplicate/missing samples,
which the fault-tolerance tests assert.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

Array = np.ndarray


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    ngram: int = 3          # injected structure order
    zipf_a: float = 1.3


class SyntheticLM:
    """Zipf unigrams + deterministic n-gram transitions (learnable)."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        # fixed "grammar": each context token deterministically prefers a
        # successor; mixture with Zipf noise makes the task non-trivial
        g = np.random.default_rng(cfg.seed ^ 0x5EED)
        self._succ = g.integers(0, cfg.vocab_size, size=cfg.vocab_size)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._zipf_p = p / p.sum()

    def batch(self, index: int) -> Dict[str, Array]:
        """Batch ``index`` for this host — pure function, O(1) seek."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + index) * 4096 + self.host_id)
        B, S = self.local_batch, cfg.seq_len
        noise = rng.choice(cfg.vocab_size, size=(B, S + 1), p=self._zipf_p)
        use_succ = rng.random((B, S + 1)) < 0.7
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = noise[:, 0]
        for t in range(1, S + 1):
            toks[:, t] = np.where(use_succ[:, t],
                                  self._succ[toks[:, t - 1]], noise[:, t])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, Array]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1
