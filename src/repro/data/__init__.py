"""repro.data substrate."""
