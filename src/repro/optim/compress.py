"""Gradient compression with float-float error feedback.

Distributed-optimization trick for 1000+-node DP: gradients are quantized
to int8 (per-tensor scale) before the cross-pod reduce, cutting inter-pod
collective bytes 4x.  The quantization residual is carried in an FF error-
feedback buffer and re-injected next step — the compensated-accumulation
idea of the paper applied to communication: over T steps the *integrated*
gradient error stays ~2^-44-bounded instead of growing like T * q_err.

Usage (pure functions, pytree-wise):
    state = init_feedback(grads_like)
    q, scales, state = compress(grads, state)      # before the collective
    grads_hat = decompress(q, scales)              # after the collective
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.ff import FF
from repro.core import transforms as T

Array = jnp.ndarray


class FeedbackState(NamedTuple):
    err_hi: Any
    err_lo: Any


def init_feedback(grads_like) -> FeedbackState:
    z = jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    z2 = jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    return FeedbackState(err_hi=z, err_lo=z2)


def _q_leaf(g: Array, eh: Array, el: Array) -> Tuple[Array, Array, Array, Array]:
    g = g.astype(jnp.float32)
    # inject carried error exactly: v = g + (eh + el) via TwoSum chain
    s, r = T.two_sum(g, eh)
    v = s
    v_lo = r + el
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    # residual = (v + v_lo) - deq, kept in FF so it never dissolves
    d, dr = T.two_diff(v, deq)
    new_hi, new_lo = T.fast_two_sum(d, dr + v_lo)
    return q, scale, new_hi, new_lo


def compress(grads, state: FeedbackState):
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_eh = treedef.flatten_up_to(state.err_hi)
    flat_el = treedef.flatten_up_to(state.err_lo)
    qs, scales, nhs, nls = [], [], [], []
    for g, eh, el in zip(flat_g, flat_eh, flat_el):
        q, s, nh, nl = _q_leaf(g, eh, el)
        qs.append(q)
        scales.append(s)
        nhs.append(nh)
        nls.append(nl)
    return (treedef.unflatten(qs), treedef.unflatten(scales),
            FeedbackState(err_hi=treedef.unflatten(nhs),
                          err_lo=treedef.unflatten(nls)))


def decompress(q, scales):
    return jax.tree_util.tree_map(
        lambda qi, s: qi.astype(jnp.float32) * s, q, scales)
