"""repro.optim substrate."""
