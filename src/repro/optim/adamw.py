"""AdamW with float-float master weights — the paper's technique applied to
the place it matters most at scale.

Why FF master weights: at large batch/LR-decay scale, per-step weight updates
shrink to ~1e-7 of the weight magnitude; in f32 (2^-24 ≈ 6e-8 relative) the
``w - lr*u`` add rounds to zero and training stagnates (the classic reason
frameworks keep f64 or 'high-precision' master copies).  TPUs have no f64
worth using — the paper's float-float gives 2^-44, restoring ~20 bits of
update headroom, with Add22 as the weight-update instruction.

State layout (all f32):
  master_hi  — the serving/forward weights (exactly the FF hi limb)
  master_lo  — FF lo limb (absorbs sub-ulp updates until they matter)
  m, v       — Adam moments
  count      — step

``ff=False`` gives the plain-f32 baseline arm for apples-to-apples studies.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

import repro.ff as ff_ns
from repro.core.ff import FF

Array = jnp.ndarray


class AdamWState(NamedTuple):
    count: Array
    master_lo: Any          # pytree like params (zeros when ff=False)
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[Array], Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    ff: bool = True                      # float-float master weights

    def init(self, params) -> AdamWState:
        zeros = lambda: jax.tree_util.tree_map(jnp.zeros_like, params)
        return AdamWState(count=jnp.zeros((), jnp.int32),
                          master_lo=zeros(), m=zeros(), v=zeros())

    def _lr(self, count):
        if callable(self.learning_rate):
            return self.learning_rate(count)
        return jnp.float32(self.learning_rate)

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState]:
        """Returns (new_params_hi, new_state)."""
        c = state.count + 1
        lr = self._lr(c)
        b1, b2 = jnp.float32(self.b1), jnp.float32(self.b2)
        bc1 = 1.0 - b1 ** c.astype(jnp.float32)
        bc2 = 1.0 - b2 ** c.astype(jnp.float32)

        def leaf(g, m, v, w, wlo):
            g = g.astype(jnp.float32)
            if self.ff:
                # the whole ~10-op chain (moments, bias correction, decay,
                # FF master Add212) is ONE dispatched composite — a single
                # fused kernel launch on TPU, the bitwise-identical jnp
                # chain elsewhere (see ff.adamw_update / DESIGN_fusion.md)
                new, m2, v2 = ff_ns.adamw_update(
                    g, m, v, w, wlo, lr, b1, b2, bc1, bc2,
                    eps=self.eps, wd=self.weight_decay)
                return new.hi, new.lo, m2, v2
            m2 = b1 * m + (1.0 - b1) * g
            v2 = b2 * v + (1.0 - b2) * g * g
            upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + self.eps)
            upd = upd + self.weight_decay * w
            delta = (-lr * upd).astype(jnp.float32)
            w2 = w + delta
            return w2, wlo, m2, v2

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        flat_w = treedef.flatten_up_to(params)
        flat_lo = treedef.flatten_up_to(state.master_lo)
        out = [leaf(g, m, v, w, lo) for g, m, v, w, lo in
               zip(flat_g, flat_m, flat_v, flat_w, flat_lo)]
        new_w = treedef.unflatten([o[0] for o in out])
        new_lo = treedef.unflatten([o[1] for o in out])
        new_m = treedef.unflatten([o[2] for o in out])
        new_v = treedef.unflatten([o[3] for o in out])
        return new_w, AdamWState(count=c, master_lo=new_lo, m=new_m, v=new_v)


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    def lr(count):
        c = count.astype(jnp.float32)
        warm = c / max(warmup, 1)
        prog = jnp.clip((c - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(c < warmup, warm, cos)
    return lr


def global_grad_norm(grads, ff: bool = False) -> Array:
    """Global L2 norm; with ff=True uses compensated accumulation ACROSS
    leaves (per-leaf sums stay plain f32: XLA reduces pairwise, and a
    1-D FF scan over a 7.5e10-element MoE tensor both overflows int32
    dims and would serialize — measured on deepseek-v2).

    Inside an ``ff.on_mesh`` scope the per-leaf sum-of-squares goes through
    the mesh-partitioned ``ff.sum`` instead: each device runs the blocked
    compensated cascade over its shard and the cross-device combine is the
    compensated ``ppermute`` tree — the grad-norm keeps the FF error
    contract across the mesh rather than flattening to a naive f32
    ``psum``.  Leaves keep their ND shape (the sharded sum splits the
    leading dim — no 1-D flatten, so the int32-dim hazard above never
    applies) and fall back to the plain per-leaf f32 sum when the mesh
    axis does not divide their leading dim or the leaf is in the
    giant-MoE class where the in-shard FF cascade would serialize.
    """
    leaves = jax.tree_util.tree_leaves(grads)
    if not ff:
        return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                            for l in leaves))
    from repro.ff import scope as ff_scope
    from repro.ff import sharded as ff_sharded

    ctx = ff_scope.current_mesh()
    nshard = ff_sharded.axis_size(ctx[0], ctx[1]) if ctx is not None else 1
    acc = FF.from_f32(jnp.float32(0))
    for l in leaves:
        if (nshard > 1 and l.ndim >= 1 and l.shape[0] % nshard == 0
                and l.size < 2 ** 31):
            sq = l.astype(jnp.float32)
            acc = ff_ns.add(acc, ff_ns.sum(sq * sq))   # mesh-routed, ND
        else:
            acc = ff_ns.add(acc, jnp.sum(l.astype(jnp.float32) ** 2))
    return jnp.sqrt(acc.to_f32())


def clip_by_global_norm(grads, max_norm: float, ff: bool = False):
    n = global_grad_norm(grads, ff=ff)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), n
