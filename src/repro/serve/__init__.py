"""``repro.serve`` — continuous-batching serving on a paged FF KV cache.

The production decode loop around the fused FF flash-attention op
(``ff.attention`` / ``repro.kernels.ff_attention``):

  * :class:`~repro.serve.paged_kv.PagedKVCache` — the KV store as fixed-size
    pages with a block table and free list.  All planes of a sequence (k/v,
    and in ``kv_mode="ff_bf16"`` the FF hi/lo limb planes) share ONE block
    table, so a page allocation always moves the full float-float value.
  * :class:`~repro.serve.engine.ServeEngine` — request queue + continuous
    batching: an explicit prefill/decode split (reusing
    ``repro.train.serve_step``), per-row sequence lengths inside one jitted
    paged decode step, and join/evict between steps.  Greedy decoding is
    token-for-token the :func:`repro.train.serve_step.greedy_generate`
    baseline (the per-row dense-softmax decode path is bitwise the scalar
    one — see ``models.layers.decode_attention``).
  * FF ``token_logprob`` scoring as the accuracy-critical tier: per-token
    scores within 2^-40 of the f64 oracle (``docs/DESIGN_serving.md``).
  * Fault tolerance (``docs/DESIGN_robustness.md``): every request ends in
    a documented terminal status (``OK/TIMEOUT/REJECTED/DEGRADED/FAILED``),
    admission is backpressured (bounded queue + deadlines), the pool
    preempts instead of stalling, and under ``ff.guard`` poisoned rows are
    quarantined and retried on the fast f32 tier — exercised by the
    ``repro.chaos`` fault-injection tier.
  * Crash safety: ``ServeEngine.snapshot()/restore()`` freeze/rebuild the
    full engine (KV planes, block table, queued+running requests, results,
    counters) with token-for-token replay parity; a write-ahead request
    journal (:class:`~repro.serve.journal.RequestJournal`) makes accepted
    requests durable before admission; :func:`~repro.serve.engine.
    resume_engine` warm-restarts from the newest snapshot generation that
    passes CRC verification, falling back warned on corruption.

Quick use::

    from repro.serve import Request, ServeEngine
    eng = ServeEngine(params, cfg, max_batch=8, eos_id=0)
    eng.submit(Request(uid=0, prompt=prompt_ids, max_new=32))
    results = eng.run()          # {uid: GenResult(tokens, logprobs, ...)}
"""

from repro.serve.paged_kv import PagedKVCache  # noqa: F401
from repro.serve.journal import JournalWarning, RequestJournal  # noqa: F401
from repro.serve.engine import (  # noqa: F401
    DEGRADED, FAILED, OK, REJECTED, SNAPSHOT_SCHEMA, STATUSES, TIMEOUT,
    GenResult, Request, ServeEngine, UnsupportedModelError,
    resume_engine,
)
