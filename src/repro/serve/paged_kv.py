"""Paged KV cache: fixed-size pages, one block table for every plane.

The contiguous per-sequence cache of ``models.init_cache`` wastes
``max_ctx`` slots per slot-holder; under continuous batching the live
lengths are ragged and churn every few steps.  This module stores KV as
``(L, num_pages, page_size, KV, hd)`` pools ("planes" — one per cached
tensor) plus per-sequence page lists, so memory scales with the sum of
live lengths rounded up to a page.

Float-float pages: in ``kv_mode="ff_bf16"`` each of k/v splits into an
FF-style hi/lo limb pair (``hi = bf16(x)``, ``lo = bf16(x - hi)`` —
double-bf16, the storage analogue of the paper's double-f32 operators).
The limb planes are NOT independently paged: every plane indexes through
the SAME block table, so allocation, eviction and serialization always
move the hi and lo limbs of a value together — an FF number never has its
limbs split across inconsistent pages.

All host-side state (block table, free list, lengths) is numpy, and
:meth:`to_state` / :meth:`from_state` round-trip the whole cache through
a plain dict of numpy arrays (serialization-safe: no jax types, no python
objects beyond the dict).
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np
import jax.numpy as jnp

Array = jnp.ndarray

#: plane-name suffixes per kv_mode (all planes share the block table)
_MODE_PLANES = {
    "bf16": ("k", "v"),
    "f32": ("k", "v"),
    "ff_bf16": ("k_hi", "k_lo", "v_hi", "v_lo"),
}
_MODE_DTYPE = {"bf16": jnp.bfloat16, "f32": jnp.float32,
               "ff_bf16": jnp.bfloat16}


def ff_split(x: Array, dtype=jnp.bfloat16):
    """Split an f32 array into (hi, lo) storage limbs: ``hi = round(x)``,
    ``lo = round(x - hi)``.  Exact Fast2Sum-style residual at the storage
    precision (the subtraction is exact in f32 because hi has f32-width
    significand content truncated to ``dtype``)."""
    xf = jnp.asarray(x, jnp.float32)
    hi = xf.astype(dtype)
    lo = (xf - hi.astype(jnp.float32)).astype(dtype)
    return hi, lo


def ff_merge(hi: Array, lo: Array) -> Array:
    """Rebuild the f32 value from storage limbs (exact sum in f32)."""
    return hi.astype(jnp.float32) + lo.astype(jnp.float32)


class PagedKVCache:
    """Fixed-pool paged KV store for ``max_seqs`` concurrent sequences.

    Planes are jnp arrays of shape ``(L, num_pages, page_size, KV, hd)``;
    the block table is numpy ``(max_seqs, max_pages)`` int32 with ``-1``
    marking unallocated entries.  Page 0..num_pages-1 are real; the engine
    uses index ``num_pages`` as the out-of-bounds "drop" target for
    inactive rows (``.at[...].set(mode="drop")``).
    """

    def __init__(self, num_layers: int, num_kv_heads: int, head_dim: int, *,
                 num_pages: int, page_size: int = 16, max_seqs: int = 8,
                 max_ctx: int = 512, kv_mode: str = "bf16"):
        if kv_mode not in _MODE_PLANES:
            raise ValueError(f"unknown kv_mode {kv_mode!r}; "
                             f"choose from {tuple(_MODE_PLANES)}")
        self.num_layers = num_layers
        self.num_kv_heads = num_kv_heads
        self.head_dim = head_dim
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_seqs = max_seqs
        self.max_pages = -(-max_ctx // page_size)   # pages per sequence row
        self.kv_mode = kv_mode
        dt = _MODE_DTYPE[kv_mode]
        shape = (num_layers, num_pages, page_size, num_kv_heads, head_dim)
        self.planes: Dict[str, Array] = {
            name: jnp.zeros(shape, dt) for name in _MODE_PLANES[kv_mode]}
        self.block_table = np.full((max_seqs, self.max_pages), -1, np.int32)
        self.seq_lens = np.zeros((max_seqs,), np.int32)
        self.free_pages: List[int] = list(range(num_pages - 1, -1, -1))

    # -- allocation --------------------------------------------------------

    def pages_for(self, length: int) -> int:
        return -(-length // self.page_size)

    def can_alloc(self, length: int) -> bool:
        return len(self.free_pages) >= self.pages_for(length)

    def alloc(self, slot: int, length: int) -> List[int]:
        """Allocate pages for a sequence of ``length`` tokens in ``slot``.
        Returns the page ids (also recorded in the block table)."""
        need = self.pages_for(length)
        if need > self.max_pages:
            raise ValueError(f"length {length} exceeds max_ctx "
                             f"({self.max_pages * self.page_size})")
        if need > len(self.free_pages):
            raise RuntimeError("paged KV pool exhausted")
        if self.seq_lens[slot] or (self.block_table[slot] >= 0).any():
            raise RuntimeError(f"slot {slot} already holds a sequence")
        ids = [self.free_pages.pop() for _ in range(need)]
        self.block_table[slot, :need] = ids
        self.seq_lens[slot] = length
        return ids

    def grow(self, slot: int, new_length: int) -> Optional[int]:
        """Extend ``slot`` to ``new_length`` tokens, allocating at most one
        new page (decode adds one token per step).  Returns the new page id
        or None if the current last page still has room."""
        have = self.pages_for(int(self.seq_lens[slot]))
        need = self.pages_for(new_length)
        if need <= have:
            self.seq_lens[slot] = new_length
            return None
        if need - have != 1:
            raise ValueError("grow() extends by at most one page")
        if not self.free_pages:
            # raise BEFORE touching seq_lens: a failed grow must leave the
            # bookkeeping exactly as it was (check_integrity-clean), so
            # the engine can preempt a neighbor and retry
            raise RuntimeError("paged KV pool exhausted")
        pid = self.free_pages.pop()
        self.block_table[slot, have] = pid
        self.seq_lens[slot] = new_length
        return pid

    def check_integrity(self):
        """Audit the host-side paging metadata (block table + free list).

        Returns ``(problems, bad_slots)``: human-readable descriptions and
        the set of slots whose page lists can no longer be trusted (an
        out-of-range page id, a page shared between two slots or with the
        free list, or a hole below the live length).  Pure numpy scan of
        ``max_seqs * max_pages`` entries — cheap enough to run per
        scheduler step under ``ff.guard``.  The caller decides what to do
        with the verdict (the serve engine quarantines the slots, zeroes
        their rows and calls :meth:`rebuild_free_list`)."""
        problems: List[str] = []
        bad = set()
        free = [int(p) for p in self.free_pages]
        free_set = set(free)
        if len(free_set) != len(free):
            problems.append("free list contains duplicate page ids")
        if any(not 0 <= p < self.num_pages for p in free_set):
            problems.append("free list contains out-of-range page ids")
        owner: Dict[int, int] = {}
        for slot in range(self.max_seqs):
            row = self.block_table[slot]
            for pid in row:
                pid = int(pid)
                if pid == -1:
                    continue
                if not 0 <= pid < self.num_pages:
                    problems.append(f"slot {slot}: page id {pid} out of "
                                    f"range [0, {self.num_pages})")
                    bad.add(slot)
                    continue
                if pid in free_set:
                    problems.append(f"slot {slot}: page {pid} is also on "
                                    f"the free list")
                    bad.add(slot)
                if pid in owner:
                    problems.append(f"page {pid} referenced by slots "
                                    f"{owner[pid]} and {slot}")
                    bad.add(slot)
                    bad.add(owner[pid])
                else:
                    owner[pid] = slot
            live = self.pages_for(int(self.seq_lens[slot]))
            if live and (row[:live] < 0).any():
                problems.append(f"slot {slot}: missing page below live "
                                f"length {int(self.seq_lens[slot])}")
                bad.add(slot)
        return problems, bad

    def rebuild_free_list(self) -> None:
        """Recompute the free list as every in-range page not referenced by
        the block table (recovery path after :meth:`check_integrity` found
        corruption and the caller cleared the untrusted rows)."""
        used = {int(p) for p in self.block_table.ravel()
                if 0 <= int(p) < self.num_pages}
        self.free_pages = [p for p in range(self.num_pages - 1, -1, -1)
                           if p not in used]

    def drop_slot(self, slot: int) -> None:
        """Clear a slot's row WITHOUT returning its pages to the free list
        (quarantine path: the row's page ids are untrusted — follow with
        :meth:`rebuild_free_list` once every bad row is cleared)."""
        self.block_table[slot] = -1
        self.seq_lens[slot] = 0

    def free_slot(self, slot: int) -> None:
        """Evict a sequence: return its pages to the free list.  Page
        contents are left as-is (stale but finite — masked reads contribute
        exact zeros), so eviction is O(pages) host work with no device op."""
        for pid in self.block_table[slot]:
            if pid >= 0:
                self.free_pages.append(int(pid))
        self.block_table[slot] = -1
        self.seq_lens[slot] = 0

    # -- data movement -----------------------------------------------------

    def write_prefill(self, slot: int, tensors: Dict[str, Array]) -> None:
        """Write per-layer contiguous K/V (``{"k": (L, S, KV, hd), "v":
        ...}`` in compute f32/bf16) into this slot's pages.  In FF mode the
        values are limb-split here; both limbs land in the same pages."""
        S = int(tensors["k"].shape[1])
        if S != int(self.seq_lens[slot]):
            raise ValueError("prefill length != allocated length")
        npg = self.pages_for(S)
        ids = self.block_table[slot, :npg]
        pad = npg * self.page_size - S
        for base in ("k", "v"):
            x = jnp.asarray(tensors[base])
            if pad:
                x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
            paged = x.reshape(x.shape[0], npg, self.page_size,
                              self.num_kv_heads, self.head_dim)
            if self.kv_mode == "ff_bf16":
                hi, lo = ff_split(paged)
                self.planes[f"{base}_hi"] = \
                    self.planes[f"{base}_hi"].at[:, ids].set(hi)
                self.planes[f"{base}_lo"] = \
                    self.planes[f"{base}_lo"].at[:, ids].set(lo)
            else:
                dt = self.planes[base].dtype
                self.planes[base] = \
                    self.planes[base].at[:, ids].set(paged.astype(dt))

    def gather(self, slot: int) -> Dict[str, Array]:
        """Contiguous read-back of a slot ({"k": (L, S, KV, hd), ...}, f32
        in FF mode, storage dtype otherwise).  Host/debug path — the engine
        gathers on-device inside its jitted step instead."""
        S = int(self.seq_lens[slot])
        npg = self.pages_for(S)
        ids = self.block_table[slot, :npg]
        out = {}
        for base in ("k", "v"):
            if self.kv_mode == "ff_bf16":
                hi = self.planes[f"{base}_hi"][:, ids]
                lo = self.planes[f"{base}_lo"][:, ids]
                paged = ff_merge(hi, lo)
            else:
                paged = self.planes[base][:, ids]
            out[base] = paged.reshape(self.num_layers, npg * self.page_size,
                                      self.num_kv_heads, self.head_dim)[:, :S]
        return out

    # -- serialization -----------------------------------------------------

    def to_state(self) -> Dict[str, np.ndarray]:
        """Whole cache as a flat dict of numpy arrays (plus scalars of the
        geometry).  bf16 planes ship as uint16 bit patterns so the dict
        round-trips through any numpy-only container (npz, plasma, ...)."""
        state: Dict[str, np.ndarray] = {
            "block_table": self.block_table.copy(),
            "seq_lens": self.seq_lens.copy(),
            "free_pages": np.asarray(self.free_pages, np.int32),
            "geometry": np.asarray(
                [self.num_layers, self.num_kv_heads, self.head_dim,
                 self.num_pages, self.page_size, self.max_seqs,
                 self.max_pages * self.page_size], np.int64),
            "kv_mode": np.frombuffer(
                self.kv_mode.encode().ljust(8, b"\0"), np.uint8).copy(),
        }
        for name, plane in self.planes.items():
            arr = np.asarray(plane)
            if arr.dtype == jnp.bfloat16:
                arr = arr.view(np.uint16)
            state[f"plane_{name}"] = arr
        return state

    @classmethod
    def from_state(cls, state: Dict[str, np.ndarray]) -> "PagedKVCache":
        """Rebuild a cache from :meth:`to_state` output.  The container
        (``repro.checkpoint``) guarantees bit integrity via CRC32; this
        validates STRUCTURE — missing keys, a malformed geometry vector,
        or plane shapes disagreeing with it raise ``ValueError`` instead
        of constructing a cache that decodes garbage."""
        for key in ("geometry", "kv_mode", "block_table", "seq_lens",
                    "free_pages"):
            if key not in state:
                raise ValueError(f"KV state missing required key {key!r}")
        geom = np.asarray(state["geometry"]).ravel()
        if geom.shape[0] != 7:
            raise ValueError(f"KV state geometry has {geom.shape[0]} "
                             f"entries; expected 7")
        L, KV, hd, NP, ps, ms, mc = (int(v) for v in geom)
        mode = bytes(state["kv_mode"]).rstrip(b"\0").decode()
        if mode not in _MODE_PLANES:
            raise ValueError(f"KV state names unknown kv_mode {mode!r}")
        want_shape = (L, NP, ps, KV, hd)
        for name in _MODE_PLANES[mode]:
            key = f"plane_{name}"
            if key not in state:
                raise ValueError(f"KV state missing plane {key!r} for "
                                 f"kv_mode {mode!r}")
            got = tuple(np.asarray(state[key]).shape)
            if got != want_shape:
                raise ValueError(f"KV state plane {key!r} shape {got} != "
                                 f"geometry {want_shape}")
        self = cls(L, KV, hd, num_pages=NP, page_size=ps, max_seqs=ms,
                   max_ctx=mc, kv_mode=mode)
        self.block_table = np.asarray(state["block_table"], np.int32).copy()
        self.seq_lens = np.asarray(state["seq_lens"], np.int32).copy()
        self.free_pages = [int(p) for p in state["free_pages"]]
        dt = _MODE_DTYPE[mode]
        for name in _MODE_PLANES[mode]:
            arr = state[f"plane_{name}"]
            if dt == jnp.bfloat16:
                arr = arr.view(np.uint16)
                self.planes[name] = jnp.asarray(arr).view(jnp.bfloat16)
            else:
                self.planes[name] = jnp.asarray(arr, dt)
        return self
