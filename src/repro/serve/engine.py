"""Continuous-batching serve engine over the paged FF KV cache.

Scheduling model (the standard production shape, single host):

  * requests enter a FIFO queue; :meth:`ServeEngine.run` drains it;
  * **prefill** runs one request at a time at its EXACT prompt length
    (jit-cached per distinct length — no prompt padding, no wasted
    attention FLOPs) through the stock :func:`repro.models.prefill` via
    ``repro.train.serve_step.make_prefill_step``, then the prompt's K/V
    moves into pages;
  * **decode** advances every running sequence one token per step inside a
    single jitted paged step: per-row positions/lengths, per-row RoPE, a
    paged scatter of the new K/V (inactive rows scatter to the
    out-of-bounds drop page) and a block-table gather feeding the per-row
    ``decode_attention`` — which, for ``impl="fast"``, is bitwise the
    scalar path :func:`~repro.train.serve_step.greedy_generate` uses, so
    the engine is token-for-token the sequential baseline;
  * between decode steps, finished rows (EOS or ``max_new``) are evicted
    (pages back to the free list) and waiting requests join (continuous
    batching) — the batch never drains to refill.

Accuracy-critical tier: every emitted token is scored with the FF
token-logprob (:func:`repro.train.serve_step.token_logprob_ff`) — the
full vocab-LSE chain stays in float-float, within 2^-40 of the f64
oracle (gated by ``benchmarks/table_serving.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core.policy import PrecisionPolicy
from repro.ff.scope import resolve_policy
from repro.models import init_cache
from repro.models.config import ModelConfig
from repro.models.layers import (apply_rope, decode_attention, mlp_apply,
                                 rms_norm, embed_apply, unembed_apply)
from repro.train.serve_step import (make_prefill_step, token_logprob,
                                    token_logprob_ff)
from repro.serve.paged_kv import PagedKVCache, ff_merge, ff_split

Array = jnp.ndarray


@dataclasses.dataclass
class Request:
    """One generation request.  ``prompt``: 1-D int32 token ids."""
    uid: int
    prompt: np.ndarray
    max_new: int = 16


@dataclasses.dataclass
class GenResult:
    """Completed generation: tokens, f32 scores, FF limb-pair scores."""
    uid: int
    tokens: np.ndarray            # (n,) int32, n <= max_new
    logprobs: np.ndarray          # (n,) f32 (compensated-LSE scores)
    logprobs_ff: np.ndarray       # (n, 2) f32 — FF (hi, lo) limb pairs
    prompt_len: int = 0


def _check_cfg(cfg: ModelConfig) -> None:
    if cfg.family != "dense" or cfg.use_mla or cfg.moe_num_experts:
        raise NotImplementedError(
            "ServeEngine drives the dense GQA decoder stack; MLA/MoE/SSM "
            "families keep the contiguous-cache loop in "
            "repro.train.serve_step for now")


class ServeEngine:
    """Continuous-batching greedy decoder with a paged KV cache.

    Parameters: ``max_batch`` concurrent rows; ``page_size`` tokens/page;
    ``max_ctx`` per-sequence ceiling (prompt + generated); ``num_pages``
    defaults to a full pool (``max_batch * pages_per_seq``); ``eos_id``
    enables per-sequence termination (None = run to ``max_new``);
    ``kv_mode`` is the page storage format ("bf16" matches the
    ``greedy_generate`` baseline cache bitwise; "ff_bf16" pages FF hi/lo
    limb planes through the shared block table).  The attention impl and
    scoring class follow the ambient ``ff.policy`` (``attention="fast"``
    default; ``ff.policy(attention="ff")`` switches the decode softmax to
    the compensated FF class).
    """

    def __init__(self, params: Any, cfg: ModelConfig, *,
                 max_batch: int = 8, page_size: int = 16,
                 max_ctx: int = 256, num_pages: Optional[int] = None,
                 eos_id: Optional[int] = None, kv_mode: str = "bf16",
                 policy: Optional[PrecisionPolicy] = None):
        _check_cfg(cfg)
        self.params = params
        self.cfg = cfg
        self.policy = resolve_policy(policy)
        self.max_batch = max_batch
        self.eos_id = eos_id
        pages_per_seq = -(-max_ctx // page_size)
        if num_pages is None:
            num_pages = max_batch * pages_per_seq
        self.kv = PagedKVCache(
            cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim,
            num_pages=num_pages, page_size=page_size, max_seqs=max_batch,
            max_ctx=max_ctx, kv_mode=kv_mode)
        self.queue: List[Request] = []
        self.results: Dict[int, GenResult] = {}
        # slot -> in-flight request bookkeeping (None = free row)
        self._slots: List[Optional[Dict[str, Any]]] = [None] * max_batch
        self._last_tok = np.zeros((max_batch,), np.int32)
        # NOTE: the page planes are deliberately NOT donated — on the CPU
        # backend donation around the layer scan costs a defensive copy
        # per step (measured 2x step latency); the non-donated step keeps
        # the pool update as cheap aliased buffers
        self._decode = jax.jit(self._make_decode_step())
        self._score = jax.jit(
            lambda lg, tk: token_logprob(lg, tk, self.policy))
        def _ff_limbs(lg, tk):
            r = token_logprob_ff(lg, tk)
            return r.hi, r.lo
        self._score_ff = jax.jit(_ff_limbs)
        self._prefill_cache: Dict[int, Any] = {}
        self.decode_steps = 0

    # -- jitted paged decode step -----------------------------------------

    def _make_decode_step(self):
        cfg, policy, kv = self.cfg, self.policy, self.kv
        ps, npg = kv.page_size, kv.max_pages
        ff_pages = kv.kv_mode == "ff_bf16"

        def step(params, token, lens, bt, active, planes):
            """token: (B,1) int32; lens: (B,) tokens already cached;
            bt: (B, npg) page table (-1 empty); active: (B,) bool;
            planes: dict of (L, NP, ps, KV, hd).  Returns (next greedy
            token (B,), its f32 and FF (hi, lo) logprobs, updated planes)
            — argmax and BOTH scoring tiers run inside the one jitted
            step, so per decode step the host sees four (B,) vectors, not
            the (B, V) logits.  Math per active row is exactly the
            ``model.decode_step`` dense body at that row's position."""
            dt = jnp.dtype(cfg.compute_dtype)
            B = token.shape[0]
            H, KVh = cfg.num_heads, cfg.num_kv_heads
            hd = cfg.resolved_head_dim
            NP = next(iter(planes.values())).shape[1]
            x = embed_apply(params["embed"], token, dt)
            # the page/offset every row writes its new K/V to (drop page
            # NP for inactive rows -> scatter is a no-op there)
            rowpage = bt[jnp.arange(B), lens // ps]
            wpage = jnp.where(active, rowpage, jnp.int32(NP))
            woff = lens % ps
            gidx = jnp.maximum(bt, 0)          # gather table (garbage rows
            posv = lens[:, None]               # are masked by lens later)

            def body(h, scanned):
                lp = scanned[0]
                pl = dict(zip(sorted(planes), scanned[1:]))
                z = rms_norm(h, lp["ln1"], cfg.norm_eps,
                             ff_stats=policy.ff_reductions)
                ap = lp["attn"]
                q = (z @ ap["wq"].astype(dt)).reshape(B, 1, H, hd)
                k = (z @ ap["wk"].astype(dt)).reshape(B, 1, KVh, hd)
                v = (z @ ap["wv"].astype(dt)).reshape(B, 1, KVh, hd)
                q = apply_rope(q, posv, cfg.rope_theta)
                k = apply_rope(k, posv, cfg.rope_theta)
                gathered = {}
                for base, new in (("k", k), ("v", v)):
                    if ff_pages:
                        hi, lo = ff_split(new[:, 0])
                        pl[f"{base}_hi"] = pl[f"{base}_hi"].at[
                            wpage, woff].set(hi, mode="drop")
                        pl[f"{base}_lo"] = pl[f"{base}_lo"].at[
                            wpage, woff].set(lo, mode="drop")
                        merged = ff_merge(pl[f"{base}_hi"][gidx],
                                          pl[f"{base}_lo"][gidx])
                    else:
                        pdt = pl[base].dtype
                        pl[base] = pl[base].at[wpage, woff].set(
                            new[:, 0].astype(pdt), mode="drop")
                        merged = pl[base][gidx]
                    gathered[base] = merged.reshape(B, npg * ps, KVh, hd)
                o = decode_attention(q, gathered["k"], gathered["v"],
                                     lens + 1, impl=policy.attention)
                h = h + (o.reshape(B, 1, H * hd) @ ap["wo"].astype(dt))
                z = rms_norm(h, lp["ln2"], cfg.norm_eps,
                             ff_stats=policy.ff_reductions)
                f = mlp_apply(lp["ffn"], z, ff_math=policy.ff_math)
                return h + f, tuple(pl[n] for n in sorted(pl))

            x, updated = lax.scan(
                body, x,
                (params["layers"],) + tuple(
                    planes[n] for n in sorted(planes)))
            x = rms_norm(x, params["final_norm"], cfg.norm_eps,
                         ff_stats=policy.ff_reductions)
            logits = unembed_apply(params["embed"], x, cfg,
                                   ff_math=policy.ff_math)[:, 0]
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            lp = token_logprob(logits, nxt, policy)
            lp_ff = token_logprob_ff(logits, nxt)
            return (nxt, lp, lp_ff.hi, lp_ff.lo,
                    dict(zip(sorted(planes), updated)))

        return step

    # -- request lifecycle -------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _prefill_fn(self, S: int):
        """Exact-length prefill, jit-cached per distinct prompt length."""
        if S not in self._prefill_cache:
            step = make_prefill_step(self.cfg, self.policy)
            self._prefill_cache[S] = jax.jit(step)
        return self._prefill_cache[S]

    def _admit(self) -> None:
        """Join waiting requests into free rows while pages allow (FIFO —
        no request starves behind an unschedulable head-of-line)."""
        while self.queue:
            req = self.queue[0]
            S = int(req.prompt.shape[0])
            total = S + req.max_new
            slot = next((i for i, s in enumerate(self._slots) if s is None),
                        None)
            if slot is None or not self.kv.can_alloc(total):
                return
            self.queue.pop(0)
            self.kv.alloc(slot, total)      # reserve the whole trajectory
            self.kv.seq_lens[slot] = S      # ...but only S tokens are live
            # the prefill cache dtype IS the page fidelity: bf16 matches
            # the greedy_generate baseline cache bitwise; the f32 / FF
            # page modes keep the full compute-precision K/V
            cache_dt = jnp.bfloat16 if self.kv.kv_mode == "bf16" \
                else jnp.float32
            cache = init_cache(self.cfg, 1, S, dtype=cache_dt)
            logits, cache = self._prefill_fn(S)(
                self.params, {"tokens": jnp.asarray(req.prompt[None])},
                cache)
            self.kv.write_prefill(slot, {
                "k": cache["layers"]["k"][:, 0],
                "v": cache["layers"]["v"][:, 0]})
            tok = int(jnp.argmax(logits, -1)[0])
            lp = float(self._score(logits, jnp.asarray([tok], jnp.int32))[0])
            lph, lpl = self._score_ff(logits, jnp.asarray([tok], jnp.int32))
            state = {"req": req, "prompt_len": S,
                     "tokens": [tok], "logprobs": [lp],
                     "logprobs_ff": [(float(lph[0]), float(lpl[0]))]}
            self._slots[slot] = state
            self._last_tok[slot] = tok
            if self._finished(state):
                self._retire(slot)

    def _finished(self, state: Dict[str, Any]) -> bool:
        if len(state["tokens"]) >= state["req"].max_new:
            return True
        return self.eos_id is not None and state["tokens"][-1] == self.eos_id

    def _retire(self, slot: int) -> None:
        state = self._slots[slot]
        req = state["req"]
        self.results[req.uid] = GenResult(
            uid=req.uid,
            tokens=np.asarray(state["tokens"], np.int32),
            logprobs=np.asarray(state["logprobs"], np.float32),
            logprobs_ff=np.asarray(state["logprobs_ff"], np.float32),
            prompt_len=state["prompt_len"])
        self.kv.free_slot(slot)
        self._slots[slot] = None
        self._last_tok[slot] = 0

    def _step_decode(self) -> None:
        active_np = np.asarray([s is not None for s in self._slots])
        lens = np.where(
            active_np,
            np.asarray([(s["prompt_len"] + len(s["tokens"]) - 1) if s else 0
                        for s in self._slots], np.int32),
            0).astype(np.int32)
        nxt, lp, lph, lpl, self.kv.planes = self._decode(
            self.params, jnp.asarray(self._last_tok[:, None]),
            jnp.asarray(lens), jnp.asarray(self.kv.block_table),
            jnp.asarray(active_np), self.kv.planes)
        self.decode_steps += 1
        # one batched device->host sync for the four (B,) vectors
        nxt, lp, lph, lpl = jax.device_get((nxt, lp, lph, lpl))
        nxt = np.asarray(nxt, np.int32)
        for slot, state in enumerate(self._slots):
            if state is None:
                continue
            # the step wrote this row's K/V at position lens[slot]
            self.kv.seq_lens[slot] = int(lens[slot]) + 1
            tok = int(nxt[slot])
            state["tokens"].append(tok)
            state["logprobs"].append(float(lp[slot]))
            state["logprobs_ff"].append((float(lph[slot]), float(lpl[slot])))
            self._last_tok[slot] = tok
            if self._finished(state):
                self._retire(slot)

    def step(self) -> bool:
        """One scheduler iteration: admit waiting requests into free rows,
        then advance every running row one token.  Returns True while work
        remains.  Public hook for callers that interleave ``submit`` with
        decoding (staggered arrivals join the running batch at the next
        step — see ``examples/serve_lm.py``)."""
        self._admit()
        if any(s is not None for s in self._slots):
            self._step_decode()
            self._admit()
        elif self.queue:
            raise RuntimeError("scheduler stalled: no running rows and "
                               "head-of-queue cannot be admitted")
        return any(s is not None for s in self._slots) or bool(self.queue)

    def run(self) -> Dict[int, GenResult]:
        """Drain the queue: admit + decode until everything completes."""
        while self.step():
            pass
        return self.results
