"""Continuous-batching serve engine over the paged FF KV cache.

Scheduling model (the standard production shape, single host):

  * requests enter a bounded FIFO queue; :meth:`ServeEngine.run` drains it;
  * **prefill** runs one request at a time at its EXACT prompt length
    (jit-cached per distinct length — no prompt padding, no wasted
    attention FLOPs) through the stock :func:`repro.models.prefill` via
    ``repro.train.serve_step.make_prefill_step``, then the prompt's K/V
    moves into pages;
  * **decode** advances every running sequence one token per step inside a
    single jitted paged step: per-row positions/lengths, per-row RoPE, a
    paged scatter of the new K/V (inactive rows scatter to the
    out-of-bounds drop page) and a block-table gather feeding the per-row
    ``decode_attention`` — which, for ``impl="fast"``, is bitwise the
    scalar path :func:`~repro.train.serve_step.greedy_generate` uses, so
    the engine is token-for-token the sequential baseline;
  * between decode steps, finished rows (EOS or ``max_new``) are evicted
    (pages back to the free list) and waiting requests join (continuous
    batching) — the batch never drains to refill.

Fault tolerance (see ``docs/DESIGN_robustness.md``): every request ends
with a documented terminal status — ``OK`` / ``TIMEOUT`` / ``REJECTED`` /
``DEGRADED`` / ``FAILED`` — and off-nominal conditions never raise out of
:meth:`step`:

  * admission backpressure: a bounded wait queue (``max_queue``) and
    per-request deadlines (wall-clock ``deadline_s`` or deterministic
    ``deadline_steps``); structurally impossible requests are ``REJECTED``
    at submit, expired ones retire as ``TIMEOUT``;
  * ``reserve="prompt"`` allocates pages lazily (prompt only) instead of
    reserving the whole trajectory; when the pool runs dry mid-decode the
    engine preempts the *youngest* running row (its pages return to the
    free list, the request re-prefills later — greedy decoding is
    deterministic, so the replay is token-for-token identical);
  * with ``ff.guard`` active (or ``guard="check"|"degrade"``), the jitted
    step additionally returns a per-row health flag — non-finite new K/V
    in any layer, a non-finite f32 score, or an FF score violating the
    normalization invariant — and flagged rows are quarantined and
    retried on the fast f32 tier (``DEGRADED``), never silently emitted;
    the paging metadata is audited per flush
    (:meth:`~repro.serve.paged_kv.PagedKVCache.check_integrity`).
  * eos-less decode can batch the device->host sync (``sync_every=N``):
    the four per-row vectors of N steps transfer in one ``device_get``,
    token-for-token identical to N=1 (the next input token stays on
    device).

Crash safety (process lifecycle — the tier around a run): the engine is
**restartable** with exact-replay semantics.

  * :meth:`ServeEngine.snapshot` captures the FULL engine between decode
    steps — paged KV planes (all three kv_modes, FF limb planes
    included), block table + free list, queued and running requests with
    their emitted tokens/scores, completed results, deadlines, and the
    sync/guard counters — as flat numpy arrays + a JSON meta dict;
    :meth:`ServeEngine.restore` rebuilds a fresh engine from them, and
    continuing decodes **token-for-token (FF logprob bit-for-bit)
    identical** to the uninterrupted run (greedy decoding is
    deterministic; the snapshot syncs pending steps first so the resumed
    math starts at a step boundary).  Wall-clock deadlines that expired
    during downtime retire as the documented ``TIMEOUT`` on restore —
    never silently revived — while deterministic ``deadline_steps``
    budgets are unaffected by downtime.
  * a write-ahead request journal (``journal=`` path,
    :class:`repro.serve.journal.RequestJournal`): ``submit()`` appends an
    fsync'd JSONL record BEFORE admission, so accepted requests survive a
    crash and are re-admitted in original order on restore (replaying to
    the same tokens); the log truncates on clean retirement and compacts
    to the unsnapshotted tail whenever a snapshot becomes durable.
  * snapshots persist through the hardened ``repro.checkpoint`` (atomic
    tmp+rename, per-leaf CRC32, schema-versioned manifest, keep-last-3
    fallback ladder); :func:`resume_engine` loads the newest generation
    that VERIFIES — a torn/bit-flipped/stale snapshot falls back warned
    to the previous one, bottoming out at a WAL-only cold replay.
    ``run(snapshot_dir=..., snapshot_every=N)`` snapshots every N decode
    steps through an :class:`~repro.checkpoint.checkpoint.AsyncCheckpointer`
    whose write errors surface into the engine loop via ``poll()`` each
    scheduler iteration (counted in ``guard_stats["snapshot_errors"]``).

Accuracy-critical tier: every emitted token is scored with the FF
token-logprob (:func:`repro.train.serve_step.token_logprob_ff`) — the
full vocab-LSE chain stays in float-float, within 2^-40 of the f64
oracle (gated by ``benchmarks/table_serving.py``).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro import obs as obs_mod
from repro.core.policy import PrecisionPolicy
from repro.ff.guard import FFGuardWarning, health_mask, report_violation
from repro.ff.scope import resolve_policy
from repro.models import init_cache
from repro.models.config import ModelConfig
from repro.models.layers import (apply_rope, decode_attention, mlp_apply,
                                 rms_norm, embed_apply, unembed_apply)
from repro.train.serve_step import (greedy_generate, make_prefill_step,
                                    token_logprob, token_logprob_ff)
from repro.serve.journal import RequestJournal
from repro.serve.paged_kv import PagedKVCache, ff_merge, ff_split

Array = jnp.ndarray

#: engine snapshot schema version (independent of the checkpoint
#: container's ``FORMAT``); restore() refuses any other version.
SNAPSHOT_SCHEMA = 1

# -- terminal statuses (every submitted request ends in exactly one) --------
OK = "OK"                  # ran to eos/max_new on the requested tier
TIMEOUT = "TIMEOUT"        # deadline expired (queued or mid-decode)
REJECTED = "REJECTED"      # never admitted: bounded queue / impossible size
DEGRADED = "DEGRADED"      # guard quarantined the row; fast-tier retry OK
FAILED = "FAILED"          # no healthy result on any tier
STATUSES = (OK, TIMEOUT, REJECTED, DEGRADED, FAILED)


class UnsupportedModelError(NotImplementedError):
    """A model config outside the engine's supported families, named by
    the offending field (raised at construction, not first request)."""

    def __init__(self, field: str, value: Any, supported: str):
        self.field = field
        self.value = value
        self.supported = supported
        super().__init__(
            f"ServeEngine does not support {field}={value!r}; supported: "
            f"{supported}.  Use repro.train.serve_step.greedy_generate "
            f"(contiguous cache) for this family.")


@dataclasses.dataclass
class Request:
    """One generation request.  ``prompt``: 1-D int32 token ids.

    ``deadline_s`` is a wall-clock budget (seconds from submit);
    ``deadline_steps`` a deterministic scheduler budget (decode steps from
    submit — the testable variant).  Either expiring retires the request
    as ``TIMEOUT`` (with any tokens produced so far)."""
    uid: int
    prompt: np.ndarray
    max_new: int = 16
    deadline_s: Optional[float] = None
    deadline_steps: Optional[int] = None


@dataclasses.dataclass
class GenResult:
    """Completed generation: tokens, f32 scores, FF limb-pair scores, and
    the terminal ``status`` (one of :data:`STATUSES`) with a human
    ``detail`` for every non-``OK`` outcome."""
    uid: int
    tokens: np.ndarray            # (n,) int32, n <= max_new
    logprobs: np.ndarray          # (n,) f32 (compensated-LSE scores)
    logprobs_ff: np.ndarray       # (n, 2) f32 — FF (hi, lo) limb pairs
    prompt_len: int = 0
    status: str = OK
    detail: str = ""


def _check_cfg(cfg: ModelConfig) -> None:
    if cfg.family != "dense":
        raise UnsupportedModelError("family", cfg.family,
                                    '"dense" (GQA decoder stack)')
    if cfg.use_mla:
        raise UnsupportedModelError(
            "use_mla", True, "use_mla=False — the MLA latent cache is not "
            "paged yet (ROADMAP item 1)")
    if cfg.moe_num_experts:
        raise UnsupportedModelError(
            "moe_num_experts", cfg.moe_num_experts,
            "moe_num_experts=0 (dense FFN)")


def _empty_result(req: Request, status: str, detail: str) -> GenResult:
    return GenResult(uid=req.uid, tokens=np.zeros((0,), np.int32),
                     logprobs=np.zeros((0,), np.float32),
                     logprobs_ff=np.zeros((0, 2), np.float32),
                     prompt_len=int(req.prompt.shape[0]),
                     status=status, detail=detail)


#: the engine's guard/robustness event categories (one obs counter each)
GUARD_STAT_KEYS = ("flagged_rows", "quarantined", "preempted",
                   "integrity_rebuilds", "snapshot_errors")


class _GuardStats:
    """``ServeEngine.guard_stats``, backed by obs counters.

    Historically a plain dict; chaos tests and callers read AND mutate it
    (``eng.guard_stats["preempted"] += 1``), and ``snapshot()/restore()``
    round-trip it.  This view keeps that exact mutable-mapping surface
    while storing every count in the engine's
    ``serve_guard_events_total{kind=...}`` counters, so the values show
    up in metrics exports and restored engines RESUME their counts
    (``update`` sets the counters to the persisted values)."""

    def __init__(self, registry: "obs_mod.MetricsRegistry"):
        self._registry = registry
        self._keys = list(GUARD_STAT_KEYS)
        for k in GUARD_STAT_KEYS:
            self._counter(k)

    def _counter(self, key: str) -> "obs_mod.Counter":
        if key not in self._keys:
            self._keys.append(key)
        return self._registry.counter("serve_guard_events_total", kind=key)

    def __getitem__(self, key: str) -> int:
        return self._counter(key).value

    def __setitem__(self, key: str, value: int) -> None:
        self._counter(key).set(int(value))

    def __contains__(self, key: str) -> bool:
        return key in self._keys

    def __iter__(self):
        return iter(tuple(self._keys))

    def __len__(self) -> int:
        return len(self._keys)

    def keys(self):
        return tuple(self._keys)

    def items(self):
        return [(k, self[k]) for k in self._keys]

    def values(self):
        return [self[k] for k in self._keys]

    def get(self, key: str, default=None):
        return self[key] if key in self._keys else default

    def update(self, other) -> None:
        for k, v in dict(other).items():
            self[k] = v

    def __repr__(self) -> str:
        return repr(dict(self.items()))

    def __eq__(self, other) -> bool:
        return dict(self.items()) == other


class ServeEngine:
    """Continuous-batching greedy decoder with a paged KV cache.

    Parameters: ``max_batch`` concurrent rows; ``page_size`` tokens/page;
    ``max_ctx`` per-sequence ceiling (prompt + generated); ``num_pages``
    defaults to a full pool (``max_batch * pages_per_seq``); ``eos_id``
    enables per-sequence termination (None = run to ``max_new``);
    ``kv_mode`` is the page storage format ("bf16" matches the
    ``greedy_generate`` baseline cache bitwise; "ff_bf16" pages FF hi/lo
    limb planes through the shared block table).  The attention impl and
    scoring class follow the ambient ``ff.policy`` (``attention="fast"``
    default; ``ff.policy(attention="ff")`` switches the decode softmax to
    the compensated FF class).

    Robustness knobs: ``max_queue`` bounds the wait queue (overflow =>
    ``REJECTED``, never an exception); ``reserve`` is ``"trajectory"``
    (default: whole-trajectory page reservation at admission — a request
    that joins always completes) or ``"prompt"`` (lazy growth per decode
    step + preempt-and-requeue of the youngest row on pool exhaustion —
    higher occupancy, same tokens); ``guard`` overrides the ambient
    ``ff.guard`` mode for the per-step health probe (None = inherit at
    construction); ``sync_every`` batches the device->host sync for
    eos-less decode (forced to 1 when ``eos_id`` is set — EOS needs the
    token on the host every step).

    Crash-safety knobs: ``journal`` names an fsync'd JSONL write-ahead
    log — ``submit()`` records every request durably before admission,
    and attaching an existing journal replays its unaccounted-for
    requests in original order (see :meth:`attach_journal` /
    :func:`resume_engine`); :meth:`snapshot` / :meth:`restore` freeze and
    rebuild the full engine with token-for-token replay parity.
    """

    def __init__(self, params: Any, cfg: ModelConfig, *,
                 max_batch: int = 8, page_size: int = 16,
                 max_ctx: int = 256, num_pages: Optional[int] = None,
                 eos_id: Optional[int] = None, kv_mode: str = "bf16",
                 policy: Optional[PrecisionPolicy] = None,
                 max_queue: Optional[int] = None,
                 reserve: str = "trajectory",
                 guard: Optional[str] = None,
                 sync_every: int = 1,
                 journal: Optional[str] = None,
                 obs: Optional["obs_mod.Observer"] = None):
        _check_cfg(cfg)
        if reserve not in ("trajectory", "prompt"):
            raise ValueError(f"reserve {reserve!r}: 'trajectory' | 'prompt'")
        if sync_every < 1:
            raise ValueError("sync_every must be >= 1")
        self.params = params
        self.cfg = cfg
        self.policy = resolve_policy(policy)
        self.max_batch = max_batch
        self.eos_id = eos_id
        self.max_queue = max_queue
        self.reserve = reserve
        if guard is None:
            from repro.ff.guard import current_guard
            guard = current_guard().mode
        if guard not in ("off", "check", "degrade"):
            raise ValueError(f"guard {guard!r}: 'off' | 'check' | 'degrade'")
        self.guard_mode = guard
        self.sync_every = 1 if eos_id is not None else int(sync_every)
        pages_per_seq = -(-max_ctx // page_size)
        if num_pages is None:
            num_pages = max_batch * pages_per_seq
        self.kv = PagedKVCache(
            cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim,
            num_pages=num_pages, page_size=page_size, max_seqs=max_batch,
            max_ctx=max_ctx, kv_mode=kv_mode)
        self.queue: List[Dict[str, Any]] = []   # {"req", "t_sub", "step_sub"}
        self.results: Dict[int, GenResult] = {}
        # slot -> in-flight request bookkeeping (None = free row)
        self._slots: List[Optional[Dict[str, Any]]] = [None] * max_batch
        self._last_tok = np.zeros((max_batch,), np.int32)
        self._token_dev = jnp.zeros((max_batch,), jnp.int32)
        self._pending: List[Dict[str, Any]] = []  # unsynced decode outputs
        self._admit_seq = 0
        self._auditing = False
        # per-engine observability: a private metrics registry (so tests /
        # concurrent engines never share counts) + the request/step trace
        self.obs = obs if obs is not None else obs_mod.Observer()
        self.guard_stats = _GuardStats(self.obs.registry)
        self._req_trace: Dict[int, Dict[str, Any]] = {}
        self._last_flush_ts = self.obs.trace.now()
        self.journal: Optional[RequestJournal] = None
        self._snap_cover: Optional[set] = None  # uids of last async save
        # NOTE: the page planes are deliberately NOT donated — on the CPU
        # backend donation around the layer scan costs a defensive copy
        # per step (measured 2x step latency); the non-donated step keeps
        # the pool update as cheap aliased buffers
        self._decode = jax.jit(self._make_decode_step())
        self._score = jax.jit(
            lambda lg, tk: token_logprob(lg, tk, self.policy))
        def _ff_limbs(lg, tk):
            r = token_logprob_ff(lg, tk)
            return r.hi, r.lo
        self._score_ff = jax.jit(_ff_limbs)
        self._prefill_cache: Dict[int, Any] = {}
        self.decode_steps = 0
        if journal is not None:
            self.attach_journal(journal)

    # -- jitted paged decode step -----------------------------------------

    def _make_decode_step(self):
        cfg, policy, kv = self.cfg, self.policy, self.kv
        ps, npg = kv.page_size, kv.max_pages
        ff_pages = kv.kv_mode == "ff_bf16"
        probe = self.guard_mode != "off"

        def step(params, token, lens, bt, active, planes):
            """token: (B,1) int32; lens: (B,) tokens already cached;
            bt: (B, npg) page table (-1 empty); active: (B,) bool;
            planes: dict of (L, NP, ps, KV, hd).  Returns (next greedy
            token (B,), its f32 and FF (hi, lo) logprobs, a per-row guard
            flag (constant False with the probe off), updated planes) —
            argmax and BOTH scoring tiers run inside the one jitted step,
            so per decode step the host sees four (B,) vectors (plus the
            flag), not the (B, V) logits.  Math per active row is exactly
            the ``model.decode_step`` dense body at that row's position."""
            dt = jnp.dtype(cfg.compute_dtype)
            B = token.shape[0]
            H, KVh = cfg.num_heads, cfg.num_kv_heads
            hd = cfg.resolved_head_dim
            NP = next(iter(planes.values())).shape[1]
            x = embed_apply(params["embed"], token, dt)
            # the page/offset every row writes its new K/V to (drop page
            # NP for inactive rows -> scatter is a no-op there)
            rowpage = bt[jnp.arange(B), lens // ps]
            wpage = jnp.where(active, rowpage, jnp.int32(NP))
            woff = lens % ps
            gidx = jnp.maximum(bt, 0)          # gather table (garbage rows
            posv = lens[:, None]               # are masked by lens later)

            def body(carry, scanned):
                h, bad = carry
                lp = scanned[0]
                pl = dict(zip(sorted(planes), scanned[1:]))
                z = rms_norm(h, lp["ln1"], cfg.norm_eps,
                             ff_stats=policy.ff_reductions)
                ap = lp["attn"]
                q = (z @ ap["wq"].astype(dt)).reshape(B, 1, H, hd)
                k = (z @ ap["wk"].astype(dt)).reshape(B, 1, KVh, hd)
                v = (z @ ap["wv"].astype(dt)).reshape(B, 1, KVh, hd)
                q = apply_rope(q, posv, cfg.rope_theta)
                k = apply_rope(k, posv, cfg.rope_theta)
                if probe:
                    # non-finite new K/V in this layer poisons the row's
                    # cache for every later step: flag at the source
                    bad = bad | ~jnp.isfinite(
                        k.astype(jnp.float32)).all(axis=(1, 2, 3))
                    bad = bad | ~jnp.isfinite(
                        v.astype(jnp.float32)).all(axis=(1, 2, 3))
                gathered = {}
                for base, new in (("k", k), ("v", v)):
                    if ff_pages:
                        hi, lo = ff_split(new[:, 0])
                        pl[f"{base}_hi"] = pl[f"{base}_hi"].at[
                            wpage, woff].set(hi, mode="drop")
                        pl[f"{base}_lo"] = pl[f"{base}_lo"].at[
                            wpage, woff].set(lo, mode="drop")
                        merged = ff_merge(pl[f"{base}_hi"][gidx],
                                          pl[f"{base}_lo"][gidx])
                    else:
                        pdt = pl[base].dtype
                        pl[base] = pl[base].at[wpage, woff].set(
                            new[:, 0].astype(pdt), mode="drop")
                        merged = pl[base][gidx]
                    gathered[base] = merged.reshape(B, npg * ps, KVh, hd)
                o = decode_attention(q, gathered["k"], gathered["v"],
                                     lens + 1, impl=policy.attention)
                h = h + (o.reshape(B, 1, H * hd) @ ap["wo"].astype(dt))
                z = rms_norm(h, lp["ln2"], cfg.norm_eps,
                             ff_stats=policy.ff_reductions)
                f = mlp_apply(lp["ffn"], z, ff_math=policy.ff_math)
                return (h + f, bad), tuple(pl[n] for n in sorted(pl))

            bad0 = jnp.zeros((B,), jnp.bool_)
            (x, bad), updated = lax.scan(
                body, (x, bad0),
                (params["layers"],) + tuple(
                    planes[n] for n in sorted(planes)))
            x = rms_norm(x, params["final_norm"], cfg.norm_eps,
                         ff_stats=policy.ff_reductions)
            logits = unembed_apply(params["embed"], x, cfg,
                                   ff_math=policy.ff_math)[:, 0]
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            lp = token_logprob(logits, nxt, policy)
            lp_ff = token_logprob_ff(logits, nxt)
            if probe:
                # score health: non-finite f32 score, or an FF score pair
                # that is non-finite / unnormalized (|lo| > ulp(hi)/2)
                bad = bad | ~jnp.isfinite(lp) | ~health_mask(lp_ff)
            return (nxt, lp, lp_ff.hi, lp_ff.lo, bad,
                    dict(zip(sorted(planes), updated)))

        return step

    # -- request lifecycle -------------------------------------------------

    def _trace_submit(self, uid: int) -> None:
        """Open the request's span timeline (idempotent per uid — preempt
        re-submission keeps the original submit timestamp)."""
        if uid not in self._req_trace:
            self._req_trace[uid] = {"submit": self.obs.trace.now(),
                                    "admit": None}
            self.obs.trace.name_request_track(uid)

    def _set_result(self, res: GenResult) -> None:
        """The single terminal-result sink: records the result AND, with
        a journal attached, durably marks the uid retired (truncating the
        log once every journaled request has a terminal status).  Closes
        the request's trace spans: a ``decode`` child (admission ->
        retire) when the request ran, and the top-level ``request`` span
        (submit -> retire) carrying the terminal status."""
        self.results[res.uid] = res
        tr = self._req_trace.pop(res.uid, None)
        if tr is not None:
            now = self.obs.trace.now()
            tid = self.obs.trace.request_tid(res.uid)
            if tr["admit"] is not None:
                self.obs.trace.complete("decode", tr["admit"],
                                        now - tr["admit"], tid=tid)
            self.obs.trace.complete(
                "request", tr["submit"], now - tr["submit"], tid=tid,
                args={"status": res.status, "uid": int(res.uid),
                      "tokens": int(res.tokens.shape[0]),
                      "detail": res.detail})
            self.obs.registry.counter("serve_requests_total",
                                      status=res.status).inc()
            self.obs.registry.counter("serve_tokens_emitted_total").inc(
                int(res.tokens.shape[0]))
        if self.journal is not None:
            self.journal.retire(res.uid, res.status)

    def submit(self, req: Request) -> str:
        """Enqueue a request.  Returns ``"QUEUED"`` or, when the request
        can never be served (bounded queue full, prompt + max_new over
        ``max_ctx``, or a trajectory larger than the whole pool), records
        a ``REJECTED`` result and returns it — submission never raises.
        With a journal attached the request is journaled (fsync'd)
        BEFORE admission: an accepted request survives a crash."""
        if self.journal is not None:
            self.journal.append(req, step_sub=self.decode_steps)
        return self._submit(req, t_sub=time.monotonic(),
                            step_sub=self.decode_steps, bounded=True)

    def _submit(self, req: Request, *, t_sub: float, step_sub: int,
                bounded: bool) -> str:
        """Admission checks + enqueue.  ``bounded=False`` (journal
        replay) skips the queue bound — the request was already accepted
        once; structural impossibility still rejects."""
        self._trace_submit(req.uid)
        S = int(req.prompt.shape[0])
        total = S + req.max_new
        max_ctx = self.kv.max_pages * self.kv.page_size
        if total > max_ctx:
            self._set_result(_empty_result(
                req, REJECTED, f"prompt+max_new = {total} exceeds "
                f"max_ctx = {max_ctx}"))
            return REJECTED
        if self.kv.pages_for(total) > self.kv.num_pages:
            self._set_result(_empty_result(
                req, REJECTED, f"trajectory needs "
                f"{self.kv.pages_for(total)} pages; pool has "
                f"{self.kv.num_pages}"))
            return REJECTED
        if bounded and self.max_queue is not None \
                and len(self.queue) >= self.max_queue:
            self._set_result(_empty_result(
                req, REJECTED, f"wait queue full (max_queue = "
                f"{self.max_queue})"))
            return REJECTED
        self.queue.append({"req": req, "t_sub": t_sub,
                           "step_sub": step_sub})
        return "QUEUED"

    def status(self, uid: int) -> str:
        """Lifecycle status for a submitted uid: a terminal status from
        :data:`STATUSES`, else ``"RUNNING"`` / ``"QUEUED"``."""
        if uid in self.results:
            return self.results[uid].status
        for s in self._slots:
            if s is not None and s["req"].uid == uid:
                return "RUNNING"
        if any(q["req"].uid == uid for q in self.queue):
            return "QUEUED"
        raise KeyError(f"unknown request uid {uid}")

    def _prefill_fn(self, S: int):
        """Exact-length prefill, jit-cached per distinct prompt length."""
        if S not in self._prefill_cache:
            step = make_prefill_step(self.cfg, self.policy)
            self._prefill_cache[S] = jax.jit(step)
        return self._prefill_cache[S]

    def _deadline_passed(self, req: Request, t_sub: float,
                         step_sub: int) -> bool:
        if req.deadline_s is not None and \
                time.monotonic() - t_sub > req.deadline_s:
            return True
        if req.deadline_steps is not None and \
                self.decode_steps - step_sub >= req.deadline_steps:
            return True
        return False

    def _expire_queue(self) -> None:
        kept = []
        for q in self.queue:
            if self._deadline_passed(q["req"], q["t_sub"], q["step_sub"]):
                self._set_result(_empty_result(
                    q["req"], TIMEOUT, "deadline expired while queued"))
            else:
                kept.append(q)
        self.queue = kept

    def _admit(self) -> None:
        """Join waiting requests into free rows while pages allow (FIFO —
        no request starves behind an unschedulable head-of-line)."""
        admitted = False
        while self.queue:
            q = self.queue[0]
            req = q["req"]
            S = int(req.prompt.shape[0])
            total = S + req.max_new
            slot = next((i for i, s in enumerate(self._slots) if s is None),
                        None)
            need = total if self.reserve == "trajectory" else S
            if slot is None or not self.kv.can_alloc(need):
                break
            self.queue.pop(0)
            tr = self._req_trace.get(req.uid)
            ts_adm = self.obs.trace.now()
            tid = self.obs.trace.request_tid(req.uid)
            if tr is not None:
                self.obs.trace.complete("queued", tr["submit"],
                                        ts_adm - tr["submit"], tid=tid)
            if self.reserve == "trajectory":
                self.kv.alloc(slot, total)  # reserve the whole trajectory
                self.kv.seq_lens[slot] = S  # ...but only S tokens are live
            else:
                self.kv.alloc(slot, S)      # lazy: grow() per decode step
            # the prefill cache dtype IS the page fidelity: bf16 matches
            # the greedy_generate baseline cache bitwise; the f32 / FF
            # page modes keep the full compute-precision K/V
            cache_dt = jnp.bfloat16 if self.kv.kv_mode == "bf16" \
                else jnp.float32
            cache = init_cache(self.cfg, 1, S, dtype=cache_dt)
            with obs_mod.annotate("serve.prefill"):
                logits, cache = self._prefill_fn(S)(
                    self.params, {"tokens": jnp.asarray(req.prompt[None])},
                    cache)
            self.kv.write_prefill(slot, {
                "k": cache["layers"]["k"][:, 0],
                "v": cache["layers"]["v"][:, 0]})
            ts_pf = self.obs.trace.now()
            self.obs.trace.complete("prefill", ts_adm, ts_pf - ts_adm,
                                    tid=tid, args={"prompt_len": S})
            self.obs.registry.histogram(
                "serve_prefill_seconds").observe((ts_pf - ts_adm) / 1e6)
            if tr is not None:
                tr["admit"] = ts_pf
            tok = int(jnp.argmax(logits, -1)[0])
            lp = float(self._score(logits, jnp.asarray([tok], jnp.int32))[0])
            lph, lpl = self._score_ff(logits, jnp.asarray([tok], jnp.int32))
            state = {"req": req, "prompt_len": S,
                     "tokens": [tok], "logprobs": [lp],
                     "logprobs_ff": [(float(lph[0]), float(lpl[0]))],
                     "pending": 0, "start_step": self.decode_steps,
                     "t_sub": q["t_sub"], "step_sub": q["step_sub"],
                     "admit_seq": self._admit_seq}
            self._admit_seq += 1
            self._slots[slot] = state
            self._last_tok[slot] = tok
            self._token_dev = self._token_dev.at[slot].set(tok)
            admitted = True
            if self.guard_mode != "off" and not (
                    np.isfinite(lp) and np.isfinite(float(lph[0]))):
                self._quarantine(slot, "non-finite prefill score")
            elif self._finished(state):
                self._retire(slot)
        if admitted and self.guard_mode != "off":
            self._audit_paging()

    def _finished(self, state: Dict[str, Any]) -> bool:
        if len(state["tokens"]) >= state["req"].max_new:
            return True
        return self.eos_id is not None and state["tokens"][-1] == self.eos_id

    def _retire(self, slot: int, status: str = OK, detail: str = "") -> None:
        state = self._slots[slot]
        req = state["req"]
        self._set_result(GenResult(
            uid=req.uid,
            tokens=np.asarray(state["tokens"], np.int32),
            logprobs=np.asarray(state["logprobs"], np.float32),
            logprobs_ff=np.asarray(state["logprobs_ff"], np.float32),
            prompt_len=state["prompt_len"],
            status=status, detail=detail))
        self.kv.free_slot(slot)
        self._slots[slot] = None
        self._last_tok[slot] = 0

    def _fast_policy(self) -> PrecisionPolicy:
        """One accuracy class below the serving policy: fast f32
        attention, builtin transcendentals, f32 scoring inputs."""
        return dataclasses.replace(
            self.policy, attention="fast", ff_math=False)

    def _quarantine(self, slot: int, why: str,
                    trust_pages: bool = True) -> None:
        """Evict a poisoned row and retry the whole request on the fast
        tier (greedy decoding is deterministic, so the retry IS the
        request's fast-class answer, not a different sample).  Healthy
        retry => ``DEGRADED``; a retry that still scores non-finite =>
        ``FAILED`` (tokens withheld — never silently wrong)."""
        state = self._slots[slot]
        req = state["req"]
        if trust_pages:
            self.kv.free_slot(slot)
        else:
            self.kv.drop_slot(slot)     # caller rebuilds the free list
        self._slots[slot] = None
        self._last_tok[slot] = 0
        self.guard_stats["quarantined"] += 1
        self.obs.trace.instant("quarantine",
                               args={"uid": int(req.uid), "why": why})
        report_violation("serve.decode", "nonfinite")
        detail = f"guard: {why}; retried on the fast tier"
        try:
            toks, lps = greedy_generate(
                self.params, self.cfg, jnp.asarray(req.prompt[None]),
                req.max_new, cache_len=state["prompt_len"] + req.max_new,
                policy=self._fast_policy(), return_logprobs=True,
                eos_id=self.eos_id)
            toks = np.asarray(toks[0], np.int32)
            lps = np.asarray(lps[0], np.float32)
        except Exception as e:   # a retry must never take the engine down
            self._set_result(_empty_result(
                req, FAILED, f"guard: {why}; fast-tier retry raised "
                f"{type(e).__name__}: {e}"))
            return
        if not np.all(np.isfinite(lps)):
            self._set_result(_empty_result(
                req, FAILED, f"guard: {why}; fast-tier retry still "
                f"non-finite"))
            return
        self._set_result(GenResult(
            uid=req.uid, tokens=toks, logprobs=lps,
            logprobs_ff=np.stack([lps, np.zeros_like(lps)], axis=1),
            prompt_len=state["prompt_len"], status=DEGRADED, detail=detail))

    def _audit_paging(self) -> None:
        """Guard-mode integrity audit of the paging metadata: quarantine
        every slot with an untrusted page list, then rebuild the free
        list.  Never raises; runs per flush and per admission round."""
        if self._auditing:
            return
        self._auditing = True
        try:
            problems, bad = self.kv.check_integrity()
            if not problems:
                return
            warnings.warn("ServeEngine: paging metadata corrupt — " +
                          "; ".join(problems[:4]) +
                          (f" (+{len(problems) - 4} more)"
                           if len(problems) > 4 else ""),
                          FFGuardWarning, stacklevel=2)
            report_violation("serve.paging", "nonfinite", len(problems))
            self._flush()
            for slot in sorted(bad):
                if self._slots[slot] is not None:
                    self._quarantine(slot, "corrupt block table",
                                     trust_pages=False)
                else:
                    self.kv.drop_slot(slot)
            self.kv.rebuild_free_list()
            self.guard_stats["integrity_rebuilds"] += 1
            self.obs.trace.instant("integrity_rebuild",
                                   args={"problems": len(problems)})
        finally:
            self._auditing = False

    # -- decode ------------------------------------------------------------

    def _row_len(self, state: Dict[str, Any]) -> int:
        """Tokens already cached for this row = prompt + emitted (incl.
        unsynced pending steps) - 1 (the latest token is the step INPUT —
        its K/V is written by the step itself)."""
        return state["prompt_len"] + len(state["tokens"]) \
            + state["pending"] - 1

    def _preempt(self, slot: int) -> None:
        """Preempt a running row: pages back to the free list, request
        back to the FRONT of the queue (it keeps its original submit
        deadline) — the later re-prefill replays deterministically, so
        the final tokens are identical to an uninterrupted run."""
        state = self._slots[slot]
        req = state["req"]
        self.kv.free_slot(slot)
        self._slots[slot] = None
        self._last_tok[slot] = 0
        self.guard_stats["preempted"] += 1
        self.obs.trace.instant("preempt", args={"uid": int(req.uid)})
        tr = self._req_trace.get(req.uid)
        if tr is not None:
            tr["admit"] = None          # decode restarts at re-admission
        self.queue.insert(0, {"req": req, "t_sub": state["t_sub"],
                              "step_sub": state["step_sub"]})

    def _ensure_growth(self) -> bool:
        """``reserve="prompt"`` only: make sure every active row has a
        page for the K/V it writes this step, preempting the youngest
        running row on pool exhaustion.  Returns False when nothing is
        left to decode (everything preempted/retired)."""
        if self.reserve == "trajectory":
            return any(s is not None for s in self._slots)
        order = sorted(
            (i for i, s in enumerate(self._slots) if s is not None),
            key=lambda i: self._slots[i]["admit_seq"])
        for slot in order:
            state = self._slots[slot]
            if state is None:       # preempted by an older row's growth
                continue
            target = self._row_len(state) + 1
            while True:
                if self.kv.pages_for(target) <= self.kv.pages_for(
                        int(self.kv.seq_lens[slot])) or self.kv.free_pages:
                    self.kv.grow(slot, target)
                    break
                # pool dry: sync pending work, then preempt the youngest
                self._flush()
                if self._slots[slot] is None:   # flush retired/quarantined
                    break
                running = [i for i, s in enumerate(self._slots)
                           if s is not None]
                if len(running) == 1:
                    # nobody to steal from: the pool cannot hold even one
                    # trajectory -> terminal, not a livelock
                    self._retire(slot, FAILED,
                                 "page pool too small for one trajectory")
                    break
                victim = max(running,
                             key=lambda i: self._slots[i]["admit_seq"])
                self._preempt(victim)
                if victim == slot:
                    break
        return any(s is not None for s in self._slots)

    def _step_decode(self) -> None:
        if not self._ensure_growth():
            return
        active_np = np.asarray([s is not None for s in self._slots])
        lens = np.asarray(
            [self._row_len(s) if s else 0 for s in self._slots],
            np.int32)
        t0 = self.obs.trace.now()
        with obs_mod.annotate("serve.decode_step"):
            nxt, lp, lph, lpl, bad, self.kv.planes = self._decode(
                self.params, self._token_dev[:, None],
                jnp.asarray(lens), jnp.asarray(self.kv.block_table),
                jnp.asarray(active_np), self.kv.planes)
        # host-side dispatch latency: jax dispatch is async, so this is
        # the step's *enqueue* cost; the blocking device time lands in
        # serve_flush_seconds at the sync_every boundary
        self.obs.registry.histogram("serve_decode_step_seconds").observe(
            (self.obs.trace.now() - t0) / 1e6)
        self._token_dev = nxt
        self._pending.append({"step": self.decode_steps, "nxt": nxt,
                              "lp": lp, "lph": lph, "lpl": lpl,
                              "bad": bad})
        self.decode_steps += 1
        for slot, state in enumerate(self._slots):
            if state is None:
                continue
            state["pending"] += 1
            # the step wrote this row's K/V at position lens[slot] (in
            # prompt mode grow() already advanced seq_lens pre-step)
            self.kv.seq_lens[slot] = int(lens[slot]) + 1

    def _flush(self) -> None:
        """Sync every pending decode step's four (B,) vectors (+ guard
        flag) to the host in ONE ``device_get``, append tokens/scores in
        step order, then apply guard / deadline / finish transitions."""
        if not self._pending:
            return
        entries = self._pending
        self._pending = []
        t0 = self.obs.trace.now()
        host = jax.device_get([(e["nxt"], e["lp"], e["lph"], e["lpl"],
                                e["bad"]) for e in entries])
        t1 = self.obs.trace.now()
        self.obs.trace.instant("host_sync",
                               args={"steps": len(entries)})
        self.obs.registry.histogram("serve_flush_seconds").observe(
            (t1 - t0) / 1e6)
        n_synced = 0
        flagged: Dict[int, bool] = {}
        for (e, (nxt, lp, lph, lpl, bad)) in zip(entries, host):
            nxt = np.asarray(nxt, np.int32)
            for slot, state in enumerate(self._slots):
                if state is None or state["pending"] == 0:
                    continue
                if state["start_step"] > e["step"]:
                    continue            # admitted after this step ran
                tok = int(nxt[slot])
                state["tokens"].append(tok)
                state["logprobs"].append(float(lp[slot]))
                state["logprobs_ff"].append(
                    (float(lph[slot]), float(lpl[slot])))
                state["pending"] -= 1
                n_synced += 1
                self._last_tok[slot] = tok
                if bool(bad[slot]):
                    flagged[slot] = True
        # decode throughput over the inter-flush window (tokens made
        # host-visible per wall second between consecutive syncs)
        if n_synced and t1 > self._last_flush_ts:
            self.obs.registry.histogram("serve_tokens_per_s").observe(
                n_synced / ((t1 - self._last_flush_ts) / 1e6))
        self._last_flush_ts = t1
        if flagged:
            self.guard_stats["flagged_rows"] += len(flagged)
        for slot in list(flagged):
            if self._slots[slot] is not None:
                self._quarantine(slot, "per-step probe flagged the row")
        for slot, state in enumerate(self._slots):
            if state is None:
                continue
            if self._deadline_passed(state["req"], state["t_sub"],
                                     state["step_sub"]):
                self._retire(slot, TIMEOUT,
                             "deadline expired mid-decode "
                             f"(kept {len(state['tokens'])} tokens)")
            elif self._finished(state):
                self._retire(slot)
        if self.guard_mode != "off":
            self._audit_paging()

    def _must_flush(self) -> bool:
        if not self._pending:
            return False
        if len(self._pending) >= self.sync_every:
            return True
        for state in self._slots:
            if state is None:
                continue
            req = state["req"]
            if len(state["tokens"]) + state["pending"] >= req.max_new:
                return True
            if req.deadline_s is not None or req.deadline_steps is not None:
                if self._deadline_passed(req, state["t_sub"],
                                         state["step_sub"]):
                    return True
        if self.queue and any(s is None for s in self._slots):
            return True                 # admission opportunity
        return False

    def step(self) -> bool:
        """One scheduler iteration: admit waiting requests into free rows,
        then advance every running row one token.  Returns True while work
        remains.  Public hook for callers that interleave ``submit`` with
        decoding (staggered arrivals join the running batch at the next
        step — see ``examples/serve_lm.py``).  Never raises for
        off-nominal scheduling conditions — every request ends in a
        terminal status from :data:`STATUSES`."""
        self._expire_queue()
        self._admit()
        if any(s is not None for s in self._slots):
            self._step_decode()
            if self._must_flush():
                self._flush()
                self._admit()
        elif self.queue:
            self._flush()
            if not any(s is not None for s in self._slots) and self.queue:
                # empty engine, head still unschedulable: terminal (pages
                # leaked or pool undersized) — fail it rather than stall
                q = self.queue.pop(0)
                self._set_result(_empty_result(
                    q["req"], FAILED,
                    "unschedulable: no running rows and the head request "
                    "cannot be admitted"))
        elif self._pending:
            self._flush()
        self._trace_step_counters()
        return (any(s is not None for s in self._slots)
                or bool(self.queue) or bool(self._pending))

    def _trace_step_counters(self) -> None:
        """Per-scheduler-step samples: queue depth, active batch rows, and
        page-pool occupancy, as both registry gauges and Perfetto counter
        tracks."""
        depth = len(self.queue)
        active = sum(1 for s in self._slots if s is not None)
        free = len(self.kv.free_pages)
        used = self.kv.num_pages - free
        self.obs.registry.gauge("serve_queue_depth").set(depth)
        self.obs.registry.gauge("serve_active_rows").set(active)
        self.obs.registry.gauge("serve_pages_used").set(used)
        self.obs.trace.counter("queue", {"depth": depth, "active": active})
        self.obs.trace.counter("pages", {"used": used, "free": free})

    def run(self, *, snapshot_dir: Optional[str] = None,
            snapshot_every: Optional[int] = None) -> Dict[int, GenResult]:
        """Drain the queue: admit + decode until everything completes.
        Every submitted uid is present in the result dict with a terminal
        status — under fault injection too (chaos tier, see
        ``repro.chaos``).

        With ``snapshot_dir`` + ``snapshot_every`` set, the engine
        snapshots every N decode steps through an async checkpointer
        (writes overlap decode), ``poll()``-ing it each scheduler
        iteration so a failing disk surfaces immediately as an
        :class:`FFGuardWarning` + ``guard_stats["snapshot_errors"]``
        (serving continues — durability degrades, decode does not).  A
        final synchronous snapshot lands after the queue drains."""
        ckpt = None
        last_snap = self.decode_steps
        if snapshot_dir is not None and snapshot_every:
            from repro.checkpoint import checkpoint as ckpt_lib
            ckpt = ckpt_lib.AsyncCheckpointer(snapshot_dir)
        while self.step():
            if ckpt is not None:
                self._poll_snapshot(ckpt)
                if self.decode_steps - last_snap >= snapshot_every:
                    try:
                        arrays, meta = self.snapshot()
                        ckpt.save(self.decode_steps, arrays, extra=meta)
                        self._snap_cover = set(self.results)
                        self.obs.trace.instant(
                            "snapshot", args={"step": self.decode_steps,
                                              "mode": "async"})
                    except Exception as e:
                        self._snapshot_error(e)
                    last_snap = self.decode_steps
        self._flush()
        if ckpt is not None:
            try:
                ckpt.wait()
            except BaseException as e:
                self._snapshot_error(e)
            self._snap_cover = None
            try:
                self.save_snapshot(snapshot_dir)
            except Exception as e:
                self._snapshot_error(e)
        return self.results

    # -- crash safety: snapshot / restore / journal ------------------------

    def _fingerprint(self) -> Dict[str, Any]:
        """The construction-time knobs a snapshot is only valid under.
        Model params/config are NOT snapshotted (the caller provides the
        same weights, as with trainer checkpoints) — the policy repr and
        config name are fingerprinted so a mismatch fails loudly."""
        return {"kv_mode": self.kv.kv_mode, "max_batch": self.max_batch,
                "page_size": self.kv.page_size,
                "max_ctx": self.kv.max_pages * self.kv.page_size,
                "num_pages": self.kv.num_pages, "eos_id": self.eos_id,
                "reserve": self.reserve, "sync_every": self.sync_every,
                "guard": self.guard_mode, "max_queue": self.max_queue,
                "policy_repr": repr(self.policy),
                "cfg_name": self.cfg.name}

    def snapshot(self):
        """Freeze the full engine between decode steps.  Returns
        ``(arrays, meta)``: a flat ``{name: np.ndarray}`` dict (KV planes
        via :meth:`PagedKVCache.to_state`, per-slot/queue prompts and
        emitted tokens/scores, completed results) plus a JSON-able meta
        dict (schema, wall time, counters, per-request deadlines as
        elapsed time — portable across processes).  Pending device work
        is synced first, so the snapshot sits at a step boundary and the
        resumed decode replays token-for-token."""
        self._flush()
        arrays: Dict[str, np.ndarray] = {}
        for k, v in self.kv.to_state().items():
            arrays[f"kv.{k}"] = v
        arrays["last_tok"] = self._last_tok.copy()
        now_m, now_w = time.monotonic(), time.time()
        slots_meta: List[Optional[Dict[str, Any]]] = []
        for i, s in enumerate(self._slots):
            if s is None:
                slots_meta.append(None)
                continue
            req = s["req"]
            arrays[f"slot.{i}.prompt"] = np.asarray(req.prompt, np.int32)
            arrays[f"slot.{i}.tokens"] = np.asarray(s["tokens"], np.int32)
            arrays[f"slot.{i}.logprobs"] = np.asarray(
                s["logprobs"], np.float32)
            arrays[f"slot.{i}.logprobs_ff"] = np.asarray(
                s["logprobs_ff"], np.float32).reshape(-1, 2)
            slots_meta.append({
                "uid": int(req.uid), "max_new": int(req.max_new),
                "deadline_s": req.deadline_s,
                "deadline_steps": req.deadline_steps,
                "prompt_len": int(s["prompt_len"]),
                "start_step": int(s["start_step"]),
                "step_sub": int(s["step_sub"]),
                "admit_seq": int(s["admit_seq"]),
                "elapsed_s": float(now_m - s["t_sub"])})
        queue_meta = []
        for j, q in enumerate(self.queue):
            req = q["req"]
            arrays[f"queue.{j}.prompt"] = np.asarray(req.prompt, np.int32)
            queue_meta.append({
                "uid": int(req.uid), "max_new": int(req.max_new),
                "deadline_s": req.deadline_s,
                "deadline_steps": req.deadline_steps,
                "step_sub": int(q["step_sub"]),
                "elapsed_s": float(now_m - q["t_sub"])})
        results_meta = []
        for uid, r in self.results.items():
            arrays[f"result.{uid}.tokens"] = np.asarray(r.tokens, np.int32)
            arrays[f"result.{uid}.logprobs"] = np.asarray(
                r.logprobs, np.float32)
            arrays[f"result.{uid}.logprobs_ff"] = np.asarray(
                r.logprobs_ff, np.float32).reshape(-1, 2)
            results_meta.append({"uid": int(uid), "status": r.status,
                                 "detail": r.detail,
                                 "prompt_len": int(r.prompt_len)})
        meta = {"schema": SNAPSHOT_SCHEMA, "wall_time": now_w,
                "decode_steps": int(self.decode_steps),
                "admit_seq": int(self._admit_seq),
                "guard_stats": {k: int(v)
                                for k, v in self.guard_stats.items()},
                "engine": self._fingerprint(),
                "slots": slots_meta, "queue": queue_meta,
                "results": results_meta}
        return arrays, meta

    def restore(self, arrays: Dict[str, np.ndarray], meta: Dict[str, Any],
                *, downtime_s: Optional[float] = None) -> None:
        """Rebuild a freshly constructed engine from :meth:`snapshot`
        output.  Validates the snapshot schema and the engine
        fingerprint (kv_mode, geometry, policy, ...) — a mismatch raises
        ``ValueError`` rather than decoding subtly-wrong tokens.

        Deadlines: per-request elapsed time was stored relative to the
        snapshot's wall clock; ``downtime_s`` (default: wall time since
        the snapshot) is added back, so a wall-clock ``deadline_s`` that
        expired while the process was down retires as the documented
        ``TIMEOUT`` immediately — never silently revived.  Deterministic
        ``deadline_steps`` budgets count decode steps and are unaffected
        by downtime."""
        if not isinstance(meta, dict) or meta.get("schema") != \
                SNAPSHOT_SCHEMA:
            raise ValueError(
                f"engine snapshot schema {meta.get('schema')!r} != "
                f"supported {SNAPSHOT_SCHEMA}")
        mine, theirs = self._fingerprint(), meta["engine"]
        for k in sorted(set(mine) | set(theirs)):
            if mine.get(k) != theirs.get(k):
                raise ValueError(
                    f"snapshot/engine mismatch: snapshot has "
                    f"{k}={theirs.get(k)!r}, this engine has "
                    f"{mine.get(k)!r}")
        if (self.queue or self._pending or self.results
                or any(s is not None for s in self._slots)):
            raise RuntimeError("restore() requires a freshly constructed "
                               "engine (no queued/running/completed work)")
        self.kv = PagedKVCache.from_state(
            {k[len("kv."):]: np.asarray(v) for k, v in arrays.items()
             if k.startswith("kv.")})
        self.decode_steps = int(meta["decode_steps"])
        self._admit_seq = int(meta["admit_seq"])
        self.guard_stats.update(meta["guard_stats"])
        now_m, now_w = time.monotonic(), time.time()
        if downtime_s is None:
            downtime_s = max(0.0, now_w - float(meta["wall_time"]))
        self._last_tok = np.asarray(arrays["last_tok"], np.int32).copy()
        self._token_dev = jnp.asarray(self._last_tok)
        for i, sm in enumerate(meta["slots"]):
            if sm is None:
                continue
            req = Request(uid=sm["uid"],
                          prompt=np.asarray(arrays[f"slot.{i}.prompt"],
                                            np.int32),
                          max_new=sm["max_new"],
                          deadline_s=sm["deadline_s"],
                          deadline_steps=sm["deadline_steps"])
            lf = np.asarray(arrays[f"slot.{i}.logprobs_ff"],
                            np.float32).reshape(-1, 2)
            self._slots[i] = {
                "req": req, "prompt_len": sm["prompt_len"],
                "tokens": [int(t) for t in arrays[f"slot.{i}.tokens"]],
                "logprobs": [float(x)
                             for x in arrays[f"slot.{i}.logprobs"]],
                "logprobs_ff": [(float(h), float(l)) for h, l in lf],
                "pending": 0, "start_step": sm["start_step"],
                "t_sub": now_m - (sm["elapsed_s"] + downtime_s),
                "step_sub": sm["step_sub"], "admit_seq": sm["admit_seq"]}
            # reopen the restored request's trace timeline (the pre-crash
            # spans belong to the crashed process's trace)
            self._trace_submit(sm["uid"])
            self._req_trace[sm["uid"]]["admit"] = self.obs.trace.now()
        self.queue = []
        for j, qm in enumerate(meta["queue"]):
            req = Request(uid=qm["uid"],
                          prompt=np.asarray(arrays[f"queue.{j}.prompt"],
                                            np.int32),
                          max_new=qm["max_new"],
                          deadline_s=qm["deadline_s"],
                          deadline_steps=qm["deadline_steps"])
            self.queue.append({
                "req": req,
                "t_sub": now_m - (qm["elapsed_s"] + downtime_s),
                "step_sub": qm["step_sub"]})
            self._trace_submit(qm["uid"])
        for rm in meta["results"]:
            uid = rm["uid"]
            self.results[uid] = GenResult(
                uid=uid,
                tokens=np.asarray(arrays[f"result.{uid}.tokens"],
                                  np.int32),
                logprobs=np.asarray(arrays[f"result.{uid}.logprobs"],
                                    np.float32),
                logprobs_ff=np.asarray(
                    arrays[f"result.{uid}.logprobs_ff"],
                    np.float32).reshape(-1, 2),
                prompt_len=rm["prompt_len"], status=rm["status"],
                detail=rm["detail"])
        # wall-clock deadlines that expired during downtime retire NOW,
        # with the tokens produced so far — documented, never revived
        self._expire_queue()
        for slot, state in enumerate(self._slots):
            if state is not None and self._deadline_passed(
                    state["req"], state["t_sub"], state["step_sub"]):
                self._retire(slot, TIMEOUT,
                             "deadline expired across restart downtime "
                             f"(kept {len(state['tokens'])} tokens)")

    def save_snapshot(self, directory: str) -> str:
        """Synchronous :meth:`snapshot` -> hardened checkpoint write
        (atomic tmp+rename, CRC32 manifest, keep-last-3).  The journal is
        compacted afterwards: the durable snapshot now covers every
        completed result.  Returns the checkpoint path."""
        from repro.checkpoint import checkpoint as ckpt_lib
        arrays, meta = self.snapshot()
        path = ckpt_lib.save(directory, self.decode_steps, arrays,
                             extra=meta)
        self.obs.trace.instant("snapshot",
                               args={"step": self.decode_steps,
                                     "mode": "sync"})
        self.obs.registry.counter("serve_snapshots_total").inc()
        if self.journal is not None:
            self.journal.compact(set(self.results))
        return path

    def _poll_snapshot(self, ckpt) -> None:
        """Surface async-write errors into the engine loop (not just the
        next ``wait()``), and compact the journal once the last enqueued
        snapshot is durably on disk."""
        err = ckpt.poll()
        if err is not None:
            self._snapshot_error(err)
            self._snap_cover = None
        elif self._snap_cover is not None and not (
                ckpt._thread is not None and ckpt._thread.is_alive()):
            if self.journal is not None:
                self.journal.compact(self._snap_cover)
            self._snap_cover = None

    def _snapshot_error(self, err: BaseException) -> None:
        self.guard_stats["snapshot_errors"] += 1
        self.obs.trace.instant("snapshot_error",
                               args={"error": type(err).__name__})
        warnings.warn(
            f"ServeEngine: snapshot write failed "
            f"({type(err).__name__}: {err}) — serving continues, restart "
            f"durability degraded", FFGuardWarning, stacklevel=3)

    def attach_journal(self, path: str) -> RequestJournal:
        """Attach a write-ahead request journal, replaying any journaled
        request not accounted for by the current engine state (terminal
        result, running row, or queued) in original submission order.
        Greedy decoding is deterministic, so a replayed request produces
        the same tokens the crashed run would have."""
        self.journal = RequestJournal(path)
        now_w = time.time()
        for rec in self.journal.pending():
            uid = rec["uid"]
            if uid in self.results:
                continue
            if any(s is not None and s["req"].uid == uid
                   for s in self._slots):
                continue
            if any(q["req"].uid == uid for q in self.queue):
                continue
            req = Request(uid=uid,
                          prompt=np.asarray(rec["prompt"], np.int32),
                          max_new=rec["max_new"],
                          deadline_s=rec.get("deadline_s"),
                          deadline_steps=rec.get("deadline_steps"))
            elapsed = max(0.0, now_w - rec.get("t_wall", now_w))
            self._submit(req, t_sub=time.monotonic() - elapsed,
                         step_sub=min(int(rec.get("step_sub", 0)),
                                      self.decode_steps),
                         bounded=False)
        return self.journal

    # -- guard introspection ----------------------------------------------

    def probe_kv(self):
        """Whole-pool FF health probe of the live KV planes: one
        :class:`~repro.ff.guard.GuardCounts` over every plane (in
        ``ff_bf16`` mode the storage limbs are merged first — bf16 limb
        pairs have their own, coarser normalization scale).  Debug /
        chaos-harness hook; the per-step probe only sees NEW K/V."""
        from repro.ff.guard import GuardCounts, guard_probe
        tot = [0, 0, 0]
        for base in ("k", "v"):
            if self.kv.kv_mode == "ff_bf16":
                plane = ff_merge(self.kv.planes[f"{base}_hi"],
                                 self.kv.planes[f"{base}_lo"])
            else:
                plane = self.kv.planes[base].astype(jnp.float32)
            c = guard_probe(plane)
            tot = [t + int(v) for t, v in zip(tot, c)]
        return GuardCounts(*(jnp.int32(t) for t in tot))


def resume_engine(params: Any, cfg: ModelConfig, snapshot_dir: str, *,
                  journal: Optional[str] = None,
                  downtime_s: Optional[float] = None,
                  policy: Optional[PrecisionPolicy] = None,
                  **engine_kwargs) -> ServeEngine:
    """Warm-restart a :class:`ServeEngine` after a crash.

    Loads the newest snapshot generation that VERIFIES (per-leaf CRC32 +
    schema version; a corrupted/torn/stale generation falls back warned
    to the previous retained one — see ``repro.checkpoint``), constructs
    an engine with the snapshot's own knobs (overridable via
    ``engine_kwargs``), restores it, then replays the write-ahead
    ``journal``'s unaccounted-for requests in original order.  When NO
    snapshot exists at all (crash before the first write, or its tmp dir
    was garbage-collected) the ladder bottoms out at a cold engine +
    full WAL replay — still token-for-token the lost run, just without
    the saved KV work.  Continuing is exact replay: greedy decode is
    deterministic, so tokens (and FF logprob bits) match the
    uninterrupted run; wall-clock deadlines that expired during downtime
    retire as ``TIMEOUT`` on restore.  Raises
    :class:`repro.checkpoint.checkpoint.CheckpointError` when
    generations exist but none verifies (corruption is never silent)."""
    from repro.checkpoint import checkpoint as ckpt_lib
    try:
        arrays, _step, meta = ckpt_lib.load_dict(snapshot_dir)
    except FileNotFoundError:
        arrays, meta = None, None
    if meta is not None:
        knobs = {k: v for k, v in meta["engine"].items()
                 if k not in ("policy_repr", "cfg_name", "guard")}
        knobs["guard"] = meta["engine"].get("guard", "off")
        knobs.update(engine_kwargs)
        eng = ServeEngine(params, cfg, policy=policy, **knobs)
        eng.restore(arrays, meta, downtime_s=downtime_s)
    else:
        eng = ServeEngine(params, cfg, policy=policy, **engine_kwargs)
    if journal is not None:
        eng.attach_journal(journal)
    return eng
