"""Write-ahead request journal for crash-safe serving.

An append-only JSONL log, fsync'd per record, written BEFORE a request is
admitted: a request the engine accepted is on disk before any work runs
on it, so a crash between accept and retire can never lose it.  Records:

  ``{"op": "submit", "uid", "prompt": [ids...], "max_new",
     "deadline_s", "deadline_steps", "t_wall", "step_sub"}``
  ``{"op": "retire", "uid", "status"}``

Recovery contract (see ``docs/DESIGN_robustness.md``): on restart, every
``submit`` record whose uid is not already accounted for by the restored
engine snapshot (terminal result, running row, or queued) is re-admitted
in original order.  Greedy decoding is deterministic, so a replayed
request produces the SAME tokens as the lost run — re-execution is
harmless, and a ``retire`` record whose result died with the process
(crash after retire, before the next snapshot) still ends in a terminal
state.  A torn final record (crash mid-append) is skipped with a warning;
everything before it is intact because each append is fsync'd.

Compaction: the log is truncated when every journaled request has retired
and none is outstanding (clean retirement), and rewritten down to the
still-unaccounted tail after a snapshot durably covers the results —
the journal only ever needs to span "since the last durable point".
"""

from __future__ import annotations

import json
import os
import time
import warnings
from typing import Any, Dict, Iterable, List, Set

import numpy as np


def _obs_count(event: str, n: int = 1) -> None:
    """WAL activity telemetry (process-global obs registry); lazy and
    failure-proof — journaling must work in obs-free contexts."""
    try:
        from repro import obs
        obs.record_journal_event(event, n)
    except Exception:
        pass


class JournalWarning(UserWarning):
    """A journal record could not be parsed (torn write) and was skipped."""


class RequestJournal:
    """fsync'd JSONL write-ahead log of submitted requests."""

    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        # original submission order survives restart: dict preserves
        # insertion order and records are appended in submit order
        self._submits: Dict[int, Dict[str, Any]] = {}
        self._retired: Set[int] = set()
        self._recover()
        self._f = open(path, "a", encoding="utf-8")

    def _recover(self) -> None:
        if not os.path.exists(self.path):
            return
        with open(self.path, "r", encoding="utf-8") as f:
            raw = f.read()
        for lineno, line in enumerate(raw.splitlines(), 1):
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
                op = rec["op"]
                uid = rec["uid"]
            except (json.JSONDecodeError, KeyError, TypeError):
                # torn tail from a crash mid-append; every earlier record
                # was fsync'd whole, so only the last line can be torn
                warnings.warn(
                    f"request journal {self.path}: skipping undecodable "
                    f"record at line {lineno} (torn write)",
                    JournalWarning, stacklevel=3)
                continue
            if op == "submit":
                self._submits[uid] = rec
            elif op == "retire":
                self._retired.add(uid)

    # -- write path --------------------------------------------------------

    def _write(self, rec: Dict[str, Any]) -> None:
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        os.fsync(self._f.fileno())

    def append(self, req, *, step_sub: int = 0) -> None:
        """Durably record a submission (called BEFORE admission)."""
        rec = {"op": "submit", "uid": int(req.uid),
               "prompt": [int(t) for t in np.asarray(req.prompt)],
               "max_new": int(req.max_new),
               "deadline_s": req.deadline_s,
               "deadline_steps": req.deadline_steps,
               "t_wall": time.time(), "step_sub": int(step_sub)}
        self._write(rec)
        self._submits[rec["uid"]] = rec
        _obs_count("append")

    def retire(self, uid: int, status: str) -> None:
        """Record a terminal status; truncates the log once every
        journaled request has retired (clean retirement)."""
        uid = int(uid)
        if uid not in self._submits:
            return
        self._write({"op": "retire", "uid": uid, "status": status})
        self._retired.add(uid)
        _obs_count("retire")
        if self._retired >= set(self._submits):
            self.truncate()

    def truncate(self) -> None:
        """Drop every record (all work is durably accounted for)."""
        self._f.close()
        self._f = open(self.path, "w", encoding="utf-8")
        self._f.flush()
        os.fsync(self._f.fileno())
        self._submits.clear()
        self._retired.clear()
        _obs_count("truncate")

    def compact(self, covered_uids: Iterable[int]) -> None:
        """Rewrite the log keeping only records for uids NOT in
        ``covered_uids`` (uids a durable snapshot now accounts for)."""
        covered = {int(u) for u in covered_uids}
        keep = [rec for uid, rec in self._submits.items()
                if uid not in covered]
        keep_retired = {uid for uid in self._retired if uid not in covered}
        self._f.close()
        tmp = self.path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            for rec in keep:
                f.write(json.dumps(rec) + "\n")
            for uid in keep_retired:
                f.write(json.dumps({"op": "retire", "uid": uid,
                                    "status": "?"}) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        _obs_count("compact")
        self._submits = {rec["uid"]: rec for rec in keep}
        self._retired = keep_retired
        self._f = open(self.path, "a", encoding="utf-8")

    # -- read path ---------------------------------------------------------

    def pending(self) -> List[Dict[str, Any]]:
        """Every journaled submit record, in original submission order.
        The engine decides what to replay (anything not accounted for by
        its restored state — including retired records whose results were
        never snapshotted)."""
        return list(self._submits.values())

    def retired_uids(self) -> Set[int]:
        return set(self._retired)

    def close(self) -> None:
        self._f.close()
