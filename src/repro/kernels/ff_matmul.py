"""Pallas TPU kernels for FF matrix multiplication.

Three kernels, mirroring ``repro.core.ffmatmul`` (DESIGN_ozaki.md):

* ``ff_matmul``  (production): hybrid MXU/VPU.  Grid (M/bm, N/bn, K/bk) with
  K innermost; each step issues one MXU block-matmul (f32, HIGHEST) and folds
  it into an FF accumulator held in VMEM scratch with Add22 (VPU).  This is
  the paper's compensated-accumulation idea applied at MXU-block granularity:
  >99% of flops stay on the MXU, accumulation error drops from O(K)u to
  O(bk)u + O(K/bk)*2^-44.

* ``ff_matmul_ozaki`` (accurate tier): fused Ozaki-slice matmul.  Operands
  are pre-split (jnp, ``core.ffmatmul.extract_slices``) into ``n``
  exponent-aligned slices whose pairwise block products are EXACT f32
  matmuls (2*beta + log2(bk) <= 26).  The kernel runs grid
  (M/bm, N/bn, K/bk, P) with the slice-pair index P innermost: each step is
  one MXU block-matmul of slice pair (si[p], sj[p]) folded into an FF
  accumulator in VMEM scratch.  The pair tables arrive via scalar prefetch,
  already sorted largest-order-first and FILTERED — pairs below FF precision
  (beta*(i+j) > 50) are never scheduled (negligible-pair skipping).  A
  K-doubled f32 residual GEMM (wrapper, jnp) corrects everything below the
  sliced significand.  Paper-quality ~2^-46 at MXU speed.

* ``ff_matmul_dot2`` (paper-faithful): every elementwise product is made
  exact with Mul12 (Dekker split on the VPU) and accumulated with a TwoSum
  cascade — the full float-float quality of the paper, at VPU cost.
  Block-vectorized: K advances ``vec`` lanes at a time with a batched
  two_prod and a pairwise-compensated tree reduction, so the sequential
  depth per (bm, bn) block is bk/vec instead of bk.

VMEM budget at hybrid defaults (bm=bn=256, bk=512):
  A tile 256*512*4 = 512 KiB, B tile 512*256*4 = 512 KiB,
  acc scratch 2 * 256*256*4 = 512 KiB, out 2 * 256 KiB  ->  ~1.8 MiB << 16 MiB.
Ozaki defaults (bm=bn=128, bk=512, n=3): A/B tiles 256 KiB each (one slice
pair at a time), acc + out 256 KiB -> ~0.8 MiB.  Dot2 (bm=bn=128, bk=128,
vec=8): the (bm, vec, bn) two_prod intermediates are 512 KiB each, ~2.5 MiB
total.  MXU alignment: all block dims are multiples of 128.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import eft

Array = jnp.ndarray


def _block_dot(a, b):
    # f32 MXU matmul; HIGHEST = 6-pass bf16 (f32-faithful) on TPU.
    return lax.dot(a, b, precision=lax.Precision.HIGHEST,
                   preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Hybrid kernel
# ---------------------------------------------------------------------------

def _ff_matmul_kernel(a_ref, b_ref, oh_ref, ol_ref, acc_hi, acc_lo, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_hi[...] = jnp.zeros_like(acc_hi)
        acc_lo[...] = jnp.zeros_like(acc_lo)

    p = _block_dot(a_ref[...], b_ref[...])
    # add22(acc, (p, 0)) — fold the block product into the FF accumulator
    sh, sl = eft.two_sum(acc_hi[...], p)
    v = sl + acc_lo[...]
    rh, rl = eft.fast_two_sum(sh, v)
    acc_hi[...] = rh
    acc_lo[...] = rl

    @pl.when(k == nk - 1)
    def _flush():
        oh_ref[...] = acc_hi[...]
        ol_ref[...] = acc_lo[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def ff_matmul(a: Array, b: Array, *, bm: int = 256, bn: int = 256,
              bk: int = 512, interpret: bool = False) -> Tuple[Array, Array]:
    """FF(M,N) = a(M,K) @ b(K,N), hybrid MXU + compensated accumulation.

    Returns (hi, lo) limbs.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    Mp, Kp = a.shape
    _, Np = b.shape
    nk = Kp // bk
    grid = (Mp // bm, Np // bn, nk)
    out = jax.ShapeDtypeStruct((Mp, Np), jnp.float32)
    oh, ol = pl.pallas_call(
        functools.partial(_ff_matmul_kernel, nk=nk),
        out_shape=(out, out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=(
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        interpret=interpret,
    )(a, b)
    return oh[:M, :N], ol[:M, :N]


# ---------------------------------------------------------------------------
# Fused Ozaki-slice kernel
# ---------------------------------------------------------------------------

def _ff_matmul_ozaki_kernel(si_ref, sj_ref, a_ref, b_ref, oh_ref, ol_ref,
                            acc_hi, acc_lo, *, nk: int, npairs: int):
    k = pl.program_id(2)
    p = pl.program_id(3)

    @pl.when((k == 0) & (p == 0))
    def _init():
        acc_hi[...] = jnp.zeros_like(acc_hi)
        acc_lo[...] = jnp.zeros_like(acc_lo)

    # one EXACT slice-pair block product on the MXU
    prod = _block_dot(a_ref[0], b_ref[0])
    sh, sl = eft.two_sum(acc_hi[...], prod)
    v = sl + acc_lo[...]
    rh, rl = eft.fast_two_sum(sh, v)
    acc_hi[...] = rh
    acc_lo[...] = rl

    @pl.when((k == nk - 1) & (p == npairs - 1))
    def _flush():
        oh_ref[...] = acc_hi[...]
        ol_ref[...] = acc_lo[...]


@functools.partial(jax.jit,
                   static_argnames=("slices", "beta", "bm", "bn", "bk",
                                    "interpret"))
def ff_matmul_ozaki(a: Array, b: Array, *, slices: int = 0, beta: int = 0,
                    bm: int = 128, bn: int = 128, bk: int = 512,
                    interpret: bool = False) -> Tuple[Array, Array]:
    """Fused Ozaki-slice FF matmul: exact slice-pair MXU block products,
    FF-accumulated in VMEM, slice-pair as the innermost grid dimension.

    Slicing (jnp prologue) is exponent-aligned per (row, full K); the
    exactness budget therefore has to hold per K-*block*:
    2*beta + log2(bk) <= 26 (see ``core.ffmatmul.ozaki_params``).  Pairs
    with beta*(i+j) > 50 are dropped before scheduling — the scalar-prefetch
    pair tables are the skip list.  Returns (hi, lo) limbs.
    """
    from repro.core import ffmatmul as core_mm

    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    n, beta, bk, max_order = core_mm.ozaki_params(K, slices=slices, beta=beta,
                                                  block_k=bk)
    pairs = sorted(
        ((i, j) for i in range(n) for j in range(n) if i + j <= max_order),
        key=lambda q: (q[0] + q[1], q[0]))
    npairs = len(pairs)
    si = jnp.asarray([q[0] for q in pairs], jnp.int32)
    sj = jnp.asarray([q[1] for q in pairs], jnp.int32)

    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    Mp, Kp = a.shape
    _, Np = b.shape

    # slices aligned over the full (padded) K — block sums stay exact by the
    # bk budget above; the kernel accumulates across K-blocks in FF.
    pa, ra = core_mm.extract_slices(a, 1, n, beta)
    pb, rb = core_mm.extract_slices(b, 0, n, beta)
    As = jnp.stack(pa)                       # (n, Mp, Kp)
    Bs = jnp.stack(pb)                       # (n, Kp, Np)

    nk = Kp // bk
    grid = (Mp // bm, Np // bn, nk, npairs)
    out = jax.ShapeDtypeStruct((Mp, Np), jnp.float32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda i, j, k, p, si, sj: (si[p], i, k)),
            pl.BlockSpec((1, bk, bn), lambda i, j, k, p, si, sj: (sj[p], k, j)),
        ],
        out_specs=(
            pl.BlockSpec((bm, bn), lambda i, j, k, p, si, sj: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k, p, si, sj: (i, j)),
        ),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
    )
    oh, ol = pl.pallas_call(
        functools.partial(_ff_matmul_ozaki_kernel, nk=nk, npairs=npairs),
        grid_spec=grid_spec,
        out_shape=(out, out),
        interpret=interpret,
    )(si, sj, As, Bs)

    # residual correction: a@b - sum(pairs) == ra@b + (a-ra)@rb, one
    # K-doubled f32 GEMM (everything below the sliced significand).
    res = _block_dot(jnp.concatenate([ra, a - ra], axis=1),
                     jnp.concatenate([b, rb], axis=0))
    sh, sl = eft.two_sum(oh, res)
    rh, rl = eft.fast_two_sum(sh, sl + ol)
    return rh[:M, :N], rl[:M, :N]


# ---------------------------------------------------------------------------
# Paper-faithful Dot3 kernel (block-vectorized)
# ---------------------------------------------------------------------------

def _ff_matmul_dot2_kernel(a_ref, b_ref, oh_ref, ol_ref, s_acc, c_acc, cc_acc,
                           *, nk: int, bk: int, vec: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        s_acc[...] = jnp.zeros_like(s_acc)
        c_acc[...] = jnp.zeros_like(c_acc)
        cc_acc[...] = jnp.zeros_like(cc_acc)

    a = a_ref[...]          # (bm, bk)
    b = b_ref[...]          # (bk, bn)

    def body(j, carry):
        s, c, cc = carry
        aj = lax.dynamic_slice_in_dim(a, j * vec, vec, axis=1)   # (bm, vec)
        bj = lax.dynamic_slice_in_dim(b, j * vec, vec, axis=0)   # (vec, bn)
        # batched Mul12: all vec outer products of this slab, exactly
        p, pe = eft.two_prod(aj[:, :, None], bj[None, :, :])     # (bm,vec,bn)
        # pairwise-compensated tree reduction over the slab axis
        slab, err = eft.pairwise_sum_compensated(
            p, axis=1, err=jnp.sum(pe, axis=1))
        s2, se = eft.two_sum(s, slab)
        c2, ce = eft.two_sum(c, se + err)
        return s2, c2, cc + ce

    s, c, cc = lax.fori_loop(
        0, bk // vec, body, (s_acc[...], c_acc[...], cc_acc[...]))
    s_acc[...] = s
    c_acc[...] = c
    cc_acc[...] = cc

    @pl.when(k == nk - 1)
    def _flush():
        rh, rl = eft.fast_two_sum(s_acc[...], c_acc[...] + cc_acc[...])
        oh_ref[...] = rh
        ol_ref[...] = rl


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "vec",
                                             "interpret"))
def ff_matmul_dot2(a: Array, b: Array, *, bm: int = 128, bn: int = 128,
                   bk: int = 128, vec: int = 8,
                   interpret: bool = False) -> Tuple[Array, Array]:
    """Paper-faithful FF matmul: exact per-element products (Mul12) +
    TwoSum cascade (Dot3 quality).  VPU-only; block-vectorized so each
    (bm, bn) tile advances K in ``vec``-wide slabs (O(K/vec) sequential
    steps) instead of rank-1 updates."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    vec = max(1, min(vec, bk))
    while bk % vec:
        vec -= 1     # largest divisor <= vec keeps the slab win for ragged bk
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    Mp, Kp = a.shape
    _, Np = b.shape
    nk = Kp // bk
    grid = (Mp // bm, Np // bn, nk)
    out = jax.ShapeDtypeStruct((Mp, Np), jnp.float32)
    oh, ol = pl.pallas_call(
        functools.partial(_ff_matmul_dot2_kernel, nk=nk, bk=bk, vec=vec),
        out_shape=(out, out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=(
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        interpret=interpret,
    )(a, b)
    return oh[:M, :N], ol[:M, :N]
