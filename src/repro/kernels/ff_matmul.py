"""Pallas TPU kernels for FF matrix multiplication.

Two kernels, mirroring ``repro.core.ffmatmul`` (DESIGN.md §2):

* ``ff_matmul``  (production): hybrid MXU/VPU.  Grid (M/bm, N/bn, K/bk) with
  K innermost; each step issues one MXU block-matmul (f32, HIGHEST) and folds
  it into an FF accumulator held in VMEM scratch with Add22 (VPU).  This is
  the paper's compensated-accumulation idea applied at MXU-block granularity:
  >99% of flops stay on the MXU, accumulation error drops from O(K)u to
  O(bk)u + O(K/bk)*2^-44.

* ``ff_matmul_dot2`` (paper-faithful): every elementwise product is made
  exact with Mul12 (Dekker split on the VPU) and accumulated with a TwoSum
  cascade — the full float-float quality of the paper, at VPU cost.  Used for
  small numerically critical matmuls and as the correctness anchor.

VMEM budget at defaults (bm=bn=256, bk=512):
  A tile 256*512*4 = 512 KiB, B tile 512*256*4 = 512 KiB,
  acc scratch 2 * 256*256*4 = 512 KiB, out 2 * 256 KiB  ->  ~1.8 MiB << 16 MiB.
MXU alignment: all block dims are multiples of 128.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import eft

Array = jnp.ndarray


def _block_dot(a, b):
    # f32 MXU matmul; HIGHEST = 6-pass bf16 (f32-faithful) on TPU.
    return lax.dot(a, b, precision=lax.Precision.HIGHEST,
                   preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Hybrid kernel
# ---------------------------------------------------------------------------

def _ff_matmul_kernel(a_ref, b_ref, oh_ref, ol_ref, acc_hi, acc_lo, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_hi[...] = jnp.zeros_like(acc_hi)
        acc_lo[...] = jnp.zeros_like(acc_lo)

    p = _block_dot(a_ref[...], b_ref[...])
    # add22(acc, (p, 0)) — fold the block product into the FF accumulator
    sh, sl = eft.two_sum(acc_hi[...], p)
    v = sl + acc_lo[...]
    rh, rl = eft.fast_two_sum(sh, v)
    acc_hi[...] = rh
    acc_lo[...] = rl

    @pl.when(k == nk - 1)
    def _flush():
        oh_ref[...] = acc_hi[...]
        ol_ref[...] = acc_lo[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def ff_matmul(a: Array, b: Array, *, bm: int = 256, bn: int = 256,
              bk: int = 512, interpret: bool = False) -> Tuple[Array, Array]:
    """FF(M,N) = a(M,K) @ b(K,N), hybrid MXU + compensated accumulation.

    Returns (hi, lo) limbs.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    Mp, Kp = a.shape
    _, Np = b.shape
    nk = Kp // bk
    grid = (Mp // bm, Np // bn, nk)
    out = jax.ShapeDtypeStruct((Mp, Np), jnp.float32)
    oh, ol = pl.pallas_call(
        functools.partial(_ff_matmul_kernel, nk=nk),
        out_shape=(out, out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=(
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        interpret=interpret,
    )(a, b)
    return oh[:M, :N], ol[:M, :N]


# ---------------------------------------------------------------------------
# Paper-faithful Dot3 kernel
# ---------------------------------------------------------------------------

def _ff_matmul_dot2_kernel(a_ref, b_ref, oh_ref, ol_ref, s_acc, c_acc, cc_acc,
                           *, nk: int, bk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        s_acc[...] = jnp.zeros_like(s_acc)
        c_acc[...] = jnp.zeros_like(c_acc)
        cc_acc[...] = jnp.zeros_like(cc_acc)

    a = a_ref[...]          # (bm, bk)
    b = b_ref[...]          # (bk, bn)

    def body(j, carry):
        s, c, cc = carry
        aj = lax.dynamic_slice_in_dim(a, j, 1, axis=1)        # (bm, 1)
        bj = lax.dynamic_slice_in_dim(b, j, 1, axis=0)        # (1, bn)
        p, pe = eft.two_prod(aj, bj)                           # exact product
        s2, se = eft.two_sum(s, p)
        c2, ce = eft.two_sum(c, se + pe)
        return s2, c2, cc + ce

    s, c, cc = lax.fori_loop(
        0, bk, body, (s_acc[...], c_acc[...], cc_acc[...]))
    s_acc[...] = s
    c_acc[...] = c
    cc_acc[...] = cc

    @pl.when(k == nk - 1)
    def _flush():
        rh, rl = eft.fast_two_sum(s_acc[...], c_acc[...] + cc_acc[...])
        oh_ref[...] = rh
        ol_ref[...] = rl


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def ff_matmul_dot2(a: Array, b: Array, *, bm: int = 128, bn: int = 128,
                   bk: int = 128, interpret: bool = False) -> Tuple[Array, Array]:
    """Paper-faithful FF matmul: exact per-element products (Mul12) +
    TwoSum cascade (Dot3 quality).  VPU-only; O(K) vector steps."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    Mp, Kp = a.shape
    _, Np = b.shape
    nk = Kp // bk
    grid = (Mp // bm, Np // bn, nk)
    out = jax.ShapeDtypeStruct((Mp, Np), jnp.float32)
    oh, ol = pl.pallas_call(
        functools.partial(_ff_matmul_dot2_kernel, nk=nk, bk=bk),
        out_shape=(out, out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=(
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        interpret=interpret,
    )(a, b)
    return oh[:M, :N], ol[:M, :N]
