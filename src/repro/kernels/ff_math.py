"""Pallas TPU kernels for the FF elementary functions (``ff.math``).

Each kernel streams (8,128)-aligned VMEM tiles through the VPU and runs
the SAME generic argument-reduction + compensated-polynomial algorithm as
the jnp implementations (``repro.core.ffmath``), instantiated with the
barrier-free ``repro.kernels.eft`` primitives — so the compiled kernel,
the interpret-mode kernel and the jnp reference are the identical
arithmetic (bitwise under the EFT-safe ISA contract, like the fused
elementwise chains).

Transcendental bodies are much deeper than the arithmetic kernels
(Horner chains, the erf series loops carry four live FF accumulators per
tile), so the default block is smaller than ``ff_elementwise``'s — the
grid grows, HBM traffic does not.  Broadcasting, padding and tiling all
reuse the ``ff_elementwise`` helpers.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import ffmath
from repro.kernels import eft
from repro.kernels.ff_elementwise import (
    _pad_to, _round_up, _spec_for, broadcast_planes, pick_block,
)

Array = jnp.ndarray

# deeper bodies -> smaller tiles: 128*512*4B = 256 KiB/plane, 6 io planes
# + the deepest live set (erf's series carries) stays well under ~4 MiB
DEFAULT_BLOCK = (128, 512)


def _unary_kernel(op):
    fn = ffmath.UNARY22[op]

    def kernel(ah_ref, al_ref, rh_ref, rl_ref):
        rh, rl = fn(ah_ref[...], al_ref[...], eft)
        rh_ref[...] = rh
        rl_ref[...] = rl

    return kernel


def _pow_kernel(ah_ref, al_ref, bh_ref, bl_ref, rh_ref, rl_ref):
    rh, rl = ffmath.pow22(ah_ref[...], al_ref[...],
                          bh_ref[...], bl_ref[...], eft)
    rh_ref[...] = rh
    rl_ref[...] = rl


_KERNELS = {op: (_unary_kernel(op), 2) for op in ffmath.UNARY22}
_KERNELS["pow"] = (_pow_kernel, 4)


@functools.partial(jax.jit, static_argnames=("op", "block", "interpret"))
def math_elementwise(op: str, *arrays: Array,
                     block: Tuple[int, int] = DEFAULT_BLOCK,
                     interpret: bool = False) -> Tuple[Array, Array]:
    """Run an FF math kernel over broadcastable hi/lo limb planes.

    Same contract as ``ff_elementwise.elementwise``: operands flatten to
    2-D against the broadcast shape, scalar/row/column operands stay
    un-materialized via their BlockSpec, outputs un-pad back.  ``op`` is
    one of ``ffmath.UNARY22`` (two planes in) or ``"pow"`` (four).
    """
    kernel, n_in = _KERNELS[op]
    assert len(arrays) == n_in, (op, len(arrays))
    arrays = tuple(jnp.asarray(a, jnp.float32) for a in arrays)
    planes, orig_shape = broadcast_planes(arrays)
    R = max(p.shape[0] for p in planes)
    C = max(p.shape[1] for p in planes)
    br, bc = pick_block(R, C, block)
    padded = [_pad_to(p, br if (p.shape[0] == R or R == 1) else 1,
                      bc if (p.shape[1] == C or C == 1) else 1)
              for p in planes]
    Rp, Cp = _round_up(R, br), _round_up(C, bc)
    grid = (Rp // br, Cp // bc)
    in_specs = [_spec_for(p.shape, (Rp, Cp), br, bc) for p in padded]
    out_spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    out_shape = jax.ShapeDtypeStruct((Rp, Cp), jnp.float32)
    rh, rl = pl.pallas_call(
        kernel,
        out_shape=(out_shape, out_shape),
        grid=grid,
        in_specs=in_specs,
        out_specs=(out_spec, out_spec),
        interpret=interpret,
    )(*padded)
    rh = rh[:R, :C].reshape(orig_shape)
    rl = rl[:R, :C].reshape(orig_shape)
    return rh, rl
