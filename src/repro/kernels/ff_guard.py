"""Pallas kernel for the FF health probe (``ff.guard_probe``).

One pass over the (hi, lo) limb planes producing a small-integer flag
plane (f32 values 0..7): bit 0 = non-finite limb, bit 1 = normalization
violation (``|lo| > 2^-24 |hi|`` — the multiplicative surrogate for the
paper's ``|lo| <= ulp(hi)/2`` invariant, exact for power-of-two ``hi``
and within one binade everywhere), bit 2 = subnormal ``lo`` (a
flush-to-zero hazard on non-IEEE hardware, not an invariant violation —
see ``docs/DESIGN_robustness.md``).  The caller reduces the flag plane
to per-category counts; padding tiles contribute healthy (0, 0) pairs
and therefore flag 0.

Reuses the elementwise tiling machinery (flatten to 2-D, (8, 128)-aligned
blocks) from :mod:`repro.kernels.ff_elementwise`.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ff_elementwise import (DEFAULT_BLOCK, _pad_to, _round_up,
                                          _to_2d, pick_block)

Array = jnp.ndarray

#: |lo| <= HALF_ULP_SURROGATE * |hi| accepts every normalized pair and
#: flags anything at least 2x out of normalization (see module doc)
HALF_ULP_SURROGATE = 2.0 ** -24
#: smallest normal f32 — anything smaller (and nonzero) is subnormal
MIN_NORMAL_F32 = 2.0 ** -126


def flag_planes(hi: Array, lo: Array) -> Tuple[Array, Array, Array]:
    """The three boolean violation planes for an FF limb pair — shared by
    the jnp probe and the kernel body (the kernel packs them into bits).

    Returns ``(nonfinite, unnormalized, denormal_lo)``.  NaN/Inf limbs
    count only as ``nonfinite`` (NaN comparisons would otherwise leak
    into the other categories)."""
    hi = jnp.asarray(hi, jnp.float32)
    lo = jnp.asarray(lo, jnp.float32)
    finite = jnp.isfinite(hi) & jnp.isfinite(lo)
    bound = jnp.abs(hi) * jnp.float32(HALF_ULP_SURROGATE)
    unnorm = finite & (jnp.abs(lo) > bound)
    # subnormal lo via exponent/mantissa bits: a float compare (lo != 0)
    # is itself DAZ-flushed on some backends — the very hazard this flag
    # reports — while the bit pattern is preserved everywhere
    bits = jax.lax.bitcast_convert_type(lo, jnp.uint32)
    denorm = finite & ((bits >> 23) & 0xFF == 0) & (bits & 0x7FFFFF != 0)
    return ~finite, unnorm, denorm


def _guard_kernel(hi_ref, lo_ref, f_ref):
    nf, un, dn = flag_planes(hi_ref[...], lo_ref[...])
    f_ref[...] = (nf.astype(jnp.float32)
                  + 2.0 * un.astype(jnp.float32)
                  + 4.0 * dn.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def guard_flags(hi: Array, lo: Array,
                block: Tuple[int, int] = DEFAULT_BLOCK,
                interpret: bool = False) -> Array:
    """Flag plane (same shape as ``hi``, f32 bit codes 0..7) for an FF
    limb pair, computed by one tiled Pallas pass."""
    hi2 = _to_2d(jnp.asarray(hi, jnp.float32))
    lo2 = _to_2d(jnp.asarray(lo, jnp.float32))
    R, C = hi2.shape
    br, bc = pick_block(R, C, block)
    hi2, lo2 = _pad_to(hi2, br, bc), _pad_to(lo2, br, bc)
    Rp, Cp = _round_up(R, br), _round_up(C, bc)
    grid = (Rp // br, Cp // bc)
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    flags = pl.pallas_call(
        _guard_kernel,
        out_shape=jax.ShapeDtypeStruct((Rp, Cp), jnp.float32),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        interpret=interpret,
    )(hi2, lo2)
    return flags[:R, :C].reshape(jnp.shape(hi))
