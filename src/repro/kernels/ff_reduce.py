"""Pallas TPU kernel for compensated (FF) row reduction.

Reduces the last axis of a 2-D array into an FF pair per row using the
paper's TwoSum cascade (Sum3 quality), processing column-blocks streamed
through VMEM.  Used by the training substrate for loss/grad-norm/LN-stat
reductions when the precision policy requests ``ff_reductions``.

Grid: (rows/br, cols/bc) with the column dimension innermost; the running
(s, c, cc) cascade lives in VMEM scratch and persists across column steps.
Inside a block the reduction is a fori_loop over lanes-groups so the order
is deterministic (bit-reproducible across shardings of other dims).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import eft

Array = jnp.ndarray


def _ff_rowsum_kernel(x_ref, oh_ref, ol_ref, s_acc, c_acc, cc_acc,
                      *, nc: int, bc: int, lane: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_acc[...] = jnp.zeros_like(s_acc)
        c_acc[...] = jnp.zeros_like(c_acc)
        cc_acc[...] = jnp.zeros_like(cc_acc)

    x = x_ref[...]                       # (br, bc)

    def body(t, carry):
        s, c, cc = carry                 # (br, lane) each
        xt = lax.dynamic_slice_in_dim(x, t * lane, lane, axis=1)
        s2, e = eft.two_sum(s, xt)
        c2, e2 = eft.two_sum(c, e)
        return s2, c2, cc + e2

    s, c, cc = lax.fori_loop(0, bc // lane, body,
                             (s_acc[...], c_acc[...], cc_acc[...]))
    s_acc[...] = s
    c_acc[...] = c
    cc_acc[...] = cc

    @pl.when(j == nc - 1)
    def _flush():
        # fold the `lane` per-lane accumulators exactly, sequentially
        def fold(i, carry):
            fh, fl = carry
            sh, sl = eft.two_sum(
                fh, lax.dynamic_slice_in_dim(s_acc[...], i, 1, axis=1)[:, 0])
            v = sl + (fl
                      + lax.dynamic_slice_in_dim(c_acc[...], i, 1, axis=1)[:, 0]
                      + lax.dynamic_slice_in_dim(cc_acc[...], i, 1, axis=1)[:, 0])
            return eft.fast_two_sum(sh, v)

        br = s_acc.shape[0]
        z = jnp.zeros((br,), jnp.float32)
        fh, fl = lax.fori_loop(0, s_acc.shape[1], fold, (z, z))
        oh_ref[...] = fh[:, None]
        ol_ref[...] = fl[:, None]


@functools.partial(jax.jit, static_argnames=("br", "bc", "lane", "interpret"))
def ff_rowsum(x: Array, *, br: int = 256, bc: int = 512, lane: int = 128,
              interpret: bool = False) -> Tuple[Array, Array]:
    """Compensated row-sum: x(R, C) -> FF(R,).  Returns (hi, lo)."""
    x = jnp.asarray(x, jnp.float32)
    R, C = x.shape
    br = min(br, R)
    bc = min(bc, C)
    lane = min(lane, bc)
    bc -= bc % lane if bc % lane else 0
    bc = max(bc, lane)
    pr, pc = (-R) % br, (-C) % bc
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    Rp, Cp = x.shape
    nc = Cp // bc
    grid = (Rp // br, nc)
    out = jax.ShapeDtypeStruct((Rp, 1), jnp.float32)
    oh, ol = pl.pallas_call(
        functools.partial(_ff_rowsum_kernel, nc=nc, bc=bc, lane=lane),
        out_shape=(out, out),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=(
            pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
        ),
        scratch_shapes=[
            pltpu.VMEM((br, lane), jnp.float32),
            pltpu.VMEM((br, lane), jnp.float32),
            pltpu.VMEM((br, lane), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return oh[:R, 0], ol[:R, 0]
