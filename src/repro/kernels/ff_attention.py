"""Fused FF flash attention: blockwise attention with *compensated online
softmax* (the ``ff.attention`` op's implementation tiers).

Attention's online softmax over thousands of keys is exactly the long
f32 reduction the paper emulates 44-bit arithmetic for: every term of the
numerator/denominator is an ``exp`` whose ~2^-24 builtin error — plus the
~sqrt(K)*2^-24 accumulation drift — lands directly in the output weights.
The accurate class here runs the whole online recurrence in FF:

  * scores are FF (2^-44 class): ``q.k^T`` as TwoProd-exact products
    through a compensated Neumaier sum over the head dim, scaled with
    ``Mul212`` — an f32 dot product's ~2^-21 score error would be
    amplified straight into relative weight error by ``exp``;
  * the running-max shift ``s - m`` is an ``Add212`` on the FF scores
    (the shift itself needs no precision — any shared shift is
    mathematically exact in the softmax quotient; only the *applied*
    subtraction must keep the FF bits, and Add212 does);
  * exponentials are FF (``ffmath.exp22`` on the FF argument), so each
    term is 2^-44-class;
  * the rescale factor ``alpha = exp(m_old - m_new)`` is FF on an exact
    TwoSum argument;
  * numerator and denominator are FF accumulators: per kv-block sums run
    a lane-parallel Neumaier cascade (numerator terms are
    TwoProd-exact ``p_hi * v`` products with the ``p_lo * v`` residual
    folded into the compensation stream), and cross-block combining is
    ``Mul22``/``Add22`` — the TwoSum-carried recurrence of the tentpole;
  * the final normalize is ``Div22``.

Tiers (registered in ``repro.ff.dispatch`` as the ``attention`` op):

  fast   — the f32 online softmax previously inlined in
           ``repro.models.layers.flash_attention``, moved here verbatim so
           the registry default is trivially bitwise with the pre-registry
           model hot path.
  ff     — the compensated recurrence above in pure jnp (barrier-carrying
           core EFTs); the portable accurate class.
  pallas — the same algorithm as ONE Pallas kernel per (head, q-block)
           stripe: grid (B*H, n_q, n_kv) with the FF accumulators living
           in VMEM scratch across the innermost kv dimension (compiled on
           TPU, interpret-mode elsewhere).
  f64    — materialized-score native-f64 softmax attention (CPU accurate
           tier at hardware speed, and the test oracle).

This module is self-contained (no ``repro.models`` imports): the model
layers call it THROUGH the registry (``ff.attention``).
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import compensated, ffmath
from repro.core import ff as core_ff
from repro.core import transforms as T
from repro.core.ff import FF
from repro.kernels import eft
from repro.kernels.ff_elementwise import LANE, SUBLANE, _round_up
from repro.kernels.ff_fused import _fold_lanes, _lane_cascade

Array = jnp.ndarray

NEG_INF = -1e30


def _dims(q: Array, k: Array) -> Tuple[int, int, int, int, int, int]:
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    if H % KV:
        raise ValueError(f"num_heads {H} not a multiple of kv heads {KV}")
    return B, Sq, H, hd, Skv, KV


def _resolve_scale(scale: Optional[float], hd: int) -> float:
    return (1.0 / math.sqrt(hd)) if scale is None else float(scale)


# ===========================================================================
# fast tier: the f32 online softmax (ex-``models.layers.flash_attention``)
# ===========================================================================

def flash_attention_fast(q: Array, k: Array, v: Array, *, causal: bool = True,
                         block_q: int = 128, block_kv: int = 128,
                         q_offset=0, kv_len: Optional[Array] = None,
                         scale: Optional[float] = None,
                         return_ff: bool = False):
    """Online-softmax blockwise attention, f32 accumulators (fast class).

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd); H = KV * G (GQA).
    Never materializes (Sq, Skv); peak extra memory is
    (B, KV, G, block_q, block_kv).  q_offset: absolute position of q[0]
    (for cached decode/prefill continuation).  ``kv_len``: optional (B,)
    per-row valid-key counts (ragged batches — the serving engine's mixed
    cache lengths); None keeps the static-Skv mask and is bitwise the
    pre-registry model path.  ``scale``: score scale, default
    ``1/sqrt(hd)``.
    """
    B, Sq, H, hd, Skv, KV = _dims(q, k)
    G = H // KV
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    pq, pkv = (-Sq) % bq, (-Skv) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    nq, nkv = q.shape[1] // bq, k.shape[1] // bkv
    sc = _resolve_scale(scale, hd)

    # (nq, B, KV, G, bq, hd)
    qb = q.reshape(B, nq, bq, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(B, nkv, bkv, KV, hd).transpose(1, 0, 3, 2, 4)  # (nkv,B,KV,bkv,hd)
    vb = v.reshape(B, nkv, bkv, KV, hd).transpose(1, 0, 3, 2, 4)

    q_pos_base = jnp.asarray(q_offset, jnp.int32)

    def one_q_block(iq, qi):
        # qi: (B, KV, G, bq, hd)
        qi32 = qi.astype(jnp.float32) * sc
        q_pos = q_pos_base + iq * bq + jnp.arange(bq, dtype=jnp.int32)

        def kv_step(carry, jk):
            m, l, acc = carry
            kj = kb[jk].astype(jnp.float32)   # (B,KV,bkv,hd)
            vj = vb[jk].astype(jnp.float32)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qi32, kj)   # (B,KV,G,bq,bkv)
            kv_pos = jk * bkv + jnp.arange(bkv, dtype=jnp.int32)
            mask = kv_pos[None, :] <= q_pos[:, None] if causal else \
                jnp.ones((bq, bkv), bool)
            # mask out kv padding
            mask = jnp.logical_and(mask, (kv_pos < Skv)[None, :])
            if kv_len is None:
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            else:
                rag = kv_pos[None, :] < kv_len[:, None]          # (B, bkv)
                full = jnp.logical_and(mask[None, None, None],
                                       rag[:, None, None, None])
                s = jnp.where(full, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", p, vj)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, bq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, bq, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0),
                                  jnp.arange(nkv, dtype=jnp.int32))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (B,KV,G,bq,hd)

    outs = lax.map(lambda args: one_q_block(*args),
                   (jnp.arange(nq, dtype=jnp.int32), qb))
    # (nq,B,KV,G,bq,hd) -> (B, Sq, H, hd)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * bq, H, hd)
    out = out[:, :Sq]
    if return_ff:
        return FF(out.astype(jnp.float32), jnp.zeros_like(out, jnp.float32))
    return out.astype(q.dtype)


# ===========================================================================
# ff tier: the compensated online recurrence in jnp (accurate class)
# ===========================================================================

def _ff_safe_den(den: FF) -> FF:
    """Guard a fully-masked row's zero denominator (mirrors the fast
    tier's ``max(l, 1e-30)``) without perturbing real denominators."""
    tiny = jnp.float32(1e-30)
    ok = den.hi > tiny
    return FF(jnp.where(ok, den.hi, tiny),
              jnp.where(ok, den.lo, jnp.float32(0.0)))


def flash_attention_ff(q: Array, k: Array, v: Array, *, causal: bool = True,
                       block_q: int = 32, block_kv: int = 128,
                       q_offset=0, kv_len: Optional[Array] = None,
                       scale: Optional[float] = None,
                       block: int = 128, return_ff: bool = False):
    """Compensated online-softmax attention (accurate class, pure jnp).

    Same blocked structure as the fast tier; scores AND the recurrence
    are FF (see module docstring).  Per kv-block sums go through the
    compensated blocked cascade (``ff_sum_blocked``); numerator terms are
    TwoProd-exact ``p_hi * v`` with the ``p_lo * v`` residual summed
    alongside, so the block sum is accurate to the FF class before the
    ``Mul22``/``Add22`` cross-block combine.  Contract: <= 2^-40 relative
    vs the f64 oracle on long-K rows (doctested in docs/NUMERICS.md).
    ``return_ff=True`` keeps both limbs (FF out) — the f32 hi limb alone
    rounds away the very bits the contract is about.
    """
    B, Sq, H, hd, Skv, KV = _dims(q, k)
    G = H // KV
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    pq, pkv = (-Sq) % bq, (-Skv) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    nq, nkv = q.shape[1] // bq, k.shape[1] // bkv
    sc = _resolve_scale(scale, hd)

    qb = q.reshape(B, nq, bq, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(B, nkv, bkv, KV, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nkv, bkv, KV, hd).transpose(1, 0, 3, 2, 4)

    q_pos_base = jnp.asarray(q_offset, jnp.int32)
    E = ffmath.CORE

    def one_q_block(iq, qi):
        qi32 = qi.astype(jnp.float32)
        q_pos = q_pos_base + iq * bq + jnp.arange(bq, dtype=jnp.int32)
        shp = (B, KV, G, bq)

        def kv_step(carry, jk):
            m, dh, dl, nh, nl = carry
            den, num = FF(dh, dl), FF(nh, nl)
            kj = kb[jk].astype(jnp.float32)
            vj = vb[jk].astype(jnp.float32)
            # FF scores: TwoProd-exact q*k products, compensated sum over
            # the head dim, Mul212 scale — 2^-44-class logits (an f32
            # dot's ~2^-21 score error would pass straight through exp as
            # relative weight error)
            pshape = (B, KV, G, bq, bkv, hd)
            tph, tpl = T.two_prod(
                jnp.broadcast_to(qi32[..., :, None, :], pshape),
                jnp.broadcast_to(kj[:, :, None, None], pshape))
            s_ff = core_ff.add22_accurate(
                compensated.ff_sum_blocked(tph, axis=-1, block=block),
                compensated.ff_sum_blocked(tpl, axis=-1, block=block))
            s_ff = core_ff.mul212(s_ff, jnp.float32(sc))  # (B,KV,G,bq,bkv)
            kv_pos = jk * bkv + jnp.arange(bkv, dtype=jnp.int32)
            mask = kv_pos[None, :] <= q_pos[:, None] if causal else \
                jnp.ones((bq, bkv), bool)
            mask = jnp.logical_and(mask, (kv_pos < Skv)[None, :])
            full = jnp.broadcast_to(mask[None, None, None], s_ff.hi.shape)
            if kv_len is not None:
                rag = kv_pos[None, :] < kv_len[:, None]
                full = jnp.logical_and(full, rag[:, None, None, None])
            shi = jnp.where(full, s_ff.hi, NEG_INF)
            slo = jnp.where(full, s_ff.lo, jnp.float32(0.0))
            m_new = jnp.maximum(m, shi.max(axis=-1))
            # FF exponentials on the Add212-shifted FF argument
            d_ff = core_ff.add212(FF(shi, slo), -m_new[..., None])
            ph, plo = ffmath.exp22(d_ff.hi, d_ff.lo, E)
            zero = jnp.float32(0.0)
            ph = jnp.where(full, ph, zero)
            plo = jnp.where(full, plo, zero)
            # FF rescale factor alpha = exp(m - m_new), argument exact
            ah, al = T.two_sum(m, -m_new)
            alpha = FF(*ffmath.exp22(ah, al, E))
            # denominator: alpha*den + blocksum(p)  (both limb planes summed)
            bs = core_ff.add22_accurate(
                compensated.ff_sum_blocked(ph, axis=-1, block=block),
                compensated.ff_sum_blocked(plo, axis=-1, block=block))
            den = core_ff.add22(core_ff.mul22(den, alpha), bs)
            # numerator: alpha*num + blocksum(p * v) with TwoProd-exact
            # hi-plane products; the lo-plane products (< 2^-24 relative)
            # ride the residual sum
            vfull = jnp.broadcast_to(vj[:, :, None, None], ph.shape + (hd,))
            th, tl = T.two_prod(jnp.broadcast_to(ph[..., None], vfull.shape),
                                vfull)
            tl = tl + plo[..., None] * vfull
            nb = core_ff.add22_accurate(
                compensated.ff_sum_blocked(th, axis=-2, block=block),
                compensated.ff_sum_blocked(tl, axis=-2, block=block))
            ab = FF(jnp.broadcast_to(alpha.hi[..., None], nb.shape),
                    jnp.broadcast_to(alpha.lo[..., None], nb.shape))
            num = core_ff.add22(core_ff.mul22(num, ab), nb)
            return (m_new, den.hi, den.lo, num.hi, num.lo), None

        m0 = jnp.full(shp, NEG_INF, jnp.float32)
        z1 = jnp.zeros(shp, jnp.float32)
        z2 = jnp.zeros(shp + (hd,), jnp.float32)
        (m, dh, dl, nh, nl), _ = lax.scan(
            kv_step, (m0, z1, z1, z2, z2), jnp.arange(nkv, dtype=jnp.int32))
        den = _ff_safe_den(FF(dh, dl))
        dfull = FF(jnp.broadcast_to(den.hi[..., None], nh.shape),
                   jnp.broadcast_to(den.lo[..., None], nh.shape))
        o = core_ff.div22(FF(nh, nl), dfull)
        return o.hi, o.lo

    ohs, ols = lax.map(lambda args: one_q_block(*args),
                       (jnp.arange(nq, dtype=jnp.int32), qb))

    def assemble(planes):
        out = planes.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * bq, H, hd)
        return out[:, :Sq]

    if return_ff:
        return FF(assemble(ohs), assemble(ols))
    return assemble(ohs).astype(q.dtype)


# ===========================================================================
# pallas tier: the same recurrence as one kernel per (head, q-block) stripe
# ===========================================================================

def _attn_kernel(q_ref, k_ref, v_ref, o_ref, ol_ref,
                 m_sc, dh_sc, dl_sc, nh_sc, nl_sc, *,
                 nkv: int, bq: int, bkv: int, hdp: int,
                 Skv: int, causal: bool, q_offset: int, scale: float):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc[...], NEG_INF)
        dh_sc[...] = jnp.zeros_like(dh_sc[...])
        dl_sc[...] = jnp.zeros_like(dl_sc[...])
        nh_sc[...] = jnp.zeros_like(nh_sc[...])
        nl_sc[...] = jnp.zeros_like(nl_sc[...])

    qb = q_ref[0]                                     # (bq, hdp)
    kbT = k_ref[0]                                    # (hdp, bkv)
    vb = v_ref[0]                                     # (bkv, hdp)

    # FF scores: TwoProd-exact outer products per head-dim slice through a
    # Neumaier cascade (k arrives pre-transposed so the slice is a native
    # (1, bkv) row; the zero-padded hdp tail contributes exactly 0)
    zs = jnp.zeros((bq, bkv), jnp.float32)

    def sbody(d, carry):
        s_, c_, cc_ = carry
        qd = lax.dynamic_slice_in_dim(qb, d, 1, axis=1)       # (bq, 1)
        kd = lax.dynamic_slice_in_dim(kbT, d, 1, axis=0)      # (1, bkv)
        th, tl = eft.two_prod(jnp.broadcast_to(qd, (bq, bkv)),
                              jnp.broadcast_to(kd, (bq, bkv)))
        s2, e = eft.two_sum(s_, th)
        c2, e2 = eft.two_sum(c_, e)
        return s2, c2, cc_ + e2 + tl

    s_, c_, cc_ = lax.fori_loop(0, hdp, sbody, (zs, zs, zs))
    sh0, e0 = eft.two_sum(s_, c_)
    sh0, sl0 = eft.fast_two_sum(sh0, e0 + cc_)
    sh0, sl0 = eft.mul212(sh0, sl0, jnp.float32(scale))

    row = (jnp.int32(q_offset) + i * bq
           + lax.broadcasted_iota(jnp.int32, (bq, bkv), 0))
    col = j * bkv + lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = col < Skv
    if causal:
        mask = jnp.logical_and(mask, col <= row)
    sh = jnp.where(mask, sh0, jnp.float32(NEG_INF))
    sl = jnp.where(mask, sl0, jnp.float32(0.0))

    m_old = m_sc[:, :1]                               # (bq, 1)
    m_new = jnp.maximum(m_old, jnp.max(sh, axis=1, keepdims=True))
    m_sc[...] = jnp.broadcast_to(m_new, (bq, LANE))
    dh, dl = eft.add212(sh, sl, jnp.broadcast_to(-m_new, sh.shape))
    ph, plo = ffmath.exp22(dh, dl, eft)
    zero = jnp.float32(0.0)
    ph = jnp.where(mask, ph, zero)
    plo = jnp.where(mask, plo, zero)
    ah, al = eft.two_sum(m_old, -m_new)
    alh, all_ = ffmath.exp22(ah, al, eft)             # (bq, 1)

    # denominator: lane-parallel Neumaier cascade over both limb planes
    z = jnp.zeros((bq, LANE), jnp.float32)
    sA, cA, ccA = _lane_cascade(ph, z, z, z, LANE)
    sA, cA, ccA = _lane_cascade(plo, sA, cA, ccA, LANE)
    bs_h, bs_l = _fold_lanes(sA, cA, ccA)             # (bq,)
    d0h, d0l = eft.mul22(dh_sc[:, :1], dl_sc[:, :1], alh, all_)
    d1h, d1l = eft.add22(d0h, d0l, bs_h[:, None], bs_l[:, None])
    dh_sc[...] = jnp.broadcast_to(d1h, (bq, LANE))
    dl_sc[...] = jnp.broadcast_to(d1l, (bq, LANE))

    # numerator block sum: Neumaier cascade over the bkv terms, each an
    # exact TwoProd of the hi plane with the lo-plane product in the
    # compensation stream
    zn = jnp.zeros((bq, hdp), jnp.float32)

    def body(t, carry):
        s_, c_, cc_ = carry
        pt_h = lax.dynamic_slice_in_dim(ph, t, 1, axis=1)     # (bq, 1)
        pt_l = lax.dynamic_slice_in_dim(plo, t, 1, axis=1)
        vt = lax.dynamic_slice_in_dim(vb, t, 1, axis=0)       # (1, hdp)
        th, tl = eft.two_prod(jnp.broadcast_to(pt_h, (bq, hdp)),
                              jnp.broadcast_to(vt, (bq, hdp)))
        tl = tl + pt_l * vt
        s2, e = eft.two_sum(s_, th)
        c2, e2 = eft.two_sum(c_, e)
        return s2, c2, cc_ + e2 + tl

    s_, c_, cc_ = lax.fori_loop(0, bkv, body, (zn, zn, zn))
    pvh, e = eft.two_sum(s_, c_)
    pvh, pvl = eft.fast_two_sum(pvh, e + cc_)
    n0h, n0l = eft.mul22(nh_sc[...], nl_sc[...],
                         jnp.broadcast_to(alh, (bq, hdp)),
                         jnp.broadcast_to(all_, (bq, hdp)))
    n1h, n1l = eft.add22(n0h, n0l, pvh, pvl)
    nh_sc[...] = n1h
    nl_sc[...] = n1l

    @pl.when(j == nkv - 1)
    def _flush():
        tiny = jnp.float32(1e-30)
        dh = dh_sc[:, :1]
        ok = dh > tiny
        dh = jnp.where(ok, dh, tiny)
        dl = jnp.where(ok, dl_sc[:, :1], jnp.float32(0.0))
        oh, ol = eft.div22(nh_sc[...], nl_sc[...],
                           jnp.broadcast_to(dh, (bq, hdp)),
                           jnp.broadcast_to(dl, (bq, hdp)))
        o_ref[0] = oh
        ol_ref[0] = ol


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_kv", "q_offset", "scale", "interpret",
    "return_ff"))
def flash_attention_pallas(q: Array, k: Array, v: Array, *,
                           causal: bool = True, block_q: int = 32,
                           block_kv: int = 128, q_offset: int = 0,
                           scale: Optional[float] = None,
                           interpret: bool = False,
                           return_ff: bool = False):
    """The compensated online-softmax recurrence as ONE Pallas kernel per
    (batch*head) stripe: grid (B*H, n_q, n_kv), FF numerator/denominator
    accumulators in VMEM scratch carried across the innermost kv steps
    (init at j == 0, Div22-normalize and flush at j == n_kv-1 — the same
    scratch-carry scheme as ``ff_fused``'s trailing reductions).

    GQA is handled by the k/v BlockSpec index maps (head h reads kv head
    ``h // G``) — grouped keys are never materialized per query head.
    Static-length masking only (``kv_len`` ragged batches take the jnp
    tier via dispatch).  Compiled on TPU; interpret-mode elsewhere.
    """
    B, Sq, H, hd, Skv, KV = _dims(q, k)
    G = H // KV
    sc = _resolve_scale(scale, hd)
    bq = _round_up(min(block_q, Sq), SUBLANE)
    bkv = _round_up(min(block_kv, Skv), LANE)
    hdp = _round_up(hd, LANE)

    def prep(x, S, bs):
        # (B, S, Hx, hd) -> (B*Hx, Sp, hdp), f32, padded
        x = jnp.asarray(x, jnp.float32)
        x = jnp.pad(x, ((0, 0), (0, (-S) % bs), (0, 0), (0, hdp - hd)))
        x = x.transpose(0, 2, 1, 3)
        return x.reshape(-1, x.shape[2], hdp)

    q3 = prep(q, Sq, bq)
    k3 = prep(k, Skv, bkv).transpose(0, 2, 1)   # (B*KV, hdp, Skvp)
    v3 = prep(v, Skv, bkv)
    Sqp, Skvp = q3.shape[1], k3.shape[2]
    nq, nkv = Sqp // bq, Skvp // bkv

    def kv_row(h):
        return (h // H) * KV + (h % H) // G

    grid = (B * H, nq, nkv)
    ostruct = jax.ShapeDtypeStruct((B * H, Sqp, hdp), jnp.float32)
    ospec = pl.BlockSpec((1, bq, hdp), lambda h, i, j: (h, i, 0))
    oh3, ol3 = pl.pallas_call(
        functools.partial(_attn_kernel, nkv=nkv, bq=bq, bkv=bkv, hdp=hdp,
                          Skv=Skv, causal=causal, q_offset=int(q_offset),
                          scale=sc),
        out_shape=[ostruct, ostruct],
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hdp), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, hdp, bkv), lambda h, i, j: (kv_row(h), 0, j)),
            pl.BlockSpec((1, bkv, hdp), lambda h, i, j: (kv_row(h), j, 0)),
        ],
        out_specs=[ospec, ospec],
        scratch_shapes=[pltpu.VMEM((bq, LANE), jnp.float32),
                        pltpu.VMEM((bq, LANE), jnp.float32),
                        pltpu.VMEM((bq, LANE), jnp.float32),
                        pltpu.VMEM((bq, hdp), jnp.float32),
                        pltpu.VMEM((bq, hdp), jnp.float32)],
        interpret=interpret,
    )(q3, k3, v3)

    def assemble(x):
        x = x.reshape(B, H, Sqp, hdp).transpose(0, 2, 1, 3)
        return x[:, :Sq, :, :hd]

    if return_ff:
        return FF(assemble(oh3), assemble(ol3))
    return assemble(oh3).astype(q.dtype)


# ===========================================================================
# f64 tier: materialized-score oracle (CPU accurate tier / test reference)
# ===========================================================================

@functools.partial(jax.jit, static_argnames=("causal", "q_offset",
                                             "has_kv_len"))
def _attention_f64_jit(q: Array, k: Array, v: Array, kv_len: Array,
                       scale: Array, neg: Array, *, causal: bool,
                       q_offset: int, has_kv_len: bool) -> Array:
    """Native-f64 softmax attention, materialized (Sq, Skv) scores.

    Trace-scoped ``enable_x64`` behind a module-level nested-jit boundary
    (the ``matmul_f64`` idiom — see ``ffmatmul._matmul_f64_jit`` for why
    the boundary is load-bearing); constants inside the scope are traced
    OPERANDS (the scale rides in as an f32 array — a literal would be
    canonicalized to f32 at trace time and poison the f64 multiply)."""
    import jax.experimental

    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    with jax.experimental.enable_x64():
        c64 = lambda x: lax.convert_element_type(x, jnp.float64)
        q64 = c64(jnp.asarray(q, jnp.float32)).reshape(B, Sq, KV, G, hd)
        k64 = c64(jnp.asarray(k, jnp.float32))
        v64 = c64(jnp.asarray(v, jnp.float32))
        s = jnp.einsum("bqkgd,bskd->bkgqs", q64, k64) * c64(scale)
        q_pos = q_offset + jnp.arange(Sq, dtype=jnp.int32)
        kv_pos = jnp.arange(Skv, dtype=jnp.int32)
        mask = kv_pos[None, :] <= q_pos[:, None] if causal else \
            jnp.ones((Sq, Skv), bool)
        full = jnp.broadcast_to(mask[None, None, None], s.shape)
        if has_kv_len:
            rag = kv_pos[None, :] < kv_len[:, None]
            full = jnp.logical_and(full, rag[:, None, None, None])
        # masked scores get the traced -1e30 operand (f64 exp underflows
        # it to an exact 0 against any real row max) — a -inf LITERAL
        # would be canonicalized to f32 at trace time and poison the tree
        s = jnp.where(full, s, jnp.broadcast_to(c64(neg), s.shape))
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        den = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bkgqs,bskd->bkgqd", p / den, v64)
        hi = lax.convert_element_type(o, jnp.float32)
        lo = lax.convert_element_type(
            o - lax.convert_element_type(hi, jnp.float64), jnp.float32)

    def assemble(x):
        return x.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)

    return assemble(hi), assemble(lo)


def attention_f64(q: Array, k: Array, v: Array, *, causal: bool = True,
                  q_offset=0, kv_len: Optional[Array] = None,
                  scale: Optional[float] = None, return_ff: bool = False):
    """f64 oracle attention (materializes the (Sq, Skv) score plane —
    validation/scoring shapes only).  ``return_ff=True`` splits the f64
    result into FF limbs (hi = f32 round, lo = f32 residual) so the
    accurate tiers can be compared below the f32 rounding floor."""
    hd = q.shape[-1]
    B = q.shape[0]
    kl = jnp.zeros((B,), jnp.int32) if kv_len is None \
        else jnp.asarray(kv_len, jnp.int32)
    sc = jnp.asarray(_resolve_scale(scale, hd), jnp.float32)
    ng = jnp.asarray(NEG_INF, jnp.float32)
    hi, lo = _attention_f64_jit(q, k, v, kl, sc, ng, causal=causal,
                                q_offset=int(q_offset),
                                has_kv_len=kv_len is not None)
    if return_ff:
        return FF(hi, lo)
    return hi.astype(q.dtype)
