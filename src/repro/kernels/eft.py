"""EFT primitives for use INSIDE Pallas kernel bodies.

Separate from ``repro.core.transforms`` because kernel bodies must not carry
the CPU-only ``optimization_barrier`` workaround (the barrier is neither
needed nor guaranteed to lower on TPU Pallas): on TPU the VPU executes f32
mul/add as written (no FMA contraction), and in interpret mode the validation
suite pins ``--xla_cpu_max_isa=SSE4_2`` (see tests/conftest.py).

These are the same branch-free algorithms as the paper (§4).
"""

from __future__ import annotations

import jax.numpy as jnp

SPLIT_CONST = 4097.0  # 2**12 + 1 (Dekker split point for binary32)


def two_sum(a, b):
    s = a + b
    bb = s - a
    return s, (a - (s - bb)) + (b - bb)


def fast_two_sum(a, b):
    s = a + b
    return s, b - (s - a)


def split(a):
    c = jnp.float32(SPLIT_CONST) * a
    a_big = c - a
    a_hi = c - a_big
    return a_hi, a - a_hi


def two_prod(a, b):
    x = a * b
    a_hi, a_lo = split(a)
    b_hi, b_lo = split(b)
    err1 = x - (a_hi * b_hi)
    err2 = err1 - (a_lo * b_hi)
    err3 = err2 - (a_hi * b_lo)
    return x, (a_lo * b_lo) - err3


def add22(ah, al, bh, bl):
    """Paper Theorem 5 (branch-free sloppy Add22) on raw limbs."""
    sh, sl = two_sum(ah, bh)
    v = sl + (al + bl)
    return fast_two_sum(sh, v)


def mul22(ah, al, bh, bl):
    """Paper Theorem 6 (Mul22) on raw limbs."""
    th, tl = two_prod(ah, bh)
    t = tl + (ah * bl + al * bh)
    return fast_two_sum(th, t)


def add212(ah, al, b):
    """FF + f32 on raw limbs (see ``core.ff.add212``)."""
    sh, sl = two_sum(ah, b)
    v = sl + al
    return fast_two_sum(sh, v)


def mul212(ah, al, b):
    """FF * f32 on raw limbs (see ``core.ff.mul212``)."""
    th, tl = two_prod(ah, b)
    t = tl + al * b
    return fast_two_sum(th, t)


def div22(ah, al, bh, bl):
    """FF division on raw limbs (Dekker quotient + one correction,
    see ``core.ff.div22``): the hardware quotient is only a *seed*."""
    ch = ah / bh
    th, tl = two_prod(ch, bh)
    cl = ((((ah - th) - tl) + al) - ch * bl) / bh
    return fast_two_sum(ch, cl)


def sqrt22(ah, al):
    """FF square root on raw limbs (one Newton correction of the hardware
    sqrt, see ``core.ff.sqrt22``)."""
    ch = jnp.sqrt(ah)
    th, tl = two_prod(ch, ch)
    num = ((ah - th) - tl) + al
    cl = num / (ch + ch)
    return fast_two_sum(ch, cl)


def fma22(ah, al, bh, bl, ch, cl):
    """a*b + c in FF on raw limbs (one renormalization, see
    ``core.ff.fma22``)."""
    th, tl = two_prod(ah, bh)
    t = tl + (ah * bl + al * bh)
    sh, sl = two_sum(th, ch)
    v = sl + (t + cl)
    return fast_two_sum(sh, v)


def pairwise_sum_compensated(p, axis: int, err=None):
    """Pairwise two_sum tree reduction over ``axis`` (see
    ``core.transforms.pairwise_sum_compensated`` for the algorithm) using
    THIS module's barrier-free two_sum — the form Pallas kernel bodies
    need.  The generic combinator carries no barriers of its own, so the
    import does not smuggle ``optimization_barrier`` into kernels."""
    from repro.core import transforms as T
    return T.pairwise_sum_compensated(p, axis, err, two_sum_fn=two_sum)
