"""Public jit'd wrappers for the Pallas FF kernels.

Selects interpret mode automatically on CPU (validation) and compiled mode
on TPU.  All wrappers take/return ``repro.core.ff.FF`` where natural.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.ff import FF
from repro.kernels import ff_elementwise, ff_matmul, ff_reduce


@functools.lru_cache(maxsize=1)
def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


def ff_add(a: FF, b: FF, *, interpret: Optional[bool] = None) -> FF:
    """Elementwise Add22 via the Pallas kernel."""
    interp = _interpret_default() if interpret is None else interpret
    rh, rl = ff_elementwise.elementwise(
        "add22", a.hi, a.lo, b.hi, b.lo, interpret=interp)
    return FF(rh, rl)


def ff_mul(a: FF, b: FF, *, interpret: Optional[bool] = None) -> FF:
    """Elementwise Mul22 via the Pallas kernel."""
    interp = _interpret_default() if interpret is None else interpret
    rh, rl = ff_elementwise.elementwise(
        "mul22", a.hi, a.lo, b.hi, b.lo, interpret=interp)
    return FF(rh, rl)


def two_prod(a, b, *, interpret: Optional[bool] = None) -> FF:
    interp = _interpret_default() if interpret is None else interpret
    x, y = ff_elementwise.elementwise("two_prod", a, b, interpret=interp)
    return FF(x, y)


def two_sum(a, b, *, interpret: Optional[bool] = None) -> FF:
    interp = _interpret_default() if interpret is None else interpret
    s, r = ff_elementwise.elementwise("two_sum", a, b, interpret=interp)
    return FF(s, r)


def matmul(a, b, *, bm: int = 256, bn: int = 256, bk: int = 512,
           interpret: Optional[bool] = None) -> FF:
    """Hybrid MXU+Add22 FF matmul (production path)."""
    interp = _interpret_default() if interpret is None else interpret
    hi, lo = ff_matmul.ff_matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=interp)
    return FF(hi, lo)


def matmul_dot2(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128,
                interpret: Optional[bool] = None) -> FF:
    """Paper-faithful FF matmul (exact products, Dot3 cascade)."""
    interp = _interpret_default() if interpret is None else interpret
    hi, lo = ff_matmul.ff_matmul_dot2(
        a, b, bm=bm, bn=bn, bk=bk, interpret=interp)
    return FF(hi, lo)


def rowsum(x, *, br: int = 256, bc: int = 512, lane: int = 128,
           interpret: Optional[bool] = None) -> FF:
    """Compensated last-axis reduction of a 2-D array -> FF per row."""
    interp = _interpret_default() if interpret is None else interpret
    hi, lo = ff_reduce.ff_rowsum(
        x, br=br, bc=bc, lane=lane, interpret=interp)
    return FF(hi, lo)
