"""DEPRECATED shim — use the unified ``repro.ff`` namespace instead.

These wrappers predate the dispatch registry: callers had to pick the Pallas
path by hand and thread ``interpret`` flags themselves.  They now route
through ``repro.ff`` with the Pallas implementation pinned (so behavior —
including bit-exactness against ``repro.kernels.ref`` — is unchanged), and
warn on use.  New code should call ``repro.ff.add`` / ``mul`` / ``matmul`` /
``sum`` and let the registry pick the backend.
"""

from __future__ import annotations

import warnings
from typing import Optional

from repro.core.ff import FF
import repro.ff as _ff


def _warn(name: str, repl: str) -> None:
    warnings.warn(
        f"repro.kernels.ops.{name} is deprecated; use {repl} "
        f"(backend dispatch replaces manual interpret= threading)",
        DeprecationWarning, stacklevel=3)


def ff_add(a: FF, b: FF, *, interpret: Optional[bool] = None) -> FF:
    """Elementwise Add22 via the Pallas kernel."""
    _warn("ff_add", "repro.ff.add")
    return _ff.add(a, b, impl="pallas", interpret=interpret)


def ff_mul(a: FF, b: FF, *, interpret: Optional[bool] = None) -> FF:
    """Elementwise Mul22 via the Pallas kernel."""
    _warn("ff_mul", "repro.ff.mul")
    return _ff.mul(a, b, impl="pallas", interpret=interpret)


def two_prod(a, b, *, interpret: Optional[bool] = None) -> FF:
    _warn("two_prod", "repro.ff.two_prod")
    return _ff.two_prod(a, b, impl="pallas", interpret=interpret)


def two_sum(a, b, *, interpret: Optional[bool] = None) -> FF:
    _warn("two_sum", "repro.ff.two_sum")
    return _ff.two_sum(a, b, impl="pallas", interpret=interpret)


def matmul(a, b, *, bm: int = 256, bn: int = 256, bk: int = 512,
           interpret: Optional[bool] = None) -> FF:
    """Hybrid MXU+Add22 FF matmul (production path)."""
    _warn("matmul", "repro.ff.matmul")
    return _ff.matmul(a, b, impl="pallas_hybrid", bm=bm, bn=bn, bk=bk,
                      interpret=interpret)


def matmul_dot2(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128,
                interpret: Optional[bool] = None) -> FF:
    """Paper-faithful FF matmul (exact products, Dot3 cascade)."""
    _warn("matmul_dot2", "repro.ff.matmul(impl='pallas_dot2')")
    return _ff.matmul(a, b, impl="pallas_dot2", bm=bm, bn=bn, bk=bk,
                      interpret=interpret)


def rowsum(x, *, br: int = 256, bc: int = 512, lane: int = 128,
           interpret: Optional[bool] = None) -> FF:
    """Compensated last-axis reduction of a 2-D array -> FF per row."""
    _warn("rowsum", "repro.ff.sum(impl='pallas_rowsum')")
    return _ff.sum(x, axis=-1, impl="pallas_rowsum", br=br, bc=bc, lane=lane,
                   interpret=interpret)
