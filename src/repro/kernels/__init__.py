"""Pallas TPU kernels for the FF hot spots + jit wrappers + oracles.

The paper's contribution IS a compute hot-spot (elementwise FF operators and
the reductions/matmuls built from them), so this layer is substantive:

  eft.py             — branch-free EFT primitives for kernel bodies
  ff_elementwise.py  — Add22/Mul22/TwoSum/TwoProd tile kernels
  ff_matmul.py       — hybrid MXU FF matmul + paper-faithful Dot3 kernel
  ff_reduce.py       — compensated row-reduction kernel
  ops.py             — DEPRECATED shim over ``repro.ff`` (the dispatch
                       registry now owns backend/interpret selection)
  ref.py             — pure-jnp oracles mirroring each kernel's order
"""
