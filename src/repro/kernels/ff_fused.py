"""Pallas executors for fused FF expression pipelines + hand-fused
composite kernels (softmax / logsumexp / layer-norm stats).

Generic executor (:func:`run_pallas`): takes a traced
``repro.ff.fusion.Program`` and runs the WHOLE chain as one ``pallas_call``
— each input's hi/lo planes stream HBM -> VMEM once, every intermediate
stays in VMEM/vector registers via the branch-free ``repro.kernels.eft``
primitives, outputs are written once.  An optional trailing row reduction
per output accumulates a lane-parallel Neumaier cascade in VMEM scratch
across column blocks (same scheme as ``ff_reduce.ff_rowsum``) and folds it
exactly on the last column step.

Hand-fused composites: softmax and logsumexp need a row *max* BEFORE the
elementwise chain, which the trailing-reduction expression model cannot
express — so they get a dedicated kernel that holds the whole row in VMEM
(rows up to :data:`MAX_FUSED_COLS`; dispatch falls back to the jnp impl
beyond that).  ``norm_stats`` fuses BOTH LayerNorm reductions (mean and
centered variance — two passes over the row) into one kernel: x is read
from HBM once instead of three times (mean pass, center pass, square-sum
pass).

Numerics: elementwise chain results are bitwise-identical to op-by-op
dispatch (same EFT sequences).  Reduction results may differ from the
jnp references by the final-rounding ulp: both sides compute the sum to
~2^-40 relative before rounding to the f32-pair, so the represented values
agree far below f32 ulp but the two summation ORDERS (lane cascade here,
``ff_sum_blocked``'s scan there) can round the last bit differently.
Tests pin this to <= 1 ulp; ``docs/DESIGN_fusion.md`` has the argument.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import ffmath
from repro.core.ff import FF
from repro.kernels import eft
from repro.kernels.ff_elementwise import (
    LANE, SUBLANE, _pad_to, _round_up, _spec_for, _to_2d, broadcast_planes,
)

# FF transcendentals usable inside fused chains (tracer ops -> the generic
# repro.core.ffmath bodies, instantiated with THIS module's barrier-free
# EFTs — the same arithmetic the jnp executor replays with the barrier-
# carrying core primitives, so the two stay bitwise-aligned)
_DEEP_OPS = ("exp22", "log22", "tanh22", "sigmoid22")

Array = jnp.ndarray

VMEM_BUDGET_BYTES = 4 * 1024 * 1024   # working-set target per block
MAX_FUSED_COLS = 16384                # whole-row kernels beyond this -> jnp


def _pick_block(planes: int, R: int, C: int,
                block: Optional[Tuple[int, int]] = None) -> Tuple[int, int]:
    """Tile for a ``planes``-deep chain: shrink rows (then cols) until
    ``planes * br * bc * 4B`` fits the VMEM budget.  Deeper chains get
    smaller tiles; the grid grows, the HBM traffic does not."""
    if block is not None:
        br, bc = block
        return (min(_round_up(br, SUBLANE), _round_up(max(R, 1), SUBLANE)),
                min(_round_up(bc, LANE), _round_up(max(C, 1), LANE)))
    budget_elems = VMEM_BUDGET_BYTES // (4 * max(planes, 1))
    bc = min(512, _round_up(max(C, 1), LANE))
    br = min(256, _round_up(max(R, 1), SUBLANE))
    while br * bc > budget_elems and br > SUBLANE:
        br = max(SUBLANE, _round_up(br // 2, SUBLANE))   # stay 8-aligned
    while br * bc > budget_elems and bc > LANE:
        bc = max(LANE, LANE * ((bc // 2) // LANE))
    return br, bc


def _eval_instrs(prog, leaf_blocks):
    """Evaluate the non-reduction instructions on loaded blocks.  FF values
    are (hi, lo) tuples; f32 values are arrays.  Returns the env list
    (rowsum instrs left as None — handled by the caller)."""
    env: List = []
    for ins in prog.instrs:
        op, args = ins.op, ins.args
        if op in ("leaf_ff", "leaf_f32"):
            v = leaf_blocks[int(ins.imm)]
        elif op == "const":
            v = jnp.float32(ins.imm)
        elif op == "fadd":
            v = env[args[0]] + env[args[1]]
        elif op == "fsub":
            v = env[args[0]] - env[args[1]]
        elif op == "fmul":
            v = env[args[0]] * env[args[1]]
        elif op == "fdiv":
            v = env[args[0]] / env[args[1]]
        elif op == "fneg":
            v = -env[args[0]]
        elif op == "fsqrt":
            v = jnp.sqrt(env[args[0]])
        elif op == "fexp":
            v = jnp.exp(env[args[0]])
        elif op == "flog":
            v = jnp.log(env[args[0]])
        elif op == "add22":
            v = eft.add22(*env[args[0]], *env[args[1]])
        elif op == "add212":
            v = eft.add212(*env[args[0]], env[args[1]])
        elif op == "mul22":
            v = eft.mul22(*env[args[0]], *env[args[1]])
        elif op == "mul212":
            v = eft.mul212(*env[args[0]], env[args[1]])
        elif op == "div22":
            v = eft.div22(*env[args[0]], *env[args[1]])
        elif op == "sqrt22":
            v = eft.sqrt22(*env[args[0]])
        elif op == "fma22":
            v = eft.fma22(*env[args[0]], *env[args[1]], *env[args[2]])
        elif op == "neg22":
            h, l = env[args[0]]
            v = (-h, -l)
        elif op in _DEEP_OPS:
            h, l = env[args[0]]
            v = getattr(ffmath, op)(h, l, eft)
        elif op == "lift":
            x = env[args[0]]
            v = (x, jnp.zeros_like(x))
        elif op == "hi":
            v = env[args[0]][0]
        elif op == "lo":
            v = env[args[0]][1]
        elif op == "pack":
            v = (env[args[0]], env[args[1]])
        elif op == "rowsum":
            v = None
        else:                                          # pragma: no cover
            raise NotImplementedError(op)
        env.append(v)
    return env


def _lane_cascade(val: Array, s, c, cc, lane: int):
    """One block's contribution to a lane-parallel Neumaier cascade:
    fold (br, bc) into three (br, lane) accumulators."""
    def body(t, carry):
        si, ci, cci = carry
        xt = lax.dynamic_slice_in_dim(val, t * lane, lane, axis=1)
        s2, e = eft.two_sum(si, xt)
        c2, e2 = eft.two_sum(ci, e)
        return s2, c2, cci + e2

    return lax.fori_loop(0, val.shape[1] // lane, body, (s, c, cc))


def _fold_lanes(s_acc, c_acc, cc_acc) -> Tuple[Array, Array]:
    """Exact sequential fold of the ``lane`` per-lane accumulators (same
    scheme as ``ff_reduce``): (br, lane) x3 -> FF per row (br,)."""
    def fold(i, carry):
        fh, fl = carry
        sh, sl = eft.two_sum(
            fh, lax.dynamic_slice_in_dim(s_acc, i, 1, axis=1)[:, 0])
        v = sl + (fl
                  + lax.dynamic_slice_in_dim(c_acc, i, 1, axis=1)[:, 0]
                  + lax.dynamic_slice_in_dim(cc_acc, i, 1, axis=1)[:, 0])
        return eft.fast_two_sum(sh, v)

    br = s_acc.shape[0]
    z = jnp.zeros((br,), jnp.float32)
    return lax.fori_loop(0, s_acc.shape[1], fold, (z, z))


def _unbroadcast(arr: Array, full_shape, nd) -> Array:
    """Recover a value of true ND shape ``nd`` from its full-broadcast
    compute plane: along every dim the value broadcasts over, all slices
    are identical copies — take index 0."""
    if tuple(nd) == tuple(full_shape):
        return arr
    pad = len(full_shape) - len(nd)
    idx = tuple(
        slice(0, 1) if (1 if d < pad else nd[d - pad]) == 1 and size != 1
        else slice(None)
        for d, size in enumerate(full_shape))
    return arr[idx].reshape(nd)


def run_pallas(prog, operands: Sequence, *,
               block: Optional[Tuple[int, int]] = None,
               interpret: bool = False):
    """Execute a fused Program as ONE pallas_call (see module docstring)."""
    from repro.ff import fusion

    # -- flatten leaves to broadcastable 2-D planes --------------------------
    raw: List[Array] = []            # one entry per plane
    leaf_plane_ix: List[Tuple[int, ...]] = []  # per leaf: plane indices
    for kind, x in zip(prog.leaf_kinds, operands):
        if kind == "ff":
            leaf_plane_ix.append((len(raw), len(raw) + 1))
            raw.extend([jnp.asarray(x.hi, jnp.float32),
                        jnp.asarray(x.lo, jnp.float32)])
        else:
            leaf_plane_ix.append((len(raw),))
            raw.append(jnp.asarray(x, jnp.float32))
    # per-value ND shapes: outputs must come back with the SAME shapes the
    # jnp executor produces (an output may depend on a subset of operands
    # and be narrower than the full broadcast of all of them)
    nd_shapes = fusion.infer_shapes(
        prog, [jnp.shape(x.hi if hasattr(x, "hi") else x)
               for x in operands])
    planes, out_shape = broadcast_planes(raw)
    if len(out_shape) == 0:
        R, C = 1, 1
    else:
        R = 1
        for d in out_shape[:-1]:
            R *= d
        C = out_shape[-1]

    # plane_count already counts leaf and output instructions once each
    n_planes = prog.plane_count()
    br, bc = _pick_block(n_planes, R, C, block)
    Rp, Cp = _round_up(R, br), _round_up(C, bc)
    nr, nc = Rp // br, Cp // bc
    padded = [_pad_to(p, br if p.shape[0] != 1 else 1,
                      bc if p.shape[1] != 1 else 1) for p in planes]
    in_specs = [_spec_for(p.shape, (Rp, Cp), br, bc) for p in padded]

    # -- outputs + reduction scratch -----------------------------------------
    ew_spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    red_spec = pl.BlockSpec((br, 1), lambda i, j: (i, 0))
    full = jax.ShapeDtypeStruct((Rp, Cp), jnp.float32)
    rcol = jax.ShapeDtypeStruct((Rp, 1), jnp.float32)
    out_shapes: List = []
    out_specs: List = []
    out_kinds: List[str] = []        # "ff" | "f32" | "red" per out id
    red_width: dict = {}             # out id -> the reduced VALUE's width
    n_red = 0
    for oid in prog.out_ids:
        ins = prog.instrs[oid]
        if ins.op == "rowsum":
            out_kinds.append("red")
            out_shapes += [rcol, rcol]
            out_specs += [red_spec, red_spec]
            vshape = nd_shapes[ins.args[0]]
            red_width[oid] = vshape[-1] if vshape else 1
            n_red += 1
        elif ins.dtype == "ff":
            out_kinds.append("ff")
            out_shapes += [full, full]
            out_specs += [ew_spec, ew_spec]
        else:
            out_kinds.append("f32")
            out_shapes.append(full)
            out_specs.append(ew_spec)
    scratch = [pltpu.VMEM((br, LANE), jnp.float32)
               for _ in range(3 * n_red)]

    n_in = len(padded)
    n_out_refs = len(out_shapes)

    def kernel(*refs):
        in_refs = refs[:n_in]
        out_refs = refs[n_in:n_in + n_out_refs]
        sc = refs[n_in + n_out_refs:]
        j = pl.program_id(1)

        if n_red:
            @pl.when(j == 0)
            def _init():
                for s in sc:
                    s[...] = jnp.zeros_like(s)

        leaf_blocks = []
        for kind, ix in zip(prog.leaf_kinds, leaf_plane_ix):
            if kind == "ff":
                leaf_blocks.append((in_refs[ix[0]][...], in_refs[ix[1]][...]))
            else:
                leaf_blocks.append(in_refs[ix[0]][...])
        env = _eval_instrs(prog, leaf_blocks)

        # a value built only from broadcast leaves keeps a degenerate
        # (1, bc)/(br, 1)/(1, 1) block shape — expand at the write/reduce
        bcast = lambda v: jnp.broadcast_to(v, (br, bc))

        oref = 0
        red = 0
        for oid, okind in zip(prog.out_ids, out_kinds):
            if okind == "red":
                val = bcast(env[prog.instrs[oid].args[0]])
                # mask padded columns — and broadcast copies beyond the
                # VALUE's own width: the chain may be nonzero on a zero
                # pad (x + 1), and a column-broadcast value must reduce
                # over its one true column, not C copies of it
                col = j * bc + lax.broadcasted_iota(jnp.int32, val.shape, 1)
                val = jnp.where(col < red_width[oid], val, jnp.float32(0))
                s, c, cc = _lane_cascade(val, sc[3 * red][...],
                                         sc[3 * red + 1][...],
                                         sc[3 * red + 2][...], LANE)
                sc[3 * red][...] = s
                sc[3 * red + 1][...] = c
                sc[3 * red + 2][...] = cc
                oh_ref, ol_ref = out_refs[oref], out_refs[oref + 1]

                @pl.when(j == nc - 1)
                def _flush(red=red, oh_ref=oh_ref, ol_ref=ol_ref):
                    fh, fl = _fold_lanes(sc[3 * red][...],
                                         sc[3 * red + 1][...],
                                         sc[3 * red + 2][...])
                    oh_ref[...] = fh[:, None]
                    ol_ref[...] = fl[:, None]

                oref += 2
                red += 1
            elif okind == "ff":
                h, l = env[oid]
                out_refs[oref][...] = bcast(h)
                out_refs[oref + 1][...] = bcast(l)
                oref += 2
            else:
                out_refs[oref][...] = bcast(env[oid])
                oref += 1

    flat = pl.pallas_call(
        kernel,
        out_shape=tuple(out_shapes),
        grid=(nr, nc),
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        scratch_shapes=scratch,
        interpret=interpret,
    )(*padded)

    # -- un-pad / un-broadcast / reshape back --------------------------------
    outs: List = []
    k = 0
    lead = out_shape[:-1] if len(out_shape) else ()
    for oid, okind in zip(prog.out_ids, out_kinds):
        nd = nd_shapes[oid]
        if okind == "red":
            outs.append(FF(
                _unbroadcast(flat[k][:R, 0].reshape(lead), lead, nd),
                _unbroadcast(flat[k + 1][:R, 0].reshape(lead), lead, nd)))
            k += 2
        elif okind == "ff":
            outs.append(FF(
                _unbroadcast(flat[k][:R, :C].reshape(out_shape),
                             out_shape, nd),
                _unbroadcast(flat[k + 1][:R, :C].reshape(out_shape),
                             out_shape, nd)))
            k += 2
        else:
            outs.append(_unbroadcast(flat[k][:R, :C].reshape(out_shape),
                                     out_shape, nd))
            k += 1
    return outs


# ===========================================================================
# hand-fused composite kernels (whole row in VMEM)
# ===========================================================================

def _row_block(R: int, C: int, planes: int, br: int) -> Tuple[int, int]:
    """Row-block size for whole-row kernels under the VMEM budget."""
    Cp = _round_up(max(C, 1), LANE)
    cap = max(SUBLANE, (VMEM_BUDGET_BYTES // (4 * planes * Cp))
              // SUBLANE * SUBLANE)
    br = min(_round_up(br, SUBLANE), cap, _round_up(max(R, 1), SUBLANE))
    return br, Cp


def _softmax_kernel(x_ref, out_ref, *, C: int, mode: str, accurate: bool):
    x = x_ref[...]                                     # (br, Cp)
    mask = lax.broadcasted_iota(jnp.int32, x.shape, 1) < C
    xm = jnp.where(mask, x, jnp.float32(-jnp.inf))
    m = jnp.max(xm, axis=1, keepdims=True)             # (br, 1)
    z = jnp.zeros((x.shape[0], LANE), jnp.float32)
    if accurate:
        # FF exponentials: x - m held exact (TwoSum), exp via the ff.math
        # kernel, BOTH limb planes through the lane cascade -> FF sum
        dh, dl = eft.two_sum(x, -m)
        eh, el = ffmath.exp22(dh, dl, eft)
        eh = jnp.where(mask, eh, jnp.float32(0))
        el = jnp.where(mask, el, jnp.float32(0))
        s, c, cc = _lane_cascade(eh, z, z, z, LANE)
        s, c, cc = _lane_cascade(el, s, c, cc, LANE)
        fh, fl = _fold_lanes(s, c, cc)                 # FF row sum
        if mode == "softmax":
            qh, _ql = eft.div22(eh, el, fh[:, None], fl[:, None])
            out_ref[...] = qh
        else:
            lh, ll = ffmath.log22(fh[:, None], fl[:, None], eft)
            oh, _ol = eft.add212(lh, ll, m)
            out_ref[...] = oh
        return
    e = jnp.where(mask, jnp.exp(x - m), jnp.float32(0))
    s, c, cc = _lane_cascade(e, z, z, z, LANE)
    fh, _fl = _fold_lanes(s, c, cc)                    # (br,)
    if mode == "softmax":
        out_ref[...] = e / fh[:, None]
    else:                                              # logsumexp
        out_ref[...] = m + jnp.log(fh)[:, None]


@functools.partial(jax.jit,
                   static_argnames=("mode", "br", "accurate", "interpret"))
def ff_softmax(x: Array, *, mode: str = "softmax", br: int = 256,
               accurate: bool = False, interpret: bool = False):
    """One-kernel compensated softmax / logsumexp over the last axis.

    The whole row lives in VMEM (C <= MAX_FUSED_COLS — callers fall back
    to the jnp impl beyond); the exp-sum uses the same lane-parallel
    Neumaier cascade as the fused rowsum.  ``mode``: "softmax" returns the
    (R, C) probabilities, "logsumexp" the (R,) LSE values.

    ``accurate=True`` is the ``ff.math``-powered accurate class: the
    exponentials run the FF exp kernel on an exact TwoSum-reduced
    argument and both limb planes feed the compensated sum, so the f32
    result is correctly-rounded-class instead of carrying the ~2^-24
    builtin-exp error into every term (still ONE kernel launch).
    """
    x = jnp.asarray(x, jnp.float32)
    shape = x.shape
    x2 = _to_2d(x)
    R, C = x2.shape
    if C > MAX_FUSED_COLS:
        raise ValueError(f"row length {C} exceeds MAX_FUSED_COLS "
                         f"({MAX_FUSED_COLS}); use the jnp impl")
    br, Cp = _row_block(R, C, planes=9 if accurate else 3, br=br)
    x2 = _pad_to(x2, br, Cp)
    Rp = x2.shape[0]
    row_spec = pl.BlockSpec((br, Cp), lambda i: (i, 0))
    if mode == "softmax":
        out_shape = jax.ShapeDtypeStruct((Rp, Cp), jnp.float32)
        out_spec = row_spec
    else:
        out_shape = jax.ShapeDtypeStruct((Rp, 1), jnp.float32)
        out_spec = pl.BlockSpec((br, 1), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_softmax_kernel, C=C, mode=mode,
                          accurate=accurate),
        out_shape=out_shape,
        grid=(Rp // br,),
        in_specs=[row_spec],
        out_specs=out_spec,
        interpret=interpret,
    )(x2)
    if mode == "softmax":
        return out[:R, :C].reshape(shape)
    return out[:R, 0].reshape(shape[:-1])


def _norm_stats_kernel(x_ref, mu_ref, var_ref, *, C: int):
    x = x_ref[...]                                     # (br, Cp)
    mask = lax.broadcasted_iota(jnp.int32, x.shape, 1) < C
    xz = jnp.where(mask, x, jnp.float32(0))
    z = jnp.zeros((x.shape[0], LANE), jnp.float32)
    s, c, cc = _lane_cascade(xz, z, z, z, LANE)
    s1h, _ = _fold_lanes(s, c, cc)
    mu = s1h / jnp.float32(C)                          # (br,)
    d = jnp.where(mask, x - mu[:, None], jnp.float32(0))
    s, c, cc = _lane_cascade(d * d, z, z, z, LANE)
    s2h, _ = _fold_lanes(s, c, cc)
    mu_ref[...] = mu[:, None]
    var_ref[...] = (s2h / jnp.float32(C))[:, None]


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def ff_norm_stats(x: Array, *, br: int = 256,
                  interpret: bool = False) -> Tuple[Array, Array]:
    """One-kernel LayerNorm statistics over the last axis: compensated
    mean AND centered variance with x read from HBM once (the op-by-op
    path reads it three times).  Returns (mean, var), f32, shape[:-1]."""
    x = jnp.asarray(x, jnp.float32)
    shape = x.shape
    x2 = _to_2d(x)
    R, C = x2.shape
    if C > MAX_FUSED_COLS:
        raise ValueError(f"row length {C} exceeds MAX_FUSED_COLS "
                         f"({MAX_FUSED_COLS}); use the jnp impl")
    br, Cp = _row_block(R, C, planes=2, br=br)
    x2 = _pad_to(x2, br, Cp)
    Rp = x2.shape[0]
    col = jax.ShapeDtypeStruct((Rp, 1), jnp.float32)
    mu, var = pl.pallas_call(
        functools.partial(_norm_stats_kernel, C=C),
        out_shape=(col, col),
        grid=(Rp // br,),
        in_specs=[pl.BlockSpec((br, Cp), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((br, 1), lambda i: (i, 0)),
                   pl.BlockSpec((br, 1), lambda i: (i, 0))),
        interpret=interpret,
    )(x2)
    lead = shape[:-1]
    return mu[:R, 0].reshape(lead), var[:R, 0].reshape(lead)
