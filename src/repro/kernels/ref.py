"""Pure-jnp oracles for every Pallas kernel (same algorithms, no tiling).

Each ``ref_*`` mirrors its kernel's arithmetic ORDER so results agree to the
last bits the order determines; accuracy vs the exact f64 oracle is asserted
separately in tests.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
from jax import lax

from repro.core import transforms as T

Array = jnp.ndarray


def ref_add22(ah, al, bh, bl) -> Tuple[Array, Array]:
    sh, sl = T.two_sum(ah, bh)
    v = sl + (al + bl)
    return T.fast_two_sum(sh, v)


def ref_mul22(ah, al, bh, bl) -> Tuple[Array, Array]:
    th, tl = T.two_prod(ah, bh)
    t = tl + (ah * bl + al * bh)
    return T.fast_two_sum(th, t)


def ref_two_prod(a, b) -> Tuple[Array, Array]:
    return T.two_prod(a, b)


def ref_two_sum(a, b) -> Tuple[Array, Array]:
    return T.two_sum(a, b)


def ref_ff_matmul(a: Array, b: Array, bk: int = 512) -> Tuple[Array, Array]:
    """Oracle for the hybrid kernel: blocked-K f32 dots + Add22 folding,
    identical K-block order."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    M, K = a.shape
    _, N = b.shape
    bk = min(bk, K)
    pk = (-K) % bk
    if pk:
        a = jnp.pad(a, ((0, 0), (0, pk)))
        b = jnp.pad(b, ((0, pk), (0, 0)))
    nk = a.shape[1] // bk
    a3 = a.reshape(M, nk, bk).transpose(1, 0, 2)
    b3 = b.reshape(nk, bk, N)

    def body(carry, ab):
        hi, lo = carry
        ai, bi = ab
        p = lax.dot(ai, bi, precision=lax.Precision.HIGHEST,
                    preferred_element_type=jnp.float32)
        sh, sl = T.two_sum(hi, p)
        v = sl + lo
        rh, rl = T.fast_two_sum(sh, v)
        return (rh, rl), None

    z = jnp.zeros((M, N), jnp.float32)
    (hi, lo), _ = lax.scan(body, (z, z), (a3, b3))
    return hi, lo


def ref_ff_matmul_dot2(a: Array, b: Array) -> Tuple[Array, Array]:
    """Oracle for the paper-faithful kernel: per-element Mul12 + Dot3
    cascade in the same K order."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    M, K = a.shape
    _, N = b.shape

    def body(carry, ab):
        s, c, cc = carry
        ai, bi = ab
        p, pe = T.two_prod(ai[:, None], bi[None, :])
        s2, se = T.two_sum(s, p)
        c2, ce = T.two_sum(c, se + pe)
        return (s2, c2, cc + ce), None

    z = jnp.zeros((M, N), jnp.float32)
    (s, c, cc), _ = lax.scan(body, (z, z, z), (a.T, b))
    return T.fast_two_sum(s, c + cc)


def ref_ff_rowsum(x: Array, lane: int = 128) -> Tuple[Array, Array]:
    """Oracle for ff_rowsum: lane-strided Sum3 cascade, then exact fold."""
    x = jnp.asarray(x, jnp.float32)
    R, C = x.shape
    lane = min(lane, C)
    pc = (-C) % lane
    if pc:
        x = jnp.pad(x, ((0, 0), (0, pc)))
    xb = x.reshape(R, -1, lane)  # (R, steps, lane)

    def body(carry, xt):
        s, c, cc = carry
        s2, e = T.two_sum(s, xt)
        c2, e2 = T.two_sum(c, e)
        return (s2, c2, cc + e2), None

    z = jnp.zeros((R, lane), jnp.float32)
    (s, c, cc), _ = lax.scan(body, (z, z, z), xb.transpose(1, 0, 2))

    def fold(carry, scc):
        fh, fl = carry
        si, ci, cci = scc
        sh, sl = T.two_sum(fh, si)
        v = sl + (fl + ci + cci)
        return T.fast_two_sum(sh, v), None

    zr = jnp.zeros((R,), jnp.float32)
    (fh, fl), _ = lax.scan(fold, (zr, zr),
                           (s.T, c.T, cc.T))
    return fh, fl
