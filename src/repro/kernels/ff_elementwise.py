"""Pallas TPU kernels for elementwise FF operators (paper Add22/Mul22).

The paper streamed texels through fragment shaders; the TPU analogue is
streaming (8,128)-aligned VMEM tiles through the VPU.  Tiles are 2-D blocks
``(block_rows, block_cols)`` of a flattened-to-2D operand; the last dim is
kept a multiple of 128 (lane width) and rows a multiple of 8 (sublanes).

Layout note: FF tensors arrive as separate hi/lo arrays (a pytree of two
f32 planes — the GPU paper used two texture channels; two planes keep each
plane contiguous and MXU/VPU-friendly).

Broadcasting: operands may be scalars, rows ``(1, C)``, columns ``(R, 1)``
or full ``(R, C)`` relative to the broadcast output shape.  Broadcast
operands are NOT materialized: their BlockSpec index map pins the broadcast
dimension to block 0 and the kernel body relies on jnp broadcasting, so a
row operand is read once per column-block instead of R times.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import eft

Array = jnp.ndarray

DEFAULT_BLOCK = (256, 512)  # 256*512*4B = 512 KiB/plane; 6 planes < 4 MiB VMEM

SUBLANE = 8     # f32 second-to-last tile dim
LANE = 128      # last tile dim


def _add22_kernel(ah_ref, al_ref, bh_ref, bl_ref, rh_ref, rl_ref):
    rh, rl = eft.add22(ah_ref[...], al_ref[...], bh_ref[...], bl_ref[...])
    rh_ref[...] = rh
    rl_ref[...] = rl


def _mul22_kernel(ah_ref, al_ref, bh_ref, bl_ref, rh_ref, rl_ref):
    rh, rl = eft.mul22(ah_ref[...], al_ref[...], bh_ref[...], bl_ref[...])
    rh_ref[...] = rh
    rl_ref[...] = rl


def _div22_kernel(ah_ref, al_ref, bh_ref, bl_ref, rh_ref, rl_ref):
    rh, rl = eft.div22(ah_ref[...], al_ref[...], bh_ref[...], bl_ref[...])
    rh_ref[...] = rh
    rl_ref[...] = rl


def _sqrt22_kernel(ah_ref, al_ref, rh_ref, rl_ref):
    rh, rl = eft.sqrt22(ah_ref[...], al_ref[...])
    rh_ref[...] = rh
    rl_ref[...] = rl


def _two_prod_kernel(a_ref, b_ref, x_ref, y_ref):
    x, y = eft.two_prod(a_ref[...], b_ref[...])
    x_ref[...] = x
    y_ref[...] = y


def _two_sum_kernel(a_ref, b_ref, s_ref, r_ref):
    s, r = eft.two_sum(a_ref[...], b_ref[...])
    s_ref[...] = s
    r_ref[...] = r


_KERNELS = {
    "add22": (_add22_kernel, 4),
    "mul22": (_mul22_kernel, 4),
    "div22": (_div22_kernel, 4),
    "sqrt22": (_sqrt22_kernel, 2),
    "two_prod": (_two_prod_kernel, 2),
    "two_sum": (_two_sum_kernel, 2),
}


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _to_2d(x: Array) -> Array:
    """Flatten to 2-D keeping the last axis (rank-0/1 become 1 x n)."""
    if x.ndim == 0:
        return x.reshape(1, 1)
    if x.ndim == 1:
        return x.reshape(1, -1)
    return x.reshape(-1, x.shape[-1])


def _pad_to(x: Array, br: int, bc: int) -> Array:
    r, c = x.shape
    pr, pc = (-r) % br, (-c) % bc
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


def pick_block(rows: int, cols: int,
               block: Tuple[int, int] = DEFAULT_BLOCK) -> Tuple[int, int]:
    """Clamp the requested block to the (padded) operand extent, rounding
    rows up to the 8-sublane multiple and cols to the 128-lane multiple —
    a (3, 130) operand gets an (8, 256) block, never a ragged (3, 130)
    one that TPU tiling cannot express."""
    br, bc = block
    br = min(_round_up(br, SUBLANE), _round_up(max(rows, 1), SUBLANE))
    bc = min(_round_up(bc, LANE), _round_up(max(cols, 1), LANE))
    return br, bc


def _spec_for(shape: Tuple[int, int], out_shape: Tuple[int, int],
              br: int, bc: int) -> pl.BlockSpec:
    """BlockSpec for an operand broadcast against ``out_shape``: broadcast
    dims use block extent 1 pinned at block 0 (the plane is never tiled —
    nor materialized — along a dim it broadcasts over)."""
    r, c = shape
    R, C = out_shape
    row_bcast = r == 1 and R != 1
    col_bcast = c == 1 and C != 1
    b = (1 if row_bcast else br, 1 if col_bcast else bc)
    if row_bcast and col_bcast:
        return pl.BlockSpec(b, lambda i, j: (0, 0))
    if row_bcast:
        return pl.BlockSpec(b, lambda i, j: (0, j))
    if col_bcast:
        return pl.BlockSpec(b, lambda i, j: (i, 0))
    return pl.BlockSpec(b, lambda i, j: (i, j))


def broadcast_planes(arrays: Sequence[Array]
                     ) -> Tuple[Tuple[Array, ...], Tuple[int, ...]]:
    """Flatten operands to 2-D against their common broadcast shape.

    Scalar / row / column operands keep their degenerate extent (the
    BlockSpec handles them); anything with a non-degenerate partial shape
    (a genuine rank mismatch like (4, 1, 8) vs (4, 3, 8)) is materialized
    with ``broadcast_to`` first — correctness over cleverness.
    """
    out_shape = jnp.broadcast_shapes(*(a.shape for a in arrays))
    if len(out_shape) == 0:
        out2 = (1, 1)
    elif len(out_shape) == 1:
        out2 = (1, out_shape[0])
    else:
        r = 1
        for d in out_shape[:-1]:
            r *= d
        out2 = (r, out_shape[-1])
    planes = []
    for a in arrays:
        a2 = _to_2d(a)
        # shapes right-align under broadcasting, so the flattened form is
        # usable iff each flat dim is the output's or a degenerate 1; a
        # partial leading-dim broadcast (e.g. (3,8) against (4,3,8) ->
        # rows 3 vs 12) falls through to materialization
        if a2.shape[0] not in (1, out2[0]) or a2.shape[1] not in (1, out2[1]):
            a2 = _to_2d(jnp.broadcast_to(a, out_shape))
        planes.append(a2)
    return tuple(planes), out_shape


@functools.partial(jax.jit, static_argnames=("op", "block", "interpret"))
def elementwise(op: str, *arrays: Array,
                block: Tuple[int, int] = DEFAULT_BLOCK,
                interpret: bool = False) -> Tuple[Array, Array]:
    """Run a 2-output elementwise FF kernel over broadcastable operands.

    Operands are flattened to 2-D against the broadcast output shape,
    padded to (8, 128)-aligned block multiples, tiled over a 2-D grid, and
    the outputs un-padded/reshaped back.  Scalar/row/column operands stay
    un-materialized (their BlockSpec pins the broadcast dim).
    """
    kernel, n_in = _KERNELS[op]
    assert len(arrays) == n_in, (op, len(arrays))
    arrays = tuple(jnp.asarray(a, jnp.float32) for a in arrays)
    planes, orig_shape = broadcast_planes(arrays)
    R = max(p.shape[0] for p in planes)
    C = max(p.shape[1] for p in planes)
    br, bc = pick_block(R, C, block)
    # a plane is broadcast along a dim only when it is degenerate AND the
    # output is not (an R==1 output's operands are "full": pad them so the
    # block write shape matches the out block)
    padded = [_pad_to(p, br if (p.shape[0] == R or R == 1) else 1,
                      bc if (p.shape[1] == C or C == 1) else 1)
              for p in planes]
    Rp, Cp = _round_up(R, br), _round_up(C, bc)
    grid = (Rp // br, Cp // bc)
    in_specs = [_spec_for(p.shape, (Rp, Cp), br, bc) for p in padded]
    out_spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    out_shape = jax.ShapeDtypeStruct((Rp, Cp), jnp.float32)
    rh, rl = pl.pallas_call(
        kernel,
        out_shape=(out_shape, out_shape),
        grid=grid,
        in_specs=in_specs,
        out_specs=(out_spec, out_spec),
        interpret=interpret,
    )(*padded)
    rh = rh[:R, :C].reshape(orig_shape)
    rl = rl[:R, :C].reshape(orig_shape)
    return rh, rl
