"""Pallas TPU kernels for elementwise FF operators (paper Add22/Mul22).

The paper streamed texels through fragment shaders; the TPU analogue is
streaming (8,128)-aligned VMEM tiles through the VPU.  Tiles are 2-D blocks
``(block_rows, block_cols)`` of a flattened-to-2D operand; the last dim is
kept a multiple of 128 (lane width) and rows a multiple of 8 (sublanes).

Layout note: FF tensors arrive as separate hi/lo arrays (a pytree of two
f32 planes — the GPU paper used two texture channels; two planes keep each
plane contiguous and MXU/VPU-friendly).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import eft

Array = jnp.ndarray

DEFAULT_BLOCK = (256, 512)  # 256*512*4B = 512 KiB/plane; 6 planes < 4 MiB VMEM


def _add22_kernel(ah_ref, al_ref, bh_ref, bl_ref, rh_ref, rl_ref):
    rh, rl = eft.add22(ah_ref[...], al_ref[...], bh_ref[...], bl_ref[...])
    rh_ref[...] = rh
    rl_ref[...] = rl


def _mul22_kernel(ah_ref, al_ref, bh_ref, bl_ref, rh_ref, rl_ref):
    rh, rl = eft.mul22(ah_ref[...], al_ref[...], bh_ref[...], bl_ref[...])
    rh_ref[...] = rh
    rl_ref[...] = rl


def _two_prod_kernel(a_ref, b_ref, x_ref, y_ref):
    x, y = eft.two_prod(a_ref[...], b_ref[...])
    x_ref[...] = x
    y_ref[...] = y


def _two_sum_kernel(a_ref, b_ref, s_ref, r_ref):
    s, r = eft.two_sum(a_ref[...], b_ref[...])
    s_ref[...] = s
    r_ref[...] = r


_KERNELS = {
    "add22": (_add22_kernel, 4),
    "mul22": (_mul22_kernel, 4),
    "two_prod": (_two_prod_kernel, 2),
    "two_sum": (_two_sum_kernel, 2),
}


def _to_2d(x: Array) -> Tuple[Array, Tuple[int, ...]]:
    shape = x.shape
    if x.ndim == 0:
        return x.reshape(1, 1), shape
    if x.ndim == 1:
        return x.reshape(1, -1), shape
    return x.reshape(-1, shape[-1]), shape


def _pad_to(x: Array, br: int, bc: int) -> Array:
    r, c = x.shape
    pr, pc = (-r) % br, (-c) % bc
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


@functools.partial(jax.jit, static_argnames=("op", "block", "interpret"))
def elementwise(op: str, *arrays: Array,
                block: Tuple[int, int] = DEFAULT_BLOCK,
                interpret: bool = False) -> Tuple[Array, Array]:
    """Run a 2-output elementwise FF kernel over arbitrarily shaped operands.

    Operands are flattened to 2-D, padded to block multiples, tiled over a
    2-D grid, and the outputs un-padded/reshaped back.
    """
    kernel, n_in = _KERNELS[op]
    assert len(arrays) == n_in, (op, len(arrays))
    arrays = tuple(jnp.asarray(a, jnp.float32) for a in arrays)
    a2, orig_shape = _to_2d(arrays[0])
    rest = [_to_2d(a)[0] for a in arrays[1:]]
    br, bc = block
    br = min(br, max(8, a2.shape[0]))
    bc = min(bc, max(128, a2.shape[1]))
    padded = [_pad_to(x, br, bc) for x in (a2, *rest)]
    R, C = padded[0].shape
    grid = (R // br, C // bc)
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    out_shape = jax.ShapeDtypeStruct((R, C), jnp.float32)
    rh, rl = pl.pallas_call(
        kernel,
        out_shape=(out_shape, out_shape),
        grid=grid,
        in_specs=[spec] * n_in,
        out_specs=(spec, spec),
        interpret=interpret,
    )(*padded)
    r, c = a2.shape
    rh = rh[:r, :c].reshape(orig_shape)
    rl = rl[:r, :c].reshape(orig_shape)
    return rh, rl
