"""Compensated reductions built from the paper's EFTs.

The paper's closing remark — "using float-float representation in compensated
algorithms has been shown to be more efficient in term of performance for
comparable accuracy" — is realized here: these are the reduction primitives
the rest of the framework (loss accumulation, norm statistics, softmax LSE,
grad-norm, error-feedback buffers) consumes.

All functions take f32 arrays and return f32 or FF; f64 never appears.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import transforms as T
from repro.core.ff import FF, add12, add22, add212, mul12

Array = jnp.ndarray
Axis = Union[None, int, Sequence[int]]


def _move_axis_front(x: Array, axis: Axis) -> Array:
    """Collapse the reduced axes to a single leading axis."""
    if axis is None:
        return x.reshape(-1)
    axes = (axis,) if isinstance(axis, int) else tuple(axis)
    axes = tuple(a % x.ndim for a in axes)
    keep = tuple(a for a in range(x.ndim) if a not in axes)
    xt = x.transpose(axes + keep)
    red = 1
    for a in axes:
        red *= x.shape[a]
    return xt.reshape((red,) + tuple(x.shape[a] for a in keep))


def kahan_sum(x: Array, axis: Axis = None) -> Array:
    """Kahan–Neumaier compensated sum, returned rounded to f32.

    ~2 ulp worst case independent of length — vs O(n) ulp for naive sums.
    """
    return ff_sum(x, axis=axis).to_f32()


def ff_sum(x: Array, axis: Axis = None) -> FF:
    """Sum of f32 array in FF via cascaded TwoSum (Neumaier cascade).

    Error: the result is as if computed in ~44-bit precision.  Implemented as
    a ``lax.scan`` over the reduced axis so the HLO stays O(1) in length.
    """
    x = jnp.asarray(x, jnp.float32)
    xf = _move_axis_front(x, axis)

    def body(carry, xi):
        s, c, cc = carry
        s2, e = T.two_sum(s, xi)
        c2, e2 = T.two_sum(c, e)        # compensate the compensation (Sum3)
        return (s2, c2, cc + e2), None

    z = jnp.zeros(xf.shape[1:], jnp.float32)
    (s, c, cc), _ = jax.lax.scan(body, (z, z, z), xf)
    rh, rl = T.fast_two_sum(s, c + cc)
    return FF(rh, rl)


def ff_sum_blocked(x: Array, axis: Axis = None, block: int = 128) -> FF:
    """Vector-friendly compensated sum: lane-parallel Neumaier over ``block``
    independent accumulators, then an exact cascade of the ``block`` partials.

    This is the TPU-native restructuring (VPU has 8x128 lanes; a pure scalar
    cascade wastes them).  Accuracy: partials are each ~2-ulp; the final
    cascade is exact, so the bound matches ``ff_sum`` up to a factor ~2.
    """
    x = jnp.asarray(x, jnp.float32)
    xf = _move_axis_front(x, axis)
    n = xf.shape[0]
    pad = (-n) % block
    if pad:
        xf = jnp.concatenate([xf, jnp.zeros((pad,) + xf.shape[1:], jnp.float32)], 0)
    xb = xf.reshape(-1, block, *xf.shape[1:])  # (n//block, block, ...)

    def body(carry, xi):
        s, c, cc = carry
        s2, e = T.two_sum(s, xi)
        c2, e2 = T.two_sum(c, e)
        return (s2, c2, cc + e2), None

    z = jnp.zeros(xb.shape[1:], jnp.float32)
    (s, c, cc), _ = jax.lax.scan(body, (z, z, z), xb)  # lane accumulators
    c = c + cc

    # exact cascade over the `block` lane-partials
    def body2(carry, pair):
        acc = carry
        acc = add22(acc, FF(pair[0], pair[1]))
        return acc, None

    pairs = jnp.stack([s, c], axis=1)  # (block, 2, ...)
    acc0 = FF.zeros(s.shape[1:])
    acc, _ = jax.lax.scan(body2, acc0, pairs)
    return acc


def ff_dot(a: Array, b: Array, axis: Axis = None) -> FF:
    """Compensated dot product (Ogita-Rump-Oishi Dot2 with FF carry).

    Each elementwise product is made exact with Mul12, then accumulated with
    TwoSum cascades — result accurate to ~2^-44 relative.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    af = _move_axis_front(a, axis)
    bf = _move_axis_front(b, axis)

    def body(carry, ab):
        s, c, cc = carry
        ai, bi = ab
        p, pe = T.two_prod(ai, bi)
        s2, se = T.two_sum(s, p)
        c2, ce = T.two_sum(c, se + pe)   # Dot3-quality cascade
        return (s2, c2, cc + ce), None

    z = jnp.zeros(af.shape[1:], jnp.float32)
    (s, c, cc), _ = jax.lax.scan(body, (z, z, z), (af, bf))
    rh, rl = T.fast_two_sum(s, c + cc)
    return FF(rh, rl)


def ff_mean(x: Array, axis: Axis = None) -> FF:
    x = jnp.asarray(x, jnp.float32)
    if axis is None:
        n = x.size
    else:
        axes = (axis,) if isinstance(axis, int) else tuple(axis)
        n = 1
        for a in axes:
            n *= x.shape[a]
    s = ff_sum(x, axis=axis)
    from repro.core.ff import mul212

    return mul212(s, jnp.float32(1.0 / n))


def ff_logsumexp(x: Array, axis: int = -1) -> Tuple[Array, FF]:
    """log-sum-exp with compensated accumulation of the exp-sum.

    Returns (max, FF(sum of exp(x - max))).  The log itself stays f32 (its
    conditioning is fine once the sum is accurate).
    """
    x = jnp.asarray(x, jnp.float32)
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    s = ff_sum_blocked(e, axis=axis, block=256)   # lane-parallel cascade
    return jnp.squeeze(m, axis=axis), s


def kahan_update(acc: FF, delta: Array) -> FF:
    """Streaming compensated accumulate: acc += delta (f32), FF carry.

    Used by the trainer for running loss and by error-feedback compression.
    """
    return add212(acc, jnp.asarray(delta, jnp.float32))
