"""Float-float elementary functions — the ``ff.math`` algorithm core.

The source paper ships float-float *arithmetic*; its companion study
(Daumas, Da Graça & Defour, "Caractéristiques arithmétiques des
processeurs graphiques") measures the other half of the story: GPU
*built-in* elementary functions are far less accurate than the emulated
arithmetic.  The same split exists in this port — every ``jnp.exp`` /
``jnp.tanh`` is a ~2^-24-accurate builtin, capping any FF pipeline that
calls one.  This module closes the gap with classic libm construction on
top of the paper's own operators:

  * **argument reduction** with error-free steps (Cody–Waite ``ln2``
    splitting whose high pieces multiply *exactly* against the reduction
    integer, TwoSum folds for the tails);
  * **compensated polynomial kernels**: FF Horner (Mul22/Add22) for the
    leading coefficients, a plain-f32 Horner tail exactly where the terms
    are provably below the FF noise floor (each crossover is justified in
    ``docs/DESIGN_math.md``);
  * **branch-free selection** (``where`` over both evaluated branches) and
    saturation at the f32 range edges, matching the paper's stream-friendly
    no-branches design rule.

Every algorithm is written ONCE over raw ``(hi, lo)`` limb pairs and
parameterized by an EFT-primitive namespace ``E``:

  * :data:`CORE` (default) — the barrier-carrying ``repro.core`` EFTs,
    safe under XLA:CPU FMA contraction; used by the ``jnp`` dispatch
    implementations and the fusion tracer's jnp executor.
  * ``repro.kernels.eft`` — the barrier-free twin for Pallas kernel
    bodies (``repro.kernels.ff_math``, the fused-pipeline executor).

Both namespaces execute the identical arithmetic, so the two executors
produce bitwise-identical results wherever the EFT-safe ISA contract
holds (the same invariant the fused elementwise chains already pin).

Accuracy (details and budgets in ``docs/DESIGN_math.md``, contracts
doctested in ``docs/NUMERICS.md``): each function meets <= 2 ulp of FF
(~2^-43 relative) on its reduced domain; reconstruction amplification
outside it is documented per function (e.g. ``expm1`` near the k = +-1
bands, ``pow`` growing with ``|b*ln a|``).

f64 never appears here (the point is no wide hardware type); the
native-f64 *dispatch* implementations live in ``repro.ff.dispatch`` as a
separate accuracy-tier escape on hardware that has f64 units.
"""

from __future__ import annotations

import math
from typing import List, NamedTuple, Tuple

import jax.numpy as jnp
from jax import lax

from repro.core import ff as core_ff
from repro.core import transforms as T
from repro.core.ff import FF

Array = jnp.ndarray
Limb = Tuple[Array, Array]

F32 = jnp.float32


class _CorePrims:
    """Raw-limb adapter over the barrier-carrying ``repro.core`` EFTs.

    Mirrors the ``repro.kernels.eft`` signatures exactly so the generic
    algorithms below can take either namespace.  Delegates to the
    ``core_ff`` algorithms (one source of truth for the sequences)."""

    two_sum = staticmethod(T.two_sum)
    fast_two_sum = staticmethod(T.fast_two_sum)
    two_prod = staticmethod(T.two_prod)

    @staticmethod
    def add22(ah, al, bh, bl):
        r = core_ff.add22(FF(ah, al), FF(bh, bl))
        return r.hi, r.lo

    @staticmethod
    def mul22(ah, al, bh, bl):
        r = core_ff.mul22(FF(ah, al), FF(bh, bl))
        return r.hi, r.lo

    @staticmethod
    def add212(ah, al, b):
        r = core_ff.add212(FF(ah, al), b)
        return r.hi, r.lo

    @staticmethod
    def mul212(ah, al, b):
        r = core_ff.mul212(FF(ah, al), b)
        return r.hi, r.lo

    @staticmethod
    def div22(ah, al, bh, bl):
        r = core_ff.div22(FF(ah, al), FF(bh, bl))
        return r.hi, r.lo


CORE = _CorePrims

# ---------------------------------------------------------------------------
# constants (derived offline from 120-bit mpmath; see docs/DESIGN_math.md)
# ---------------------------------------------------------------------------

# Cody–Waite split of ln2: L1/L2 carry <= 16 significand bits each, so
# k*L1 and k*L2 are EXACT f32 products for |k| <= 2^8 (the reduction
# integer k never exceeds ~160 after input clipping); L3 is the f32
# residual (|k*L3| <= 2^-28, one negligible rounding).
_EXP_L1 = 0.693145751953125          # 45426 * 2^-16
_EXP_L2 = 1.4286197256296873e-06     # 49087 * 2^-35
_EXP_L3 = -1.290532e-11
_INV_LN2 = 1.4426950408889634

# ln2 as an FF constant (for the log reconstruction e*ln2)
_LN2_H, _LN2_L = 0.6931471824645996, -1.9046542121259336e-09

_TWO_OVER_SQRTPI = (1.1283792, -5.8635383e-08)
_INV_SQRT2 = (0.70710677, 1.21016175e-08)

# exp kernel: exp(r) = 1 + r + r^2 * W(r), W(r) = sum_j r^j / (j+2)!.
# FF coefficients for j = 0..5; f32 Horner tail for j = 6..11 (tail terms
# contribute < 2^-46 relative — below the FF noise floor).
_EXP_W_FF = (
    (0.5, 0.0),
    (0.16666667, -4.967054e-09),
    (0.041666668, -1.2417635e-09),
    (0.008333334, -4.346172e-10),
    (0.0013888889, -3.3631094e-11),
    (0.0001984127, -2.7255969e-12),
)
_EXP_W_F32 = (2.4801588e-05, 2.7557319e-06, 2.755732e-07,
              2.5052108e-08, 2.0876756e-09, 1.6059044e-10)

# atanh kernel: log(m) = 2 s S(s^2), s = (m-1)/(m+1), m in [1/sqrt2, sqrt2):
# S(z) = sum_n z^n / (2n+1).  FF for n = 0..3, f32 tail n = 4..9
# (z <= 0.0295, so the n >= 4 terms sit below 2^-45 relative).
_LOG_S_FF = (
    (1.0, 0.0),
    (0.33333334, -9.934108e-09),
    (0.2, -2.9802323e-09),
    (0.14285715, -6.386212e-09),
)
_LOG_S_F32 = (0.11111111, 0.09090909, 0.07692308,
              0.06666667, 0.05882353, 0.05263158)

# tanh Maclaurin (odd series, coefficients of x^(2n+1)) for |x| <= 0.35:
# FF for n = 0..5, f32 tail n = 6..11 (truncation 2^-52 at the boundary).
_TANH_C_FF = (
    (1.0, 0.0),
    (-0.33333334, 9.934108e-09),
    (0.13333334, -6.9538753e-09),
    (-0.053968254, 5.085317e-10),
    (0.021869488, 4.7568083e-10),
    (-0.008863236, 2.939079e-10),
)
_TANH_C_F32 = (0.003592128, -0.0014558344, 0.0005900274,
               -0.00023912912, 9.691538e-05, -3.9278322e-05)

# sqrt(pi) as an FF constant (asymptotic-erfc denominator)
_SQRTPI = (1.7724539, -5.32464e-08)

# asymptotic erfc series A(w) = sum_k (-1)^k (2k-1)!! w^k, w = 1/(2x^2),
# truncated at k = 12 (first omitted term < 2^-22 at x = 4, far below the
# band's needed accuracy — see DESIGN_math.md); f32 Horner suffices there.
_ERFC_ASY = (1.0, -1.0, 3.0, -15.0, 105.0, -945.0, 10395.0, -135135.0,
             2027025.0, -34459425.0, 654729075.0, -13749310575.0,
             316234143225.0)

# domain edges
_EXP_CLIP_LO, _EXP_CLIP_HI = -105.0, 89.0   # beyond: saturated anyway
_TANH_SMALL = 0.35                          # Maclaurin branch bound
_ERF_SMALL = 1.0                            # alternating-series bound
_ERF_MID = 4.0                              # positive-series / asymptotic seam
_ERF_ALT_TERMS = 17                         # n = 1..16 after the n=0 seed
_ERF_POS_TERMS = 60                         # n = 1..59 after the n=0 seed


def _exp2i(k: Array) -> Array:
    """Exact 2^k for int32 k in [-126, 127], built from exponent bits
    (``jnp.exp2`` is polynomial-approximated on XLA:CPU — inexact at 221
    of 254 integer exponents under the EFT-safe ISA; see PR 2's ldexp
    repair of the Ozaki slice grid)."""
    return lax.bitcast_convert_type(
        ((k + jnp.int32(127)) << jnp.int32(23)).astype(jnp.int32),
        jnp.float32)


def _scale2k(h: Array, l: Array, k: Array) -> Limb:
    """(h, l) * 2^k for int32 k in [-252, 254], exact via two half-steps
    (each half exponent stays in the normal range)."""
    k1 = k >> 1
    k2 = k - k1
    s1, s2 = _exp2i(k1), _exp2i(k2)
    return (h * s1) * s2, (l * s1) * s2


# ---------------------------------------------------------------------------
# exp / expm1
# ---------------------------------------------------------------------------

def _exp_reduce(xh: Array, xl: Array, E) -> Tuple[Array, Array, Array]:
    """Cody–Waite reduction x = k*ln2 + r with r an FF pair, |r| <= ln2/2.

    k*L1 and k*L2 are exact f32 products (16-bit pieces, |k| <= 160 after
    clipping) and ``xc - k*L1`` is exact by the classic Cody–Waite grid
    argument, so the only reduction errors are one rounding of the
    negligible ``k*L3`` fold and the Add212 renormalization (~2^-45.5
    absolute).  Returns (rh, rl, k_int32)."""
    xc = jnp.clip(xh, F32(_EXP_CLIP_LO), F32(_EXP_CLIP_HI))
    kf = jnp.round(xc * F32(_INV_LN2))
    h1 = xc - kf * F32(_EXP_L1)                   # exact
    sh, sl = E.two_sum(h1, -(kf * F32(_EXP_L2)))  # k*L2 exact; TwoSum exact
    v = xl - kf * F32(_EXP_L3)                    # both ~2^-28: one rounding
    rh, rl = E.add212(sh, sl, v)
    return rh, rl, kf.astype(jnp.int32)


def _exp_poly(rh: Array, rl: Array, E) -> Limb:
    """expm1(r) = r + r^2 W(r) on |r| <= ln2/2 as an FF pair.

    W runs a plain-f32 Horner for degrees 11..6 (terms < 2^-46 of the
    result) and an FF Horner for degrees 5..0; the r^2 W term is <= 0.087,
    so W's own error budget relaxes by that factor (DESIGN_math.md)."""
    t = F32(_EXP_W_F32[-1])
    for c in _EXP_W_F32[-2::-1]:
        t = t * rh + F32(c)
    wh, wl = t, jnp.zeros_like(t)
    for ch, cl in _EXP_W_FF[::-1]:
        wh, wl = E.mul22(wh, wl, rh, rl)
        wh, wl = E.add22(wh, wl, jnp.broadcast_to(F32(ch), rh.shape),
                         jnp.broadcast_to(F32(cl), rh.shape))
    zh, zl = E.mul22(rh, rl, rh, rl)              # r^2
    qh, ql = E.mul22(zh, zl, wh, wl)              # r^2 W
    return E.add22(rh, rl, qh, ql)                # r + r^2 W


def exp22(xh: Array, xl: Array, E=CORE) -> Limb:
    """FF exp of an FF input (raw limbs).  <= 2 ulp_FF on the reduced
    domain; saturates to inf above ~88.72 and to 0 below ~-103 (f32
    range; the lo limb flushes first near the subnormal edge)."""
    rh, rl, k = _exp_reduce(xh, xl, E)
    sh, sl = _exp_poly(rh, rl, E)
    ph, pl = E.add212(sh, sl, F32(1.0))           # 1 + expm1(r)
    eh, el = _scale2k(ph, pl, k)
    inf = F32(jnp.inf)
    big = xh > F32(_EXP_CLIP_HI)
    tiny = xh < F32(_EXP_CLIP_LO)
    eh = jnp.where(big, inf, jnp.where(tiny, F32(0.0), eh))
    # natural hi-limb overflow (x in (~88.72, CLIP_HI]): zero the lo limb
    # so the saturated FF is a clean (inf, 0), not (inf, garbage)
    el = jnp.where(big | tiny | (eh == inf), F32(0.0), el)
    nan = xh != xh
    return jnp.where(nan, xh, eh), jnp.where(nan, xh, el)


def expm122(xh: Array, xl: Array, E=CORE) -> Limb:
    """FF expm1: full relative accuracy on |x| <= ln2/2 (the k = 0 branch
    is the exp kernel *without* the +1), exp(x) - 1 with the documented
    k = +-1 cancellation amplification (~x5) beyond."""
    rh, rl, k = _exp_reduce(xh, xl, E)
    sh, sl = _exp_poly(rh, rl, E)                 # expm1(r): the k=0 answer
    ph, pl = E.add212(sh, sl, F32(1.0))
    eh, el = _scale2k(ph, pl, k)
    gh, gl = E.add212(eh, el, F32(-1.0))          # exp(x) - 1, k != 0
    # exp's hi limb overflows naturally just below the clip bound
    # (x in (~88.72, CLIP_HI]): inf - 1 trips TwoSum nans — saturate
    ovf = eh == F32(jnp.inf)
    gh = jnp.where(ovf, eh, gh)
    gl = jnp.where(ovf, F32(0.0), gl)
    small = k == 0
    oh = jnp.where(small, sh, gh)
    ol = jnp.where(small, sl, gl)
    # |x| < 2^-45: expm1(x) == x at FF precision (x^2/2 < 2^-46 |x|), and
    # the identity keeps signed zero (the EFT renormalization's -0 + 0
    # rounds to +0) and sidesteps the sub-2^-100 TwoProd underflow domain
    idt = jnp.abs(xh) < F32(2.0**-45)
    oh = jnp.where(idt, xh, oh)
    ol = jnp.where(idt, xl, ol)
    inf = F32(jnp.inf)
    big = xh > F32(_EXP_CLIP_HI)
    tiny = xh < F32(_EXP_CLIP_LO)
    oh = jnp.where(big, inf, jnp.where(tiny, F32(-1.0), oh))
    ol = jnp.where(big | tiny, F32(0.0), ol)
    nan = xh != xh
    return jnp.where(nan, xh, oh), jnp.where(nan, xh, ol)


# ---------------------------------------------------------------------------
# log / log1p
# ---------------------------------------------------------------------------

def _atanh_poly(sh: Array, sl: Array, E) -> Limb:
    """S(z) = sum z^n/(2n+1) at z = s^2 <= 0.0295 (FF Horner n=3..0 over
    an f32 tail n=9..4)."""
    zh, zl = E.mul22(sh, sl, sh, sl)
    t = F32(_LOG_S_F32[-1])
    for c in _LOG_S_F32[-2::-1]:
        t = t * zh + F32(c)
    ah, al = t, jnp.zeros_like(t)
    for ch, cl in _LOG_S_FF[::-1]:
        ah, al = E.mul22(ah, al, zh, zl)
        ah, al = E.add22(ah, al, jnp.broadcast_to(F32(ch), sh.shape),
                         jnp.broadcast_to(F32(cl), sh.shape))
    return ah, al


def _log_core(mh: Array, ml: Array, ef: Array, E) -> Limb:
    """log(2^e * m) = e*ln2 + 2 s S(s^2), s = (m-1)/(m+1), for m already
    reduced to [1/sqrt2, sqrt2) — no cancellation between the two terms
    by construction of the symmetric mantissa range."""
    nh, nl = E.add212(mh, ml, F32(-1.0))
    dh, dl = E.add212(mh, ml, F32(1.0))
    sh, sl = E.div22(nh, nl, dh, dl)
    ph, pl = _atanh_poly(sh, sl, E)
    lh, ll = E.mul22(sh, sl, ph, pl)
    lh, ll = F32(2.0) * lh, F32(2.0) * ll         # exact
    th, tl = E.mul212(jnp.broadcast_to(F32(_LN2_H), ef.shape),
                      jnp.broadcast_to(F32(_LN2_L), ef.shape), ef)
    return E.add22(th, tl, lh, ll)


def _frexp_sqrt2(xh: Array, xl: Array):
    """Branch-free frexp variant: x = 2^e * m with m in [1/sqrt2, sqrt2).
    Exact: exponent/mantissa bit surgery on hi, exact 2^-e scaling of lo."""
    bits = lax.bitcast_convert_type(xh, jnp.int32)
    e = ((bits >> jnp.int32(23)) & jnp.int32(0xFF)) - jnp.int32(127)
    mh = lax.bitcast_convert_type(
        (bits & jnp.int32(0x007FFFFF)) | jnp.int32(0x3F800000), jnp.float32)
    big = mh > F32(1.4142135)
    mh = jnp.where(big, mh * F32(0.5), mh)
    e = e + big.astype(jnp.int32)
    ml, _zero = _scale2k(xl, jnp.zeros_like(xl), -e)
    return mh, ml, e


def log22(xh: Array, xl: Array, E=CORE) -> Limb:
    """FF natural log of an FF input.  <= 2 ulp_FF on the reduced domain
    (e = 0); nan for x < 0, -inf at x == 0."""
    mh, ml, e = _frexp_sqrt2(xh, xl)
    rh, rl = _log_core(mh, ml, e.astype(jnp.float32), E)
    neg_inf, inf, nan = F32(-jnp.inf), F32(jnp.inf), F32(jnp.nan)
    bad = (xh < 0) | (xh != xh)
    rh = jnp.where(xh == 0, neg_inf, jnp.where(bad, nan, rh))
    rh = jnp.where(xh == inf, inf, rh)
    rl = jnp.where((xh == 0) | bad | (xh == inf), F32(0.0), rl)
    return rh, rl


def log1p22(xh: Array, xl: Array, E=CORE) -> Limb:
    """FF log1p.  The near branch (1+x in the reduced mantissa range,
    x in [-0.2929, 0.4142]) evaluates 2 atanh(x/(2+x)) directly from x —
    full relative accuracy down to the last FF bit even for tiny x (never
    forming 1+x, whose FF representation would floor the error at
    2^-49/|x|); the far branch folds x into an exact TwoSum with 1 and
    takes the regular log."""
    # near: s = x / (2 + x), |s| <= 0.1716 — same kernel as log
    dh, dl = E.add212(xh, xl, F32(2.0))
    sh, sl = E.div22(xh, xl, dh, dl)
    ph, pl = _atanh_poly(sh, sl, E)
    nh, nl = E.mul22(sh, sl, ph, pl)
    nh, nl = F32(2.0) * nh, F32(2.0) * nl
    # far: w = 1 + x exactly (TwoSum + lo fold), then log.  The traced
    # operand goes FIRST: XLA's algebraic simplifier folds the residual of
    # two_sum(<literal>, x) to zero ((1 + x) - 1 -> x — the paper's §5
    # compiler hazard resurfacing through constant folding), while the
    # (x, <literal>) orientation survives; pinned by tests/test_ff_math.py.
    wh, we = E.two_sum(xh, jnp.ones_like(xh))
    wl = we + xl
    wh, wl = E.fast_two_sum(wh, wl)
    fh, fl = log22(wh, wl, E)
    near = (xh >= F32(-0.2928932)) & (xh <= F32(0.41421354))
    rh = jnp.where(near, nh, fh)
    rl = jnp.where(near, nl, fl)
    # identity band: log1p(x) == x at FF precision below 2^-45; also keeps
    # signed zero and the sub-2^-100 EFT underflow domain exact
    idt = jnp.abs(xh) < F32(2.0**-45)
    rh = jnp.where(idt, xh, rh)
    rl = jnp.where(idt, xl, rl)
    inf = xh == F32(jnp.inf)                      # 1 + inf trips TwoSum nans
    rh = jnp.where(inf, F32(jnp.inf), rh)
    rl = jnp.where(inf, F32(0.0), rl)
    nan = xh != xh
    return jnp.where(nan, xh, rh), jnp.where(nan, xh, rl)


# ---------------------------------------------------------------------------
# tanh / sigmoid
# ---------------------------------------------------------------------------

def tanh22(xh: Array, xl: Array, E=CORE) -> Limb:
    """FF tanh: odd Maclaurin kernel on |x| <= 0.35 (<= 2 ulp_FF), the
    bounded rational expm1 form tanh = -t/(2+t), t = expm1(-2|x|)
    beyond (saturating smoothly: t -> -1 => tanh -> +-1 exactly at FF
    resolution for |x| >~ 17)."""
    # small: x * P(x^2), FF Horner over the f32 tail
    zh, zl = E.mul22(xh, xl, xh, xl)
    t = F32(_TANH_C_F32[-1])
    for c in _TANH_C_F32[-2::-1]:
        t = t * zh + F32(c)
    ph, pl = t, jnp.zeros_like(t)
    for ch, cl in _TANH_C_FF[::-1]:
        ph, pl = E.mul22(ph, pl, zh, zl)
        ph, pl = E.add22(ph, pl, jnp.broadcast_to(F32(ch), xh.shape),
                         jnp.broadcast_to(F32(cl), xh.shape))
    smh, sml = E.mul22(xh, xl, ph, pl)
    # large: -t/(2+t) on |x|, sign restored (negation is exact)
    sgn = jnp.where(xh < 0, F32(-1.0), F32(1.0))
    yh, yl = F32(-2.0) * sgn * xh, F32(-2.0) * sgn * xl
    th, tl = expm122(yh, yl, E)
    dh, dl = E.add212(th, tl, F32(2.0))
    qh, ql = E.div22(-th, -tl, dh, dl)
    lgh, lgl = sgn * qh, sgn * ql
    small = jnp.abs(xh) <= F32(_TANH_SMALL)
    rh = jnp.where(small, smh, lgh)
    rl = jnp.where(small, sml, lgl)
    # identity band (tanh(x) == x below 2^-45: x^3/3 < 2^-90); keeps
    # signed zero and the sub-2^-100 EFT underflow domain exact
    idt = jnp.abs(xh) < F32(2.0**-45)
    return jnp.where(idt, xh, rh), jnp.where(idt, xl, rl)


def sigmoid22(xh: Array, xl: Array, E=CORE) -> Limb:
    """FF logistic sigmoid via the cancellation-free two-sided form
    sigma(x) = u/(1 + z), z = exp(-|x|), u = 1 for x >= 0 else z."""
    sgn = jnp.where(xh < 0, F32(-1.0), F32(1.0))
    zh, zl = exp22(-sgn * xh, -sgn * xl, E)
    dh, dl = E.add212(zh, zl, F32(1.0))
    pos = xh >= 0
    nh = jnp.where(pos, jnp.ones_like(zh), zh)
    nl = jnp.where(pos, jnp.zeros_like(zl), zl)
    rh, rl = E.div22(nh, nl, dh, dl)
    nan = xh != xh
    return jnp.where(nan, xh, rh), jnp.where(nan, xh, rl)


# ---------------------------------------------------------------------------
# erf / gelu / silu
# ---------------------------------------------------------------------------

def _erf_small(xh: Array, xl: Array, E) -> Limb:
    """Alternating Maclaurin sum for |x| <= 1: erf = (2/sqrt pi) x
    sum_n (-1)^n (x^2)^n / (n! (2n+1)).  Mild cancellation (amplification
    <= 1.5 at the boundary); every term update is FF (Mul22 + exact-
    integer Div22), so the sum holds ~2^-43."""
    zh, zl = E.mul22(xh, xl, xh, xl)
    one = jnp.ones_like(xh)
    zero = jnp.zeros_like(xh)

    def body(n, carry):
        uh, ul, ah, al = carry
        nf = n.astype(jnp.float32)
        uh, ul = E.mul22(uh, ul, zh, zl)
        uh, ul = E.div22(uh, ul, nf * one, zero)            # u = z^n / n!
        th, tl = E.div22(uh, ul, (F32(2.0) * nf + F32(1.0)) * one, zero)
        s = jnp.where(n % 2 == 1, F32(-1.0), F32(1.0))
        ah, al = E.add22(ah, al, s * th, s * tl)
        return uh, ul, ah, al

    _, _, ah, al = lax.fori_loop(1, _ERF_ALT_TERMS, body,
                                 (one, zero, one, zero))
    sh, sl = E.mul22(xh, xl, ah, al)
    return E.mul22(sh, sl, jnp.broadcast_to(F32(_TWO_OVER_SQRTPI[0]),
                                            xh.shape),
                   jnp.broadcast_to(F32(_TWO_OVER_SQRTPI[1]), xh.shape))


def _erf_mid(axh: Array, axl: Array, E) -> Limb:
    """Positive (Kummer) series for 1 < x <= 4: erf = (2x/sqrt pi)
    e^{-x^2} sum_n (2x^2)^n / (2n+1)!!.  All terms positive — no
    cancellation — so the FF sum holds ~2^-43 relative; 60 terms carry
    the slow post-peak geometric decay (ratio 2x^2/(2n+3)) below 2^-45
    at the x = 4 seam.  The e^{-x^2} factor reuses the FF exp with x^2
    carried as an FF product."""
    zh, zl = E.mul22(axh, axl, axh, axl)          # x^2
    vh, vl = F32(2.0) * zh, F32(2.0) * zl         # 2 x^2 (exact)
    one = jnp.ones_like(axh)
    zero = jnp.zeros_like(axh)

    def body(n, carry):
        th, tl, ah, al = carry
        nf = n.astype(jnp.float32)
        th, tl = E.mul22(th, tl, vh, vl)
        th, tl = E.div22(th, tl, (F32(2.0) * nf + F32(1.0)) * one, zero)
        ah, al = E.add22(ah, al, th, tl)
        return th, tl, ah, al

    _, _, ah, al = lax.fori_loop(1, _ERF_POS_TERMS, body,
                                 (one, zero, one, zero))
    eh, el = exp22(-zh, -zl, E)
    gh, gl = E.mul22(axh, axl, eh, el)
    gh, gl = E.mul22(gh, gl, ah, al)
    return E.mul22(gh, gl, jnp.broadcast_to(F32(_TWO_OVER_SQRTPI[0]),
                                            axh.shape),
                   jnp.broadcast_to(F32(_TWO_OVER_SQRTPI[1]), axh.shape))


def _erf_big(axh: Array, axl: Array, E) -> Limb:
    """Asymptotic band x > 4: erf = 1 - erfc, erfc = e^{-x^2} A(w) /
    (x sqrt pi), w = 1/(2x^2).  erf is within 2^-48 of 1 here, so erfc
    only needs relative accuracy 2^-43/erfc(x) — an f32 Horner over the
    13-term divergent-series prefix clears that with >2^4 margin at the
    seam and exponentially more beyond; e^{-x^2} underflowing to 0 IS the
    saturation branch (erf -> exactly 1)."""
    zh, zl = E.mul22(axh, axl, axh, axl)          # x^2
    w = F32(0.5) / zh                             # f32 precision suffices
    a = F32(_ERFC_ASY[-1])
    for c in _ERFC_ASY[-2::-1]:
        a = a * w + F32(c)
    eh, el = exp22(-zh, -zl, E)
    uh, ul = E.mul212(eh, el, a)
    dh, dl = E.mul22(axh, axl, jnp.broadcast_to(F32(_SQRTPI[0]), axh.shape),
                     jnp.broadcast_to(F32(_SQRTPI[1]), axh.shape))
    ch, cl = E.div22(uh, ul, dh, dl)              # erfc
    return E.add212(-ch, -cl, F32(1.0))           # 1 - erfc


def erf22(xh: Array, xl: Array, E=CORE) -> Limb:
    """FF error function.  <= 2 ulp_FF relative on |x| <= 1 (the series
    kernel domain); the positive-series band (1 < x <= 4) and the
    asymptotic-erfc band (x > 4) keep erf's 2^-43 contract through to
    exact +-1 saturation once e^{-x^2} underflows."""
    sgn = jnp.where(xh < 0, F32(-1.0), F32(1.0))
    axh, axl = sgn * xh, sgn * xl
    # clamp the tail bands at 30 (erf(30) == 1 at any FF precision): keeps
    # x^2 inside the Dekker-split overflow bound and turns +-inf into the
    # saturated value instead of split-generated nans
    big_in = axh > F32(30.0)
    axh = jnp.minimum(axh, F32(30.0))
    axl = jnp.where(big_in, F32(0.0), axl)
    smh, sml = _erf_small(xh, xl, E)              # odd series: sign built in
    mdh, mdl = _erf_mid(axh, axl, E)
    bgh, bgl = _erf_big(axh, axl, E)
    mid = axh <= F32(_ERF_MID)
    lgh = jnp.where(mid, mdh, bgh)
    lgl = jnp.where(mid, mdl, bgl)
    small = axh <= F32(_ERF_SMALL)
    rh = jnp.where(small, smh, sgn * lgh)
    rl = jnp.where(small, sml, sgn * lgl)
    zero = xh == 0                                # erf(+-0) = +-0 exactly
    rh = jnp.where(zero, xh, rh)
    rl = jnp.where(zero, F32(0.0), rl)
    nan = xh != xh
    return jnp.where(nan, xh, rh), jnp.where(nan, xh, rl)


def gelu22(xh: Array, xl: Array, E=CORE) -> Limb:
    """FF exact-form GELU: 0.5 x (1 + erf(x/sqrt2)).  Relative contract
    for x >= -1; absolute (2^-40-class) in the deep-negative tail where
    1 + erf cancels (an FF erfc kernel would be the upgrade path —
    documented in DESIGN_math.md)."""
    vh, vl = E.mul22(xh, xl, jnp.broadcast_to(F32(_INV_SQRT2[0]), xh.shape),
                     jnp.broadcast_to(F32(_INV_SQRT2[1]), xh.shape))
    eh, el = erf22(vh, vl, E)
    oh, ol = E.add212(eh, el, F32(1.0))
    rh, rl = E.mul22(xh, xl, oh, ol)
    rh, rl = F32(0.5) * rh, F32(0.5) * rl         # exact scale
    zero = xh == 0                                # gelu(+-0) = +-0 exactly
    rh = jnp.where(zero, xh, rh)
    rl = jnp.where(zero, F32(0.0), rl)
    # inf * (1 + erf) trips TwoProd nans at both rails; take the limits
    ninf, pinf = xh == F32(-jnp.inf), xh == F32(jnp.inf)
    rh = jnp.where(ninf, F32(0.0), jnp.where(pinf, F32(jnp.inf), rh))
    rl = jnp.where(ninf | pinf, F32(0.0), rl)
    return rh, rl


def silu22(xh: Array, xl: Array, E=CORE) -> Limb:
    """FF SiLU (swish): x * sigmoid(x).  Cancellation-free on both sides,
    so the relative contract holds on the full f32 range."""
    sh, sl = sigmoid22(xh, xl, E)
    rh, rl = E.mul22(xh, xl, sh, sl)
    zero = xh == 0                                # silu(+-0) = +-0 exactly
    rh = jnp.where(zero, xh, rh)
    rl = jnp.where(zero, F32(0.0), rl)
    ninf, pinf = xh == F32(-jnp.inf), xh == F32(jnp.inf)
    rh = jnp.where(ninf, F32(0.0), jnp.where(pinf, F32(jnp.inf), rh))
    rl = jnp.where(ninf | pinf, F32(0.0), rl)
    return rh, rl


# ---------------------------------------------------------------------------
# pow
# ---------------------------------------------------------------------------

def pow22(ah: Array, al: Array, bh: Array, bl: Array, E=CORE) -> Limb:
    """FF power a**b = exp(b * log a) for a > 0 (nan for a < 0 — no
    integer-exponent special-casing; a == 0 follows IEEE pow: 0**0 = 1,
    0**+b = 0, 0**-b = inf).  Error grows with the exponent magnitude:
    ~(1 + |b ln a|) * 2^-43 relative (the log's FF error is amplified
    |b ln a|-fold through exp — the standard double-word pow bound)."""
    lh, ll = log22(ah, al, E)
    th, tl = E.mul22(lh, ll, bh, bl)
    rh, rl = exp22(th, tl, E)
    # a == 0 / a == inf: the +-inf log trips TwoProd nans in the b fold —
    # select the IEEE limits explicitly (b == 0 -> 1 last: 0**0 == 1)
    inf, zero, one = F32(jnp.inf), F32(0.0), F32(1.0)
    for edge, blim in ((ah == 0, zero), (ah == inf, inf)):
        rh = jnp.where(edge & (bh > 0), blim, rh)
        rh = jnp.where(edge & (bh < 0), jnp.where(blim == 0, inf, zero), rh)
        rl = jnp.where(edge, zero, rl)
    b0 = bh == 0
    rh = jnp.where(b0, one, rh)
    rl = jnp.where(b0, zero, rl)
    return rh, rl


# ---------------------------------------------------------------------------
# FF-object convenience wrappers (the jnp dispatch impls and autodiff
# rules call these; kernels call the raw-limb forms with E=kernels.eft)
# ---------------------------------------------------------------------------

def _wrap1(fn):
    def call(a: FF) -> FF:
        return FF(*fn(a.hi, a.lo, CORE))
    return call


exp = _wrap1(exp22)
expm1 = _wrap1(expm122)
log = _wrap1(log22)
log1p = _wrap1(log1p22)
tanh = _wrap1(tanh22)
sigmoid = _wrap1(sigmoid22)
erf = _wrap1(erf22)
gelu = _wrap1(gelu22)
silu = _wrap1(silu22)


def pow(a: FF, b: FF) -> FF:  # noqa: A001 - mirrors jnp.pow
    return FF(*pow22(a.hi, a.lo, b.hi, b.lo, CORE))


UNARY22 = {
    "exp": exp22, "expm1": expm122, "log": log22, "log1p": log1p22,
    "tanh": tanh22, "sigmoid": sigmoid22, "erf": erf22, "gelu": gelu22,
    "silu": silu22,
}


# ---------------------------------------------------------------------------
# seam registry — every reduction-boundary input class, enumerated FROM
# the live constants above (verify.sweeps walks this; a constant edit
# moves the swept neighborhoods with it, there is no copy to go stale)
# ---------------------------------------------------------------------------

class SeamSpec(NamedTuple):
    """One seam input class of an ``ff.math`` reduction scheme.

    kind
      ``centers`` — bit-step exhaustive f32 neighborhoods around each
      listed value (the sweep budget is split across centers);
      ``window`` — a closed interval: edges bit-stepped exhaustively,
      interior log-covered with the remaining budget;
      ``points`` — an explicit, exact value set (specials).
    check
      ``contract`` — |rel err| <= bound vs the beyond-f64 oracle;
      ``identity`` — output limbs bitwise-equal the input limbs;
      ``special``  — oracle special handling (nan/inf/limit classes).
    """

    name: str
    fn: str          # UNARY22 key
    kind: str        # centers | window | points
    data: tuple
    bound: float     # relative bound for check == "contract"
    check: str
    note: str


def _exp_k_boundaries(half: bool) -> tuple:
    """x where round(x/ln2) changes (half) or r crosses zero (integer):
    the Cody–Waite k-grid of :func:`_exp_reduce`, from the live clip
    window so the center list tracks any retuning."""
    ln2 = _EXP_L1 + _EXP_L2  # the split's own value of ln2
    kmin = int(math.ceil(_EXP_CLIP_LO / ln2))
    kmax = int(math.floor(_EXP_CLIP_HI / ln2))
    off = 0.5 if half else 0.0
    return tuple((k + off) * ln2 for k in range(kmin, kmax + 1))


def _tanh_expm1_boundaries() -> tuple:
    """|x| where the large-branch expm1(-2|x|) reduction integer flips:
    |x| = (j - 0.5) ln2 / 2 up to deep saturation; both signs (odd)."""
    ln2 = _EXP_L1 + _EXP_L2
    xs = []
    j = 1
    while (j - 0.5) * ln2 / 2.0 < 19.0:           # past saturation ~17-18
        x = (j - 0.5) * ln2 / 2.0
        if x > _TANH_SMALL:                        # inside the large branch
            xs += [x, -x]
        j += 1
    return tuple(xs)


def reduction_seams() -> List[SeamSpec]:
    """The exhaustive-sweep registry for exp / log / tanh (the three
    hardest reduction schemes; the rest of ``UNARY22`` is covered by the
    sampled tier).  ``tests/test_verify_sweep.py`` asserts completeness
    of this list against the documented seam classes."""
    ln2 = _EXP_L1 + _EXP_L2
    lo_flush = math.log(2.0 ** -82)                # exp(x) < 2^-82: lo flushes
    subn_onset = math.log(2.0 ** -126)             # exp(x) goes subnormal
    total_flush = math.log(2.0 ** -149)            # exp(x) rounds to zero
    nat_ovf = math.log((2.0 - 2.0 ** -24) * 2.0 ** 127)   # hi-limb overflow
    seams: List[SeamSpec] = [
        # ---- exp -----------------------------------------------------
        SeamSpec("exp/cody_waite_half_k", "exp", "centers",
                 _exp_k_boundaries(half=True), 2.0 ** -42, "contract",
                 "round(x/ln2) flips: largest |r| and the k<->k+1 "
                 "reconstruction seam"),
        SeamSpec("exp/cody_waite_integer_k", "exp", "centers",
                 _exp_k_boundaries(half=False), 2.0 ** -42, "contract",
                 "r crosses zero: maximal cancellation in the reduction"),
        SeamSpec("exp/overflow_window", "exp", "window",
                 (88.5, float(_EXP_CLIP_HI) + 0.5), 2.0 ** -42, "contract",
                 f"natural hi-limb overflow at ~{nat_ovf:.4f} through the "
                 "clip edge: saturation must be a clean (inf, 0)"),
        SeamSpec("exp/underflow_window", "exp", "window",
                 (float(_EXP_CLIP_LO) - 0.5, subn_onset + 0.5),
                 2.0 ** -42, "contract",
                 f"subnormal onset {subn_onset:.4f}, total flush "
                 f"{total_flush:.4f}, clip edge {_EXP_CLIP_LO}"),
        SeamSpec("exp/lo_flush_band", "exp", "window",
                 (lo_flush - 0.5, lo_flush + 0.5), 2.0 ** -42, "contract",
                 "exp(x) < 2^-82: the lo limb itself flushes — bound "
                 "degrades to f32 (2^-23) there by the documented model"),
        SeamSpec("exp/tiny_arguments", "exp", "window",
                 (-(2.0 ** -40), 2.0 ** -40), 2.0 ** -42, "contract",
                 "k = 0, r = x: exp ~= 1 + x, poly tail below FF noise"),
        SeamSpec("exp/subnormal_arguments", "exp", "points",
                 (2.0 ** -130, -(2.0 ** -130), 2.0 ** -149, -(2.0 ** -149),
                  1e-40, -1e-40), 2.0 ** -42, "contract",
                 "subnormal x: exp(x) == 1 at FF resolution"),
        SeamSpec("exp/specials", "exp", "points",
                 (0.0, -0.0, math.inf, -math.inf, math.nan,
                  3.4028235e38, -3.4028235e38), 2.0 ** -42, "special",
                 "IEEE specials and the f32 extremes"),
        # ---- log -----------------------------------------------------
        SeamSpec("log/binade_boundaries", "log", "centers",
                 tuple(2.0 ** e for e in range(-126, 128, 2)),
                 2.0 ** -42, "contract",
                 "frexp exponent surgery flips e at every power of two"),
        SeamSpec("log/sqrt2_fold", "log", "centers",
                 tuple(1.4142135 * 2.0 ** e
                       for e in (-126, -64, -16, -2, -1, 0, 1, 2, 16, 64,
                                 126)),
                 2.0 ** -42, "contract",
                 "the m > 1.4142135 fold halves m and bumps e: the "
                 "mantissa-range seam, sampled across binades"),
        SeamSpec("log/near_one", "log", "window",
                 (1.0 - 2.0 ** -8, 1.0 + 2.0 ** -8), 2.0 ** -42, "contract",
                 "log(1+eps) cancellation: atanh kernel at its smallest s"),
        SeamSpec("log/specials", "log", "points",
                 (0.0, -0.0, math.inf, -math.inf, math.nan, -1.0,
                  3.4028235e38), 2.0 ** -42, "special",
                 "+-0 -> -inf, x < 0 -> nan, inf -> inf"),
        # ---- tanh ----------------------------------------------------
        SeamSpec("tanh/small_large_seam", "tanh", "centers",
                 (float(_TANH_SMALL), -float(_TANH_SMALL)),
                 2.0 ** -41, "contract",
                 "Maclaurin vs expm1-rational handoff at |x| = 0.35"),
        SeamSpec("tanh/expm1_k_boundaries", "tanh", "centers",
                 _tanh_expm1_boundaries(), 2.0 ** -41, "contract",
                 "the large branch's own Cody–Waite grid at y = -2|x|"),
        SeamSpec("tanh/saturation_window", "tanh", "window",
                 (16.5, 18.5), 2.0 ** -41, "contract",
                 "t -> -1: tanh == +-1 at FF resolution beyond ~17.3"),
        SeamSpec("tanh/deep_saturation", "tanh", "points",
                 (20.0, -20.0, 50.0, -50.0, 88.0, -88.0, 1e10, -1e10,
                  1e38, -1e38), 2.0 ** -41, "contract",
                 "deep saturation must stay exactly +-1, not drift"),
        SeamSpec("tanh/identity_band", "tanh", "window",
                 (2.0 ** -60, 2.0 ** -45), 0.0, "identity",
                 "|x| < 2^-45: output limbs must be the input limbs, "
                 "bitwise (keeps signed zero and the EFT underflow domain)"),
        SeamSpec("tanh/identity_edge", "tanh", "centers",
                 (2.0 ** -45, -(2.0 ** -45)), 2.0 ** -41, "contract",
                 "both sides of the identity-band edge meet the bound"),
        SeamSpec("tanh/specials", "tanh", "points",
                 (0.0, -0.0, math.inf, -math.inf, math.nan),
                 2.0 ** -41, "special",
                 "+-inf -> +-1 exactly, nan propagates, signed zero kept"),
    ]
    return seams
