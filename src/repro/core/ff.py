"""The float-float (FF) format — paper §4 — as a JAX pytree numeric type.

An FF value represents ``x = hi + lo`` (unevaluated sum of two f32, with
``|lo| <= ulp(hi)/2`` when normalized), giving ~49 significand bits of which
the paper's error analysis guarantees 44.  The representation range is that
of f32 (paper §7).

Design notes
------------
* ``FF`` is a registered pytree of two equal-shape f32 arrays, so it shards,
  ``jit``s, ``vmap``s, ``scan``s and checkpoints like any ordinary tensor.
  (The GPU analogue in the paper stored hi/lo in two texture channels.)
* All algorithms are the paper's branch-free variants.  The one algorithm the
  paper benchmarked with a test in it (CPU Add22, §6) is provided as
  ``add22_accurate`` in its modern branch-free TwoSum form.
* f64 never appears in library code (the whole point is *no* wide hardware
  type); f64 is used only in tests/benchmarks as the exact oracle.
"""

from __future__ import annotations

from typing import Any, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import transforms as T

Array = jnp.ndarray
Scalar = Union[float, int]

# Paper Theorem 6: |eps| <= 2^-44 for Mul22; Add22 bound in Theorem 5.
FF_EPS = 2.0**-44
FF_PRECISION_BITS = 44


@jax.tree_util.register_pytree_node_class
class FF:
    """Unevaluated sum of two f32 arrays: value == hi + lo."""

    __slots__ = ("hi", "lo")

    def __init__(self, hi: Array, lo: Array):
        self.hi = hi
        self.lo = lo

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.hi, self.lo), None

    @classmethod
    def tree_unflatten(cls, aux: Any, children) -> "FF":
        return cls(*children)

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_f32(cls, x: Array) -> "FF":
        x = jnp.asarray(x, jnp.float32)
        return cls(x, jnp.zeros_like(x))

    @classmethod
    def from_f64(cls, x) -> "FF":
        """Exact-as-possible FF from a wide value (test/init convenience).

        hi = fl32(x); lo = fl32(x - hi).  Only used at the host boundary
        (weight init, test vectors) — never inside jitted compute.
        """
        import numpy as np

        x64 = np.asarray(x, np.float64)
        hi = x64.astype(np.float32)
        lo = (x64 - hi.astype(np.float64)).astype(np.float32)
        return cls(jnp.asarray(hi), jnp.asarray(lo))

    @classmethod
    def zeros(cls, shape, **kw) -> "FF":
        z = jnp.zeros(shape, jnp.float32, **kw)
        return cls(z, jnp.zeros_like(z))

    # -- views --------------------------------------------------------------
    @property
    def shape(self):
        return self.hi.shape

    @property
    def dtype(self):
        return self.hi.dtype

    @property
    def ndim(self):
        return self.hi.ndim

    def to_f32(self) -> Array:
        """Round to nearest f32 (hi is already the correctly rounded value)."""
        return self.hi

    def to_f64(self):
        """Exact wide value — ONLY for host-side verification."""
        import numpy as np

        return np.asarray(self.hi, np.float64) + np.asarray(self.lo, np.float64)

    def astuple(self) -> Tuple[Array, Array]:
        return self.hi, self.lo

    def __repr__(self):
        return f"FF(hi={self.hi!r}, lo={self.lo!r})"

    # -- shape ops (exact: they permute/slice both limbs identically) --------
    def reshape(self, *s) -> "FF":
        return FF(self.hi.reshape(*s), self.lo.reshape(*s))

    def transpose(self, *axes) -> "FF":
        return FF(self.hi.transpose(*axes), self.lo.transpose(*axes))

    def __getitem__(self, idx) -> "FF":
        return FF(self.hi[idx], self.lo[idx])

    # -- arithmetic (operator sugar over the module-level functions) ---------
    def __neg__(self) -> "FF":
        return FF(-self.hi, -self.lo)

    def __abs__(self) -> "FF":
        neg = self.hi < 0
        return FF(jnp.where(neg, -self.hi, self.hi), jnp.where(neg, -self.lo, self.lo))

    def __add__(self, other) -> "FF":
        return add22(self, _coerce(other))

    __radd__ = __add__

    def __sub__(self, other) -> "FF":
        return add22(self, -_coerce(other))

    def __rsub__(self, other) -> "FF":
        return add22(_coerce(other), -self)

    def __mul__(self, other) -> "FF":
        return mul22(self, _coerce(other))

    __rmul__ = __mul__

    def __truediv__(self, other) -> "FF":
        return div22(self, _coerce(other))

    def __rtruediv__(self, other) -> "FF":
        return div22(_coerce(other), self)

    # -- comparisons on the represented value hi + lo ------------------------
    # Library ops always return *normalized* FF (|lo| <= ulp(hi)/2), for
    # which value order == lexicographic (hi, lo) order and value equality
    # == limb equality.  All return boolean arrays (elementwise, like jnp);
    # consequently FF is unhashable, matching jnp.ndarray semantics.
    def __eq__(self, other):  # type: ignore[override]
        o = _coerce(other)
        return (self.hi == o.hi) & (self.lo == o.lo)

    def __ne__(self, other):  # type: ignore[override]
        o = _coerce(other)
        return (self.hi != o.hi) | (self.lo != o.lo)

    def __lt__(self, other):
        o = _coerce(other)
        return (self.hi < o.hi) | ((self.hi == o.hi) & (self.lo < o.lo))

    def __le__(self, other):
        o = _coerce(other)
        return (self.hi < o.hi) | ((self.hi == o.hi) & (self.lo <= o.lo))

    def __gt__(self, other):
        o = _coerce(other)
        return (self.hi > o.hi) | ((self.hi == o.hi) & (self.lo > o.lo))

    def __ge__(self, other):
        o = _coerce(other)
        return (self.hi > o.hi) | ((self.hi == o.hi) & (self.lo >= o.lo))

    __hash__ = None  # type: ignore[assignment]


def _coerce(x) -> FF:
    if isinstance(x, FF):
        return x
    return FF.from_f32(jnp.asarray(x, jnp.float32))


# ---------------------------------------------------------------------------
# Paper algorithms (array-valued; every op maps over lanes branch-free).
# ---------------------------------------------------------------------------

def add12(a: Array, b: Array) -> FF:
    """Paper Theorem 2 (Knuth Add12): exact a+b as an FF."""
    s, r = T.two_sum(a, b)
    return FF(s, r)


def mul12(a: Array, b: Array) -> FF:
    """Paper Theorem 4 (Dekker Mul12): exact a*b as an FF."""
    x, y = T.two_prod(a, b)
    return FF(x, y)


def add22(a: FF, b: FF) -> FF:
    """Paper Theorem 5 Add22 (branch-free, 'sloppy' variant).

    Error bound: delta <= max(2^-24 |al+bl|, 2^-44 |a+b|).
    """
    sh, sl = T.two_sum(a.hi, b.hi)
    v = sl + (a.lo + b.lo)
    rh, rl = T.fast_two_sum(sh, v)
    return FF(rh, rl)


def add22_accurate(a: FF, b: FF) -> FF:
    """Accurate Add22 (2-ulp bound, ~2^-44 relative always).

    The branch-free descendant of the 'one test' variant the paper mentions:
    the magnitude test is replaced by a second TwoSum on the low limbs.
    ~8 extra flops over ``add22``; use where the |al+bl| term matters
    (e.g. long compensated reductions).
    """
    sh, sl = T.two_sum(a.hi, b.hi)
    th, tl = T.two_sum(a.lo, b.lo)
    c = sl + th
    vh, vl = T.fast_two_sum(sh, c)
    w = tl + vl
    rh, rl = T.fast_two_sum(vh, w)
    return FF(rh, rl)


def add212(a: FF, b: Array) -> FF:
    """FF + f32 (cheaper than coercing b to FF then add22)."""
    sh, sl = T.two_sum(a.hi, b)
    v = sl + a.lo
    rh, rl = T.fast_two_sum(sh, v)
    return FF(rh, rl)


def mul22(a: FF, b: FF) -> FF:
    """Paper Theorem 6 Mul22: relative error <= 2^-44."""
    th, tl = T.two_prod(a.hi, b.hi)
    t = tl + (a.hi * b.lo + a.lo * b.hi)
    rh, rl = T.fast_two_sum(th, t)
    return FF(rh, rl)


def mul212(a: FF, b: Array) -> FF:
    """FF * f32."""
    th, tl = T.two_prod(a.hi, b)
    t = tl + a.lo * b
    rh, rl = T.fast_two_sum(th, t)
    return FF(rh, rl)


def div22(a: FF, b: FF) -> FF:
    """FF division (Dekker-style: quotient + one correction step).

    The paper notes GPUs implement division as reciprocal×multiply with
    doubled error (§3); this algorithm only needs the hardware quotient as a
    *seed*, so it tolerates that.
    """
    ch = a.hi / b.hi
    th, tl = T.two_prod(ch, b.hi)
    cl = ((((a.hi - th) - tl) + a.lo) - ch * b.lo) / b.hi
    rh, rl = T.fast_two_sum(ch, cl)
    return FF(rh, rl)


def sqrt22(a: FF) -> FF:
    """FF square root via one Newton correction of the hardware sqrt."""
    ch = jnp.sqrt(a.hi)
    th, tl = T.two_prod(ch, ch)
    num = ((a.hi - th) - tl) + a.lo
    cl = num / (ch + ch)
    rh, rl = T.fast_two_sum(ch, cl)
    return FF(rh, rl)


def normalize(a: FF) -> FF:
    """Re-establish |lo| <= ulp(hi)/2 (Fast2Sum renormalization)."""
    rh, rl = T.fast_two_sum(a.hi, a.lo)
    return FF(rh, rl)


def fma22(a: FF, b: FF, c: FF) -> FF:
    """a*b + c in FF (fused at the algorithm level: one renormalization)."""
    th, tl = T.two_prod(a.hi, b.hi)
    t = tl + (a.hi * b.lo + a.lo * b.hi)
    sh, sl = T.two_sum(th, c.hi)
    v = sl + (t + c.lo)
    rh, rl = T.fast_two_sum(sh, v)
    return FF(rh, rl)


# -- tree helpers (FF pytrees of parameters) ---------------------------------

def tree_from_f32(tree):
    return jax.tree_util.tree_map(FF.from_f32, tree)


def tree_to_f32(tree):
    return jax.tree_util.tree_map(
        lambda x: x.to_f32() if isinstance(x, FF) else x,
        tree,
        is_leaf=lambda x: isinstance(x, FF),
    )
