"""EFT-safety self-check — paper §5, automated.

The paper discovered their DirectX toolchain rewrote ``(a+b)-a -> b`` and had
to hand-patch shaders.  Our toolchain hazard is different but analogous:
XLA:CPU's LLVM backend may contract ``s + a*b`` into ``fma(a, b, s)`` inside
vectorized fusions (AVX2+), which changes ``fl(a*b)`` relative to its other
use sites and silently breaks every EFT.

``check_eft_safe()`` runs a jitted probe reproducing the hazard pattern and
compares it with the op-by-op (eager) result.  Call sites:

  * imported by tests (hard assert),
  * called at trainer/benchmark startup (loud warning + remedy).

Remedy on CPU: ``XLA_FLAGS=--xla_cpu_max_isa=SSE4_2`` (no FMA instruction ->
no contraction).  This also matches the paper's hardware model: 2006 GPUs had
no FMA either.  On TPU the VPU does not contract f32 mul/add, so the probe
passes natively.
"""

from __future__ import annotations

import os
import warnings

import numpy as np

_REMEDY = (
    "XLA is contracting mul+add into FMA, breaking float-float EFTs "
    "(paper §5 'forbidden optimizations'). On CPU set "
    "XLA_FLAGS=--xla_cpu_max_isa=SSE4_2 before importing jax."
)


def check_eft_safe() -> bool:
    """True iff jitted TwoSum-of-product matches the op-by-op result."""
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    s = jnp.asarray(rng.standard_normal((8, 16)).astype(np.float32))
    a = jnp.asarray(rng.standard_normal(8).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(16).astype(np.float32))

    def probe(s, a, b):
        p = a[:, None] * b[None, :]
        s2 = s + p
        bb = s2 - s
        se = (p - bb) + (s - (s2 - bb))
        return s2, se

    eager = probe(s, a, b)
    jitted = jax.jit(probe)(s, a, b)
    return all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(eager, jitted)
    )


def require_eft_safe(strict: bool = False) -> bool:
    ok = check_eft_safe()
    if not ok:
        if strict:
            raise RuntimeError(_REMEDY)
        warnings.warn(_REMEDY, RuntimeWarning, stacklevel=2)
    return ok


def set_cpu_eft_flags() -> None:
    """Prepend the CPU anti-contraction flag to XLA_FLAGS.  MUST run before
    the first jax import.  No-op on real TPU backends (flag is CPU-only)."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_cpu_max_isa" not in flags:
        os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + flags).strip()
