"""Precision policy: where float-float is applied inside a model/optimizer.

This is how the paper's technique becomes a *framework feature* rather than a
micro-library: every model and the optimizer consult a ``PrecisionPolicy``
and transparently route the numerically critical paths through FF.

Policies (ordered by cost):
  * ``baseline``   — plain f32 activations / f32 master weights (control arm;
                     what you'd ship without the paper).
  * ``ff_master``  — FF master weights + FF optimizer accumulators only
                     (zero extra cost in forward/backward; the production
                     default at scale).
  * ``ff_reduce``  — ff_master + compensated reductions (loss, LN/RMS stats,
                     softmax LSE, grad-norm).
  * ``ff_full``    — ff_reduce + FF logits matmul (split-operand path).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Level = Literal["baseline", "ff_master", "ff_reduce", "ff_full"]


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    level: Level = "ff_master"
    # granular switches (derived from level, overridable)
    ff_master_weights: bool = True
    ff_reductions: bool = False
    ff_logits: bool = False
    # activation compute dtype for the bulk matmuls
    compute_dtype: str = "bfloat16"
    # block size for blocked-K compensated matmuls
    ff_matmul_block_k: int = 512
    # which ``repro.ff`` matmul implementation the dispatch registry selects
    # inside this policy's scope ("auto" = backend default; see
    # ``repro.ff.dispatch`` for the registered names: hybrid/split/dot2/ozaki)
    matmul_impl: str = "auto"

    @staticmethod
    def make(level: Level = "ff_master", compute_dtype: str = "bfloat16",
             **overrides) -> "PrecisionPolicy":
        table = dict(
            baseline=dict(ff_master_weights=False, ff_reductions=False, ff_logits=False),
            ff_master=dict(ff_master_weights=True, ff_reductions=False, ff_logits=False),
            ff_reduce=dict(ff_master_weights=True, ff_reductions=True, ff_logits=False),
            ff_full=dict(ff_master_weights=True, ff_reductions=True, ff_logits=True),
        )
        if level not in table:
            raise ValueError(f"unknown precision-policy level {level!r}; "
                             f"choose from {tuple(table)}")
        base = table[level]
        base.update(overrides)
        return PrecisionPolicy(level=level, compute_dtype=compute_dtype, **base)


BASELINE = PrecisionPolicy.make("baseline")
FF_MASTER = PrecisionPolicy.make("ff_master")
FF_REDUCE = PrecisionPolicy.make("ff_reduce")
FF_FULL = PrecisionPolicy.make("ff_full")
