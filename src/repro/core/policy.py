"""Precision policy: where float-float is applied inside a model/optimizer.

This is how the paper's technique becomes a *framework feature* rather than a
micro-library: every model and the optimizer consult a ``PrecisionPolicy``
and transparently route the numerically critical paths through FF.

Policies (ordered by cost):
  * ``baseline``   — plain f32 activations / f32 master weights (control arm;
                     what you'd ship without the paper).
  * ``ff_master``  — FF master weights + FF optimizer accumulators only
                     (zero extra cost in forward/backward; the production
                     default at scale).
  * ``ff_reduce``  — ff_master + compensated reductions (loss, LN/RMS stats,
                     softmax LSE, grad-norm).
  * ``ff_full``    — ff_reduce + FF logits matmul (split-operand path).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Level = Literal["baseline", "ff_master", "ff_reduce", "ff_full"]


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    level: Level = "ff_master"
    # granular switches (derived from level, overridable)
    ff_master_weights: bool = True
    ff_reductions: bool = False
    ff_logits: bool = False
    # Route model transcendentals (silu gates, tanh logit soft-caps,
    # Mamba2 exp decay chains, token-logprob scoring) through the FF
    # elementary functions (``repro.ff.math``).  Derived False at EVERY
    # level — the default policies stay bitwise-identical to the
    # pre-ff.math library — and opted in per scope:
    # ``ff.policy("ff_full", ff_math=True)``.
    ff_math: bool = False
    # Which ``ff.attention`` implementation the model attention layers
    # request ("fast" = the f32 online softmax, "ff"/"pallas" = the
    # compensated FF recurrence, "f64" = oracle tier).  Derived "fast" at
    # EVERY level — default policies stay bitwise-identical to the
    # pre-registry attention hot path — and opted in per scope:
    # ``ff.policy(attention="ff")``.
    attention: str = "fast"
    # activation compute dtype for the bulk matmuls
    compute_dtype: str = "bfloat16"
    # Block size for blocked-K compensated matmuls.  MUST match the
    # defaults of the kernel (kernels/ff_matmul.ff_matmul bk=512) and jnp
    # (core/ffmatmul.matmul_compensated block_k=512) hybrid paths, so the
    # registry default and an explicit impl="hybrid" call compile the SAME
    # program (tests/test_tune.py pins the three; the bench harness asserts
    # dispatch_default parity with the resolved impl at runtime).  Tuned
    # tables (repro.ff.tuning) override this per shape bucket when present.
    ff_matmul_block_k: int = 512
    # which ``repro.ff`` matmul implementation the dispatch registry selects
    # inside this policy's scope ("auto" = tuned winner for the call shape
    # when a tuning table exists, else backend default; see
    # ``repro.ff.dispatch``: hybrid/split/dot2/ozaki/pallas_* and the
    # special "tuned"/"tuned_accurate" selectors)
    matmul_impl: str = "auto"

    @staticmethod
    def make(level: Level = "ff_master", compute_dtype: str = "bfloat16",
             **overrides) -> "PrecisionPolicy":
        table = dict(
            baseline=dict(ff_master_weights=False, ff_reductions=False, ff_logits=False),
            ff_master=dict(ff_master_weights=True, ff_reductions=False, ff_logits=False),
            ff_reduce=dict(ff_master_weights=True, ff_reductions=True, ff_logits=False),
            ff_full=dict(ff_master_weights=True, ff_reductions=True, ff_logits=True),
        )
        if level not in table:
            raise ValueError(f"unknown precision-policy level {level!r}; "
                             f"choose from {tuple(table)}")
        base = table[level]
        base.update(overrides)
        return PrecisionPolicy(level=level, compute_dtype=compute_dtype, **base)


BASELINE = PrecisionPolicy.make("baseline")
FF_MASTER = PrecisionPolicy.make("ff_master")
FF_REDUCE = PrecisionPolicy.make("ff_reduce")
FF_FULL = PrecisionPolicy.make("ff_full")
