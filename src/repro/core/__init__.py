"""Core float-float (FF) library — the paper's contribution in JAX.

This is the *algorithm layer* the ``repro.ff`` dispatch registry targets.
Application code (models/optim/train/examples) should import ``repro.ff``
instead: it adds backend dispatch, custom differentiation rules, and the
scoped precision policy on top of these algorithms.

Public API:
    FF, add12, mul12, add22, add22_accurate, mul22, div22, sqrt22, fma22
    two_sum, fast_two_sum, split, two_prod
    ff_sum, ff_dot, kahan_sum, ff_logsumexp
    matmul_compensated, matmul_split, matmul_dot2
    PrecisionPolicy
"""

from repro.core.transforms import (  # noqa: F401
    two_sum, fast_two_sum, split, split_safe, two_prod, two_prod_safe, two_diff,
)
from repro.core.ff import (  # noqa: F401
    FF, FF_EPS, FF_PRECISION_BITS,
    add12, mul12, add22, add22_accurate, add212, mul22, mul212,
    div22, sqrt22, normalize, fma22, tree_from_f32, tree_to_f32,
)
from repro.core.compensated import (  # noqa: F401
    kahan_sum, ff_sum, ff_sum_blocked, ff_dot, ff_mean, ff_logsumexp, kahan_update,
)
from repro.core.ffmatmul import (  # noqa: F401
    matmul_compensated, matmul_split, matmul_dot2, matmul_ozaki,
)
from repro.core.policy import (  # noqa: F401
    PrecisionPolicy, BASELINE, FF_MASTER, FF_REDUCE, FF_FULL,
)
