"""FF matrix multiplication — the MXU adaptation of the paper's Mul12/Add22.

The 2006 paper ran float-float *element-wise* in fragment shaders.  On TPU the
compute workhorse is the MXU (128x128 systolic matmul), which does NOT do
exact f32 products (f32 matmuls are composed of bf16 passes unless
``precision=HIGHEST`` forces 6-pass, and even then K-accumulation rounds).
Porting the paper mechanically (scalar Mul12 chains) would leave the MXU idle.

Instead we restructure (DESIGN.md §2):

* ``matmul_compensated``  — blocked K: each K-block is a hardware matmul
  (``precision=HIGHEST``), blocks are combined with Add22.  Accumulation error
  drops from O(K)·2^-24 to O(block)·2^-24 + O(K/block)·2^-44: the compensated
  cascade of the paper applied at *block* granularity instead of element
  granularity.  This is the fast production path (used for FF logits).

* ``matmul_split``        — Dekker-split operands (12-bit halves) make every
  elementwise product exact; the three significant cross terms are separate
  MXU matmuls whose results are combined in FF.  Product error is eliminated
  entirely; remaining error is K-accumulation only.  Composable with blocked K.

* ``matmul_dot2``         — per-element Dot2 (two_prod + cascaded two_sum over
  K via ``lax.scan``).  Full ~2^-44 quality; VPU-only.  This is the oracle-
  grade path, also realized as a Pallas kernel in ``repro.kernels.ff_matmul``.

All take f32 (M,K) x (K,N) and return FF (M,N).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import transforms as T
from repro.core.ff import FF, add22, normalize

Array = jnp.ndarray


def _dot_f32(a: Array, b: Array) -> Array:
    """Hardware matmul with forced f32-faithful passes (paper §5 lesson:
    never let the toolchain silently lower your precision)."""
    return lax.dot(a, b, precision=lax.Precision.HIGHEST,
                   preferred_element_type=jnp.float32)


def matmul_compensated(a: Array, b: Array, block_k: int = 512) -> FF:
    """Blocked-K FF-accumulated matmul (fast path).

    hypothesis: with K-blocks of size Bk, per-block error ~ Bk * 2^-24 * |.|
    and the FF combine contributes ~ (K/Bk) * 2^-44; Bk=512 balances both for
    K up to ~1M while keeping the MXU busy >99% of flops.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    nb = max(1, -(-K // block_k))
    pad = nb * block_k - K
    if pad:
        a = jnp.concatenate([a, jnp.zeros((M, pad), jnp.float32)], axis=1)
        b = jnp.concatenate([b, jnp.zeros((pad, N), jnp.float32)], axis=0)
    a3 = a.reshape(M, nb, block_k).transpose(1, 0, 2)   # (nb, M, Bk)
    b3 = b.reshape(nb, block_k, N)                      # (nb, Bk, N)

    def body(acc: FF, ab):
        ai, bi = ab
        p = _dot_f32(ai, bi)
        return add22(acc, FF.from_f32(p)), None

    acc0 = FF.zeros((M, N))
    acc, _ = lax.scan(body, acc0, (a3, b3))
    return acc


def matmul_split(a: Array, b: Array, block_k: Optional[int] = 512) -> FF:
    """Split-operand FF matmul (exact products; TPU-native Mul12).

    a = a_hi + a_lo, b = b_hi + b_lo with 12-bit halves (Dekker split), so
    a_hi*b_hi, a_hi*b_lo, a_lo*b_hi, a_lo*b_lo are all exact f32 products.
    Each cross-term matmul still rounds in its K-accumulation; the four
    partial matrices are combined with Add22.  Composed with blocked K the
    same way as ``matmul_compensated``.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    M, K = a.shape
    _, N = b.shape
    a_hi, a_lo = T.split(a)
    b_hi, b_lo = T.split(b)

    def partials(ai_hi, ai_lo, bi_hi, bi_lo):
        # dominant term first; combine low-order terms in f32 (they are
        # each <= 2^-12 of the dominant term; their own rounding is <=2^-48).
        hh = _dot_f32(ai_hi, bi_hi)
        hl = _dot_f32(ai_hi, bi_lo)
        lh = _dot_f32(ai_lo, bi_hi)
        ll = _dot_f32(ai_lo, bi_lo)
        t = add22(FF.from_f32(hl), FF.from_f32(lh))
        t = add22(t, FF.from_f32(ll))
        return add22(FF.from_f32(hh), t)

    if block_k is None or block_k >= K:
        return partials(a_hi, a_lo, b_hi, b_lo)

    nb = -(-K // block_k)
    pad = nb * block_k - K

    def padk(x, axis):
        if not pad:
            return x
        w = [(0, 0)] * x.ndim
        w[axis] = (0, pad)
        return jnp.pad(x, w)

    ah = padk(a_hi, 1).reshape(M, nb, block_k).transpose(1, 0, 2)
    al = padk(a_lo, 1).reshape(M, nb, block_k).transpose(1, 0, 2)
    bh = padk(b_hi, 0).reshape(nb, block_k, N)
    bl = padk(b_lo, 0).reshape(nb, block_k, N)

    def body(acc: FF, abi):
        ahi, ali, bhi, bli = abi
        return add22(acc, partials(ahi, ali, bhi, bli)), None

    acc0 = FF.zeros((M, N))
    acc, _ = lax.scan(body, acc0, (ah, al, bh, bl))
    return acc


def matmul_dot2(a: Array, b: Array) -> FF:
    """Per-element Dot2 matmul: full float-float quality (~2^-44 relative).

    Scans over K with exact products (Mul12) and a compensated cascade.
    O(MN) state, VPU-only — use for small, numerically critical matmuls
    (router logits, final LM-head rows under study) and as the oracle for the
    Pallas kernel.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    M, K = a.shape
    _, N = b.shape

    def body(carry, ab):
        s, c, cc = carry
        ai, bi = ab                       # (M,), (N,)
        p, pe = T.two_prod(ai[:, None], bi[None, :])
        s2, se = T.two_sum(s, p)
        c2, ce = T.two_sum(c, se + pe)    # Dot3-quality cascade
        return (s2, c2, cc + ce), None

    z = jnp.zeros((M, N), jnp.float32)
    (s, c, cc), _ = lax.scan(body, (z, z, z), (a.T, b))
    rh, rl = T.fast_two_sum(s, c + cc)
    return FF(rh, rl)


def matmul_ozaki(a: Array, b: Array, slices: int = 0) -> FF:
    """Ozaki-scheme FF matmul: error-free slice products with error-free
    in-matmul accumulation — paper-quality accuracy at MXU speed.

    BEYOND-PAPER (DESIGN.md §2, EXPERIMENTS §Perf): the 2006 paper made
    single *products* exact (Mul12).  For matmuls the accumulation over K
    also has to be exact.  Slice each operand into ``n`` magnitude-aligned
    pieces of ``beta`` significand bits, with
        beta = (24 - ceil(log2 K)) // 2
    so every slice-pair product (2*beta bits) summed K times (+log2 K bits)
    still fits f32's 24-bit significand: each of the n^2 hardware matmuls is
    EXACT.  The n^2 partial matrices are then combined with Add22.  Total
    error: only the final FF merges (~2^-44) — versus O(K)*2^-24 for naive
    f32 and ~2^-24 for the split/compensated paths.

    Cost: n^2 MXU matmuls (n ~ 4-5 for K<=16k) vs dot2's K VPU steps.
    """
    import numpy as np

    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    M, K = a.shape
    _, N = b.shape
    t = int(np.ceil(np.log2(max(K, 2))))
    beta = max(2, (24 - t) // 2 - 1)     # -1: RN carry margin per slice
    n = slices or int(np.ceil(26.0 / beta))

    def extract(x, axis):
        """n magnitude-aligned slices of <=beta(+1) bits each.

        sigma = 2^(e_max + 24 - beta): adding/subtracting it truncates r to
        granularity ulp(sigma) = 2^(e_max + 1 - beta), i.e. keeps the top
        ~beta bits of the axis-aligned significand (Ozaki et al. 2012).
        """
        parts = []
        r = x
        for _ in range(n):
            mu = jnp.max(jnp.abs(r), axis=axis, keepdims=True)
            e = jnp.ceil(jnp.log2(jnp.maximum(mu, jnp.float32(1e-38))))
            sigma = jnp.exp2(e + jnp.float32(24 - beta))
            w = (r + sigma) - sigma          # top beta bits
            parts.append(w)
            r = r - w                        # exact (aligned granularities)
        return parts, r

    pa, ra = extract(a, axis=1)
    pb, rb = extract(b, axis=0)

    acc = FF.zeros((M, N))
    # keep every pair contributing above FF precision (beta*(i+j) <= 50);
    # largest-magnitude pairs first keeps the Add22 chain well-ordered
    max_order = int(np.ceil(50.0 / beta))
    for i in range(n):
        for j in range(n):
            if i + j > max_order:            # < 2^-50: below FF precision
                continue
            p = _dot_f32(pa[i], pb[j])       # EXACT: fits 24 bits
            acc = add22(acc, FF.from_f32(p))
    # residual correction (everything below the n slices)
    if True:
        acc = add22(acc, FF.from_f32(_dot_f32(ra, b)))
        acc = add22(acc, FF.from_f32(_dot_f32(a - ra, rb)))
    return acc
