"""FF matrix multiplication — the MXU adaptation of the paper's Mul12/Add22.

The 2006 paper ran float-float *element-wise* in fragment shaders.  On TPU the
compute workhorse is the MXU (128x128 systolic matmul), which does NOT do
exact f32 products (f32 matmuls are composed of bf16 passes unless
``precision=HIGHEST`` forces 6-pass, and even then K-accumulation rounds).
Porting the paper mechanically (scalar Mul12 chains) would leave the MXU idle.

Instead we restructure (DESIGN_ozaki.md):

* ``matmul_compensated``  — blocked K: each K-block is a hardware matmul
  (``precision=HIGHEST``), blocks are combined with Add22.  Accumulation error
  drops from O(K)·2^-24 to O(block)·2^-24 + O(K/block)·2^-44: the compensated
  cascade of the paper applied at *block* granularity instead of element
  granularity.  This is the fast production path (used for FF logits).

* ``matmul_split``        — Dekker-split operands (12-bit halves) make every
  elementwise product exact; the three significant cross terms are separate
  MXU matmuls whose results are combined in FF.  Product error is eliminated
  entirely; remaining error is K-accumulation only.  Composable with blocked K.

* ``matmul_dot2``         — per-element Dot2 (two_prod + cascaded two_sum),
  block-vectorized over K-chunks.  Full ~2^-44 quality; VPU-only.  This is
  the oracle-grade path, also realized as a Pallas kernel in
  ``repro.kernels.ff_matmul``.

* ``matmul_ozaki``        — exponent-aligned slicing: ALL slice-pair products
  AND their in-chunk K-accumulation are exact in hardware matmuls.  Paper
  accuracy (~2^-46) at matrix-unit speed; the fast member of the accurate
  tier on f64-less backends.  See ``ozaki_params`` for the slicing rules.

* ``matmul_f64``          — native double-precision GEMM rounded to FF.  The
  paper emulates f64 on f32-only hardware; on backends whose hardware HAS
  f64 (CPU, most GPUs) the fastest route to paper-quality accuracy is one
  dgemm.  The accurate-tier dispatch default on such backends.

All take f32 (M,K) x (K,N) and return FF (M,N).
"""

from __future__ import annotations

import math
from typing import List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import transforms as T
from repro.core.ff import FF, add22

Array = jnp.ndarray


def _dot_f32(a: Array, b: Array) -> Array:
    """Hardware matmul with forced f32-faithful passes (paper §5 lesson:
    never let the toolchain silently lower your precision)."""
    return lax.dot(a, b, precision=lax.Precision.HIGHEST,
                   preferred_element_type=jnp.float32)


def matmul_compensated(a: Array, b: Array, block_k: int = 512) -> FF:
    """Blocked-K FF-accumulated matmul (fast path).

    hypothesis: with K-blocks of size Bk, per-block error ~ Bk * 2^-24 * |.|
    and the FF combine contributes ~ (K/Bk) * 2^-44; Bk=512 balances both for
    K up to ~1M while keeping the MXU busy >99% of flops.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    nb = max(1, -(-K // block_k))
    if nb == 1:
        # single K-block: the scan degenerates to add22(zeros, FF(p, 0)),
        # which is bitwise FF(p, 0) (TwoSum/Fast2Sum with exact zeros) —
        # skip the fold machinery AND the zero-pad (padding only fed the
        # block reshape; the unpadded GEMM is the same one-f32-GEMM error
        # class, though K < block_k callers may see different last-ulp
        # rounding than the padded formulation produced).  Measured ~40%
        # of the whole call at (4096, 512, 4096); this is every
        # K <= block_k call site, and in particular the K-split mesh
        # shard, whose combine renormalizes anyway.
        return FF(_dot_f32(a, b), jnp.zeros((M, N), jnp.float32))
    pad = nb * block_k - K
    if pad:
        a = jnp.concatenate([a, jnp.zeros((M, pad), jnp.float32)], axis=1)
        b = jnp.concatenate([b, jnp.zeros((pad, N), jnp.float32)], axis=0)
    a3 = a.reshape(M, nb, block_k).transpose(1, 0, 2)   # (nb, M, Bk)
    b3 = b.reshape(nb, block_k, N)                      # (nb, Bk, N)

    def body(acc: FF, ab):
        ai, bi = ab
        p = _dot_f32(ai, bi)
        return add22(acc, FF.from_f32(p)), None

    acc0 = FF.zeros((M, N))
    acc, _ = lax.scan(body, acc0, (a3, b3))
    return acc


def matmul_split(a: Array, b: Array, block_k: Optional[int] = 512) -> FF:
    """Split-operand FF matmul (exact products; TPU-native Mul12).

    a = a_hi + a_lo, b = b_hi + b_lo with 12-bit halves (Dekker split), so
    a_hi*b_hi, a_hi*b_lo, a_lo*b_hi, a_lo*b_lo are all exact f32 products.
    Each cross-term matmul still rounds in its K-accumulation; the four
    partial matrices are combined with Add22.  Composed with blocked K the
    same way as ``matmul_compensated``.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    M, K = a.shape
    _, N = b.shape
    a_hi, a_lo = T.split(a)
    b_hi, b_lo = T.split(b)

    def partials(ai_hi, ai_lo, bi_hi, bi_lo):
        # dominant term first; combine low-order terms in f32 (they are
        # each <= 2^-12 of the dominant term; their own rounding is <=2^-48).
        hh = _dot_f32(ai_hi, bi_hi)
        hl = _dot_f32(ai_hi, bi_lo)
        lh = _dot_f32(ai_lo, bi_hi)
        ll = _dot_f32(ai_lo, bi_lo)
        t = add22(FF.from_f32(hl), FF.from_f32(lh))
        t = add22(t, FF.from_f32(ll))
        return add22(FF.from_f32(hh), t)

    if block_k is None or block_k >= K:
        return partials(a_hi, a_lo, b_hi, b_lo)

    nb = -(-K // block_k)
    pad = nb * block_k - K

    def padk(x, axis):
        if not pad:
            return x
        w = [(0, 0)] * x.ndim
        w[axis] = (0, pad)
        return jnp.pad(x, w)

    ah = padk(a_hi, 1).reshape(M, nb, block_k).transpose(1, 0, 2)
    al = padk(a_lo, 1).reshape(M, nb, block_k).transpose(1, 0, 2)
    bh = padk(b_hi, 0).reshape(nb, block_k, N)
    bl = padk(b_lo, 0).reshape(nb, block_k, N)

    def body(acc: FF, abi):
        ahi, ali, bhi, bli = abi
        return add22(acc, partials(ahi, ali, bhi, bli)), None

    acc0 = FF.zeros((M, N))
    acc, _ = lax.scan(body, acc0, (ah, al, bh, bl))
    return acc


def matmul_dot2(a: Array, b: Array, chunk: int = 32) -> FF:
    """Per-element Dot2 matmul: full float-float quality (~2^-44 relative).

    Block-vectorized: K is processed in ``chunk``-wide slabs.  Each slab
    forms the (M, chunk, N) outer products exactly with a batched two_prod
    (Mul12) and reduces them with a pairwise-compensated two_sum tree; the
    slab results feed a Dot3-quality cascade across slabs.  Versus the old
    one-rank-1-update-per-k ``lax.scan``, the sequential depth drops from K
    to K/chunk with identical error structure: every product is exact, every
    rounding is captured in a compensation term.

    O(M·chunk·N) live state, VPU-only — use for small, numerically critical
    matmuls (router logits, final LM-head rows under study) and as the oracle
    for the Pallas kernels.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    M, K = a.shape
    _, N = b.shape
    chunk = max(1, min(chunk, K))
    nb = -(-K // chunk)
    pad = nb * chunk - K
    if pad:
        a = jnp.concatenate([a, jnp.zeros((M, pad), jnp.float32)], axis=1)
        b = jnp.concatenate([b, jnp.zeros((pad, N), jnp.float32)], axis=0)
    a3 = a.reshape(M, nb, chunk).transpose(1, 0, 2)   # (nb, M, c)
    b3 = b.reshape(nb, chunk, N)                      # (nb, c, N)

    def slab(ai, bi):
        """Exact products + pairwise-compensated reduction of one K-slab.

        Returns (sum, err) with sum + err == the slab's exact dot to ~2^-48.
        """
        p, pe = T.two_prod(ai[:, :, None], bi[None, :, :])   # (M, c, N) exact
        # product error terms are <= 2^-24 of their products; a plain sum
        # only rounds at ~2^-48 of the slab total.  The tree collects
        # every two_sum rounding into the same compensation term.
        return T.pairwise_sum_compensated(p, 1, jnp.sum(pe, axis=1))

    def body(carry, ab):
        s, c, cc = carry
        ai, bi = ab
        ps, pe = slab(ai, bi)
        s2, se = T.two_sum(s, ps)
        c2, ce = T.two_sum(c, se + pe)    # Dot3-quality cascade across slabs
        return (s2, c2, cc + ce), None

    z = jnp.zeros((M, N), jnp.float32)
    (s, c, cc), _ = lax.scan(body, (z, z, z), (a3, b3))
    rh, rl = T.fast_two_sum(s, c + cc)
    return FF(rh, rl)


# ---------------------------------------------------------------------------
# Ozaki-scheme FF matmul
# ---------------------------------------------------------------------------

def ozaki_params(K: int, slices: int = 0, beta: int = 0,
                 block_k: int = 0) -> Tuple[int, int, int, int]:
    """Slicing parameters for ``matmul_ozaki`` — the explicit heuristic.

    Exactness budget: a slice holds at most ``2^(beta-1)`` quanta of its
    per-(row, K-chunk) granularity (1.5*sigma extraction keeps r+sigma in one
    binade, so round-to-nearest never spills an extra bit).  A slice-pair
    product is then <= ``2^(2*beta-2)`` quanta, and its sum over a K-chunk of
    ``bk`` terms stays below f32's exact-integer ceiling 2^24 iff

        2*beta + ceil(log2 bk) <= 26.

    Heuristic defaults (overridable per argument):
      * ``block_k = min(K, 1024)`` — the largest chunk that still admits
        beta = 8, i.e. the fewest GEMM passes (slices^2 grows ~(24/beta)^2
        while chunking overhead grows with K/block_k).
      * ``beta = (26 - ceil(log2 block_k)) // 2`` — widest exact slice.
      * ``slices = ceil(24 / beta)`` — cover the full f32 significand below
        the per-(row, chunk) max exponent; everything deeper is handled by
        the f32 residual-correction GEMM at ~2^-24 * 2^-24 relative.
        Short contractions (K <= 512) get one extra margin slice when
        coverage would be under 27 bits: the residual GEMM's rounding lacks
        the ~sqrt(K) cancellation discount there, and small-K slice GEMMs
        are cheap.  Operands whose within-row exponent RANGE is wide
        (>~2^20 spread) push significance below the sliced horizon — pass a
        larger ``slices`` (see ``suggest_slices``) to extend coverage by
        beta bits per slice.

    Pairs with ``beta*(i+j) > 50`` fall below FF precision (2^-50 relative
    to the leading pair even before the condition-number discount) and are
    skipped; ``max_order`` encodes that rule.

    Returns ``(slices, beta, block_k, max_order)``.
    """
    K = max(int(K), 1)
    bk = int(block_k) or min(K, 1024)
    bk = min(bk, K)
    t = math.ceil(math.log2(max(bk, 2)))
    beta = int(beta) or max(2, (26 - t) // 2)
    if 2 * beta + t > 26:
        raise ValueError(
            f"ozaki exactness budget violated: 2*beta + ceil(log2 block_k) "
            f"= {2 * beta + t} > 26 (beta={beta}, block_k={bk}); slice-pair "
            f"block sums would round inside the 'exact' GEMMs — lower beta "
            f"or block_k")
    n = int(slices)
    if not n:
        n = max(2, -(-24 // beta))
        if n * beta < 27 and K <= 512:
            n += 1                      # small-K margin slice (see above)
    max_order = max(1, 50 // beta)
    return n, beta, bk, max_order


def suggest_slices(a, b, block_k: int = 0) -> int:
    """Host-side slice-count pick from the operands' exponent range.

    Eager-only helper (inspects concrete values; do not call under jit).
    Measures the within-row / within-column exponent spread that the
    row-aligned slicing must bridge and widens coverage accordingly:
    every extra ``beta`` bits of spread costs one extra slice.
    """
    a = np.asarray(a)
    b = np.asarray(b)
    K = a.shape[-1]
    n, beta, bk, _ = ozaki_params(K, block_k=block_k)

    def spread(x, axis):
        ax = np.abs(x)
        hi = ax.max(axis=axis)
        tiny = np.finfo(np.float32).tiny
        lo = np.where(ax > 0, ax, np.inf).min(axis=axis)
        s = np.log2(np.maximum(hi, tiny)) - np.log2(np.maximum(lo, tiny))
        s = s[np.isfinite(s)]
        return float(np.median(s)) if s.size else 0.0

    extra = max(0.0, max(spread(a, -1), spread(b, -2)) - 4.0)
    return min(n + int(math.ceil(extra / beta)), max(n, 50 // beta))


def extract_slices(x: Array, axis: int, n: int, beta: int
                   ) -> Tuple[List[Array], Array]:
    """n exponent-aligned slices of <= beta bits each, plus the residual.

    sigma_i = 1.5 * 2^(e + 24 - beta*(i+1)) with e = ceil(log2 max|x|) along
    ``axis``:  r + sigma_i stays inside sigma_i's binade for either sign of
    r, so ``(r + sigma) - sigma`` rounds r to the slice granularity
    2^(e+1-beta*(i+1)) *uniformly* — each slice is at most 2^(beta-1) quanta
    in magnitude (Ozaki et al. 2012; the 1.5 factor is what makes the
    2*beta + log2(K) <= 26 exactness budget hold for signed data, not just
    in expectation).  Each ``r - w`` is exact (aligned granularities).
    """
    mu = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    e = jnp.ceil(jnp.log2(jnp.maximum(mu, jnp.float32(1e-38))))
    # Two edges guard the alignment exponent, both of which would silently
    # break the 2*beta + log2(bk) <= 26 exactness budget by doubling every
    # slice's quanta allowance:
    #  * f32 log2 is not correctly rounded — for mu just ABOVE a power of
    #    two it can land exactly on the integer, so ceil underestimates by
    #    1; one compare against an exact 2^e repairs it (2^e >= mu after).
    #  * jnp.exp2 itself is polynomial-approximated on XLA:CPU (inexact at
    #    most integer exponents under the EFT-safe ISA pin!), so both the
    #    repair compare and the sigma grid must build their powers of two
    #    with ldexp, which is exact by construction.
    ie = e.astype(jnp.int32)
    ie = jnp.where(jnp.ldexp(jnp.float32(1), ie) < mu, ie + 1, ie)
    parts = []
    r = x
    for i in range(n):
        sigma = jnp.float32(1.5) * jnp.ldexp(jnp.float32(1),
                                             ie + (24 - beta * (i + 1)))
        w = (r + sigma) - sigma
        parts.append(w)
        r = r - w
    return parts, r


def matmul_ozaki(a: Array, b: Array, slices: int = 0, *, beta: int = 0,
                 block_k: int = 0) -> FF:
    """Ozaki-scheme FF matmul: error-free slice products with error-free
    in-chunk accumulation — paper-quality accuracy at matrix-unit speed.

    BEYOND-PAPER (DESIGN_ozaki.md): the 2006 paper made single *products*
    exact (Mul12).  For matmuls the accumulation over K also has to be
    exact.  Slice each operand into ``n`` exponent-aligned pieces of
    ``beta`` significand bits (see ``ozaki_params``/``extract_slices``) so
    every slice-pair product summed over a K-chunk still fits f32's
    significand: each hardware matmul is EXACT.

    The n^2 pair products for ALL chunks are issued as ONE batched stacked
    GEMM — slices concatenated along M and N, chunks batched:

        (nc, n*M, bk) @ (nc, bk, n*N)   ==   einsum('cik,ckj->cij')

    which keeps the matrix unit saturated instead of n^2 * nc separate
    dispatches (the old Python-level slice loop).  Two batched per-chunk
    f32 residual GEMMs (operands already live in the chunked layout — no
    concat/transpose traffic) catch everything below the sliced 24 bits:
    a@b = sliced-pairs + ra@b + a@rb - ra@rb, where the ra@rb term
    (~2^-48 relative, below FF precision) is deliberately dropped.  Pair
    and residual blocks are then folded with ONE vectorized
    pairwise-compensated reduction over the stacked block axis: the same
    error structure as the former sequential Add22 cascade (every two_sum
    rounding lands in the compensation term) at log2(#blocks) vectorized
    passes over (M, N) instead of ~n^2*nc serial sweeps.

    Total error ~2^-46 relative to |A||B| for operands with moderate
    within-row exponent range; n^2+2 matmul-unit flops vs dot2's K VPU
    steps.  ``slices=0`` picks the documented heuristic.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    n, beta, bk, max_order = ozaki_params(K, slices=slices, beta=beta,
                                          block_k=block_k)
    nc = -(-K // bk)
    pad = nc * bk - K
    a_p, b_p = a, b
    if pad:
        a_p = jnp.concatenate([a, jnp.zeros((M, pad), jnp.float32)], axis=1)
        b_p = jnp.concatenate([b, jnp.zeros((pad, N), jnp.float32)], axis=0)
    Kp = nc * bk

    a3 = a_p.reshape(M, nc, bk).transpose(1, 0, 2)        # (nc, M, bk)
    b3 = b_p.reshape(nc, bk, N)                           # (nc, bk, N)
    pa, ra3 = extract_slices(a3, 2, n, beta)
    pb, rb3 = extract_slices(b3, 1, n, beta)

    As = jnp.concatenate(pa, axis=1)                      # (nc, n*M, bk)
    Bs = jnp.concatenate(pb, axis=2)                      # (nc, bk, n*N)
    G = jnp.matmul(As, Bs, precision=lax.Precision.HIGHEST,
                   preferred_element_type=jnp.float32)
    G = G.reshape(nc, n, M, n, N)                         # exact pair blocks

    # residual correction, batched per chunk:
    #   a@b - sum(pairs) == ra@b + (a-ra)@rb == ra@b + a@rb - ra@rb.
    # We issue ra@b and a@rb (a3/b3 are already materialized, so no extra
    # elementwise pass to form a-ra) and drop the over-counted ra@rb: both
    # factors sit ~2^-24 below their operand rows, so the term is ~2^-48
    # relative — below FF precision.
    res1 = jnp.matmul(ra3, b3, precision=lax.Precision.HIGHEST,
                      preferred_element_type=jnp.float32)
    res2 = jnp.matmul(a3, rb3, precision=lax.Precision.HIGHEST,
                      preferred_element_type=jnp.float32)

    # fold: one vectorized pairwise-compensated reduction over every kept
    # pair block and residual block; negligible pairs (order > max_order,
    # below FF precision even before the condition-number discount) are
    # dropped before stacking
    keep = [i * n + j for i in range(n) for j in range(n)
            if i + j <= max_order]
    blocks = G.transpose(1, 3, 0, 2, 4).reshape(n * n, nc, M, N)
    if len(keep) < n * n:
        blocks = blocks[np.asarray(keep)]
    blocks = jnp.concatenate([blocks.reshape(-1, M, N), res1, res2], axis=0)
    s, e = T.pairwise_sum_compensated(blocks, 0)
    rh, rl = T.two_sum(s, e)
    return FF(rh, rl)


# ---------------------------------------------------------------------------
# native-f64 reference matmul (backends whose hardware has f64)
# ---------------------------------------------------------------------------

def matmul_f64(a: Array, b: Array) -> FF:
    """Native double-precision GEMM, rounded to FF.

    The paper's premise is emulating f64 on f32-only hardware; the dispatch
    corollary is that on backends whose hardware HAS f64 (CPU, most GPUs)
    the fastest paper-quality path is a single native dgemm: every f32
    product is EXACT in f64 (24+24 < 53 significand bits) and the
    K-accumulation rounds at 2^-53 per step, so the FF-rounded result lands
    at ~2^-48 relative — comfortably inside the accurate tier at a small
    multiple of the naive f32 GEMM (vs ~10x+ for the best pure-f32 scheme).

    ``jax.experimental.enable_x64`` scopes the wide-dtype escape to this
    trace only: it works eagerly, inside an outer f32 ``jit``, and under
    ``vmap``/``grad``, without flipping the global x64 flag.  The body
    lives behind its own ``jit`` boundary on purpose: ``custom_vjp``'s
    lowering canonicalizes a sub-jaxpr's result types under the ambient
    (x64-off) config while leaving its f64 internals alone, which rejects
    an inlined mixed-dtype body — an opaque pjit call sidesteps that.
    TPU has no f64 unit — the dispatch wrapper substitutes the fused
    Ozaki kernel there (``repro.ff.dispatch._mm_f64``).
    """
    return FF(*_matmul_f64_jit(jnp.asarray(a, jnp.float32),
                               jnp.asarray(b, jnp.float32)))


@jax.jit
def _matmul_f64_jit(a: Array, b: Array) -> Tuple[Array, Array]:
    with jax.experimental.enable_x64():
        r = lax.dot(lax.convert_element_type(a, jnp.float64),
                    lax.convert_element_type(b, jnp.float64),
                    precision=lax.Precision.HIGHEST)
        hi = lax.convert_element_type(r, jnp.float32)
        lo = lax.convert_element_type(
            r - lax.convert_element_type(hi, jnp.float64), jnp.float32)
    return hi, lo
