"""Error-free transformations (EFTs) — the mathematical core of the paper.

Implements, in pure JAX f32, the primitives of Da Graça & Defour 2006:

  * ``two_sum``       — Add12 / Knuth TwoSum (branch-free, 6 flops).
  * ``fast_two_sum``  — Dekker Fast2Sum (3 flops, requires |a| >= |b|).
  * ``split``         — Dekker splitting at s=12 for p=24 (f32).
  * ``two_prod``      — Mul12 / Dekker product via ``split`` (no FMA assumed,
                        exactly as the paper: GPUs of 2006 had no FMA, and the
                        TPU VPU has no f32 scalar FMA primitive exposed either).

Hardware-assumption note (paper §3/§4): the paper proves these correct under
*faithful rounding + a guard bit*.  XLA:CPU and XLA:TPU f32 adds/muls are IEEE
round-to-nearest — strictly stronger, so every proof carries over.

XLA-safety note (paper §5): the paper had to hand-patch DirectX shaders
because the compiler rewrote ``(a ⊕ b) ⊖ a → b``.  XLA does **not** perform
unsafe floating-point reassociation on f32, so these sequences are preserved
under ``jax.jit``.  The one genuine hazard on TPU is *matmul* precision
(bf16 passes by default) — handled in ``ffmatmul.py`` via
``precision=HIGHEST`` / split-operand passes, never here.

Everything here is shape-polymorphic and dtype-strict: inputs must be f32
(asserted), outputs are f32.

Domain note (matches paper §6.1): XLA (like 2006 GPUs) flushes subnormals to
zero, so EFT exactness requires every intermediate to stay normal.  For
``split``/``two_prod`` that means |x| in [2^-100, 2^115] (the split residue is
up to 2^-12 smaller than x; products of halves must not underflow).  The
paper excludes denormal inputs from its accuracy study for the same reason.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
from jax import lax

Array = jnp.ndarray


def _opaque(x: Array) -> Array:
    """Optimization barrier: prevents the backend from contracting a rounded
    product into a later add (``s + a*b -> fma(a,b,s)``), which silently
    changes ``fl(a*b)`` at its other use sites and breaks EFT exactness.

    This is the paper §5 problem reborn: they hand-edited DirectX fragment
    programs; we pin the rounded value with ``lax.optimization_barrier``.
    XLA:TPU does not contract f32 mul+add on the VPU, but XLA:CPU (the
    validation backend) does — measured in tests/test_core_ff.py.
    """
    return lax.optimization_barrier(x)

# Dekker split point for binary32: p = 24, s = 12  →  2^s + 1.
_SPLIT_CONST = 4097.0  # == 2**12 + 1
# |a| above this can overflow inside split's (2^s+1)*a product (f32 max ≈
# 2^128; 2^128 / 2^13 ≈ 2^115).  ``split_safe`` rescales above it.
_SPLIT_OVERFLOW_THRESH = 2.0**115


def _f32(x: Array) -> Array:
    x = jnp.asarray(x)
    if x.dtype != jnp.float32:
        raise TypeError(f"float-float EFTs are defined for f32, got {x.dtype}")
    return x


def two_sum(a: Array, b: Array) -> Tuple[Array, Array]:
    """Add12 (Knuth).  Returns (s, r) with s = fl(a+b) and s + r == a + b exactly.

    Branch-free 6-operation variant — the paper's preferred form (§4): GPU
    stream processors (and TPU VPU lanes) execute both sides of a branch, so
    3 extra flops beat one test.
    """
    a, b = _f32(a), _f32(b)
    s = a + b
    bb = s - a
    err_b = b - bb          # error on b's side
    err_a = a - (s - bb)    # error on a's side
    return s, err_a + err_b


def fast_two_sum(a: Array, b: Array) -> Tuple[Array, Array]:
    """Dekker Fast2Sum: 3 flops; exact only when |a| >= |b| (or a == 0).

    Used to renormalize results whose magnitude ordering is known
    (e.g. after Add22/Mul22 where |hi| dominates by construction).
    """
    a, b = _f32(a), _f32(b)
    s = a + b
    r = b - (s - a)
    return s, r


def split(a: Array) -> Tuple[Array, Array]:
    """Dekker SPLIT (paper Theorem 3), s = 12 for binary32.

    Returns (a_hi, a_lo), non-overlapping, a_hi + a_lo == a exactly,
    each half fitting in <= 12 significand bits, so products of halves are
    exact in f32.  No overflow guard — see ``split_safe``.
    """
    a = _f32(a)
    # _opaque: without it the backend may contract ``c - a`` into
    # ``fma(4097, a, -a)`` — computing 4096*a exactly and skipping the
    # rounding of c that the algorithm *relies on* (Theorem 3 proof).
    c = _opaque(jnp.float32(_SPLIT_CONST) * a)
    a_big = c - a
    a_hi = c - a_big
    a_lo = a - a_hi
    return a_hi, a_lo


def split_safe(a: Array) -> Tuple[Array, Array]:
    """Overflow-guarded split: rescales |a| >= 2^115 by 2^-16 and back.

    Branch-free (select), matching the paper's no-branches design rule.
    """
    a = _f32(a)
    big = jnp.abs(a) >= jnp.float32(_SPLIT_OVERFLOW_THRESH)
    scale_dn = jnp.where(big, jnp.float32(2.0**-16), jnp.float32(1.0))
    scale_up = jnp.where(big, jnp.float32(2.0**16), jnp.float32(1.0))
    hi, lo = split(a * scale_dn)
    return hi * scale_up, lo * scale_up


def two_prod(a: Array, b: Array) -> Tuple[Array, Array]:
    """Mul12 (Dekker, paper Theorem 4).  x + y == a * b exactly.

    x = fl(a*b); y recovers the rounding error via split products, every one
    of which is exact in f32 (12-bit halves).
    """
    a, b = _f32(a), _f32(b)
    # _opaque: pins x = fl(a*b).  Otherwise a consumer like ``s + x`` can be
    # contracted into fma(a, b, s) while y was computed against rounded x —
    # the residual no longer matches and the FF pair is inconsistent.
    x = _opaque(a * b)
    a_hi, a_lo = split(a)
    b_hi, b_lo = split(b)
    # The err chain itself is FMA-safe: contracting ``x - ahi*bhi`` into
    # fma(-ahi, bhi, x) computes the same (provably representable) value.
    err1 = x - (a_hi * b_hi)
    err2 = err1 - (a_lo * b_hi)
    err3 = err2 - (a_hi * b_lo)
    y = (a_lo * b_lo) - err3
    return x, y


def two_prod_safe(a: Array, b: Array) -> Tuple[Array, Array]:
    """Mul12 with overflow-guarded splits (for |a| or |b| near f32 max)."""
    a, b = _f32(a), _f32(b)
    x = _opaque(a * b)
    a_hi, a_lo = split_safe(a)
    b_hi, b_lo = split_safe(b)
    err1 = x - (a_hi * b_hi)
    err2 = err1 - (a_lo * b_hi)
    err3 = err2 - (a_hi * b_lo)
    y = (a_lo * b_lo) - err3
    return x, y


def two_diff(a: Array, b: Array) -> Tuple[Array, Array]:
    """TwoDiff: (s, r) with s + r == a - b exactly (branch-free).

    Negation is exact in IEEE binary formats, so this is two_sum(a, -b).
    """
    a, b = _f32(a), _f32(b)
    return two_sum(a, -b)


def pairwise_sum_compensated(p: Array, axis: int, err: Array = None,
                             *, two_sum_fn=None) -> Tuple[Array, Array]:
    """Pairwise two_sum tree reduction over ``axis``: returns (sum, err)
    with sum + err tracking the exact total to ~2^-48 relative.

    Every tree-level rounding is captured by two_sum and folded into
    ``err`` (which only ever absorbs terms <= one ulp of the running
    partials, so its own f32 accumulation rounds at second order).  The
    tree halves the reduced axis per level — this is the vectorized slab
    reducer of the block-vectorized dot2 paths.

    ``two_sum_fn`` selects the EFT flavor: this module's barrier-carrying
    ``two_sum`` by default (safe under XLA:CPU FMA contraction), or the
    barrier-free ``repro.kernels.eft.two_sum`` inside Pallas kernel bodies.
    """
    ts = two_sum_fn if two_sum_fn is not None else two_sum
    if err is None:
        err = jnp.zeros_like(jnp.take(p, 0, axis=axis))
    while p.shape[axis] > 1:
        width = p.shape[axis]
        half = width // 2
        lo = lax.slice_in_dim(p, 0, half, axis=axis)
        hi = lax.slice_in_dim(p, half, 2 * half, axis=axis)
        s, e = ts(lo, hi)
        err = err + jnp.sum(e, axis=axis)
        if width % 2:
            s = jnp.concatenate(
                [s, lax.slice_in_dim(p, width - 1, width, axis=axis)],
                axis=axis)
        p = s
    return jnp.take(p, 0, axis=axis), err
