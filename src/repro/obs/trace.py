"""Chrome trace-event recording for the serving engine.

Emits the subset of the Trace Event Format that Perfetto (and Chrome's
``chrome://tracing``) load directly:

* ``ph="X"`` complete spans — one ``request`` span per request plus its
  ``queued`` / ``prefill`` / ``decode`` children, laid out one Perfetto
  track per request (``tid`` = request uid);
* ``ph="C"`` counter tracks — queue depth, active batch rows, page-pool
  occupancy, sampled once per scheduler step;
* ``ph="i"`` instants — preemptions, quarantines, snapshot writes,
  ``sync_every`` host syncs, journal compactions.

Timestamps are microseconds from ``time.perf_counter_ns`` relative to
recorder construction, so a trace is self-consistent and monotonic
regardless of wall-clock adjustments.  Everything is recorded from host
Python between jit dispatches; nothing here runs under tracing.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = ["TraceRecorder", "ENGINE_TID"]

# tid used for engine-wide (non-per-request) events; request spans use
# tid = uid + REQUEST_TID_BASE so uid 0 doesn't collide with the engine row.
ENGINE_TID = 0
REQUEST_TID_BASE = 1


class TraceRecorder:
    """Accumulates Chrome trace events; thread-safe, append-only."""

    def __init__(self, pid: int = 1):
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._t0 = time.perf_counter_ns()
        self._pid = pid
        self._meta(ENGINE_TID, "engine")

    # -- clock ------------------------------------------------------------
    def now(self) -> float:
        """Microseconds since recorder construction (monotonic)."""
        return (time.perf_counter_ns() - self._t0) / 1_000.0

    # -- event emission ---------------------------------------------------
    def _meta(self, tid: int, name: str) -> None:
        self._append({"ph": "M", "pid": self._pid, "tid": tid, "ts": 0,
                      "name": "thread_name", "args": {"name": name}})

    def _append(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            self._events.append(ev)

    def request_tid(self, uid: int) -> int:
        return REQUEST_TID_BASE + int(uid)

    def name_request_track(self, uid: int) -> None:
        self._meta(self.request_tid(uid), f"request uid={uid}")

    def complete(self, name: str, ts_us: float, dur_us: float, *,
                 tid: int = ENGINE_TID, cat: str = "serve",
                 args: Optional[Dict[str, Any]] = None) -> None:
        """A ``ph="X"`` complete span covering [ts_us, ts_us + dur_us]."""
        ev = {"ph": "X", "pid": self._pid, "tid": tid, "name": name,
              "cat": cat, "ts": float(ts_us), "dur": max(float(dur_us), 0.0)}
        if args:
            ev["args"] = args
        self._append(ev)

    def instant(self, name: str, *, tid: int = ENGINE_TID, cat: str = "serve",
                ts_us: Optional[float] = None,
                args: Optional[Dict[str, Any]] = None) -> None:
        ev = {"ph": "i", "pid": self._pid, "tid": tid, "name": name,
              "cat": cat, "s": "t",
              "ts": self.now() if ts_us is None else float(ts_us)}
        if args:
            ev["args"] = args
        self._append(ev)

    def counter(self, name: str, values: Dict[str, float], *,
                ts_us: Optional[float] = None) -> None:
        self._append({"ph": "C", "pid": self._pid, "tid": ENGINE_TID,
                      "name": name, "cat": "serve",
                      "ts": self.now() if ts_us is None else float(ts_us),
                      "args": {k: float(v) for k, v in values.items()}})

    # -- export -----------------------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def to_chrome_trace(self) -> Dict[str, Any]:
        """``{"traceEvents": [...]}`` with events sorted by timestamp
        (metadata first), ready for ``json.dump`` → Perfetto."""
        evs = self.events()
        evs.sort(key=lambda e: (e["ph"] != "M", e["ts"]))
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)

    # -- structural summary (for tests) -----------------------------------
    def span_structure(self) -> List[tuple]:
        """Timestamp-free span summary: sorted ``(tid, name, status)``
        tuples for every complete span.  Two runs of the same request set
        must agree here regardless of ``sync_every`` batching."""
        out = []
        for ev in self.events():
            if ev["ph"] != "X":
                continue
            status = (ev.get("args") or {}).get("status", "")
            out.append((ev["tid"], ev["name"], status))
        return sorted(out)
