"""Observability smoke (the CI ``obs`` job's sanity layer).

``python -m repro.obs`` serves a tiny dense model through the
continuous-batching engine with every observability layer on —
per-engine metrics, the process-global dispatch/tune/guard telemetry,
the Chrome request trace, and the ``obs.enable()`` profiler annotations
— then checks the acceptance contract end to end:

  * the metrics snapshot's dispatch-resolution counters name the winning
    impl per resolved op (``ff_dispatch_resolutions_total{op=...,
    impl=..., source=...}``);
  * the trace is Perfetto-loadable Chrome JSON (``json.loads``
    round-trip) with ONE complete ``request`` span per submitted
    request, each carrying a documented terminal status, and monotone
    non-negative timestamps;
  * guard/serve counters and latency histograms populated.

Exits non-zero listing every violated check.  ``--metrics-json`` /
``--trace-out`` write the artifacts (CI uploads them).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_f = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _f:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _f).strip()

FAILURES = []


def check(cond: bool, what: str) -> None:
    mark = "ok" if cond else "FAIL"
    print(f"  [{mark}] {what}")
    if not cond:
        FAILURES.append(what)


def main() -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    ap.add_argument("--metrics-json", type=str, default=None)
    ap.add_argument("--trace-out", type=str, default=None)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=6)
    args = ap.parse_args()

    import numpy as np
    import jax

    import repro.ff as ff
    from repro import obs
    from repro.models import init_params
    from repro.models.config import ModelConfig
    from repro.serve import STATUSES, Request, ServeEngine

    cfg = ModelConfig(name="obs-smoke", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256, max_seq_len=64,
                      compute_dtype="float32", remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)

    print("obs: instrumented serving smoke (guard=check, profiling on)")
    observer = obs.Observer()
    before = obs.REGISTRY.snapshot()
    with obs.enable(), ff.policy("ff_reduce"):
        # an Ozaki-class matmul so the accurate tier shows up in the
        # dispatch telemetry next to the engine's fast-path resolutions
        a = jax.numpy.ones((64, 64), jax.numpy.float32)
        ff.matmul(a, a, impl="ozaki").to_f32().block_until_ready()
        eng = ServeEngine(params, cfg, max_batch=2, page_size=4,
                          max_ctx=32, guard="check", obs=observer)
        for i in range(args.requests):
            eng.submit(Request(
                uid=i,
                prompt=rng.integers(
                    1, cfg.vocab_size,
                    size=int(rng.integers(6, 14))).astype(np.int32),
                max_new=args.max_new))
        results = eng.run()

    check(sorted(results) == list(range(args.requests)),
          "every submitted request terminated")
    check(all(r.status in STATUSES for r in results.values()),
          "every status documented")

    # -- metrics contract --------------------------------------------------
    delta = obs.REGISTRY.delta(before)
    resolved = {}
    for series, n in delta["counters"].items():
        if n and series.startswith("ff_dispatch_resolutions_total"):
            labels = dict(kv.split("=", 1) for kv in
                          series.split("{", 1)[1].rstrip("}").split(","))
            op = labels["op"].strip('"')
            resolved.setdefault(op, set()).add(
                (labels["impl"].strip('"'), labels["source"].strip('"')))
    check(bool(resolved),
          "dispatch-resolution counters recorded during the run")
    check(all(impl for impls in resolved.values() for impl, _ in impls),
          "each resolution names the winning impl")
    check(any(impl == "ozaki" for i, _ in resolved.get("matmul", set())
              for impl in [i]),
          "explicit ozaki matmul resolution visible in telemetry")
    for op, impls in sorted(resolved.items()):
        wins = ", ".join(f"{i} ({s})" for i, s in sorted(impls))
        print(f"    ff.{op}: {wins}")
    snap = observer.snapshot()
    check(snap["counters"].get('serve_requests_total{status="OK"}', 0)
          + snap["counters"].get('serve_requests_total{status="DEGRADED"}',
                                 0) >= 1,
          "engine request counters populated")
    check(snap["histograms"].get("serve_decode_step_seconds",
                                 {}).get("count", 0) > 0,
          "decode-step latency histogram populated")
    prom = observer.registry.to_prometheus() + obs.REGISTRY.to_prometheus()
    check("serve_guard_events_total" in prom
          and "ff_dispatch_resolutions_total" in prom,
          "Prometheus text exposition includes both registries")

    # -- trace contract ----------------------------------------------------
    payload = json.loads(json.dumps(observer.to_chrome_trace()))
    evs = payload["traceEvents"]
    spans = [e for e in evs if e["ph"] == "X" and e["name"] == "request"]
    check(len(spans) == args.requests,
          f"one complete request span per request "
          f"({len(spans)}/{args.requests})")
    check(all(e["args"]["status"] in STATUSES for e in spans),
          "every request span carries a documented terminal status")
    ts = [e["ts"] for e in evs if e["ph"] != "M"]
    check(all(t >= 0 for t in ts) and ts == sorted(ts),
          "trace timestamps monotone non-negative after export sort")
    check(all(e.get("dur", 0) >= 0 for e in evs if e["ph"] == "X"),
          "span durations non-negative")

    if args.metrics_json:
        observer.dump_metrics(args.metrics_json)
        print(f"  metrics -> {args.metrics_json}")
    if args.trace_out:
        observer.dump_trace(args.trace_out)
        print(f"  trace   -> {args.trace_out}")

    print()
    if FAILURES:
        print(f"obs smoke: {len(FAILURES)} check(s) FAILED")
        for f in FAILURES:
            print(f"  - {f}")
        return 1
    print("obs smoke: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
