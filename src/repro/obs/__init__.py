"""``repro.obs`` — metrics, tracing, and profiling for the FF system.

Three layers, all host-side and stdlib-only at import time (``repro.obs``
must never import ``repro.ff`` — dispatch/guard/tuning import *us*
lazily, and a cycle here would break the registry bootstrap):

* **Metrics** (:mod:`repro.obs.registry`): thread-safe counters / gauges /
  log2-bucket histograms with snapshot/delta and JSON + Prometheus
  exposition.  A process-global registry (:data:`REGISTRY`) collects
  dispatch-resolution, tune-cache, and warning counters — recorded at
  *trace* time only, so steady-state jit execution pays zero cost.
  Engines carry their own per-instance registry (via :class:`Observer`)
  so concurrent engines and tests don't share counts.

* **Tracing** (:mod:`repro.obs.trace`): Chrome trace-event JSON
  (Perfetto-loadable) — per-request span timelines and per-step engine
  events.

* **Profiling** (:mod:`repro.obs.profiling`): ``obs.enable()`` scope
  gating ``jax.profiler.TraceAnnotation``/``named_scope`` wrappers around
  prefill, decode, Ozaki matmul, and the sharded combines.

``python -m repro.obs`` runs an instrumented serving smoke and emits both
artifacts — see :mod:`repro.obs.__main__`.
"""

from __future__ import annotations

from typing import Optional

from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                LOG2_BUCKETS)
from repro.obs.trace import TraceRecorder, ENGINE_TID
from repro.obs.profiling import annotate, enable, enabled

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "LOG2_BUCKETS",
    "TraceRecorder", "ENGINE_TID",
    "annotate", "enable", "enabled",
    "REGISTRY", "Observer",
    "record_resolution", "record_tune_lookup", "record_warning",
    "record_guard_violation", "record_journal_event",
]

# Process-global registry: dispatch/tuning/guard telemetry that isn't tied
# to one engine instance.  Tests bracket assertions with snapshot/delta.
REGISTRY = MetricsRegistry()


# -- hooks called (lazily) from repro.ff internals -------------------------

def record_resolution(op: str, impl: str, source: str, backend: str,
                      shape_bucket: str) -> None:
    """One dispatch resolution: ``op`` resolved to ``impl`` because of
    ``source`` (explicit/scope/policy/mesh/tuned/.../guard_degraded) on
    ``backend`` for the pow2 ``shape_bucket``.  Trace-time only."""
    REGISTRY.counter("ff_dispatch_resolutions_total", op=op, impl=impl,
                     source=source, backend=backend,
                     shape=shape_bucket).inc()


def record_tune_lookup(hit: bool) -> None:
    REGISTRY.counter("ff_tune_cache_total",
                     result=("hit" if hit else "miss")).inc()


def record_warning(kind: str) -> None:
    """``kind`` in {"tune", "guard"} — one FFTuneWarning/FFGuardWarning
    *event* (counted even when the warning itself is warn-once
    suppressed)."""
    REGISTRY.counter("ff_warnings_total", kind=kind).inc()


def record_guard_violation(op: str, kind: str, count: int = 1) -> None:
    """Per-(op, kind) guard violation count; accumulates unconditionally,
    unlike the warn-once user-facing warning."""
    if count > 0:
        REGISTRY.counter("ff_guard_violations_total",
                         op=op, kind=kind).inc(int(count))


def record_journal_event(event: str, n: int = 1) -> None:
    """Write-ahead-journal activity: append/retire/compact/truncate."""
    REGISTRY.counter("serve_journal_events_total", event=event).inc(int(n))


class Observer:
    """Per-engine observability bundle: a private metrics registry plus a
    trace recorder.  ``ServeEngine(obs=...)`` accepts one; when omitted the
    engine builds its own so counter assertions stay per-instance."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 trace: Optional[TraceRecorder] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.trace = trace if trace is not None else TraceRecorder()

    def snapshot(self) -> dict:
        return self.registry.snapshot()

    def delta(self, prev: Optional[dict]) -> dict:
        return self.registry.delta(prev)

    def to_chrome_trace(self) -> dict:
        return self.trace.to_chrome_trace()

    def dump_trace(self, path: str) -> None:
        self.trace.dump(path)

    def dump_metrics(self, path: str,
                     extra: Optional[MetricsRegistry] = None) -> None:
        """Write a combined metrics JSON: this observer's registry plus the
        process-global one (dispatch/tune/guard counters) — the artifact
        ``launch/serve.py --metrics-json`` uploads."""
        import json
        payload = {"engine": self.registry.snapshot(),
                   "global": (extra if extra is not None
                              else REGISTRY).snapshot()}
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
