"""Scoped profiler annotations (``obs.enable()`` / ``obs.annotate``).

The hot paths — engine prefill, the jitted decode step, the Ozaki matmul
slices, the sharded combines — are wrapped in :func:`annotate`.  Outside
an :class:`enable` scope that wrapper is a no-op ``nullcontext`` (one
thread-local list check, nothing allocated), so the default serving path
pays effectively nothing.  Inside the scope it enters both

* :class:`jax.profiler.TraceAnnotation` — names the host-side dispatch
  region in ``jax.profiler.trace`` / TensorBoard / Perfetto captures; and
* :func:`jax.named_scope` — names the traced XLA ops so the annotation
  survives into compiled-program profiles,

mirroring the ``ff.policy`` thread-local-stack idiom: enter the scope
before tracing/profiling, per-thread, re-entrant.
"""

from __future__ import annotations

import contextlib
import threading

__all__ = ["enable", "enabled", "annotate"]


class _ObsState(threading.local):
    def __init__(self):
        self.stack = []


_STATE = _ObsState()


def enabled() -> bool:
    """True inside an ``obs.enable()`` scope (innermost wins)."""
    return bool(_STATE.stack) and _STATE.stack[-1]


class enable:
    """Context manager toggling profiler annotations for the scope.

    ``obs.enable()`` turns annotations on; ``obs.enable(False)`` forces
    them off for an inner region (same disabler idiom as
    ``ff.on_mesh(None)``)."""

    def __init__(self, on: bool = True):
        self._on = bool(on)

    def __enter__(self) -> bool:
        _STATE.stack.append(self._on)
        return self._on

    def __exit__(self, *exc):
        _STATE.stack.pop()
        return False


def annotate(name: str):
    """Combined ``TraceAnnotation`` + ``named_scope`` when enabled,
    ``nullcontext`` otherwise.  Import of jax is deferred so the metrics
    registry stays importable in jax-free tooling contexts."""
    if not enabled():
        return contextlib.nullcontext()
    try:
        import jax
        import jax.profiler
    except Exception:                      # pragma: no cover - jax-free env
        return contextlib.nullcontext()
    stack = contextlib.ExitStack()
    stack.enter_context(jax.profiler.TraceAnnotation(name))
    stack.enter_context(jax.named_scope(name))
    return stack
