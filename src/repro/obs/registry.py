"""Thread-safe in-process metrics: counters, gauges, log2 histograms.

The registry is deliberately tiny and stdlib-only — serving-engine steps
and dispatch resolution record into it from Python (host) code, so the
cost model is "a dict lookup and an integer add under a lock", a few
hundred nanoseconds per event.  Everything hot in the numeric path stays
inside jit; nothing here is ever traced.

Exposition formats:

* :meth:`MetricsRegistry.snapshot` — a plain ``dict`` (JSON-ready) that
  tests and the chaos tier assert on;
* :meth:`MetricsRegistry.delta` — counter/histogram differences against a
  previous snapshot (gauges report their current value), so a test can
  bracket exactly one engine run;
* :meth:`MetricsRegistry.to_json` / :meth:`MetricsRegistry.to_prometheus`
  — the serialized forms ``launch/serve.py --metrics-json`` and
  ``--metrics-port`` emit.

Histograms use fixed log2 buckets: upper bounds ``2**e`` for
``e in [LOG2_LO, LOG2_HI)`` plus ``+Inf``.  With the default range the
buckets span 1 µs .. 64 s, wide enough for both a single decode step and
a cold restore, and *fixed* so two snapshots are always subtractable.
"""

from __future__ import annotations

import json
import math
import threading
from typing import Dict, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "LOG2_LO", "LOG2_HI", "LOG2_BUCKETS",
]

# Fixed log2 bucket upper bounds (seconds): 2^-20 s ~ 1 us .. 2^6 = 64 s.
LOG2_LO = -20
LOG2_HI = 7
LOG2_BUCKETS: Tuple[float, ...] = tuple(
    float(2.0 ** e) for e in range(LOG2_LO, LOG2_HI))


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


class Counter:
    """Monotonic counter.  ``inc`` is the hot call; ``set`` exists only so
    snapshot *restore* paths (e.g. ``ServeEngine.restore``) can resume a
    persisted value."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    def set(self, v: int) -> None:
        with self._lock:
            self._value = int(v)

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Point-in-time value (queue depth, pool occupancy)."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Histogram over the fixed log2 buckets (plus +Inf overflow)."""

    __slots__ = ("_lock", "_counts", "_sum", "_count")

    def __init__(self):
        self._lock = threading.Lock()
        self._counts = [0] * (len(LOG2_BUCKETS) + 1)
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        if v <= 0.0 or math.isnan(v):
            idx = 0
        elif v > LOG2_BUCKETS[-1]:
            idx = len(LOG2_BUCKETS)          # +Inf overflow bucket
        else:
            # first bucket whose upper bound >= v:  2^ceil(log2 v)
            e = math.ceil(math.log2(v))
            idx = min(max(e - LOG2_LO, 0), len(LOG2_BUCKETS) - 1)
        with self._lock:
            self._counts[idx] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def buckets(self) -> List[Tuple[float, int]]:
        """Cumulative (le, count) pairs, Prometheus-style, ending at +Inf."""
        out, cum = [], 0
        with self._lock:
            counts = list(self._counts)
        for le, c in zip(LOG2_BUCKETS, counts[:-1]):
            cum += c
            out.append((le, cum))
        out.append((math.inf, cum + counts[-1]))
        return out


class MetricsRegistry:
    """Named, labeled metric families; creation is lazy and idempotent."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[Tuple[str, tuple], Counter] = {}
        self._gauges: Dict[Tuple[str, tuple], Gauge] = {}
        self._histograms: Dict[Tuple[str, tuple], Histogram] = {}

    # -- metric accessors -------------------------------------------------
    def counter(self, name: str, **labels: str) -> Counter:
        key = (name, _label_key(labels))
        c = self._counters.get(key)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(key, Counter())
        return c

    def gauge(self, name: str, **labels: str) -> Gauge:
        key = (name, _label_key(labels))
        g = self._gauges.get(key)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(key, Gauge())
        return g

    def histogram(self, name: str, **labels: str) -> Histogram:
        key = (name, _label_key(labels))
        h = self._histograms.get(key)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(key, Histogram())
        return h

    # -- snapshot / delta --------------------------------------------------
    @staticmethod
    def _series_name(key: Tuple[str, tuple]) -> str:
        name, labels = key
        return name + _fmt_labels(labels)

    def snapshot(self) -> Dict[str, dict]:
        """Plain-dict snapshot: ``{"counters": {...}, "gauges": {...},
        "histograms": {series: {count, sum, buckets}}}``."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._histograms)
        snap: Dict[str, dict] = {"counters": {}, "gauges": {}, "histograms": {}}
        for key, c in sorted(counters.items()):
            snap["counters"][self._series_name(key)] = c.value
        for key, g in sorted(gauges.items()):
            snap["gauges"][self._series_name(key)] = g.value
        for key, h in sorted(hists.items()):
            snap["histograms"][self._series_name(key)] = {
                "count": h.count,
                "sum": h.sum,
                "buckets": [[("+Inf" if math.isinf(le) else le), n]
                            for le, n in h.buckets()],
            }
        return snap

    def delta(self, prev: Optional[Dict[str, dict]]) -> Dict[str, dict]:
        """Current snapshot minus ``prev`` (counters and histogram counts
        subtract; gauges pass through).  ``prev=None`` == full snapshot."""
        cur = self.snapshot()
        if not prev:
            return cur
        out: Dict[str, dict] = {"counters": {}, "gauges": dict(cur["gauges"]),
                                "histograms": {}}
        pc = prev.get("counters", {})
        for name, v in cur["counters"].items():
            out["counters"][name] = v - pc.get(name, 0)
        ph = prev.get("histograms", {})
        for name, h in cur["histograms"].items():
            p = ph.get(name, {"count": 0, "sum": 0.0})
            out["histograms"][name] = {
                "count": h["count"] - p.get("count", 0),
                "sum": h["sum"] - p.get("sum", 0.0),
                "buckets": h["buckets"],
            }
        return out

    # -- exposition --------------------------------------------------------
    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def to_prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4)."""
        lines: List[str] = []
        with self._lock:
            counters = sorted(self._counters.items())
            gauges = sorted(self._gauges.items())
            hists = sorted(self._histograms.items())
        seen_types: Dict[str, str] = {}

        def _header(name: str, kind: str) -> None:
            if seen_types.get(name) != kind:
                lines.append(f"# TYPE {name} {kind}")
                seen_types[name] = kind

        for (name, labels), c in counters:
            _header(name, "counter")
            lines.append(f"{name}{_fmt_labels(labels)} {c.value}")
        for (name, labels), g in gauges:
            _header(name, "gauge")
            lines.append(f"{name}{_fmt_labels(labels)} {g.value}")
        for (name, labels), h in hists:
            _header(name, "histogram")
            base = dict(labels)
            for le, cum in h.buckets():
                ble = "+Inf" if math.isinf(le) else repr(le)
                lab = _fmt_labels(_label_key({**base, "le": ble}))
                lines.append(f"{name}_bucket{lab} {cum}")
            lines.append(f"{name}_sum{_fmt_labels(labels)} {h.sum}")
            lines.append(f"{name}_count{_fmt_labels(labels)} {h.count}")
        return "\n".join(lines) + "\n"
