"""HLO artifact analysis for the roofline report.

* ``cost_summary(compiled)``       — flops / bytes from cost_analysis()
* ``collective_bytes(hlo_text)``   — per-collective-type byte totals parsed
  from the HLO module (cost_analysis does not expose collectives)
* ``depth_extrapolate``            — XLA counts ``while`` (scan) bodies ONCE
  (verified empirically); lowering depth-1 and depth-2 variants and solving
  linearly recovers exact full-depth totals.

Collective byte accounting (ring algorithms, per participating device):
  all-gather:          output is the gathered (full) tensor;  wire bytes
                       ~ (n-1)/n * full          -> we record full output
  reduce-scatter:      wire ~ (n-1)/n * input    -> record input (=out*n)
  all-reduce:          wire ~ 2(n-1)/n * size    -> record 2*size
  all-to-all:          wire ~ (n-1)/n * size     -> record size
  collective-permute:  record size
The (n-1)/n factor is applied in roofline.py where n is known.
"""

from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    """'f32[128,256]' -> bytes.  Tuples handled by caller via findall."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum collective op sizes by type over the whole module.

    Counts '-start' forms once (skips '-done').  Sizes taken from the
    defining (output) shape; all-reduce doubled per the ring model;
    reduce-scatter recorded as input size (= output * shards in the group,
    conservatively approximated by output bytes when group size is absent —
    roofline.py multiplies by group factors).
    """
    out: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?[a-z0-9]+\[[^=]*?)\s*"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start)?\(", line)
        if not m:
            continue
        if "-done" in line.split("=")[1][:60]:
            continue
        shape_str, op = m.group(1), m.group(2)
        sz = _shape_bytes(shape_str)
        if op == "all-reduce":
            sz *= 2
        out[op] += sz
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def cost_summary(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        out[k] = float(getattr(ma, k, 0) or 0)
    out["per_device_total"] = (out["argument_size_in_bytes"]
                               + out["output_size_in_bytes"]
                               + out["temp_size_in_bytes"]
                               - out.get("alias_size_in_bytes", 0.0))
    return out


def depth_extrapolate(vals_d1: Dict[str, float], vals_d2: Dict[str, float],
                      depth: int) -> Dict[str, float]:
    """Linear extrapolation: f(L) = f(1) + (L-1) * (f(2) - f(1)).

    Negative per-layer deltas (parsing noise) are clamped to 0.
    """
    out = {}
    keys = set(vals_d1) | set(vals_d2)
    for k in keys:
        a = vals_d1.get(k, 0.0)
        b = vals_d2.get(k, 0.0)
        per_layer = max(b - a, 0.0)
        out[k] = a + (depth - 1) * per_layer
    return out
