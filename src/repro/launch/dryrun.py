import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct inputs (no allocation), print
memory_analysis / cost_analysis, parse collective bytes, and write the
artifact JSON that benchmarks/roofline.py consumes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes

Depth extrapolation: XLA's cost_analysis counts scan (while) bodies once,
so per-cell we additionally lower depth-1/depth-2 (per scan unit) variants
and extrapolate flops/bytes/collective-bytes linearly to the full depth.
memory_analysis comes from the FULL-depth compile (stacked params are real).
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, ShapeSpec, cell_applicable, get_config
from repro.core.policy import PrecisionPolicy
from repro.distributed import sharding as shd
from repro.distributed import act_sharding as act_shd
from repro.launch import hlo_analysis as hla
from repro.launch import hlo_costs
from repro.launch.mesh import make_production_mesh
from repro.models import init_params, init_cache
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamW
from repro.train.train_step import make_train_step


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; weak-type-correct, shardable)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, spec: ShapeSpec) -> Dict[str, Any]:
    B, S = spec.global_batch, spec.seq_len
    if spec.kind == "train":
        d = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    elif spec.kind == "prefill":
        d = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    else:  # decode: one new token against a seq_len cache
        d = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    if cfg.family == "vlm" and spec.kind != "decode":
        d["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec" and spec.kind != "decode":
        d["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return d


def _shapes_of(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _depth_variants(cfg: ModelConfig):
    """[(name, cfg, depth_value)] for linear flop extrapolation."""
    if cfg.family == "encdec":
        c11 = dataclasses.replace(cfg, num_layers=1, encoder_layers=1)
        c21 = dataclasses.replace(cfg, num_layers=1, encoder_layers=2)
        c12 = dataclasses.replace(cfg, num_layers=2, encoder_layers=1)
        return ("encdec", [c11, c21, c12])
    if cfg.family == "hybrid":
        per = cfg.attn_every
        c1 = dataclasses.replace(cfg, num_layers=per)
        c2 = dataclasses.replace(cfg, num_layers=2 * per)
        return ("stack", [c1, c2])
    c1 = dataclasses.replace(cfg, num_layers=1)
    c2 = dataclasses.replace(cfg, num_layers=2)
    return ("stack", [c1, c2])


def _full_depth(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_every
    return cfg.num_layers


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------

def _lower_cell(cfg: ModelConfig, spec: ShapeSpec, mesh,
                policy: PrecisionPolicy) -> Tuple[Any, Any]:
    """Return (lowered, compiled) for one (cfg, shape, mesh)."""
    B, S = spec.global_batch, spec.seq_len
    params_shapes = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    p_sh = shd.param_shardings(params_shapes, cfg, mesh)
    batch = input_specs(cfg, spec)
    b_sh = shd.batch_shardings(batch, mesh)

    if spec.kind == "train":
        opt = AdamW(learning_rate=1e-3, ff=policy.ff_master_weights)
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        o_sh = shd.opt_state_shardings(None, p_sh)
        step = make_train_step(cfg, policy, opt, microbatches=1)
        rep = NamedSharding(mesh, P())
        metrics_sh = {"loss": rep, "aux": rep, "grad_norm": rep, "lr": rep}
        fn = jax.jit(step,
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, metrics_sh),
                     donate_argnums=(0, 1))
        with mesh, act_shd.activation_sharding(mesh, cfg.d_model, B):
            lowered = fn.lower(params_shapes, opt_shapes, batch)
    else:
        cache_len = S if spec.kind != "prefill" else S
        extra = cfg.num_patches if cfg.family == "vlm" else 0
        cache_shapes = jax.eval_shape(
            lambda: init_cache(cfg, B, cache_len + extra, jnp.bfloat16))
        c_sh = shd.cache_shardings(cache_shapes, cfg, mesh, B)
        rep = NamedSharding(mesh, P())
        daxes = shd._dp_for_batch(B, mesh)
        logits_spec = shd.validate_spec(
            P(daxes, "model"), (B, cfg.vocab_size), mesh)
        logits_sh = NamedSharding(mesh, logits_spec)
        if spec.kind == "prefill":
            from repro.train.serve_step import make_prefill_step
            step = make_prefill_step(cfg, policy)
            fn = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh),
                         out_shardings=(logits_sh, c_sh),
                         donate_argnums=(2,))
            with mesh, act_shd.activation_sharding(mesh, cfg.d_model, B):
                lowered = fn.lower(params_shapes, batch, cache_shapes)
        else:
            from repro.train.serve_step import make_decode_step
            step = make_decode_step(cfg, policy)
            tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            fn = jax.jit(step,
                         in_shardings=(p_sh, b_sh["tokens"], rep, c_sh),
                         out_shardings=(logits_sh, c_sh),
                         donate_argnums=(3,))
            with mesh, act_shd.activation_sharding(mesh, cfg.d_model, B):
                lowered = fn.lower(params_shapes, tok, pos, cache_shapes)
    compiled = lowered.compile()
    return lowered, compiled


def run_cell(arch: str, shape: str, multi_pod: bool,
             policy: Optional[PrecisionPolicy] = None,
             cfg_override: Optional[ModelConfig] = None,
             verbose: bool = True) -> Dict[str, Any]:
    cfg = cfg_override or get_config(arch)
    spec = SHAPES[shape]
    ok, reason = cell_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "kind": spec.kind, "seq_len": spec.seq_len,
        "global_batch": spec.global_batch,
    }
    if not ok:
        result["status"] = "skipped"
        result["reason"] = reason
        return result
    policy = policy or PrecisionPolicy.make("ff_master")
    result["policy"] = policy.level
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    # full-depth compile: memory analysis + trip-count-aware HLO cost walk
    # (XLA's cost_analysis counts while bodies once — hlo_costs multiplies
    # by the known_trip_count annotations instead; see hlo_costs.py)
    lowered, compiled = _lower_cell(cfg, spec, mesh, policy)
    result["memory"] = hla.memory_summary(compiled)
    parsed = hlo_costs.analyze_text(compiled.as_text())
    cost = {"flops": parsed["flops"], "bytes": parsed["hbm_bytes"]}
    coll = {k: parsed.get(k, 0.0) for k in hlo_costs.COLLECTIVE_OPS}
    coll["total"] = parsed["collective_bytes"]

    result["cost"] = cost
    result["cost_xla_while_body_once"] = hla.cost_summary(compiled)
    result["collectives"] = coll
    result["compile_seconds"] = time.time() - t0
    result["status"] = "ok"

    if verbose:
        ma = result["memory"]
        print(f"=== {arch} x {shape} x {mesh_name} ===")
        print(f"  memory/device: args {ma['argument_size_in_bytes']/2**30:.2f} GiB, "
              f"temp {ma['temp_size_in_bytes']/2**30:.2f} GiB, "
              f"out {ma['output_size_in_bytes']/2**30:.2f} GiB "
              f"(aliased {ma['alias_size_in_bytes']/2**30:.2f} GiB)")
        print(f"  HLO flops (extrapolated): {cost['flops']:.3e}  "
              f"bytes: {cost['bytes']:.3e}")
        print(f"  collective bytes: {coll['total']:.3e} "
              f"(AG {coll['all-gather']:.2e} AR {coll['all-reduce']:.2e} "
              f"RS {coll['reduce-scatter']:.2e} A2A {coll['all-to-all']:.2e} "
              f"CP {coll['collective-permute']:.2e})")
        print(f"  compile: {result['compile_seconds']:.1f}s")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--policy", default="ff_master")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    policy = PrecisionPolicy.make(args.policy)
    failures = 0
    for multi_pod in meshes:
        for arch, shape in cells:
            tag = f"{arch.replace('-', '_')}__{shape}__" + \
                ("2x16x16" if multi_pod else "16x16")
            out_path = os.path.join(args.out, tag + ".json")
            try:
                res = run_cell(arch, shape, multi_pod, policy=policy)
            except Exception as e:  # a failing cell is a bug: record + count
                traceback.print_exc()
                res = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if multi_pod else "16x16",
                       "status": "FAILED", "error": f"{type(e).__name__}: {e}"}
                failures += 1
            with open(out_path, "w") as f:
                json.dump(res, f, indent=1)
    print(f"\ndry-run complete: {len(cells) * len(meshes)} cells, "
          f"{failures} failures")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
