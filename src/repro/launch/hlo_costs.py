"""Trip-count-aware HLO cost model.

XLA's built-in ``cost_analysis()`` counts each ``while`` body ONCE, which
undercounts scan-over-layers models by ~L x and misses in-loop collectives
entirely (measured: a 40-layer model reported ~1 layer of flops).  This
module parses the post-optimization HLO text — where XLA annotates every
loop with ``backend_config={"known_trip_count":{"n":...}}`` — and walks the
call graph multiplying per-computation costs by trip counts.

Costs:
  flops       — dots: 2 * prod(output dims) * prod(contracting dims)
                (batch dims land in the output product, so this is exact);
                other ops: 1 flop per output element (minor terms).
  hbm_bytes   — produced-tensor flow model: every materialized (non-fused)
                output counts write+read (2x output bytes).  Operand reads
                are thereby attributed to their producer; sparse reads
                (embedding gathers, dynamic slices of stacked weights) are
                counted at slice size, not table size — counting operand
                footprints instead was measured to overcount ~500x on
                scanned FSDP models.
  collectives — per-type byte totals: output-shape bytes, all-reduce
                doubled (ring), '-start' counted / '-done' skipped.

Validated against cost_analysis() on loop-free modules (exact match on
dot flops) and against 6ND analytics on scanned transformers.
"""

from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_ATOM = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w\-]+)\((.*)$")
_OPERAND = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"(?:calls|body|to_apply)=%([\w.\-]+)")
_BODY = re.compile(r"body=%([\w.\-]+)")
_COND = re.compile(r"condition=%([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_ATOM.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        dl = [int(d) for d in dims.split(",") if d] if dims else []
        out.append((dtype, dl))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(shape_str: str) -> int:
    total = 0
    for _, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


class Instr:
    __slots__ = ("name", "shape", "op", "rest")

    def __init__(self, name, shape, op, rest):
        self.name = name
        self.shape = shape
        self.op = op
        self.rest = rest


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.instr_shapes: Dict[Tuple[str, str], str] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Dict[str, float]] = {}

    # ------------------------------------------------------------------
    _COMMENT = re.compile(r"/\*.*?\*/")

    def _parse(self, text: str) -> None:
        current = None
        for raw in text.splitlines():
            if "/*" in raw:
                raw = self._COMMENT.sub("", raw)
            if raw and not raw.startswith(" ") and "{" in raw:
                m = re.match(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(", raw)
                if m:
                    current = m.group(2)
                    self.computations[current] = []
                    if m.group(1):
                        self.entry = current
                    continue
                current = None
                continue
            if current is None:
                continue
            if raw.strip() == "}":
                current = None
                continue
            m = _INSTR.match(raw)
            if not m:
                continue
            name, shape, op, rest = m.groups()
            ins = Instr(name, shape, op, rest)
            self.computations[current].append(ins)
            self.instr_shapes[(current, name)] = shape

    # ------------------------------------------------------------------
    def _dot_flops(self, comp: str, ins: Instr) -> float:
        out_elems = _shape_elems(ins.shape)
        mc = _LHS_C.search(ins.rest)
        cdims = [int(x) for x in mc.group(1).split(",") if x] if mc else []
        ops = _OPERAND.findall(ins.rest.split(", lhs_contracting")[0])
        contract = 1
        if ops:
            lhs_shape = self.instr_shapes.get((comp, ops[0]))
            if lhs_shape:
                dims = _shape_dims(lhs_shape)
                if dims:
                    dl = dims[0][1]
                    for c in cdims:
                        if c < len(dl):
                            contract *= dl[c]
        return 2.0 * out_elems * contract

    _SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "copy-start", "copy-done", "after-all",
                   "partition-id", "replica-id", "iota"}

    def _instr_cost(self, comp: str, ins: Instr) -> Dict[str, float]:
        cost = {"flops": 0.0, "hbm_bytes": 0.0}
        for c in COLLECTIVE_OPS:
            cost[c] = 0.0
        op = ins.op

        if op == "while":
            trips = 1.0
            mt = _TRIP.search(ins.rest)
            if mt:
                trips = float(mt.group(1))
            body = _BODY.search(ins.rest)
            cond = _COND.search(ins.rest)
            for ref in (body, cond):
                if ref:
                    sub = self.comp_cost(ref.group(1))
                    for k, v in sub.items():
                        cost[k] += trips * v
            return cost

        if op == "conditional":
            mb = _BRANCHES.search(ins.rest)
            if mb:
                branches = _OPERAND.findall(mb.group(1))
                subs = [self.comp_cost(b) for b in branches]
                if subs:
                    for k in cost:
                        cost[k] += max(s.get(k, 0.0) for s in subs)
            return cost

        base_op = op[:-6] if op.endswith("-start") else op
        if base_op in COLLECTIVE_OPS:
            sz = float(_shape_bytes(ins.shape))
            if base_op == "all-gather" and op.endswith("-start"):
                # -start output includes the (input, output) tuple; halve-ish:
                # use output entry = gathered tensor ≈ 2/3 of tuple bytes;
                # keep full tuple as a conservative upper bound instead.
                pass
            if base_op == "all-reduce":
                sz *= 2.0
            cost[base_op] += sz
            cost["hbm_bytes"] += float(_shape_bytes(ins.shape))
            return cost
        if op.endswith("-done"):
            return cost

        if op in ("fusion", "call", "async-start"):
            mc = _CALLS.search(ins.rest)
            sub_root_dus_bytes = None
            if mc:
                sub = self.comp_cost(mc.group(1))
                for k, v in sub.items():
                    if op == "fusion" and k == "hbm_bytes":
                        continue   # fusion internals are VMEM-resident
                    cost[k] += v
                sub_root_dus_bytes = (self._dus_root_bytes(mc.group(1))
                                      if op == "fusion" else None)
            if sub_root_dus_bytes is not None:
                # fusion rooted in dynamic-update-slice: an IN-PLACE slice
                # write (XLA aliases the buffer); only the slice moves.
                cost["hbm_bytes"] += 2.0 * sub_root_dus_bytes
            else:
                cost["hbm_bytes"] += self._boundary_bytes(comp, ins)
            return cost

        if op == "dynamic-update-slice":
            upd = self._operand_shape(comp, ins, 1)
            cost["hbm_bytes"] += 2.0 * (_shape_bytes(upd) if upd else
                                        _shape_bytes(ins.shape))
            return cost

        if op == "dot":
            cost["flops"] += self._dot_flops(comp, ins)
            cost["hbm_bytes"] += self._boundary_bytes(comp, ins)
            return cost

        if op in ("reduce", "reduce-window", "scatter", "select-and-scatter",
                  "sort", "custom-call"):
            cost["flops"] += float(_shape_elems(ins.shape))
            cost["hbm_bytes"] += self._boundary_bytes(comp, ins)
            return cost

        if op in self._SKIP_BYTES:
            return cost

        # generic elementwise-ish op
        cost["flops"] += float(_shape_elems(ins.shape))
        cost["hbm_bytes"] += self._boundary_bytes(comp, ins)
        return cost

    def _boundary_bytes(self, comp: str, ins: Instr) -> float:
        # produced-bytes flow model: write + one subsequent read
        return 2.0 * float(_shape_bytes(ins.shape))

    def _operand_shape(self, comp: str, ins: Instr, idx: int):
        args = ins.rest.split("),")[0]
        names = _OPERAND.findall(args)
        if idx < len(names):
            return self.instr_shapes.get((comp, names[idx]))
        return None

    def _dus_root_bytes(self, comp: str):
        """If the computation's ROOT is a dynamic-update-slice (possibly
        wrapped in convert/bitcast/copy — XLA:CPU round-trips the carried
        buffer through f32), return the update slice's bytes, else None.
        DUS is an in-place slice write under buffer aliasing; counting the
        full buffer per scan step overstated llama-405b bytes by ~40%."""
        instrs = self.computations.get(comp, [])
        if not instrs:
            return None
        by_name = {i.name: i for i in instrs}
        root = instrs[-1]
        for _ in range(4):  # look through wrapper chain
            if root.op == "dynamic-update-slice":
                upd = self._operand_shape(comp, root, 1)
                return float(_shape_bytes(upd)) if upd else None
            if root.op in ("convert", "bitcast", "copy"):
                args = _OPERAND.findall(root.rest.split("),")[0])
                if args and args[0] in by_name:
                    root = by_name[args[0]]
                    continue
            break
        return None

    # ------------------------------------------------------------------
    def comp_cost(self, comp: str) -> Dict[str, float]:
        if comp in self._memo:
            return self._memo[comp]
        cost = {"flops": 0.0, "hbm_bytes": 0.0}
        for c in COLLECTIVE_OPS:
            cost[c] = 0.0
        self._memo[comp] = cost  # break cycles defensively
        for ins in self.computations.get(comp, []):
            # fused computations' internal elementwise costs are intra-VMEM:
            # counted as flops but their hbm handled at the boundary; we add
            # both (flops inside, bytes at fusion site in _instr_cost).
            sub = self._instr_cost(comp, ins)
            for k, v in sub.items():
                cost[k] += v
        return cost

    def totals(self) -> Dict[str, float]:
        if not self.entry:
            return {}
        out = dict(self.comp_cost(self.entry))
        out["collective_bytes"] = sum(out[c] for c in COLLECTIVE_OPS)
        return out


def analyze_text(hlo_text: str) -> Dict[str, float]:
    return HloCostModel(hlo_text).totals()


_METADATA_NAME = re.compile(r'op_name="([^"]*)"')


def top_bytes(hlo_text: str, k: int = 20):
    """Largest HBM-byte contributors (trip-multiplied), attributed to the
    producing JAX op via HLO metadata — the profiler substitute for the
    §Perf loop."""
    m = HloCostModel(hlo_text)
    contrib: Dict[str, float] = {}

    def walk(comp: str, mult: float):
        for ins in m.computations.get(comp, []):
            if ins.op == "while":
                mt = _TRIP.search(ins.rest)
                trips = float(mt.group(1)) if mt else 1.0
                for r in (_BODY.search(ins.rest), _COND.search(ins.rest)):
                    if r:
                        walk(r.group(1), mult * trips)
                continue
            if ins.op in m._SKIP_BYTES or ins.op.endswith("-done"):
                continue
            b = m._instr_cost(comp, ins)["hbm_bytes"] * mult
            if b <= 0:
                continue
            mm = _METADATA_NAME.search(ins.rest)
            name = mm.group(1) if mm else ins.op
            # collapse per-instruction noise to the jax-level op path
            key = f"{ins.op}:{name}"
            contrib[key] = contrib.get(key, 0.0) + b

    if m.entry:
        walk(m.entry, 1.0)
    return sorted(contrib.items(), key=lambda kv: -kv[1])[:k]
