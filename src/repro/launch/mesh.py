"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax
device state; the dry-run sets XLA_FLAGS for 512 host devices BEFORE
calling this.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the same axis names (smoke tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def make_local_data_mesh():
    """All local devices on the DATA axis (model=1).

    The mesh the ``--mesh`` launchers hand to ``ff.on_mesh``: the FF
    reductions partition over the data-parallel axis, so on a multi-device
    host the compensated cross-device combines actually engage
    (``make_local_mesh`` puts every device on 'model', leaving a size-1
    data axis — correct for TP layout experiments, inert for the mesh
    reduction tier)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
