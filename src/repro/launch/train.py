"""Training launcher: config-driven entry point.

Single-host CPU demo:
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --reduced \
      --steps 50 --policy ff_master

On a real multi-host TPU deployment the same entry point runs under
``jax.distributed.initialize()`` (one process per host); the data pipeline
shards by host id and the mesh comes from ``make_production_mesh``.
"""

import argparse
import os

_f = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _f:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _f).strip()

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-size variant (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--policy", default="ff_master")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", action="store_true",
                    help="build the local device mesh and route the step's "
                         "loss/grad reductions through the mesh-partitioned "
                         "FF tier (compensated cross-device combines)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.core.policy import PrecisionPolicy
    from repro.core.selfcheck import require_eft_safe
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models import init_params
    from repro.optim.adamw import AdamW, cosine_schedule
    from repro.train.train_step import make_train_step
    from repro.train.trainer import Trainer, TrainerConfig

    require_eft_safe()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    policy = PrecisionPolicy.make(args.policy,
                                  compute_dtype=cfg.compute_dtype)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.name}: {n/1e6:.1f}M params, policy={policy.level}")

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_local_data_mesh
        mesh = make_local_data_mesh()
        print(f"[train] mesh: {dict(mesh.shape)} — FF reductions are "
              f"mesh-partitioned (repro.ff.sharded)")
    opt = AdamW(learning_rate=cosine_schedule(args.lr, 10, args.steps),
                ff=policy.ff_master_weights)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, policy, opt,
                                      microbatches=args.microbatches,
                                      mesh=mesh),
                      donate_argnums=(0, 1))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch))

    def data_iter(i):
        return {k: jnp.asarray(v) for k, v in data.batch(i).items()}

    trainer = Trainer(
        TrainerConfig(total_steps=args.steps,
                      ckpt_every=max(args.steps // 3, 1),
                      ckpt_dir=args.ckpt_dir, log_every=10),
        step_fn, params, opt_state, data_iter)
    if args.ckpt_dir:
        trainer.restore()
    print(f"[train] done: {trainer.run()}")


if __name__ == "__main__":
    main()
