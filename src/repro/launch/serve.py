"""Serving launcher: batched prefill + greedy decode loop.

Demo:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m --reduced \
      --batch 4 --prompt-len 32 --max-new 16

``--engine`` routes dense archs through the continuous-batching
:class:`repro.serve.ServeEngine` (paged FF KV cache, per-request
mixed-length prompts, FF token-logprob scoring) instead of the one-shot
padded-batch greedy loop.
"""

import argparse
import os
import time

_f = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _f:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _f).strip()

import jax
import jax.numpy as jnp


def _start_metrics_server(observer, port: int):
    """Serve ``observer``'s registry (+ the global telemetry registry) as
    Prometheus text exposition on /metrics, in a daemon thread."""
    import threading
    from http.server import BaseHTTPRequestHandler, HTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path.rstrip("/") not in ("", "/metrics"):
                self.send_response(404)
                self.end_headers()
                return
            from repro import obs
            body = (observer.registry.to_prometheus()
                    + obs.REGISTRY.to_prometheus()).encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):            # quiet: stats, not access logs
            pass

    srv = HTTPServer(("127.0.0.1", port), Handler)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    return srv


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--engine", action="store_true",
                    help="serve through the continuous-batching ServeEngine "
                         "(paged KV cache; dense non-MLA archs)")
    ap.add_argument("--kv-mode", type=str, default="bf16",
                    choices=("bf16", "f32", "ff_bf16"),
                    help="--engine page storage: bf16 (baseline parity), "
                         "f32, or ff_bf16 (double-bf16 limb planes)")
    ap.add_argument("--guard", type=str, default="off",
                    choices=("off", "check", "degrade"),
                    help="--engine numeric guardrails: 'check' compiles the "
                         "per-step FF/KV health probe (quarantine + fast-tier "
                         "retry of poisoned rows), 'degrade' also drops "
                         "violating ops one accuracy class")
    ap.add_argument("--mesh", action="store_true",
                    help="shard params over the local device mesh and route "
                         "the scoring reductions through the mesh-aware FF "
                         "tier")
    ap.add_argument("--snapshot-dir", type=str, default=None,
                    help="--engine crash safety: directory for engine "
                         "snapshots (atomic CRC32'd checkpoints, "
                         "keep-last-3) and the write-ahead request journal")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="--engine: snapshot every N decode steps through "
                         "the async checkpointer (0 = off; requires "
                         "--snapshot-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="--engine: warm-restart from the newest VERIFIED "
                         "snapshot generation under --snapshot-dir (corrupt "
                         "generations fall back warned) and replay the "
                         "journal, instead of submitting fresh requests")
    ap.add_argument("--metrics-json", type=str, default=None,
                    help="--engine observability: write the metrics snapshot "
                         "(engine counters/gauges/histograms + the global "
                         "dispatch/tune/guard telemetry) to this JSON file "
                         "after the run")
    ap.add_argument("--trace-out", type=str, default=None,
                    help="--engine observability: write the Chrome "
                         "trace-event JSON (per-request spans + per-step "
                         "events; open in Perfetto) to this file")
    ap.add_argument("--metrics-port", type=int, default=0,
                    help="--engine observability: serve Prometheus text "
                         "exposition on http://127.0.0.1:PORT/metrics for "
                         "the duration of the run (0 = off)")
    args = ap.parse_args()
    if (args.snapshot_every or args.resume) and not args.snapshot_dir:
        ap.error("--snapshot-every/--resume require --snapshot-dir")
    if (args.metrics_json or args.trace_out or args.metrics_port) \
            and not args.engine:
        ap.error("--metrics-json/--trace-out/--metrics-port require --engine")

    import contextlib

    import repro.ff as ff
    from repro.configs import get_config
    from repro.models import init_params
    from repro.train.serve_step import greedy_generate

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh_scope = contextlib.nullcontext()
    if args.mesh:
        from repro.distributed.sharding import param_shardings
        from repro.launch.mesh import make_local_data_mesh
        mesh = make_local_data_mesh()
        params = jax.device_put(params, param_shardings(cfg=cfg, mesh=mesh,
                                                        params=params))
        mesh_scope = ff.on_mesh(mesh, axis="data")
        print(f"[serve] mesh: {dict(mesh.shape)} — params sharded, FF "
              f"scoring reductions mesh-routed")
    if args.engine:
        import numpy as np
        from repro import obs
        from repro.serve import Request, ServeEngine, resume_engine
        journal = (os.path.join(args.snapshot_dir, "wal.jsonl")
                   if args.snapshot_dir else None)
        observer = obs.Observer()
        metrics_server = None
        if args.metrics_port:
            metrics_server = _start_metrics_server(observer, args.metrics_port)
            print(f"[serve] metrics: http://127.0.0.1:{args.metrics_port}"
                  f"/metrics")
        rng = np.random.default_rng(1)
        lo = max(4, args.prompt_len // 2)
        lens = rng.integers(lo, args.prompt_len + 1, size=args.batch)
        if args.resume:
            t0 = time.perf_counter()
            eng = resume_engine(params, cfg, args.snapshot_dir,
                                journal=journal, max_batch=args.batch,
                                max_ctx=args.prompt_len + args.max_new + 8,
                                kv_mode=args.kv_mode, guard=args.guard,
                                obs=observer)
            n_restored = sum(s is not None for s in eng._slots)
            print(f"[serve] resumed from {args.snapshot_dir}: "
                  f"{len(eng.results)} completed, {n_restored} running, "
                  f"{len(eng.queue)} queued/replayed "
                  f"({time.perf_counter() - t0:.2f}s to warm state)")
        else:
            eng = ServeEngine(params, cfg, max_batch=args.batch,
                              max_ctx=args.prompt_len + args.max_new + 8,
                              kv_mode=args.kv_mode, guard=args.guard,
                              journal=journal, obs=observer)
            for i, l in enumerate(lens):
                eng.submit(Request(
                    uid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=int(l)).astype(np.int32),
                    max_new=args.max_new))
        t0 = time.perf_counter()
        results = eng.run(snapshot_dir=args.snapshot_dir,
                          snapshot_every=args.snapshot_every or None)
        dt = time.perf_counter() - t0
        n_tok = sum(len(r.tokens) for r in results.values())
        all_lps = np.concatenate(
            [r.logprobs for r in results.values()]
            or [np.zeros((0,), np.float32)])
        by_status: dict = {}
        for r in results.values():
            by_status[r.status] = by_status.get(r.status, 0) + 1
        status_str = " ".join(f"{k}={v}" for k, v in sorted(by_status.items()))
        mean_lp = float(all_lps.mean()) if all_lps.size else float("nan")
        print(f"[serve] {cfg.name} engine({args.kv_mode}, guard={args.guard}):"
              f" {len(results)} requests (prompts {lens.min()}..{lens.max()}),"
              f" {n_tok} tokens in {dt:.1f}s ({n_tok / dt:.1f} tok/s), mean "
              f"token logprob {mean_lp:.4f}, status {status_str}")
        if results:
            print(results[sorted(results)[0]].tokens)
        if args.metrics_json:
            observer.dump_metrics(args.metrics_json)
            print(f"[serve] metrics snapshot -> {args.metrics_json}")
        if args.trace_out:
            observer.dump_trace(args.trace_out)
            print(f"[serve] Perfetto trace ({len(observer.trace.events())} "
                  f"events) -> {args.trace_out}")
        if metrics_server is not None:
            metrics_server.shutdown()
        return
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len),
                                0, cfg.vocab_size)
    extra = {}
    if cfg.family == "vlm":
        extra["patches"] = jnp.zeros(
            (args.batch, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        extra["frames"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    t0 = time.perf_counter()
    with mesh_scope:
        toks, lps = greedy_generate(
            params, cfg, prompt, max_new=args.max_new,
            cache_len=args.prompt_len + args.max_new + 8
            + (cfg.num_patches if cfg.family == "vlm" else 0),
            extra_inputs=extra or None, return_logprobs=True)
        # sequence score: compensated FF sum of token logprobs — inside a
        # --mesh scope this is the mesh-partitioned ff.sum (compensated
        # cross-device combine); without it, the blocked cascade
        mean_lp = ff.sum(lps.reshape(-1)).to_f32() / lps.size
    dt = time.perf_counter() - t0
    print(f"[serve] {cfg.name}: generated {toks.shape} in {dt:.1f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s), "
          f"mean token logprob {float(mean_lp):.4f}")
    print(toks[0])


if __name__ == "__main__":
    main()
