"""repro.launch substrate."""
