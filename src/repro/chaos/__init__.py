"""``repro.chaos`` — deterministic fault injection for the FF serving tier.

Robustness claims are only as good as the faults they were tested
against.  This package injects the failure modes an FF serving system
actually meets — numeric poison in the limb planes, corrupted paging
metadata, exhausted page pools, truncated tuning sidecars, expired
deadlines — as *deterministic, seed-driven* perturbations, so every chaos
scenario is a reproducible test rather than a flake generator.

The contract under test (``docs/DESIGN_robustness.md``): with
``ff.guard`` active the engine finishes **every** submitted request with
a documented terminal status (``OK/TIMEOUT/REJECTED/DEGRADED/FAILED`` —
zero unhandled exceptions) and **never silently returns wrong tokens**:
a request that reports ``OK`` is token-for-token the healthy run, a
``DEGRADED`` one is token-for-token the fast-f32-tier run, and anything
the guard could not save is withheld as ``FAILED``.

Faults (all on :class:`~repro.chaos.inject.ChaosMonkey`):

  * :meth:`~repro.chaos.inject.ChaosMonkey.corrupt_kv_limbs` — NaN / Inf
    / subnormal-lo poison written into LIVE paged KV positions (stale
    pages are legal scratch — the documented cache invariant is
    "stale but finite", so chaos only targets positions a row will read);
  * :meth:`~repro.chaos.inject.ChaosMonkey.flip_block_table` — paging
    metadata corruption: duplicate, out-of-range, or free-list-colliding
    page ids;
  * :meth:`~repro.chaos.inject.ChaosMonkey.exhaust_pool` — steal free
    pages for a scope (forced allocation failure / preemption pressure);
  * :meth:`~repro.chaos.inject.ChaosMonkey.mangle_tune_json` — truncated
    / garbage / wrongly-typed ``FF_TUNE.json`` sidecars;
  * deadline forcing is plain data: submit a
    :class:`~repro.serve.Request` with ``deadline_steps=0``;
  * restart-tier corruption — :meth:`~repro.chaos.inject.ChaosMonkey.
    tear_checkpoint_tmp` (crash mid-save), :meth:`~repro.chaos.inject.
    ChaosMonkey.flip_checkpoint_bit` (bit-rot the CRC must catch), and
    :meth:`~repro.chaos.inject.ChaosMonkey.stale_manifest` (foreign /
    downgraded writer) against the engine snapshot store.

``python -m repro.chaos.restart`` (module :mod:`repro.chaos.restart`,
the CI ``chaos-restart`` job) goes one tier harsher: it SIGKILLs a
subprocess engine mid-decode and proves the warm restart
(:func:`repro.serve.resume_engine` — verified snapshot + write-ahead
journal replay) is token-for-token and FF-logprob bit-for-bit the
uninterrupted run, per ``kv_mode``.

``python -m repro.chaos`` runs the guarded-serving smoke (the CI chaos
job): a tiny model served under every fault class, exiting non-zero
unless every request lands in a documented terminal status with parity.
"""

from repro.chaos.inject import ChaosMonkey  # noqa: F401
