"""Kill-and-resume restart chaos: SIGKILL a subprocess engine mid-decode,
then warm-restart and prove exact-replay parity.

``python -m repro.chaos.restart`` (the CI ``chaos-restart`` job) runs the
full scenario per kv_mode:

  1. a CHILD process (``--child``) serves a deterministic request set
     with a write-ahead journal and a synchronous snapshot every 2 decode
     steps, throttled so the parent's SIGKILL reliably lands mid-decode;
  2. the PARENT waits for snapshot progress, SIGKILLs the child — which
     may die mid-snapshot-write (torn ``.tmp``) or mid-journal-append
     (torn JSONL tail); both are designed-for states;
  3. the parent resumes via :func:`repro.serve.resume_engine` (newest
     VERIFIED snapshot generation + WAL replay) and runs to completion;
  4. every request's tokens must be **identical** — and the FF logprob
     limb pairs **bit-for-bit identical** — to an uninterrupted engine
     run of the same request set (greedy decode is deterministic, and
     both processes compile the same XLA programs under the pinned
     ``--xla_cpu_max_isa`` ISA).

Exit 0 iff every scenario ends in exact-replay parity with every request
in a documented terminal status.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

_f = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _f:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _f).strip()

import numpy as np  # noqa: E402

KV_MODES = ("bf16", "f32", "ff_bf16")
MAX_NEW = 10
SNAPSHOT_EVERY = 2


def _cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(name="restart-chaos", family="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                       vocab_size=256, max_seq_len=64,
                       compute_dtype="float32", remat=False)


def _params(cfg):
    import jax
    from repro.models import init_params
    return init_params(cfg, jax.random.PRNGKey(0))


def _requests():
    from repro.serve import Request
    rng = np.random.default_rng(17)
    prompts = [rng.integers(1, 256, size=int(n)).astype(np.int32)
               for n in (6, 9, 12)]
    return [Request(uid=i, prompt=p, max_new=MAX_NEW)
            for i, p in enumerate(prompts)]


def _engine(params, cfg, kv_mode, journal=None):
    from repro.serve import ServeEngine
    return ServeEngine(params, cfg, max_batch=2, page_size=4, max_ctx=32,
                       kv_mode=kv_mode, journal=journal)


def child_main(workdir: str, kv_mode: str, step_delay: float) -> int:
    """Serve the deterministic request set with WAL + periodic snapshots,
    throttled so the parent's SIGKILL lands mid-decode.  Writes a
    progress file after each snapshot and a ``done`` marker only on
    clean completion (the parent asserts it never appears)."""
    cfg = _cfg()
    params = _params(cfg)
    snapdir = os.path.join(workdir, "snap")
    eng = _engine(params, cfg, kv_mode,
                  journal=os.path.join(workdir, "wal.jsonl"))
    for r in _requests():
        eng.submit(r)
    snaps = 0
    while eng.step():
        if eng.decode_steps % SNAPSHOT_EVERY == 0:
            eng.save_snapshot(snapdir)
            snaps += 1
            tmp = os.path.join(workdir, "progress.tmp")
            with open(tmp, "w") as f:
                f.write(json.dumps({"snaps": snaps,
                                    "steps": eng.decode_steps}))
            os.replace(tmp, os.path.join(workdir, "progress.json"))
        time.sleep(step_delay)
    eng.save_snapshot(snapdir)
    with open(os.path.join(workdir, "done"), "w") as f:
        f.write("clean")
    return 0


def run_scenario(workdir: str, kv_mode: str = "bf16", *,
                 step_delay: float = 0.25, kill_after_snaps: int = 2,
                 timeout_s: float = 300.0) -> dict:
    """Parent side: spawn, SIGKILL mid-decode, resume, verify parity.
    Returns a report dict; raises AssertionError on any contract
    violation."""
    os.makedirs(workdir, exist_ok=True)
    progress = os.path.join(workdir, "progress.json")
    env = dict(os.environ)
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env["PYTHONPATH"] = src_root + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.chaos.restart", "--child",
         "--dir", workdir, "--kv-mode", kv_mode,
         "--step-delay", str(step_delay)],
        env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    deadline = time.monotonic() + timeout_s
    try:
        while True:
            if time.monotonic() > deadline:
                raise AssertionError(
                    f"[{kv_mode}] child produced no snapshot progress "
                    f"within {timeout_s}s")
            if proc.poll() is not None:
                raise AssertionError(
                    f"[{kv_mode}] child exited (rc={proc.returncode}) "
                    f"before the kill — increase step_delay")
            if os.path.exists(progress):
                with open(progress) as f:
                    prog = json.load(f)
                if prog["snaps"] >= kill_after_snaps:
                    break
            time.sleep(0.05)
        proc.kill()                      # SIGKILL: no atexit, no cleanup
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=60)
    assert not os.path.exists(os.path.join(workdir, "done")), \
        f"[{kv_mode}] child finished cleanly; the kill tested nothing"

    cfg = _cfg()
    params = _params(cfg)
    from repro.serve import OK, resume_engine
    eng = resume_engine(params, cfg, os.path.join(workdir, "snap"),
                        journal=os.path.join(workdir, "wal.jsonl"))
    resumed = eng.run()

    base = _engine(params, cfg, kv_mode)
    for r in _requests():
        base.submit(r)
    baseline = base.run()

    assert set(resumed) == set(baseline), (
        f"[{kv_mode}] uid sets differ: resumed {sorted(resumed)} vs "
        f"baseline {sorted(baseline)}")
    for uid in sorted(baseline):
        a, b = baseline[uid], resumed[uid]
        assert b.status == OK, (
            f"[{kv_mode}] uid {uid}: resumed status {b.status} "
            f"({b.detail})")
        assert np.array_equal(a.tokens, b.tokens), (
            f"[{kv_mode}] uid {uid}: token mismatch after resume")
        assert np.array_equal(a.logprobs_ff, b.logprobs_ff), (
            f"[{kv_mode}] uid {uid}: FF logprob limbs not bit-identical")
    return {"kv_mode": kv_mode, "killed_at_snaps": kill_after_snaps,
            "resumed_uids": sorted(resumed),
            "statuses": {u: resumed[u].status for u in sorted(resumed)}}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--dir", type=str, default=None)
    ap.add_argument("--kv-mode", type=str, default="bf16",
                    choices=KV_MODES)
    ap.add_argument("--step-delay", type=float, default=0.25)
    ap.add_argument("--modes", type=str, default=",".join(KV_MODES),
                    help="comma-separated kv_modes for the parent sweep")
    args = ap.parse_args(argv)
    if args.child:
        if not args.dir:
            ap.error("--child requires --dir")
        return child_main(args.dir, args.kv_mode, args.step_delay)
    import tempfile
    failures = []
    for mode in args.modes.split(","):
        workdir = tempfile.mkdtemp(prefix=f"restart-chaos-{mode}-")
        print(f"chaos-restart: SIGKILL mid-decode + resume [{mode}]")
        try:
            report = run_scenario(workdir, mode,
                                  step_delay=args.step_delay)
        except AssertionError as e:
            print(f"  [FAIL] {e}")
            failures.append(str(e))
            continue
        print(f"  [ok] exact-replay parity: uids "
              f"{report['resumed_uids']} all "
              f"{sorted(set(report['statuses'].values()))}")
    if failures:
        print(f"chaos-restart: {len(failures)} scenario(s) FAILED")
        return 1
    print("chaos-restart: all kill-and-resume scenarios passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
