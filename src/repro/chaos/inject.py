"""Seed-driven fault injectors for the paged FF serving stack.

Every injector draws from one ``numpy`` generator seeded at construction,
so a chaos scenario is a pure function of ``(seed, call sequence)`` —
rerunning a failing test replays the exact same poison in the exact same
limb.  Injectors mutate real engine state (the jnp limb planes, the numpy
block table, the sidecar file on disk); nothing is mocked, so the
recovery paths exercised are the production ones.
"""

from __future__ import annotations

import contextlib
import json
import os
from typing import List, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.serve.paged_kv import PagedKVCache

#: poison values per corruption kind; "denormal_lo" is the flush-to-zero
#: hazard (legal-magnitude subnormal), not an invariant violation
_POISON = {"nan": float("nan"), "inf": float("inf"), "denormal_lo": 2.0 ** -130}


class ChaosMonkey:
    """Deterministic fault injector (one ``numpy`` RNG, seeded once)."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    # -- numeric poison ----------------------------------------------------

    def corrupt_kv_limbs(self, kv: PagedKVCache, slot: int, *,
                         kind: str = "nan", n: int = 1,
                         base: Optional[str] = None,
                         limb: str = "lo") -> List[Tuple[int, int, int, int]]:
        """Write ``kind`` poison into ``n`` LIVE cached positions of
        ``slot`` (positions below ``seq_lens[slot]`` — the ones decode
        actually reads; stale page contents are documented legal scratch).
        In ``ff_bf16`` mode the poison lands in the ``limb`` plane ("hi" |
        "lo"); elsewhere in the single k/v plane.  Returns the poisoned
        ``(layer, position, head, dim)`` coordinates."""
        if kind not in _POISON:
            raise ValueError(f"kind {kind!r}: choose from {tuple(_POISON)}")
        live = int(kv.seq_lens[slot])
        if live <= 0:
            raise ValueError(f"slot {slot} holds no live sequence")
        ps = kv.page_size
        coords = []
        for _ in range(n):
            b = base or ("k", "v")[self.rng.integers(2)]
            plane = f"{b}_{limb}" if kv.kv_mode == "ff_bf16" else b
            layer = int(self.rng.integers(kv.num_layers))
            pos = int(self.rng.integers(live))
            head = int(self.rng.integers(kv.num_kv_heads))
            dim = int(self.rng.integers(kv.head_dim))
            page = int(kv.block_table[slot, pos // ps])
            off = pos % ps
            val = jnp.asarray(_POISON[kind], kv.planes[plane].dtype)
            kv.planes[plane] = kv.planes[plane].at[
                layer, page, off, head, dim].set(val)
            coords.append((layer, pos, head, dim))
        return coords

    # -- paging metadata corruption ----------------------------------------

    def flip_block_table(self, kv: PagedKVCache, slot: int, *,
                         mode: str = "oob") -> str:
        """Corrupt one live block-table entry of ``slot``: ``"oob"`` (page
        id past the pool), ``"dup"`` (alias another live slot's page — both
        rows now share storage), or ``"free"`` (alias a page on the free
        list — decode and a future allocation now race).  Returns a
        description of the flip."""
        live = kv.pages_for(int(kv.seq_lens[slot]))
        if live <= 0:
            raise ValueError(f"slot {slot} holds no live pages")
        idx = int(self.rng.integers(live))
        old = int(kv.block_table[slot, idx])
        if mode == "oob":
            new = kv.num_pages + int(self.rng.integers(1, 9))
        elif mode == "dup":
            victims = [
                int(p)
                for s in range(kv.max_seqs) if s != slot
                for p in kv.block_table[s][
                    :kv.pages_for(int(kv.seq_lens[s]))]
                if int(p) >= 0]
            if not victims:
                raise ValueError("no other live slot to alias")
            new = victims[int(self.rng.integers(len(victims)))]
        elif mode == "free":
            if not kv.free_pages:
                raise ValueError("free list is empty")
            new = int(kv.free_pages[
                int(self.rng.integers(len(kv.free_pages)))])
        else:
            raise ValueError(f"mode {mode!r}: 'oob' | 'dup' | 'free'")
        kv.block_table[slot, idx] = new
        return f"slot {slot} entry {idx}: page {old} -> {new} ({mode})"

    # -- resource pressure -------------------------------------------------

    @contextlib.contextmanager
    def exhaust_pool(self, kv: PagedKVCache, keep: int = 0):
        """Steal all but ``keep`` free pages for the scope's duration
        (forced allocation failure / preemption pressure), restoring the
        stolen pages on exit.  Yields the stolen page ids."""
        stolen = []
        while len(kv.free_pages) > keep:
            stolen.append(kv.free_pages.pop())
        try:
            yield stolen
        finally:
            kv.free_pages.extend(reversed(stolen))

    # -- checkpoint / restart corruption -----------------------------------

    def tear_checkpoint_tmp(self, directory: str, *, step: int = 99) -> str:
        """Fabricate a crash mid-save: a ``step_XXXXXXXX.tmp`` directory
        holding a partial leaf and NO manifest — exactly what SIGKILL
        during :func:`repro.checkpoint.checkpoint.save` leaves behind.
        The read path must skip and garbage-collect it.  Returns the tmp
        path."""
        path = os.path.join(directory, f"step_{step:08d}.tmp")
        os.makedirs(path, exist_ok=True)
        with open(os.path.join(path, "torn_leaf.npy"), "wb") as f:
            f.write(b"\x93NUMPY" + bytes(
                self.rng.integers(0, 256, size=40, dtype=np.uint8)))
        return path

    def flip_checkpoint_bit(self, directory: str, *,
                            step: Optional[int] = None) -> str:
        """Flip ONE random bit in one ``.npy`` leaf of the (latest)
        retained generation — classic bit-rot.  The CRC32 verify must
        catch it and fall back to the previous generation.  Returns a
        description of the flip."""
        from repro.checkpoint import checkpoint as ckpt
        if step is None:
            step = ckpt.latest_step(directory)
        if step is None:
            raise ValueError(f"no checkpoint generation under {directory}")
        path = os.path.join(directory, f"step_{step:08d}")
        leaves = sorted(f for f in os.listdir(path) if f.endswith(".npy"))
        if not leaves:
            raise ValueError(f"{path} holds no leaves")
        leaf = leaves[int(self.rng.integers(len(leaves)))]
        fpath = os.path.join(path, leaf)
        size = os.path.getsize(fpath)
        # skip the ~128-byte npy header: flip payload data, the case a
        # CRC (not the npy parser) must catch
        lo = min(128, size - 1)
        byte = int(self.rng.integers(lo, size))
        bit = int(self.rng.integers(8))
        with open(fpath, "r+b") as f:
            f.seek(byte)
            old = f.read(1)[0]
            f.seek(byte)
            f.write(bytes([old ^ (1 << bit)]))
        return f"step {step} leaf {leaf}: bit {bit} of byte {byte} flipped"

    def stale_manifest(self, directory: str, *,
                       step: Optional[int] = None, version: int = 1) -> str:
        """Rewrite the (latest) generation's manifest with a stale schema
        ``version`` — the restart-after-downgrade / foreign-writer case.
        The loader must treat it as unverifiable and fall back.  Returns
        the manifest path."""
        if step is None:
            from repro.checkpoint import checkpoint as ckpt
            step = ckpt.latest_step(directory)
        if step is None:
            raise ValueError(f"no checkpoint generation under {directory}")
        mpath = os.path.join(directory, f"step_{step:08d}", "manifest.json")
        with open(mpath) as f:
            manifest = json.load(f)
        manifest["format"] = version
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        return mpath

    # -- sidecar corruption ------------------------------------------------

    def mangle_tune_json(self, path: str, *, mode: str = "truncate") -> str:
        """Write a corrupted ``FF_TUNE.json`` at ``path``: ``"truncate"``
        (a valid payload cut mid-record — the killed-during-write case),
        ``"garbage"`` (non-JSON bytes), or ``"wrong_types"`` (valid JSON,
        wrong structure: one salvageable op entry, one list where a dict
        belongs).  Returns ``path``."""
        good = {
            "meta": {"backend": "cpu", "format": 1},
            "table": {
                "cpu/add": {"16x16": {"fast": {
                    "impl": "jnp", "opts": {}, "us": 1.0}}},
                "cpu/matmul": {"256x256": {"accurate": {
                    "impl": "ozaki", "opts": {}, "us": 42.0}}},
            },
        }
        if mode == "truncate":
            text = json.dumps(good, indent=2)
            cut = int(len(text) * 0.6)
            payload = text[:cut].encode()
        elif mode == "garbage":
            payload = bytes(self.rng.integers(0, 256, size=64, dtype=np.uint8))
        elif mode == "wrong_types":
            bad = dict(good)
            bad["table"] = {
                "cpu/add": good["table"]["cpu/add"],     # salvageable
                "cpu/matmul": ["not", "a", "dict"],      # dropped
                "cpu/softmax": {"64x64": "not-a-record"},
            }
            payload = json.dumps(bad).encode()
        else:
            raise ValueError(
                f"mode {mode!r}: 'truncate' | 'garbage' | 'wrong_types'")
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "wb") as f:
            f.write(payload)
        return path
