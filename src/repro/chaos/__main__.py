"""Guarded-serving chaos smoke (the CI ``chaos`` job).

``python -m repro.chaos`` serves a tiny dense model under every fault
class the injectors produce and checks the robustness contract end to
end:

  * every submitted request terminates with a documented status
    (``OK/TIMEOUT/REJECTED/DEGRADED/FAILED``) — zero unhandled exceptions;
  * ``OK`` results are token-for-token the healthy sequential baseline,
    ``DEGRADED`` results are token-for-token the fast-f32-tier baseline
    (never silently wrong);
  * a mangled ``FF_TUNE.json`` degrades to static dispatch defaults with
    a warning, not a crash;
  * restart tier: snapshot/restore replays token-for-token (FF logprobs
    bit-for-bit); a torn ``.tmp``, a flipped checkpoint bit, or a
    stale-version manifest falls back WARNED to the previous retained
    generation (never a silent load); a write-ahead journal replays
    crash-lost requests in order.  (The SIGKILL-a-subprocess variant is
    ``python -m repro.chaos.restart`` — the CI ``chaos-restart`` job.)

Exits non-zero listing every violated check.  Deterministic: fixed model
seed, fixed :class:`~repro.chaos.ChaosMonkey` seed.
"""

from __future__ import annotations

import dataclasses
import os
import sys
import tempfile
import warnings

import numpy as np
import jax
import jax.numpy as jnp

from repro.chaos import ChaosMonkey
from repro.ff import tuning
from repro.ff.scope import resolve_policy
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.serve import (DEGRADED, OK, REJECTED, STATUSES, TIMEOUT,
                         Request, ServeEngine)
from repro.train.serve_step import greedy_generate

CFG = ModelConfig(name="chaos-smoke", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=256, max_seq_len=64, compute_dtype="float32",
                  remat=False)

FAILURES = []


def check(cond: bool, what: str) -> None:
    mark = "ok" if cond else "FAIL"
    print(f"  [{mark}] {what}")
    if not cond:
        FAILURES.append(what)


def _prompts(rng, n, lo=6, hi=14):
    return [rng.integers(1, CFG.vocab_size,
                         size=int(s)).astype(np.int32)
            for s in rng.integers(lo, hi, size=n)]


def _baseline(params, prompt, max_new, policy=None):
    return np.asarray(greedy_generate(
        params, CFG, jnp.asarray(prompt[None]), max_new, cache_len=48,
        policy=policy)[0])


def main() -> int:
    rng = np.random.default_rng(7)
    monkey = ChaosMonkey(seed=11)
    params = init_params(CFG, jax.random.PRNGKey(0))
    fast = dataclasses.replace(resolve_policy(None), attention="fast",
                               ff_math=False)

    print("chaos: healthy guarded serving (guard=check)")
    prompts = _prompts(rng, 3)
    eng = ServeEngine(params, CFG, max_batch=2, page_size=4, max_ctx=32,
                      guard="check")
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new=6))
    res = eng.run()
    check(sorted(res) == [0, 1, 2], "all requests terminated")
    check(all(r.status == OK for r in res.values()),
          "healthy run: every status OK")
    check(all(np.array_equal(res[i].tokens, _baseline(params, p, 6))
              for i, p in enumerate(prompts)),
          "healthy run: token parity with greedy baseline")

    print("chaos: NaN poison in live KV limbs (guard=degrade)")
    prompts = _prompts(rng, 2)
    eng = ServeEngine(params, CFG, max_batch=2, page_size=4, max_ctx=32,
                      guard="degrade")
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new=6))
    eng.step()
    monkey.corrupt_kv_limbs(eng.kv, slot=0, kind="nan", n=2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        res = eng.run()
    check(sorted(res) == [0, 1], "poisoned run: all requests terminated")
    check(all(r.status in STATUSES for r in res.values()),
          "poisoned run: statuses documented")
    check(any(r.status == DEGRADED for r in res.values()),
          "poisoned run: the poisoned row was quarantined (DEGRADED)")
    # observability contract under faults: the engine's observer must have
    # recorded the quarantine as a trace instant, the DEGRADED terminal in
    # a request span, and the counter view must agree with guard_stats.
    evs = eng.obs.to_chrome_trace()["traceEvents"]
    check(any(e["ph"] == "i" and e["name"] == "quarantine" for e in evs),
          "poisoned run: quarantine instant recorded in trace")
    check(any(e["ph"] == "X" and e["name"] == "request"
              and e["args"].get("status") == DEGRADED for e in evs),
          "poisoned run: DEGRADED request span recorded in trace")
    snap = eng.obs.snapshot()
    check(snap["counters"].get(
              'serve_guard_events_total{kind="quarantined"}', 0)
          == eng.guard_stats["quarantined"] >= 1,
          "poisoned run: obs counter agrees with guard_stats[quarantined]")
    for i, p in enumerate(prompts):
        want = _baseline(params, p, 6,
                         fast if res[i].status == DEGRADED else None)
        check(np.array_equal(res[i].tokens, want),
              f"poisoned run: uid {i} ({res[i].status}) token parity")

    print("chaos: block-table corruption (guard=degrade)")
    prompts = _prompts(rng, 2)
    eng = ServeEngine(params, CFG, max_batch=2, page_size=4, max_ctx=32,
                      guard="degrade")
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new=6))
    eng.step()
    monkey.flip_block_table(eng.kv, slot=1, mode="oob")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        res = eng.run()
    check(sorted(res) == [0, 1], "paging chaos: all requests terminated")
    check(all(r.status in STATUSES for r in res.values()),
          "paging chaos: statuses documented")
    check(eng.guard_stats["integrity_rebuilds"] >= 1,
          "paging chaos: integrity audit rebuilt the free list")
    check(any(e["ph"] == "i" and e["name"] == "integrity_rebuild"
              for e in eng.obs.to_chrome_trace()["traceEvents"]),
          "paging chaos: integrity_rebuild instant recorded in trace")
    probs, _ = eng.kv.check_integrity()
    check(not probs, "paging chaos: metadata clean after recovery")
    for i, p in enumerate(prompts):
        want = _baseline(params, p, 6,
                         fast if res[i].status == DEGRADED else None)
        check(np.array_equal(res[i].tokens, want),
              f"paging chaos: uid {i} ({res[i].status}) token parity")

    print("chaos: pool exhaustion -> preempt-and-requeue (reserve=prompt)")
    prompts = _prompts(rng, 3, lo=7, hi=9)
    eng = ServeEngine(params, CFG, max_batch=3, page_size=4, max_ctx=32,
                      num_pages=8, reserve="prompt")
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new=8))
    res = eng.run()
    check(sorted(res) == [0, 1, 2], "preemption: all requests terminated")
    check(all(r.status == OK for r in res.values()),
          "preemption: every request still completed OK")
    check(eng.guard_stats["preempted"] >= 1,
          "preemption: at least one row was preempted")
    check(all(np.array_equal(res[i].tokens, _baseline(params, p, 8))
              for i, p in enumerate(prompts)),
          "preemption: token parity preserved across requeue")

    print("chaos: backpressure — deadlines, bounded queue, oversize")
    prompts = _prompts(rng, 2)
    eng = ServeEngine(params, CFG, max_batch=1, page_size=4, max_ctx=32,
                      max_queue=2)
    eng.submit(Request(uid=0, prompt=prompts[0], max_new=6))
    eng.submit(Request(uid=1, prompt=prompts[1], max_new=6,
                       deadline_steps=1))
    st = eng.submit(Request(uid=2, prompt=prompts[0], max_new=64))
    check(st == REJECTED and eng.results[2].status == REJECTED,
          "oversize request REJECTED at submit")
    st = eng.submit(Request(uid=3, prompt=prompts[1], max_new=6))
    check(st == REJECTED, "queue overflow REJECTED at submit (max_queue)")
    res = eng.run()
    check(res[0].status == OK and res[1].status == TIMEOUT,
          "deadline_steps while queued -> TIMEOUT; head -> OK")
    check(sorted(res) == [0, 1, 2, 3], "backpressure: all uids terminated")

    print("chaos: mangled FF_TUNE.json sidecars")
    for mode in ("truncate", "garbage", "wrong_types"):
        with tempfile.NamedTemporaryFile(suffix=".json",
                                         delete=False) as tmp:
            path = tmp.name
        monkey.mangle_tune_json(path, mode=mode)
        tuning.clear()
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            table = tuning.load(path)
        check(len(caught) >= 1, f"tune sidecar [{mode}]: warned, not raised")
        if mode == "wrong_types":
            check("cpu/add" in table,
                  "tune sidecar [wrong_types]: valid entries salvaged")
    tuning.clear()

    print("chaos: snapshot/restore exact replay (kv_mode=ff_bf16)")
    from repro.checkpoint import checkpoint as ckpt_lib
    from repro.serve import resume_engine
    prompts = _prompts(rng, 3)
    submitted = [Request(uid=i, prompt=p, max_new=8)
                 for i, p in enumerate(prompts)]
    base = ServeEngine(params, CFG, max_batch=2, page_size=4, max_ctx=32,
                       kv_mode="ff_bf16")
    for r in submitted:
        base.submit(r)
    res_base = base.run()
    snapdir = tempfile.mkdtemp(prefix="chaos-snap-")
    eng = ServeEngine(params, CFG, max_batch=2, page_size=4, max_ctx=32,
                      kv_mode="ff_bf16")
    for r in submitted:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    eng.save_snapshot(snapdir)       # generation 1 (mid-run)
    for _ in range(2):
        eng.step()
    eng.save_snapshot(snapdir)       # generation 2 (later)
    eng2 = resume_engine(params, CFG, snapdir)
    res = eng2.run()
    check(sorted(res) == [0, 1, 2], "restart: all requests terminated")
    check(all(np.array_equal(res[i].tokens, res_base[i].tokens)
              for i in res),
          "restart: token-for-token parity with the uninterrupted run")
    check(all(np.array_equal(res[i].logprobs_ff, res_base[i].logprobs_ff)
              for i in res),
          "restart: FF logprob limb pairs bit-for-bit identical")

    print("chaos: corrupted checkpoints fall back WARNED, never silent")
    # the two retained generations above are the ladder under test
    monkey.tear_checkpoint_tmp(snapdir)
    steps_before = ckpt_lib.available_steps(snapdir)
    check(len(steps_before) == 2 and not any(
        d.endswith(".tmp") for d in os.listdir(snapdir)),
        "torn .tmp write: skipped and garbage-collected")
    newest = steps_before[-1]
    monkey.flip_checkpoint_bit(snapdir, step=newest)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        eng3 = resume_engine(params, CFG, snapdir)
    check(any(issubclass(w.category, ckpt_lib.CheckpointCorruptionWarning)
              for w in caught),
          "bit flip: CRC mismatch warned (loud fallback)")
    check(eng3.decode_steps == steps_before[0],
          "bit flip: fell back to the previous retained generation")
    res = eng3.run()
    check(all(np.array_equal(res[i].tokens, res_base[i].tokens)
              for i in res),
          "bit flip: replay from the older generation still exact")
    monkey.stale_manifest(snapdir, step=steps_before[0])
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        try:
            ckpt_lib.load_dict(snapdir)
            loud = False
        except ckpt_lib.CheckpointError:
            loud = True      # every generation bad -> raise, not silence
    check(loud and len(caught) >= 2,
          "stale manifest: no generation verifies -> loud CheckpointError")

    print("chaos: write-ahead journal replays crash-lost requests")
    waldir = tempfile.mkdtemp(prefix="chaos-wal-")
    wal = os.path.join(waldir, "wal.jsonl")
    eng = ServeEngine(params, CFG, max_batch=2, page_size=4, max_ctx=32,
                      journal=wal)
    for r in submitted:
        eng.submit(r)
    del eng                          # crash before any decode/snapshot
    eng2 = resume_engine(params, CFG,
                         os.path.join(waldir, "snap"),
                         journal=wal, max_batch=2, page_size=4,
                         max_ctx=32)
    check([q["req"].uid for q in eng2.queue] == [0, 1, 2],
          "WAL: requests re-admitted in original order")
    res = eng2.run()
    base_bf16 = ServeEngine(params, CFG, max_batch=2, page_size=4,
                            max_ctx=32)
    for r in submitted:
        base_bf16.submit(r)
    res_base2 = base_bf16.run()
    check(all(np.array_equal(res[i].tokens, res_base2[i].tokens)
              for i in res),
          "WAL: replayed requests produce the same tokens")
    check(os.path.getsize(wal) == 0,
          "WAL: journal truncated on clean retirement")

    print()
    if FAILURES:
        print(f"chaos smoke: {len(FAILURES)} check(s) FAILED")
        for f in FAILURES:
            print(f"  - {f}")
        return 1
    print("chaos smoke: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
