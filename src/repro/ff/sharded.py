"""Mesh-partitioned FF ops: the ``shard_map`` tier of the dispatch registry.

The paper's float-float operators survive a device mesh only if the
*cross-device* combining step preserves the same error contract as the
on-device arithmetic — ``psum``-ing FF partials as two independent f32
planes silently reintroduces the naive-f32 rounding the whole technique
exists to remove.  This module partitions the FF matmul/reduction ops over
a mesh with ``jax.experimental.shard_map`` and combines partial results
across devices with *compensated* collectives:

``combine="psum"`` (the fast class)
    ``TwoSum(psum(hi), psum(lo))``: one hardware all-reduce per limb plane,
    then an exact renormalization.  The collective itself rounds in f32, so
    the combine adds at most ``ceil(log2 P) * 2^-24 * sum_i |hi_i|``
    absolute error over ``P`` devices — the right trade for the fast
    matmul class, whose on-device bound is already ~2^-24-relative
    (blocked compensated accumulation), and documented as such in
    ``docs/NUMERICS.md``.

``combine="tree"`` (the accurate class)
    A ``ppermute`` butterfly (recursive doubling): ``log2 P`` exchange
    steps, each folding the received partial into the local FF accumulator
    with the 2-ulp ``Add22_accurate``.  Every device applies the same
    exact-EFT folds, so the combine preserves the ~2^-44 per-op contract
    (adds ``<= log2 P`` Add22 rounding steps) and is bitwise deterministic
    and identical across devices (TwoSum residuals are exact, hence
    order-symmetric).  Non-power-of-two axis sizes fall back to an
    ``all_gather`` + ordered Add22_accurate fold — same bound, one gather.

Partitioning choices:

* ``matmul``: the K (contraction) dimension is split over the mesh axis —
  each device computes a full (M, N) FF partial from its K-chunk with the
  *resolved single-device implementation* (so the tuned table still picks
  the inner kernel, at the LOCAL (M, K/P, N) shape), then partials combine
  as above.  ``"sharded"`` is the fast class (inner = the fast-tier
  winner, psum combine); ``"sharded_accurate"`` the accurate class (inner
  = the accurate-tier winner — f64/ozaki/dot2 —, tree combine).
* ``sum`` / ``dot``: the leading (reduced) dimension is split; each device
  runs the on-device compensated cascade over its shard, then partial FF
  sums tree-combine.  Default combine is ``"tree"``: these ops *are* the
  accurate tier.
* ``norm_stats``: a last-axis (row) reduction — rows never cross devices,
  so the mesh impl just pins row-parallel execution (leading dim split,
  bitwise-identical per row to the single-device impl, no collective).

Routing is scoped opt-in via ``ff.on_mesh(mesh, axis=...)`` (see
``repro.ff.scope``): outside the scope nothing here is reachable except by
explicit ``impl="sharded*"`` request.  Every implementation degrades
gracefully — no mesh scope, a non-2D matmul, or a non-divisible dimension
falls back (with a warning) to the single-device implementation its class
resolves to, so a mesh default can never brick a call.

Differentiation: these impls slot into the existing ``custom_vjp``
primitives in ``repro.ff.autodiff`` — the vjp rules run *above* the
``shard_map``, and their backward matmuls re-enter this tier (the ambient
``on_mesh`` scope is read at trace time, so keep the scope open around
``jax.grad`` tracing, exactly like a policy scope).
"""

from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import ff as core_ff
from repro.core.ff import FF
from repro.ff import dispatch, scope

Array = jnp.ndarray
AxisName = Union[str, Tuple[str, ...]]


# ---------------------------------------------------------------------------
# mesh helpers
# ---------------------------------------------------------------------------

def _axes_tuple(axis: AxisName) -> Tuple[str, ...]:
    return (axis,) if isinstance(axis, str) else tuple(axis)


def axis_size(mesh, axis: AxisName) -> int:
    """Total number of shards along ``axis`` (product over tuple axes)."""
    n = 1
    for a in _axes_tuple(axis):
        n *= mesh.shape[a]
    return n


def _resolve_inner(op: str, inner: Optional[str], accurate: bool,
                   shape: Optional[Tuple[int, ...]]) -> str:
    """Resolve the per-shard single-device implementation for ``op``.

    Runs under ``on_mesh(None)`` so resolution cannot re-enter the mesh
    tier; ``inner=None`` resolves the class default — the tuned fast
    winner / backend default for the fast class, ``"tuned_accurate"`` (with
    its static f64/ozaki/dot2 fallback chain) for the accurate class — at
    the LOCAL shard shape, so measured winners apply to the work a device
    actually does."""
    with scope.on_mesh(None):
        name = dispatch.resolve_name(
            op, inner if inner is not None
            else ("tuned_accurate" if accurate else None), shape=shape)
    if name.startswith("sharded"):     # explicit inner="sharded" would recurse
        raise ValueError(f"inner implementation of a sharded {op} cannot "
                         f"itself be {name!r}")
    return name


# ---------------------------------------------------------------------------
# compensated cross-device combines (call inside a shard_map body)
# ---------------------------------------------------------------------------

def psum_combine(r: FF, axis: AxisName) -> FF:
    """Fast-class combine: per-limb ``psum`` + exact TwoSum renormalize.

    Error: the two all-reduces round in f32, adding at most
    ``ceil(log2 P) * 2^-24 * sum_i |hi_i|`` absolute (the lo-plane term is
    a factor 2^-24 smaller); the final TwoSum is exact."""
    hi = jax.lax.psum(r.hi, axis)
    lo = jax.lax.psum(r.lo, axis)
    return core_ff.add12(hi, lo)


def _tree_one_axis(r: FF, ax: str, n: int) -> FF:
    if n == 1:
        return r
    if n & (n - 1):
        # non-power-of-two axis: one gather, then an ordered exact fold —
        # same Add22_accurate bound, identical on every device
        his = jax.lax.all_gather(r.hi, ax)
        los = jax.lax.all_gather(r.lo, ax)
        acc = FF(his[0], los[0])
        for i in range(1, n):
            acc = core_ff.add22_accurate(acc, FF(his[i], los[i]))
        return acc
    step = 1
    while step < n:
        perm = [(i, i ^ step) for i in range(n)]
        oh = jax.lax.ppermute(r.hi, ax, perm)
        ol = jax.lax.ppermute(r.lo, ax, perm)
        r = core_ff.add22_accurate(r, FF(oh, ol))
        step <<= 1
    return r


def tree_combine(r: FF, axis: AxisName, mesh) -> FF:
    """Accurate-class combine: ``ppermute`` TwoSum butterfly.

    ``log2 P`` recursive-doubling steps, each folding the partner's FF
    partial with ``Add22_accurate`` (2-ulp).  The result is bitwise
    identical on every device (TwoSum residuals are exact, so Add22 is
    argument-order-symmetric) and deterministic; total combine error is
    ``<= log2(P)`` Add22_accurate roundings, preserving the ~2^-44
    contract.  Tuple axes fold one axis at a time."""
    for ax in _axes_tuple(axis):
        r = _tree_one_axis(r, ax, mesh.shape[ax])
    return r


def _combine(r: FF, axis: AxisName, mesh, how: str) -> FF:
    from repro.obs import annotate
    with annotate(f"ff.sharded_combine_{how}"):
        if how == "psum":
            return psum_combine(r, axis)
        if how == "tree":
            return tree_combine(r, axis, mesh)
    raise ValueError(f"unknown combine {how!r}; expected 'psum' or 'tree'")


# ---------------------------------------------------------------------------
# sharded matmul (K-contraction split)
# ---------------------------------------------------------------------------

def _mm_sharded(accurate: bool):
    cls = "sharded_accurate" if accurate else "sharded"

    def fn(a: Array, b: Array, *, inner: Optional[str] = None,
           combine: Optional[str] = None, **opts) -> FF:
        ctx = scope.current_mesh()
        M, K = int(a.shape[-2]), int(a.shape[-1])
        N = int(b.shape[-1])
        nshard = axis_size(ctx[0], ctx[1]) if ctx is not None else 1
        if ctx is None or a.ndim != 2 or b.ndim != 2 or K % nshard:
            why = ("no ff.on_mesh scope is active" if ctx is None else
                   f"K={K} is not divisible by the {nshard}-way mesh axis"
                   if K % nshard else
                   f"{a.ndim}-D/{b.ndim}-D operands are not a 2-D matmul")
            name = _resolve_inner("matmul", inner, accurate, (M, K, N))
            dispatch._fallback_warn(cls, "matmul",
                                    f"{why}; using single-device "
                                    f"impl {name!r}")
            kw = dict(opts)
            for k, v in dispatch.resolve_opts("matmul", name,
                                              (M, K, N)).items():
                kw.setdefault(k, v)
            return dispatch.lookup("matmul", name)(a, b, **kw)
        mesh, axis = ctx
        how = combine or ("tree" if accurate else "psum")
        kl = K // nshard
        name = _resolve_inner("matmul", inner, accurate, (M, kl, N))
        base = dispatch.lookup("matmul", name)
        kw = dict(opts)
        for k, v in dispatch.resolve_opts("matmul", name, (M, kl, N)).items():
            kw.setdefault(k, v)

        def body(al, bl):
            r = base(al, bl, **kw)
            r = _combine(r, axis, mesh, how)
            return r.hi, r.lo

        hi, lo = shard_map(
            body, mesh=mesh,
            in_specs=(P(None, axis), P(axis, None)),
            out_specs=(P(), P()), check_rep=False)(
                jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32))
        return FF(hi, lo)

    fn.__name__ = f"_mm_{cls}"
    fn.__doc__ = (f"{'Accurate' if accurate else 'Fast'}-class mesh matmul: "
                  f"K split over the ff.on_mesh axis, "
                  f"{'ppermute Add22 tree' if accurate else 'psum+TwoSum'} "
                  f"combine (see module docstring).")
    return fn


# ---------------------------------------------------------------------------
# sharded reductions (leading-dim split)
# ---------------------------------------------------------------------------

def _lead_axes(axis, ndim: int) -> Tuple[int, ...]:
    if axis is None:
        return tuple(range(ndim))
    axes = (axis,) if isinstance(axis, int) else tuple(axis)
    return tuple(a % ndim for a in axes)


def _bucket2d(shape: Tuple[int, ...]) -> Tuple[int, int]:
    """Tuning-bucket view of a local shard shape — (prod(leading), last),
    mirroring ``repro.ff.autodiff._bucket2d`` so the tuned table's
    reduction winners apply to the work a device actually does."""
    if len(shape) == 0:
        return (1, 1)
    if len(shape) == 1:
        return (1, int(shape[0]))
    r = 1
    for d in shape[:-1]:
        r *= int(d)
    return (r, int(shape[-1]))


def _resolve_red_inner(op: str, local_shape: Tuple[int, ...]):
    """Per-shard inner impl + tuned opts for a reduction, resolved through
    the registry under ``on_mesh(None)`` (like the matmul inner): the
    backend default / tuned winner at the LOCAL shard bucket — so on TPU
    the mesh tier keeps the rowsum kernel and measured block configs
    instead of hardcoding the jnp cascade."""
    bucket = _bucket2d(local_shape)
    with scope.on_mesh(None):
        name = dispatch.resolve_name(op, None, shape=bucket)
    if name.startswith("sharded"):     # a foreign tuned table must not recurse
        name = "blocked" if op == "sum" else "jnp"
    return dispatch.lookup(op, name), dispatch.resolve_opts(op, name, bucket)


def _red_fallback(op: str, why: str, call):
    """Resolve + run the single-device impl for a reduction the mesh tier
    cannot serve (mesh defaults must never brick a call)."""
    with scope.on_mesh(None):
        name = dispatch.resolve_name(op)
    dispatch._fallback_warn("sharded", op,
                            f"{why}; using single-device impl {name!r}")
    return call(dispatch.lookup(op, name))


def _sum_sharded(x: Array, axis=None, *, combine: str = "tree",
                 block: int = 128, **opts) -> FF:
    """Mesh-partitioned compensated sum: leading dim split over the
    ``on_mesh`` axis, on-device blocked Neumaier cascade per shard, FF
    partials combined with the compensated tree (default) or psum."""
    ctx = scope.current_mesh()
    x = jnp.asarray(x, jnp.float32)
    axes = _lead_axes(axis, x.ndim)
    nshard = axis_size(ctx[0], ctx[1]) if ctx is not None else 1
    servable = (ctx is not None and x.ndim >= 1 and 0 in axes
                and x.shape[0] % nshard == 0)
    if not servable:
        why = ("no ff.on_mesh scope is active" if ctx is None else
               "axis does not reduce the leading (mesh-split) dim"
               if x.ndim < 1 or 0 not in axes else
               f"dim 0 ({x.shape[0] if x.ndim else 0}) is not divisible "
               f"by the {nshard}-way mesh axis")
        return _red_fallback("sum", why,
                             lambda f: f(x, axis=axis, block=block, **opts))
    mesh, maxis = ctx
    lshape = (x.shape[0] // nshard,) + tuple(x.shape[1:])
    base, tuned = _resolve_red_inner("sum", lshape)
    kw = dict(opts)
    kw.setdefault("block", block)
    for k, v in tuned.items():
        kw.setdefault(k, v)

    def body(xl):
        r = base(xl, axis=axes, **kw)
        r = _combine(r, maxis, mesh, combine)
        return r.hi, r.lo

    in_spec = P(maxis, *([None] * (x.ndim - 1)))
    hi, lo = shard_map(body, mesh=mesh, in_specs=(in_spec,),
                       out_specs=(P(), P()), check_rep=False)(x)
    return FF(hi, lo)


def _dot_sharded(a: Array, b: Array, axis=None, *, combine: str = "tree",
                 **opts) -> FF:
    """Mesh-partitioned compensated dot: per-shard Dot2/Dot3 cascade over
    the leading dim, FF partials tree-combined."""
    ctx = scope.current_mesh()
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    axes = _lead_axes(axis, a.ndim)
    nshard = axis_size(ctx[0], ctx[1]) if ctx is not None else 1
    servable = (ctx is not None and a.ndim >= 1 and 0 in axes
                and a.shape == b.shape and a.shape[0] % nshard == 0)
    if not servable:
        why = ("no ff.on_mesh scope is active" if ctx is None else
               "operands/axis are not a leading-dim reduction divisible "
               f"by the {nshard}-way mesh axis")
        return _red_fallback("dot", why,
                             lambda f: f(a, b, axis=axis, **opts))
    mesh, maxis = ctx
    lshape = (a.shape[0] // nshard,) + tuple(a.shape[1:])
    base, tuned = _resolve_red_inner("dot", lshape)
    kw = dict(opts)
    for k, v in tuned.items():
        kw.setdefault(k, v)

    def body(al, bl):
        r = base(al, bl, axis=axes, **kw)
        r = _combine(r, maxis, mesh, combine)
        return r.hi, r.lo

    in_spec = P(maxis, *([None] * (a.ndim - 1)))
    hi, lo = shard_map(body, mesh=mesh, in_specs=(in_spec, in_spec),
                       out_specs=(P(), P()), check_rep=False)(a, b)
    return FF(hi, lo)


def _norm_stats_sharded(x: Array, **opts):
    """Row-parallel LayerNorm statistics on the mesh: the reduction is
    within-row (last axis), so shards never exchange data — the mesh impl
    pins leading-dim partitioning and runs the single-device impl
    bitwise-identically per row."""
    ctx = scope.current_mesh()
    x = jnp.asarray(x, jnp.float32)
    nshard = axis_size(ctx[0], ctx[1]) if ctx is not None else 1
    servable = (ctx is not None and x.ndim >= 2
                and x.shape[0] % nshard == 0)
    if not servable:
        why = ("no ff.on_mesh scope is active" if ctx is None else
               f"leading dim of a {x.ndim}-D input is not divisible by "
               f"the {nshard}-way mesh axis")
        return _red_fallback("norm_stats", why, lambda f: f(x, **opts))
    mesh, maxis = ctx
    with scope.on_mesh(None):
        inner_name = dispatch.resolve_name("norm_stats")
    base = dispatch.lookup("norm_stats", inner_name)

    def body(xl):
        return base(xl, **opts)

    in_spec = P(maxis, *([None] * (x.ndim - 1)))
    return shard_map(body, mesh=mesh, in_specs=(in_spec,),
                     out_specs=(P(maxis), P(maxis)), check_rep=False)(x)


# ---------------------------------------------------------------------------
# registration: mesh defaults inside ff.on_mesh scopes
# ---------------------------------------------------------------------------

dispatch.register("matmul", "sharded", _mm_sharded(accurate=False),
                  mesh_default=True)
dispatch.register("matmul", "sharded_accurate", _mm_sharded(accurate=True))
dispatch.register("sum", "sharded", _sum_sharded, mesh_default=True)
dispatch.register("dot", "sharded", _dot_sharded, mesh_default=True)
dispatch.register("norm_stats", "sharded", _norm_stats_sharded,
                  mesh_default=True)
