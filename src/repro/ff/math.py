"""``repro.ff.math`` — differentiable, dispatched FF elementary functions.

The float-float *arithmetic* operators cap a pipeline's accuracy only
until the first ``exp``/``log``/``tanh`` call — the hardware builtins are
~2^-24-accurate, three orders of magnitude off the 2^-44 contract (the
gap the paper's companion study measured on 2006 GPUs, alive and well in
every f32 XLA backend).  This namespace closes it: classic argument
reduction + compensated FF polynomial kernels (``repro.core.ffmath``)
behind the standard ``repro.ff`` machinery —

  * registry dispatch per function (``jnp`` compensated reference /
    ``pallas`` kernel / native-``f64`` CPU tier / documented ``fast`` f32
    class), shape-aware and ``ff.tune``-aware like every other op;
  * ``jax.custom_vjp`` rules computing derivatives IN FF
    (``repro.ff.autodiff``), so ``exp``/``gelu``/... gradients hold
    ~2^-43 like the arithmetic ops;
  * fusion-tracer integration: ``fusion.exp``/``log``/``tanh``/
    ``sigmoid`` on FF nodes compile into fused one-kernel chains, and the
    accurate-class ``softmax``/``logsumexp`` impls ride these kernels.

Usage::

    import repro.ff as ff

    y = ff.exp(x)                    # FF in/out, ~2^-43 on reduced domain
    y = ff.tanh(x, impl="pallas")    # explicit kernel selection
    g = jax.grad(lambda t: ff.silu(t).to_f32().sum())(x)   # FF-grade grad

Error contracts per function are doctested in ``docs/NUMERICS.md``;
reduction schemes and budgets in ``docs/DESIGN_math.md``.
"""

from __future__ import annotations

from typing import Optional, Union

import jax.numpy as jnp

from repro.core.ff import FF
from repro.ff import dispatch
from repro.ff.autodiff import (
    Operand, _broadcast2, _bucket2d, _kind, _math1_p, _merge_tuned,
    _operand, _opts_tuple, _pow_p, _shape_of,
)

Array = jnp.ndarray


def _guard_protect(op: str, value: FF) -> FF:
    """Route the op result through the ambient ``ff.guard`` scope (identity
    when no scope is active / mode="off" — see ``repro.ff.guard``)."""
    from importlib import import_module
    return import_module("repro.ff.guard").protect(op, value)


def _unary_call(op: str, a: Operand, impl: Optional[str], opts: dict) -> FF:
    a = _operand(a)
    shape = _bucket2d(_shape_of(a))
    name = dispatch.resolve_name(op, impl, shape=shape)
    return _guard_protect(op, _math1_p(
        (op, name, _kind(a),
         _opts_tuple(_merge_tuned(op, name, shape, opts))), a))


def exp(a: Operand, *, impl: Optional[str] = None, **opts) -> FF:
    """FF exponential (argument reduction + compensated polynomial).
    <= 2 ulp_FF (~2^-43) on the reduced domain; saturates at the f32
    range edges.  FF or f32 operand -> FF."""
    return _unary_call("exp", a, impl, opts)


def expm1(a: Operand, *, impl: Optional[str] = None, **opts) -> FF:
    """FF exp(x) - 1 with full relative accuracy near 0 (the k = 0
    reduction branch is the exp kernel without its +1)."""
    return _unary_call("expm1", a, impl, opts)


def log(a: Operand, *, impl: Optional[str] = None, **opts) -> FF:
    """FF natural logarithm (frexp-style decomposition + atanh series).
    nan for x < 0, -inf at 0."""
    return _unary_call("log", a, impl, opts)


def log1p(a: Operand, *, impl: Optional[str] = None, **opts) -> FF:
    """FF log(1 + x), fully accurate for tiny x (never forms 1 + x in
    the near branch)."""
    return _unary_call("log1p", a, impl, opts)


def tanh(a: Operand, *, impl: Optional[str] = None, **opts) -> FF:
    """FF hyperbolic tangent (Maclaurin kernel small, bounded rational
    expm1 form large, exact +-1 saturation)."""
    return _unary_call("tanh", a, impl, opts)


def sigmoid(a: Operand, *, impl: Optional[str] = None, **opts) -> FF:
    """FF logistic sigmoid via the cancellation-free two-sided form."""
    return _unary_call("sigmoid", a, impl, opts)


def erf(a: Operand, *, impl: Optional[str] = None, **opts) -> FF:
    """FF error function (alternating series |x|<=1, positive Kummer
    series to 4, asymptotic erfc beyond; exact +-1 saturation)."""
    return _unary_call("erf", a, impl, opts)


def gelu(a: Operand, *, impl: Optional[str] = None, **opts) -> FF:
    """FF exact-form GELU: 0.5 x (1 + erf(x/sqrt2)) — the transcendental
    the logit path actually wants (no tanh approximation)."""
    return _unary_call("gelu", a, impl, opts)


def silu(a: Operand, *, impl: Optional[str] = None, **opts) -> FF:
    """FF SiLU / swish: x * sigmoid(x), cancellation-free everywhere."""
    return _unary_call("silu", a, impl, opts)


def pow(a: Operand, b: Operand, *, impl: Optional[str] = None,  # noqa: A001
        **opts) -> FF:
    """FF power a**b = exp(b log a) for a > 0 (error grows with
    |b ln a| — see NUMERICS).  IEEE edge rules for a in {0, inf}, b = 0."""
    a, b = _broadcast2(_operand(a), _operand(b))
    shape = _bucket2d(jnp.broadcast_shapes(_shape_of(a), _shape_of(b)))
    name = dispatch.resolve_name("pow", impl, shape=shape)
    return _guard_protect("pow", _pow_p(
        (name, _kind(a), _kind(b),
         _opts_tuple(_merge_tuned("pow", name, shape, opts))), a, b))


UNARY = ("exp", "expm1", "log", "log1p", "tanh", "sigmoid", "erf", "gelu",
         "silu")
__all__ = list(UNARY) + ["pow"]
