"""Differentiable public FF ops: dispatch + ``jax.custom_vjp`` rules.

Why custom rules: autodiff through raw TwoSum/TwoProd graphs is both wrong
under reassociation (the EFT error terms have zero derivative a.e., so the
transpose visits ~6x the flops to compute what the calculus already knows)
and numerically pointless.  The rules here are the FF-arithmetic calculus:

    d(a + b) = da + db          d(a * b) = a*db + b*da
    d(a / b) = da/b - (a/b)*db/b        d(sqrt a) = da / (2*sqrt a)

computed *in FF*, so gradients inherit the ~2^-44 operator accuracy.

Cotangent convention ("value convention"): the cotangent of an FF output is
itself FF-structured, and its *represented value* ``ct.hi + ct.lo`` is the
cotangent of the represented value ``hi + lo``.  All ops here produce and
consume that convention; ``FF.to_f32()`` (reads ``hi``) is the compatible
boundary to plain-f32 autodiff.  Do not feed FF outputs of these ops into
raw ``repro.core`` EFT graphs *inside a differentiated region* — per-leaf
cotangents from raw graphs double-count against the value convention.

Implementation note: every op is a ``custom_vjp`` primitive whose first
(``nondiff_argnums``) argument is a hashable ``meta`` tuple carrying the
resolved implementation name, the operand kinds ("ff"/"arr"), static shape
or axis info, and impl options — resolution against the dispatch registry
and the ambient scope happens once, in the public wrapper, at trace time.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import ff as core_ff
from repro.core.ff import FF
from repro.core.ffmatmul import _dot_f32
from repro.ff import dispatch, scope

Array = jnp.ndarray
Operand = Union[FF, Array, float, int]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _kind(x) -> str:
    return "ff" if isinstance(x, FF) else "arr"


def _g_val(g: FF) -> FF:
    """Incoming cotangent (FF-structured) -> normalized FF cotangent value."""
    return core_ff.add12(g.hi, g.lo)


def _ct(kind: str, gv: FF):
    """Cotangent for an input of the given kind (arr = rounded value)."""
    return gv if kind == "ff" else gv.hi


def _ff_mul_any(g: FF, x) -> FF:
    return core_ff.mul22(g, x) if isinstance(x, FF) else core_ff.mul212(g, x)


def _ff_div_any(g: FF, x) -> FF:
    return core_ff.div22(g, x if isinstance(x, FF) else FF.from_f32(x))


def _operand(x) -> Union[FF, Array]:
    if isinstance(x, FF):
        return x
    return jnp.asarray(x, jnp.float32)


def _broadcast2(a, b):
    """Broadcast limbs OUTSIDE the primitives so standard autodiff handles
    the summing over broadcast dimensions."""
    shape = jnp.broadcast_shapes(jnp.shape(a.hi if isinstance(a, FF) else a),
                                 jnp.shape(b.hi if isinstance(b, FF) else b))

    def bc(x):
        if isinstance(x, FF):
            if x.shape == shape:
                return x
            return FF(jnp.broadcast_to(x.hi, shape),
                      jnp.broadcast_to(x.lo, shape))
        return x if jnp.shape(x) == shape else jnp.broadcast_to(x, shape)

    return bc(a), bc(b)


def _opts_tuple(opts: dict) -> tuple:
    return tuple(sorted(opts.items()))


def _norm_axes(axis, ndim) -> Optional[Tuple[int, ...]]:
    if axis is None:
        return None
    axes = (axis,) if isinstance(axis, int) else tuple(axis)
    return tuple(a % ndim for a in axes)


# ---------------------------------------------------------------------------
# elementwise: add / mul / div / sqrt
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _add_p(meta, a, b):
    return dispatch.lookup("add", meta[0])(a, b, **dict(meta[3]))


def _add_fwd(meta, a, b):
    return _add_p(meta, a, b), None


def _add_bwd(meta, _res, g):
    gv = _g_val(g)
    return _ct(meta[1], gv), _ct(meta[2], gv)


_add_p.defvjp(_add_fwd, _add_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _mul_p(meta, a, b):
    return dispatch.lookup("mul", meta[0])(a, b, **dict(meta[3]))


def _mul_fwd(meta, a, b):
    return _mul_p(meta, a, b), (a, b)


def _mul_bwd(meta, res, g):
    a, b = res
    gv = _g_val(g)
    return _ct(meta[1], _ff_mul_any(gv, b)), _ct(meta[2], _ff_mul_any(gv, a))


_mul_p.defvjp(_mul_fwd, _mul_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _div_p(meta, a, b):
    return dispatch.lookup("div", meta[0])(a, b, **dict(meta[3]))


def _div_fwd(meta, a, b):
    out = _div_p(meta, a, b)
    return out, (b, out)


def _div_bwd(meta, res, g):
    b, out = res
    gv = _g_val(g)
    q = _ff_div_any(gv, b)                       # g / b
    db = -_ff_mul_any(q, out)                    # -(g/b) * (a/b)
    return _ct(meta[1], q), _ct(meta[2], db)


_div_p.defvjp(_div_fwd, _div_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sqrt_p(meta, a):
    return dispatch.lookup("sqrt", meta[0])(a, **dict(meta[3]))


def _sqrt_fwd(meta, a):
    out = _sqrt_p(meta, a)
    return out, out


def _sqrt_bwd(meta, out, g):
    gv = _g_val(g)
    da = core_ff.div22(gv, core_ff.mul212(out, jnp.float32(2.0)))
    return (_ct(meta[1], da),)


_sqrt_p.defvjp(_sqrt_fwd, _sqrt_bwd)


def _shape_of(x) -> Tuple[int, ...]:
    return x.shape if isinstance(x, FF) else jnp.shape(x)


def _bucket2d(shape) -> Tuple[int, int]:
    """Tuning-bucket view of an elementwise-family operand: the kernels
    flatten to (prod(leading), last), and ``ff.tune`` keys its buckets the
    same way — resolving on the raw ND shape would miss every tuned entry
    at real call sites (3-D/4-D activations)."""
    if len(shape) == 0:
        return (1, 1)
    if len(shape) == 1:
        return (1, int(shape[0]))
    r = 1
    for d in shape[:-1]:
        r *= int(d)
    return (r, int(shape[-1]))


def _merge_tuned(op: str, name: str, shape, opts: dict) -> dict:
    """Tuned block config for (op, impl, shape-bucket) merged UNDER the
    caller's explicit opts (mirrors ff.matmul's option precedence)."""
    opts = dict(opts)
    for k, v in dispatch.resolve_opts(op, name, shape).items():
        opts.setdefault(k, v)
    return opts


def _ew_meta(op, impl, a, b, opts):
    """Shape-aware elementwise resolution: the ff.tune table participates
    exactly as it does for matmul (winner-by-bucket when resolution falls
    through to the default, tuned block opts for the resolved impl).
    Callers pass operands already broadcast by _broadcast2; bucketing on
    the joint broadcast shape keeps this operand-order-independent even
    if a future caller skips that step."""
    shape = _bucket2d(jnp.broadcast_shapes(_shape_of(a), _shape_of(b)))
    name = dispatch.resolve_name(op, impl, shape=shape)
    return (name, _kind(a), _kind(b),
            _opts_tuple(_merge_tuned(op, name, shape, opts)))


def add(a: Operand, b: Operand, *, impl: Optional[str] = None, **opts) -> FF:
    """FF addition (paper Add22).  Accepts FF or f32 operands."""
    a, b = _broadcast2(_operand(a), _operand(b))
    return _add_p(_ew_meta("add", impl, a, b, opts), a, b)


def sub(a: Operand, b: Operand, *, impl: Optional[str] = None, **opts) -> FF:
    """FF subtraction: add(a, -b)."""
    b = _operand(b)
    return add(a, -b if isinstance(b, FF) else -jnp.asarray(b, jnp.float32),
               impl=impl, **opts)


def mul(a: Operand, b: Operand, *, impl: Optional[str] = None, **opts) -> FF:
    """FF multiplication (paper Mul22, relative error <= 2^-44)."""
    a, b = _broadcast2(_operand(a), _operand(b))
    return _mul_p(_ew_meta("mul", impl, a, b, opts), a, b)


def div(a: Operand, b: Operand, *, impl: Optional[str] = None, **opts) -> FF:
    """FF division (Dekker quotient + correction)."""
    a, b = _broadcast2(_operand(a), _operand(b))
    return _div_p(_ew_meta("div", impl, a, b, opts), a, b)


def sqrt(a: Operand, *, impl: Optional[str] = None, **opts) -> FF:
    """FF square root (hardware sqrt + one Newton correction)."""
    a = _operand(a)
    shape = _bucket2d(_shape_of(a))
    name = dispatch.resolve_name("sqrt", impl, shape=shape)
    return _sqrt_p((name, _kind(a), None,
                    _opts_tuple(_merge_tuned("sqrt", name, shape, opts))), a)


# ---------------------------------------------------------------------------
# EFTs: two_sum / two_prod  (f32, f32) -> FF, exact
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _two_sum_p(meta, a, b):
    return dispatch.lookup("two_sum", meta[0])(a, b, **dict(meta[1]))


def _two_sum_fwd(meta, a, b):
    return _two_sum_p(meta, a, b), None


def _two_sum_bwd(meta, _res, g):
    gv = _g_val(g).hi
    return gv, gv


_two_sum_p.defvjp(_two_sum_fwd, _two_sum_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _two_prod_p(meta, a, b):
    return dispatch.lookup("two_prod", meta[0])(a, b, **dict(meta[1]))


def _two_prod_fwd(meta, a, b):
    return _two_prod_p(meta, a, b), (a, b)


def _two_prod_bwd(meta, res, g):
    a, b = res
    gv = _g_val(g)
    return core_ff.mul212(gv, b).hi, core_ff.mul212(gv, a).hi


_two_prod_p.defvjp(_two_prod_fwd, _two_prod_bwd)


def two_sum(a, b, *, impl: Optional[str] = None, **opts) -> FF:
    """Exact a + b as FF (paper Theorem 2)."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    name = dispatch.resolve_name("two_sum", impl)
    return _two_sum_p((name, _opts_tuple(opts)), a, b)


def two_prod(a, b, *, impl: Optional[str] = None, **opts) -> FF:
    """Exact a * b as FF (paper Theorem 4)."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    name = dispatch.resolve_name("two_prod", impl)
    return _two_prod_p((name, _opts_tuple(opts)), a, b)


# ---------------------------------------------------------------------------
# matmul: f32 or FF operands -> FF
# ---------------------------------------------------------------------------

def _mm_any(impl: str, opts: tuple, a, b) -> FF:
    """Dispatch-selected f32 base matmul, extended to FF operands with the
    two significant cross terms (a.lo@b.lo is < 2^-48, below FF precision)."""
    base = dispatch.lookup("matmul", impl)
    kw = dict(opts)
    if not isinstance(a, FF) and not isinstance(b, FF):
        return base(a, b, **kw)
    ah = a.hi if isinstance(a, FF) else a
    bh = b.hi if isinstance(b, FF) else b
    out = base(ah, bh, **kw)
    if isinstance(b, FF):
        out = core_ff.add22(out, FF.from_f32(_dot_f32(ah, b.lo)))
    if isinstance(a, FF):
        out = core_ff.add22(out, FF.from_f32(_dot_f32(a.lo, bh)))
    return out


def _t(x):
    if isinstance(x, FF):
        return FF(jnp.swapaxes(x.hi, -1, -2), jnp.swapaxes(x.lo, -1, -2))
    return jnp.swapaxes(x, -1, -2)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _matmul_p(meta, a, b):
    return _mm_any(meta[0], meta[3], a, b)


def _matmul_fwd(meta, a, b):
    return _matmul_p(meta, a, b), (a, b)


def _matmul_bwd(meta, res, g):
    a, b = res
    gv = _g_val(g)
    da = _mm_any(meta[0], meta[3], gv, _t(b))
    db = _mm_any(meta[0], meta[3], _t(a), gv)
    return _ct(meta[1], da), _ct(meta[2], db)


_matmul_p.defvjp(_matmul_fwd, _matmul_bwd)


def matmul(a: Union[FF, Array], b: Union[FF, Array], *,
           impl: Optional[str] = None, **opts) -> FF:
    """FF matrix product of (M,K) x (K,N) operands (f32 or FF).

    The implementation is registry-dispatched (``hybrid`` blocked-K MXU
    path by default; ``split``/``dot2``/``ozaki``/``pallas_ozaki``
    selectable per call, per ``ff.use`` scope, or via
    ``policy(matmul=...)``; ``"tuned"``/``"tuned_accurate"`` pick the
    measured winner from the ``ff.tune`` table).  Resolution is
    shape-aware: when the tuning table has an entry for this
    (backend, M/K/N bucket), the default impl AND its block configuration
    come from measurements.  Option precedence: explicit kwargs > tuned
    block config > the ambient policy's ``ff_matmul_block_k``.
    """
    a = a if isinstance(a, FF) else jnp.asarray(a, jnp.float32)
    b = b if isinstance(b, FF) else jnp.asarray(b, jnp.float32)
    mkn = (a.shape[-2], a.shape[-1], b.shape[-1])
    name = dispatch.resolve_name("matmul", impl, shape=mkn)
    opts = dict(opts)
    if "bk" in opts and name in ("hybrid", "compensated", "split", "ozaki"):
        opts.setdefault("block_k", opts.pop("bk"))  # pallas-style knob name
    for k, v in dispatch.resolve_opts("matmul", name, mkn).items():
        opts.setdefault(k, v)
    if name in ("hybrid", "compensated", "split"):
        opts.setdefault("block_k", scope.current_policy().ff_matmul_block_k)
    return _matmul_p((name, _kind(a), _kind(b), _opts_tuple(opts)), a, b)


# ---------------------------------------------------------------------------
# reductions: sum / mean / dot / logsumexp
# ---------------------------------------------------------------------------

def _expand(gval: Array, axes: Optional[Tuple[int, ...]], shape) -> Array:
    if axes is None:
        axes = tuple(range(len(shape)))
    full = gval
    for ax in sorted(axes):
        full = jnp.expand_dims(full, ax)
    return jnp.broadcast_to(full, shape)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sum_p(meta, x):
    impl, axes, _shape, opts = meta
    return dispatch.lookup("sum", impl)(x, axis=axes, **dict(opts))


def _sum_fwd(meta, x):
    return _sum_p(meta, x), None


def _sum_bwd(meta, _res, g):
    _impl, axes, shape, _opts = meta
    return (_expand(_g_val(g).hi, axes, shape),)


_sum_p.defvjp(_sum_fwd, _sum_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _mean_p(meta, x):
    impl, axes, _shape, opts = meta
    return dispatch.lookup("mean", impl)(x, axis=axes, **dict(opts))


def _mean_fwd(meta, x):
    return _mean_p(meta, x), None


def _mean_bwd(meta, _res, g):
    _impl, axes, shape, _opts = meta
    n = 1
    for ax in (range(len(shape)) if axes is None else axes):
        n *= shape[ax]
    return (_expand(_g_val(g).hi, axes, shape) / jnp.float32(n),)


_mean_p.defvjp(_mean_fwd, _mean_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _dot_p(meta, a, b):
    impl, axes, _shape, opts = meta
    return dispatch.lookup("dot", impl)(a, b, axis=axes, **dict(opts))


def _dot_fwd(meta, a, b):
    return _dot_p(meta, a, b), (a, b)


def _dot_bwd(meta, res, g):
    _impl, axes, shape, _opts = meta
    a, b = res
    gfull = _expand(_g_val(g).hi, axes, shape)
    return gfull * b, gfull * a


_dot_p.defvjp(_dot_fwd, _dot_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _lse_p(meta, x):
    impl, axis, opts = meta
    return dispatch.lookup("logsumexp", impl)(x, axis=axis, **dict(opts))


def _lse_fwd(meta, x):
    out = _lse_p(meta, x)
    return out, (x, out)


def _lse_bwd(meta, res, g):
    _impl, axis, _opts = meta
    x, out = res
    ge = jnp.expand_dims(g, axis)
    return (ge * jnp.exp(x - jnp.expand_dims(out, axis)),)


_lse_p.defvjp(_lse_fwd, _lse_bwd)


def sum(x: Array, axis=None, *, impl: Optional[str] = None, **opts) -> FF:
    """Compensated sum of an f32 array -> FF (~44-bit accurate)."""
    x = jnp.asarray(x, jnp.float32)
    bshape = _bucket2d(x.shape)
    name = dispatch.resolve_name("sum", impl, shape=bshape)
    return _sum_p((name, _norm_axes(axis, x.ndim), x.shape,
                   _opts_tuple(_merge_tuned("sum", name, bshape, opts))), x)


def mean(x: Array, axis=None, *, impl: Optional[str] = None, **opts) -> FF:
    """Compensated mean of an f32 array -> FF."""
    x = jnp.asarray(x, jnp.float32)
    name = dispatch.resolve_name("mean", impl)
    return _mean_p((name, _norm_axes(axis, x.ndim), x.shape,
                    _opts_tuple(opts)), x)


def dot(a: Array, b: Array, axis=None, *, impl: Optional[str] = None,
        **opts) -> FF:
    """Compensated dot product (Dot2/Dot3 quality) -> FF."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    name = dispatch.resolve_name("dot", impl)
    return _dot_p((name, _norm_axes(axis, a.ndim), a.shape,
                   _opts_tuple(opts)), a, b)


def logsumexp(x: Array, axis: int = -1, *, impl: Optional[str] = None,
              **opts) -> Array:
    """Compensated log-sum-exp -> f32 array (gradient = softmax)."""
    x = jnp.asarray(x, jnp.float32)
    bshape = _bucket2d(x.shape)
    name = dispatch.resolve_name("logsumexp", impl, shape=bshape)
    return _lse_p((name, axis % x.ndim,
                   _opts_tuple(_merge_tuned("logsumexp", name, bshape,
                                            opts))), x)


# ---------------------------------------------------------------------------
# fused composite chains: softmax / mean_sq / norm_stats / adamw_update
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _softmax_p(meta, x):
    impl, axis, opts = meta
    return dispatch.lookup("softmax", impl)(x, axis=axis, **dict(opts))


def _softmax_fwd(meta, x):
    y = _softmax_p(meta, x)
    return y, y


def _softmax_bwd(meta, y, g):
    _impl, axis, _opts = meta
    dot = jnp.sum(g * y, axis=axis, keepdims=True)
    return ((g - dot) * y,)


_softmax_p.defvjp(_softmax_fwd, _softmax_bwd)


def softmax(x: Array, axis: int = -1, *, impl: Optional[str] = None,
            **opts) -> Array:
    """Compensated softmax -> f32 array.

    The denominator is an FF-accurate compensated exp-sum; on TPU the whole
    max/exp/sum/divide chain is ONE fused Pallas kernel (rows up to
    ``ff_fused.MAX_FUSED_COLS``; longer rows fall back to the jnp impl).
    """
    x = jnp.asarray(x, jnp.float32)
    bshape = _bucket2d(x.shape)
    name = dispatch.resolve_name("softmax", impl, shape=bshape)
    return _softmax_p((name, axis % x.ndim,
                       _opts_tuple(_merge_tuned("softmax", name, bshape,
                                                opts))), x)


# ---------------------------------------------------------------------------
# attention (fused FF flash attention; see kernels/ff_attention.py)
# ---------------------------------------------------------------------------

_ATTN_FAST_KEYS = ("causal", "block_q", "block_kv", "q_offset", "scale")


def _attn_fast_vjp(opts, g, q, k, v, kv_len=None):
    """Accurate-tier gradients route through ``jax.vjp`` over the FAST
    recurrence: the FF value is 2^-44-class, the gradients stay at
    flash-attention training precision (documented — same contract as
    every fused op whose bwd re-derives from the f32 formulation)."""
    fopts = {k_: v_ for k_, v_ in dict(opts).items() if k_ in _ATTN_FAST_KEYS}
    fn = dispatch.lookup("attention", "fast")
    _y, vjp = jax.vjp(
        lambda q_, k_, v_: fn(q_, k_, v_, kv_len=kv_len, **fopts), q, k, v)
    return vjp(g)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _attention_p(meta, q, k, v):
    impl, opts = meta
    return dispatch.lookup("attention", impl)(q, k, v, **dict(opts))


def _attention_fwd(meta, q, k, v):
    return _attention_p(meta, q, k, v), (q, k, v)


def _attention_bwd(meta, res, g):
    _impl, opts = meta
    return _attn_fast_vjp(opts, g, *res)


_attention_p.defvjp(_attention_fwd, _attention_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _attention_kv_p(meta, q, k, v, kv_len_f):
    impl, opts = meta
    return dispatch.lookup("attention", impl)(
        q, k, v, kv_len=kv_len_f.astype(jnp.int32), **dict(opts))


def _attention_kv_fwd(meta, q, k, v, kv_len_f):
    return _attention_kv_p(meta, q, k, v, kv_len_f), (q, k, v, kv_len_f)


def _attention_kv_bwd(meta, res, g):
    _impl, opts = meta
    q, k, v, kv_len_f = res
    dq, dk, dv = _attn_fast_vjp(opts, g, q, k, v,
                                kv_len=kv_len_f.astype(jnp.int32))
    # the per-row length is integer-semantics: it rides as f32 only
    # because custom_vjp must emit a cotangent for every operand
    return dq, dk, dv, jnp.zeros_like(kv_len_f)


_attention_kv_p.defvjp(_attention_kv_fwd, _attention_kv_bwd)


def attention(q: Array, k: Array, v: Array, *, causal: bool = True,
              q_offset: int = 0, kv_len: Optional[Array] = None,
              scale: Optional[float] = None, impl: Optional[str] = None,
              return_ff: bool = False, **opts):
    """Blockwise (flash) attention with registry-selected softmax class.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) with H = KV * G (GQA).
    ``impl="fast"`` (the default everywhere) is bitwise the pre-registry
    f32 online softmax — including its gradients, which take the plain-AD
    path.  The accurate tiers ("ff"/"pallas"/"f64") compute 2^-44-class
    attention weights (FF scores + ``ff.math.exp`` + TwoSum-carried
    accumulators; <= 2^-40 vs f64 on long-K rows, see docs/NUMERICS.md)
    and back-propagate through the fast recurrence.  ``kv_len``: optional
    (B,) per-row valid-key counts for ragged serving batches.
    ``return_ff=True`` returns the FF limb pair (scoring/validation path,
    outside the custom_vjp).  ``q_offset`` must be a concrete int (the
    accurate tiers' masks are staged per offset); decode loops use
    ``causal=False`` + ``kv_len`` instead.
    """
    bshape = _bucket2d((q.shape[1], k.shape[1]))
    name = dispatch.resolve_name("attention", impl, shape=bshape)
    merged = _merge_tuned("attention", name, bshape, opts)
    call = dict(causal=bool(causal), q_offset=int(q_offset),
                scale=None if scale is None else float(scale), **merged)
    fn = dispatch.lookup("attention", name)
    if return_ff:
        return fn(q, k, v, kv_len=kv_len, return_ff=True, **call)
    if name == "fast":
        return fn(q, k, v, kv_len=kv_len, **call)
    meta = (name, _opts_tuple(call))
    if kv_len is None:
        return _attention_p(meta, q, k, v)
    return _attention_kv_p(meta, q, k, v,
                           jnp.asarray(kv_len).astype(jnp.float32))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _mean_sq_p(meta, x):
    impl, _shape, opts = meta
    return dispatch.lookup("mean_sq", impl)(x, **dict(opts))


def _mean_sq_fwd(meta, x):
    return _mean_sq_p(meta, x), x


def _mean_sq_bwd(meta, x, g):
    _impl, shape, _opts = meta
    n = shape[-1]
    return (x * (2.0 * g[..., None] / jnp.float32(n)),)


_mean_sq_p.defvjp(_mean_sq_fwd, _mean_sq_bwd)


def mean_sq(x: Array, *, impl: Optional[str] = None, **opts) -> Array:
    """Compensated mean of squares over the last axis -> f32 (the RMSNorm
    statistic).  One fused kernel on TPU: x*x never touches HBM."""
    x = jnp.asarray(x, jnp.float32)
    bshape = _bucket2d(x.shape)
    name = dispatch.resolve_name("mean_sq", impl, shape=bshape)
    return _mean_sq_p((name, x.shape,
                       _opts_tuple(_merge_tuned("mean_sq", name, bshape,
                                                opts))), x)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _norm_stats_p(meta, x):
    impl, _shape, opts = meta
    return dispatch.lookup("norm_stats", impl)(x, **dict(opts))


def _norm_stats_fwd(meta, x):
    mu, var = _norm_stats_p(meta, x)
    return (mu, var), (x, mu)


def _norm_stats_bwd(meta, res, g):
    _impl, shape, _opts = meta
    x, mu = res
    gmu, gvar = g
    n = jnp.float32(shape[-1])
    dx = gmu[..., None] / n + gvar[..., None] * 2.0 * (x - mu[..., None]) / n
    return (dx,)


_norm_stats_p.defvjp(_norm_stats_fwd, _norm_stats_bwd)


def norm_stats(x: Array, *, impl: Optional[str] = None, **opts):
    """Compensated LayerNorm statistics over the last axis -> (mean, var),
    both f32.  One fused kernel on TPU: both reductions (mean and centered
    variance) share a single read of x."""
    x = jnp.asarray(x, jnp.float32)
    bshape = _bucket2d(x.shape)
    name = dispatch.resolve_name("norm_stats", impl, shape=bshape)
    return _norm_stats_p((name, x.shape,
                          _opts_tuple(_merge_tuned("norm_stats", name,
                                                   bshape, opts))), x)


def adamw_update(g: Array, m: Array, v: Array, w: Array, wlo: Array,
                 lr, b1, b2, bc1, bc2, *, eps: float, wd: float,
                 impl: Optional[str] = None, **opts):
    """The AdamW leaf update as ONE dispatched chain (~10 FF/f32 ops):
    moment updates, bias correction, decoupled weight decay, and the FF
    master-weight Add212 — fused into a single kernel launch on TPU.

    Returns ``(new_master FF, m2, v2)``.  Runs outside ``jax.grad``
    (optimizer step), so it carries no vjp rule.
    """
    g = jnp.asarray(g, jnp.float32)
    shape = _bucket2d(jnp.shape(g))
    name = dispatch.resolve_name("adamw_update", impl, shape=shape)
    opts = _merge_tuned("adamw_update", name, shape, opts)
    return dispatch.lookup("adamw_update", name)(
        g, m, v, w, wlo, lr, b1, b2, bc1, bc2, eps=eps, wd=wd, **opts)


# ---------------------------------------------------------------------------
# elementary functions (ff.math): one generic unary primitive + pow
# ---------------------------------------------------------------------------
#
# Derivative rules computed IN FF (same policy as the arithmetic ops):
# d(exp x) = exp(x) dx, d(log x) = dx/x, d(tanh x) = (1-t)(1+t) dx, etc. —
# each factor built from Mul22/Div22/the ffmath kernels, so gradients
# inherit the ~2^-43 operator accuracy (grad tests pin <= 2^-40 vs f64).

from repro.core import ffmath as _ffmath


def _ffc(pair, like) -> FF:
    h, l = pair
    return FF(jnp.broadcast_to(jnp.float32(h), jnp.shape(like.hi)),
              jnp.broadcast_to(jnp.float32(l), jnp.shape(like.hi)))


def _asff_op(x) -> FF:
    return x if isinstance(x, FF) else FF.from_f32(x)


def _one_minus(t: FF) -> FF:
    return core_ff.add212(FF(-t.hi, -t.lo), jnp.float32(1.0))


def _bwd_exp(gv, a, out):
    return core_ff.mul22(gv, out)


def _bwd_expm1(gv, a, out):
    return core_ff.mul22(gv, core_ff.add212(out, jnp.float32(1.0)))


def _bwd_log(gv, a, out):
    return core_ff.div22(gv, _asff_op(a))


def _bwd_log1p(gv, a, out):
    return core_ff.div22(gv, core_ff.add212(_asff_op(a), jnp.float32(1.0)))


def _bwd_tanh(gv, a, out):
    # (1 - t)(1 + t): factored form keeps relative accuracy as |t| -> 1
    sech2 = core_ff.mul22(_one_minus(out),
                          core_ff.add212(out, jnp.float32(1.0)))
    return core_ff.mul22(gv, sech2)


def _bwd_sigmoid(gv, a, out):
    return core_ff.mul22(gv, core_ff.mul22(out, _one_minus(out)))


def _bwd_erf(gv, a, out):
    af = _asff_op(a)
    z = core_ff.mul22(af, af)
    e = FF(*_ffmath.exp22(-z.hi, -z.lo))
    return core_ff.mul22(gv, core_ff.mul22(e, _ffc(_ffmath._TWO_OVER_SQRTPI,
                                                   af)))


# 1/sqrt(2 pi), FF (gelu's pdf factor)
_INV_SQRT2PI = (0.3989423, -1.133517e-08)


def _bwd_gelu(gv, a, out):
    # gelu'(x) = Phi(x) + x phi(x), Phi = 0.5 (1 + erf(x/sqrt2)),
    # phi = exp(-x^2/2)/sqrt(2 pi)
    af = _asff_op(a)
    v = core_ff.mul22(af, _ffc(_ffmath._INV_SQRT2, af))
    e = FF(*_ffmath.erf22(v.hi, v.lo))
    phi_cap = core_ff.add212(e, jnp.float32(1.0))
    phi_cap = FF(jnp.float32(0.5) * phi_cap.hi, jnp.float32(0.5) * phi_cap.lo)
    z = core_ff.mul22(af, af)
    w = FF(*_ffmath.exp22(jnp.float32(-0.5) * z.hi,
                          jnp.float32(-0.5) * z.lo))
    pdf = core_ff.mul22(w, _ffc(_INV_SQRT2PI, af))
    return core_ff.mul22(gv, core_ff.add22(phi_cap, core_ff.mul22(af, pdf)))


def _bwd_silu(gv, a, out):
    # silu'(x) = s (1 + x (1 - s))
    af = _asff_op(a)
    s = FF(*_ffmath.sigmoid22(af.hi, af.lo))
    inner = core_ff.add212(core_ff.mul22(af, _one_minus(s)), jnp.float32(1.0))
    return core_ff.mul22(gv, core_ff.mul22(s, inner))


_MATH_BWD = {
    "exp": _bwd_exp, "expm1": _bwd_expm1, "log": _bwd_log,
    "log1p": _bwd_log1p, "tanh": _bwd_tanh, "sigmoid": _bwd_sigmoid,
    "erf": _bwd_erf, "gelu": _bwd_gelu, "silu": _bwd_silu,
}


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _math1_p(meta, a):
    op, impl, _kind, opts = meta
    return dispatch.lookup(op, impl)(a, **dict(opts))


def _math1_fwd(meta, a):
    out = _math1_p(meta, a)
    return out, (a, out)


def _math1_bwd(meta, res, g):
    op, _impl, kind, _opts = meta
    a, out = res
    gv = _g_val(g)
    return (_ct(kind, _MATH_BWD[op](gv, a, out)),)


_math1_p.defvjp(_math1_fwd, _math1_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _pow_p(meta, a, b):
    _impl, _ka, _kb, opts = meta
    return dispatch.lookup("pow", meta[0])(a, b, **dict(opts))


def _pow_fwd(meta, a, b):
    out = _pow_p(meta, a, b)
    return out, (a, b, out)


def _pow_bwd(meta, res, g):
    a, b, out = res
    gv = _g_val(g)
    af, bf = _asff_op(a), _asff_op(b)
    da = core_ff.mul22(gv, core_ff.mul22(bf, core_ff.div22(out, af)))
    ln_a = FF(*_ffmath.log22(af.hi, af.lo))
    db = core_ff.mul22(gv, core_ff.mul22(out, ln_a))
    return _ct(meta[1], da), _ct(meta[2], db)


_pow_p.defvjp(_pow_fwd, _pow_bwd)
