"""Backend dispatch registry for ``repro.ff``.

The paper presents float-float operators as a *library the application calls
uniformly*, with the GPU backend hidden behind the operator.  This module is
that seam for the JAX port: each public op name maps to a set of named
implementations, and resolution picks one per call site at trace time:

    per-call ``impl=`` kwarg
      > ``ff.use(op=impl)`` scope
      > policy (``PrecisionPolicy.matmul_impl``, for ``matmul``)
      > mesh default (ops with a registered mesh impl, inside ``ff.on_mesh``)
      > per-backend default registered here
      > first registered implementation

    Resolution is therefore *backend x mesh-context*: the same call site
    picks the best single-device implementation for the active backend, and
    — only inside an ``ff.on_mesh`` scope — the ``shard_map``-partitioned
    implementation from ``repro.ff.sharded``.  Single-device call sites
    (no mesh scope) never see the mesh tier.

Implementations are plain callables over ``repro.core`` algorithms and
``repro.kernels`` Pallas kernels; several are themselves backend-aware
(compiled Pallas on TPU, interpret-Pallas or pure-jnp on CPU) so "best
implementation per backend" lives in exactly one place.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import compensated, ffmatmul, ffmath
from repro.core import ff as core_ff
from repro.core import transforms as T
from repro.core.ff import FF
from repro.ff import scope

Array = jnp.ndarray

_REGISTRY: Dict[str, Dict[str, Callable]] = {}
_DEFAULTS: Dict[str, Dict[str, str]] = {}     # op -> {backend|"*": impl}
_MESH_DEFAULTS: Dict[str, str] = {}           # op -> impl inside ff.on_mesh

# static fallback order for a "tuned_accurate" request on an untuned shape
# bucket (see resolve_name): per-op, first registered name wins
_ACCURATE_FALLBACK: Dict[str, Tuple[str, ...]] = {
    "matmul": ("f64", "ozaki", "dot2"),
    "add": ("accurate",),
    # composites whose f32-builtin exponentials cap them at the fast class:
    # the accurate tier is the ff.math-powered impl
    "softmax": ("ff",),
    "logsumexp": ("ff",),
    # attention: native-f64 materialized scores where the hardware has
    # them (size-guarded; degrades to the FF recurrence on TPU / at
    # training shapes), else the compensated jnp recurrence
    "attention": ("f64", "ff"),
    # ff.math family: native f64 where the hardware has it (degrades to the
    # compensated jnp formulation on TPU), else the FF kernel itself
    **{op: ("f64", "jnp") for op in tuple(ffmath.UNARY22) + ("pow",)},
}


def backend() -> str:
    """The JAX backend the dispatcher routes for ("cpu", "tpu", "gpu")."""
    return jax.default_backend()


def register(op: str, impl: str, fn: Callable, *,
             default_for: Tuple[str, ...] = (),
             mesh_default: bool = False) -> Callable:
    """Register ``fn`` as implementation ``impl`` of ``op``.

    ``default_for`` lists backends this impl is the default on ("*" = any
    backend without a more specific default).  ``mesh_default=True`` makes
    it the default *inside an* ``ff.on_mesh`` *scope* (mesh-context
    resolution; see module docstring) — outside any mesh scope it is only
    reachable by explicit ``impl=``/``ff.use`` selection.
    """
    _REGISTRY.setdefault(op, {})[impl] = fn
    for b in default_for:
        _DEFAULTS.setdefault(op, {})[b] = impl
    if mesh_default:
        _MESH_DEFAULTS[op] = impl
    return fn


def mesh_default(op: str) -> Optional[str]:
    """The implementation ``op`` resolves to inside ``ff.on_mesh`` scopes
    (``None`` when the op has no mesh-partitioned implementation)."""
    return _MESH_DEFAULTS.get(op)


def ops() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def impls(op: str) -> Tuple[str, ...]:
    """Registered implementation names for ``op``."""
    return tuple(sorted(_REGISTRY.get(op, ())))


def resolve_name(op: str, impl: Optional[str] = None,
                 shape: Optional[Tuple[int, ...]] = None) -> str:
    """Resolve which implementation a call to ``op`` uses (see module doc).

    With ``shape`` (e.g. ``(M, K, N)`` for matmul), the measurement-driven
    tuning table (``repro.ff.tune``) participates in resolution:

      * the special names ``"tuned"`` / ``"tuned_accurate"`` (usable
        per-call, in ``ff.use`` scopes and in ``policy(matmul=...)``)
        resolve to the cached winner of the fast / accurate class;
      * when resolution falls through to the backend default (no explicit
        choice anywhere), a cached fast-class winner overrides the static
        default — ``dispatch_default`` is then never slower than the best
        registered impl wherever measurements exist.
    """
    if op not in _REGISTRY:
        raise KeyError(f"unknown ff op {op!r}; registered: {ops()}")
    # `src` tracks which resolution rule actually picked the winner — it
    # feeds the ff_dispatch_resolutions_total telemetry counter below.
    # Resolution runs at trace time only, so the recording is free in
    # steady-state jit execution.
    name = impl or scope.current_impl(op)
    src = ("explicit" if impl else
           "scope" if name is not None else None)
    if name is None and op == "matmul":
        pol = scope.current_policy().matmul_impl
        if pol and pol != "auto":
            name, src = pol, "policy"
    # mesh-context resolution: inside an ff.on_mesh scope, ops with a
    # registered mesh impl route to the shard_map tier UNLESS something
    # more explicit (per-call impl, use() scope, policy) chose otherwise.
    # Outside any mesh scope this branch never fires — single-device call
    # sites resolve exactly as before.
    if name is None and op in _MESH_DEFAULTS \
            and scope.current_mesh() is not None:
        name, src = _MESH_DEFAULTS[op], "mesh"
    if name in ("tuned", "tuned_accurate"):
        from repro.ff import tuning as _tune
        accurate = name == "tuned_accurate"
        name = (_tune.lookup_impl(op, shape,
                                  "accurate" if accurate else "fast")
                if shape is not None else None)
        src = "tuned_accurate" if accurate else "tuned"
        if name is not None and name not in _REGISTRY[op]:
            name = None   # stale/foreign sidecar must never break dispatch
        # an explicit accurate-tier request must NEVER degrade to the fast
        # class just because the shape bucket is untuned — fall back to the
        # static accurate-tier default (per-op: e.g. matmul's "f64"
        # resolves to one native dgemm where the hardware has f64 and
        # degrades to the fused Ozaki kernel on TPU)
        if name is None and accurate:
            reg = _REGISTRY.get(op, {})
            name = next((c for c in _ACCURATE_FALLBACK.get(op, ())
                         if c in reg), None)
            src = "accurate_fallback"
    if name is None and shape is not None:
        from repro.ff import tuning as _tune
        name = _tune.lookup_impl(op, shape)
        src = "tuned_default"
        if name is not None and name not in _REGISTRY[op]:
            name = None   # see above: unknown tuned winner -> static default
    if name is None:
        d = _DEFAULTS.get(op, {})
        name = d.get(backend(), d.get("*"))
        src = "static_default"
    if name is None:
        name, src = next(iter(_REGISTRY[op])), "first_registered"
    if name not in _REGISTRY[op]:
        raise KeyError(
            f"ff op {op!r} has no implementation {name!r}; "
            f"available: {impls(op)}")
    # guard-context resolution: inside an ff.guard(mode="degrade") scope
    # that has recorded a violation for this op, the accurate-class
    # resolution drops one class (ff -> fast f32) — identity everywhere
    # else (see repro.ff.guard.maybe_degrade).
    import sys
    _guard = sys.modules.get("repro.ff.guard")   # NOT `from repro.ff import
    if _guard is None:                           # guard` — the package attr
        from importlib import import_module      # is the scope *class*
        _guard = import_module("repro.ff.guard")
    final = _guard.maybe_degrade(op, name)
    if final != name:
        src = "guard_degraded"
    _record_resolution(op, final, src or "static_default", shape)
    return final


def _record_resolution(op: str, name: str, src: str,
                       shape: Optional[Tuple[int, ...]]) -> None:
    """Dispatch telemetry (trace-time only): count (op, impl, source,
    backend, shape-bucket) into the process-global obs registry.  Lazy
    import — repro.obs must never be a hard import of the dispatch core,
    and obs itself never imports repro.ff (no cycle)."""
    try:
        from repro import obs as _obs
        if shape:
            from repro.ff import tuning as _tune
            bucket = _tune.bucket_key(shape)
        else:
            bucket = ""
        _obs.record_resolution(op, name, src, backend(), bucket)
    except Exception:     # telemetry must never break dispatch
        pass


def resolve_opts(op: str, name: str,
                 shape: Optional[Tuple[int, ...]] = None) -> dict:
    """Measured-best block config for ``name`` at ``shape`` (empty when the
    tuning table has no entry).  Callers merge these UNDER explicit opts."""
    if shape is None:
        return {}
    from repro.ff import tuning as _tune
    return _tune.lookup_opts(op, name, shape)


def lookup(op: str, impl: str) -> Callable:
    return _REGISTRY[op][impl]


def call(op: str, impl: Optional[str], *args, **kw):
    return lookup(op, resolve_name(op, impl))(*args, **kw)


# ===========================================================================
# implementation registrations
# ===========================================================================

def _interpret(flag: Optional[bool]) -> bool:
    """Pallas interpret mode: explicit flag wins, else compiled on TPU only."""
    return (backend() != "tpu") if flag is None else flag


def _fallback_warn(impl: str, op: str, why: str) -> None:
    """A kernel impl substituting its jnp formulation must say so: tuned
    winners/defaults must never brick a call, but an EXPLICIT impl=
    request landing here would otherwise silently validate or benchmark
    the wrong kernel.  Fires once per trace (Python-level warn)."""
    import warnings
    warnings.warn(f"ff.{op}(impl={impl!r}): {why}; falling back to the "
                  f"jnp formulation", stacklevel=3)


def _as_ff(x) -> FF:
    if isinstance(x, FF):
        return x
    return FF.from_f32(jnp.asarray(x, jnp.float32))


# -- elementwise add/mul/div/sqrt -------------------------------------------

def _add_jnp(a, b, **_kw) -> FF:
    if isinstance(a, FF) and not isinstance(b, FF):
        return core_ff.add212(a, jnp.asarray(b, jnp.float32))
    if isinstance(b, FF) and not isinstance(a, FF):
        return core_ff.add212(b, jnp.asarray(a, jnp.float32))
    return core_ff.add22(_as_ff(a), _as_ff(b))


def _add_accurate(a, b, **_kw) -> FF:
    return core_ff.add22_accurate(_as_ff(a), _as_ff(b))


def _mul_jnp(a, b, **_kw) -> FF:
    if isinstance(a, FF) and not isinstance(b, FF):
        return core_ff.mul212(a, jnp.asarray(b, jnp.float32))
    if isinstance(b, FF) and not isinstance(a, FF):
        return core_ff.mul212(b, jnp.asarray(a, jnp.float32))
    return core_ff.mul22(_as_ff(a), _as_ff(b))


def _ew_block(block) -> tuple:
    from repro.kernels import ff_elementwise
    return tuple(block) if block else ff_elementwise.DEFAULT_BLOCK


def _elementwise_pallas(op22):
    def fn(a, b, *, block=None, interpret: Optional[bool] = None,
           **_kw) -> FF:
        from repro.kernels import ff_elementwise
        af, bf = _as_ff(a), _as_ff(b)
        rh, rl = ff_elementwise.elementwise(
            op22, af.hi, af.lo, bf.hi, bf.lo, block=_ew_block(block),
            interpret=_interpret(interpret))
        return FF(rh, rl)
    return fn


def _div_jnp(a, b, **_kw) -> FF:
    return core_ff.div22(_as_ff(a), _as_ff(b))


def _sqrt_jnp(a, **_kw) -> FF:
    return core_ff.sqrt22(_as_ff(a))


def _sqrt_pallas(a, *, block=None, interpret: Optional[bool] = None,
                 **_kw) -> FF:
    from repro.kernels import ff_elementwise
    af = _as_ff(a)
    rh, rl = ff_elementwise.elementwise(
        "sqrt22", af.hi, af.lo, block=_ew_block(block),
        interpret=_interpret(interpret))
    return FF(rh, rl)


# Elementwise default is jnp on EVERY backend: a 4-20 flop FF op fuses into
# the surrounding XLA graph, while a standalone pallas_call pads operands to
# (8,128) tiles and breaks fusion — Pallas only wins where a kernel owns a
# loop (matmul/rowsum below) or a whole CHAIN of FF ops rides one launch
# (ff.fused / the composite kernels below).  The per-op pallas impls stay
# registered for validation and for explicit callers.
register("add", "jnp", _add_jnp, default_for=("*",))
register("add", "accurate", _add_accurate)
register("add", "pallas", _elementwise_pallas("add22"))
register("mul", "jnp", _mul_jnp, default_for=("*",))
register("mul", "pallas", _elementwise_pallas("mul22"))
register("div", "jnp", _div_jnp, default_for=("*",))
register("div", "pallas", _elementwise_pallas("div22"))
register("sqrt", "jnp", _sqrt_jnp, default_for=("*",))
register("sqrt", "pallas", _sqrt_pallas)


# -- EFTs (f32, f32) -> FF ---------------------------------------------------

def _two_sum_jnp(a, b) -> FF:
    s, r = T.two_sum(jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32))
    return FF(s, r)


def _two_prod_jnp(a, b) -> FF:
    x, y = T.two_prod(jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32))
    return FF(x, y)


def _eft_pallas(op):
    def fn(a, b, *, interpret: Optional[bool] = None) -> FF:
        from repro.kernels import ff_elementwise
        x, y = ff_elementwise.elementwise(
            op, jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
            interpret=_interpret(interpret))
        return FF(x, y)
    return fn


register("two_sum", "jnp", _two_sum_jnp, default_for=("*",))
register("two_sum", "pallas", _eft_pallas("two_sum"))
register("two_prod", "jnp", _two_prod_jnp, default_for=("*",))
register("two_prod", "pallas", _eft_pallas("two_prod"))


# -- matmul (f32/FF operands handled by the autodiff layer; these take f32) --

def _mm_hybrid(a: Array, b: Array, *, block_k: int = 512,
               bm: int = 256, bn: int = 256,
               interpret: Optional[bool] = None, **_kw) -> FF:
    """Blocked-K MXU + Add22 — the production path.  Compiled Pallas on TPU,
    pure-jnp (identical K-block order) elsewhere."""
    if backend() == "tpu" and interpret is not True:
        from repro.kernels import ff_matmul
        hi, lo = ff_matmul.ff_matmul(a, b, bm=bm, bn=bn, bk=block_k,
                                     interpret=False)
        return FF(hi, lo)
    return ffmatmul.matmul_compensated(a, b, block_k=block_k)


def _mm_pallas_hybrid(a: Array, b: Array, *, bm: int = 256, bn: int = 256,
                      bk: int = 512, interpret: Optional[bool] = None,
                      **_kw) -> FF:
    from repro.kernels import ff_matmul
    hi, lo = ff_matmul.ff_matmul(a, b, bm=bm, bn=bn, bk=bk,
                                 interpret=_interpret(interpret))
    return FF(hi, lo)


def _mm_dot2(a: Array, b: Array, *, bm: int = 128, bn: int = 128,
             bk: int = 128, vec: int = 8, chunk: int = 32,
             interpret: Optional[bool] = None, **_kw) -> FF:
    """Paper-faithful Mul12 + Dot3 cascade (~2^-44), block-vectorized over
    K.  Pallas kernel on TPU, pure-jnp chunked scan elsewhere."""
    if backend() == "tpu" and interpret is not True:
        from repro.kernels import ff_matmul
        hi, lo = ff_matmul.ff_matmul_dot2(a, b, bm=bm, bn=bn, bk=bk,
                                          vec=vec, interpret=False)
        return FF(hi, lo)
    return ffmatmul.matmul_dot2(a, b, chunk=chunk)


def _mm_pallas_dot2(a: Array, b: Array, *, bm: int = 128, bn: int = 128,
                    bk: int = 128, vec: int = 8,
                    interpret: Optional[bool] = None, **_kw) -> FF:
    from repro.kernels import ff_matmul
    hi, lo = ff_matmul.ff_matmul_dot2(a, b, bm=bm, bn=bn, bk=bk, vec=vec,
                                      interpret=_interpret(interpret))
    return FF(hi, lo)


def _mm_split(a: Array, b: Array, *, block_k: int = 512, **_kw) -> FF:
    return ffmatmul.matmul_split(a, b, block_k=block_k)


def _mm_compensated(a: Array, b: Array, *, block_k: int = 512, **_kw) -> FF:
    return ffmatmul.matmul_compensated(a, b, block_k=block_k)


def _mm_ozaki(a: Array, b: Array, *, slices: int = 0, beta: int = 0,
              block_k: int = 0, interpret: Optional[bool] = None,
              **_kw) -> FF:
    """Exact-slice Ozaki matmul (~2^-46): fused Pallas kernel on TPU,
    batched stacked-GEMM jnp path elsewhere."""
    from repro.obs import annotate
    with annotate("ff.matmul_ozaki"):
        if backend() == "tpu" and interpret is not True:
            from repro.kernels import ff_matmul
            hi, lo = ff_matmul.ff_matmul_ozaki(
                a, b, slices=slices, beta=beta,
                bk=block_k or 512, interpret=False)
            return FF(hi, lo)
        return ffmatmul.matmul_ozaki(a, b, slices=slices, beta=beta,
                                     block_k=block_k)


def _mm_f64(a: Array, b: Array, *, interpret: Optional[bool] = None,
            **_kw) -> FF:
    """Native-f64 dgemm rounded to FF (~2^-48) — the accurate tier at
    hardware speed on backends that HAVE f64 (CPU, most GPUs).  TPU has no
    f64 unit, so the same name degrades gracefully to the best pure-f32
    accurate impl there (the fused Ozaki kernel): "f64" means "f64-quality
    results the fastest way this hardware can", which on f32-only hardware
    is exactly the paper's emulation."""
    if backend() == "tpu":
        return _mm_ozaki(a, b, interpret=interpret)
    return ffmatmul.matmul_f64(a, b)


def _mm_pallas_ozaki(a: Array, b: Array, *, slices: int = 0, beta: int = 0,
                     bm: int = 128, bn: int = 128, bk: int = 512,
                     interpret: Optional[bool] = None, **_kw) -> FF:
    from repro.kernels import ff_matmul
    hi, lo = ff_matmul.ff_matmul_ozaki(a, b, slices=slices, beta=beta,
                                       bm=bm, bn=bn, bk=bk,
                                       interpret=_interpret(interpret))
    return FF(hi, lo)


register("matmul", "hybrid", _mm_hybrid, default_for=("*",))
register("matmul", "pallas_hybrid", _mm_pallas_hybrid)
register("matmul", "compensated", _mm_compensated)
register("matmul", "split", _mm_split)
register("matmul", "dot2", _mm_dot2)
register("matmul", "pallas_dot2", _mm_pallas_dot2)
register("matmul", "ozaki", _mm_ozaki)
register("matmul", "pallas_ozaki", _mm_pallas_ozaki)
register("matmul", "f64", _mm_f64)


# -- reductions --------------------------------------------------------------

def _sum_blocked(x: Array, axis=None, *, block: int = 128, **_kw) -> FF:
    return compensated.ff_sum_blocked(x, axis=axis, block=block)


def _sum_cascade(x: Array, axis=None, **_kw) -> FF:
    return compensated.ff_sum(x, axis=axis)


def _sum_pallas_rowsum(x: Array, axis=None, *, br: int = 256, bc: int = 512,
                       lane: int = 128,
                       interpret: Optional[bool] = None, **_kw) -> FF:
    """Pallas row-reduction kernel over the last axis.  ND inputs flatten
    to (prod(leading), last) — the real call sites are 3-D/4-D
    activations and must actually reach the kernel.  Non-last axes fall
    back to the blocked jnp impl: this name can be a TUNED default for a
    shape bucket, and a tuned winner must never brick a call."""
    from repro.kernels import ff_reduce
    if isinstance(axis, tuple) and len(axis) == 1:
        axis = axis[0]
    if x.ndim < 1 or axis not in (-1, x.ndim - 1):
        _fallback_warn("pallas_rowsum", "sum",
                       f"axis {axis} of a {x.ndim}-D input is not a "
                       f"last-axis row reduction")
        return _sum_blocked(x, axis=axis)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]) if x.ndim != 2 else x
    hi, lo = ff_reduce.ff_rowsum(x2, br=br, bc=bc, lane=lane,
                                 interpret=_interpret(interpret))
    return FF(hi.reshape(lead), lo.reshape(lead))


def _dot_jnp(a: Array, b: Array, axis=None, **_kw) -> FF:
    return compensated.ff_dot(a, b, axis=axis)


def _mean_jnp(x: Array, axis=None, *, block: int = 128, **_kw) -> FF:
    n = x.size if axis is None else 1
    if axis is not None:
        axes = (axis,) if isinstance(axis, int) else tuple(axis)
        for ax in axes:
            n *= x.shape[ax]
    s = compensated.ff_sum_blocked(x, axis=axis, block=block)
    # divide in FF: multiplying by an f32-rounded 1/n would cap the op at
    # ~2^-24 (FF.from_f64 keeps n exact to 2^48, covering any real axis)
    return core_ff.div22(s, FF.from_f64(float(n)))


def _logsumexp_jnp(x: Array, axis: int = -1, *, block: int = 256, **_kw):
    """Compensated LSE: returns the f32 log-sum-exp values."""
    x = jnp.asarray(x, jnp.float32)
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    s = compensated.ff_sum_blocked(e, axis=axis, block=block)
    return jnp.squeeze(m, axis=axis) + jnp.log(s.to_f32())


def _last_axis_fusable(x: Array, axis: int) -> bool:
    """Whether the whole-row composite kernels apply: last-axis reduction
    with the row fitting the VMEM budget (see ff_fused.MAX_FUSED_COLS)."""
    from repro.kernels import ff_fused
    return (x.ndim >= 1 and axis in (-1, x.ndim - 1)
            and x.shape[-1] <= ff_fused.MAX_FUSED_COLS)


def _logsumexp_pallas(x: Array, axis: int = -1, *, br: int = 256,
                      interpret: Optional[bool] = None, **_kw):
    """One-kernel max + exp + compensated sum + log (whole row in VMEM).
    Registered as the TPU default, so it must never brick a call it cannot
    serve: non-last axes / over-long rows fall back to the jnp impl."""
    x = jnp.asarray(x, jnp.float32)
    if not _last_axis_fusable(x, axis):
        _fallback_warn("pallas", "logsumexp",
                       "not a last-axis reduction within MAX_FUSED_COLS")
        return _logsumexp_jnp(x, axis=axis)
    from repro.kernels import ff_fused
    return ff_fused.ff_softmax(x, mode="logsumexp", br=br,
                               interpret=_interpret(interpret))


import functools as _ft


@_ft.partial(jax.jit, static_argnames=("axis",))
def _sum_f64_axis(e: Array, axis: int) -> Array:
    """Exp-sum at native f64 (the matmul_f64 corollary for reductions):
    on hardware WITH f64 units one wide sum reaches ~2^-53-per-step
    accuracy — past FF quality — at naive-sum speed.  Scoped exactly like
    ``ffmatmul._matmul_f64_jit`` (trace-local enable_x64 behind a nested
    jit boundary; see its docstring for why the boundary is load-bearing
    — and module-level like it, so eager callers hit the jit cache
    instead of recompiling per call)."""
    import jax.experimental
    from jax import lax

    with jax.experimental.enable_x64():
        s = jnp.sum(lax.convert_element_type(e, jnp.float64), axis=axis)
        return lax.convert_element_type(s, jnp.float32)


def _logsumexp_f64(x: Array, axis: int = -1, **_kw):
    """Compensated-quality LSE via a native-f64 exp-sum (CPU default).
    Like matmul's "f64", the name means "f64-quality the fastest way this
    hardware can": TPU has no f64 unit, so it degrades to the fused
    Pallas kernel there."""
    if backend() == "tpu":
        return _logsumexp_pallas(x, axis=axis)
    x = jnp.asarray(x, jnp.float32)
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return jnp.squeeze(m, axis=axis) + jnp.log(_sum_f64_axis(e, axis))


def _softmax_f64(x: Array, axis: int = -1, **_kw):
    """Compensated-quality softmax via a native-f64 denominator; degrades
    to the fused Pallas kernel on TPU (see _logsumexp_f64)."""
    if backend() == "tpu":
        return _softmax_pallas(x, axis=axis)
    x = jnp.asarray(x, jnp.float32)
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    s = _sum_f64_axis(e, axis)
    return e / jnp.expand_dims(s, axis % x.ndim)


register("sum", "blocked", _sum_blocked, default_for=("*",))
register("sum", "cascade", _sum_cascade)
register("sum", "pallas_rowsum", _sum_pallas_rowsum)
register("dot", "jnp", _dot_jnp, default_for=("*",))
register("mean", "jnp", _mean_jnp, default_for=("*",))
# per-backend resolution like every other op: jnp is the generic default,
# the fused Pallas kernel takes over where it is compiled (TPU), and the
# native-f64 reduction where the hardware has f64 units (CPU) — the old
# blanket default_for=("*",) left every non-jnp path dead code
register("logsumexp", "jnp", _logsumexp_jnp, default_for=("*",))
register("logsumexp", "pallas", _logsumexp_pallas, default_for=("tpu",))
register("logsumexp", "f64", _logsumexp_f64, default_for=("cpu",))


# -- fused composite chains (the hot real-world FF pipelines) ----------------
#
# Each composite is ONE dispatch op with a jnp fallback (bitwise-identical
# to the op-by-op formulation it replaced) and a fused implementation that
# rides a single kernel launch — compiled Pallas on TPU, the replayed-jnp
# executor elsewhere (same graph XLA already fuses).  Callers go through
# the differentiable wrappers in repro.ff.autodiff.

def _softmax_jnp(x: Array, axis: int = -1, *, block: int = 256, **_kw):
    """Compensated softmax: exp(x - max) / FF-accurate denominator."""
    x = jnp.asarray(x, jnp.float32)
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    s = compensated.ff_sum_blocked(e, axis=axis, block=block)
    return e / jnp.expand_dims(s.to_f32(), axis % x.ndim)


def _softmax_pallas(x: Array, axis: int = -1, *, br: int = 256,
                    interpret: Optional[bool] = None, **_kw):
    x = jnp.asarray(x, jnp.float32)
    if not _last_axis_fusable(x, axis):
        _fallback_warn("pallas", "softmax",
                       "not a last-axis reduction within MAX_FUSED_COLS")
        return _softmax_jnp(x, axis=axis)
    from repro.kernels import ff_fused
    return ff_fused.ff_softmax(x, mode="softmax", br=br,
                               interpret=_interpret(interpret))


register("softmax", "jnp", _softmax_jnp, default_for=("*",))
register("softmax", "pallas", _softmax_pallas, default_for=("tpu",))
register("softmax", "f64", _softmax_f64, default_for=("cpu",))


def _adamw_chain(sqrtf, packf, addf, g, m, v, w, wlo,
                 lr, b1, b2, bc1, bc2, eps, wd):
    """THE AdamW leaf update — shared verbatim between the jnp impl and
    the fused tracer so the two can never drift (op order is bitwise-
    load-bearing: `(1.0 - b2) * g * g` associates left)."""
    m2 = b1 * m + (1.0 - b1) * g
    v2 = b2 * v + (1.0 - b2) * g * g
    upd = (m2 / bc1) / (sqrtf(v2 / bc2) + eps)
    upd = upd + wd * w
    delta = -lr * upd
    new = addf(packf(w, wlo), delta)        # Add212: FF master += delta
    return new, m2, v2


def _adamw_jnp(g, m, v, w, wlo, lr, b1, b2, bc1, bc2, *,
               eps: float, wd: float, **_kw):
    return _adamw_chain(jnp.sqrt, FF, core_ff.add212,
                        g, m, v, w, wlo, lr, b1, b2, bc1, bc2, eps, wd)


def _adamw_fused(g, m, v, w, wlo, lr, b1, b2, bc1, bc2, *,
                 eps: float, wd: float,
                 interpret: Optional[bool] = None, **_kw):
    from repro.ff import fusion

    fn = fusion.fused(lambda *a: _adamw_chain(
        fusion.sqrt, fusion.pack, (lambda x, y: x + y), *a, eps, wd))
    return fn(g, m, v, w, wlo, lr, b1, b2, bc1, bc2,
              interpret=interpret)


register("adamw_update", "jnp", _adamw_jnp, default_for=("*",))
register("adamw_update", "fused", _adamw_fused, default_for=("tpu",))


def _mean_sq_jnp(x: Array, *, block: int = 128, **_kw) -> Array:
    """RMSNorm statistic: compensated mean of squares -> f32."""
    x = jnp.asarray(x, jnp.float32)
    return (compensated.ff_sum_blocked(x * x, axis=-1, block=block).to_f32()
            / x.shape[-1])


def _mean_sq_fused(x: Array, *, interpret: Optional[bool] = None,
                   **_kw) -> Array:
    from repro.ff import fusion

    x = jnp.asarray(x, jnp.float32)
    if not _last_axis_fusable(x, -1):
        _fallback_warn("fused", "mean_sq", "row exceeds MAX_FUSED_COLS")
        return _mean_sq_jnp(x)
    fn = fusion.fused(lambda xf: (xf * xf).sum())
    return fn(x, interpret=interpret).to_f32() / x.shape[-1]


register("mean_sq", "jnp", _mean_sq_jnp, default_for=("*",))
register("mean_sq", "fused", _mean_sq_fused, default_for=("tpu",))


def _norm_stats_jnp(x: Array, *, block: int = 128, **_kw):
    """LayerNorm statistics: compensated mean and centered variance."""
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[-1]
    mu = compensated.ff_sum_blocked(x, axis=-1, block=block).to_f32() / n
    var = compensated.ff_sum_blocked(
        (x - mu[..., None]) ** 2, axis=-1, block=block).to_f32() / n
    return mu, var


def _norm_stats_pallas(x: Array, *, br: int = 256,
                       interpret: Optional[bool] = None, **_kw):
    x = jnp.asarray(x, jnp.float32)
    if not _last_axis_fusable(x, -1):
        _fallback_warn("pallas", "norm_stats", "row exceeds MAX_FUSED_COLS")
        return _norm_stats_jnp(x)
    from repro.kernels import ff_fused
    return ff_fused.ff_norm_stats(x, br=br, interpret=_interpret(interpret))


register("norm_stats", "jnp", _norm_stats_jnp, default_for=("*",))
register("norm_stats", "pallas", _norm_stats_pallas, default_for=("tpu",))


# -- FF elementary functions (the ff.math subsystem) -------------------------
#
# Four implementation classes per function, mirroring the matmul tiers:
#
#   * ``jnp``     — the compensated reference: repro.core.ffmath argument
#                   reduction + FF polynomial kernels over the barrier-
#                   carrying core EFTs (the default on every backend
#                   WITHOUT native f64 — i.e. everywhere but CPU below;
#                   fuses into the surrounding XLA graph like the
#                   arithmetic elementwise ops).
#   * ``pallas``  — the same algorithm as a Pallas kernel (barrier-free
#                   eft primitives; compiled on TPU, interpret-mode
#                   validation elsewhere).  Bitwise-identical to ``jnp``
#                   under the EFT-safe ISA contract.
#   * ``f64``     — native double transcendental rounded to FF, scoped
#                   exactly like ``matmul_f64`` (trace-local enable_x64
#                   behind a module-level nested jit).  The accurate-tier
#                   default on CPU; degrades to ``jnp`` on TPU (no f64
#                   unit) — "f64-quality the fastest way this hardware
#                   can".
#   * ``fast``    — the f32 builtin on the rounded hi limb, lifted back to
#                   FF with a zero lo.  ~2^-24: a *documented-contract*
#                   escape hatch for throughput experiments, never a
#                   default and never fast-winner eligible in ff.tune.

MATH_UNARY_OPS: Tuple[str, ...] = tuple(sorted(ffmath.UNARY22))
MATH_OPS: Tuple[str, ...] = MATH_UNARY_OPS + ("pow",)


def _math_jnp(op: str):
    fn = ffmath.UNARY22[op]

    def impl(a, **_kw) -> FF:
        af = _as_ff(a)
        return FF(*fn(af.hi, af.lo, ffmath.CORE))
    return impl


def _math_pallas(op: str):
    def impl(a, *, block=None, interpret: Optional[bool] = None,
             **_kw) -> FF:
        from repro.kernels import ff_math
        af = _as_ff(a)
        rh, rl = ff_math.math_elementwise(
            op, af.hi, af.lo,
            block=tuple(block) if block else ff_math.DEFAULT_BLOCK,
            interpret=_interpret(interpret))
        return FF(rh, rl)
    return impl


def _math_f64_fns():
    # resolved lazily inside the jitted body so the x64 scope is active.
    # gelu is spelled out with weakly-typed python-float constants:
    # jax.nn.gelu's own constants canonicalize to f32 under the ambient
    # (x64-off) jit config and poison the f64 trace
    from jax import lax as _lax

    # constants are DERIVED from the traced value (exp(x-x) == 1): a bare
    # literal — python float or jnp.float64 — gets constant-folded at
    # trace time and canonicalized back to f32 under the ambient x64-off
    # config, poisoning the f64 graph (same hazard _pow_f64_jit dodges)
    def sig(x):
        one = jnp.exp(x - x)
        return one / (one + jnp.exp(-x))

    def gelu(x):
        one = jnp.exp(x - x)
        two = one + one
        return (one / two) * x * (one + _lax.erf(x / jnp.sqrt(two)))

    return {
        "exp": jnp.exp, "expm1": jnp.expm1, "log": jnp.log,
        "log1p": jnp.log1p, "tanh": jnp.tanh, "sigmoid": sig,
        "erf": _lax.erf, "gelu": gelu,
        "silu": lambda x: x * sig(x),
    }


@_ft.partial(jax.jit, static_argnames=("op",))
def _math_f64_jit(op: str, ah: Array, al: Array) -> Tuple[Array, Array]:
    """Native-f64 elementary function -> FF (the matmul_f64 corollary for
    transcendentals).  Same trace-scoped enable_x64 behind a module-level
    nested-jit boundary (see ``ffmatmul._matmul_f64_jit`` for why the
    boundary is load-bearing under custom_vjp lowering)."""
    import jax.experimental
    from jax import lax

    with jax.experimental.enable_x64():
        x = (lax.convert_element_type(ah, jnp.float64)
             + lax.convert_element_type(al, jnp.float64))
        r = _math_f64_fns()[op](x)
        hi = lax.convert_element_type(r, jnp.float32)
        lo = lax.convert_element_type(
            r - lax.convert_element_type(hi, jnp.float64), jnp.float32)
    return hi, lo


def _math_f64(op: str):
    jnp_impl = _math_jnp(op)

    def impl(a, **_kw) -> FF:
        if backend() == "tpu":
            return jnp_impl(a)
        af = _as_ff(a)
        return FF(*_math_f64_jit(op, af.hi, af.lo))
    return impl


_MATH_FAST_FNS = {
    "exp": jnp.exp, "expm1": jnp.expm1, "log": jnp.log, "log1p": jnp.log1p,
    "tanh": jnp.tanh, "sigmoid": jax.nn.sigmoid,
    "erf": jax.lax.erf, "gelu": lambda x: jax.nn.gelu(x, approximate=False),
    "silu": jax.nn.silu,
}


def _math_fast(op: str):
    fn = _MATH_FAST_FNS[op]

    def impl(a, **_kw) -> FF:
        af = _as_ff(a)
        return FF.from_f32(fn(af.hi + af.lo))
    return impl


for _op in MATH_UNARY_OPS:
    register(_op, "jnp", _math_jnp(_op), default_for=("*",))
    register(_op, "pallas", _math_pallas(_op))
    register(_op, "f64", _math_f64(_op), default_for=("cpu",))
    register(_op, "fast", _math_fast(_op))


def _pow_jnp(a, b, **_kw) -> FF:
    af, bf = _as_ff(a), _as_ff(b)
    return FF(*ffmath.pow22(af.hi, af.lo, bf.hi, bf.lo, ffmath.CORE))


def _pow_pallas(a, b, *, block=None, interpret: Optional[bool] = None,
                **_kw) -> FF:
    from repro.kernels import ff_math
    af, bf = _as_ff(a), _as_ff(b)
    rh, rl = ff_math.math_elementwise(
        "pow", af.hi, af.lo, bf.hi, bf.lo,
        block=tuple(block) if block else ff_math.DEFAULT_BLOCK,
        interpret=_interpret(interpret))
    return FF(rh, rl)


@jax.jit
def _pow_f64_jit(ah, al, bh, bl) -> Tuple[Array, Array]:
    import jax.experimental
    from jax import lax

    # domain test on the f32 limb (a < 0 iff hi < 0 for normalized FF):
    # literal promotion inside the scoped-x64 region mixes f32/f64 operands.
    # b == 0 is excluded: pow22's rule is b == 0 -> 1 LAST (0**0 == 1,
    # (-2)**0 == 1), and the mask must not flip that between impl tiers
    neg = (ah < jnp.float32(0)) & (bh != jnp.float32(0))
    with jax.experimental.enable_x64():
        a = (lax.convert_element_type(ah, jnp.float64)
             + lax.convert_element_type(al, jnp.float64))
        b = (lax.convert_element_type(bh, jnp.float64)
             + lax.convert_element_type(bl, jnp.float64))
        # match the FF kernel's domain rules (a < 0 -> nan, no integer-b
        # special case) so impl choice never flips domain semantics.  The
        # nan is derived from `a` (0/0) — a literal constant would be
        # canonicalized back to f32 under the trace-scoped x64 config
        nan64 = (a - a) / (a - a)         # 0/0; stays f64 under the
        r = jnp.where(neg, nan64, jnp.power(a, b))    # scoped-x64 trace
        hi = lax.convert_element_type(r, jnp.float32)
        lo = lax.convert_element_type(
            r - lax.convert_element_type(hi, jnp.float64), jnp.float32)
    return hi, lo


def _pow_f64(a, b, **_kw) -> FF:
    if backend() == "tpu":
        return _pow_jnp(a, b)
    af, bf = _as_ff(a), _as_ff(b)
    return FF(*_pow_f64_jit(af.hi, af.lo, bf.hi, bf.lo))


def _pow_fast(a, b, **_kw) -> FF:
    af, bf = _as_ff(a), _as_ff(b)
    a32, b32 = af.hi + af.lo, bf.hi + bf.lo
    return FF.from_f32(jnp.where((a32 < 0) & (b32 != 0),
                                 jnp.float32(jnp.nan),
                                 jnp.power(a32, b32)))


register("pow", "jnp", _pow_jnp, default_for=("*",))
register("pow", "pallas", _pow_pallas)
register("pow", "f64", _pow_f64, default_for=("cpu",))
register("pow", "fast", _pow_fast)


# -- accurate-class softmax / logsumexp (ff.math-powered) --------------------
#
# The existing impls compute their exponentials with the f32 builtin, so
# every term carries ~2^-24 relative error no matter how well the SUM is
# compensated — the Daumas–Da Graça–Defour gap in miniature.  The "ff"
# impls run exp in FF on an exact TwoSum-reduced argument and carry both
# limb planes through the compensated sum, making the f32 output
# correctly-rounded-class.  On TPU the whole chain is still ONE fused
# Pallas kernel (ff_softmax(accurate=True)); elsewhere it is the jnp
# formulation below.  Selected via impl="ff", ff.use, or tuned_accurate.

def _ff_exp_terms(x: Array, axis: int):
    """exp(x - max) in FF with the reduction held exact (TwoSum)."""
    m = jnp.max(x, axis=axis, keepdims=True)
    dh, dl = T.two_sum(x, jnp.broadcast_to(-m, x.shape))
    eh, el = ffmath.exp22(dh, dl, ffmath.CORE)
    return m, FF(eh, el)


def _ff_expsum(e: FF, axis: int, block: int) -> FF:
    hi = compensated.ff_sum_blocked(e.hi, axis=axis, block=block)
    lo = compensated.ff_sum_blocked(e.lo, axis=axis, block=block)
    return core_ff.add22_accurate(hi, lo)


def _softmax_ff(x: Array, axis: int = -1, *, block: int = 256,
                br: int = 256, interpret: Optional[bool] = None, **_kw):
    """Accurate-class softmax: FF exponentials + FF division per element."""
    x = jnp.asarray(x, jnp.float32)
    if backend() == "tpu" and interpret is not True \
            and _last_axis_fusable(x, axis):
        from repro.kernels import ff_fused
        return ff_fused.ff_softmax(x, mode="softmax", br=br, accurate=True,
                                   interpret=False)
    _m, e = _ff_exp_terms(x, axis)
    s = _ff_expsum(e, axis, block)
    sb = FF(jnp.expand_dims(s.hi, axis % x.ndim),
            jnp.expand_dims(s.lo, axis % x.ndim))
    return core_ff.div22(e, FF(jnp.broadcast_to(sb.hi, x.shape),
                               jnp.broadcast_to(sb.lo, x.shape))).hi


def _logsumexp_ff(x: Array, axis: int = -1, *, block: int = 256,
                  br: int = 256, interpret: Optional[bool] = None, **_kw):
    """Accurate-class LSE: FF exponentials, FF log of the FF exp-sum."""
    x = jnp.asarray(x, jnp.float32)
    if backend() == "tpu" and interpret is not True \
            and _last_axis_fusable(x, axis):
        from repro.kernels import ff_fused
        return ff_fused.ff_softmax(x, mode="logsumexp", br=br, accurate=True,
                                   interpret=False)
    m, e = _ff_exp_terms(x, axis)
    s = _ff_expsum(e, axis, block)
    logs = FF(*ffmath.log22(s.hi, s.lo, ffmath.CORE))
    return core_ff.add212(logs, jnp.squeeze(m, axis=axis)).hi


register("softmax", "ff", _softmax_ff)
register("logsumexp", "ff", _logsumexp_ff)


# -- attention (fused FF flash attention; kernels/ff_attention.py) ----------
#
# Impl classes:
#   * ``fast``   — the f32 online softmax that previously lived inline in
#                  ``models.layers.flash_attention``; bitwise the
#                  pre-registry model hot path, and the default on EVERY
#                  backend (the accurate tiers change result bits, so
#                  unlike softmax/logsumexp there is no silent TPU kernel
#                  default — models opt in via ``ff.policy(attention=...)``).
#   * ``ff``     — compensated online softmax: FF scores (TwoProd dot),
#                  ``ff.math.exp`` FF weights, TwoSum-carried FF
#                  numerator/denominator, Div22 normalize (pure jnp).
#   * ``pallas`` — the same recurrence as one fused kernel per
#                  (batch*head, q-block) stripe with the FF accumulators
#                  in VMEM scratch; static masks only, so per-row
#                  ``kv_len`` (ragged serving batches) falls back to ff.
#   * ``f64``    — materialized-score native-f64 oracle (CPU accurate
#                  tier; size-guarded, degrades to ff on TPU).

def _attention_fast(q, k, v, *, interpret=None, **kw):
    from repro.kernels import ff_attention
    return ff_attention.flash_attention_fast(q, k, v, **kw)


def _attention_ff(q, k, v, *, interpret=None, **kw):
    from repro.kernels import ff_attention
    return ff_attention.flash_attention_ff(q, k, v, **kw)


def _attention_pallas(q, k, v, *, interpret=None, block=128, **kw):
    from repro.kernels import ff_attention
    if kw.get("kv_len") is not None:
        _fallback_warn("pallas", "attention",
                       "per-row kv_len (ragged batch) needs dynamic masks "
                       "the kernel's static grid cannot express")
        return ff_attention.flash_attention_ff(q, k, v, block=block, **kw)
    kw.pop("kv_len", None)
    return ff_attention.flash_attention_pallas(
        q, k, v, interpret=_interpret(interpret), **kw)


def _attention_f64(q, k, v, *, causal=True, q_offset=0, kv_len=None,
                   scale=None, return_ff=False, **kw):
    from repro.kernels import ff_attention
    if backend() != "tpu":
        B, Sq, H = q.shape[0], q.shape[1], q.shape[2]
        if B * H * Sq * k.shape[1] <= (1 << 24):
            return ff_attention.attention_f64(
                q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
                scale=scale, return_ff=return_ff)
        _fallback_warn("f64", "attention",
                       "materialized f64 score plane exceeds the size guard")
    return ff_attention.flash_attention_ff(
        q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
        scale=scale, return_ff=return_ff)


register("attention", "fast", _attention_fast, default_for=("*",))
register("attention", "ff", _attention_ff)
register("attention", "pallas", _attention_pallas)
register("attention", "f64", _attention_f64)
