"""Backend dispatch registry for ``repro.ff``.

The paper presents float-float operators as a *library the application calls
uniformly*, with the GPU backend hidden behind the operator.  This module is
that seam for the JAX port: each public op name maps to a set of named
implementations, and resolution picks one per call site at trace time:

    per-call ``impl=`` kwarg
      > ``ff.use(op=impl)`` scope
      > policy (``PrecisionPolicy.matmul_impl``, for ``matmul``)
      > per-backend default registered here
      > first registered implementation

Implementations are plain callables over ``repro.core`` algorithms and
``repro.kernels`` Pallas kernels; several are themselves backend-aware
(compiled Pallas on TPU, interpret-Pallas or pure-jnp on CPU) so "best
implementation per backend" lives in exactly one place.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import compensated, ffmatmul
from repro.core import ff as core_ff
from repro.core import transforms as T
from repro.core.ff import FF
from repro.ff import scope

Array = jnp.ndarray

_REGISTRY: Dict[str, Dict[str, Callable]] = {}
_DEFAULTS: Dict[str, Dict[str, str]] = {}     # op -> {backend|"*": impl}


def backend() -> str:
    """The JAX backend the dispatcher routes for ("cpu", "tpu", "gpu")."""
    return jax.default_backend()


def register(op: str, impl: str, fn: Callable, *,
             default_for: Tuple[str, ...] = ()) -> Callable:
    """Register ``fn`` as implementation ``impl`` of ``op``.

    ``default_for`` lists backends this impl is the default on ("*" = any
    backend without a more specific default).
    """
    _REGISTRY.setdefault(op, {})[impl] = fn
    for b in default_for:
        _DEFAULTS.setdefault(op, {})[b] = impl
    return fn


def ops() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def impls(op: str) -> Tuple[str, ...]:
    """Registered implementation names for ``op``."""
    return tuple(sorted(_REGISTRY.get(op, ())))


def resolve_name(op: str, impl: Optional[str] = None,
                 shape: Optional[Tuple[int, ...]] = None) -> str:
    """Resolve which implementation a call to ``op`` uses (see module doc).

    With ``shape`` (e.g. ``(M, K, N)`` for matmul), the measurement-driven
    tuning table (``repro.ff.tune``) participates in resolution:

      * the special names ``"tuned"`` / ``"tuned_accurate"`` (usable
        per-call, in ``ff.use`` scopes and in ``policy(matmul=...)``)
        resolve to the cached winner of the fast / accurate class;
      * when resolution falls through to the backend default (no explicit
        choice anywhere), a cached fast-class winner overrides the static
        default — ``dispatch_default`` is then never slower than the best
        registered impl wherever measurements exist.
    """
    if op not in _REGISTRY:
        raise KeyError(f"unknown ff op {op!r}; registered: {ops()}")
    name = impl or scope.current_impl(op)
    if name is None and op == "matmul":
        pol = scope.current_policy().matmul_impl
        if pol and pol != "auto":
            name = pol
    if name in ("tuned", "tuned_accurate"):
        from repro.ff import tuning as _tune
        accurate = name == "tuned_accurate"
        name = (_tune.lookup_impl(op, shape,
                                  "accurate" if accurate else "fast")
                if shape is not None else None)
        if name is not None and name not in _REGISTRY[op]:
            name = None   # stale/foreign sidecar must never break dispatch
        # an explicit accurate-tier request must NEVER degrade to the fast
        # class just because the shape bucket is untuned — fall back to the
        # static accurate-tier default: "f64" resolves to one native dgemm
        # where the hardware has f64 and degrades to the fused Ozaki kernel
        # on TPU, so it is the right fallback wherever it is registered
        if name is None and accurate:
            reg = _REGISTRY.get(op, {})
            name = next((c for c in ("f64", "ozaki", "dot2") if c in reg),
                        None)
    if name is None and shape is not None:
        from repro.ff import tuning as _tune
        name = _tune.lookup_impl(op, shape)
        if name is not None and name not in _REGISTRY[op]:
            name = None   # see above: unknown tuned winner -> static default
    if name is None:
        d = _DEFAULTS.get(op, {})
        name = d.get(backend(), d.get("*"))
    if name is None:
        name = next(iter(_REGISTRY[op]))
    if name not in _REGISTRY[op]:
        raise KeyError(
            f"ff op {op!r} has no implementation {name!r}; "
            f"available: {impls(op)}")
    return name


def resolve_opts(op: str, name: str,
                 shape: Optional[Tuple[int, ...]] = None) -> dict:
    """Measured-best block config for ``name`` at ``shape`` (empty when the
    tuning table has no entry).  Callers merge these UNDER explicit opts."""
    if shape is None:
        return {}
    from repro.ff import tuning as _tune
    return _tune.lookup_opts(op, name, shape)


def lookup(op: str, impl: str) -> Callable:
    return _REGISTRY[op][impl]


def call(op: str, impl: Optional[str], *args, **kw):
    return lookup(op, resolve_name(op, impl))(*args, **kw)


# ===========================================================================
# implementation registrations
# ===========================================================================

def _interpret(flag: Optional[bool]) -> bool:
    """Pallas interpret mode: explicit flag wins, else compiled on TPU only."""
    return (backend() != "tpu") if flag is None else flag


def _as_ff(x) -> FF:
    if isinstance(x, FF):
        return x
    return FF.from_f32(jnp.asarray(x, jnp.float32))


# -- elementwise add/mul/div/sqrt -------------------------------------------

def _add_jnp(a, b) -> FF:
    if isinstance(a, FF) and not isinstance(b, FF):
        return core_ff.add212(a, jnp.asarray(b, jnp.float32))
    if isinstance(b, FF) and not isinstance(a, FF):
        return core_ff.add212(b, jnp.asarray(a, jnp.float32))
    return core_ff.add22(_as_ff(a), _as_ff(b))


def _add_accurate(a, b) -> FF:
    return core_ff.add22_accurate(_as_ff(a), _as_ff(b))


def _mul_jnp(a, b) -> FF:
    if isinstance(a, FF) and not isinstance(b, FF):
        return core_ff.mul212(a, jnp.asarray(b, jnp.float32))
    if isinstance(b, FF) and not isinstance(a, FF):
        return core_ff.mul212(b, jnp.asarray(a, jnp.float32))
    return core_ff.mul22(_as_ff(a), _as_ff(b))


def _elementwise_pallas(op22):
    def fn(a, b, *, interpret: Optional[bool] = None) -> FF:
        from repro.kernels import ff_elementwise
        af, bf = _as_ff(a), _as_ff(b)
        rh, rl = ff_elementwise.elementwise(
            op22, af.hi, af.lo, bf.hi, bf.lo, interpret=_interpret(interpret))
        return FF(rh, rl)
    return fn


def _div_jnp(a, b) -> FF:
    return core_ff.div22(_as_ff(a), _as_ff(b))


def _sqrt_jnp(a) -> FF:
    return core_ff.sqrt22(_as_ff(a))


# Elementwise default is jnp on EVERY backend: a 4-20 flop FF op fuses into
# the surrounding XLA graph, while a standalone pallas_call pads operands to
# (8,128) tiles and breaks fusion — Pallas only wins where a kernel owns a
# loop (matmul/rowsum below).  The pallas impls stay registered for
# validation and for fused-kernel callers that want them explicitly.
register("add", "jnp", _add_jnp, default_for=("*",))
register("add", "accurate", _add_accurate)
register("add", "pallas", _elementwise_pallas("add22"))
register("mul", "jnp", _mul_jnp, default_for=("*",))
register("mul", "pallas", _elementwise_pallas("mul22"))
register("div", "jnp", _div_jnp, default_for=("*",))
register("sqrt", "jnp", _sqrt_jnp, default_for=("*",))


# -- EFTs (f32, f32) -> FF ---------------------------------------------------

def _two_sum_jnp(a, b) -> FF:
    s, r = T.two_sum(jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32))
    return FF(s, r)


def _two_prod_jnp(a, b) -> FF:
    x, y = T.two_prod(jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32))
    return FF(x, y)


def _eft_pallas(op):
    def fn(a, b, *, interpret: Optional[bool] = None) -> FF:
        from repro.kernels import ff_elementwise
        x, y = ff_elementwise.elementwise(
            op, jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
            interpret=_interpret(interpret))
        return FF(x, y)
    return fn


register("two_sum", "jnp", _two_sum_jnp, default_for=("*",))
register("two_sum", "pallas", _eft_pallas("two_sum"))
register("two_prod", "jnp", _two_prod_jnp, default_for=("*",))
register("two_prod", "pallas", _eft_pallas("two_prod"))


# -- matmul (f32/FF operands handled by the autodiff layer; these take f32) --

def _mm_hybrid(a: Array, b: Array, *, block_k: int = 512,
               bm: int = 256, bn: int = 256,
               interpret: Optional[bool] = None, **_kw) -> FF:
    """Blocked-K MXU + Add22 — the production path.  Compiled Pallas on TPU,
    pure-jnp (identical K-block order) elsewhere."""
    if backend() == "tpu" and interpret is not True:
        from repro.kernels import ff_matmul
        hi, lo = ff_matmul.ff_matmul(a, b, bm=bm, bn=bn, bk=block_k,
                                     interpret=False)
        return FF(hi, lo)
    return ffmatmul.matmul_compensated(a, b, block_k=block_k)


def _mm_pallas_hybrid(a: Array, b: Array, *, bm: int = 256, bn: int = 256,
                      bk: int = 512, interpret: Optional[bool] = None,
                      **_kw) -> FF:
    from repro.kernels import ff_matmul
    hi, lo = ff_matmul.ff_matmul(a, b, bm=bm, bn=bn, bk=bk,
                                 interpret=_interpret(interpret))
    return FF(hi, lo)


def _mm_dot2(a: Array, b: Array, *, bm: int = 128, bn: int = 128,
             bk: int = 128, vec: int = 8, chunk: int = 32,
             interpret: Optional[bool] = None, **_kw) -> FF:
    """Paper-faithful Mul12 + Dot3 cascade (~2^-44), block-vectorized over
    K.  Pallas kernel on TPU, pure-jnp chunked scan elsewhere."""
    if backend() == "tpu" and interpret is not True:
        from repro.kernels import ff_matmul
        hi, lo = ff_matmul.ff_matmul_dot2(a, b, bm=bm, bn=bn, bk=bk,
                                          vec=vec, interpret=False)
        return FF(hi, lo)
    return ffmatmul.matmul_dot2(a, b, chunk=chunk)


def _mm_pallas_dot2(a: Array, b: Array, *, bm: int = 128, bn: int = 128,
                    bk: int = 128, vec: int = 8,
                    interpret: Optional[bool] = None, **_kw) -> FF:
    from repro.kernels import ff_matmul
    hi, lo = ff_matmul.ff_matmul_dot2(a, b, bm=bm, bn=bn, bk=bk, vec=vec,
                                      interpret=_interpret(interpret))
    return FF(hi, lo)


def _mm_split(a: Array, b: Array, *, block_k: int = 512, **_kw) -> FF:
    return ffmatmul.matmul_split(a, b, block_k=block_k)


def _mm_compensated(a: Array, b: Array, *, block_k: int = 512, **_kw) -> FF:
    return ffmatmul.matmul_compensated(a, b, block_k=block_k)


def _mm_ozaki(a: Array, b: Array, *, slices: int = 0, beta: int = 0,
              block_k: int = 0, interpret: Optional[bool] = None,
              **_kw) -> FF:
    """Exact-slice Ozaki matmul (~2^-46): fused Pallas kernel on TPU,
    batched stacked-GEMM jnp path elsewhere."""
    if backend() == "tpu" and interpret is not True:
        from repro.kernels import ff_matmul
        hi, lo = ff_matmul.ff_matmul_ozaki(a, b, slices=slices, beta=beta,
                                           bk=block_k or 512, interpret=False)
        return FF(hi, lo)
    return ffmatmul.matmul_ozaki(a, b, slices=slices, beta=beta,
                                 block_k=block_k)


def _mm_f64(a: Array, b: Array, *, interpret: Optional[bool] = None,
            **_kw) -> FF:
    """Native-f64 dgemm rounded to FF (~2^-48) — the accurate tier at
    hardware speed on backends that HAVE f64 (CPU, most GPUs).  TPU has no
    f64 unit, so the same name degrades gracefully to the best pure-f32
    accurate impl there (the fused Ozaki kernel): "f64" means "f64-quality
    results the fastest way this hardware can", which on f32-only hardware
    is exactly the paper's emulation."""
    if backend() == "tpu":
        return _mm_ozaki(a, b, interpret=interpret)
    return ffmatmul.matmul_f64(a, b)


def _mm_pallas_ozaki(a: Array, b: Array, *, slices: int = 0, beta: int = 0,
                     bm: int = 128, bn: int = 128, bk: int = 512,
                     interpret: Optional[bool] = None, **_kw) -> FF:
    from repro.kernels import ff_matmul
    hi, lo = ff_matmul.ff_matmul_ozaki(a, b, slices=slices, beta=beta,
                                       bm=bm, bn=bn, bk=bk,
                                       interpret=_interpret(interpret))
    return FF(hi, lo)


register("matmul", "hybrid", _mm_hybrid, default_for=("*",))
register("matmul", "pallas_hybrid", _mm_pallas_hybrid)
register("matmul", "compensated", _mm_compensated)
register("matmul", "split", _mm_split)
register("matmul", "dot2", _mm_dot2)
register("matmul", "pallas_dot2", _mm_pallas_dot2)
register("matmul", "ozaki", _mm_ozaki)
register("matmul", "pallas_ozaki", _mm_pallas_ozaki)
register("matmul", "f64", _mm_f64)


# -- reductions --------------------------------------------------------------

def _sum_blocked(x: Array, axis=None, *, block: int = 128, **_kw) -> FF:
    return compensated.ff_sum_blocked(x, axis=axis, block=block)


def _sum_cascade(x: Array, axis=None, **_kw) -> FF:
    return compensated.ff_sum(x, axis=axis)


def _sum_pallas_rowsum(x: Array, axis=None, *, br: int = 256, bc: int = 512,
                       lane: int = 128,
                       interpret: Optional[bool] = None, **_kw) -> FF:
    """Pallas row-reduction kernel: 2-D input, last axis only."""
    from repro.kernels import ff_reduce
    if isinstance(axis, tuple) and len(axis) == 1:
        axis = axis[0]
    if x.ndim != 2 or axis not in (-1, 1):
        raise ValueError(
            f"pallas_rowsum needs a 2-D input reduced over the last axis, "
            f"got shape {x.shape}, axis {axis}")
    hi, lo = ff_reduce.ff_rowsum(x, br=br, bc=bc, lane=lane,
                                 interpret=_interpret(interpret))
    return FF(hi, lo)


def _dot_jnp(a: Array, b: Array, axis=None, **_kw) -> FF:
    return compensated.ff_dot(a, b, axis=axis)


def _mean_jnp(x: Array, axis=None, *, block: int = 128, **_kw) -> FF:
    n = x.size if axis is None else 1
    if axis is not None:
        axes = (axis,) if isinstance(axis, int) else tuple(axis)
        for ax in axes:
            n *= x.shape[ax]
    s = compensated.ff_sum_blocked(x, axis=axis, block=block)
    # divide in FF: multiplying by an f32-rounded 1/n would cap the op at
    # ~2^-24 (FF.from_f64 keeps n exact to 2^48, covering any real axis)
    return core_ff.div22(s, FF.from_f64(float(n)))


def _logsumexp_jnp(x: Array, axis: int = -1, *, block: int = 256, **_kw):
    """Compensated LSE: returns the f32 log-sum-exp values."""
    x = jnp.asarray(x, jnp.float32)
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    s = compensated.ff_sum_blocked(e, axis=axis, block=block)
    return jnp.squeeze(m, axis=axis) + jnp.log(s.to_f32())


register("sum", "blocked", _sum_blocked, default_for=("*",))
register("sum", "cascade", _sum_cascade)
register("sum", "pallas_rowsum", _sum_pallas_rowsum)
register("dot", "jnp", _dot_jnp, default_for=("*",))
register("mean", "jnp", _mean_jnp, default_for=("*",))
register("logsumexp", "jnp", _logsumexp_jnp, default_for=("*",))
