"""``ff.guard`` — numeric guardrails for float-float values.

The paper's 2^-44 contract holds only while both limbs stay well-formed:
finite, and normalized (``|lo| <= ulp(hi)/2``).  Non-IEEE arithmetic,
flush-to-zero hardware, or a corrupted KV page silently violate exactly
those invariants (Daumas et al., cs/0605081).  This module makes the
invariants *observable* and *recoverable*:

* :func:`guard_probe` — a jit-compatible health probe (registered
  dispatch op, jnp + Pallas impls): per-category violation counts
  (``nonfinite`` / ``unnormalized`` / ``denormal_lo``) as one cheap
  fused reduction over the limb planes.
* :func:`health_mask` / :func:`assert_healthy` — the elementwise
  invariant as a boolean mask (for ``jnp.where`` repairs) and as a
  host-side check raising the typed :class:`FFError` taxonomy.
* :class:`guard` — a scoped policy slot, ``ff.guard(mode=...)``::

      with ff.guard(mode="degrade") as g:
          y = ff.exp(x)              # violation -> warn, count, and the
          ...                        # op re-resolves one class lower
      g.counters                     # {("exp", "nonfinite"): 2, ...}

  ``mode="off"`` (default ambient state) disables every probe,
  ``"check"`` detects + warns + counts, ``"degrade"`` additionally drops
  the *offending op* one accuracy class (ff -> fast f32) for the rest of
  the scope — the dispatch registry consults :func:`maybe_degrade` at
  resolution time — and repairs flagged lanes via :func:`protect`.

Like every ``repro.ff`` scope this is trace-time, thread-local Python
state; runtime detections (``jax.debug.callback``) update the scope's
counters and degradation set as they execute, so already-compiled calls
keep their resolution and *newly traced* calls inside the scope pick up
the degraded class.  See ``docs/DESIGN_robustness.md``.
"""

from __future__ import annotations

import threading
import warnings
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ff import FF
from repro.kernels.ff_guard import flag_planes, guard_flags

Array = jnp.ndarray

_MODES = ("off", "check", "degrade")


def _obs_record(fn_name: str, *args) -> None:
    """Guard telemetry into the process-global obs registry — lazy import,
    never raises (obs must stay optional below the dispatch layer)."""
    try:
        from repro import obs
        getattr(obs, fn_name)(*args)
    except Exception:
        pass


# ===========================================================================
# FFError taxonomy
# ===========================================================================

class FFError(RuntimeError):
    """Base of the structured FF failure taxonomy.

    Carries the op name, a violation ``kind`` and a human detail string —
    catch :class:`FFError` for "any FF guardrail tripped", or the
    subclasses for one failure mode."""

    kind = "error"

    def __init__(self, op: str, detail: str = ""):
        self.op = op
        self.detail = detail
        super().__init__(
            f"ff.{op}: {self.kind}" + (f" — {detail}" if detail else ""))


class FFNonFiniteError(FFError):
    """A NaN or Inf limb reached an FF value."""
    kind = "nonfinite"


class FFNormalizationError(FFError):
    """An FF pair violates ``|lo| <= ulp(hi)/2`` — the two limbs overlap
    and the 2^-44 contract no longer holds."""
    kind = "unnormalized"


class FFResourceError(FFError):
    """A host-side FF resource fault (page pool, bounded queue, sidecar)."""
    kind = "resource"


class FFGuardWarning(UserWarning):
    """A guard scope detected (and handled) an FF invariant violation."""


class FFTuneWarning(UserWarning):
    """The tuning sidecar was unusable and static defaults are in effect."""


#: violation kind -> the error class assert_healthy raises for it
_ERRORS = {"nonfinite": FFNonFiniteError,
           "unnormalized": FFNormalizationError}


# ===========================================================================
# probes (jit-compatible)
# ===========================================================================

class GuardCounts(NamedTuple):
    """Per-category violation counts from one :func:`guard_probe` pass.

    ``nonfinite`` and ``unnormalized`` are invariant *violations*;
    ``denormal_lo`` is a hazard flag (a subnormal ``lo`` limb is legal,
    but flush-to-zero hardware would zero it — silent precision loss)."""
    nonfinite: Array
    unnormalized: Array
    denormal_lo: Array

    @property
    def violations(self) -> Array:
        """nonfinite + unnormalized (the health-gating total)."""
        return self.nonfinite + self.unnormalized


def _as_limbs(x, lo=None) -> Tuple[Array, Array]:
    if isinstance(x, FF):
        return x.hi, x.lo
    hi = jnp.asarray(x, jnp.float32)
    lo = jnp.zeros_like(hi) if lo is None else jnp.asarray(lo, jnp.float32)
    return hi, lo


def health_mask(x, lo=None) -> Array:
    """Elementwise FF health: True where both limbs are finite and the
    pair is normalized (``denormal_lo`` does not fail health — see
    :class:`GuardCounts`).  Accepts an :class:`FF` or (hi, lo) planes."""
    hi, lo = _as_limbs(x, lo)
    nf, un, _ = flag_planes(hi, lo)
    return ~(nf | un)


def _counts(nf: Array, un: Array, dn: Array) -> GuardCounts:
    return GuardCounts(jnp.sum(nf, dtype=jnp.int32),
                       jnp.sum(un, dtype=jnp.int32),
                       jnp.sum(dn, dtype=jnp.int32))


def _guard_probe_jnp(x, lo=None) -> GuardCounts:
    hi, lo = _as_limbs(x, lo)
    return _counts(*flag_planes(hi, lo))


def _guard_probe_pallas(x, lo=None, *, block=None,
                        interpret: Optional[bool] = None) -> GuardCounts:
    from repro.ff.dispatch import _interpret
    from repro.kernels.ff_elementwise import DEFAULT_BLOCK
    hi, lo = _as_limbs(x, lo)
    flags = guard_flags(hi, lo, block=tuple(block) if block else DEFAULT_BLOCK,
                        interpret=_interpret(interpret))
    codes = flags.astype(jnp.int32)
    return GuardCounts(jnp.sum(codes & 1, dtype=jnp.int32),
                       jnp.sum((codes >> 1) & 1, dtype=jnp.int32),
                       jnp.sum((codes >> 2) & 1, dtype=jnp.int32))


def guard_probe(x, lo=None, *, impl: Optional[str] = None,
                **opts) -> GuardCounts:
    """Count FF invariant violations in one fused reduction.

    Returns :class:`GuardCounts` ``(nonfinite, unnormalized,
    denormal_lo)`` int32 scalars for an :class:`FF` (or explicit
    ``(hi, lo)`` planes, or a plain array checked for finiteness only).
    jit-compatible — the probe is itself a registered dispatch op
    (``jnp`` fused-reduction default everywhere; ``pallas`` tiled flag
    kernel), so it follows ``ff.use`` scopes and per-call ``impl=`` like
    any other op.  Exact (integer counts) on every impl."""
    from repro.ff import dispatch
    name = dispatch.resolve_name("guard_probe", impl)
    return dispatch.lookup("guard_probe", name)(x, lo, **opts)


def assert_healthy(x, lo=None, *, op: str = "value") -> None:
    """Host-side invariant check: raises the typed :class:`FFError`
    subclass for the first violated category (nonfinite before
    unnormalized).  Concrete arrays only — inside jit use
    :func:`guard_probe` / :func:`health_mask`."""
    c = guard_probe(x, lo)
    for kind, n in (("nonfinite", c.nonfinite),
                    ("unnormalized", c.unnormalized)):
        n = int(n)
        if n:
            raise _ERRORS[kind](op, f"{n} element(s) flagged by guard_probe")


# ===========================================================================
# the scoped guard policy slot
# ===========================================================================

class GuardScope:
    """State of one active ``ff.guard`` scope: mode, per-(op, kind)
    violation counters, and the set of ops degraded within the scope."""

    def __init__(self, mode: str):
        if mode not in _MODES:
            raise ValueError(f"guard mode {mode!r}; choose from {_MODES}")
        self.mode = mode
        self.counters: Dict[Tuple[str, str], int] = {}
        self.degraded: set = set()
        self._warned: set = set()

    def record(self, op: str, kind: str, count: int = 1) -> None:
        """Count a detected violation; warn once per (op, kind); in
        ``degrade`` mode mark ``op`` for one-class-lower resolution.

        The obs counter below accumulates on EVERY call — the user-facing
        warning is warn-once per (op, kind), but suppressing the warning
        must not stop the per-(op, kind) violation telemetry (the
        ``ff_guard_violations_total`` series keeps growing after the
        first event)."""
        if self.mode == "off" or count <= 0:
            return
        key = (op, kind)
        self.counters[key] = self.counters.get(key, 0) + int(count)
        _obs_record("record_guard_violation", op, kind, int(count))
        if self.mode == "degrade" and kind in _ERRORS:
            self.degraded.add(op)
        if key not in self._warned:
            self._warned.add(key)
            act = ("degrading ff.%s one accuracy class for this scope"
                   % op if self.mode == "degrade" and kind in _ERRORS
                   else "counting only (mode=%r)" % self.mode)
            _obs_record("record_warning", "guard")
            warnings.warn(f"ff.guard: {count} {kind} FF element(s) in "
                          f"ff.{op} — {act}", FFGuardWarning, stacklevel=2)


_OFF = GuardScope("off")


class _GuardState(threading.local):
    def __init__(self):
        self.stack = []


_STATE = _GuardState()


def current_guard() -> GuardScope:
    """The innermost active guard scope (a shared ``mode="off"`` scope
    when none is active)."""
    return _STATE.stack[-1] if _STATE.stack else _OFF


class guard:
    """Context manager installing an FF guard policy for the scope.

    ``mode``: ``"off"`` (no probes — the ambient default), ``"check"``
    (detect, warn, count), or ``"degrade"`` (check + repair flagged lanes
    + re-resolve the offending op one accuracy class lower for the rest
    of the scope).  Yields the :class:`GuardScope` so callers can read
    ``.counters`` / ``.degraded`` afterwards.  Trace-time and
    thread-local, like ``ff.policy`` / ``ff.use``."""

    def __init__(self, mode: str = "check"):
        self._scope = GuardScope(mode)

    def __enter__(self) -> GuardScope:
        _STATE.stack.append(self._scope)
        return self._scope

    def __exit__(self, *exc):
        _STATE.stack.pop()
        return False


def report_violation(op: str, kind: str, count: int = 1) -> None:
    """Record a violation against the innermost guard scope (module-level
    entry point for host-side detectors like the serve engine)."""
    current_guard().record(op, kind, count)


def protect(op: str, value, fallback=None):
    """Guard an FF op result under the ambient scope (trace-time hook).

    ``mode="off"``: returns ``value`` untouched (zero cost — nothing is
    traced).  ``"check"``: probes the result; nonzero violation counts
    surface through a ``jax.debug.callback`` into the scope's counters +
    one warning.  ``"degrade"``: additionally repairs flagged lanes to
    ``fallback`` (default: the f32-rounded ``hi`` limb with NaN/Inf
    zeroed — the fast-class value of the same computation) and marks
    ``op`` for degraded resolution in subsequent traces."""
    g = current_guard()
    if g.mode == "off" or not isinstance(value, FF):
        return value
    nf, un, _ = flag_planes(value.hi, value.lo)
    bad = nf | un
    nbad_nf = jnp.sum(nf, dtype=jnp.int32)
    nbad_un = jnp.sum(un, dtype=jnp.int32)

    def _cb(n_nf, n_un, scope=g, op=op):
        scope.record(op, "nonfinite", int(n_nf))
        scope.record(op, "unnormalized", int(n_un))

    jax.debug.callback(_cb, nbad_nf, nbad_un)
    if g.mode != "degrade":
        return value
    if fallback is None:
        hi = jnp.where(jnp.isfinite(value.hi), value.hi, jnp.float32(0))
        fb = FF(hi, jnp.zeros_like(hi))
    elif isinstance(fallback, FF):
        fb = fallback
    else:
        f = jnp.asarray(fallback, jnp.float32)
        fb = FF(jnp.broadcast_to(f, value.hi.shape),
                jnp.zeros(value.hi.shape, jnp.float32))
    return FF(jnp.where(bad, fb.hi, value.hi),
              jnp.where(bad, fb.lo, value.lo))


# per-op preferred fast-class impls for one-class degradation (first
# registered name wins; ops not listed fall back to any fast-class impl)
_FAST_DEGRADE: Dict[str, Tuple[str, ...]] = {
    "matmul": ("hybrid", "split", "jnp"),
    "add": ("jnp",),
    "softmax": ("jnp",),
    "logsumexp": ("jnp",),
    "attention": ("fast",),
}


def maybe_degrade(op: str, name: str) -> str:
    """Dispatch hook: inside a ``mode="degrade"`` scope that has marked
    ``op``, swap an accurate-class resolution for the op's fast class
    (one class lower — never a different op, never a worse accurate
    impl).  Anywhere else: identity."""
    g = current_guard()
    if g.mode != "degrade" or op not in g.degraded:
        return name
    from repro.ff import dispatch, tuning
    if tuning.accuracy_class(op, name) == "fast":
        return name                      # already at the fast class
    reg = dispatch._REGISTRY.get(op, {})
    cands = _FAST_DEGRADE.get(op, ())
    swap = next((c for c in cands if c in reg), None)
    if swap is None:
        swap = next((c for c in reg
                     if tuning.accuracy_class(op, c) == "fast"), None)
    if swap is None:
        return name                      # no fast class registered: keep
    key = (op, "degrade-resolve")
    if key not in g._warned:
        g._warned.add(key)
        _obs_record("record_warning", "guard")
        warnings.warn(f"ff.guard(mode='degrade'): resolving ff.{op} to "
                      f"fast-class impl {swap!r} (was {name!r}) for this "
                      f"scope", FFGuardWarning, stacklevel=3)
    return swap


def _register():
    from repro.ff import dispatch
    dispatch.register("guard_probe", "jnp", _guard_probe_jnp,
                      default_for=("*",))
    dispatch.register("guard_probe", "pallas", _guard_probe_pallas)


_register()
