"""Registry-driven documentation for ``repro.ff`` (``ff.render_api_table``).

The dispatch registry is the single source of truth for which ops exist,
which implementations each has, and what resolves where (per backend, and
inside ``ff.on_mesh`` scopes).  ``docs/API.md`` embeds a generated op x
backend x impl matrix between marker comments; this module renders it FROM
the registry and checks the document against it, so the reference can never
silently drift from the code:

    python -m repro.ff.docgen --check docs/API.md    # CI gate (exit 1 on drift)
    python -m repro.ff.docgen --write docs/API.md    # regenerate in place

``--check`` additionally requires a ``### ff.<op>`` reference section for
every registered op — a newly registered op fails CI until it is
documented.  The matrix is built from static registration data only
(registered names, ``default_for`` backends, mesh defaults), so its content
is identical on every machine; measured/tuned winners deliberately do not
appear (they are machine-local — see ``docs/API.md``'s prose).
"""

from __future__ import annotations

import re
import sys
from typing import List

BEGIN = "<!-- BEGIN GENERATED: ff-api-matrix -->"
END = "<!-- END GENERATED: ff-api-matrix -->"
_REGEN = ("<!-- regenerate: python -m repro.ff.docgen --write docs/API.md "
          "-->")


def _summary(op: str) -> str:
    """First sentence of the public ``repro.ff`` wrapper's docstring for
    ``op`` (every registered op must have one — a missing public wrapper
    fails here with AttributeError), capped for table width."""
    import repro.ff as ff

    doc = (getattr(ff, op).__doc__ or "").strip()
    para = []
    for line in doc.splitlines():
        if not line.strip():
            break
        para.append(line.strip())
    text = " ".join(para)
    for stop in (". ", ".  "):
        if stop in text:
            text = text.split(stop, 1)[0]
            break
    text = text.rstrip(".:")
    text = text if len(text) <= 90 else text[:87].rstrip() + "..."
    # a raw '|' (e.g. "|x| <= 1" in the erf docstring) would split the
    # markdown table cell and break every column after it
    return text.replace("|", "\\|")


def render_api_table() -> str:
    """The op x backend x impl matrix, rendered from the dispatch registry.

    One row per registered op: its one-line summary (taken from the public
    wrapper's docstring), every registered implementation name, the static
    per-backend defaults, and the ``ff.on_mesh`` default.  Returns a
    markdown table bracketed by the generator markers."""
    from repro.ff import dispatch

    rows = []
    for op in dispatch.ops():
        impls = ", ".join(f"`{n}`" for n in dispatch.impls(op))
        d = dispatch._DEFAULTS.get(op, {})
        defaults = ", ".join(
            f"{b}→`{d[b]}`" for b in sorted(d, key=lambda k: (k == "*", k)))
        mesh = dispatch.mesh_default(op)
        rows.append(f"| `ff.{op}` | {_summary(op)} | {impls} | "
                    f"{defaults or '—'} | {f'`{mesh}`' if mesh else '—'} |")
    body = "\n".join(rows)
    return (f"{BEGIN}\n{_REGEN}\n"
            "| op | summary | implementations | backend defaults "
            "| `on_mesh` default |\n"
            "|---|---|---|---|---|\n"
            f"{body}\n{END}")


def check_doc(path: str) -> List[str]:
    """Consistency problems between ``path`` and the live registry
    (empty list = the doc is in sync)."""
    from repro.ff import dispatch

    problems: List[str] = []
    try:
        with open(path) as f:
            text = f.read()
    except OSError as e:
        return [f"cannot read {path}: {e}"]
    m = re.search(re.escape(BEGIN) + r"\n(.*?)" + re.escape(END),
                  text, re.S)
    if not m:
        problems.append(f"{path} has no generated ff-api-matrix block "
                        f"({BEGIN} ... {END})")
    elif f"{BEGIN}\n{m.group(1)}{END}" != render_api_table():
        problems.append(
            f"the generated matrix in {path} is stale — run "
            f"`python -m repro.ff.docgen --write {path}`")
    for op in dispatch.ops():
        # closing delimiter required: a bare prefix match would let
        # '### `ff.mean_sq(...)' satisfy the check for 'mean'
        if not re.search(rf"^### `ff\.{re.escape(op)}\(", text, re.M):
            problems.append(f"registered op {op!r} has no `### ff.{op}(...)` "
                            f"reference section in {path}")
    return problems


def write_doc(path: str) -> None:
    """Replace the generated block in ``path`` with a fresh render."""
    with open(path) as f:
        text = f.read()
    pat = re.compile(re.escape(BEGIN) + r".*?" + re.escape(END), re.S)
    if not pat.search(text):
        raise SystemExit(f"{path} has no generated ff-api-matrix block to "
                         f"replace")
    with open(path, "w") as f:
        f.write(pat.sub(lambda _: render_api_table(), text))


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--check", metavar="PATH")
    g.add_argument("--write", metavar="PATH")
    args = ap.parse_args(argv)
    if args.write:
        write_doc(args.write)
        print(f"[docgen] wrote ff-api-matrix into {args.write}")
        return 0
    problems = check_doc(args.check)
    for p in problems:
        print(f"[docgen] FAIL: {p}", file=sys.stderr)
    if not problems:
        print(f"[docgen] {args.check} is in sync with the dispatch registry")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
