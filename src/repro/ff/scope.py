"""Scoped precision policy + dispatch overrides for the ``repro.ff`` namespace.

Replaces positional ``PrecisionPolicy`` threading: models, the optimizer and
the train/serve step builders call :func:`resolve_policy` (explicit argument
wins, otherwise the innermost active :class:`policy` scope, otherwise the
process default).  Example::

    with ff.policy("ff_full", matmul="hybrid", compute_dtype="float32"):
        step = make_train_step(cfg, None, opt)   # reads the scope

Scopes are plain Python state consulted at *trace* time.  Enter them before
tracing (i.e. around step-builder calls or the first call of a jitted
function); re-entering a scope around an already-compiled function does not
retrace it — the same caveat as any Python-level configuration in JAX.

Scopes are thread-local, so concurrent trainer/server threads can hold
different policies.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Union

from repro.core.policy import PrecisionPolicy, BASELINE


class _ScopeState(threading.local):
    def __init__(self):
        self.policies = []      # innermost-last stack of PrecisionPolicy
        self.impls = []         # innermost-last stack of {op: impl_name}
        self.meshes = []        # innermost-last stack of (mesh, axes) | None


_STATE = _ScopeState()
_DEFAULT = [BASELINE]           # process-wide fallback (list for mutability)


def current_policy() -> PrecisionPolicy:
    """The innermost active policy scope, or the process default."""
    if _STATE.policies:
        return _STATE.policies[-1]
    return _DEFAULT[0]


def set_default_policy(p: PrecisionPolicy) -> PrecisionPolicy:
    """Set the process-wide fallback policy; returns the previous one."""
    old = _DEFAULT[0]
    _DEFAULT[0] = p
    return old


def resolve_policy(explicit: Optional[PrecisionPolicy] = None) -> PrecisionPolicy:
    """Explicit policy if given, else the ambient scoped/default policy."""
    return explicit if explicit is not None else current_policy()


class policy:
    """Context manager installing a :class:`PrecisionPolicy` for the scope.

    Accepts a level name (``"baseline" | "ff_master" | "ff_reduce" |
    "ff_full"``), an existing :class:`PrecisionPolicy`, or nothing (derive
    from the current scope), plus field overrides.  ``matmul=`` selects the
    FF matmul implementation the dispatch registry uses inside the scope
    (e.g. ``"hybrid"``, ``"split"``, ``"dot2"``, ``"ozaki"``); the special
    names ``"tuned"`` / ``"tuned_accurate"`` select the measured winner of
    the fast / paper-accuracy class from the ``ff.tune`` table, and the
    default ``"auto"`` also consults that table before falling back to the
    registered backend default.
    """

    def __init__(self,
                 level_or_policy: Union[str, PrecisionPolicy, None] = None,
                 *, matmul: Optional[str] = None, **overrides):
        self._base = level_or_policy
        self._matmul = matmul
        self._overrides = overrides

    def _build(self) -> PrecisionPolicy:
        base = self._base
        if isinstance(base, PrecisionPolicy):
            p = (dataclasses.replace(base, **self._overrides)
                 if self._overrides else base)
        elif base is None:
            p = dataclasses.replace(current_policy(), **self._overrides)
        else:
            p = PrecisionPolicy.make(base, **self._overrides)
        if self._matmul is not None:
            p = dataclasses.replace(p, matmul_impl=self._matmul)
        return p

    def __enter__(self) -> PrecisionPolicy:
        p = self._build()
        _STATE.policies.append(p)
        return p

    def __exit__(self, *exc):
        _STATE.policies.pop()
        return False


class use:
    """Context manager overriding dispatch per-op: ``with ff.use(matmul="dot2")``.

    Finer-grained than :class:`policy` — overrides only the implementation
    choice of the named ops, leaving the precision policy untouched.
    """

    def __init__(self, **op_impls: str):
        self._m = dict(op_impls)

    def __enter__(self) -> Dict[str, str]:
        _STATE.impls.append(self._m)
        return self._m

    def __exit__(self, *exc):
        _STATE.impls.pop()
        return False


def current_impl(op: str) -> Optional[str]:
    """The innermost ``use()`` override for ``op``, if any."""
    for m in reversed(_STATE.impls):
        if op in m:
            return m[op]
    return None


class on_mesh:
    """Context manager establishing the ambient device mesh for FF dispatch.

    Inside the scope, ops with a registered mesh implementation
    (``matmul``/``sum``/``dot``/``norm_stats`` — see ``repro.ff.sharded``)
    resolve to their ``shard_map``-partitioned variants, whose cross-device
    combines preserve the per-op FF error contract instead of flattening to
    a naive f32 ``psum``.  Call sites outside any ``on_mesh`` scope are
    completely untouched — mesh routing is a scoped opt-in, exactly like
    :class:`policy` / :class:`use`::

        mesh = jax.make_mesh((8,), ("data",))
        with ff.on_mesh(mesh, axis="data"):
            C = ff.matmul(A, B)                    # K split over "data"
            C = ff.matmul(A, B, impl="sharded_accurate")   # ppermute tree

    ``axis`` names the mesh axis (or tuple of axes) the contraction /
    leading dimension is partitioned over.  ``on_mesh(None)`` *disables*
    mesh routing for an inner region (the sharded implementations use this
    to resolve their per-shard inner op without re-entering themselves).

    Like every ``repro.ff`` scope this is trace-time Python state: enter it
    around ``jit``/``grad`` *tracing* (step-builder calls, first call of a
    jitted function), not around already-compiled calls.  Thread-local.
    """

    def __init__(self, mesh, axis: Union[str, tuple] = "data"):
        if mesh is not None:
            axes = (axis,) if isinstance(axis, str) else tuple(axis)
            missing = [a for a in axes if a not in mesh.axis_names]
            if missing:
                raise ValueError(
                    f"on_mesh: axis {missing} not in mesh axes "
                    f"{tuple(mesh.axis_names)}")
            self._entry = (mesh, axis if isinstance(axis, str) else axes)
        else:
            self._entry = None

    def __enter__(self):
        _STATE.meshes.append(self._entry)
        return self._entry

    def __exit__(self, *exc):
        _STATE.meshes.pop()
        return False


def current_mesh():
    """The innermost active ``on_mesh`` entry: ``(mesh, axis)`` or ``None``
    (no scope active, or the innermost scope is the ``on_mesh(None)``
    disabler)."""
    if _STATE.meshes:
        return _STATE.meshes[-1]
    return None
