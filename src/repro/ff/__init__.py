"""``repro.ff`` — the unified float-float namespace.

One numpy-like API surface for the paper's float-float operators, with the
backend hidden behind a dispatch registry (compiled Pallas on TPU,
interpret-Pallas or pure-jnp on CPU), ``jax.custom_vjp`` differentiation
rules for the core ops, and a scoped precision-policy API::

    import repro.ff as ff

    z = ff.mul(ff.from_f64(np.pi), ff.from_f64(np.e))   # ~2^-44 accurate
    s = ff.sum(x, axis=-1)                              # compensated, FF
    C = ff.matmul(A, B)                                 # blocked-K MXU path
    C = ff.matmul(A, B, impl="dot2")                    # paper-faithful

    with ff.policy("ff_full", matmul="hybrid"):
        loss, grads = jax.value_and_grad(loss_fn)(params)   # scope-aware

Layering: ``repro.core`` holds the paper's algorithms (the registry
targets), ``repro.kernels`` the Pallas kernels, and this namespace is the
only import model/optimizer/training code needs.
"""

from repro.core.ff import (  # noqa: F401
    FF, FF_EPS, FF_PRECISION_BITS, normalize, tree_from_f32, tree_to_f32,
)
from repro.core.policy import (  # noqa: F401
    PrecisionPolicy, BASELINE, FF_MASTER, FF_REDUCE, FF_FULL,
)
from repro.ff.scope import (  # noqa: F401
    policy, use, current_policy, set_default_policy, resolve_policy,
)
from repro.ff.dispatch import (  # noqa: F401
    backend, register, ops, impls, resolve_name, resolve_opts,
)
from repro.ff.tuning import tune  # noqa: F401
from repro.ff import tuning  # noqa: F401
from repro.ff.autodiff import (  # noqa: F401
    add, sub, mul, div, sqrt, matmul, sum, mean, dot, logsumexp,
    softmax, mean_sq, norm_stats, adamw_update,
    two_sum, two_prod,
)
from repro.ff import fusion  # noqa: F401
from repro.ff.fusion import fused  # noqa: F401

# -- constructors / views (constructor sugar over the FF class) --------------
from_f32 = FF.from_f32
from_f64 = FF.from_f64
zeros = FF.zeros


def to_f32(x):
    """Round an FF (or pass through an array) to f32."""
    return x.to_f32() if isinstance(x, FF) else x


def asff(x) -> FF:
    """Coerce an array/scalar/FF to FF."""
    if isinstance(x, FF):
        return x
    return FF.from_f32(x)
