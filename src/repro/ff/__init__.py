"""``repro.ff`` — the unified float-float namespace.

One numpy-like API surface for the paper's float-float operators, with the
backend hidden behind a dispatch registry (compiled Pallas on TPU,
interpret-Pallas or pure-jnp on CPU, ``shard_map``-partitioned on a device
mesh), ``jax.custom_vjp`` differentiation rules for the core ops, and
scoped configuration::

    import repro.ff as ff

    z = ff.mul(ff.from_f64(np.pi), ff.from_f64(np.e))   # ~2^-44 accurate
    s = ff.sum(x, axis=-1)                              # compensated, FF
    C = ff.matmul(A, B)                                 # blocked-K MXU path
    C = ff.matmul(A, B, impl="dot2")                    # paper-faithful

    with ff.policy("ff_full", matmul="hybrid"):
        loss, grads = jax.value_and_grad(loss_fn)(params)   # scope-aware

    with ff.on_mesh(mesh, axis="data"):
        C = ff.matmul(A, B)    # K split over the mesh, compensated combine

Scopes (all trace-time, thread-local): :func:`policy` installs a
``PrecisionPolicy`` level, :func:`use` overrides single ops'
implementations, :func:`on_mesh` routes the mesh-partitioned tier
(``repro.ff.sharded``).  :func:`tune` fills the measured-winner table that
drives default resolution; :func:`render_api_table` renders the registry
as the ``docs/API.md`` dispatch matrix (CI-checked).

Layering: ``repro.core`` holds the paper's algorithms (the registry
targets), ``repro.kernels`` the Pallas kernels, ``repro.ff.sharded`` the
mesh tier, and this namespace is the only import model/optimizer/training
code needs.  Reference: ``docs/API.md`` (ops x impls x backends),
``docs/NUMERICS.md`` (per-op error contracts, doctested).
"""

from repro.core.ff import (  # noqa: F401
    FF, FF_EPS, FF_PRECISION_BITS, normalize, tree_from_f32, tree_to_f32,
)
from repro.core.policy import (  # noqa: F401
    PrecisionPolicy, BASELINE, FF_MASTER, FF_REDUCE, FF_FULL,
)
from repro.ff.scope import (  # noqa: F401
    policy, use, current_policy, set_default_policy, resolve_policy,
    on_mesh, current_mesh,
)
from repro.ff.dispatch import (  # noqa: F401
    backend, register, ops, impls, resolve_name, resolve_opts, mesh_default,
)
from repro.ff.tuning import tune  # noqa: F401
from repro.ff import tuning  # noqa: F401
from repro.ff.autodiff import (  # noqa: F401
    add, sub, mul, div, sqrt, matmul, sum, mean, dot, logsumexp,
    softmax, attention, mean_sq, norm_stats, adamw_update,
    two_sum, two_prod,
)
from repro.ff import math  # noqa: F401  (the FF elementary-function tier)
from repro.ff.math import (  # noqa: F401
    exp, expm1, log, log1p, tanh, sigmoid, erf, gelu, silu, pow,
)
from repro.ff import fusion  # noqa: F401
from repro.ff.fusion import fused  # noqa: F401
from repro.ff import sharded  # noqa: F401  (registers the mesh impls)
from repro.ff.guard import (  # noqa: F401  (registers guard_probe)
    guard, guard_probe, health_mask, assert_healthy, current_guard,
    GuardCounts, FFError, FFNonFiniteError, FFNormalizationError,
    FFResourceError, FFGuardWarning, FFTuneWarning,
)
from repro.ff.docgen import render_api_table  # noqa: F401

# -- constructors / views (constructor sugar over the FF class) --------------
from_f32 = FF.from_f32        # f32 array -> FF with zero lo limb (exact)
from_f64 = FF.from_f64        # wide host value -> FF to ~2^-48 (host only)
zeros = FF.zeros              # FF of zeros with the given shape


def to_f32(x):
    """Round an FF to f32 (its ``hi`` limb — already correctly rounded);
    plain arrays pass through unchanged.

    The boundary from FF results (and FF-structured cotangents) back to
    plain-f32 code: exact up to the representation's own rounding, never
    an additional operation."""
    return x.to_f32() if isinstance(x, FF) else x


def asff(x) -> FF:
    """Coerce an array/scalar/FF to FF (exact: non-FF inputs become the
    ``hi`` limb with a zero ``lo``)."""
    if isinstance(x, FF):
        return x
    return FF.from_f32(x)
