"""Measurement-driven dispatch tuning for ``repro.ff`` (``ff.tune``).

The dispatch registry knows *which* implementations exist; this module
learns *which one is fastest where*.  ``tune()`` times registered
implementations x block configurations per (backend, shape-bucket), and
caches the winners in a JSON sidecar so later sessions (and CI) consult
measurements instead of guesses:

    ff.tune("matmul", shapes=[(128, 4096, 128)])   # times + caches
    C = ff.matmul(A, B)                            # default now = measured winner

Winners are recorded per *accuracy class* so tuning can never trade
correctness for speed silently:

  * ``fast``      — fastest implementation overall (the class the backend
                    default lives in; every registered impl is at least
                    naive-f32 quality).
  * ``accurate``  — fastest among the paper-quality (~2^-44) tier
                    (dot2 / pallas_dot2 / ozaki / pallas_ozaki).

``dispatch.resolve_name`` consults the ``fast`` winner whenever resolution
falls through to the backend default (no per-call ``impl=``, no ``use()``
scope, policy ``matmul_impl="auto"``), and the special impl name
``"tuned"``/``"tuned_accurate"`` selects the winner explicitly from any
site (per-call, ``ff.use``, ``ff.policy``).  ``lookup_opts`` additionally
returns the winning block configuration for an impl picked by name, so an
explicit ``impl="hybrid"`` call still gets its measured-best ``block_k``.

The sidecar (``FF_TUNE.json`` at the repo root by default, override with
``$REPRO_FF_TUNE_CACHE``) is committed for the CPU CI backend: a cached
bucket is trusted as-is — a second ``tune()`` call is a pure cache hit and
re-times nothing (``force=True`` re-measures).
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

Shape = Tuple[int, int, int]

CACHE_ENV = "REPRO_FF_TUNE_CACHE"

# accuracy tier of each registered matmul impl (relative error vs |A||B|):
# "fast" ~2^-24 (naive class or better), "accurate" ~2^-44 (paper quality).
ACCURACY_CLASS: Dict[str, str] = {
    "hybrid": "fast",
    "pallas_hybrid": "fast",
    "compensated": "fast",
    "split": "fast",
    "dot2": "accurate",
    "pallas_dot2": "accurate",
    "ozaki": "accurate",
    "pallas_ozaki": "accurate",
    "f64": "accurate",      # native dgemm where the hardware has f64;
                            # degrades to the ozaki kernel on TPU
    # mesh tier (repro.ff.sharded): class = inner impl class; the combine
    # preserves it (tree) or is documented separately (psum) — never timed
    # by ff.tune (no mesh in the tuning harness), classified for dispatch
    "sharded": "fast",
    "sharded_accurate": "accurate",
}

# per-op accuracy tiers beyond matmul.  Elementwise/reduction impls are all
# paper-quality (the equivalence tests pin them to the op-by-op reference),
# with one exception: sloppy Add22 has an unbounded relative bound under
# cancellation, so only the "accurate" variant is in the accurate tier.
# The ff.math family: jnp/pallas/f64 all meet the FF contract; "fast" is
# the documented f32-builtin escape (~2^-24).  softmax/logsumexp gained a
# genuinely FF-accurate "ff" impl in the math PR — the f32-builtin-exp
# impls are the fast class (every term carries ~2^-24 regardless of the
# compensated sum), "ff" is the accurate tier.
_MATH_TIER = {"jnp": "accurate", "pallas": "accurate", "f64": "accurate",
              "fast": "fast"}
_OP_ACCURACY: Dict[str, Dict[str, str]] = {
    "matmul": ACCURACY_CLASS,
    "add": {"jnp": "fast", "pallas": "fast", "accurate": "accurate"},
    "softmax": {"jnp": "fast", "pallas": "fast", "f64": "fast",
                "ff": "accurate"},
    "logsumexp": {"jnp": "fast", "pallas": "fast", "f64": "fast",
                  "ff": "accurate"},
    "attention": {"fast": "fast", "ff": "accurate", "pallas": "accurate",
                  "f64": "accurate"},
    **{op: _MATH_TIER for op in ("exp", "expm1", "log", "log1p", "tanh",
                                 "sigmoid", "erf", "gelu", "silu", "pow")},
}


def accuracy_class(op: str, impl: str) -> str:
    return _OP_ACCURACY.get(op, {}).get(impl, "accurate")


# block configurations swept per impl (matmul).  Keep small: tune cost is
# len(configs) * reps matmuls per impl per shape bucket.
SWEEP_CONFIGS: Dict[str, List[dict]] = {
    "hybrid": [{"block_k": 256}, {"block_k": 512}, {"block_k": 1024},
               {"block_k": 2048}],
    "compensated": [{"block_k": 512}, {"block_k": 1024}],
    "split": [{"block_k": 512}, {"block_k": 1024}],
    "dot2": [{}],
    "f64": [{}],
    "ozaki": [{"block_k": 512}, {"block_k": 1024}],
    "pallas_hybrid": [{"bk": 512}],
    "pallas_dot2": [{}],
    "pallas_ozaki": [{"bk": 512}],
}

# which impls may be crowned the FAST (default-overriding) winner, per op.
# A tuned default silently replacing the static default must stay inside
# the op's documented bit contract: for "sum" (an FF-OUTPUT op whose lo
# limbs are reproducibility-sensitive), blocked and pallas_rowsum agree
# to the final-ulp reduction contract, but "cascade" is a different
# summation order kept for explicit use — crowning it would make result
# bits depend on whether a shape falls in a tuned bucket; for "add", the
# sloppy jnp/pallas pair is bitwise-identical while "accurate" is a
# different algorithm (it keeps its accurate-tier record instead).
# Ops absent here allow any timed impl: matmul's long-standing contract,
# and the f32-output composites (softmax/logsumexp/mean_sq/norm_stats),
# whose registered impls are mutually bounded by the documented <=2-ulp
# cross-impl contract (tests/test_fusion.py pins it) — within that band
# the measured-fastest impl is exactly what the tuner exists to pick.
_FAST_ELIGIBLE: Dict[str, Tuple[str, ...]] = {
    "sum": ("blocked", "pallas_rowsum"),
    "add": ("jnp", "pallas"),
    # the f32-class "fast" escape and the bit-different accurate "ff"
    # composites must never be crowned the silent default
    "softmax": ("jnp", "pallas", "f64"),
    "logsumexp": ("jnp", "pallas", "f64"),
    # attention's accurate tiers all change result bits vs the fast f32
    # recurrence (and block sizes change the online-softmax association),
    # so only "fast" may ever be crowned — and it gets no block sweeps
    "attention": ("fast",),
    **{op: ("jnp", "pallas", "f64") for op in
       ("exp", "expm1", "log", "log1p", "tanh", "sigmoid", "erf", "gelu",
        "silu", "pow")},
}

# elementwise/reduction family: block-shape sweeps per (op, impl).  Sweeps
# only cover knobs that cannot change RESULT BITS (tile shapes never alter
# the lane-cascade order; the jnp reduction "block" knob would, so it is
# deliberately NOT swept — tuned numerics must equal untuned numerics).
_EW_BLOCKS = [{"block": (128, 512)}, {"block": (256, 512)},
              {"block": (512, 512)}]
_ROW_BLOCKS = [{"br": 128}, {"br": 256}]
# transcendental kernels carry deep live sets: sweep smaller tiles
_MATH_BLOCKS = [{"block": (64, 512)}, {"block": (128, 512)},
                {"block": (256, 512)}]
SWEEP_CONFIGS_BY_OP: Dict[str, Dict[str, List[dict]]] = {
    "matmul": SWEEP_CONFIGS,
    "add": {"pallas": _EW_BLOCKS},
    "mul": {"pallas": _EW_BLOCKS},
    "div": {"pallas": _EW_BLOCKS},
    "sqrt": {"pallas": _EW_BLOCKS},
    "sum": {"pallas_rowsum": [{"br": 256, "bc": 512},
                              {"br": 512, "bc": 512}]},
    "logsumexp": {"pallas": _ROW_BLOCKS, "ff": _ROW_BLOCKS},
    "softmax": {"pallas": _ROW_BLOCKS, "ff": _ROW_BLOCKS},
    "norm_stats": {"pallas": _ROW_BLOCKS},
    **{op: {"pallas": _MATH_BLOCKS} for op in
       ("exp", "expm1", "log", "log1p", "tanh", "sigmoid", "erf", "gelu",
        "silu", "pow")},
}


def _sweep(op: str, impl: str) -> List[dict]:
    return SWEEP_CONFIGS_BY_OP.get(op, {}).get(impl, [{}])


# -- per-op benchmark operand builders ---------------------------------------
# Each returns (args, static_kw) for a bucket's dims; ops absent here
# cannot be tuned.  Elementwise/reduction ops take 2-D (R, C) shapes.

def _ff_pair(rng, shape, positive=False):
    import jax.numpy as jnp
    from repro.core.ff import FF
    h = rng.standard_normal(shape).astype(np.float32)
    if positive:
        h = np.abs(h) + 0.5
    lo = (h * 1e-8 * rng.standard_normal(shape)).astype(np.float32)
    return FF(jnp.asarray(h), jnp.asarray(lo))


def _f32(rng, shape):
    import jax.numpy as jnp
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


def _args_matmul(rng, dims):
    M, K, N = dims
    return (_f32(rng, (M, K)), _f32(rng, (K, N))), {}


def _args_ew2(positive=False):
    def mk(rng, dims):
        return (_ff_pair(rng, tuple(dims), positive),
                _ff_pair(rng, tuple(dims), positive)), {}
    return mk


def _args_ew1(rng, dims):
    return (_ff_pair(rng, tuple(dims), positive=True),), {}


def _args_row(rng, dims):
    return (_f32(rng, tuple(dims)),), {"axis": -1}


def _args_stats(rng, dims):
    return (_f32(rng, tuple(dims)),), {}


def _args_attention(rng, dims):
    """(R, C) bucket -> q (1, R, 4, 64), k/v (1, C, 2, 64) — a GQA layout
    whose (Sq, Skv) matches how ``ff.attention`` buckets its call shape."""
    r, c = int(dims[0]), int(dims[1])
    q = _f32(rng, (1, r, 4, 64))
    k = _f32(rng, (1, c, 2, 64))
    v = _f32(rng, (1, c, 2, 64))
    return (q, k, v), {"causal": True}


def _args_adamw(rng, dims):
    import jax.numpy as jnp
    shape = tuple(dims)
    args = (_f32(rng, shape),                 # g
            _f32(rng, shape) * 0.1,           # m
            jnp.abs(_f32(rng, shape)) * 0.01,  # v
            _f32(rng, shape),                 # w
            _f32(rng, shape) * 1e-8,          # wlo
            jnp.float32(1e-3), jnp.float32(0.9), jnp.float32(0.95),
            jnp.float32(0.1), jnp.float32(0.05))
    return args, {"eps": 1e-8, "wd": 0.1}


def _args_pow(rng, dims):
    return (_ff_pair(rng, tuple(dims), positive=True),
            _ff_pair(rng, tuple(dims))), {}


_TUNE_ARGS = {
    "matmul": _args_matmul,
    "add": _args_ew2(),
    "mul": _args_ew2(),
    "div": _args_ew2(positive=True),
    "sqrt": _args_ew1,
    "sum": _args_row,
    "logsumexp": _args_row,
    "softmax": _args_row,
    "mean_sq": _args_stats,
    "norm_stats": _args_stats,
    "attention": _args_attention,
    "adamw_update": _args_adamw,
    # ff.math family: positive FF operands sit inside every function's
    # domain (log/log1p/pow included), so one builder serves them all
    **{op: _args_ew1 for op in ("exp", "expm1", "log", "log1p", "tanh",
                                "sigmoid", "erf", "gelu", "silu")},
    "pow": _args_pow,
}

_TABLE: Dict[str, dict] = {}     # op -> bucket -> record
_LOADED_FROM: Optional[str] = None


def default_cache_path() -> str:
    env = os.environ.get(CACHE_ENV)
    if env:
        return env
    # repo root when running from a source checkout; cwd otherwise
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.abspath(os.path.join(here, "..", "..", ".."))
    if os.path.isdir(os.path.join(root, "src")):
        return os.path.join(root, "FF_TUNE.json")
    return os.path.join(os.getcwd(), "FF_TUNE.json")


def _pow2_bucket(x: int) -> int:
    b = 1
    while b < x:
        b <<= 1
    return b


def bucket_key(shape: Sequence[int]) -> str:
    """Shape bucket: dims rounded up to powers of two (measured winners
    generalize across nearby shapes; exact-shape tables would never hit)."""
    return "x".join(str(_pow2_bucket(int(d))) for d in shape)


def _backend() -> str:
    import jax
    return jax.default_backend()


def _bucket_store(op: str, create: bool = False) -> dict:
    b = _backend()
    key = f"{b}/{op}"
    if create:
        return _TABLE.setdefault(key, {})
    return _TABLE.get(key, {})


def clear() -> None:
    """Drop the in-memory table (cache file untouched)."""
    global _LOADED_FROM
    _TABLE.clear()
    _LOADED_FROM = None


def _obs_record(fn_name: str, *args) -> None:
    """Telemetry into the process-global obs registry; never raises and
    never a hard import (obs is optional at the dispatch layer)."""
    try:
        from repro import obs
        getattr(obs, fn_name)(*args)
    except Exception:
        pass


def _warn_tune(msg: str) -> None:
    import warnings
    from repro.ff.guard import FFTuneWarning
    _obs_record("record_warning", "tune")
    warnings.warn(msg, FFTuneWarning, stacklevel=3)


def load(path: Optional[str] = None) -> dict:
    """Load the sidecar into the in-memory table (merging over it).

    A malformed sidecar (truncated write, hand-edited garbage, wrong
    structure) must never take dispatch down: parse / shape problems warn
    (``FFTuneWarning``) and fall back to the static defaults, salvaging
    whatever well-formed ``backend/op`` entries remain.  The path is
    still recorded as loaded so a bad file is read (and warned about)
    once, not on every dispatch."""
    global _LOADED_FROM
    path = path or default_cache_path()
    if not os.path.exists(path):
        return dict(_TABLE)
    try:
        with open(path) as f:
            payload = json.load(f)
    except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
        _warn_tune(f"FF_TUNE sidecar {path!r} is unreadable "
                   f"({type(e).__name__}: {e}); falling back to static "
                   f"dispatch defaults")
        _LOADED_FROM = path          # don't re-read the bad file per lookup
        return dict(_TABLE)
    table = payload.get("table") if isinstance(payload, dict) else None
    if not isinstance(table, dict):
        _warn_tune(f"FF_TUNE sidecar {path!r} has no 'table' mapping; "
                   f"falling back to static dispatch defaults")
        _LOADED_FROM = path
        return dict(_TABLE)
    dropped = 0
    for key, buckets in table.items():
        # salvage structurally sound entries, drop the rest: a key maps
        # "backend/op" -> {bucket -> record dict}
        if not (isinstance(key, str) and isinstance(buckets, dict)
                and all(isinstance(b, str) and isinstance(rec, dict)
                        for b, rec in buckets.items())):
            dropped += 1
            continue
        _TABLE.setdefault(key, {}).update(buckets)
    if dropped:
        _warn_tune(f"FF_TUNE sidecar {path!r}: dropped {dropped} malformed "
                   f"table entr{'y' if dropped == 1 else 'ies'} (kept "
                   f"{len(table) - dropped}); static defaults cover the "
                   f"rest")
    _LOADED_FROM = path
    return dict(_TABLE)


def save(path: Optional[str] = None) -> str:
    """Write the tuning table atomically: dump to ``<path>.tmp``, fsync,
    then ``os.replace`` — a crash mid-dump leaves the previous sidecar
    intact instead of the torn file :func:`load` would have to salvage."""
    import jax

    path = path or _LOADED_FROM or default_cache_path()
    payload = {
        "meta": {
            "backend": _backend(),
            "jax": jax.__version__,
            "format": 1,
        },
        "table": _TABLE,
    }
    tmp = path + ".tmp"
    try:
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def _ensure_loaded() -> None:
    if _LOADED_FROM is None and not _TABLE:
        try:
            load()
        except Exception:     # unreadable sidecar must never break dispatch
            pass


def lookup(op: str, shape: Sequence[int],
           accuracy: str = "fast") -> Optional[dict]:
    """Tuned winner record {"impl", "opts", "us"} for the shape bucket."""
    _ensure_loaded()
    rec = _bucket_store(op).get(bucket_key(shape))
    hit = bool(rec) and rec.get(accuracy) is not None
    _obs_record("record_tune_lookup", hit)
    if rec:
        return rec.get(accuracy)
    return None


def lookup_impl(op: str, shape: Sequence[int],
                accuracy: str = "fast") -> Optional[str]:
    rec = lookup(op, shape, accuracy)
    return rec["impl"] if rec else None


def _detuple(opts: dict) -> dict:
    """JSON round-trips tuples as lists; dispatch metas must stay hashable
    (custom_vjp nondiff args), so block shapes come back as tuples."""
    return {k: tuple(v) if isinstance(v, list) else v
            for k, v in opts.items()}


def lookup_opts(op: str, impl: str, shape: Sequence[int]) -> dict:
    """Measured-best block config for an impl chosen by name (may be {})."""
    _ensure_loaded()
    rec = _bucket_store(op).get(bucket_key(shape))
    if rec:
        per = rec.get("impls", {}).get(impl)
        if per:
            return _detuple(per.get("opts", {}))
    return {}


def time_interleaved(fns: Sequence, args, reps: int, *, rounds: int = 5,
                     sample_target_s: float = 0.03, rep_cap: int = 0,
                     min_reps: int = 2
                     ) -> List[Optional[Tuple[float, float]]]:
    """THE timing protocol for FF matmul measurements — shared by
    ``ff.tune`` and ``benchmarks.table_ffmatmul`` so their numbers can
    never disagree on methodology.

    Every candidate is measured once per round, in a fresh (deterministic)
    permutation each round.  Shuffling — not rotating — matters: with a
    fixed cyclic order every candidate keeps the SAME predecessor each
    round, and one that always runs right after the expensive candidates
    sees a throttled/hot machine every time (measured 1.3-1.6x on
    identical compiled programs — a bias min-of-rounds cannot cancel
    because it is in all rounds, and which would silently crown the wrong
    tuned winner).  Per-sample rep counts are time-targeted
    (``sample_target_s``) so sub-ms candidates aren't dominated by
    timer/sync noise, capped (``rep_cap``, default ``6 * reps``) so slow
    candidates stay cheap.

    Returns, per candidate, ``(min_s, median_s)`` across rounds — the min
    rejects contention episodes, the median is recorded as a dispersion
    hint — or ``None`` for a candidate whose warmup failed (config invalid
    for this shape/backend).  ``AssertionError`` from a candidate always
    propagates: bugs (and test probes) must surface."""
    import jax

    nreps: List[int] = []
    samples: List[Optional[List[float]]] = []
    for fn in fns:
        try:
            out = fn(*args)      # compile + warm
            jax.block_until_ready(jax.tree_util.tree_leaves(out))
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(jax.tree_util.tree_leaves(out))
            est = time.perf_counter() - t0
        except AssertionError:
            raise
        except Exception:
            nreps.append(0)
            samples.append(None)
            continue
        cap = rep_cap or 6 * reps
        nreps.append(max(min_reps,
                         min(cap, int(sample_target_s / max(est, 1e-7)))))
        samples.append([])
    live = [i for i, n in enumerate(nreps) if n]
    shuffler = np.random.default_rng(0)
    for r in range(rounds):
        for i in (live if r == 0 else list(shuffler.permutation(live))):
            fn = fns[i]
            t0 = time.perf_counter()
            for _ in range(nreps[i]):
                out = fn(*args)
            jax.block_until_ready(jax.tree_util.tree_leaves(out))
            samples[i].append((time.perf_counter() - t0) / nreps[i])
    out: List[Optional[Tuple[float, float]]] = []
    for s in samples:
        if s is None:
            out.append(None)
        else:
            s = sorted(s)
            out.append((s[0], s[len(s) // 2]))
    return out


def _time_candidates(fns: Sequence, args, reps: int,
                     rounds: int = 5) -> List[Optional[float]]:
    """Tune's view of :func:`time_interleaved`: min-of-rounds per
    candidate, ``None`` where the config failed to run.  (Kept as a
    separate module attribute so tests can probe that a cached bucket
    never re-times.)"""
    return [r[0] if r is not None else None
            for r in time_interleaved(fns, args, reps, rounds=rounds)]


def tune(op: str = "matmul",
         shapes: Optional[Iterable[Sequence[int]]] = None,
         impls: Optional[Sequence[str]] = None,
         reps: int = 5,
         cache: Optional[str] = None,
         force: bool = False) -> dict:
    """Time registered ``op`` impls x block configs per shape bucket; cache
    and return the winners.  A bucket already in the cache is returned
    without re-timing (the round-trip contract) unless ``force``.

    Args:
      op: a tunable op name (see the families below).
      shapes: iterable of shape tuples to bucket and measure (defaults:
        a small + a large bucket per family).
      impls: explicit impl names to time (default: every registered impl
        except interpret-mode pallas off-TPU and the mesh-only sharded
        tier, which has no mesh here and would mis-measure its fallback).
      reps: timing repetitions fed to the shared shuffled-interleave
        protocol (:func:`time_interleaved`).
      cache: sidecar path override (default ``FF_TUNE.json`` /
        ``$REPRO_FF_TUNE_CACHE``).
      force: re-measure buckets already cached.

    Returns ``{"table": <op's buckets>, "cache": <path written>}``.
    Accuracy is never traded silently: winners are recorded per accuracy
    class (``fast``/``accurate``) and only ``_FAST_ELIGIBLE`` impls can be
    crowned the default-overriding fast winner.

    Tunable op families (one shared shuffled-interleave timing protocol):

      * ``matmul`` — 3-dim ``(M, K, N)`` shapes (PR 2);
      * elementwise — ``add``/``mul``/``div``/``sqrt``, 2-dim ``(R, C)``;
      * reductions & fused composites — ``sum``/``logsumexp``/``softmax``/
        ``mean_sq``/``norm_stats``/``adamw_update``, 2-dim ``(R, C)``;
      * ``ff.math`` — ``exp``/``expm1``/``log``/``log1p``/``tanh``/
        ``sigmoid``/``erf``/``gelu``/``silu``/``pow``, 2-dim ``(R, C)``
        (per-op accuracy classes: jnp/pallas/f64 are FF-contract tier,
        the f32-builtin ``fast`` class is never crowned a default).

    Sweeps only cover tile-shape knobs that cannot change result bits
    (see SWEEP_CONFIGS_BY_OP) — a tuned table can shift where time is
    spent, never what is computed.
    """
    import jax

    from repro.ff import dispatch

    if op not in _TUNE_ARGS:
        raise NotImplementedError(
            f"ff.tune has no operand builder for {op!r}; tunable: "
            f"{tuple(sorted(_TUNE_ARGS))}")
    if shapes is None:
        shapes = (((128, 512, 128), (128, 4096, 128)) if op == "matmul"
                  else ((256, 1024), (4096, 4096)))
    if cache or not _TABLE:
        load(cache)
    store = _bucket_store(op, create=True)
    if impls:
        names = tuple(impls)
    else:
        # off-TPU the pallas impls run in interpret mode — orders of
        # magnitude slow by construction, not worth timing.  The sharded
        # (mesh) impls are NEVER auto-timed: the tuning harness has no
        # ff.on_mesh scope, so they would fall back to (and double-count)
        # their single-device inner impl.
        names = tuple(n for n in dispatch.impls(op)
                      if not n.startswith("sharded")
                      and (_backend() == "tpu" or not n.startswith("pallas")))
    rng = np.random.default_rng(0)

    for shape in shapes:
        key = bucket_key(shape)
        if key in store and not force:
            continue
        dims = tuple(int(d) for d in key.split("x"))
        args, static_kw = _TUNE_ARGS[op](rng, dims)
        cands: List[Tuple[str, dict]] = []
        calls = []
        for name in names:
            fn = dispatch.lookup(op, name)
            for cfg in _sweep(op, name):
                cands.append((name, dict(cfg)))
                calls.append(jax.jit(
                    lambda *a, fn=fn, cfg=cfg: fn(*a, **static_kw, **cfg)))
        times = _time_candidates(calls, args, reps)
        per_impl: Dict[str, dict] = {}
        for (name, cfg), t in zip(cands, times):
            if t is None:
                # config invalid for this shape/backend — skip, but never
                # silently: a tuned table missing an impl looks identical
                # to that impl losing the timing race
                import warnings
                warnings.warn(
                    f"ff.tune: skipping {name}{cfg} at {key}: failed to run")
                continue
            if name not in per_impl or t * 1e6 < per_impl[name]["us"]:
                per_impl[name] = {"opts": cfg, "us": t * 1e6}
        if not per_impl:
            continue
        rec: Dict[str, dict] = {"impls": per_impl}
        pool = [n for n in per_impl if n in _FAST_ELIGIBLE.get(op, per_impl)]
        if pool:
            fast = min(pool, key=lambda n: per_impl[n]["us"])
            rec["fast"] = {"impl": fast, **per_impl[fast]}
        # no eligible impl timed (explicit impls= outside the bit
        # contract, or every eligible config failed): record timings but
        # crown NO fast winner — the static default keeps its bits
        acc_names = [n for n in per_impl
                     if accuracy_class(op, n) == "accurate"]
        if acc_names:
            acc = min(acc_names, key=lambda n: per_impl[n]["us"])
            rec["accurate"] = {"impl": acc, **per_impl[acc]}
        store[key] = rec

    path = save(cache)
    return {"table": dict(_bucket_store(op)), "cache": path}
