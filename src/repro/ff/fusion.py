"""Lazy FF expression fusion: record a chain of elementwise FF ops, compile
it into ONE kernel.

The paper's per-operator throughput numbers hide the cost that dominates
real applications (Collange–Daumas–Defour, cs/0703028): *chains* of emulated
operators.  Dispatched op-by-op, every ``ff.add``/``ff.mul`` is its own
kernel launch that round-trips both hi/lo planes through HBM — a 20-flop FF
op pays two full memory sweeps.  This module removes the round-trips:

    import repro.ff as ff

    @ff.fused
    def axpy(a, x, y):            # a: scalar, x/y: FF — classified per call
        return a * x + y          # ONE kernel: Mul212 + Add22 in VMEM

    z = axpy(2.0, x, y)           # FF out; hi/lo read once, written once

``fused(fn)`` re-traces ``fn`` with :class:`FFExpr` stand-ins on every call
(cheap Python; under ``jax.jit`` it happens once per compilation), producing
a small straight-line program.  The program then runs on the best available
executor:

  * **Pallas** (compiled on TPU, ``interpret=True`` anywhere): one
    ``pallas_call`` evaluating the whole chain on VMEM tiles with the
    branch-free ``repro.kernels.eft`` primitives — each input plane is read
    from HBM once, intermediates never leave registers/VMEM, outputs are
    written once.
  * **jnp** (CPU/GPU default): the same instruction list replayed through
    ``repro.core`` ops inside the surrounding XLA graph.  This is
    *bitwise-identical* to the op-by-op ``repro.ff`` dispatch results (same
    algorithms, same order, same barrier-carrying EFTs) — so tests can
    assert exact equivalence, and non-TPU backends lose nothing.

Supported ops: ``+ - * /``, ``sqrt``, ``neg``, ``fma``, ``scale``,
``exp``/``log`` (FF nodes run the FF-accurate ``ff.math`` kernels and
stay FF; f32 nodes keep the hardware builtins bitwise), ``tanh``/
``sigmoid`` (FF-accurate; f32 nodes are lifted), FF limb access
(``.hi``/``.lo``), ``pack`` (build an FF from two f32 nodes), plus ONE
optional *trailing* row reduction per output (``rowsum`` — compensated
Neumaier cascade over the last axis, f32-valued nodes only).  Mixed FF/f32 promotion follows the
dispatch registry exactly: ``ff+f32 -> Add212``, ``ff*f32 -> Mul212``,
``div`` lifts the f32 side, plain-f32 nodes stay plain f32 (so optimizer
moment math, for example, is *not* silently promoted to FF).

VMEM budget (how deep can a chain be?): the Pallas executor sizes its
block so ``planes * br * bc * 4B`` fits in ~4 MiB, where ``planes`` counts
input planes (2/FF, 1/f32) + output planes + one plane per instruction
(a safe overestimate of live intermediates).  Deeper chains simply get
smaller tiles; the grid grows, the HBM traffic does not.  See
``docs/DESIGN_fusion.md``.

Differentiation: a fused callable is a *forward* kernel with no vjp rule —
use it inside ``custom_vjp`` ops (as ``adamw_update``/``mean_sq``/
``norm_stats`` in the dispatch registry do), not under ``jax.grad``.
"""

from __future__ import annotations

from typing import Any, Callable, List, NamedTuple, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core import compensated, ffmath
from repro.core import ff as core_ff
from repro.core.ff import FF

Array = jnp.ndarray

# result planes per value dtype (the VMEM budget unit)
_PLANES = {"ff": 2, "f32": 1}

# FF transcendentals (repro.core.ffmath): argument reduction + compensated
# polynomial bodies hold far more live temporaries than one arithmetic EFT
# — surcharge their VMEM accounting so the Pallas executor shrinks tiles
_DEEP_OPS = {"exp22", "log22", "tanh22", "sigmoid22"}
_DEEP_OP_PLANES = 8


class Instr(NamedTuple):
    op: str                  # e.g. "leaf_ff", "add22", "fmul", "rowsum", ...
    args: Tuple[int, ...]    # ids of input values
    imm: Optional[float]     # immediate (for "const"; leaf index for leaves)
    dtype: str               # "ff" | "f32"


class Program(NamedTuple):
    """A traced straight-line FF expression chain."""
    leaf_kinds: Tuple[str, ...]      # "ff" | "f32" | "scalar" per operand
    instrs: Tuple[Instr, ...]        # instr i produces value i
    out_ids: Tuple[int, ...]

    @property
    def reductions(self) -> Tuple[int, ...]:
        return tuple(i for i in self.out_ids
                     if self.instrs[i].op == "rowsum")

    def plane_count(self) -> int:
        """Upper bound on simultaneously-live full-size VMEM planes per
        block: every instruction's result — leaves and outputs included,
        each counted ONCE — held live for the whole kernel.  Values that
        never occupy a full (br, bc) plane are skipped: rowsums ((br,
        lane) scratch), consts and scalar leaves ((1, 1) blocks/regs),
        hi/lo/pack (zero-copy views of already-counted planes); ``lift``
        allocates only its zero lo plane."""
        n = 0
        for ins in self.instrs:
            op = ins.op
            if op in ("rowsum", "const", "hi", "lo", "pack"):
                continue
            if op in ("leaf_ff", "leaf_f32") \
                    and self.leaf_kinds[int(ins.imm)] == "scalar":
                continue
            n += 1 if op == "lift" else _PLANES[ins.dtype]
            if op in _DEEP_OPS:
                n += _DEEP_OP_PLANES
        return max(n, 1)


class _Trace:
    def __init__(self):
        self.instrs: List[Instr] = []

    def emit(self, op: str, args: Tuple[int, ...] = (),
             imm: Optional[float] = None, dtype: str = "f32") -> "FFExpr":
        self.instrs.append(Instr(op, args, imm, dtype))
        return FFExpr(self, len(self.instrs) - 1, dtype)


class FFExpr:
    """Tracer value inside a ``ff.fused`` function (FF- or f32-typed)."""

    __slots__ = ("_tr", "_id", "dtype")

    def __init__(self, tr: _Trace, vid: int, dtype: str):
        self._tr = tr
        self._id = vid
        self.dtype = dtype

    # -- limb views ----------------------------------------------------------
    @property
    def hi(self) -> "FFExpr":
        if self.dtype != "ff":
            return self
        return self._tr.emit("hi", (self._id,), dtype="f32")

    @property
    def lo(self) -> "FFExpr":
        if self.dtype != "ff":
            raise TypeError("f32 expression has no .lo limb")
        return self._tr.emit("lo", (self._id,), dtype="f32")

    def _node(self, x) -> "FFExpr":
        if isinstance(x, FFExpr):
            if x._tr is not self._tr:
                raise ValueError("mixing FFExpr values from different traces")
            return x
        try:
            return self._tr.emit("const", imm=float(x))
        except (TypeError, ValueError):
            raise TypeError(
                f"fused chains take FFExpr nodes or Python constants, got "
                f"{type(x).__name__}; pass dynamic values as operands of "
                f"the fused call") from None

    # -- arithmetic (promotion mirrors repro.ff.dispatch bitwise) ------------
    def __add__(self, other) -> "FFExpr":
        b = self._node(other)
        a = self
        if a.dtype == "ff" and b.dtype == "ff":
            return self._tr.emit("add22", (a._id, b._id), dtype="ff")
        if a.dtype == "ff":
            return self._tr.emit("add212", (a._id, b._id), dtype="ff")
        if b.dtype == "ff":
            return self._tr.emit("add212", (b._id, a._id), dtype="ff")
        return self._tr.emit("fadd", (a._id, b._id))

    __radd__ = __add__

    def __neg__(self) -> "FFExpr":
        op = "neg22" if self.dtype == "ff" else "fneg"
        return self._tr.emit(op, (self._id,), dtype=self.dtype)

    def __sub__(self, other) -> "FFExpr":
        b = self._node(other)
        if self.dtype == "f32" and b.dtype == "f32":
            return self._tr.emit("fsub", (self._id, b._id))
        return self + (-b)

    def __rsub__(self, other) -> "FFExpr":
        b = self._node(other)
        if self.dtype == "f32" and b.dtype == "f32":
            return self._tr.emit("fsub", (b._id, self._id))
        return b + (-self)

    def __mul__(self, other) -> "FFExpr":
        b = self._node(other)
        a = self
        if a.dtype == "ff" and b.dtype == "ff":
            return self._tr.emit("mul22", (a._id, b._id), dtype="ff")
        if a.dtype == "ff":
            return self._tr.emit("mul212", (a._id, b._id), dtype="ff")
        if b.dtype == "ff":
            return self._tr.emit("mul212", (b._id, a._id), dtype="ff")
        return self._tr.emit("fmul", (a._id, b._id))

    __rmul__ = __mul__

    def _lift(self) -> "FFExpr":
        if self.dtype == "ff":
            return self
        return self._tr.emit("lift", (self._id,), dtype="ff")

    def __truediv__(self, other) -> "FFExpr":
        b = self._node(other)
        if self.dtype == "ff" or b.dtype == "ff":
            a, b = self._lift(), b._lift()
            return self._tr.emit("div22", (a._id, b._id), dtype="ff")
        return self._tr.emit("fdiv", (self._id, b._id))

    def __rtruediv__(self, other) -> "FFExpr":
        return self._node(other).__truediv__(self)

    # -- trailing reduction --------------------------------------------------
    def sum(self) -> "FFExpr":
        """Compensated row-sum over the LAST axis -> FF per row.  Must be
        returned directly (trailing); f32-valued nodes only — take ``.hi``
        of an FF chain first (or restructure) if you need to reduce one."""
        if self.dtype == "ff":
            raise TypeError(
                "rowsum reduces f32-valued nodes (the op-by-op analogue "
                "ff.sum takes an f32 array); reduce .hi or restructure")
        return self._tr.emit("rowsum", (self._id,), dtype="ff")


# -- free-function helpers over tracer nodes ---------------------------------

def sqrt(x: FFExpr) -> FFExpr:
    op = "sqrt22" if x.dtype == "ff" else "fsqrt"
    return x._tr.emit(op, (x._id,), dtype=x.dtype)


def exp(x: FFExpr) -> FFExpr:
    """exp of a tracer node.  FF nodes run the FF-accurate ``ff.math``
    kernel (``repro.core.ffmath.exp22``, ~2^-43) and stay FF; f32 nodes
    keep the hardware ``jnp.exp`` (bitwise-stable for existing chains) —
    lift with :func:`pack`/arithmetic first if you need the accurate one."""
    if x.dtype == "ff":
        return x._tr.emit("exp22", (x._id,), dtype="ff")
    return x._tr.emit("fexp", (x._id,))


def log(x: FFExpr) -> FFExpr:
    """log of a tracer node: FF nodes -> FF-accurate ``log22``; f32 nodes
    keep the hardware ``jnp.log`` (see :func:`exp`)."""
    if x.dtype == "ff":
        return x._tr.emit("log22", (x._id,), dtype="ff")
    return x._tr.emit("flog", (x._id,))


def tanh(x: FFExpr) -> FFExpr:
    """FF-accurate tanh (``ff.math`` kernel).  f32 nodes are lifted to FF
    first — there is deliberately no f32-builtin form (the accuracy gap is
    the reason this op exists)."""
    return x._tr.emit("tanh22", (x._lift()._id,), dtype="ff")


def sigmoid(x: FFExpr) -> FFExpr:
    """FF-accurate logistic sigmoid; f32 nodes are lifted to FF first."""
    return x._tr.emit("sigmoid22", (x._lift()._id,), dtype="ff")


def fma(a: FFExpr, b: FFExpr, c: FFExpr) -> FFExpr:
    """a*b + c with ONE renormalization (core fma22) when any node is FF."""
    tr = a._tr
    b, c = a._node(b), a._node(c)
    if a.dtype == b.dtype == c.dtype == "f32":
        return a * b + c
    a, b, c = a._lift(), b._lift(), c._lift()
    return tr.emit("fma22", (a._id, b._id, c._id), dtype="ff")


def scale(a: FFExpr, s) -> FFExpr:
    """a * s for an f32/scalar s (Mul212 when a is FF)."""
    return a * (a._node(s))


def pack(h: FFExpr, l: FFExpr) -> FFExpr:
    """Assemble an FF value from two f32 nodes (e.g. master hi/lo planes)."""
    if h.dtype != "f32" or l.dtype != "f32":
        raise TypeError("pack takes two f32 nodes")
    return h._tr.emit("pack", (h._id, l._id), dtype="ff")


def rowsum(x: FFExpr) -> FFExpr:
    return x.sum()


# ---------------------------------------------------------------------------
# tracing + execution
# ---------------------------------------------------------------------------

def _classify(x) -> str:
    if isinstance(x, FF):
        return "ff"
    a = jnp.shape(x)
    return "scalar" if a == () else "f32"


def trace(fn: Callable, kinds: Sequence[str]) -> Tuple[Program, Any]:
    """Trace ``fn`` over leaves of the given kinds.  Returns the program and
    the output *structure* (nested tuples mirroring fn's return value, with
    value ids at the leaves)."""
    tr = _Trace()
    leaves = []
    for k, kind in enumerate(kinds):
        dtype = "ff" if kind == "ff" else "f32"
        leaves.append(tr.emit(f"leaf_{'ff' if kind == 'ff' else 'f32'}",
                              imm=float(k), dtype=dtype))
    out = fn(*leaves)
    flat = out if isinstance(out, (tuple, list)) else (out,)
    for o in flat:
        if not isinstance(o, FFExpr):
            raise TypeError(f"fused fn must return FFExpr nodes, got "
                            f"{type(o).__name__}")
        if o._tr is not tr:
            raise ValueError("fused fn returned a node from another trace")
    prog = Program(tuple(kinds), tuple(tr.instrs),
                   tuple(o._id for o in flat))
    # rowsum nodes must be trailing: nothing may consume them
    for ins in prog.instrs:
        for a in ins.args:
            if prog.instrs[a].op == "rowsum":
                raise ValueError("rowsum must be a trailing output, not an "
                                 "input to further ops")
    return prog, isinstance(out, (tuple, list))


def infer_shapes(prog: Program,
                 operand_shapes: Sequence[Tuple[int, ...]]
                 ) -> List[Tuple[int, ...]]:
    """Per-value ND broadcast shape given the call's operand shapes — the
    shapes the jnp executor produces naturally; the Pallas executor uses
    them to extract each output from its full-broadcast compute planes."""
    shapes: List[Tuple[int, ...]] = []
    for ins in prog.instrs:
        op, args = ins.op, ins.args
        if op in ("leaf_ff", "leaf_f32"):
            s = tuple(operand_shapes[int(ins.imm)])
        elif op == "const":
            s = ()
        elif op == "rowsum":
            s = shapes[args[0]][:-1]
        elif len(args) == 1:
            s = shapes[args[0]]
        else:
            s = tuple(jnp.broadcast_shapes(*(shapes[a] for a in args)))
        shapes.append(s)
    return shapes


def run_jnp(prog: Program, operands: Sequence[Any]) -> List[Any]:
    """Replay the program through ``repro.core`` ops — bitwise-identical to
    op-by-op dispatch (same algorithms, order and barrier-carrying EFTs)."""
    env: List[Any] = []
    for ins in prog.instrs:
        op, args = ins.op, ins.args
        if op in ("leaf_ff", "leaf_f32"):
            x = operands[int(ins.imm)]
            v = x if isinstance(x, FF) else jnp.asarray(x, jnp.float32)
        elif op == "const":
            v = jnp.float32(ins.imm)
        elif op == "fadd":
            v = env[args[0]] + env[args[1]]
        elif op == "fsub":
            v = env[args[0]] - env[args[1]]
        elif op == "fmul":
            v = env[args[0]] * env[args[1]]
        elif op == "fdiv":
            v = env[args[0]] / env[args[1]]
        elif op == "fneg":
            v = -env[args[0]]
        elif op == "fsqrt":
            v = jnp.sqrt(env[args[0]])
        elif op == "fexp":
            v = jnp.exp(env[args[0]])
        elif op == "flog":
            v = jnp.log(env[args[0]])
        elif op == "add22":
            v = core_ff.add22(env[args[0]], env[args[1]])
        elif op == "add212":
            v = core_ff.add212(env[args[0]], env[args[1]])
        elif op == "mul22":
            v = core_ff.mul22(env[args[0]], env[args[1]])
        elif op == "mul212":
            v = core_ff.mul212(env[args[0]], env[args[1]])
        elif op == "div22":
            v = core_ff.div22(env[args[0]], env[args[1]])
        elif op == "sqrt22":
            v = core_ff.sqrt22(env[args[0]])
        elif op == "fma22":
            v = core_ff.fma22(env[args[0]], env[args[1]], env[args[2]])
        elif op == "neg22":
            v = -env[args[0]]
        elif op in _DEEP_OPS:
            x = env[args[0]]
            v = FF(*getattr(ffmath, op)(x.hi, x.lo, ffmath.CORE))
        elif op == "lift":
            x = env[args[0]]
            v = FF(x, jnp.zeros_like(x))
        elif op == "hi":
            v = env[args[0]].hi
        elif op == "lo":
            v = env[args[0]].lo
        elif op == "pack":
            v = FF(env[args[0]], env[args[1]])
        elif op == "rowsum":
            # block=128 matches the op-by-op reference exactly:
            # ff.sum(x, axis=-1, block=128) -> ff_sum_blocked
            v = compensated.ff_sum_blocked(env[args[0]], axis=-1, block=128)
        else:                                          # pragma: no cover
            raise NotImplementedError(op)
        env.append(v)
    return [env[i] for i in prog.out_ids]


class FusedFn:
    """A fused FF expression pipeline (see module docstring)."""

    def __init__(self, fn: Callable, *, interpret: Optional[bool] = None,
                 block: Optional[Tuple[int, int]] = None):
        self._fn = fn
        self._interpret = interpret
        self._block = block
        self.__doc__ = fn.__doc__
        self.__name__ = getattr(fn, "__name__", "fused")

    def __call__(self, *operands, interpret: Optional[bool] = None,
                 block: Optional[Tuple[int, int]] = None):
        """Trace the wrapped fn over ``operands`` and run it fused.

        Args:
          operands: positional leaves — ``FF``, f32 array, or scalar; each
            is classified per call (scalars stay broadcast immediates).
          interpret/block: per-call overrides of the decorator options.

        Returns the wrapped fn's structure with ``FFExpr`` leaves realized
        (FF for ff-typed nodes/rowsums, f32 arrays otherwise).  Error
        contract: the jnp executor is bitwise-identical to op-by-op
        dispatch; the Pallas executor matches it exactly for pure
        elementwise chains and to <=1-2 ulp for reduction-carrying chains
        (two compensated summation orders — see docs/DESIGN_fusion.md).
        """
        from repro.ff import dispatch

        interpret = self._interpret if interpret is None else interpret
        block = block or self._block
        kinds = tuple(_classify(x) for x in operands)
        prog, multi = trace(self._fn, kinds)
        use_pallas = interpret is True or (
            dispatch.backend() == "tpu" and interpret is not False)
        if use_pallas:
            from repro.kernels import ff_fused
            outs = ff_fused.run_pallas(prog, operands, block=block,
                                       interpret=bool(interpret))
        else:
            outs = run_jnp(prog, operands)
        return tuple(outs) if multi else outs[0]

    def program(self, *operands) -> Program:
        """The program this call signature would trace (introspection)."""
        return trace(self._fn, tuple(_classify(x) for x in operands))[0]


def fused(fn: Optional[Callable] = None, *,
          interpret: Optional[bool] = None,
          block: Optional[Tuple[int, int]] = None):
    """Decorator: compile an FF elementwise chain into one kernel.

    Args:
      fn: a function over :class:`FFExpr` stand-ins using ``+ - * /``,
        :func:`sqrt`/:func:`exp`/:func:`log`/:func:`fma`/:func:`scale`/
        :func:`pack`, limb views ``.hi``/``.lo``, and at most one
        *trailing* ``.sum()`` row reduction per output (see module
        docstring for the full op set and FF/f32 promotion rules).
      interpret: None (auto — compiled Pallas on TPU, jnp elsewhere),
        True (Pallas interpret mode anywhere — validation), False (force
        the jnp executor).
      block: Pallas tile override; default is VMEM-budget derived
        (``planes * br * bc * 4B <= ~4 MiB``).

    Returns a :class:`FusedFn`: call it with the operands (FF / f32 array
    / scalar, classified per call); one kernel launch on TPU, the
    bitwise-identical jnp graph elsewhere.  The result is a forward
    kernel with no vjp rule — wrap it in a ``custom_vjp`` op (as the
    dispatch composites do) rather than differentiating through it.
    """
    if fn is None:
        return lambda f: FusedFn(f, interpret=interpret, block=block)
    return FusedFn(fn, interpret=interpret, block=block)
