"""repro.checkpoint substrate."""
