"""repro.checkpoint substrate — atomic, async, checksummed checkpoints.

See :mod:`repro.checkpoint.checkpoint` for the format (per-step
directories of ``.npy`` leaves + a CRC32'd, schema-versioned manifest)
and the verified-load fallback ladder.
"""

from repro.checkpoint.checkpoint import (  # noqa: F401
    FORMAT, AsyncCheckpointer, CheckpointCorruptionWarning, CheckpointError,
    available_steps, latest_step, load, load_dict, save,
)
