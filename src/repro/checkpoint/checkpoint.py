"""Fault-tolerant checkpointing: atomic, async, checksummed, elastic.

Format (schema ``FORMAT``): one directory per step with flat ``.npy``
leaves + a JSON manifest of the pytree structure.  Writes go to
``<dir>.tmp`` then ``os.rename`` — a crash mid-save can never corrupt the
latest checkpoint, and the orphaned ``.tmp`` it leaves behind is skipped
and garbage-collected by the next :func:`latest_step` / :func:`load`.
``save_async`` snapshots to host memory synchronously (cheap) and writes
on a worker thread so the train/serve loop never blocks on the
filesystem.

Integrity: the manifest records a schema version plus a per-leaf CRC32 of
the on-disk bytes.  :func:`load` / :func:`load_dict` verify both; on ANY
mismatch (bit-flip, truncated leaf, missing file, stale schema) they warn
(:class:`CheckpointCorruptionWarning`) and **fall back to the previous
retained generation** instead of returning corrupted arrays.  Only when
no retained generation verifies does loading raise
(:class:`CheckpointError`) — corruption is never silent, and a torn write
never takes recovery down.

Elasticity: leaves are saved as FULL (host-gathered) arrays, so a restart
may re-shard onto a different mesh/device-count — ``load`` just returns
numpy and the caller ``device_put``s with the new sharding.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import warnings
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "__"

#: manifest schema version.  Bumped when the on-disk layout changes; a
#: manifest with any other version is treated as corrupt (stale-schema
#: mismatch) and falls into the generation ladder like a bad CRC.
FORMAT = 2


class CheckpointError(RuntimeError):
    """No retained checkpoint generation verified (or an explicit step was
    requested and nothing at-or-below it is loadable)."""


class CheckpointCorruptionWarning(UserWarning):
    """A checkpoint generation failed verification and was skipped."""


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = SEP.join(_key_str(k) for k in path) or "leaf"
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"i{k.idx}"
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def _stored_view(arr: np.ndarray) -> np.ndarray:
    """The array as written to disk (numpy can't serialize ml_dtypes)."""
    if str(arr.dtype) == "bfloat16":
        return arr.view(np.uint16)
    return arr


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def save(directory: str, step: int, tree, extra: Optional[Dict] = None) -> str:
    """Synchronous atomic save (tmp dir + rename).  Returns final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    manifest = {"format": FORMAT, "step": step, "leaves": [],
                "extra": extra or {}}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        stored = _stored_view(arr)
        np.save(os.path.join(tmp, name + ".npy"), stored)
        manifest["leaves"].append({"name": name, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype),
                                   "crc32": _crc(stored)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # retention: keep last 3 (the fallback ladder load() walks down)
    ckpts = sorted(d for d in os.listdir(directory) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for old in ckpts[:-3]:
        shutil.rmtree(os.path.join(directory, old), ignore_errors=True)
    return final


class AsyncCheckpointer:
    """Snapshot-on-call, write-on-thread.  At most one write in flight;
    a new save waits for the previous (backpressure, bounded memory).

    Write errors are never lost: :meth:`wait` (blocking) raises them, and
    :meth:`poll` (non-blocking) returns them — the serve engine calls
    ``poll()`` every scheduler iteration so a failing disk surfaces into
    the engine loop within one step instead of at the next ``wait()``."""

    def __init__(self, directory: str,
                 error_cb: Optional[Callable[[BaseException], None]] = None):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._error_cb = error_cb

    def save(self, step: int, tree, extra: Optional[Dict] = None) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.directory, step, host_tree, extra)
            except BaseException as e:  # surfaced by poll()/wait()
                self._error = e
                if self._error_cb is not None:
                    try:
                        self._error_cb(e)
                    except Exception:
                        pass

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def poll(self) -> Optional[BaseException]:
        """Non-blocking: reap a finished write and return (clearing) its
        error, if any.  Returns None while a write is still in flight or
        when the last write succeeded."""
        if self._thread is not None:
            if self._thread.is_alive():
                return None
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            return err
        return None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def _gc_tmp(directory: str) -> List[str]:
    """Remove orphaned ``step_*.tmp`` directories left by a crash
    mid-save.  Called from the read paths (``latest_step`` /
    ``available_steps`` / ``load``) — which run before any writer starts,
    so an in-flight save's tmp dir is never swept by its own process."""
    removed = []
    for d in os.listdir(directory):
        if d.startswith("step_") and d.endswith(".tmp"):
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
            removed.append(d)
    return removed


def available_steps(directory: str) -> List[int]:
    """Ascending list of retained generation steps (orphaned ``.tmp``
    dirs are skipped and garbage-collected)."""
    if not os.path.isdir(directory):
        return []
    _gc_tmp(directory)
    steps = []
    for d in os.listdir(directory):
        if not d.startswith("step_") or d.endswith(".tmp"):
            continue
        try:
            steps.append(int(d.split("_")[1]))
        except (IndexError, ValueError):
            continue
    return sorted(set(steps))


def latest_step(directory: str) -> Optional[int]:
    steps = available_steps(directory)
    return max(steps) if steps else None


def _read_verified(path: str) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Read one generation, verifying schema version and per-leaf CRC32.
    Raises :class:`CheckpointError` on ANY mismatch — truncated or
    missing leaf, flipped bit, undecodable or stale-schema manifest."""
    mpath = os.path.join(path, "manifest.json")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointError(f"{path}: manifest unreadable "
                              f"({type(e).__name__}: {e})")
    fmt = manifest.get("format") if isinstance(manifest, dict) else None
    if fmt != FORMAT:
        raise CheckpointError(f"{path}: manifest schema {fmt!r} != "
                              f"supported {FORMAT} (stale or foreign "
                              f"checkpoint)")
    arrays: Dict[str, np.ndarray] = {}
    for leaf in manifest["leaves"]:
        name = leaf["name"]
        fpath = os.path.join(path, name + ".npy")
        try:
            a = np.load(fpath)
        except Exception as e:       # missing, truncated, garbled header
            raise CheckpointError(f"{path}: leaf {name!r} unreadable "
                                  f"({type(e).__name__}: {e})")
        want_crc = leaf.get("crc32")
        if want_crc is None or _crc(a) != want_crc:
            raise CheckpointError(f"{path}: leaf {name!r} failed its CRC32 "
                                  f"check (bit-rot or torn write)")
        if leaf["dtype"] == "bfloat16":
            import ml_dtypes
            a = a.view(ml_dtypes.bfloat16)
        if tuple(a.shape) != tuple(leaf["shape"]):
            raise CheckpointError(f"{path}: leaf {name!r} shape "
                                  f"{tuple(a.shape)} != manifest "
                                  f"{tuple(leaf['shape'])}")
        arrays[name] = a
    return arrays, manifest


def load_dict(directory: str, step: Optional[int] = None
              ) -> Tuple[Dict[str, np.ndarray], int, Dict]:
    """Load the newest VERIFIED generation as ``{leaf_name: array}``.

    Walks the retained generations newest-first (from ``step`` down, when
    given): a generation failing verification is warned about
    (:class:`CheckpointCorruptionWarning`) and the ladder falls back to
    the previous one — corrupted arrays are never returned silently.
    Raises :class:`FileNotFoundError` when no generation exists at all,
    :class:`CheckpointError` when generations exist but none verifies.
    Returns ``(arrays, step, extra)``."""
    steps = available_steps(directory)
    if step is not None:
        steps = [s for s in steps if s <= step]
    if not steps:
        raise FileNotFoundError(f"no checkpoint under {directory}"
                                + (f" at or below step {step}"
                                   if step is not None else ""))
    last_err: Optional[CheckpointError] = None
    for s in reversed(steps):
        path = os.path.join(directory, f"step_{s:08d}")
        try:
            arrays, manifest = _read_verified(path)
        except CheckpointError as e:
            warnings.warn(
                f"checkpoint generation step_{s:08d} failed verification "
                f"({e}); falling back to the previous retained generation",
                CheckpointCorruptionWarning, stacklevel=2)
            last_err = e
            continue
        return arrays, s, manifest.get("extra", {})
    raise CheckpointError(
        f"no retained checkpoint generation under {directory} verifies; "
        f"last error: {last_err}")


def load(directory: str, tree_like, step: Optional[int] = None
         ) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``tree_like`` (shapes may be resharded
    by the caller afterwards).  Verification + generation fallback as in
    :func:`load_dict`; a leaf missing from the verified checkpoint or a
    shape that disagrees with ``tree_like`` is a caller/structure error
    and still raises (KeyError / ValueError).  Returns
    ``(tree, step, extra)``."""
    arrays, step, extra = load_dict(directory, step)
    flat = _flatten_with_paths(tree_like)
    new_leaves = []
    for name, like in flat:
        if name not in arrays:
            raise KeyError(f"checkpoint missing leaf {name}")
        a = arrays[name]
        want = tuple(np.shape(like))
        if tuple(a.shape) != want:
            raise ValueError(f"leaf {name}: ckpt {a.shape} != expected {want}")
        new_leaves.append(a)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step, extra
