"""Fault-tolerant checkpointing: atomic, async, elastic-reshardable.

Format: one directory per step with flat ``.npy`` leaves + a JSON manifest
of the pytree structure.  Writes go to ``<dir>.tmp`` then ``os.rename`` —
a crash mid-save can never corrupt the latest checkpoint.  ``save_async``
snapshots to host memory synchronously (cheap) and writes on a worker
thread so the train loop never blocks on the filesystem.

Elasticity: leaves are saved as FULL (host-gathered) arrays, so a restart
may re-shard onto a different mesh/device-count — ``load`` just returns
numpy and the caller ``device_put``s with the new sharding.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "__"


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = SEP.join(_key_str(k) for k in path) or "leaf"
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"i{k.idx}"
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def save(directory: str, step: int, tree, extra: Optional[Dict] = None) -> str:
    """Synchronous atomic save.  Returns final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if dtype == "bfloat16":          # numpy can't serialize ml_dtypes
            np.save(os.path.join(tmp, name + ".npy"), arr.view(np.uint16))
        else:
            np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"].append({"name": name, "shape": list(arr.shape),
                                   "dtype": dtype})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # retention: keep last 3
    ckpts = sorted(d for d in os.listdir(directory) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for old in ckpts[:-3]:
        shutil.rmtree(os.path.join(directory, old), ignore_errors=True)
    return final


class AsyncCheckpointer:
    """Snapshot-on-call, write-on-thread.  At most one write in flight;
    a new save waits for the previous (backpressure, bounded memory)."""

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, extra: Optional[Dict] = None) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.directory, step, host_tree, extra)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def load(directory: str, tree_like, step: Optional[int] = None
         ) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``tree_like`` (shapes may be resharded
    by the caller afterwards).  Returns (tree, step, extra)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = {}
    for leaf_info in manifest["leaves"]:
        n = leaf_info["name"]
        a = np.load(os.path.join(path, n + ".npy"))
        if leaf_info["dtype"] == "bfloat16":
            import ml_dtypes
            a = a.view(ml_dtypes.bfloat16)
        arrays[n] = a
    flat = _flatten_with_paths(tree_like)
    new_leaves = []
    for name, like in flat:
        if name not in arrays:
            raise KeyError(f"checkpoint missing leaf {name}")
        a = arrays[name]
        want = tuple(np.shape(like))
        if tuple(a.shape) != want:
            raise ValueError(f"leaf {name}: ckpt {a.shape} != expected {want}")
        new_leaves.append(a)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step, manifest["extra"]
