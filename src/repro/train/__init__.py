"""repro.train substrate."""
