"""Serving steps: batched prefill + single-token decode, plus a simple
continuous-batching loop used by the serving example."""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from typing import Optional

from repro.core.policy import PrecisionPolicy
from repro.ff.scope import resolve_policy
from repro.models import prefill, decode_step, init_cache
from repro.models.config import ModelConfig

Array = jnp.ndarray


def make_prefill_step(cfg: ModelConfig,
                      policy: Optional[PrecisionPolicy] = None):
    """policy=None reads the ambient ``repro.ff.policy`` scope at build."""
    policy = resolve_policy(policy)

    def step(params, batch: Dict[str, Array], cache):
        return prefill(params, batch, cfg, cache, policy)
    return step


def make_decode_step(cfg: ModelConfig,
                     policy: Optional[PrecisionPolicy] = None):
    policy = resolve_policy(policy)

    def step(params, token: Array, pos: Array, cache):
        return decode_step(params, token, pos, cache, cfg, policy)
    return step


def token_logprob(logits: Array, token: Array,
                  policy: Optional[PrecisionPolicy] = None) -> Array:
    """Log-probability of ``token`` under ``logits`` (B, V) -> (B,).

    The normalizer goes through the compensated ``ff.logsumexp`` — at
    serving scale the per-token score is a *loss reduction over the vocab
    axis*, and a naive f32 LSE over a 100k+ vocab loses the very bits the
    confidence consumer cares about.  When the ambient (or explicit)
    policy requests FF transcendentals (``ff_math=True``), the score runs
    the accurate-class ``"ff"`` impl: FF exponentials and an ``ff.math.log``
    of the FF exp-sum, instead of f32-builtin exp/log around the
    compensated sum."""
    import repro.ff as ff

    policy = resolve_policy(policy)
    impl = "ff" if policy.ff_math else None
    lse = ff.logsumexp(jnp.asarray(logits, jnp.float32), axis=-1, impl=impl)
    chosen = jnp.take_along_axis(
        jnp.asarray(logits, jnp.float32), token[:, None], axis=-1)[:, 0]
    return chosen - lse


def token_logprob_ff(logits: Array, token: Array):
    """FF-valued chosen-token log-probability: (B, V), (B,) -> FF of (B,).

    The f32-returning :func:`token_logprob` rounds the score to ~2^-24 at
    the final subtract, which floors any contract tighter than that.  The
    serving accuracy gate (logprob within 2^-40 of the f64 oracle, see
    docs/DESIGN_serving.md) therefore scores through this variant: the
    whole chain — TwoSum max-shift, FF exponentials, compensated exp-sum,
    FF log, and the final chosen-minus-LSE subtract — stays in FF, and the
    caller compares limb pairs."""
    import repro.core.compensated as compensated
    import repro.core.ff as core_ff
    import repro.core.ffmath as ffmath
    import repro.core.transforms as T
    from repro.core.ff import FF

    x = jnp.asarray(logits, jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    dh, dl = T.two_sum(x, jnp.broadcast_to(-m, x.shape))
    eh, el = ffmath.exp22(dh, dl, ffmath.CORE)
    s = core_ff.add22_accurate(
        compensated.ff_sum_blocked(eh, axis=-1, block=256),
        compensated.ff_sum_blocked(el, axis=-1, block=256))
    logs = FF(*ffmath.log22(s.hi, s.lo, ffmath.CORE))
    lse = core_ff.add212(logs, jnp.squeeze(m, axis=-1))
    chosen = jnp.take_along_axis(x, token[:, None], axis=-1)[:, 0]
    return core_ff.add212(FF(-lse.hi, -lse.lo), chosen)


def greedy_generate(params, cfg: ModelConfig, prompt: Array, max_new: int,
                    cache_len: int,
                    policy: Optional[PrecisionPolicy] = None,
                    extra_inputs: Dict[str, Array] | None = None,
                    return_logprobs: bool = False,
                    eos_id: Optional[int] = None):
    """Greedy decoding loop (jit per step).  prompt: (B, S) int32.

    ``return_logprobs=True`` additionally returns the (B, n) array of
    chosen-token log-probabilities, scored with the compensated FF
    log-sum-exp (:func:`token_logprob`).

    ``eos_id`` (default None = historical behaviour, always ``max_new``
    tokens) enables per-sequence termination: rows that have emitted
    ``eos_id`` keep decoding in lockstep but their subsequent tokens are
    pinned to ``eos_id``, and the loop exits early once EVERY row has
    finished — so ``n <= max_new`` and everything past a row's first EOS
    is EOS.  This is the semantic baseline the continuous-batching engine
    (``repro.serve``) must reproduce token-for-token."""
    B, S = prompt.shape
    cache = init_cache(cfg, B, cache_len)
    batch = {"tokens": prompt}
    if extra_inputs:
        batch.update(extra_inputs)
    pf = jax.jit(make_prefill_step(cfg, policy))
    dc = jax.jit(make_decode_step(cfg, policy))
    pol = resolve_policy(policy)
    score = jax.jit(lambda lg, tk: token_logprob(lg, tk, pol))
    logits, cache = pf(params, batch, cache)
    toks = [jnp.argmax(logits, -1).astype(jnp.int32)]
    lps = [score(logits, toks[-1])] if return_logprobs else None
    done = (toks[-1] == eos_id) if eos_id is not None else None
    pos0 = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    for t in range(max_new - 1):
        if eos_id is not None and bool(done.all()):
            break
        logits, cache = dc(params, toks[-1][:, None], jnp.int32(pos0 + t), cache)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        if eos_id is not None:
            nxt = jnp.where(done, jnp.int32(eos_id), nxt)
            done = done | (nxt == eos_id)
        toks.append(nxt)
        if return_logprobs:
            lps.append(score(logits, toks[-1]))
    out = jnp.stack(toks, axis=1)
    if return_logprobs:
        return out, jnp.stack(lps, axis=1)
    return out
