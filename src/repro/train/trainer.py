"""Production training loop: checkpoint/restart, straggler detection,
elastic resume, compensated metric accumulation.

Fault-tolerance model (single-controller JAX):
  * atomic+async checkpoints every ``ckpt_every`` steps;
  * on (re)start, auto-resume from the latest checkpoint — the data
    pipeline is index-deterministic so no sample is lost or repeated;
  * an injectable ``fault_hook(step)`` lets tests kill the loop at an
    arbitrary step and assert bit-identical resume;
  * elastic: checkpoints store full (host) arrays, so a restart may map
    them onto a different mesh (device count) — ``Trainer.restore``
    re-device_puts with the current shardings.

Straggler mitigation: per-step wall-times in a ring buffer; a step slower
than ``median * straggler_factor`` is logged and counted.  On a real
multi-host deployment this signal feeds the scheduler (re-slice / hot
standby); here it is surfaced as a metric + callback so the policy is
testable.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.core.ff import FF
import repro.ff as ff


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    straggler_window: int = 32
    straggler_factor: float = 3.0


class Trainer:
    def __init__(self, tcfg: TrainerConfig, step_fn: Callable, params, opt_state,
                 data_iter, *, fault_hook: Optional[Callable[[int], None]] = None,
                 log_fn: Callable[[str], None] = print):
        self.tcfg = tcfg
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.data_iter = data_iter
        self.fault_hook = fault_hook
        self.log = log_fn
        self.step = 0
        self.times = deque(maxlen=tcfg.straggler_window)
        self.straggler_events = 0
        # running loss with FF compensation (the paper's technique applied
        # to the humble metrics accumulator — exact over 10^6 steps)
        self.loss_acc = FF.from_f32(jax.numpy.float32(0))
        self.loss_count = 0
        self.ckpt = (ckpt_lib.AsyncCheckpointer(tcfg.ckpt_dir)
                     if tcfg.ckpt_dir else None)

    # ------------------------------------------------------------------
    def restore(self, shardings=None) -> bool:
        """Resume from the latest checkpoint if present."""
        if not self.tcfg.ckpt_dir:
            return False
        latest = ckpt_lib.latest_step(self.tcfg.ckpt_dir)
        if latest is None:
            return False
        tree = {"params": self.params, "opt": self.opt_state}
        restored, step, extra = ckpt_lib.load(self.tcfg.ckpt_dir, tree, latest)
        if shardings is not None:
            restored = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), restored, shardings)
        else:
            restored = jax.tree_util.tree_map(jax.numpy.asarray, restored)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.step = step
        self.log(f"[trainer] resumed from step {step}")
        return True

    # ------------------------------------------------------------------
    def _maybe_checkpoint(self, force: bool = False):
        if self.ckpt and (force or self.step % self.tcfg.ckpt_every == 0):
            self.ckpt.save(self.step,
                           {"params": self.params, "opt": self.opt_state},
                           extra={"step": self.step})

    def _record_time(self, dt: float):
        self.times.append(dt)
        if len(self.times) >= 8:
            med = float(np.median(self.times))
            if dt > med * self.tcfg.straggler_factor:
                self.straggler_events += 1
                self.log(f"[trainer] straggler step {self.step}: "
                         f"{dt*1e3:.1f}ms vs median {med*1e3:.1f}ms")

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        while self.step < self.tcfg.total_steps:
            if self.fault_hook:
                self.fault_hook(self.step)   # may raise (simulated failure)
            batch = self.data_iter(self.step)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            loss = jax.device_get(metrics["loss"])
            self._record_time(time.perf_counter() - t0)
            self.loss_acc = ff.add(self.loss_acc,
                                   jax.numpy.float32(loss))
            self.loss_count += 1
            self.step += 1
            if self.step % self.tcfg.log_every == 0:
                self.log(f"[trainer] step {self.step} "
                         f"loss {float(loss):.4f} "
                         f"gnorm {float(jax.device_get(metrics.get('grad_norm', 0))):.3f}")
            self._maybe_checkpoint()
        self._maybe_checkpoint(force=True)
        if self.ckpt:
            self.ckpt.wait()
        mean_loss = float(self.loss_acc.to_f64() / max(self.loss_count, 1))
        return {"step": self.step, "mean_loss": mean_loss,
                "straggler_events": self.straggler_events,
                "last_loss": float(loss)}
