"""Jittable train/serve step builders shared by trainer, dry-run, benches.

``make_train_step(cfg, policy, optimizer)`` returns
    step(params, opt_state, batch) -> (params, opt_state, metrics)
with optional microbatch gradient accumulation (scan over microbatches —
the standard memory/throughput knob at scale).
"""

from __future__ import annotations

import contextlib
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.ff import FF
from repro.core.policy import PrecisionPolicy
from repro.ff.scope import on_mesh, resolve_policy
from repro.models import train_forward
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamW, AdamWState, clip_by_global_norm

Array = jnp.ndarray


def _mesh_axes(mesh, mesh_axis):
    """Data-parallel mesh axes the step's reductions partition over."""
    if mesh is None:
        return None
    if mesh_axis is not None:
        return mesh_axis
    from repro.distributed.sharding import dp_axes
    axes = dp_axes(mesh)
    return axes or tuple(mesh.axis_names)[:1]


def _reduction_scope(mesh, axes, policy: Optional[PrecisionPolicy] = None):
    """``ff.on_mesh`` scope for the step's LOSS/GRAD reductions only.

    Matmul stays pinned to its single-device resolution inside the scope
    (the model's compute matmuls are already partitioned by the XLA SPMD
    layer; re-splitting their K over the data axis would fight it), unless
    the step's policy names an impl explicitly — so exactly the
    *reductions* (loss sum, grad-norm, norm stats) cross the mesh through
    the compensated FF combines."""
    import repro.ff as ff

    from repro.ff import scope as ff_scope

    @contextlib.contextmanager
    def scope_cm():
        if mesh is None:
            yield
            return
        # an ambient user `ff.use(matmul=...)` choice outranks the pin —
        # the pin only exists to beat the MESH default, not user config
        user = ff_scope.current_impl("matmul")
        pol = policy.matmul_impl if policy is not None else \
            ff.current_policy().matmul_impl
        pin = user or (pol if pol and pol != "auto" else "tuned")
        with on_mesh(mesh, axes), ff.use(matmul=pin):
            yield
    return scope_cm


def make_loss_fn(cfg: ModelConfig, policy: Optional[PrecisionPolicy] = None,
                 *, mesh=None, mesh_axis=None):
    """policy=None reads the ambient ``repro.ff.policy`` scope (resolved
    eagerly, at builder time, so the scope only needs to wrap the builder).

    With ``mesh`` (and optionally ``mesh_axis``, default: the mesh's
    data-parallel axes), the loss-side FF reductions — the chunked-CE
    ``ff.sum`` and the norm statistics — trace inside an ``ff.on_mesh``
    scope, partitioning over the mesh with compensated cross-device
    combines (see ``repro.ff.sharded``).  ``mesh=None`` is bitwise the
    pre-mesh behavior."""
    policy = resolve_policy(policy)
    scope_cm = _reduction_scope(mesh, _mesh_axes(mesh, mesh_axis), policy)

    def loss_fn(params, batch):
        with scope_cm():
            loss, metrics = train_forward(params, batch, cfg, policy)
        return loss, metrics
    return loss_fn


def make_train_step(cfg: ModelConfig,
                    policy: Optional[PrecisionPolicy] = None,
                    optimizer: Optional[AdamW] = None, *,
                    microbatches: int = 1,
                    clip_norm: Optional[float] = 1.0,
                    mesh=None, mesh_axis=None) -> Callable:
    """Build ``step(params, opt_state, batch) -> (params, opt_state,
    metrics)``.

    ``mesh``/``mesh_axis`` opt the step's loss and gradient reductions into
    the mesh-partitioned FF tier (``ff.on_mesh`` around loss tracing and
    the global grad-norm): cross-device combining then preserves the FF
    error contract instead of flattening to naive f32 ``psum``s.  The
    microbatch loss accumulator always uses the compensated FF carry.
    """
    if optimizer is None:
        raise TypeError("make_train_step requires an optimizer "
                        "(policy is optional — it falls back to the "
                        "ambient ff.policy scope — but the optimizer is not)")
    policy = resolve_policy(policy)
    axes = _mesh_axes(mesh, mesh_axis)
    loss_fn = make_loss_fn(cfg, policy, mesh=mesh, mesh_axis=axes)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    scope_cm = _reduction_scope(mesh, axes, policy)

    def step(params, opt_state: AdamWState, batch: Dict[str, Array]):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            # split batch leading dim into microbatches and scan-accumulate
            def reshape(x):
                b = x.shape[0]
                assert b % microbatches == 0
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree_util.tree_map(reshape, batch)

            def acc_body(carry, mbatch):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(params, mbatch)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                # compensated loss carry: microbatch losses accumulate in
                # FF, so long accumulation chains keep the ~2^-44 contract
                from repro.core.ff import add212
                return (g_acc, add212(l_acc, l)), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_acc), _ = lax.scan(
                acc_body, (g0, FF.from_f32(jnp.float32(0))), mb)
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, grads)
            loss = loss_acc.to_f32() / microbatches
            metrics = {"loss": loss, "aux": jnp.float32(0)}

        if clip_norm is not None:
            with scope_cm():
                grads, gnorm = clip_by_global_norm(
                    grads, clip_norm, ff=policy.ff_reductions)
        else:
            gnorm = jnp.float32(0)
        new_params, new_state = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = optimizer._lr(new_state.count)
        return new_params, new_state, metrics

    return step


def make_eval_step(cfg: ModelConfig, policy: Optional[PrecisionPolicy] = None):
    loss_fn = make_loss_fn(cfg, policy)

    def step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return metrics
    return step
