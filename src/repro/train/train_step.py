"""Jittable train/serve step builders shared by trainer, dry-run, benches.

``make_train_step(cfg, policy, optimizer)`` returns
    step(params, opt_state, batch) -> (params, opt_state, metrics)
with optional microbatch gradient accumulation (scan over microbatches —
the standard memory/throughput knob at scale).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.policy import PrecisionPolicy
from repro.ff.scope import resolve_policy
from repro.models import train_forward
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamW, AdamWState, clip_by_global_norm

Array = jnp.ndarray


def make_loss_fn(cfg: ModelConfig, policy: Optional[PrecisionPolicy] = None):
    """policy=None reads the ambient ``repro.ff.policy`` scope (resolved
    eagerly, at builder time, so the scope only needs to wrap the builder)."""
    policy = resolve_policy(policy)

    def loss_fn(params, batch):
        loss, metrics = train_forward(params, batch, cfg, policy)
        return loss, metrics
    return loss_fn


def make_train_step(cfg: ModelConfig,
                    policy: Optional[PrecisionPolicy] = None,
                    optimizer: Optional[AdamW] = None, *,
                    microbatches: int = 1,
                    clip_norm: Optional[float] = 1.0) -> Callable:
    if optimizer is None:
        raise TypeError("make_train_step requires an optimizer "
                        "(policy is optional — it falls back to the "
                        "ambient ff.policy scope — but the optimizer is not)")
    policy = resolve_policy(policy)
    loss_fn = make_loss_fn(cfg, policy)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state: AdamWState, batch: Dict[str, Array]):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            # split batch leading dim into microbatches and scan-accumulate
            def reshape(x):
                b = x.shape[0]
                assert b % microbatches == 0
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree_util.tree_map(reshape, batch)

            def acc_body(carry, mbatch):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(params, mbatch)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = lax.scan(acc_body, (g0, jnp.float32(0)), mb)
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = {"loss": loss, "aux": jnp.float32(0)}

        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(
                grads, clip_norm, ff=policy.ff_reductions)
        else:
            gnorm = jnp.float32(0)
        new_params, new_state = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = optimizer._lr(new_state.count)
        return new_params, new_state, metrics

    return step


def make_eval_step(cfg: ModelConfig, policy: Optional[PrecisionPolicy] = None):
    loss_fn = make_loss_fn(cfg, policy)

    def step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return metrics
    return step
