"""Unified model configuration covering every assigned architecture family.

One dataclass; family-specific fields are ignored by other families.
Exact full-size instances live in ``repro.configs.<arch>``; smoke tests use
``reduced()`` copies.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Tuple

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family = "dense"

    # transformer trunk
    num_layers: int = 12
    d_model: int = 1024
    num_heads: int = 8
    num_kv_heads: int = 8
    d_ff: int = 4096
    vocab_size: int = 32000
    head_dim: Optional[int] = None          # default d_model // num_heads
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 131072

    # attention impl
    attn_block_q: int = 512                  # flash q-block
    attn_block_kv: int = 1024                # flash kv-block

    # MoE
    moe_num_experts: int = 0                 # 0 = dense FFN
    moe_top_k: int = 2
    moe_d_ff: int = 0                        # per-expert hidden (0 -> d_ff)
    moe_shared_experts: int = 0              # deepseek-style shared experts
    moe_capacity_factor: float = 1.25
    moe_every: int = 1                       # MoE FFN every k-th layer

    # MLA (deepseek)
    use_mla: bool = False
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # SSM (mamba2 / hybrid)
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    attn_every: int = 0                      # hybrid: 1 attn layer per period
    attn_index: int = 3                      # position of attn layer in period

    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500                  # stub frame-embedding length

    # vlm
    num_patches: int = 0                     # stub patch-embedding count

    # numerics
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    logit_softcap: float = 0.0
    # sequence-chunked cross-entropy: never materialize (B, S, V) logits;
    # chunk of 0 disables (tiny smoke configs)
    loss_chunk: int = 512

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def reduced(self, **overrides) -> "ModelConfig":
        """Small same-family variant for CPU smoke tests."""
        small = dict(
            num_layers=min(self.num_layers, 2 * max(1, self.attn_every or 1)),
            d_model=256,
            num_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads // max(1, self.num_heads // 4))),
            d_ff=512,
            vocab_size=512,
            head_dim=64 if not self.use_mla else None,
            max_seq_len=512,
            attn_block_q=64,
            attn_block_kv=64,
            moe_num_experts=min(self.moe_num_experts, 8),
            moe_top_k=min(self.moe_top_k, 2),
            moe_d_ff=128 if self.moe_num_experts else 0,
            moe_shared_experts=min(self.moe_shared_experts, 1),
            kv_lora_rank=64,
            q_lora_rank=96,
            qk_nope_head_dim=32,
            qk_rope_head_dim=16,
            v_head_dim=32,
            ssm_state=32,
            ssm_head_dim=32,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 64),
            num_patches=min(self.num_patches, 16),
            remat=False,
        )
        # keep hybrid period structure intact but small
        if self.attn_every:
            small["num_layers"] = 2 * self.attn_every
        small.update(overrides)
        return dataclasses.replace(self, **small)
