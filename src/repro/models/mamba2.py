"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block in pure JAX.

Chunked SSD: intra-chunk terms are quadratic attention-like einsums over
chunk length Q; inter-chunk recurrence carries the (H, P, N) state with a
``lax.scan`` over chunks — O(S) total, the sub-quadratic path the long_500k
shape requires.

Decode is a single recurrent state update per token (state: (B, H, P, N)).
Depthwise causal conv (width 4) over the x/B/C projections with a rolling
cache for decode, as in the reference implementation.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

import repro.ff as ff
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rms_norm

Array = jnp.ndarray
Params = Dict[str, Any]

CHUNK = 256


def _exp(x: Array, ff_math: bool) -> Array:
    """exp for the SSD decay chains: the f32 builtin (bitwise-default),
    or the FF elementary function rounded back to f32 (policy
    ``ff_math`` switch) — the decay products ``exp(a_i)...exp(a_j)``
    compound the builtin's ~2^-24 per-factor error across a whole chunk,
    which is exactly the error class the FF exp removes."""
    if ff_math:
        return ff.to_f32(ff.exp(x))
    return jnp.exp(x)


def _softplus(x: Array, ff_math: bool) -> Array:
    """dt = softplus(raw): builtin, or the stable FF form
    ``max(x, 0) + log1p(exp(-|x|))`` riding ``ff.exp``/``ff.log1p``."""
    if ff_math:
        t = ff.log1p(ff.exp(-jnp.abs(x)))
        return jnp.maximum(x, jnp.float32(0.0)) + ff.to_f32(t)
    return jax.nn.softplus(x)


def ssd_params(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    conv_dim = di + 2 * N   # x plus B and C (single group)
    ks = jax.random.split(key, 6)
    # separate projections (z | x | BC | dt) instead of one fused in_proj:
    # each gets a clean tensor-parallel sharding (di -> 'model' axis) without
    # cutting across semantic segment boundaries.
    return {
        "w_z": dense_init(ks[0], (d, di)),
        "w_x": dense_init(ks[3], (d, di)),
        "w_bc": dense_init(ks[4], (d, 2 * N)),
        "w_dt": dense_init(ks[5], (d, H)),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_dim),
                                     jnp.float32) * 0.1),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_w": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[2], (di, d)),
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over (B, S, C) with kernel (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
              for i in range(W))
    return jax.nn.silu(out + b.astype(x.dtype))


def _segsum(a: Array) -> Array:
    """Stable 'segment sum' producing L[i,j] = sum_{j<m<=i} a[m] for j<=i.

    a: (..., Q) -> (..., Q, Q) lower-triangular cumulative sums.
    """
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # sum over (j, i]
    idx = jnp.arange(Q)
    mask = idx[:, None] >= idx[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(x: Array, dt: Array, A: Array, Bm: Array, Cm: Array,
             state: Array | None = None,
             ff_math: bool = False) -> Tuple[Array, Array]:
    """Chunked SSD.

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); A: (H,) negative;
    Bm, Cm: (B, S, N)  (single SSM group, broadcast over heads);
    state: optional initial (B, H, P, N).
    Returns (y (B,S,H,P), final_state).

    ``ff_math=True`` routes every decay exponential through ``ff.exp``
    (policy switch; default bitwise-identical to the builtin path).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(CHUNK, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // Q

    # reshape to chunks: (B, nc, Q, ...)
    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    a = dtc * A[None, None, None, :]                    # (B,nc,Q,H) negative
    a_t = a.transpose(0, 1, 3, 2)                       # (B,nc,H,Q)
    a_cum = jnp.cumsum(a_t, axis=-1)                    # within-chunk
    L = _exp(_segsum(a_t), ff_math)                     # (B,nc,H,Q,Q)

    # weighted inputs
    xdt = xc * dtc[..., None]                           # (B,nc,Q,H,P)

    # 1) intra-chunk (diagonal) term
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)      # (B,nc,Q,Q)
    y_diag = jnp.einsum("bcqk,bchqk,bckhp->bcqhp",
                        scores, L, xdt.transpose(0, 1, 2, 3, 4))
    # note: einsum above needs xdt as (B,nc,K,H,P): same layout ✓

    # 2) chunk-final states: decay from position k to end of chunk
    decay_end = _exp(a_cum[..., -1:] - a_cum, ff_math)  # (B,nc,H,Q)
    states = jnp.einsum("bckn,bchk,bckhp->bchpn",
                        Bc, decay_end, xdt)             # (B,nc,H,P,N)

    # 3) inter-chunk recurrence
    chunk_decay = _exp(a_cum[..., -1], ff_math)         # (B,nc,H)

    def step(carry, inp):
        st = carry                                      # (B,H,P,N)
        s_new, dec = inp                                # (B,H,P,N), (B,H)
        st2 = st * dec[..., None, None] + s_new
        return st2, st                                  # emit state BEFORE chunk

    st0 = state if state is not None else jnp.zeros(
        (Bsz, H, P, N), x.dtype)
    final, prev_states = lax.scan(
        step, st0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # 4) inter-chunk output: decay from chunk start to position q
    decay_in = _exp(a_cum, ff_math)                     # (B,nc,H,Q)
    y_off = jnp.einsum("bcqn,bchq,bchpn->bcqhp",
                       Cc, decay_in, prev_states)

    y = (y_diag + y_off).reshape(Bsz, Sp, H, P)[:, :S]
    return y, final


def ssd_block_apply(p: Params, x: Array, cfg: ModelConfig,
                    state: Params | None = None,
                    return_state: bool = False,
                    ff_math: bool = False):
    """Full mamba2 mixer: in_proj -> conv -> SSD -> gated norm -> out_proj."""
    B, S, d = x.shape
    di = cfg.ssm_d_inner
    H = cfg.ssm_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    dt_x = x.dtype
    z = x @ p["w_z"].astype(dt_x)
    xin = x @ p["w_x"].astype(dt_x)
    bc = x @ p["w_bc"].astype(dt_x)
    Bm, Cm = bc[..., :N], bc[..., N:]
    dt_raw = x @ p["w_dt"].astype(dt_x)

    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    xin = conv_out[..., :di]
    Bm = conv_out[..., di:di + N]
    Cm = conv_out[..., di + N:]

    dt = _softplus(dt_raw.astype(jnp.float32)
                   + p["dt_bias"][None, None, :], ff_math)
    A = -_exp(p["A_log"], ff_math)                      # (H,) negative
    xh = xin.reshape(B, S, H, P)
    y, final = ssd_scan(xh.astype(jnp.float32), dt, A,
                        Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                        None if state is None else state["ssm"],
                        ff_math=ff_math)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(dt_x)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(dt_x)
    if return_state:
        new_state = {"ssm": final,
                     "conv": conv_in[:, -(cfg.ssm_conv_width - 1):, :]}
        return out, new_state
    return out


def ssd_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                          cfg.ssm_state), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1,
                           cfg.ssm_d_inner + 2 * cfg.ssm_state), dtype),
    }


def ssd_decode_step(p: Params, x: Array, cfg: ModelConfig,
                    state: Params,
                    ff_math: bool = False) -> Tuple[Array, Params]:
    """One-token recurrent update.  x: (B, 1, d)."""
    B, S, d = x.shape
    assert S == 1
    di = cfg.ssm_d_inner
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    dt_x = x.dtype
    z = x @ p["w_z"].astype(dt_x)
    xin = x @ p["w_x"].astype(dt_x)
    bc = x @ p["w_bc"].astype(dt_x)
    Bm, Cm = bc[..., :N], bc[..., N:]
    dt_raw = x @ p["w_dt"].astype(dt_x)

    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)    # (B,1,conv_dim)
    window = jnp.concatenate(
        [state["conv"].astype(dt_x), conv_in], axis=1)   # (B,W,conv_dim)
    w = p["conv_w"].astype(dt_x)
    conv_out = jax.nn.silu(
        (window * w[None]).sum(axis=1, keepdims=True)
        + p["conv_b"].astype(dt_x))
    xin = conv_out[..., :di]
    Bm = conv_out[..., di:di + N].astype(jnp.float32)
    Cm = conv_out[..., di + N:].astype(jnp.float32)

    dt = _softplus(dt_raw.astype(jnp.float32)
                   + p["dt_bias"][None, None, :], ff_math)[:, 0]  # (B,H)
    A = -_exp(p["A_log"], ff_math)
    decay = _exp(dt * A[None, :], ff_math)                         # (B,H)
    xh = xin.reshape(B, H, P).astype(jnp.float32)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm[:, 0], xh)
    st = state["ssm"].astype(jnp.float32) * decay[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", st, Cm[:, 0])
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(dt_x)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(dt_x)
    new_state = {"ssm": st.astype(state["ssm"].dtype),
                 "conv": window[:, 1:].astype(state["conv"].dtype)}
    return out, new_state
