"""Model assembly: config -> params / train_forward / prefill / decode.

Families
--------
dense / moe / vlm : decoder-only LM (GQA or MLA attention, dense or MoE FFN)
ssm               : mamba2 SSD stack (attention-free)
hybrid            : jamba-style period structure (1 attn per ``attn_every``
                    layers, MoE FFN every ``moe_every``-th layer)
encdec            : whisper-style encoder-decoder (stub frame embeddings)

All stacks are ``lax.scan`` over layer-stacked params (HLO is O(1) in depth)
with optional ``jax.checkpoint`` (remat) on the body.  Decode threads a
layer-stacked cache through the same scan.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

import repro.ff as ff
from repro.core.policy import PrecisionPolicy
from repro.distributed import act_sharding as act_shd
from repro.models import mamba2, mla, moe as moe_lib
from repro.models.config import ModelConfig
from repro.models.layers import (attn_apply, attn_cache_init, attn_decode,
                                 attn_params, attn_prefill, embed_apply,
                                 embed_params, mlp_apply, mlp_params,
                                 rms_norm, unembed_apply)

Array = jnp.ndarray
Params = Dict[str, Any]


def _cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


# ===========================================================================
# parameter init
# ===========================================================================

def _layer_params(key, cfg: ModelConfig, layer_idx: int) -> Params:
    """One decoder layer (used vmapped over layers for dense stacks)."""
    k1, k2 = jax.random.split(key)
    p: Params = {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
                 "ln2": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.use_mla:
        p["attn"] = mla.mla_params(k1, cfg)
    else:
        p["attn"] = attn_params(k1, cfg)
    if cfg.moe_num_experts and (layer_idx % cfg.moe_every == 0):
        p["ffn"] = moe_lib.moe_params(k2, cfg)
    else:
        p["ffn"] = mlp_params(k2, cfg)
    return p


def _stacked_layers(key, cfg: ModelConfig) -> Params:
    """Stack identical-structure layers along axis 0 for scan."""
    keys = jax.random.split(key, cfg.num_layers)
    if cfg.moe_num_experts and cfg.moe_every != 1:
        raise ValueError("interleaved dense/MoE stacks use the hybrid path")
    init_one = functools.partial(_layer_params, cfg=cfg, layer_idx=0)
    return jax.vmap(init_one)(keys)


def init_params(cfg: ModelConfig, key) -> Params:
    ke, kl, kf, kenc = jax.random.split(key, 4)
    params: Params = {"embed": embed_params(ke, cfg),
                      "final_norm": jnp.ones((cfg.d_model,), jnp.float32)}

    if cfg.family in ("dense", "moe", "vlm"):
        params["layers"] = _stacked_layers(kl, cfg)

    elif cfg.family == "ssm":
        keys = jax.random.split(kl, cfg.num_layers)

        def one(k):
            return {"ln": jnp.ones((cfg.d_model,), jnp.float32),
                    "mixer": mamba2.ssd_params(k, cfg)}

        params["layers"] = jax.vmap(one)(keys)

    elif cfg.family == "hybrid":
        period = cfg.attn_every
        assert cfg.num_layers % period == 0
        n_periods = cfg.num_layers // period
        keys = jax.random.split(kl, n_periods)

        def one_period(k):
            ks = jax.random.split(k, period)
            layers = []
            for i in range(period):
                ki1, ki2 = jax.random.split(ks[i])
                lp: Params = {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
                              "ln2": jnp.ones((cfg.d_model,), jnp.float32)}
                if i == cfg.attn_index:
                    lp["mixer_attn"] = attn_params(ki1, cfg)
                else:
                    lp["mixer_ssd"] = mamba2.ssd_params(ki1, cfg)
                if cfg.moe_num_experts and (i % cfg.moe_every == 1):
                    lp["ffn_moe"] = moe_lib.moe_params(ki2, cfg)
                else:
                    lp["ffn_mlp"] = mlp_params(ki2, cfg)
                layers.append(lp)
            return tuple(layers)

        params["layers"] = jax.vmap(one_period)(keys)

    elif cfg.family == "encdec":
        ekeys = jax.random.split(kenc, cfg.encoder_layers)

        def enc_one(k):
            k1, k2 = jax.random.split(k)
            return {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
                    "ln2": jnp.ones((cfg.d_model,), jnp.float32),
                    "attn": attn_params(k1, cfg),
                    "ffn": mlp_params(k2, cfg)}

        params["encoder"] = jax.vmap(enc_one)(ekeys)

        dkeys = jax.random.split(kl, cfg.num_layers)

        def dec_one(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
                    "ln2": jnp.ones((cfg.d_model,), jnp.float32),
                    "ln3": jnp.ones((cfg.d_model,), jnp.float32),
                    "attn": attn_params(k1, cfg),
                    "xattn": attn_params(k2, cfg),
                    "ffn": mlp_params(k3, cfg)}

        params["layers"] = jax.vmap(dec_one)(dkeys)
        params["enc_final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    else:
        raise ValueError(cfg.family)

    if cfg.family == "vlm":
        params["patch_proj"] = jnp.eye(cfg.d_model, dtype=jnp.float32)

    return params


# ===========================================================================
# forward blocks
# ===========================================================================

def _decoder_layer(x: Array, lp: Params, cfg: ModelConfig,
                   policy: PrecisionPolicy, positions: Array) -> Tuple[Array, Array]:
    h = rms_norm(x, lp["ln1"], cfg.norm_eps, ff_stats=policy.ff_reductions)
    if cfg.use_mla:
        a = mla.mla_apply(lp["attn"], h, cfg, positions=positions,
                          attn_impl=policy.attention)
    else:
        a = attn_apply(lp["attn"], h, cfg, positions=positions,
                       attn_impl=policy.attention)
    x = x + a
    h = rms_norm(x, lp["ln2"], cfg.norm_eps, ff_stats=policy.ff_reductions)
    if "router" in lp["ffn"]:
        f, aux = moe_lib.moe_apply(lp["ffn"], h, cfg,
                                   ff_stats=policy.ff_reductions,
                                   ff_math=policy.ff_math)
    else:
        f, aux = (mlp_apply(lp["ffn"], h, ff_math=policy.ff_math),
                  jnp.float32(0))
    return x + f, aux


def _ssm_layer(x: Array, lp: Params, cfg: ModelConfig,
               policy: PrecisionPolicy) -> Array:
    h = rms_norm(x, lp["ln"], cfg.norm_eps, ff_stats=policy.ff_reductions)
    return x + mamba2.ssd_block_apply(lp["mixer"], h, cfg,
                                      ff_math=policy.ff_math)


def _hybrid_period(x: Array, pp, cfg: ModelConfig, policy: PrecisionPolicy,
                   positions: Array) -> Tuple[Array, Array]:
    aux_total = jnp.float32(0)
    for i in range(cfg.attn_every):
        lp = jax.tree_util.tree_map(lambda t: t, pp[i])  # slice view
        h = rms_norm(x, lp["ln1"], cfg.norm_eps, ff_stats=policy.ff_reductions)
        if "mixer_attn" in lp:
            m = attn_apply(lp["mixer_attn"], h, cfg, positions=positions,
                           attn_impl=policy.attention)
        else:
            m = mamba2.ssd_block_apply(lp["mixer_ssd"], h, cfg,
                                       ff_math=policy.ff_math)
        x = x + m
        h = rms_norm(x, lp["ln2"], cfg.norm_eps, ff_stats=policy.ff_reductions)
        if "ffn_moe" in lp:
            f, aux = moe_lib.moe_apply(lp["ffn_moe"], h, cfg,
                                       ff_stats=policy.ff_reductions,
                                       ff_math=policy.ff_math)
            aux_total = aux_total + aux
        else:
            f = mlp_apply(lp["ffn_mlp"], h, ff_math=policy.ff_math)
        x = x + f
    return x, aux_total


def _run_stack(params: Params, x: Array, cfg: ModelConfig,
               policy: PrecisionPolicy, positions: Array) -> Tuple[Array, Array]:
    """Scan the layer stack; returns (hidden, aux_loss)."""
    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, lp):
            h, aux = carry
            h = act_shd.constrain_hidden(h)
            h, a = _decoder_layer(h, lp, cfg, policy, positions)
            return (h, aux + a), None
    elif cfg.family == "ssm":
        def body(carry, lp):
            h, aux = carry
            h = act_shd.constrain_hidden(h)
            return (_ssm_layer(h, lp, cfg, policy), aux), None
    elif cfg.family == "hybrid":
        def body(carry, pp):
            h, aux = carry
            h = act_shd.constrain_hidden(h)
            h, a = _hybrid_period(h, pp, cfg, policy, positions)
            return (h, aux + a), None
    else:
        raise ValueError(cfg.family)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = lax.scan(body, (x, jnp.float32(0)), params["layers"])
    return x, aux


def _encoder_stack(params: Params, frames: Array, cfg: ModelConfig,
                   policy: PrecisionPolicy) -> Array:
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(h, lp):
        z = rms_norm(h, lp["ln1"], cfg.norm_eps, ff_stats=policy.ff_reductions)
        h = h + attn_apply(lp["attn"], z, cfg, positions=positions,
                           causal=False, attn_impl=policy.attention)
        z = rms_norm(h, lp["ln2"], cfg.norm_eps, ff_stats=policy.ff_reductions)
        return h + mlp_apply(lp["ffn"], z,
                             ff_math=policy.ff_math), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = lax.scan(body, frames, params["encoder"])
    return rms_norm(h, params["enc_final_norm"], cfg.norm_eps)


def _encdec_decoder(params: Params, x: Array, enc: Array, cfg: ModelConfig,
                    policy: PrecisionPolicy, positions: Array) -> Array:
    B, Se, _ = enc.shape
    enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))

    def body(carry, lp):
        h = carry
        z = rms_norm(h, lp["ln1"], cfg.norm_eps, ff_stats=policy.ff_reductions)
        h = h + attn_apply(lp["attn"], z, cfg, positions=positions,
                           attn_impl=policy.attention)
        z = rms_norm(h, lp["ln2"], cfg.norm_eps, ff_stats=policy.ff_reductions)
        h = h + _cross_attn(lp["xattn"], z, enc, cfg, positions, enc_pos,
                            attn_impl=policy.attention)
        z = rms_norm(h, lp["ln3"], cfg.norm_eps, ff_stats=policy.ff_reductions)
        return h + mlp_apply(lp["ffn"], z,
                             ff_math=policy.ff_math), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = lax.scan(body, x, params["layers"])
    return h


def _cross_attn(p: Params, x: Array, enc: Array, cfg: ModelConfig,
                positions: Array, enc_pos: Array,
                attn_impl: str = "fast") -> Array:
    from repro.models.layers import apply_rope, flash_attention
    B, S, _ = x.shape
    Se = enc.shape[1]
    hd = cfg.resolved_head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, cfg.num_heads, hd)
    k = (enc @ p["wk"].astype(dt)).reshape(B, Se, cfg.num_kv_heads, hd)
    v = (enc @ p["wv"].astype(dt)).reshape(B, Se, cfg.num_kv_heads, hd)
    o = flash_attention(q, k, v, causal=False, block_q=cfg.attn_block_q,
                        block_kv=cfg.attn_block_kv, impl=attn_impl)
    return o.reshape(B, S, cfg.num_heads * hd) @ p["wo"].astype(dt)


# ===========================================================================
# training forward + loss
# ===========================================================================

def chunked_cross_entropy(x: Array, params: Params, targets: Array,
                          cfg: ModelConfig,
                          policy: Optional[PrecisionPolicy] = None) -> Array:
    """Sequence-chunked CE: logits are computed per S-chunk inside a remat'd
    scan and immediately reduced — the (B, S, V) tensor never exists.  At
    vocab 128k+ this is the difference between ~100s of GiB of temp per
    device and ~100s of MiB (measured in the dry-run)."""
    policy = ff.resolve_policy(policy)
    B, S, d = x.shape
    c = cfg.loss_chunk
    if not c or S <= c:
        logits = unembed_apply(params["embed"], x, cfg,
                               ff_math=policy.ff_math)
        return cross_entropy(logits, targets, policy)
    pad = (-S) % c
    mask = jnp.ones((B, S), jnp.float32)
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = x.shape[1] // c
    xc = x.reshape(B, nc, c, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nc, c).transpose(1, 0, 2)
    mc = mask.reshape(B, nc, c).transpose(1, 0, 2)

    def body(carry, args):
        tot, cnt = carry
        xi, ti, mi = args
        xi = act_shd.constrain_hidden(xi)
        logits = unembed_apply(params["embed"], xi, cfg,
                               ff_math=policy.ff_math).astype(jnp.float32)
        if policy.ff_reductions:
            lse = ff.logsumexp(logits, axis=-1)
        else:
            lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, ti[..., None].astype(jnp.int32), axis=-1)[..., 0]
        nll = (lse - gold) * mi
        return (tot + nll.sum(), cnt + mi.sum()), None

    body = jax.checkpoint(body, prevent_cse=False)
    (tot, cnt), _ = lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                             (xc, tc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def cross_entropy(logits: Array, targets: Array,
                  policy: Optional[PrecisionPolicy] = None,
                  mask: Optional[Array] = None) -> Array:
    """Token-mean CE.  With ff_reductions: compensated LSE + loss sum."""
    policy = ff.resolve_policy(policy)
    V = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    if policy.ff_reductions:
        lse = ff.logsumexp(lf, axis=-1)
    else:
        lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        mask = (targets >= 0)
    mask = mask.astype(jnp.float32)
    nll = nll * mask
    if policy.ff_reductions:
        tot = ff.sum(nll.reshape(-1), block=1024).to_f32()
        cnt = jnp.maximum(mask.sum(), 1.0)
    else:
        tot = nll.sum()
        cnt = jnp.maximum(mask.sum(), 1.0)
    return tot / cnt


def train_forward(params: Params, batch: Dict[str, Array], cfg: ModelConfig,
                  policy: Optional[PrecisionPolicy] = None
                  ) -> Tuple[Array, Dict]:
    policy = ff.resolve_policy(policy)
    dt = _cdtype(cfg)
    tokens = batch["tokens"]
    targets = batch["targets"]
    B, S = tokens.shape
    x = embed_apply(params["embed"], tokens, dt)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    if cfg.family == "vlm":
        patches = batch["patches"].astype(dt) @ params["patch_proj"].astype(dt)
        x = jnp.concatenate([patches, x], axis=1)
        Pn = patches.shape[1]
        positions = jnp.broadcast_to(
            jnp.arange(S + Pn, dtype=jnp.int32), (B, S + Pn))

    if cfg.family == "encdec":
        enc = _encoder_stack(params, batch["frames"].astype(dt), cfg, policy)
        x = _encdec_decoder(params, x, enc, cfg, policy, positions)
        aux = jnp.float32(0)
    else:
        x, aux = _run_stack(params, x, cfg, policy, positions)

    if cfg.family == "vlm":
        x = x[:, -S:]                      # loss over text positions only

    x = rms_norm(x, params["final_norm"], cfg.norm_eps,
                 ff_stats=policy.ff_reductions)
    loss = chunked_cross_entropy(x, params, targets, cfg, policy)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux": aux}


# ===========================================================================
# serving: prefill + decode
# ===========================================================================

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    """Layer-stacked cache pytree matching the scan structure."""
    def stack(make, n):
        one = make()
        return jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t[None], (n,) + t.shape), one)

    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.use_mla:
            return {"layers": stack(
                lambda: mla.mla_cache_init(cfg, batch, max_len, dtype),
                cfg.num_layers)}
        return {"layers": stack(
            lambda: attn_cache_init(cfg, batch, max_len, dtype),
            cfg.num_layers)}
    if cfg.family == "ssm":
        return {"layers": stack(
            lambda: mamba2.ssd_state_init(cfg, batch, jnp.float32),
            cfg.num_layers)}
    if cfg.family == "hybrid":
        period = cfg.attn_every
        n_periods = cfg.num_layers // period
        per = {}
        for i in range(period):
            if i == cfg.attn_index:
                per[f"attn_{i}"] = attn_cache_init(cfg, batch, max_len, dtype)
            else:
                per[f"ssm_{i}"] = mamba2.ssd_state_init(cfg, batch, jnp.float32)
        return {"layers": stack(lambda: per, n_periods)}
    if cfg.family == "encdec":
        dec = stack(lambda: attn_cache_init(cfg, batch, max_len, dtype),
                    cfg.num_layers)
        xkv = stack(lambda: {
            "k": jnp.zeros((batch, cfg.encoder_seq, cfg.num_kv_heads,
                            cfg.resolved_head_dim), dtype),
            "v": jnp.zeros((batch, cfg.encoder_seq, cfg.num_kv_heads,
                            cfg.resolved_head_dim), dtype)},
            cfg.num_layers)
        return {"layers": dec, "cross": xkv}
    raise ValueError(cfg.family)


def prefill(params: Params, batch: Dict[str, Array], cfg: ModelConfig,
            cache: Params, policy: Optional[PrecisionPolicy] = None
            ) -> Tuple[Array, Params]:
    """Run the prompt through the model, filling the cache.  Returns
    (last-position logits, cache)."""
    policy = ff.resolve_policy(policy)
    dt = _cdtype(cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_apply(params["embed"], tokens, dt)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    if cfg.family == "vlm":
        patches = batch["patches"].astype(dt) @ params["patch_proj"].astype(dt)
        x = jnp.concatenate([patches, x], axis=1)
        Pn = patches.shape[1]
        positions = jnp.broadcast_to(
            jnp.arange(S + Pn, dtype=jnp.int32), (B, S + Pn))

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, scanned):
            h = carry
            lp, lcache = scanned
            z = rms_norm(h, lp["ln1"], cfg.norm_eps,
                         ff_stats=policy.ff_reductions)
            if cfg.use_mla:
                a, lcache = mla.mla_prefill(lp["attn"], z, cfg,
                                            positions=positions, cache=lcache,
                                            attn_impl=policy.attention)
            else:
                a, lcache = attn_prefill(lp["attn"], z, cfg,
                                         positions=positions, cache=lcache,
                                         attn_impl=policy.attention)
            h = h + a
            z = rms_norm(h, lp["ln2"], cfg.norm_eps,
                         ff_stats=policy.ff_reductions)
            if "router" in lp["ffn"]:
                f, _ = moe_lib.moe_apply(lp["ffn"], z, cfg,
                                         ff_math=policy.ff_math)
            else:
                f = mlp_apply(lp["ffn"], z, ff_math=policy.ff_math)
            return h + f, lcache

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, new_lcache = lax.scan(body, x, (params["layers"], cache["layers"]))
        cache = {"layers": new_lcache}

    elif cfg.family == "ssm":
        def body(carry, scanned):
            h = carry
            lp, lcache = scanned
            z = rms_norm(h, lp["ln"], cfg.norm_eps,
                         ff_stats=policy.ff_reductions)
            m, new_state = mamba2.ssd_block_apply(
                lp["mixer"], z, cfg, state=None, return_state=True,
                ff_math=policy.ff_math)
            return h + m, new_state

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, new_lcache = lax.scan(body, x, (params["layers"], cache["layers"]))
        cache = {"layers": new_lcache}

    elif cfg.family == "hybrid":
        def body(carry, scanned):
            h = carry
            pp, pcache = scanned
            new_cache = {}
            for i in range(cfg.attn_every):
                lp = pp[i]
                z = rms_norm(h, lp["ln1"], cfg.norm_eps,
                             ff_stats=policy.ff_reductions)
                if "mixer_attn" in lp:
                    a, c = attn_prefill(lp["mixer_attn"], z, cfg,
                                        positions=positions,
                                        cache=pcache[f"attn_{i}"],
                                        attn_impl=policy.attention)
                    new_cache[f"attn_{i}"] = c
                else:
                    a, st = mamba2.ssd_block_apply(
                        lp["mixer_ssd"], z, cfg, return_state=True,
                        ff_math=policy.ff_math)
                    new_cache[f"ssm_{i}"] = st
                h = h + a
                z = rms_norm(h, lp["ln2"], cfg.norm_eps,
                             ff_stats=policy.ff_reductions)
                if "ffn_moe" in lp:
                    f, _ = moe_lib.moe_apply(lp["ffn_moe"], z, cfg,
                                             ff_math=policy.ff_math)
                else:
                    f = mlp_apply(lp["ffn_mlp"], z,
                                  ff_math=policy.ff_math)
                h = h + f
            return h, new_cache

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, new_lcache = lax.scan(body, x, (params["layers"], cache["layers"]))
        cache = {"layers": new_lcache}

    elif cfg.family == "encdec":
        enc = _encoder_stack(params, batch["frames"].astype(dt), cfg, policy)
        B_, Se, _ = enc.shape
        hd = cfg.resolved_head_dim

        def fill_cross(lp, xc):
            k = (enc @ lp["xattn"]["wk"].astype(dt)).reshape(
                B_, Se, cfg.num_kv_heads, hd)
            v = (enc @ lp["xattn"]["wv"].astype(dt)).reshape(
                B_, Se, cfg.num_kv_heads, hd)
            return {"k": k.astype(xc["k"].dtype), "v": v.astype(xc["v"].dtype)}

        cross = jax.vmap(fill_cross)(params["layers"], cache["cross"])

        def body(carry, scanned):
            h = carry
            lp, lcache, xkv = scanned
            z = rms_norm(h, lp["ln1"], cfg.norm_eps,
                         ff_stats=policy.ff_reductions)
            a, lcache = attn_prefill(lp["attn"], z, cfg,
                                     positions=positions, cache=lcache,
                                     attn_impl=policy.attention)
            h = h + a
            z = rms_norm(h, lp["ln2"], cfg.norm_eps,
                         ff_stats=policy.ff_reductions)
            h = h + _cross_attn_cached(lp["xattn"], z, xkv, cfg,
                                       attn_impl=policy.attention)
            z = rms_norm(h, lp["ln3"], cfg.norm_eps,
                         ff_stats=policy.ff_reductions)
            return h + mlp_apply(lp["ffn"], z,
                                 ff_math=policy.ff_math), lcache

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, new_lcache = lax.scan(
            body, x, (params["layers"], cache["layers"], cross))
        cache = {"layers": new_lcache, "cross": cross}
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps,
                 ff_stats=policy.ff_reductions)
    logits = unembed_apply(params["embed"], x, cfg,
                           ff_math=policy.ff_math)
    return logits[:, 0], cache


def _cross_attn_cached(p: Params, x: Array, xkv: Params,
                       cfg: ModelConfig, attn_impl: str = "fast") -> Array:
    from repro.models.layers import flash_attention
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, cfg.num_heads, hd)
    o = flash_attention(q, xkv["k"].astype(dt), xkv["v"].astype(dt),
                        causal=False, block_q=cfg.attn_block_q,
                        block_kv=cfg.attn_block_kv, impl=attn_impl)
    return o.reshape(B, S, cfg.num_heads * hd) @ p["wo"].astype(dt)


def decode_step(params: Params, token: Array, pos: Array, cache: Params,
                cfg: ModelConfig, policy: Optional[PrecisionPolicy] = None
                ) -> Tuple[Array, Params]:
    """One decode step.  token: (B, 1) int32; pos: () int32 (write index).
    Returns (logits (B, V), new cache)."""
    policy = ff.resolve_policy(policy)
    dt = _cdtype(cfg)
    x = embed_apply(params["embed"], token, dt)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, scanned):
            h = carry
            lp, lcache = scanned
            z = rms_norm(h, lp["ln1"], cfg.norm_eps,
                         ff_stats=policy.ff_reductions)
            if cfg.use_mla:
                a, lcache = mla.mla_decode(lp["attn"], z, cfg, pos=pos,
                                           cache=lcache,
                                           attn_impl=policy.attention)
            else:
                a, lcache = attn_decode(lp["attn"], z, cfg, pos=pos,
                                        cache=lcache,
                                        attn_impl=policy.attention)
            h = h + a
            z = rms_norm(h, lp["ln2"], cfg.norm_eps,
                         ff_stats=policy.ff_reductions)
            if "router" in lp["ffn"]:
                f, _ = moe_lib.moe_apply(lp["ffn"], z, cfg,
                                         ff_math=policy.ff_math)
            else:
                f = mlp_apply(lp["ffn"], z, ff_math=policy.ff_math)
            return h + f, lcache

        x, new_lcache = lax.scan(body, x, (params["layers"], cache["layers"]))
        cache = dict(cache)
        cache["layers"] = new_lcache

    elif cfg.family == "ssm":
        def body(carry, scanned):
            h = carry
            lp, st = scanned
            z = rms_norm(h, lp["ln"], cfg.norm_eps,
                         ff_stats=policy.ff_reductions)
            m, st = mamba2.ssd_decode_step(lp["mixer"], z, cfg, st,
                                           ff_math=policy.ff_math)
            return h + m, st

        x, new_lcache = lax.scan(body, x, (params["layers"], cache["layers"]))
        cache = {"layers": new_lcache}

    elif cfg.family == "hybrid":
        def body(carry, scanned):
            h = carry
            pp, pcache = scanned
            new_cache = {}
            for i in range(cfg.attn_every):
                lp = pp[i]
                z = rms_norm(h, lp["ln1"], cfg.norm_eps,
                             ff_stats=policy.ff_reductions)
                if "mixer_attn" in lp:
                    a, c = attn_decode(lp["mixer_attn"], z, cfg, pos=pos,
                                       cache=pcache[f"attn_{i}"],
                                       attn_impl=policy.attention)
                    new_cache[f"attn_{i}"] = c
                else:
                    a, st = mamba2.ssd_decode_step(
                        lp["mixer_ssd"], z, cfg, pcache[f"ssm_{i}"],
                        ff_math=policy.ff_math)
                    new_cache[f"ssm_{i}"] = st
                h = h + a
                z = rms_norm(h, lp["ln2"], cfg.norm_eps,
                             ff_stats=policy.ff_reductions)
                if "ffn_moe" in lp:
                    f, _ = moe_lib.moe_apply(lp["ffn_moe"], z, cfg,
                                             ff_math=policy.ff_math)
                else:
                    f = mlp_apply(lp["ffn_mlp"], z,
                                  ff_math=policy.ff_math)
                h = h + f
            return h, new_cache

        x, new_lcache = lax.scan(body, x, (params["layers"], cache["layers"]))
        cache = {"layers": new_lcache}

    elif cfg.family == "encdec":
        def body(carry, scanned):
            h = carry
            lp, lcache, xkv = scanned
            z = rms_norm(h, lp["ln1"], cfg.norm_eps,
                         ff_stats=policy.ff_reductions)
            a, lcache = attn_decode(lp["attn"], z, cfg, pos=pos, cache=lcache,
                                    attn_impl=policy.attention)
            h = h + a
            z = rms_norm(h, lp["ln2"], cfg.norm_eps,
                         ff_stats=policy.ff_reductions)
            h = h + _cross_attn_decode(lp["xattn"], z, xkv, cfg,
                                       attn_impl=policy.attention)
            z = rms_norm(h, lp["ln3"], cfg.norm_eps,
                         ff_stats=policy.ff_reductions)
            return h + mlp_apply(lp["ffn"], z,
                                 ff_math=policy.ff_math), lcache

        x, new_lcache = lax.scan(
            body, x, (params["layers"], cache["layers"], cache["cross"]))
        cache = dict(cache)
        cache["layers"] = new_lcache
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps,
                 ff_stats=policy.ff_reductions)
    logits = unembed_apply(params["embed"], x, cfg,
                           ff_math=policy.ff_math)
    return logits[:, 0], cache


def _cross_attn_decode(p: Params, x: Array, xkv: Params,
                       cfg: ModelConfig, attn_impl: str = "fast") -> Array:
    from repro.models.layers import decode_attention
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, 1, cfg.num_heads, hd)
    Se = xkv["k"].shape[1]
    o = decode_attention(q, xkv["k"], xkv["v"], jnp.int32(Se),
                        impl=attn_impl)
    return o.reshape(B, 1, cfg.num_heads * hd) @ p["wo"].astype(dt)
