"""Multi-head Latent Attention (DeepSeek-V2) with compressed KV cache.

Faithful structure: queries via low-rank (q_lora) path; K/V via a shared
``kv_lora_rank`` latent that IS the cache (plus a decoupled RoPE key slice).
Decode uses the absorbed formulation (q projected into latent space), so
per-token decode touches only (B, S, kv_lora + rope_dim) — the reason MLA
exists.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

import repro.ff as ff
from repro.models.config import ModelConfig
from repro.models.layers import (NEG_INF, apply_rope, dense_init,
                                 flash_attention, rms_norm)

Array = jnp.ndarray
Params = Dict[str, Any]


def mla_params(key, cfg: ModelConfig) -> Params:
    H = cfg.num_heads
    dq = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "wq_a": dense_init(ks[0], (cfg.d_model, cfg.q_lora_rank)),
        "q_norm": jnp.ones((cfg.q_lora_rank,), jnp.float32),
        "wq_b": dense_init(ks[1], (cfg.q_lora_rank, H * dq)),
        "wkv_a": dense_init(ks[2], (cfg.d_model,
                                    cfg.kv_lora_rank + cfg.qk_rope_head_dim)),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), jnp.float32),
        "wk_b": dense_init(ks[3], (cfg.kv_lora_rank, H * cfg.qk_nope_head_dim)),
        "wv_b": dense_init(ks[4], (cfg.kv_lora_rank, H * cfg.v_head_dim)),
        "wo": dense_init(ks[5], (H * cfg.v_head_dim, cfg.d_model)),
    }


def _project_q(p: Params, x: Array, cfg: ModelConfig, positions: Array):
    B, S, _ = x.shape
    H = cfg.num_heads
    dt = x.dtype
    q_lat = rms_norm(x @ p["wq_a"].astype(dt), p["q_norm"], cfg.norm_eps)
    q = (q_lat @ p["wq_b"].astype(dt)).reshape(
        B, S, H, cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    q_nope = q[..., :cfg.qk_nope_head_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_latent(p: Params, x: Array, cfg: ModelConfig, positions: Array):
    B, S, _ = x.shape
    dt = x.dtype
    kv = x @ p["wkv_a"].astype(dt)
    c_kv = rms_norm(kv[..., :cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., cfg.kv_lora_rank:][:, :, None, :],
                        positions, cfg.rope_theta)[:, :, 0]  # (B,S,rope_dim)
    return c_kv, k_rope


def mla_apply(p: Params, x: Array, cfg: ModelConfig, *,
              positions: Array, attn_impl: str = "fast") -> Array:
    """Training / prefill path: up-project latent to per-head K/V and run
    blockwise attention (memory-feasible: latent is recomputed per block by
    XLA remat rather than cached)."""
    B, S, _ = x.shape
    H = cfg.num_heads
    dt = x.dtype
    q_nope, q_rope = _project_q(p, x, cfg, positions)
    c_kv, k_rope = _project_latent(p, x, cfg, positions)
    k_nope = (c_kv @ p["wk_b"].astype(dt)).reshape(B, S, H, cfg.qk_nope_head_dim)
    v = (c_kv @ p["wv_b"].astype(dt)).reshape(B, S, H, cfg.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :],
                                          (B, S, H, cfg.qk_rope_head_dim))],
                        axis=-1)
    # pad v to qk head dim for the shared flash kernel, then slice back
    dq = q.shape[-1]
    if cfg.v_head_dim < dq:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dq - cfg.v_head_dim)))
    o = flash_attention(q, k, v, causal=True, block_q=cfg.attn_block_q,
                        block_kv=cfg.attn_block_kv, impl=attn_impl)
    o = o[..., :cfg.v_head_dim].reshape(B, S, H * cfg.v_head_dim)
    return o @ p["wo"].astype(dt)


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Params:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_prefill(p: Params, x: Array, cfg: ModelConfig, *, positions: Array,
                cache: Params, attn_impl: str = "fast") -> Tuple[Array, Params]:
    B, S, _ = x.shape
    c_kv, k_rope = _project_latent(p, x, cfg, positions)
    cache = dict(cache)
    cache["c_kv"] = lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), 0, axis=1)
    cache["k_rope"] = lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), 0, axis=1)
    return mla_apply(p, x, cfg, positions=positions, attn_impl=attn_impl), cache


def mla_decode(p: Params, x: Array, cfg: ModelConfig, *, pos: Array,
               cache: Params, attn_impl: str = "fast") -> Tuple[Array, Params]:
    """Absorbed decode: score = q_nope·Wk_b·c_kv + q_rope·k_rope over the
    latent cache; output = (softmax @ c_kv) absorbed through Wv_b.

    ``attn_impl="fast"`` keeps the historical dense-softmax path verbatim
    (bitwise).  Any other impl re-expresses the absorbed score as a single
    GQA attention call — q = [q_eff ‖ q_rope], k = [c_kv ‖ k_rope] with one
    shared KV head, v = c_kv zero-padded to match — and routes it through
    ``ff.attention``'s compensated softmax class.
    """
    B, S, _ = x.shape
    assert S == 1
    H = cfg.num_heads
    dt = x.dtype
    posv = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = _project_q(p, x, cfg, posv)          # (B,1,H,*)
    c_new, kr_new = _project_latent(p, x, cfg, posv)
    cache = dict(cache)
    cache["c_kv"] = lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos, 0))
    cache["k_rope"] = lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos, 0))
    c_kv = cache["c_kv"].astype(jnp.float32)              # (B,Smax,r)
    k_rope = cache["k_rope"].astype(jnp.float32)          # (B,Smax,dr)
    Smax = c_kv.shape[1]

    wk_b = p["wk_b"].astype(jnp.float32).reshape(
        cfg.kv_lora_rank, H, cfg.qk_nope_head_dim)
    # absorb: q_eff (B,H,r) = q_nope . wk_b^T
    q_eff = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32), wk_b)
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    if attn_impl != "fast":
        r, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
        q_cat = jnp.concatenate(
            [q_eff, q_rope[:, 0].astype(jnp.float32)], axis=-1)[:, None]
        k_cat = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None]
        v_lat = jnp.pad(c_kv, ((0, 0), (0, 0), (0, dr)))[:, :, None]
        lat = ff.attention(q_cat, k_cat, v_lat, causal=False,
                           kv_len=jnp.full((B,), pos + 1, jnp.int32),
                           scale=scale, impl=attn_impl)[:, 0, :, :r]
    else:
        s = (jnp.einsum("bhr,bsr->bhs", q_eff, c_kv)
             + jnp.einsum("bhd,bsd->bhs",
                          q_rope[:, 0].astype(jnp.float32), k_rope))
        s = s * scale
        valid = jnp.arange(Smax, dtype=jnp.int32) <= pos
        s = jnp.where(valid[None, None], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        lat = jnp.einsum("bhs,bsr->bhr", pr, c_kv)        # (B,H,r)
    wv_b = p["wv_b"].astype(jnp.float32).reshape(
        cfg.kv_lora_rank, H, cfg.v_head_dim)
    o = jnp.einsum("bhr,rhd->bhd", lat, wv_b).reshape(B, 1, H * cfg.v_head_dim)
    return o.astype(dt) @ p["wo"].astype(dt), cache
