"""Shared transformer layers: norms, RoPE, flash (blockwise) attention with
GQA + KV cache, SwiGLU MLP, embeddings.

Everything is a pure function over parameter pytrees (dicts of jnp arrays) —
no framework objects — so pjit/shard_map, scan and remat compose freely.

Precision-policy integration (the paper's technique as a feature):
  * ``rms_norm(..., ff_stats=True)`` computes the variance with a compensated
    (TwoSum-cascade) reduction — exact enough that bf16/f32 layernorm drift
    disappears at 500k-token sequence scale.
  * attention softmax accumulators are always f32 (standard), with the
    log-sum-exp renormalization structured like the paper's branch-free ops.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

import repro.ff as ff
from repro.models.config import ModelConfig

Array = jnp.ndarray
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis=0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, w: Array, eps: float, ff_stats: bool = False) -> Array:
    """RMSNorm; with ff_stats=True the mean-square is a compensated sum.

    Layout note (§Perf iter 2): the statistics are f32 (and optionally FF),
    but NO f32 (B,S,d) tensor is materialized — only the (B,S,1) scale is
    f32.  With TP-sharded activations, XLA otherwise all-gathers the f32
    pre-convert tensor, doubling the dominant collective (measured on
    llama3-405b train_4k: activation AG/AR were f32, 2x wire bytes).
    """
    xf = x.astype(jnp.float32)
    if ff_stats:
        # one dispatched composite: x*x never round-trips HBM on TPU
        # (fused square+compensated-rowsum kernel; jnp impl elsewhere is
        # bitwise the old ff.sum(xf*xf, block=128)/n formulation)
        ms = ff.mean_sq(xf)[..., None]
    else:
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    scale = lax.rsqrt(ms + eps).astype(x.dtype)      # (B,S,1), cheap in bf16
    return x * scale * w.astype(x.dtype)


def layer_norm(x: Array, w: Array, b: Array, eps: float,
               ff_stats: bool = False) -> Array:
    xf = x.astype(jnp.float32)
    if ff_stats:
        # both LayerNorm reductions in one dispatched composite (fused
        # two-pass kernel on TPU reads x from HBM once; the jnp impl is
        # bitwise the old two ff.sum(block=128) passes)
        mu, var = ff.norm_stats(xf)
        mu, var = mu[..., None], var[..., None]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, hd) ; positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blockwise ("flash") attention — the only memory-feasible form at 32k+
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool,
                    block_q: int, block_kv: int, q_offset=0,
                    impl: str = "fast") -> Array:
    """Online-softmax blockwise attention, via the ``ff.attention`` registry.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd); H = KV * G (GQA).
    Never materializes (Sq, Skv); peak extra memory is
    (B, KV, G, block_q, block_kv).  q_offset: absolute position of q[0]
    (for cached decode/prefill continuation).

    ``impl="fast"`` (the default) is bitwise the historical in-module
    recurrence — the math now lives in ``repro.kernels.ff_attention`` as
    the registry's fast tier.  Passing ``impl="ff"``/``"pallas"``/``"f64"``
    (normally via ``ff.policy(attention=...)`` threaded through the model
    code) swaps in the compensated FF softmax class.
    """
    return ff.attention(q, k, v, causal=causal, q_offset=q_offset,
                        block_q=block_q, block_kv=block_kv, impl=impl)


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     cache_len: Array, *, impl: str = "fast") -> Array:
    """Single-position attention against a (possibly partially filled) cache.

    q: (B, 1, H, hd); caches: (B, Smax, KV, hd); cache_len: () int32 —
    number of valid cache positions (the new token's K/V must already be
    written at cache_len-1) — or (B,) int32 for ragged serving batches
    where every row has its own filled length.

    The ``impl="fast"`` path below is bitwise the historical dense-softmax
    implementation for scalar ``cache_len``; the per-row form only changes
    the mask broadcast, so each row is bitwise what the scalar call would
    produce for that row's length (masked tails contribute exact zeros) —
    the property the paged serving engine's parity contract rests on.
    Accurate impls route through ``ff.attention(causal=False, kv_len=...)``.
    """
    B, _, H, hd = q.shape
    cache_len = jnp.asarray(cache_len, jnp.int32)
    if impl != "fast":
        kv_len = jnp.broadcast_to(cache_len, (B,))
        return ff.attention(q, k_cache, v_cache, causal=False,
                            kv_len=kv_len, impl=impl)
    _, Smax, KV, _ = k_cache.shape
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    q4 = q.reshape(B, KV, G, hd).astype(jnp.float32) * scale
    kf = k_cache.astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", q4, kf)            # (B,KV,G,Smax)
    pos = jnp.arange(Smax, dtype=jnp.int32)
    if cache_len.ndim:
        valid = (pos[None] < cache_len[:, None])[:, None, None]  # (B,1,1,S)
    else:
        valid = (pos < cache_len)[None, None, None]
    s = jnp.where(valid, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p / jnp.maximum(l, 1e-30),
                     v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer (params + apply, train & decode)
# ---------------------------------------------------------------------------

def attn_params(key, cfg: ModelConfig) -> Params:
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (cfg.d_model, cfg.num_heads * hd)),
        "wk": dense_init(k2, (cfg.d_model, cfg.num_kv_heads * hd)),
        "wv": dense_init(k3, (cfg.d_model, cfg.num_kv_heads * hd)),
        "wo": dense_init(k4, (cfg.num_heads * hd, cfg.d_model)),
    }


def attn_apply(p: Params, x: Array, cfg: ModelConfig, *,
               positions: Array, causal: bool = True,
               attn_impl: str = "fast") -> Array:
    """Full-sequence attention (training / prefill)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, cfg.num_heads, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, cfg.num_kv_heads, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, cfg.num_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = flash_attention(q, k, v, causal=causal, block_q=cfg.attn_block_q,
                        block_kv=cfg.attn_block_kv, impl=attn_impl)
    return o.reshape(B, S, cfg.num_heads * hd) @ p["wo"].astype(dt)


def attn_prefill(p: Params, x: Array, cfg: ModelConfig, *, positions: Array,
                 cache: Params, attn_impl: str = "fast") -> Tuple[Array, Params]:
    """Prefill: same as train but also writes the KV cache."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, cfg.num_heads, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, cfg.num_kv_heads, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, cfg.num_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = flash_attention(q, k, v, causal=True, block_q=cfg.attn_block_q,
                        block_kv=cfg.attn_block_kv, impl=attn_impl)
    cache = dict(cache)
    cache["k"] = lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
    cache["v"] = lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
    return o.reshape(B, S, cfg.num_heads * hd) @ p["wo"].astype(dt), cache


def attn_decode(p: Params, x: Array, cfg: ModelConfig, *,
                pos: Array, cache: Params,
                attn_impl: str = "fast") -> Tuple[Array, Params]:
    """One-token decode: update cache at ``pos``, attend to cache[:pos+1]."""
    B, S, _ = x.shape
    assert S == 1
    hd = cfg.resolved_head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, 1, cfg.num_heads, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, 1, cfg.num_kv_heads, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, 1, cfg.num_kv_heads, hd)
    posv = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    cache = dict(cache)
    cache["k"] = lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
    cache["v"] = lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
    o = decode_attention(q, cache["k"], cache["v"], pos + 1, impl=attn_impl)
    return o.reshape(B, 1, cfg.num_heads * hd) @ p["wo"].astype(dt), cache


def attn_cache_init(cfg: ModelConfig, batch: int, max_len: int,
                    dtype=jnp.bfloat16) -> Params:
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype),
    }


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_params(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (cfg.d_model, d_ff)),
        "w_up": dense_init(k2, (cfg.d_model, d_ff)),
        "w_down": dense_init(k3, (d_ff, cfg.d_model)),
    }


def mlp_apply(p: Params, x: Array, ff_math: bool = False) -> Array:
    """SwiGLU MLP.  ``ff_math=True`` (policy ``ff_math`` switch) computes
    the silu gate with the FF elementary function (``ff.silu``, ~2^-43)
    instead of the ~2^-24 f32 builtin; the default is bitwise-identical
    to the pre-``ff.math`` library."""
    dt = x.dtype
    pre = x @ p["w_gate"].astype(dt)
    if ff_math:
        g = ff.to_f32(ff.silu(pre.astype(jnp.float32))).astype(dt)
    else:
        g = jax.nn.silu(pre)
    u = x @ p["w_up"].astype(dt)
    return (g * u) @ p["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embed_params(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"tok": dense_init(k1, (cfg.vocab_size, cfg.d_model))}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, (cfg.d_model, cfg.vocab_size))
    return p


def embed_apply(p: Params, tokens: Array, dtype) -> Array:
    return p["tok"].astype(dtype)[tokens]


def unembed_apply(p: Params, x: Array, cfg: ModelConfig,
                  ff_math: bool = False) -> Array:
    """Unembedding (+ optional logit soft-cap).  ``ff_math=True`` runs
    the soft-cap tanh through ``ff.tanh`` — the cap is the LAST op before
    the loss/logprob reductions, so the builtin's ~2^-24 error otherwise
    floors everything the FF loss machinery measures downstream."""
    dt = x.dtype
    w = p["unembed"].astype(dt) if "unembed" in p else p["tok"].astype(dt).T
    logits = x @ w
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        if ff_math:
            t = ff.tanh(logits.astype(jnp.float32) / jnp.float32(c))
            logits = (jnp.float32(c) * ff.to_f32(t)).astype(dt)
        else:
            logits = c * jnp.tanh(logits / c)
    return logits
