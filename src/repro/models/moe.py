"""Mixture-of-Experts FFN with capacity-based dispatch (GShard-style, but
scatter/gather instead of the O(T*E*C) dispatch einsum so it scales to
160-expert configs).

Router numerics follow the precision policy: router logits/softmax always in
f32, and with ``ff_reductions`` the load-balance statistics use compensated
sums (router stats are the classic place where f32 accumulation drifts at
million-token batches).

Sharding: expert dim maps to the 'model' mesh axis, token dim to 'data'
(EP x DP).  The scatter/gather lowers to all-to-all under SPMD when token
and expert shardings differ — visible in the dry-run collective table.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

import repro.ff as ff
from repro.models.config import ModelConfig
from repro.models.layers import dense_init

Array = jnp.ndarray
Params = Dict[str, Any]


def moe_params(key, cfg: ModelConfig) -> Params:
    E = cfg.moe_num_experts
    dff = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (cfg.d_model, E)),
        "w_gate": dense_init(ks[1], (E, cfg.d_model, dff), in_axis=1),
        "w_up": dense_init(ks[2], (E, cfg.d_model, dff), in_axis=1),
        "w_down": dense_init(ks[3], (E, dff, cfg.d_model), in_axis=1),
    }
    if cfg.moe_shared_experts:
        from repro.models.layers import mlp_params
        p["shared"] = mlp_params(
            ks[4], cfg, d_ff=cfg.moe_shared_experts * dff)
    return p


def moe_apply(p: Params, x: Array, cfg: ModelConfig,
              ff_stats: bool = False,
              ff_math: bool = False) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (out, aux_loss).  ``ff_math`` routes the expert
    (and shared-expert) silu gates through ``ff.silu`` — the same policy
    switch the dense MLP honors; default bitwise-identical."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    dt = x.dtype
    xt = x.reshape(T, d)

    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)   # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                      # (T,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # capacity per expert
    cap = int(max(1, round(k * T * cfg.moe_capacity_factor / E)))

    # position of each (token, slot) within its expert — sort-based instead
    # of a (T*k, E) one-hot cumsum, which is O(T*k*E) memory (4 TB at
    # deepseek train_4k scale); this is O(T*k log T*k) compute, O(T*k) memory
    e_idx = idx.reshape(T * k)
    Tk = T * k
    order = jnp.argsort(e_idx, stable=True)                        # (Tk,)
    sorted_e = e_idx[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=e_idx.dtype))
    pos_sorted = jnp.arange(Tk, dtype=jnp.int32) - starts[sorted_e]
    pos_in_e = jnp.zeros((Tk,), jnp.int32).at[order].set(pos_sorted)
    keep = pos_in_e < cap

    # dispatch: scatter token embeddings into (E, cap, d)
    t_idx = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    buf = jnp.zeros((E, cap, d), dt)
    safe_pos = jnp.where(keep, pos_in_e, cap - 1)
    contrib = jnp.where(keep[:, None], xt[t_idx], 0).astype(dt)
    buf = buf.at[e_idx, safe_pos].add(contrib, mode="drop")

    # expert FFN (batched over E)
    pre = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(dt))
    if ff_math:
        g = ff.to_f32(ff.silu(pre.astype(jnp.float32))).astype(dt)
    else:
        g = jax.nn.silu(pre)
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(dt))
    h = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"].astype(dt))

    # combine: gather back and weight by gates
    y = h[e_idx, safe_pos]                                         # (T*k,d)
    y = jnp.where(keep[:, None], y, 0) * gate_vals.reshape(T * k, 1).astype(dt)
    out = jnp.zeros((T, d), dt).at[t_idx].add(y)

    if cfg.moe_shared_experts:
        from repro.models.layers import mlp_apply
        out = out + mlp_apply(p["shared"], xt, ff_math=ff_math)

    # load-balance aux loss (Switch):  E * sum_e f_e * P_e
    if ff_stats:
        me = (ff.sum(probs, axis=0, block=4096).to_f32() / T)
    else:
        me = jnp.mean(probs, axis=0)                               # (E,)
    counts = jnp.zeros((E,), jnp.float32).at[e_idx].add(1.0)
    ce = counts / (T * k)
    aux = E * jnp.sum(me * ce)

    return out.reshape(B, S, d), aux
