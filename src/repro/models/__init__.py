"""Model zoo: unified config + families (dense/MoE/MLA, SSM, hybrid,
enc-dec, VLM) as pure functions over parameter pytrees."""
from repro.models.config import ModelConfig  # noqa: F401
from repro.models.model import (  # noqa: F401
    init_params, train_forward, prefill, decode_step, init_cache,
)
