"""Quickstart: the unified ``repro.ff`` namespace in 60 seconds.

Run:  PYTHONPATH=src python examples/quickstart.py [--smoke]

``--smoke`` (used by the CI examples job) shrinks the demo sizes so the
whole tour runs in seconds while still exercising every API it shows —
the snippets here mirror the README/docs and must never drift from them.
"""
import argparse
import os
_f = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _f:      # EFT-safe CPU mode (core/selfcheck.py)
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _f).strip()

_ap = argparse.ArgumentParser()
_ap.add_argument("--smoke", action="store_true",
                 help="tiny sizes for CI (same API coverage)")
SMOKE = _ap.parse_args().smoke
N_SUM = 1 << 16 if SMOKE else 1 << 20
K_MM = 512 if SMOKE else 2048

import numpy as np
import jax
import jax.numpy as jnp

import repro.ff as ff
from repro.core.selfcheck import require_eft_safe

require_eft_safe()

print(f"=== repro.ff on backend: {ff.backend()} ===")
print(f"registered ops: {ff.ops()}")

print("\n=== 1. Error-free transformations (paper Theorems 2-4) ===")
a = jnp.float32(1.0 + 2**-23)      # 1 + ulp
b = jnp.float32(2**-30)            # far below ulp(a)
s = ff.two_sum(a, b)
print(f"a + b in f32      : {float(a + b)!r}   (b vanishes)")
print(f"two_sum (hi, lo)  : ({float(s.hi)!r}, {float(s.lo)!r})   (b preserved in lo)")
exact = np.float64(a) + np.float64(b)
print(f"hi+lo == exact f64: {float(np.float64(s.hi) + np.float64(s.lo)) == exact}")

print("\n=== 2. 44-bit compound arithmetic (Theorems 5-6) ===")
x = ff.from_f64(np.pi)             # pi to ~48 bits in two f32
y = ff.from_f64(np.e)
z = ff.mul(x, y)
print(f"pi * e  (f32)     : {np.float32(np.pi) * np.float32(np.e):.10f}")
print(f"pi * e  (FF)      : {float(z.to_f64()):.15f}")
print(f"pi * e  (f64 ref) : {np.pi * np.e:.15f}")
q = ff.div(1.0, x)                 # FF.__rtruediv__ sugar: 1.0 / x
print(f"1/pi    (FF)      : {float(q.to_f64()):.15f}")
print(f"x == x, x < y     : {bool((x == x).all())}, {bool((x < y).all())}")

print("\n=== 3. Compensated reductions ===")
rng = np.random.default_rng(0)
v = (rng.standard_normal(N_SUM) * 10 ** rng.uniform(-6, 6, N_SUM)).astype(np.float32)
naive = float(jnp.sum(jnp.asarray(v)))
comp = ff.sum(jnp.asarray(v))
exact = float(np.sum(v.astype(np.float64)))
print(f"naive f32 sum rel err : {abs(naive - exact) / abs(exact):.2e}")
print(f"ff.sum rel err        : {abs(float(comp.to_f64()) - exact) / abs(exact):.2e}")

print("\n=== 4. Backend-dispatched FF matmul ===")
A = rng.standard_normal((64, K_MM)).astype(np.float32)
B = rng.standard_normal((K_MM, 64)).astype(np.float32)
E = A.astype(np.float64) @ B.astype(np.float64)
S = np.abs(A.astype(np.float64)) @ np.abs(B.astype(np.float64))
naive = np.asarray(jnp.asarray(A) @ jnp.asarray(B), np.float64)
print(f"{'impl':22s}  max err/|A||B|")
print(f"{'naive f32':22s}: {(np.abs(naive - E) / S).max():.2e}")
for impl in ("hybrid", "split", "dot2", "ozaki"):
    R = ff.matmul(jnp.asarray(A), jnp.asarray(B), impl=impl)
    print(f"{impl:22s}: {(np.abs(R.to_f64() - E) / S).max():.2e}")

print("\n=== 5. Scoped precision policy ===")
with ff.policy("ff_full", matmul="dot2") as p:
    print(f"inside scope : level={p.level} matmul={p.matmul_impl} "
          f"ff_reductions={ff.current_policy().ff_reductions}")
    R = ff.matmul(jnp.asarray(A), jnp.asarray(B))      # routed to dot2
    print(f"scoped matmul: max err/|A||B| = {(np.abs(R.to_f64() - E) / S).max():.2e}")
print(f"outside scope: level={ff.current_policy().level}")

print("\n=== 6. Differentiable FF (custom_vjp: d(a*b) = a db + b da in FF) ===")
xv = ff.from_f64(rng.standard_normal(8))
yv = ff.from_f64(rng.standard_normal(8))
g = jax.grad(lambda t: ff.mul(t, yv).to_f32().sum())(xv)
got = np.float64(g.hi) + np.float64(g.lo)
want = yv.to_f64()
print(f"grad(ff.mul) vs analytic rel err: "
      f"{(np.abs(got - want) / np.maximum(np.abs(want), 1e-30)).max():.2e}")
