"""Quickstart: the float-float core in 60 seconds.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
_f = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _f:      # EFT-safe CPU mode (core/selfcheck.py)
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _f).strip()

import numpy as np
import jax.numpy as jnp

from repro.core import (FF, add12, mul12, add22, mul22, ff_sum, ff_dot,
                        matmul_split, matmul_dot2)
from repro.core.selfcheck import require_eft_safe

require_eft_safe()

print("=== 1. Error-free transformations (paper Theorems 2-4) ===")
a = jnp.float32(1.0 + 2**-23)      # 1 + ulp
b = jnp.float32(2**-30)            # far below ulp(a)
s = add12(a, b)
print(f"a + b in f32      : {float(a + b)!r}   (b vanishes)")
print(f"Add12 (hi, lo)    : ({float(s.hi)!r}, {float(s.lo)!r})   (b preserved in lo)")
exact = np.float64(a) + np.float64(b)
print(f"hi+lo == exact f64: {float(np.float64(s.hi) + np.float64(s.lo)) == exact}")

p = mul12(jnp.float32(1.2345678), jnp.float32(7.654321))
exact = np.float64(np.float32(1.2345678)) * np.float64(np.float32(7.654321))
print(f"Mul12 exact       : {float(np.float64(p.hi) + np.float64(p.lo)) == exact}")

print("\n=== 2. 44-bit compound arithmetic (Theorems 5-6) ===")
x = FF.from_f64(np.pi)             # pi to ~48 bits in two f32
y = FF.from_f64(np.e)
z = mul22(x, y)
print(f"pi * e  (f32)     : {np.float32(np.pi) * np.float32(np.e):.10f}")
print(f"pi * e  (FF)      : {float(z.to_f64()):.15f}")
print(f"pi * e  (f64 ref) : {np.pi * np.e:.15f}")

print("\n=== 3. Compensated reductions ===")
rng = np.random.default_rng(0)
v = (rng.standard_normal(1 << 20) * 10 ** rng.uniform(-6, 6, 1 << 20)).astype(np.float32)
naive = float(jnp.sum(jnp.asarray(v)))
comp = ff_sum(jnp.asarray(v))
exact = float(np.sum(v.astype(np.float64)))
print(f"naive f32 sum rel err : {abs(naive - exact) / abs(exact):.2e}")
print(f"ff_sum rel err        : {abs(float(comp.to_f64()) - exact) / abs(exact):.2e}")

print("\n=== 4. FF matmul (MXU adaptation, DESIGN.md §2) ===")
A = rng.standard_normal((64, 2048)).astype(np.float32)
B = rng.standard_normal((2048, 64)).astype(np.float32)
E = A.astype(np.float64) @ B.astype(np.float64)
S = np.abs(A.astype(np.float64)) @ np.abs(B.astype(np.float64))
naive = np.asarray(jnp.asarray(A) @ jnp.asarray(B), np.float64)
for name, fn in (("split-operand", matmul_split), ("dot2 (paper-faithful)", matmul_dot2)):
    R = fn(jnp.asarray(A), jnp.asarray(B))
    err = (np.abs(R.to_f64() - E) / S).max()
    print(f"{name:22s}: max err/|A||B| = {err:.2e}")
print(f"{'naive f32':22s}: max err/|A||B| = {(np.abs(naive - E) / S).max():.2e}")
