"""Serving example: batched prefill + greedy decode with KV cache.

Covers: dense GQA serving, SSM (mamba2-family) recurrent-state serving,
teacher-forced consistency (decode logits == forward logits), and a
continuous-batching trace through ``repro.serve.ServeEngine`` — staggered
request arrivals with mixed prompt lengths joining and leaving the running
batch mid-flight, token-for-token the sequential greedy baseline.

Run:  PYTHONPATH=src python examples/serve_lm.py          # full demo
      PYTHONPATH=src python examples/serve_lm.py --smoke  # CI-sized
"""
import argparse
import dataclasses
import os

_f = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _f:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _f).strip()

import numpy as np
import jax
import jax.numpy as jnp

import repro.ff as ff
from repro.models import init_params, prefill, init_cache
from repro.models.config import ModelConfig
from repro.serve import Request, ServeEngine
from repro.train.serve_step import greedy_generate


def serve(cfg: ModelConfig, label: str, smoke: bool = False):
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S, new = (2, 16, 6) if smoke else (4, 48, 16)
    prompt = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    toks = greedy_generate(params, cfg, prompt, max_new=new,
                           cache_len=S + new + 8)
    assert toks.shape == (B, new)
    assert bool(jnp.all((toks >= 0) & (toks < cfg.vocab_size)))
    # teacher-forced check: feeding generated tokens back through prefill
    # reproduces the greedy choice at the last position
    full = jnp.concatenate([prompt, toks[:, :-1]], axis=1)
    cache = init_cache(cfg, B, S + new + 8)
    logits, _ = jax.jit(lambda p, b, c: prefill(p, b, cfg, c))(
        params, {"tokens": full}, cache)
    redo = jnp.argmax(logits, -1)
    agree = float(jnp.mean((redo == toks[:, -1]).astype(jnp.float32)))
    print(f"{label:12s}: generated {toks.shape}, "
          f"teacher-forced agreement {agree:.2f}")


def serve_engine_trace(cfg: ModelConfig, smoke: bool = False,
                       metrics_json=None, trace_out=None):
    """Continuous batching with STAGGERED arrivals: a second wave of
    requests is submitted while the first wave is mid-decode, joins the
    running batch at the next step, and every result still matches the
    sequential greedy baseline token-for-token.  With ``metrics_json`` /
    ``trace_out`` set, the run is fully instrumented (obs.enable()
    profiler annotations on) and emits the metrics snapshot and the
    Perfetto-loadable request trace as artifacts."""
    from repro import obs
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    max_new = 4 if smoke else 12
    n1, n2 = (2, 2) if smoke else (4, 3)
    lens = rng.integers(6, 25, size=n1 + n2)
    prompts = [rng.integers(1, cfg.vocab_size, size=int(l)).astype(np.int32)
               for l in lens]
    instrumented = bool(metrics_json or trace_out)
    obs_scope = obs.enable() if instrumented else None
    if obs_scope is not None:
        obs_scope.__enter__()
    eng = ServeEngine(params, cfg, max_batch=3, page_size=8, max_ctx=64)

    for i in range(n1):                       # wave 1 arrives
        eng.submit(Request(uid=i, prompt=prompts[i], max_new=max_new))
    trace = []
    steps = 0
    live = True
    while live:
        live = eng.step()
        steps += 1
        if steps == 2:                        # wave 2 arrives mid-decode
            for i in range(n1, n1 + n2):
                eng.submit(Request(uid=i, prompt=prompts[i],
                                   max_new=max_new))
            live = True
        running = sorted(s["req"].uid for s in eng._slots if s is not None)
        trace.append(running)
    results = eng.results
    assert len(results) == n1 + n2

    # every request, wave 1 or wave 2, matches its own sequential run
    for i, p in enumerate(prompts):
        ref = greedy_generate(params, cfg, jnp.asarray(p[None]), max_new,
                              cache_len=64)
        assert np.array_equal(results[i].tokens, np.asarray(ref[0])), (
            f"engine output diverged from greedy baseline for uid={i}")
    joined = sum(1 for a, b in zip(trace, trace[1:])
                 if set(b) - set(a))
    print(f"engine      : {n1}+{n2} staggered requests "
          f"(prompts {lens.min()}..{lens.max()}) through batch=3 in "
          f"{steps} steps, {joined} mid-flight joins, all token-for-token "
          f"== greedy")
    if obs_scope is not None:
        obs_scope.__exit__(None, None, None)
    if metrics_json:
        eng.obs.dump_metrics(metrics_json)
        print(f"engine      : metrics snapshot -> {metrics_json}")
    if trace_out:
        eng.obs.dump_trace(trace_out)
        spans = sum(1 for e in eng.obs.trace.events()
                    if e["ph"] == "X" and e["name"] == "request")
        assert spans == n1 + n2, (spans, n1 + n2)
        print(f"engine      : Perfetto trace ({spans} request spans) -> "
              f"{trace_out}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: smaller models/requests, same coverage")
    ap.add_argument("--metrics-json", type=str, default=None,
                    help="write the engine+dispatch metrics snapshot of the "
                         "continuous-batching trace to this JSON file")
    ap.add_argument("--trace-out", type=str, default=None,
                    help="write the Chrome trace-event JSON (Perfetto) of "
                         "the continuous-batching trace to this file")
    args = ap.parse_args()

    dense = ModelConfig(
        name="serve-dense", family="dense", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=4096, head_dim=64,
        max_seq_len=256, attn_block_q=64, attn_block_kv=64,
        compute_dtype="float32", remat=False)
    # serving reads the scoped precision policy (ff_reduce = compensated
    # LSE/norm statistics in prefill+decode, no extra matmul cost)
    with ff.policy("ff_reduce", compute_dtype="float32"):
        serve(dense, "dense GQA", smoke=args.smoke)

    ssm = ModelConfig(
        name="serve-ssm", family="ssm", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=4096,
        ssm_state=32, ssm_head_dim=32, max_seq_len=256,
        compute_dtype="float32", remat=False)
    with ff.policy("ff_reduce", compute_dtype="float32"):
        serve(ssm, "mamba2 (SSD)", smoke=args.smoke)

    if args.smoke:
        small = dataclasses.replace(dense, num_layers=2, d_model=128,
                                    d_ff=256, vocab_size=512)
        serve_engine_trace(small, smoke=True,
                           metrics_json=args.metrics_json,
                           trace_out=args.trace_out)
    else:
        serve_engine_trace(dense, metrics_json=args.metrics_json,
                           trace_out=args.trace_out)


if __name__ == "__main__":
    main()
