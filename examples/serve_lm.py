"""Serving example: batched prefill + greedy decode with KV cache.

Covers: dense GQA serving, SSM (mamba2-family) recurrent-state serving,
and teacher-forced consistency (decode logits == forward logits).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import os

_f = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _f:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _f).strip()

import numpy as np
import jax
import jax.numpy as jnp

import repro.ff as ff
from repro.models import init_params, prefill, init_cache
from repro.models.config import ModelConfig
from repro.train.serve_step import greedy_generate


def serve(cfg: ModelConfig, label: str):
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S, new = 4, 48, 16
    prompt = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    toks = greedy_generate(params, cfg, prompt, max_new=new,
                           cache_len=S + new + 8)
    assert toks.shape == (B, new)
    assert bool(jnp.all((toks >= 0) & (toks < cfg.vocab_size)))
    # teacher-forced check: feeding generated tokens back through prefill
    # reproduces the greedy choice at the last position
    full = jnp.concatenate([prompt, toks[:, :-1]], axis=1)
    cache = init_cache(cfg, B, S + new + 8)
    logits, _ = jax.jit(lambda p, b, c: prefill(p, b, cfg, c))(
        params, {"tokens": full}, cache)
    redo = jnp.argmax(logits, -1)
    agree = float(jnp.mean((redo == toks[:, -1]).astype(jnp.float32)))
    print(f"{label:12s}: generated {toks.shape}, "
          f"teacher-forced agreement {agree:.2f}")


def main():
    dense = ModelConfig(
        name="serve-dense", family="dense", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=4096, head_dim=64,
        max_seq_len=256, attn_block_q=64, attn_block_kv=64,
        compute_dtype="float32", remat=False)
    # serving reads the scoped precision policy (ff_reduce = compensated
    # LSE/norm statistics in prefill+decode, no extra matmul cost)
    with ff.policy("ff_reduce", compute_dtype="float32"):
        serve(dense, "dense GQA")

    ssm = ModelConfig(
        name="serve-ssm", family="ssm", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=4096,
        ssm_state=32, ssm_head_dim=32, max_seq_len=256,
        compute_dtype="float32", remat=False)
    with ff.policy("ff_reduce", compute_dtype="float32"):
        serve(ssm, "mamba2 (SSD)")


if __name__ == "__main__":
    main()
