"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
FF master weights, checkpointing, and straggler monitoring.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--policy ff_master]

Compares against a plain-f32 baseline arm with --policy baseline.
``--smoke`` (the CI examples job) trains a tiny model for a few steps and
asserts the loss moved — enough to catch any API drift in this script
without CI-scale compute.
"""
import argparse
import os

_f = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _f:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _f).strip()

import jax
import jax.numpy as jnp

import repro.ff as ff
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def model_100m() -> ModelConfig:
    # ~100M params: 12L, d=768, 12H, ffn 2048, vocab 32k
    return ModelConfig(
        name="repro-100m", family="dense",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
        d_ff=2048, vocab_size=32000, head_dim=64, max_seq_len=1024,
        attn_block_q=128, attn_block_kv=128, loss_chunk=128,
        compute_dtype="float32", remat=False,
    )


def model_smoke() -> ModelConfig:
    # CI-sized: ~0.5M params, compiles + trains in seconds on 2 CPU cores
    return ModelConfig(
        name="repro-smoke", family="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512, head_dim=32, max_seq_len=128,
        attn_block_q=64, attn_block_kv=64, loss_chunk=64,
        compute_dtype="float32", remat=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--policy", default="ff_master",
                    choices=["baseline", "ff_master", "ff_reduce", "ff_full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny model, few steps, loss-moved assert")
    args = ap.parse_args()

    if args.smoke:
        args.steps = min(args.steps, 30)
        args.seq = min(args.seq, 64)
        args.ckpt_dir = None

    cfg = model_smoke() if args.smoke else model_100m()
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))

    # Scoped policy: the step builder (and everything under it) reads the
    # ambient ff.policy scope — no positional threading.
    with ff.policy(args.policy, compute_dtype="float32") as policy:
        print(f"model: {n/1e6:.1f}M params, policy={policy.level}")
        opt = AdamW(learning_rate=cosine_schedule(3e-4, 20, args.steps),
                    ff=policy.ff_master_weights)
        opt_state = opt.init(params)
        step_fn = jax.jit(make_train_step(cfg, optimizer=opt),
                          donate_argnums=(0, 1))

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq, global_batch=args.batch))

    def data_iter(i):
        return {k: jnp.asarray(v) for k, v in data.batch(i).items()}

    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_every=100,
                      ckpt_dir=args.ckpt_dir, log_every=20),
        step_fn, params, opt_state, data_iter)
    trainer.restore()
    out = trainer.run()
    print(f"done: {out}")
    # the synthetic grammar is learnable: loss must drop well below ln(V)
    # (smoke mode only has ~30 steps — require movement, not convergence)
    import numpy as np
    frac = 0.98 if args.smoke else 0.8
    assert out["last_loss"] < np.log(cfg.vocab_size) * frac, "did not learn"
    print(f"final loss {out['last_loss']:.3f} "
          f"(uniform would be {np.log(cfg.vocab_size):.3f})")


if __name__ == "__main__":
    main()
