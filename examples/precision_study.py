"""Precision study: what FF buys at each integration point (the paper's
technique as a framework feature, measured end-to-end).

Four arms train the same model from the same init on the same data:
  baseline   — plain f32 master weights
  ff_master  — FF master weights (paper technique in the optimizer)
  ff_reduce  — + compensated loss/norm/LSE reductions
  ff_full    — + FF logits path

Prints final losses and the master-weight drift diagnostic: after LR
decay, per-step updates drop below f32 ulp and the baseline arm silently
stops moving; the FF arms keep integrating.

Run:  PYTHONPATH=src python examples/precision_study.py [--steps 150]
"""
import argparse
import os

_f = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _f:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _f).strip()

import numpy as np
import jax
import jax.numpy as jnp

import repro.ff as ff_ns
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamW
from repro.train.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="study-20m", family="dense",
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=4,
        d_ff=1024, vocab_size=8192, head_dim=64, max_seq_len=512,
        attn_block_q=128, attn_block_kv=128, loss_chunk=128,
        compute_dtype="float32", remat=False)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=256,
                                  global_batch=8))
    params0 = init_params(cfg, jax.random.PRNGKey(0))

    results = {}
    for level in ("baseline", "ff_master", "ff_reduce", "ff_full"):
        with ff_ns.policy(level, compute_dtype="float32") as policy:
            opt = AdamW(learning_rate=3e-4, ff=policy.ff_master_weights)
            step_fn = jax.jit(make_train_step(cfg, optimizer=opt))
        params, opt_state = params0, opt.init(params0)
        losses = []
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            params, opt_state, m = step_fn(params, opt_state, batch)
            losses.append(float(m["loss"]))
        results[level] = losses
        print(f"{level:10s}: first {losses[0]:.4f}  last {losses[-1]:.4f}  "
              f"mean(last10) {np.mean(losses[-10:]):.4f}")

    # All arms must learn; FF arms must match or beat baseline.
    base = np.mean(results["baseline"][-10:])
    for level in ("ff_master", "ff_reduce", "ff_full"):
        assert np.mean(results[level][-10:]) <= base * 1.05, level
    print("\nFF arms match/beat the f32 baseline at equal step count.")

    # sub-ulp integration demo (the stagnation experiment, see
    # benchmarks/table_optimizer.py for the isolated version)
    print("\nsub-ulp drift test (lr=2e-9, 1000 steps, w=1.0):")
    for ff in (False, True):
        opt = AdamW(learning_rate=2e-9, b1=0.0, b2=0.0, eps=1e-30,
                    weight_decay=0.0, ff=ff)
        p = {"w": jnp.ones((16,), jnp.float32)}
        s = opt.init(p)
        g = {"w": jnp.ones((16,), jnp.float32)}
        step = jax.jit(lambda p, s: opt.update(g, s, p))
        for _ in range(1000):
            p, s = step(p, s)
        if ff:
            w = np.float64(np.asarray(p["w"]))[0] + np.float64(
                np.asarray(s.master_lo["w"]))[0]
        else:
            w = float(p["w"][0])
        print(f"  {'FF ' if ff else 'f32'} master: w = {w:.12f} "
              f"(exact: {1 - 2e-9 * 1000:.12f})")


if __name__ == "__main__":
    main()
