"""Shared fixtures.  NOTE: do NOT set XLA_FLAGS device-count here — smoke
tests and benches must see 1 device (dry-run sets its own flags).

We DO set --xla_cpu_max_isa=SSE4_2 (before any jax import): XLA:CPU's LLVM
backend on AVX2+ contracts mul+add into FMA inside fusions, which breaks the
paper's error-free transformations (see core/selfcheck.py).  The paper's 2006
GPUs had no FMA either, so this is also the faithful hardware model."""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_max_isa" not in _flags:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_max_isa=SSE4_2 " + _flags).strip()

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)


def f32_vec(rng, n, lo=-10, hi=10):
    """Well-scaled random f32 test vector (no denormals/inf/nan — the paper
    excludes them too, §6.1)."""
    return (rng.standard_normal(n) * 10.0 ** rng.uniform(lo, hi, n)).astype(np.float32)
