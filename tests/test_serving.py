"""Serving subsystem: flash-attention accuracy contract, paged FF KV
cache, and continuous-batching engine parity with the sequential baseline.

Contracts under test (docs/DESIGN_serving.md):
  * accurate-tier flash attention ("ff"/"pallas") within 2^-40 of the f64
    oracle on long-K rows (the compensated online softmax claim);
  * the paged KV cache round-trips bitwise, pages FF hi/lo limbs through
    ONE block table, and serializes to plain numpy;
  * the engine is token-for-token ``greedy_generate`` under mixed-length
    continuous batching with join/evict (logprobs agree to batched-matmul
    ulp noise, NOT bitwise — XLA tiles B=8 matmuls differently than B=1);
  * FF token-logprob scoring within 2^-40 of the f64 oracle.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.ff as ff
from repro.core.policy import PrecisionPolicy
from repro.kernels.ff_attention import attention_f64
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.serve import PagedKVCache, Request, ServeEngine
from repro.serve.paged_kv import ff_merge, ff_split
from repro.train.serve_step import greedy_generate, token_logprob_ff

TOL = 2.0 ** -40


# --------------------------------------------------------------------------
# flash-attention accuracy contract
# --------------------------------------------------------------------------

def _attn_operands(rng, B=2, Sq=4, Skv=768, H=2, KV=1, hd=32):
    q = jnp.asarray(rng.standard_normal((B, Sq, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, Skv, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, Skv, KV, hd)).astype(np.float32))
    return q, k, v


@pytest.mark.parametrize("impl", ["ff", "pallas"])
def test_flash_attention_ulp_contract(rng, impl):
    """Accurate tiers <= 2^-40 of the f64 oracle on long-K rows (per-row
    relative to max|ref| — the paper's error model for dot-product
    accumulation)."""
    q, k, v = _attn_operands(rng)
    got = ff.attention(q, k, v, causal=False, impl=impl, return_ff=True)
    ref = attention_f64(q, k, v, causal=False, return_ff=True)
    r64 = np.asarray(ref.hi, np.float64) + np.asarray(ref.lo, np.float64)
    g64 = np.asarray(got.hi, np.float64) + np.asarray(got.lo, np.float64)
    den = np.abs(r64).max(axis=(1, 3), keepdims=True)
    err = float((np.abs(g64 - r64) / den).max())
    assert err <= TOL, f"attention[{impl}] err {err:.3e} > 2^-40"


def test_flash_attention_fast_vs_accurate(rng):
    """The fast tier agrees with the accurate tier to f32 working
    precision (sanity: both compute the same softmax(QK^T)V)."""
    q, k, v = _attn_operands(rng, Skv=256)
    fast = np.asarray(ff.attention(q, k, v, causal=False, impl="fast"))
    acc = np.asarray(ff.attention(q, k, v, causal=False, impl="ff"))
    assert np.max(np.abs(fast - acc)) < 1e-5


def test_flash_attention_kv_len_rows(rng):
    """Per-row kv_len masking matches slicing each row by hand."""
    q, k, v = _attn_operands(rng, B=3, Skv=96)
    kv_len = jnp.asarray([17, 96, 41], jnp.int32)
    got = ff.attention(q, k, v, causal=False, kv_len=kv_len, impl="ff",
                       return_ff=True)
    for b, n in enumerate(np.asarray(kv_len)):
        ref = attention_f64(q[b:b + 1], k[b:b + 1, :n], v[b:b + 1, :n],
                            causal=False, return_ff=True)
        r64 = np.asarray(ref.hi, np.float64) + np.asarray(ref.lo, np.float64)
        g64 = (np.asarray(got.hi[b:b + 1], np.float64)
               + np.asarray(got.lo[b:b + 1], np.float64))
        den = np.abs(r64).max(axis=(1, 3), keepdims=True)
        assert float((np.abs(g64 - r64) / den).max()) <= TOL


# --------------------------------------------------------------------------
# paged KV cache
# --------------------------------------------------------------------------

def _kv_tensors(rng, L=2, S=21, KV=2, hd=8):
    return {"k": jnp.asarray(rng.standard_normal((L, S, KV, hd)),
                             jnp.float32),
            "v": jnp.asarray(rng.standard_normal((L, S, KV, hd)),
                             jnp.float32)}


def test_paged_roundtrip_bitwise(rng):
    """write_prefill -> gather is bitwise the storage cast of the input,
    for every kv_mode; FF limbs recombine exactly (the split residual sum
    is exact in f32)."""
    tensors = _kv_tensors(rng)
    for mode in ("bf16", "f32", "ff_bf16"):
        kv = PagedKVCache(2, 2, 8, num_pages=12, page_size=4, max_seqs=2,
                          max_ctx=32, kv_mode=mode)
        kv.alloc(0, 21)
        kv.write_prefill(0, tensors)
        back = kv.gather(0)
        for base in ("k", "v"):
            x = tensors[base]
            if mode == "bf16":
                want = np.asarray(x.astype(jnp.bfloat16))
            elif mode == "f32":
                want = np.asarray(x)
            else:   # double-bf16 limbs recombine to hi+lo exactly
                hi, lo = ff_split(x)
                want = np.asarray(ff_merge(hi, lo))
            assert np.array_equal(np.asarray(back[base], np.float32),
                                  np.asarray(want, np.float32)), \
                f"{mode}/{base} round-trip not bitwise"


def test_ff_bf16_pages_beat_single_bf16(rng):
    """The double-bf16 limb pair carries ~2x the mantissa of one bf16."""
    x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    hi, lo = ff_split(x)
    err_ff = np.max(np.abs(np.asarray(ff_merge(hi, lo)) - np.asarray(x)))
    err_bf = np.max(np.abs(np.asarray(hi.astype(jnp.float32))
                           - np.asarray(x)))
    assert err_ff <= 2.0 ** -14 * float(np.abs(np.asarray(x)).max())
    assert err_ff < err_bf / 16


def test_paged_evict_reuse(rng):
    """Evicting a slot recycles its pages; a new sequence writing into the
    recycled pages reads back its own data bitwise."""
    kv = PagedKVCache(2, 2, 8, num_pages=6, page_size=4, max_seqs=2,
                      max_ctx=24, kv_mode="f32")
    a = _kv_tensors(rng, S=20)
    kv.alloc(0, 20)
    kv.write_prefill(0, a)
    used = set(int(p) for p in kv.block_table[0] if p >= 0)
    assert len(kv.free_pages) == 1
    kv.free_slot(0)
    assert len(kv.free_pages) == 6
    b = _kv_tensors(rng, S=20)
    kv.alloc(1, 20)                      # must reuse evicted pages
    assert used & set(int(p) for p in kv.block_table[1] if p >= 0)
    kv.write_prefill(1, b)
    back = kv.gather(1)
    assert np.array_equal(np.asarray(back["k"]), np.asarray(b["k"]))


def test_paged_alloc_guards():
    kv = PagedKVCache(1, 1, 4, num_pages=4, page_size=4, max_seqs=2,
                      max_ctx=16)
    kv.alloc(0, 13)                      # 4 pages
    assert not kv.can_alloc(1)
    with pytest.raises(RuntimeError):
        kv.alloc(1, 1)                   # pool exhausted
    with pytest.raises(RuntimeError):
        kv.alloc(0, 4)                   # slot occupied


def test_paged_state_roundtrip(rng):
    """to_state/from_state: plain numpy dict, bitwise planes + bookkeeping
    (including the FF limb planes and their SHARED block table)."""
    for mode in ("bf16", "ff_bf16"):
        kv = PagedKVCache(2, 2, 8, num_pages=10, page_size=4, max_seqs=2,
                          max_ctx=32, kv_mode=mode)
        kv.alloc(0, 9)
        kv.write_prefill(0, _kv_tensors(rng, S=9))
        state = kv.to_state()
        assert all(isinstance(v, np.ndarray) for v in state.values())
        kv2 = PagedKVCache.from_state(state)
        assert kv2.kv_mode == mode
        assert np.array_equal(kv2.block_table, kv.block_table)
        assert np.array_equal(kv2.seq_lens, kv.seq_lens)
        assert kv2.free_pages == kv.free_pages
        for name in kv.planes:
            a = np.asarray(kv.planes[name], np.float32)
            b = np.asarray(kv2.planes[name], np.float32)
            assert np.array_equal(a, b), f"{mode}/{name} plane drifted"
        assert np.array_equal(np.asarray(kv2.gather(0)["v"], np.float32),
                              np.asarray(kv.gather(0)["v"], np.float32))


# --------------------------------------------------------------------------
# engine vs greedy baseline
# --------------------------------------------------------------------------

CFG = ModelConfig(name="serve-test", family="dense", num_layers=2,
                  d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                  vocab_size=512, max_seq_len=128, compute_dtype="float32",
                  remat=False)


@pytest.fixture(scope="module")
def served():
    return init_params(CFG, jax.random.PRNGKey(0))


def _mixed_requests(rng, n, max_new):
    lens = rng.integers(5, 23, size=n)
    return [Request(uid=i,
                    prompt=rng.integers(1, CFG.vocab_size,
                                        size=int(l)).astype(np.int32),
                    max_new=max_new)
            for i, l in enumerate(lens)]


def test_engine_matches_greedy_mixed_lengths(served, rng):
    """5 mixed-length requests through max_batch=2 (forces joins and
    evictions) == per-request greedy_generate token-for-token; logprobs to
    batched-matmul ulp noise."""
    reqs = _mixed_requests(rng, 5, max_new=8)
    eng = ServeEngine(served, CFG, max_batch=2, page_size=8, max_ctx=48)
    for r in reqs:
        eng.submit(r)
    res = eng.run()
    assert sorted(res) == [r.uid for r in reqs]
    for r in reqs:
        toks, lps = greedy_generate(served, CFG, jnp.asarray(r.prompt[None]),
                                    r.max_new, cache_len=48,
                                    return_logprobs=True)
        assert np.array_equal(res[r.uid].tokens, np.asarray(toks[0])), \
            f"uid={r.uid}: engine tokens diverge from greedy"
        np.testing.assert_allclose(res[r.uid].logprobs, np.asarray(lps[0]),
                                   atol=1e-4)
        # the FF limb-pair score agrees with its own f32 tier at f32 ulp
        ffsum = res[r.uid].logprobs_ff.sum(axis=1)
        np.testing.assert_allclose(ffsum, res[r.uid].logprobs, atol=1e-4)


def test_engine_eos_matches_greedy(served, rng):
    """Per-sequence EOS early-exit: pick an eos_id the model actually
    emits, and check engine == greedy_generate(eos_id=...) per request
    (rows pin to EOS, loop exits early)."""
    reqs = _mixed_requests(rng, 3, max_new=10)
    probe = greedy_generate(served, CFG, jnp.asarray(reqs[0].prompt[None]),
                            10, cache_len=48)
    eos = int(np.asarray(probe)[0, 3])   # something it emits mid-stream
    eng = ServeEngine(served, CFG, max_batch=2, page_size=8, max_ctx=48,
                      eos_id=eos)
    for r in reqs:
        eng.submit(r)
    res = eng.run()
    for r in reqs:
        want = np.asarray(greedy_generate(
            served, CFG, jnp.asarray(r.prompt[None]), r.max_new,
            cache_len=48, eos_id=eos)[0])
        got = res[r.uid].tokens
        n = len(got)
        assert np.array_equal(got, want[:n])
        # greedy pads finished rows with EOS; the engine stops the row
        assert all(int(t) == eos for t in want[n:])


def test_engine_staggered_submit(served, rng):
    """Requests submitted mid-decode join the running batch at the next
    step() and still match their sequential runs."""
    reqs = _mixed_requests(rng, 3, max_new=6)
    eng = ServeEngine(served, CFG, max_batch=2, page_size=8, max_ctx=48)
    eng.submit(reqs[0])
    eng.step()
    eng.step()
    for r in reqs[1:]:
        eng.submit(r)                    # arrives mid-flight
    res = eng.run()
    assert sorted(res) == [0, 1, 2]
    for r in reqs:
        want = greedy_generate(served, CFG, jnp.asarray(r.prompt[None]),
                               r.max_new, cache_len=48)
        assert np.array_equal(res[r.uid].tokens, np.asarray(want[0]))


def test_greedy_generate_eos_semantics(served, rng):
    """eos_id=None is the historical full-length path; with eos_id set,
    tokens before the first EOS are unchanged and everything after a
    row's first EOS is pinned to EOS."""
    prompt = jnp.asarray(
        rng.integers(1, CFG.vocab_size, size=(2, 9)).astype(np.int32))
    base = np.asarray(greedy_generate(served, CFG, prompt, 10,
                                      cache_len=48))
    eos = int(base[0, 4])
    out = np.asarray(greedy_generate(served, CFG, prompt, 10, cache_len=48,
                                     eos_id=eos))
    assert out.shape[1] <= base.shape[1]
    for b in range(2):
        hits = np.nonzero(base[b, :out.shape[1]] == eos)[0]
        cut = int(hits[0]) + 1 if hits.size else out.shape[1]
        assert np.array_equal(out[b, :cut], base[b, :cut])
        assert np.all(out[b, cut:] == eos)


def test_engine_ff_policy(served, rng):
    """ff.policy(attention="ff") routes the engine decode softmax through
    the compensated FF class; outputs stay within working precision of the
    fast tier."""
    from repro.ff.scope import resolve_policy
    reqs = _mixed_requests(rng, 2, max_new=4)
    with ff.policy(attention="ff", compute_dtype="float32"):
        pol = resolve_policy(None)
        eng = ServeEngine(served, CFG, max_batch=2, page_size=8, max_ctx=48)
    assert pol.attention == "ff" and eng.policy.attention == "ff"
    for r in reqs:
        eng.submit(r)
    res = eng.run()
    for r in reqs:
        # the baseline under the SAME policy: threading is consistent
        want, lps = greedy_generate(served, CFG, jnp.asarray(r.prompt[None]),
                                    r.max_new, cache_len=48, policy=pol,
                                    return_logprobs=True)
        assert np.array_equal(res[r.uid].tokens, np.asarray(want[0]))
        # batched-vs-single matmul tiling noise compounds through the
        # layer stack to ~1e-4 on logprobs (tokens are the hard contract)
        np.testing.assert_allclose(res[r.uid].logprobs, np.asarray(lps[0]),
                                   atol=5e-4)
        # and the FF class only moves outputs at working precision
        plain = greedy_generate(served, CFG, jnp.asarray(r.prompt[None]),
                                r.max_new, cache_len=48)
        assert np.array_equal(res[r.uid].tokens, np.asarray(plain[0]))


# --------------------------------------------------------------------------
# paged KV failure paths (robustness tier — see docs/DESIGN_robustness.md)
# --------------------------------------------------------------------------

def test_paged_grow_failure_paths():
    """grow(): pool exhaustion raises WITHOUT mutating the bookkeeping
    (the engine relies on retry-after-preempt); multi-page jumps are
    structural errors; over-max_ctx allocation is a ValueError."""
    kv = PagedKVCache(1, 1, 4, num_pages=3, page_size=4, max_seqs=2,
                      max_ctx=16)
    with pytest.raises(ValueError):
        kv.alloc(0, 17)                      # length > max_ctx
    kv.alloc(0, 10)                          # 3 pages: pool now empty
    assert kv.grow(0, 12) is None            # same page: no allocation
    with pytest.raises(RuntimeError):
        kv.grow(0, 13)                       # needs a 4th page, pool dry
    assert int(kv.seq_lens[0]) == 12         # failed grow left state alone
    problems, _ = kv.check_integrity()
    assert not problems
    kv2 = PagedKVCache(1, 1, 4, num_pages=6, page_size=4, max_seqs=1,
                       max_ctx=24)
    kv2.alloc(0, 2)
    with pytest.raises(ValueError):
        kv2.grow(0, 12)                      # +2 pages in one call


def test_paged_double_alloc_and_exhaustion():
    kv = PagedKVCache(1, 1, 4, num_pages=4, page_size=4, max_seqs=3,
                      max_ctx=16)
    kv.alloc(0, 13)                          # 4 pages
    with pytest.raises(RuntimeError):
        kv.alloc(1, 1)                       # pool exhausted on alloc
    with pytest.raises(RuntimeError):
        kv.alloc(0, 4)                       # double-alloc of a live slot
    assert not kv.free_pages and int(kv.seq_lens[1]) == 0  # no leak


def test_paged_dirty_page_reuse_masked():
    """free_slot leaves page contents dirty by design; a shorter sequence
    reusing those pages must never observe the stale tail (gather slices
    to the live length; decode masks by lens).

    Local rng: this test was added after the suite's session-scoped rng
    stream was calibrated — consuming shared draws here would shift the
    random inputs of every later accuracy test."""
    rng = np.random.default_rng(779)
    kv = PagedKVCache(2, 2, 8, num_pages=5, page_size=4, max_seqs=2,
                      max_ctx=20, kv_mode="f32")
    big = _kv_tensors(rng, S=20)
    kv.alloc(0, 20)
    kv.write_prefill(0, big)
    kv.free_slot(0)                          # pages dirty with `big`
    small = _kv_tensors(rng, S=9)
    kv.alloc(1, 9)                           # reuses dirty pages
    kv.write_prefill(1, small)
    back = kv.gather(1)
    assert back["k"].shape[1] == 9           # stale tail not observable
    assert np.array_equal(np.asarray(back["k"]), np.asarray(small["k"]))


def test_paged_integrity_audit_and_rebuild():
    """check_integrity catalogues every corruption class; drop_slot +
    rebuild_free_list restore a clean, fully-accounted pool."""
    kv = PagedKVCache(1, 1, 4, num_pages=8, page_size=4, max_seqs=3,
                      max_ctx=16)
    kv.alloc(0, 8)
    kv.alloc(1, 8)
    problems, bad = kv.check_integrity()
    assert not problems and not bad
    kv.block_table[0, 0] = 99                # out of range
    kv.block_table[1, 1] = kv.block_table[1, 0]   # duplicate reference
    problems, bad = kv.check_integrity()
    assert problems and bad == {0, 1}
    for slot in bad:
        kv.drop_slot(slot)                   # pages untrusted: not freed
    kv.rebuild_free_list()
    problems, bad = kv.check_integrity()
    assert not problems and not bad
    assert sorted(kv.free_pages) == list(range(8))  # every page recovered
    kv.alloc(2, 16)                          # pool fully usable again


# --------------------------------------------------------------------------
# batched host sync (eos-less decode)
# --------------------------------------------------------------------------

def test_engine_batched_sync_parity(served):
    """sync_every=4 (one device_get per 4 decode steps) is token-for-token
    AND logprob-for-logprob identical to sync_every=1 — the next input
    token stays on device, so batching the sync changes no math.

    Local rng (not the session fixture): see
    test_paged_dirty_page_reuse_masked."""
    reqs = _mixed_requests(np.random.default_rng(780), 3, max_new=7)
    results = {}
    for n in (1, 4):
        eng = ServeEngine(served, CFG, max_batch=2, page_size=8,
                          max_ctx=48, sync_every=n)
        assert eng.sync_every == n
        for r in reqs:
            eng.submit(Request(uid=r.uid, prompt=r.prompt,
                               max_new=r.max_new))
        results[n] = eng.run()
    for r in reqs:
        a, b = results[1][r.uid], results[4][r.uid]
        assert np.array_equal(a.tokens, b.tokens)
        np.testing.assert_array_equal(a.logprobs, b.logprobs)
        np.testing.assert_array_equal(a.logprobs_ff, b.logprobs_ff)
        want = greedy_generate(served, CFG, jnp.asarray(r.prompt[None]),
                               r.max_new, cache_len=48)
        assert np.array_equal(b.tokens, np.asarray(want[0]))


def test_engine_eos_forces_per_step_sync(served):
    """EOS termination needs the token on the host every step, so eos_id
    overrides sync_every."""
    eng = ServeEngine(served, CFG, max_batch=2, page_size=8, max_ctx=48,
                      eos_id=3, sync_every=8)
    assert eng.sync_every == 1


# --------------------------------------------------------------------------
# FF token-logprob accuracy tier
# --------------------------------------------------------------------------

def test_token_logprob_ff_oracle(rng):
    """Limb-pair score within 2^-40 of the exact f64 log-softmax over a
    wide-dynamic-range vocab row."""
    logits = jnp.asarray(
        (rng.standard_normal((4, 4096)) * 8.0).astype(np.float32))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    s = token_logprob_ff(logits, tok)
    lg64 = np.asarray(logits, np.float64)
    m = lg64.max(-1, keepdims=True)
    lse = np.log(np.exp(lg64 - m).sum(-1)) + m[:, 0]
    ref = lg64[np.arange(4), np.asarray(tok)] - lse
    got = np.asarray(s.hi, np.float64) + np.asarray(s.lo, np.float64)
    err = float(np.max(np.abs(got - ref) / np.maximum(np.abs(ref), 1e-30)))
    assert err <= TOL, f"token_logprob_ff err {err:.3e} > 2^-40"
