"""The SMT tier and its foundations.

Two layers, deliberately split by dependency:

* **Always-on (no z3)** — the symbolic tracer pins: the traced formulas
  come from the LIVE raw-limb code paths, bitwise-checked against real
  jnp execution, and the residuals/bounds hold on the traced path per
  the exact rational oracle.  These guarantee that whatever the solver
  proves is about the shipped code.
* **z3-gated** — the actual proof obligations (UNSAT on the negated
  contract), the domain non-vacuity check, and the deliberately-false
  canary (must come back ``counterexample`` — guarding the encoding
  against vacuous UNSAT).  A ``skipif`` marker keeps this layer a clean
  skip where z3-solver is not installed; the CI verify job runs the
  no-z3 path first to prove skip-not-fail.
"""

import os

import numpy as np
import pytest

from repro.verify import oracle, smt, symtrace

TIMEOUT_MS = int(os.environ.get("VERIFY_SMT_TIMEOUT_MS", "120000"))


# ---------------------------------------------------------------------------
# always-on: trace fidelity (the proofs are about THIS code)
# ---------------------------------------------------------------------------

def _grids(rng, n=4096):
    a = (rng.standard_normal(n) * np.exp2(rng.integers(-30, 30, n))
         ).astype(np.float32)
    b = (rng.standard_normal(n) * np.exp2(rng.integers(-30, 30, n))
         ).astype(np.float32)
    al = (a * np.float32(2 ** -25) * rng.standard_normal(n)
          ).astype(np.float32)
    bl = (b * np.float32(2 ** -25) * rng.standard_normal(n)
          ).astype(np.float32)
    return a, al, b, bl


def _bits(x):
    return np.asarray(x, np.float32).view(np.uint32)


def _assert_bitwise(name, traced, live):
    for t, l in zip(traced, live):
        t = np.asarray(t, np.float32)
        l = np.asarray(l, np.float32)
        same = (_bits(t) == _bits(l)) | (np.isnan(t) & np.isnan(l))
        assert same.all(), (name, int(np.argmin(same)))


@pytest.mark.parametrize("namespace", symtrace.NAMESPACES)
def test_traced_path_matches_live(namespace):
    """NumpyBackend symbolic execution == the real jnp execution,
    bitwise, for every raw-limb op the obligations are generated from.
    THE load-bearing pin: it runs in tier-1 with or without z3."""
    import jax.numpy as jnp

    rng = np.random.default_rng(20260809)
    a, al, b, bl = _grids(rng)
    be = symtrace.NumpyBackend()
    fns = symtrace.eft_fns(namespace)
    for name, fn in fns.items():
        if name == "sqrt22":
            args = [np.abs(a) + np.float32(0.5), al]
        elif name == "fast_two_sum":
            hi = np.where(np.abs(a) >= np.abs(b), a, b)
            lo = np.where(np.abs(a) >= np.abs(b), b, a)
            args = [hi, lo]
        elif name in ("two_sum", "two_prod"):
            args = [a, b]
        else:
            args = [a, al, b, bl]
        traced = symtrace.run_traced(namespace, name, be, args)
        live = fn(*[jnp.asarray(x) for x in args])
        _assert_bitwise(f"{namespace}.{name}", traced, live)


def test_live_paths_restores_module_bindings():
    import jax.numpy as jnp
    from jax import lax

    import repro.core.ff as core_ff
    import repro.core.transforms as T
    import repro.kernels.eft as KE

    with symtrace.live_paths():
        assert KE.jnp is not jnp                 # proxied inside
    assert KE.jnp is jnp
    assert T.jnp is jnp and T.lax is lax
    assert core_ff.jnp is jnp


@pytest.mark.parametrize("namespace", symtrace.NAMESPACES)
def test_traced_two_sum_residual_exact_on_oracle(namespace):
    """The contract the SMT tier proves, checked on the traced path with
    exact rational arithmetic (runs everywhere)."""
    rng = np.random.default_rng(5)
    be = symtrace.NumpyBackend()
    for _ in range(300):
        a = np.float32(rng.standard_normal() * 2.0 ** rng.integers(-20, 20))
        b = np.float32(rng.standard_normal() * 2.0 ** rng.integers(-20, 20))
        s, r = symtrace.run_traced(namespace, "two_sum", be, [a, b])
        assert (oracle.exact(np.float32(s)) + oracle.exact(np.float32(r))
                == oracle.exact(a) + oracle.exact(b))


@pytest.mark.parametrize("namespace", symtrace.NAMESPACES)
def test_traced_two_prod_residual_exact_on_oracle(namespace):
    rng = np.random.default_rng(6)
    be = symtrace.NumpyBackend()
    for _ in range(300):
        a = np.float32(rng.standard_normal() * 2.0 ** rng.integers(-20, 20))
        b = np.float32(rng.standard_normal() * 2.0 ** rng.integers(-20, 20))
        p, e = symtrace.run_traced(namespace, "two_prod", be, [a, b])
        assert (oracle.exact(np.float32(p)) + oracle.exact(np.float32(e))
                == oracle.exact(a) * oracle.exact(b))


def test_obligation_registry_shape():
    """Every advertised obligation exists for every namespace it names,
    and the skip path is clean when z3 is absent."""
    keys = set(smt.OBLIGATIONS)
    for ns in symtrace.NAMESPACES:
        for name in ("two_sum_residual_exact", "fast_two_sum_residual_exact",
                     "two_prod_residual_exact", "mul22_rel_bound_2pow44",
                     "add22_sloppy_thm5_bound"):
            assert f"{name}[{ns}]" in keys
    assert "add22_accurate_rel_bound_2pow44[core]" in keys
    assert "canary_two_sum_residual_nonzero[kernels]" in keys
    if not smt.have_z3():
        r = smt.prove("two_sum_residual_exact[kernels]")
        assert r.status == "skipped" and r.ok


def test_sym_is_numpy_coercion_proof():
    """numpy scalars must defer to Sym's reflected operators (the Dekker
    split spells ``jnp.float32(4097) * a``) — an object-array leak here
    would silently break the trace."""
    be = symtrace.NumpyBackend()
    s = be.lift(np.float32(2.0))
    out = np.float32(3.0) * s
    assert isinstance(out, symtrace.Sym)
    assert float(out.val) == 6.0
    out2 = np.float32(1.0) - s
    assert isinstance(out2, symtrace.Sym) and float(out2.val) == -1.0


# ---------------------------------------------------------------------------
# z3-gated: the proofs themselves (a marker, NOT a module-level
# importorskip — the always-on pins above must run everywhere)
# ---------------------------------------------------------------------------

requires_z3 = pytest.mark.skipif(
    not smt.have_z3(), reason="z3-solver not installed (optional dep)")


def _prove(key):
    r = smt.prove(key, timeout_ms=TIMEOUT_MS)
    if r.status == "unknown":
        pytest.xfail(f"solver unknown/timeout on {key}: {r.detail}")
    return r


@pytest.mark.parametrize("namespace", symtrace.NAMESPACES)
@pytest.mark.parametrize("name", ["two_sum_residual_exact",
                                  "fast_two_sum_residual_exact",
                                  "two_prod_residual_exact"])
@requires_z3
def test_eft_exactness_proofs(name, namespace):
    r = _prove(f"{name}[{namespace}]")
    assert r.status == "proved", r.detail


@pytest.mark.parametrize("key", [
    "add22_sloppy_thm5_bound[kernels]",
    "add22_sloppy_thm5_bound[core]",
    "add22_accurate_rel_bound_2pow44[core]",
    "mul22_rel_bound_2pow44[kernels]",
    "mul22_rel_bound_2pow44[core]",
])
@requires_z3
def test_bound_proofs(key):
    r = _prove(key)
    assert r.status == "proved", r.detail


@pytest.mark.parametrize("name", ["two_sum", "fast_two_sum", "two_prod",
                                  "add22", "mul22"])
@requires_z3
def test_namespace_equivalence_proofs(name):
    """jnp == pallas limb-for-limb, as a theorem instead of a sample."""
    r = _prove(f"{name}_kernels_equals_core[both]")
    assert r.status == "proved", r.detail


@requires_z3
def test_false_obligation_yields_counterexample():
    """The canary: a deliberately false claim must produce a model —
    otherwise the whole encoding could be vacuously UNSAT."""
    r = _prove("canary_two_sum_residual_nonzero[kernels]")
    assert r.status == "proved", r.detail      # 'proved' == sat-as-required


@requires_z3
def test_domain_is_not_vacuous():
    """The normal-or-zero constraints alone must be satisfiable."""
    import z3
    ctx = smt._Ctx()
    constraints, _goal = smt.OBLIGATIONS[
        "two_sum_residual_exact[kernels]"].build(ctx)
    s = z3.Solver()
    s.set("timeout", TIMEOUT_MS)
    s.add(*constraints)
    assert s.check() == z3.sat


@pytest.mark.slow_sweep
@pytest.mark.parametrize("key", [
    "div22_rel_bound_2pow43[kernels]",
    "div22_rel_bound_2pow43[core]",
    "sqrt22_rel_bound_2pow44[kernels]",
    "sqrt22_rel_bound_2pow44[core]",
])
@requires_z3
def test_heavy_bound_proofs(key):
    r = _prove(key)
    assert r.status == "proved", r.detail
