"""Property-based (hypothesis) invariants for the FF core: adversarial
scalars against the paper's EFT theorems.

Split out of test_core_ff.py so the main suite runs without hypothesis;
this module skips itself when the dependency is absent.
"""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    FF, add12, add22, add22_accurate, div22, mul22, split, sqrt22,
    two_prod, two_sum,
)


def ff64(x):
    return np.asarray(x.hi).astype(np.float64) + np.asarray(x.lo).astype(np.float64)


# ---------------------------------------------------------------------------
# Property-based tests (hypothesis): invariants on adversarial scalars
# ---------------------------------------------------------------------------

finite_f32 = st.floats(
    allow_nan=False, allow_infinity=False, width=32,
).filter(lambda x: x == 0.0 or 1e-30 < abs(x) < 1e30)


@settings(max_examples=200, deadline=None)
@given(finite_f32, finite_f32)
def test_prop_two_sum_exact(a, b):
    s, r = two_sum(jnp.float32(a), jnp.float32(b))
    assert float(s) + float(r) == float(np.float64(np.float32(a)) + np.float64(np.float32(b)))


@settings(max_examples=200, deadline=None)
@given(finite_f32, finite_f32)
def test_prop_two_prod_exact(a, b):
    p = np.float64(np.float32(a)) * np.float64(np.float32(b))
    if p != 0 and (abs(p) > 3e38 or abs(p) < 1e-25):
        return  # overflow/underflow (incl. subnormal split residues, FTZ)
        # excluded, like the paper §6.1
    x, y = two_prod(jnp.float32(a), jnp.float32(b))
    assert float(x) + float(y) == p


@settings(max_examples=200, deadline=None)
@given(finite_f32)
def test_prop_split_nonoverlap(a):
    hi, lo = split(jnp.float32(a))
    hi, lo = float(hi), float(lo)
    assert hi + lo == float(np.float32(a))
    assert abs(lo) <= abs(hi) or hi == 0.0


@settings(max_examples=100, deadline=None)
@given(finite_f32, finite_f32, finite_f32, finite_f32)
def test_prop_add22_associativity_error(a, b, c, d):
    """FF addition is not associative, but both orders stay within 2^-40 of
    exact — the invariant applications rely on."""
    fa, fb = add12(jnp.float32(a), jnp.float32(b)), add12(jnp.float32(c), jnp.float32(d))
    exact = (np.float64(np.float32(a)) + np.float64(np.float32(b))
             + np.float64(np.float32(c)) + np.float64(np.float32(d)))
    mag = (abs(np.float64(np.float32(a))) + abs(np.float64(np.float32(b)))
           + abs(np.float64(np.float32(c))) + abs(np.float64(np.float32(d))))
    if mag == 0:
        return
    r1 = ff64(add22_accurate(fa, fb))
    assert abs(r1 - exact) / mag < 2.0**-40


# ---------------------------------------------------------------------------
# adversarial limb classes: pairs constructed to sit exactly on the FF
# normalization boundaries the random strategies above almost never hit
# ---------------------------------------------------------------------------

# hi limbs in the safe interior (the paper §6.1 domain: well away from
# overflow and the Dekker-split window edges)
_safe_hi = st.floats(
    allow_nan=False, allow_infinity=False, width=32,
).filter(lambda x: 1e-20 < abs(x) < 1e20)

# near-overflow hi limbs: the top decades of the f32 range
_big_hi = st.floats(
    min_value=1e30, max_value=3.0e38, width=32,
).flatmap(lambda m: st.sampled_from([m, -m]))


def _ulp32(x: float) -> float:
    return float(np.nextafter(np.float32(x), np.float32(np.inf))
                 - np.float32(x)) if x >= 0 else _ulp32(-x)


@st.composite
def adversarial_pair(draw, hi_strategy=_safe_hi):
    """An FF pair whose lo limb lands in one of the adversarial classes:
    exactly +-0.5 ulp(hi) (the normalization tie), a subnormal magnitude,
    a maximal in-contract lo, or zero."""
    hi = np.float32(draw(hi_strategy))
    cls = draw(st.sampled_from(["tie", "subnormal", "max_lo", "zero"]))
    sign = draw(st.sampled_from([1.0, -1.0]))
    if cls == "tie":
        lo = np.float32(sign * 0.5 * _ulp32(float(hi)))
    elif cls == "subnormal":
        lo = np.float32(sign * 2.0 ** -140)
    elif cls == "max_lo":
        lo = np.float32(sign * 0.49 * _ulp32(float(hi)))
    else:
        lo = np.float32(sign * 0.0)
    return FF(jnp.float32(hi), jnp.float32(lo))


def _ff_exact64(x: FF) -> float:
    return float(np.asarray(x.hi, np.float64) + np.asarray(x.lo, np.float64))


@settings(max_examples=150, deadline=None)
@given(adversarial_pair(), adversarial_pair())
def test_prop_add22_adversarial_limbs(a, b):
    """Thm 5 class on tie/subnormal/max-lo limbs, opposite signs
    included: |err| <= max(2^-24 |al + bl|, 2^-43 |a + b|) in the f64
    view (f64 can resolve both floors at these magnitudes)."""
    exact = _ff_exact64(a) + _ff_exact64(b)
    got = ff64(add22(a, b))
    lo_mag = abs(float(np.asarray(a.lo, np.float64))
                 + float(np.asarray(b.lo, np.float64)))
    # the 2^-125 floor absorbs flush-to-zero hardware dropping a
    # subnormal lo limb outright (paper §6.1 exclusion)
    tol = max(2.0 ** -24 * lo_mag, 2.0 ** -43 * abs(exact), 2.0 ** -125)
    assert abs(got - exact) <= tol or exact == got


@settings(max_examples=150, deadline=None)
@given(adversarial_pair(), adversarial_pair())
def test_prop_add22_accurate_adversarial_limbs(a, b):
    exact = _ff_exact64(a) + _ff_exact64(b)
    got = ff64(add22_accurate(a, b))
    # opposite-sign cancellation can leave |exact| far below either
    # operand; the accurate variant must still track it to 2^-43 rel
    # (subnormal-lo pairs bottom out at the f32 representability floor)
    floor = 2.0 ** -126
    assert abs(got - exact) <= max(2.0 ** -43 * abs(exact), floor)


@settings(max_examples=150, deadline=None)
@given(adversarial_pair(), adversarial_pair())
def test_prop_mul22_adversarial_limbs(a, b):
    exact = _ff_exact64(a) * _ff_exact64(b)
    if not (1e-30 < abs(exact) < 1e30):
        return                                   # paper §6.1 exclusions
    got = ff64(mul22(a, b))
    assert abs(got - exact) <= 2.0 ** -43 * abs(exact)


@settings(max_examples=150, deadline=None)
@given(adversarial_pair(), adversarial_pair())
def test_prop_div22_adversarial_limbs(a, b):
    den = _ff_exact64(b)
    if den == 0:
        return
    exact = _ff_exact64(a) / den
    if not (1e-30 < abs(exact) < 1e30):
        return
    got = ff64(div22(a, b))
    assert abs(got - exact) <= 2.0 ** -42 * abs(exact)


@settings(max_examples=150, deadline=None)
@given(adversarial_pair())
def test_prop_sqrt22_adversarial_limbs(a):
    v = _ff_exact64(a)
    if v <= 0:
        return
    exact = float(np.sqrt(np.float64(v)))
    got = ff64(sqrt22(a))
    assert abs(got - exact) <= 2.0 ** -43 * abs(exact)


@settings(max_examples=100, deadline=None)
@given(_big_hi)
def test_prop_add22_near_overflow_hi(hi):
    """Near-overflow hi limbs: add22 of (hi, ~max lo) with its negation
    cancels exactly; with itself it overflows to inf, never to garbage."""
    a = FF(jnp.float32(hi), jnp.float32(0.49 * _ulp32(abs(float(hi)))))
    cancel = add22(a, FF(-a.hi, -a.lo))
    assert float(cancel.hi) == 0.0 and float(cancel.lo) == 0.0
    doubled = add22(a, a)
    d64 = 2.0 * _ff_exact64(a)
    thresh = 3.4028236692093846e38              # f32 round-to-inf threshold
    if abs(d64) >= thresh * (1 + 2.0 ** -40):
        assert not np.isfinite(float(doubled.hi))
    elif abs(d64) <= thresh * (1 - 2.0 ** -40):
        assert abs(ff64(doubled) - d64) <= 2.0 ** -43 * abs(d64)
    # inside the 2^-40 band around the threshold either rounding is fine


