"""Property-based (hypothesis) invariants for the FF core: adversarial
scalars against the paper's EFT theorems.

Split out of test_core_ff.py so the main suite runs without hypothesis;
this module skips itself when the dependency is absent.
"""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    add12, add22_accurate, split, two_prod, two_sum,
)


def ff64(x):
    return np.asarray(x.hi).astype(np.float64) + np.asarray(x.lo).astype(np.float64)


# ---------------------------------------------------------------------------
# Property-based tests (hypothesis): invariants on adversarial scalars
# ---------------------------------------------------------------------------

finite_f32 = st.floats(
    allow_nan=False, allow_infinity=False, width=32,
).filter(lambda x: x == 0.0 or 1e-30 < abs(x) < 1e30)


@settings(max_examples=200, deadline=None)
@given(finite_f32, finite_f32)
def test_prop_two_sum_exact(a, b):
    s, r = two_sum(jnp.float32(a), jnp.float32(b))
    assert float(s) + float(r) == float(np.float64(np.float32(a)) + np.float64(np.float32(b)))


@settings(max_examples=200, deadline=None)
@given(finite_f32, finite_f32)
def test_prop_two_prod_exact(a, b):
    p = np.float64(np.float32(a)) * np.float64(np.float32(b))
    if p != 0 and (abs(p) > 3e38 or abs(p) < 1e-25):
        return  # overflow/underflow (incl. subnormal split residues, FTZ)
        # excluded, like the paper §6.1
    x, y = two_prod(jnp.float32(a), jnp.float32(b))
    assert float(x) + float(y) == p


@settings(max_examples=200, deadline=None)
@given(finite_f32)
def test_prop_split_nonoverlap(a):
    hi, lo = split(jnp.float32(a))
    hi, lo = float(hi), float(lo)
    assert hi + lo == float(np.float32(a))
    assert abs(lo) <= abs(hi) or hi == 0.0


@settings(max_examples=100, deadline=None)
@given(finite_f32, finite_f32, finite_f32, finite_f32)
def test_prop_add22_associativity_error(a, b, c, d):
    """FF addition is not associative, but both orders stay within 2^-40 of
    exact — the invariant applications rely on."""
    fa, fb = add12(jnp.float32(a), jnp.float32(b)), add12(jnp.float32(c), jnp.float32(d))
    exact = (np.float64(np.float32(a)) + np.float64(np.float32(b))
             + np.float64(np.float32(c)) + np.float64(np.float32(d)))
    mag = (abs(np.float64(np.float32(a))) + abs(np.float64(np.float32(b)))
           + abs(np.float64(np.float32(c))) + abs(np.float64(np.float32(d))))
    if mag == 0:
        return
    r1 = ff64(add22_accurate(fa, fb))
    assert abs(r1 - exact) / mag < 2.0**-40


