"""Tests for the measurement-driven dispatch tuning subsystem
(``repro.ff.tuning``): cache round-trip (second run hits, no re-timing),
resolve_name/resolve_opts integration, the "tuned" selector names, and the
block_k default alignment that the tuned table papers over."""
import inspect
import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

import repro.ff as ff
from repro.ff import dispatch, tuning


SHAPE = (32, 256, 32)


@pytest.fixture
def tune_cache(tmp_path, monkeypatch):
    """Isolated tuning table: fresh in-memory state, sidecar in tmp_path,
    restored afterwards so other tests see the repo's committed table."""
    path = str(tmp_path / "FF_TUNE.json")
    monkeypatch.setenv(tuning.CACHE_ENV, path)
    tuning.clear()
    yield path
    tuning.clear()


def _tune_once(path, **kw):
    return ff.tune("matmul", shapes=[SHAPE],
                   impls=("hybrid", "compensated", "ozaki"),
                   reps=1, **kw)


def test_tune_roundtrips_through_cache(tune_cache, monkeypatch):
    out = _tune_once(tune_cache)
    assert out["cache"] == tune_cache and os.path.exists(tune_cache)
    key = tuning.bucket_key(SHAPE)
    table = out["table"]
    assert key in table
    rec = table[key]
    assert rec["fast"]["impl"] in ("hybrid", "compensated", "ozaki")
    assert rec["accurate"]["impl"] == "ozaki"
    # the fast winner is never slower than any timed impl
    best_us = min(v["us"] for v in rec["impls"].values())
    assert rec["fast"]["us"] == best_us

    # second run: pure cache hit — re-timing would call _time_candidates
    def boom(*a, **k):
        raise AssertionError("tune() re-timed a cached bucket")

    monkeypatch.setattr(tuning, "_time_candidates", boom)
    out2 = _tune_once(tune_cache)
    assert out2["table"][key]["fast"] == rec["fast"]

    # cold process simulation: drop memory, load from sidecar
    tuning.clear()
    assert tuning.lookup_impl("matmul", SHAPE) == rec["fast"]["impl"]

    # force=True must re-measure (and therefore trip the patched timer)
    with pytest.raises(AssertionError, match="re-timed"):
        _tune_once(tune_cache, force=True)


def test_resolution_consults_tuned_table(tune_cache):
    _tune_once(tune_cache)
    rec = tuning.lookup("matmul", SHAPE)
    # default resolution (no impl anywhere) uses the tuned fast winner
    assert dispatch.resolve_name("matmul", None, shape=SHAPE) == rec["impl"]
    # ... but only when a bucket exists; unknown shapes keep the default
    assert dispatch.resolve_name(
        "matmul", None, shape=(8, 8, 8)) == dispatch.resolve_name("matmul")
    # the special selector names work per-call and in scopes
    assert dispatch.resolve_name("matmul", "tuned", shape=SHAPE) == rec["impl"]
    acc = tuning.lookup("matmul", SHAPE, "accurate")
    assert dispatch.resolve_name(
        "matmul", "tuned_accurate", shape=SHAPE) == acc["impl"]
    with ff.use(matmul="tuned_accurate"):
        assert dispatch.resolve_name(
            "matmul", None, shape=SHAPE) == acc["impl"]
    # explicit per-call impl always beats the table
    assert dispatch.resolve_name("matmul", "dot2", shape=SHAPE) == "dot2"
    # an accurate-tier request on an UNTUNED shape must stay in the
    # accurate tier (static fallback), never degrade to the fast default;
    # "f64" is the backend-portable accurate fallback (native dgemm on
    # CPU/GPU, degrades to the fused Ozaki kernel on TPU)
    assert dispatch.resolve_name(
        "matmul", "tuned_accurate", shape=(8, 8, 8)) == "f64"
    # tuned opts ride along for the winning impl
    opts = dispatch.resolve_opts("matmul", rec["impl"], SHAPE)
    assert opts == rec["opts"]


def test_stale_sidecar_never_breaks_dispatch(tune_cache):
    """A tuned table naming an impl this build doesn't register (renamed
    impl, hand-edited or foreign FF_TUNE.json) must fall through to the
    static default, not brick every plain ff.matmul call with KeyError."""
    backend = ff.backend()
    payload = {"meta": {"backend": backend, "jax": "0", "format": 1},
               "table": {f"{backend}/matmul": {
                   tuning.bucket_key(SHAPE): {
                       "fast": {"impl": "gone_impl", "opts": {}, "us": 1.0},
                       "accurate": {"impl": "gone_impl", "opts": {},
                                    "us": 1.0},
                       "impls": {}}}}}
    with open(tune_cache, "w") as f:
        json.dump(payload, f)
    tuning.clear()
    static_default = dispatch.resolve_name("matmul")
    assert dispatch.resolve_name("matmul", None, shape=SHAPE) == static_default
    assert dispatch.resolve_name("matmul", "tuned", shape=SHAPE) \
        == static_default
    # accurate-tier request degrades to the static accurate fallback
    assert dispatch.resolve_name("matmul", "tuned_accurate", shape=SHAPE) \
        == "f64"


def test_tuned_dispatch_default_not_slower_record(tune_cache):
    """The acceptance property in table form: the tuned default's recorded
    time is within 5% of the fastest impl at equal-or-better accuracy (it
    IS the fastest timed config, so this is exact in the table)."""
    _tune_once(tune_cache)
    rec = tuning.lookup("matmul", SHAPE)
    per = tuning._bucket_store("matmul")[tuning.bucket_key(SHAPE)]["impls"]
    assert rec["us"] <= min(v["us"] for v in per.values()) * 1.05


def test_tuned_matmul_runs_and_matches_explicit(tune_cache, rng):
    _tune_once(tune_cache)
    rec = tuning.lookup("matmul", SHAPE)
    A = jnp.asarray(rng.standard_normal(SHAPE[:2]).astype(np.float32))
    B = jnp.asarray(rng.standard_normal(SHAPE[1:]).astype(np.float32))
    got = ff.matmul(A, B)                       # tuned default
    want = ff.matmul(A, B, impl=rec["impl"], **rec["opts"])
    assert np.array_equal(np.asarray(got.hi), np.asarray(want.hi))
    assert np.array_equal(np.asarray(got.lo), np.asarray(want.lo))


def test_cache_file_carries_backend_metadata(tune_cache):
    _tune_once(tune_cache)
    with open(tune_cache) as f:
        payload = json.load(f)
    assert payload["meta"]["backend"] == ff.backend()
    assert "jax" in payload["meta"]
    assert any(k.startswith(ff.backend() + "/") for k in payload["table"])


def test_tune_elementwise_family(tune_cache):
    """The tune subsystem covers the elementwise/reduction family: winners
    per bucket, resolution integration, and the accurate tier for add."""
    shape = (32, 256)
    out = ff.tune("add", shapes=[shape], reps=1)
    key = tuning.bucket_key(shape)
    rec = out["table"][key]
    assert set(rec["impls"]) >= {"jnp", "accurate"}   # pallas skipped on cpu
    assert rec["fast"]["impl"] in rec["impls"]
    # sloppy Add22 is NOT accurate-tier; the accurate variant is
    assert rec["accurate"]["impl"] == "accurate"
    assert dispatch.resolve_name("add", None, shape=shape) \
        == rec["fast"]["impl"]
    assert dispatch.resolve_name("add", "tuned_accurate", shape=shape) \
        == "accurate"
    # an untuned bucket's accurate-tier request uses the static fallback
    assert dispatch.resolve_name("add", "tuned_accurate", shape=(8, 8)) \
        == "accurate"

    out2 = ff.tune("softmax", shapes=[shape], reps=1)
    rec2 = out2["table"][key]
    assert dispatch.resolve_name("softmax", None, shape=shape) \
        == rec2["fast"]["impl"]
    # composite winners must agree with the default to the last bit — the
    # sweep only covers knobs that cannot change result bits
    import jax.numpy as jnp
    x = jnp.asarray(np.random.default_rng(0)
                    .standard_normal(shape).astype(np.float32))
    got = ff.softmax(x)
    want = ff.softmax(x, impl=rec2["fast"]["impl"], **rec2["fast"]["opts"])
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_sum_tuned_rowsum_winner_never_bricks_other_axes(tune_cache):
    """A pallas_rowsum fast winner (legal on TPU) must not break
    ff.sum(x) / ff.sum(x, axis=0) on that bucket — the impl falls back to
    blocked for axes/ranks the kernel cannot serve."""
    import jax.numpy as jnp
    backend = ff.backend()
    payload = {"meta": {"backend": backend, "jax": "0", "format": 1},
               "table": {f"{backend}/sum": {
                   "32x256": {
                       "fast": {"impl": "pallas_rowsum", "opts": {},
                                "us": 1.0},
                       "impls": {"pallas_rowsum": {"opts": {}, "us": 1.0}}}}}}
    with open(tune_cache, "w") as f:
        json.dump(payload, f)
    tuning.clear()
    x = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((32, 256)).astype(np.float32))
    assert dispatch.resolve_name("sum", None, shape=(32, 256)) \
        == "pallas_rowsum"
    for axis in (None, 0, -1, (0, 1)):
        got = ff.sum(x, axis=axis)
        want = np.asarray(x, np.float64).sum(axis=axis)
        assert np.allclose(np.asarray(got.hi, np.float64)
                           + np.asarray(got.lo, np.float64), want,
                           rtol=1e-7), axis


def test_fast_winner_respects_bit_contract(tune_cache):
    """'cascade' (sum) and 'accurate' (add) are timed but never crowned
    the default-overriding fast winner — a tuned bucket must not change
    the bits of plain ff.sum/ff.add calls."""
    out = ff.tune("sum", shapes=[(32, 256)], reps=1,
                  impls=("blocked", "cascade"))
    assert out["table"]["32x256"]["fast"]["impl"] == "blocked"
    assert "cascade" in out["table"]["32x256"]["impls"]
    out2 = ff.tune("add", shapes=[(16, 128)], reps=1,
                   impls=("jnp", "accurate"))
    assert out2["table"]["16x128"]["fast"]["impl"] == "jnp"
    assert out2["table"]["16x128"]["accurate"]["impl"] == "accurate"
    # no ELIGIBLE impl timed at all -> no fast record is written (the
    # static default keeps its bits), timings still recorded
    out3 = ff.tune("sum", shapes=[(64, 128)], reps=1, impls=("cascade",))
    rec3 = out3["table"]["64x128"]
    assert "fast" not in rec3 and "cascade" in rec3["impls"]
    assert dispatch.resolve_name("sum", None, shape=(64, 128)) \
        == dispatch.resolve_name("sum")


def test_elementwise_buckets_hit_from_nd_shapes(tune_cache):
    """Real call sites are 3-D/4-D; resolution flattens to the same
    (prod(leading), last) bucket the tuner writes, so tuned entries hit."""
    import jax.numpy as jnp
    from repro.ff.autodiff import _bucket2d

    assert _bucket2d((2, 16, 256)) == (32, 256)
    assert _bucket2d((256,)) == (1, 256)
    assert _bucket2d(()) == (1, 1)
    out = ff.tune("softmax", shapes=[(32, 256)], reps=1)
    winner = out["table"]["32x256"]["fast"]["impl"]
    x3 = jnp.asarray(np.random.default_rng(0)
                     .standard_normal((2, 16, 256)).astype(np.float32))
    # the 3-D call resolves through the tuned 2-D bucket (same result
    # either way — sweeps are bit-safe — so assert via resolve_name)
    assert dispatch.resolve_name("softmax", None,
                                 shape=_bucket2d(x3.shape)) == winner
    got = ff.softmax(x3)
    want = ff.softmax(x3, impl=winner)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_tune_unknown_op_raises():
    with pytest.raises(NotImplementedError, match="operand builder"):
        ff.tune("not_an_op", shapes=[(8, 8)])


def test_lookup_opts_detuples_json_lists(tune_cache):
    """Block-shape opts survive the JSON round-trip as tuples (dispatch
    metas are hashable custom_vjp nondiff args)."""
    backend = ff.backend()
    payload = {"meta": {"backend": backend, "jax": "0", "format": 1},
               "table": {f"{backend}/add": {
                   tuning.bucket_key((32, 256)): {
                       "fast": {"impl": "pallas", "opts": {"block": [256, 512]},
                                "us": 1.0},
                       "impls": {"pallas": {"opts": {"block": [256, 512]},
                                            "us": 1.0}}}}}}
    with open(tune_cache, "w") as f:
        json.dump(payload, f)
    tuning.clear()
    opts = tuning.lookup_opts("add", "pallas", (32, 256))
    assert opts == {"block": (256, 512)}
    assert isinstance(opts["block"], tuple)
    # and the full resolution path stays hashable end-to-end
    a = ff.from_f64(np.pi)
    b = ff.from_f64(np.e)
    got = ff.add(a, b)          # default resolves to the tuned pallas row
    assert np.isfinite(float(got.hi))


def test_block_k_defaults_aligned():
    """PrecisionPolicy.ff_matmul_block_k must equal the kernel and jnp path
    defaults — the divergence class behind dispatch_default being slower
    than the very impl it resolves to."""
    from repro.core.policy import PrecisionPolicy
    from repro.core import ffmatmul
    from repro.kernels import ff_matmul as kmm

    pol = PrecisionPolicy().ff_matmul_block_k
    jnp_default = inspect.signature(
        ffmatmul.matmul_compensated).parameters["block_k"].default
    kernel_default = inspect.signature(
        kmm.ff_matmul).parameters["bk"].default
    hybrid_default = inspect.signature(
        dispatch.lookup("matmul", "hybrid")).parameters["block_k"].default
    assert pol == jnp_default == kernel_default == hybrid_default == 512
