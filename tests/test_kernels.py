"""Per-kernel validation: interpret=True execution vs ref.py oracles vs the
exact f64 oracle, swept over shapes (ragged, aligned, tiny, large)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.ff as ff
from repro.core.ff import FF
from repro.kernels import ops, ref
from conftest import f32_vec


def _f64(x):
    return np.asarray(x).astype(np.float64)


def ff64(x: FF):
    return _f64(x.hi) + _f64(x.lo)


SHAPES = [(1,), (7,), (128,), (8, 128), (3, 130), (256, 512), (2, 3, 65), (513, 257)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("op", ["add22", "mul22"])
def test_elementwise_kernel_vs_ref(rng, shape, op):
    n = int(np.prod(shape))
    ah = f32_vec(rng, n, -3, 3).reshape(shape)
    al = (ah * 1e-8 * rng.standard_normal(n).reshape(shape)).astype(np.float32)
    bh = f32_vec(rng, n, -3, 3).reshape(shape)
    bl = (bh * 1e-8 * rng.standard_normal(n).reshape(shape)).astype(np.float32)
    a, b = FF(jnp.asarray(ah), jnp.asarray(al)), FF(jnp.asarray(bh), jnp.asarray(bl))
    got = ops.ff_add(a, b, interpret=True) if op == "add22" else ops.ff_mul(a, b, interpret=True)
    ref_fn = ref.ref_add22 if op == "add22" else ref.ref_mul22
    want_hi, want_lo = ref_fn(a.hi, a.lo, b.hi, b.lo)
    # identical algorithm & order -> bit-exact
    assert np.array_equal(np.asarray(got.hi), np.asarray(want_hi)), (op, shape)
    assert np.array_equal(np.asarray(got.lo), np.asarray(want_lo)), (op, shape)
    # and correct vs f64
    ea = _f64(ah) + _f64(al)
    eb = _f64(bh) + _f64(bl)
    exact = ea + eb if op == "add22" else ea * eb
    err = np.abs(ff64(got) - exact)
    mag = np.abs(ea) + np.abs(eb) if op == "add22" else np.abs(exact)
    assert (err / np.maximum(mag, 1e-300)).max() < 2.0**-40


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("op", ["two_sum", "two_prod"])
def test_eft_kernels_exact(rng, shape, op):
    n = int(np.prod(shape))
    a = f32_vec(rng, n, -5, 5).reshape(shape)
    b = f32_vec(rng, n, -5, 5).reshape(shape)
    fn = ops.two_sum if op == "two_sum" else ops.two_prod
    got = fn(jnp.asarray(a), jnp.asarray(b), interpret=True)
    exact = _f64(a) + _f64(b) if op == "two_sum" else _f64(a) * _f64(b)
    assert np.array_equal(ff64(got), exact), (op, shape)


MM_SHAPES = [
    (8, 16, 8), (128, 128, 128), (100, 300, 50), (256, 1024, 128),
    (1, 2048, 1), (257, 513, 129),
]


@pytest.mark.parametrize("mkn", MM_SHAPES)
def test_ff_matmul_hybrid_vs_ref(rng, mkn):
    M, K, N = mkn
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    got = ops.matmul(jnp.asarray(A), jnp.asarray(B), interpret=True)
    # oracle with identical K-block order (bk=512 default, incl. padding)
    want_hi, want_lo = ref.ref_ff_matmul(jnp.asarray(A), jnp.asarray(B), bk=512)
    E = _f64(A) @ _f64(B)
    S = np.abs(_f64(A)) @ np.abs(_f64(B))
    u = 2.0**-24
    assert np.all(np.abs(ff64(got) - E) <= 2 * K * u * S + 1e-30)
    # kernel vs ref: same block order -> tight agreement
    ref64 = _f64(want_hi) + _f64(want_lo)
    assert np.all(np.abs(ff64(got) - ref64) <= 2.0**-44 * S + 1e-30)


@pytest.mark.parametrize("mkn", [(8, 16, 8), (32, 128, 16), (100, 300, 50),
                                 (64, 1100, 8), (17, 100, 5)])
@pytest.mark.parametrize("slices", [0, 5])
def test_ff_matmul_ozaki_kernel_vs_oracle(rng, mkn, slices):
    """Fused Ozaki-slice kernel (slice-pair innermost grid dim, scalar-
    prefetch pair tables): paper-quality accuracy on every shape class —
    ragged, K spanning multiple bk-blocks (K=1100 > bk=512 exercises the
    FF cross-block accumulation), and slices=5 exercises pair skipping."""
    from repro.kernels import ff_matmul as kmm
    M, K, N = mkn
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    hi, lo = kmm.ff_matmul_ozaki(jnp.asarray(A), jnp.asarray(B),
                                 slices=slices, interpret=True)
    E = _f64(A) @ _f64(B)
    S = np.abs(_f64(A)) @ np.abs(_f64(B))
    got = _f64(hi) + _f64(lo)
    assert np.all(np.abs(got - E) <= 2.0**-42 * S + 1e-30), mkn
    # and it agrees with the jnp batched-GEMM path to accurate-tier level
    want = ff.matmul(jnp.asarray(A), jnp.asarray(B), impl="ozaki",
                     slices=slices)
    assert np.all(np.abs(got - want.to_f64()) <= 2.0**-42 * S + 1e-30), mkn


@pytest.mark.parametrize("mkn", [(8, 16, 8), (32, 128, 16), (64, 256, 8), (17, 100, 5)])
def test_ff_matmul_dot2_vs_ref(rng, mkn):
    M, K, N = mkn
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    got = ops.matmul_dot2(jnp.asarray(A), jnp.asarray(B), interpret=True)
    E = _f64(A) @ _f64(B)
    S = np.abs(_f64(A)) @ np.abs(_f64(B))
    u = 2.0**-24
    assert np.all(np.abs(ff64(got) - E) <= u * np.abs(E) + 2 * K * K * u * u * S)
    want_hi, want_lo = ref.ref_ff_matmul_dot2(jnp.asarray(A), jnp.asarray(B))
    ref64 = _f64(want_hi) + _f64(want_lo)
    assert np.all(np.abs(ff64(got) - ref64) <= 2.0**-44 * S + 1e-30)


@pytest.mark.parametrize("shape", [(4, 128), (16, 1000), (256, 512), (3, 4096), (1, 64)])
def test_ff_rowsum_vs_ref_and_oracle(rng, shape):
    R, C = shape
    x = f32_vec(rng, R * C, -4, 4).reshape(R, C)
    got = ops.rowsum(jnp.asarray(x), interpret=True)
    exact = np.sum(_f64(x), axis=1)
    s_abs = np.sum(np.abs(_f64(x)), axis=1)
    assert np.all(np.abs(ff64(got) - exact) <= 2.0**-40 * s_abs)
    want_hi, want_lo = ref.ref_ff_rowsum(jnp.asarray(x))
    ref64 = _f64(want_hi) + _f64(want_lo)
    assert np.all(np.abs(ff64(got) - ref64) <= 2.0**-44 * s_abs + 1e-30)


def test_kernel_beats_naive_sum(rng):
    """The FF rowsum must beat a plain f32 sum on an adversarial vector."""
    x = np.concatenate([[1e8], np.full(65536, 0.11, np.float32), [-1e8]]).astype(np.float32)
    x = x.reshape(1, -1)
    exact = np.sum(_f64(x))
    got = float(ff64(ops.rowsum(jnp.asarray(x), interpret=True))[0])
    naive = float(np.float32(np.asarray(jnp.sum(jnp.asarray(x)))))
    assert abs(got - exact) < abs(naive - exact) / 100


def test_matmul_grad_flow(rng):
    """Kernels are used in inference/optimizer paths (no custom VJP); the
    wrapper must still be jittable inside larger graphs."""
    A = jnp.asarray(rng.standard_normal((16, 32)).astype(np.float32))
    B = jnp.asarray(rng.standard_normal((32, 8)).astype(np.float32))

    @jax.jit
    def f(a, b):
        r = ops.matmul(a, b, interpret=True)
        return r.hi.sum() + r.lo.sum()

    assert np.isfinite(float(f(A, B)))
