"""Fused FF expression pipelines: equivalence and composite-kernel tests.

The contract (ISSUE 3): every fused chain and composite kernel must be
bitwise-identical to the op-by-op dispatch result — or within 1 ulp with a
documented reason — in both interpret (Pallas) and compiled (jnp-executor
under jit) modes.  The two documented 1-ulp classes are:

  * reduction outputs: the fused kernels use the lane-parallel Neumaier
    cascade of ``ff_reduce`` while the op-by-op reference uses
    ``ff_sum_blocked``'s scan — both are accurate to ~2^-40 relative, so
    the two f32-rounded results can differ by at most the final ulp;
  * composites whose denominator/stat feeds further f32 ops (softmax,
    norm_stats variance): the <=1-ulp reduction difference propagates
    through one more rounding, giving <=2 ulp on the output.

Comparisons are made in the SAME compilation mode on both sides: eager and
jitted XLA already differ by ~1 ulp through f32 div/sqrt chains for any
program (the backend rewrites e.g. x/sqrt(y) under jit), which has nothing
to do with fusion.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.ff as ff
from repro.core import compensated
from repro.core.ff import FF
from repro.ff import dispatch, fusion

from conftest import f32_vec


def _f64(x):
    return np.asarray(x).astype(np.float64)


def ff64(x: FF):
    return _f64(x.hi) + _f64(x.lo)


def _rand_ff(rng, shape, lo=-3, hi=3):
    n = int(np.prod(shape))
    h = f32_vec(rng, n, lo, hi).reshape(shape)
    l = (h * 1e-8 * rng.standard_normal(shape)).astype(np.float32)
    return FF(jnp.asarray(h), jnp.asarray(l))


def _assert_bitwise(a, b, what=""):
    if isinstance(a, FF):
        assert np.array_equal(np.asarray(a.hi), np.asarray(b.hi)), what
        assert np.array_equal(np.asarray(a.lo), np.asarray(b.lo)), what
    else:
        assert np.array_equal(np.asarray(a), np.asarray(b)), what


def _assert_ulp(a, b, tol, what=""):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    ulp = np.abs(a - b) / np.spacing(np.maximum(np.abs(b),
                                                np.float32(1e-30)))
    assert ulp.max() <= tol, (what, float(ulp.max()))


# ---------------------------------------------------------------------------
# generic fused chains vs op-by-op dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 128), (17, 300), (3, 130), (64,)])
def test_fused_chain_bitwise_both_modes(rng, shape):
    """A mixed FF/f32 chain (mul212/add22/div22/sqrt22 + f32 ops): the
    jnp executor replays the exact op-by-op graph (bitwise under jit) and
    the Pallas interpret executor evaluates the same EFT sequences."""
    x = _rand_ff(rng, shape)
    y = _rand_ff(rng, shape)
    s = jnp.float32(1.618)

    @ff.fused
    def chain(x, y, s):
        t = s * x + y                 # mul212, add22
        u = t * t                     # mul22
        return u / (y * y + 1.0), t   # mul22, add212, div22

    def op_by_op(x, y, s):
        t = ff.add(ff.mul(x, s), y)
        u = ff.mul(t, t)
        return ff.div(u, ff.add(ff.mul(y, y), jnp.float32(1.0))), t

    want = jax.jit(op_by_op)(x, y, s)
    got_jnp = jax.jit(lambda *a: chain(*a))(x, y, s)
    got_pal = jax.jit(lambda *a: chain(*a, interpret=True))(x, y, s)
    for g1, g2, w in zip(got_jnp, got_pal, want):
        _assert_bitwise(g1, w, "jnp executor vs op-by-op")
        _assert_bitwise(g2, w, "pallas executor vs op-by-op")


def test_fused_broadcast_and_scalars(rng):
    row = jnp.asarray(rng.standard_normal((1, 200)).astype(np.float32))
    col = jnp.asarray(rng.standard_normal((64, 1)).astype(np.float32))

    @ff.fused
    def chain(r, c, s):
        return r * c + s

    for interpret in (False, True):
        out = chain(row, col, 2.5, interpret=interpret)
        assert out.shape == (64, 200)
        ref = jax.jit(lambda r, c: r * c + 2.5)(row, col)
        _assert_bitwise(out, np.asarray(ref), f"interpret={interpret}")


def test_fused_rowsum_reduction(rng):
    """Trailing rowsum: jnp executor is bitwise ff.sum(block=128); the
    Pallas cascade is within the documented final ulp, and both are
    ~2^-40 vs the exact sum of the f32 squares."""
    x = jnp.asarray(f32_vec(rng, 5 * 1000, -4, 4).reshape(5, 1000))

    @ff.fused
    def msq(x):
        return (x * x).sum()

    want = jax.jit(lambda x: compensated.ff_sum_blocked(
        x * x, axis=-1, block=128))(x)
    got = jax.jit(lambda x: msq(x))(x)
    _assert_bitwise(got, want, "jnp rowsum vs ff_sum_blocked")

    got_pal = msq(x, interpret=True)
    _assert_ulp(got_pal.hi, want.hi, 1, "pallas rowsum hi")
    # oracle: exact sum of the f32 SQUARES (the chain squares in f32, as
    # the op-by-op path does — the reduction is what must be compensated)
    q = np.asarray(jnp.asarray(x) * jnp.asarray(x), np.float64)
    exact = q.sum(axis=1)
    for g in (got, got_pal):
        rel = np.abs(ff64(g) - exact) / np.abs(exact)
        assert rel.max() < 2.0 ** -40


def test_fused_rowsum_masks_padding(rng):
    """A chain that is NONZERO on padded columns (x + 1) must still reduce
    exactly over the true columns — the kernel masks before accumulating."""
    x = jnp.asarray(f32_vec(rng, 3 * 130, -2, 2).reshape(3, 130))

    @ff.fused
    def s1(x):
        return (x + 1.0).sum()

    got = s1(x, interpret=True)
    # oracle: exact sum of the f32 values of x+1 (per-element f32
    # rounding belongs to the chain, not the reduction)
    xp1 = np.asarray(jnp.asarray(x) + jnp.float32(1.0), np.float64)
    exact = xp1.sum(axis=1)
    mag = np.abs(xp1).sum(axis=1)
    assert (np.abs(ff64(got) - exact) / np.maximum(mag, 1e-30)).max() \
        < 2.0 ** -40


def test_fused_vmem_budget_blocks():
    """Deeper chains get smaller tiles, never a budget blowout."""
    from repro.kernels.ff_fused import VMEM_BUDGET_BYTES, _pick_block
    shallow = _pick_block(4, 4096, 4096)
    deep = _pick_block(64, 4096, 4096)
    assert shallow[0] * shallow[1] >= deep[0] * deep[1]
    assert 64 * deep[0] * deep[1] * 4 <= VMEM_BUDGET_BYTES
    assert deep[0] % 8 == 0 and deep[1] % 128 == 0


def test_fused_output_shapes_match_jnp_executor(rng):
    """An output that depends on a SUBSET of operands must come back with
    the same (narrower) shape from both executors — the Pallas executor
    un-broadcasts each output to its inferred ND shape."""
    x = jnp.asarray(rng.standard_normal((4,)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((3, 4)).astype(np.float32))
    col = jnp.asarray(rng.standard_normal((3, 1)).astype(np.float32))

    @ff.fused
    def chain(x, y, c):
        return x + 1.0, x * y, c.sum()

    o_jnp = chain(x, y, col)
    o_pal = chain(x, y, col, interpret=True)
    assert o_jnp[0].shape == o_pal[0].shape == (4,)
    assert o_jnp[1].shape == o_pal[1].shape == (3, 4)
    # rowsum of a column-broadcast value reduces ITS one true column,
    # not C copies of it
    assert o_jnp[2].shape == o_pal[2].shape == (3,)
    _assert_bitwise(o_pal[0], o_jnp[0], "narrow f32 out")
    _assert_bitwise(o_pal[1], o_jnp[1], "full f32 out")
    _assert_ulp(o_pal[2].hi, o_jnp[2].hi, 1, "degenerate rowsum")
    assert np.allclose(ff64(o_pal[2]), np.asarray(col)[:, 0], atol=1e-7)


def test_fused_sub_emits_fsub(rng):
    """f32 subtraction lowers to a real fsub instruction (live in both
    executors) and matches jnp bitwise."""
    a = jnp.asarray(rng.standard_normal((8, 64)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((8, 64)).astype(np.float32))

    fn = ff.fused(lambda a, b: (a - b, 1.0 - b))
    prog = fn.program(a, b)
    assert any(i.op == "fsub" for i in prog.instrs)
    for interpret in (False, True):
        o1, o2 = fn(a, b, interpret=interpret)
        _assert_bitwise(o1, np.asarray(a - b), "a-b")
        _assert_bitwise(o2, np.asarray(1.0 - b), "1-b")


def test_tracer_guards():
    with pytest.raises(ValueError, match="trailing"):
        fusion.trace(lambda x: x.sum() + 1.0, ("f32",))
    with pytest.raises(TypeError, match="f32-valued"):
        fusion.trace(lambda x: x.sum(), ("ff",))
    with pytest.raises(TypeError):
        fusion.trace(lambda x: 3.0, ("f32",))


# ---------------------------------------------------------------------------
# composite kernels vs the op-by-op dispatch formulations
# ---------------------------------------------------------------------------

def _adamw_args(rng, shape):
    mk = lambda s=1.0: jnp.asarray(
        (rng.standard_normal(shape) * s).astype(np.float32))
    g, m, w = mk(), mk(0.1), mk()
    v = jnp.abs(mk(0.01))
    wlo = mk(1e-8)
    scal = tuple(jnp.float32(z) for z in (1e-3, 0.9, 0.95, 0.1, 0.05))
    return (g, m, v, w, wlo) + scal


@pytest.mark.parametrize("fused_impl,interpret", [("fused", False),
                                                  ("fused", True)])
def test_adamw_update_fused_bitwise(rng, fused_impl, interpret):
    """The fused AdamW chain is bitwise the jnp op-by-op chain in both
    executor modes (pure elementwise: no reduction, no ulp allowance)."""
    args = _adamw_args(rng, (33, 257))
    kw = dict(eps=1e-8, wd=0.1)
    ref = jax.jit(lambda *a: ff.adamw_update(*a, impl="jnp", **kw))(*args)
    got = jax.jit(lambda *a: ff.adamw_update(*a, impl=fused_impl,
                                             interpret=interpret,
                                             **kw))(*args)
    for r, g2 in zip(ref, got):
        _assert_bitwise(g2, r, f"adamw {fused_impl} interpret={interpret}")


def test_adamw_optimizer_matches_pre_fusion_formulation(rng):
    """optim.AdamW(ff=True) through the composite == the pre-fusion leaf
    written out op-by-op, bitwise (same jit)."""
    from repro.optim.adamw import AdamW

    shape = (13, 40)
    params = {"w": jnp.asarray(rng.standard_normal(shape).astype(np.float32))}
    grads = {"w": jnp.asarray(rng.standard_normal(shape).astype(np.float32))}
    opt = AdamW(learning_rate=1e-3, ff=True)
    state = opt.init(params)

    @jax.jit
    def step(g, s, p):
        return opt.update(g, s, p)

    new_p, new_s = step(grads, state, params)

    def reference(g, m, v, w, wlo, c):
        b1, b2 = jnp.float32(0.9), jnp.float32(0.95)
        lr = jnp.float32(1e-3)
        bc1 = 1.0 - b1 ** c.astype(jnp.float32)
        bc2 = 1.0 - b2 ** c.astype(jnp.float32)
        m2 = b1 * m + (1.0 - b1) * g
        v2 = b2 * v + (1.0 - b2) * g * g
        upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + 1e-8)
        upd = upd + 0.1 * w
        delta = (-lr * upd).astype(jnp.float32)
        new = ff.add(FF(w, wlo), delta)
        return new.hi, new.lo, m2, v2

    ref = jax.jit(reference)(grads["w"], state.m["w"], state.v["w"],
                             params["w"], state.master_lo["w"],
                             state.count + 1)
    assert np.array_equal(np.asarray(new_p["w"]), np.asarray(ref[0]))
    assert np.array_equal(np.asarray(new_s.master_lo["w"]),
                          np.asarray(ref[1]))
    assert np.array_equal(np.asarray(new_s.m["w"]), np.asarray(ref[2]))
    assert np.array_equal(np.asarray(new_s.v["w"]), np.asarray(ref[3]))


def _softmax_ref(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    s = compensated.ff_sum_blocked(e, axis=-1, block=256)
    return e / s.to_f32()[..., None]


@pytest.mark.parametrize("impl", ["jnp", "f64", "pallas"])
def test_softmax_impls_vs_op_by_op(rng, impl):
    x = jnp.asarray(rng.standard_normal((37, 300)).astype(np.float32))
    want = jax.jit(_softmax_ref)(x)
    got = jax.jit(lambda x: ff.softmax(x, impl=impl))(x)
    # denominator is a <=1-ulp-different compensated sum -> <=2 ulp out
    tol = 0 if impl == "jnp" else 2
    _assert_ulp(got, want, tol, f"softmax {impl}")
    # and correct vs the f64 oracle
    x64 = _f64(x)
    e = np.exp(x64 - x64.max(axis=-1, keepdims=True))
    oracle = e / e.sum(axis=-1, keepdims=True)
    assert np.abs(np.asarray(got, np.float64) - oracle).max() < 1e-6


@pytest.mark.parametrize("impl", ["jnp", "f64", "pallas"])
def test_logsumexp_impls_vs_op_by_op(rng, impl):
    x = jnp.asarray(rng.standard_normal((37, 300)).astype(np.float32))

    def ref(x):
        m = jnp.max(x, axis=-1, keepdims=True)
        e = jnp.exp(x - m)
        s = compensated.ff_sum_blocked(e, axis=-1, block=256)
        return jnp.squeeze(m, -1) + jnp.log(s.to_f32())

    want = jax.jit(ref)(x)
    got = jax.jit(lambda x: ff.logsumexp(x, impl=impl))(x)
    tol = 0 if impl == "jnp" else 1
    _assert_ulp(got, want, tol, f"logsumexp {impl}")
    x64 = _f64(x)
    oracle = np.log(np.exp(x64 - x64.max(-1, keepdims=True)
                           ).sum(-1)) + x64.max(-1)
    assert np.abs(np.asarray(got, np.float64) - oracle).max() < 1e-5


@pytest.mark.parametrize("impl", ["jnp", "fused"])
def test_mean_sq_impls_vs_op_by_op(rng, impl):
    x = jnp.asarray(f32_vec(rng, 16 * 700, -3, 3).reshape(16, 700))
    want = jax.jit(lambda x: compensated.ff_sum_blocked(
        x * x, axis=-1, block=128).to_f32() / 700)(x)
    got = jax.jit(lambda x: ff.mean_sq(x, impl=impl))(x)
    _assert_bitwise(got, np.asarray(want), f"mean_sq {impl}")
    # interpret-mode fused kernel: documented final-ulp allowance
    got_i = ff.mean_sq(x, impl="fused", interpret=True)
    _assert_ulp(got_i, want, 1, "mean_sq fused interpret")


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_norm_stats_impls_vs_op_by_op(rng, impl):
    x = jnp.asarray(rng.standard_normal((21, 500)).astype(np.float32))

    def ref(x):
        mu = compensated.ff_sum_blocked(x, axis=-1, block=128).to_f32() / 500
        var = compensated.ff_sum_blocked(
            (x - mu[..., None]) ** 2, axis=-1, block=128).to_f32() / 500
        return mu, var

    want_mu, want_var = jax.jit(ref)(x)
    got_mu, got_var = jax.jit(lambda x: ff.norm_stats(x, impl=impl))(x)
    tol_mu = 0 if impl == "jnp" else 1
    tol_var = 0 if impl == "jnp" else 2   # mu's ulp feeds the square pass
    _assert_ulp(got_mu, want_mu, tol_mu, f"norm_stats mu {impl}")
    _assert_ulp(got_var, want_var, tol_var, f"norm_stats var {impl}")
    x64 = _f64(x)
    assert np.abs(np.asarray(got_mu) - x64.mean(-1)).max() < 1e-6
    assert np.abs(np.asarray(got_var) - x64.var(-1)).max() < 1e-6


def test_composite_grads(rng):
    """Custom vjps of the composite wrappers vs analytic f64 gradients."""
    x = jnp.asarray(rng.standard_normal((5, 64)).astype(np.float32))
    x64 = _f64(x)

    g_ms = jax.grad(lambda t: ff.mean_sq(t).sum())(x)
    assert np.allclose(np.asarray(g_ms), 2 * x64 / 64, atol=1e-6)

    g_sm = jax.grad(lambda t: (ff.softmax(t) ** 2).sum())(x)
    e = np.exp(x64 - x64.max(-1, keepdims=True))
    y = e / e.sum(-1, keepdims=True)
    gy = 2 * y
    want = (gy - (gy * y).sum(-1, keepdims=True)) * y
    assert np.allclose(np.asarray(g_sm), want, atol=1e-5)

    g_ns = jax.grad(lambda t: ff.norm_stats(t)[1].sum())(x)
    mu = x64.mean(-1, keepdims=True)
    assert np.allclose(np.asarray(g_ns), 2 * (x64 - mu) / 64, atol=1e-6)


def test_rms_layer_norm_use_composites(rng):
    """models.layers ff_stats paths route through the composites and stay
    numerically indistinguishable from the pre-migration formulations."""
    from repro.models.layers import layer_norm, rms_norm

    x = jnp.asarray(rng.standard_normal((4, 9, 256)).astype(np.float32))
    w = jnp.ones((256,), jnp.float32)
    b = jnp.zeros((256,), jnp.float32)

    got = rms_norm(x, w, 1e-6, ff_stats=True)
    ms = compensated.ff_sum_blocked(x * x, axis=-1,
                                    block=128).to_f32() / 256
    want = x * jax.lax.rsqrt(ms + 1e-6)[..., None] * w
    assert np.allclose(np.asarray(got), np.asarray(want), atol=1e-7)

    got_ln = layer_norm(x, w, b, 1e-6, ff_stats=True)
    mu = compensated.ff_sum_blocked(x, axis=-1, block=128).to_f32() / 256
    var = compensated.ff_sum_blocked(
        (x - mu[..., None]) ** 2, axis=-1, block=128).to_f32() / 256
    want_ln = (x - mu[..., None]) * jax.lax.rsqrt(var[..., None] + 1e-6)
    assert np.allclose(np.asarray(got_ln), np.asarray(want_ln), atol=1e-6)


def test_logsumexp_registration_per_backend():
    """Satellite: logsumexp resolves per-backend like every other op —
    jnp is the generic fallback, the fused Pallas kernel is the TPU
    default, the native-f64 reduction the CPU default."""
    d = dispatch._DEFAULTS["logsumexp"]
    assert d["*"] == "jnp" and d["tpu"] == "pallas" and d["cpu"] == "f64"
    assert set(d) >= {"*", "tpu", "cpu"}
    for b, want in (("tpu", "pallas"), ("cpu", "f64")):
        orig = dispatch.backend
        try:
            dispatch.backend = lambda b=b: b
            assert dispatch.resolve_name("logsumexp") == want
        finally:
            dispatch.backend = orig
    # softmax and the composites follow the same pattern
    assert dispatch._DEFAULTS["softmax"]["tpu"] == "pallas"
    assert dispatch._DEFAULTS["adamw_update"]["tpu"] == "fused"
    assert dispatch._DEFAULTS["norm_stats"]["tpu"] == "pallas"
    assert dispatch._DEFAULTS["mean_sq"]["tpu"] == "fused"


def test_long_row_falls_back_to_jnp(rng, monkeypatch):
    """Rows beyond the VMEM whole-row budget must not brick the default."""
    from repro.kernels import ff_fused
    monkeypatch.setattr(ff_fused, "MAX_FUSED_COLS", 128)
    x = jnp.asarray(rng.standard_normal((4, 300)).astype(np.float32))
    # ... but never SILENTLY: an explicit impl= request must hear about it
    with pytest.warns(UserWarning, match="falling back"):
        got = ff.softmax(x, impl="pallas")
    want = jax.jit(_softmax_ref)(x)
    _assert_ulp(got, want, 2, "fallback softmax")


# ---------------------------------------------------------------------------
# elementwise kernel shape handling (satellite: broadcasting + alignment)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sa,sb", [((17, 200), (1, 200)),
                                   ((17, 200), (17, 1)),
                                   ((17, 200), ()),
                                   ((3, 130), (3, 130)),
                                   ((4, 3, 65), (3, 65)),
                                   ((5,), (5,))])
def test_elementwise_kernel_broadcasting(rng, sa, sb):
    from repro.kernels import ff_elementwise as fe
    na, nb = int(np.prod(sa or (1,))), int(np.prod(sb or (1,)))
    a = f32_vec(rng, na, -2, 2).reshape(sa)
    b = f32_vec(rng, nb, -2, 2).reshape(sb)
    rh, rl = fe.elementwise("add22", a, np.zeros_like(a), b,
                            np.zeros_like(b), interpret=True)
    want = _f64(a) + _f64(b)
    assert rh.shape == want.shape
    got = _f64(rh) + _f64(rl)
    assert np.abs(got - want).max() <= 2.0 ** -40 * np.abs(want).max() + 1e-30


def test_elementwise_block_alignment():
    """Row blocks are rounded up to the 8-sublane multiple and column
    blocks to the 128-lane multiple (never a ragged (3, 130) block)."""
    from repro.kernels.ff_elementwise import pick_block
    assert pick_block(3, 130) == (8, 256)
    assert pick_block(1000, 1000, (256, 512)) == (256, 512)
    assert pick_block(4, 4) == (8, 128)
    br, bc = pick_block(300, 700, (100, 200))
    assert br % 8 == 0 and bc % 128 == 0
