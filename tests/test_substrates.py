"""Substrate tests: FF-master-weight optimizer (the paper's key systems
win), checkpoint/restart fault tolerance, data determinism, trainer loop."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim.adamw import AdamW, cosine_schedule, clip_by_global_norm
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.checkpoint import checkpoint as ckpt
from repro.train.trainer import Trainer, TrainerConfig
from repro.train.train_step import make_train_step
from repro.core.policy import PrecisionPolicy
from repro.models import init_params
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_ff_master_weights_beat_f32_stagnation():
    """THE paper-value test: with per-step updates ~2^-26 relative, plain
    f32 master weights stagnate (update < half-ulp rounds to nothing);
    FF master weights accumulate them exactly."""
    w0 = jnp.full((128,), 1.0, jnp.float32)
    params = {"w": w0}
    # constant tiny gradient; lr such that delta ~ 1e-9 (way below f32 ulp of 1.0)
    g = {"w": jnp.full((128,), 1.0, jnp.float32)}
    for ff in (False, True):
        opt = AdamW(learning_rate=1e-9, b1=0.0, b2=0.0, eps=1e-30,
                    weight_decay=0.0, ff=ff)
        state = opt.init(params)
        p = params
        step = jax.jit(lambda pr, st: opt.update(g, st, pr))
        for _ in range(1000):
            p, state = step(p, state)
        if ff:
            # true value via hi+lo
            total = (np.asarray(p["w"], np.float64)
                     + np.asarray(state.master_lo["w"], np.float64))
            drift = np.abs(total - (1.0 - 1e-9 * 1000))
            assert drift.max() < 1e-10, "FF master should track 1000 sub-ulp steps"
        else:
            assert float(jnp.max(jnp.abs(p["w"] - 1.0))) == 0.0, \
                "f32 master should stagnate (documents the failure FF fixes)"


def test_adamw_descends():
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (16, 16))
    x0 = {"x": jnp.zeros((16,))}
    target = jax.random.normal(jax.random.PRNGKey(1), (16,))

    def loss(p):
        return jnp.sum((A @ p["x"] - target) ** 2)

    opt = AdamW(learning_rate=1e-2, weight_decay=0.0, ff=True)
    state = opt.init(x0)
    p = x0
    l0 = float(loss(p))
    grad_fn = jax.jit(jax.grad(loss))
    update = jax.jit(opt.update)
    # 500 steps: Adam at lr=1e-2 covers the ~2.0 distance to the optimum
    # with margin (200 was never enough — this test predates the suite
    # actually collecting; see the hypothesis import guard)
    for _ in range(500):
        g = grad_fn(p)
        p, state = update(g, state, p)
    assert float(loss(p)) < l0 * 0.01


def test_cosine_schedule_and_clip():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.int32(100))) <= 1e-3 * 0.11
    g = {"a": jnp.full((10,), 100.0)}
    gc, n = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(gc["a"])) - 1.0) < 1e-5


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab_size=64, seq_len=32, global_batch=8)
    a = SyntheticLM(cfg, host_id=0, num_hosts=2)
    b = SyntheticLM(cfg, host_id=1, num_hosts=2)
    a2 = SyntheticLM(cfg, host_id=0, num_hosts=2)
    x1, x2 = a.batch(7), a2.batch(7)
    assert np.array_equal(x1["tokens"], x2["tokens"])        # deterministic
    assert not np.array_equal(a.batch(7)["tokens"], b.batch(7)["tokens"])
    assert a.batch(0)["tokens"].shape == (4, 32)              # host split
    # targets are next-token shifted
    cfgs = DataConfig(vocab_size=64, seq_len=32, global_batch=2)
    s = SyntheticLM(cfgs)
    bt = s.batch(3)
    assert bt["tokens"].shape == bt["targets"].shape
    # structure is learnable: successor transitions appear
    frac = np.mean(bt["targets"][:, :-1] == bt["tokens"][:, 1:])
    assert frac > 0.99  # targets literally are shifted tokens


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16)},
            "n": jnp.int32(7)}
    ckpt.save(str(tmp_path), 5, tree, extra={"foo": 1})
    out, step, extra = ckpt.load(str(tmp_path), tree)
    assert step == 5 and extra == {"foo": 1}
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_atomic_and_retention(tmp_path):
    tree = {"a": jnp.zeros((4,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree)
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000003", "step_00000004", "step_00000005"]
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_async_checkpointer(tmp_path):
    c = ckpt.AsyncCheckpointer(str(tmp_path))
    tree = {"w": jnp.ones((64,))}
    c.save(1, tree)
    c.save(2, tree)   # waits for 1
    c.wait()
    assert ckpt.latest_step(str(tmp_path)) == 2


# ---------------------------------------------------------------------------
# trainer: fault tolerance + straggler detection + resume determinism
# ---------------------------------------------------------------------------

def _tiny_setup(tmp_path, total_steps=12, ckpt_every=4):
    cfg = ModelConfig(name="tiny", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16,
                      max_seq_len=64, attn_block_q=32, attn_block_kv=32,
                      compute_dtype="float32", remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=1e-3, ff=True)
    opt_state = opt.init(params)
    policy = PrecisionPolicy.make("ff_master")
    step_fn = jax.jit(make_train_step(cfg, policy, opt))
    data = SyntheticLM(DataConfig(vocab_size=128, seq_len=32, global_batch=4))

    def data_iter(i):
        b = data.batch(i)
        return {k: jnp.asarray(v) for k, v in b.items()}

    tcfg = TrainerConfig(total_steps=total_steps, ckpt_every=ckpt_every,
                         ckpt_dir=str(tmp_path), log_every=1000)
    return cfg, params, opt_state, step_fn, data_iter, tcfg


def test_trainer_fault_and_resume(tmp_path):
    cfg, params, opt_state, step_fn, data_iter, tcfg = _tiny_setup(tmp_path)

    # run A: crash at step 7 (after a checkpoint at 4)
    class Boom(RuntimeError):
        pass

    def fault(step):
        if step == 7:
            raise Boom()

    t1 = Trainer(tcfg, step_fn, params, opt_state, data_iter,
                 fault_hook=fault, log_fn=lambda s: None)
    with pytest.raises(Boom):
        t1.run()
    t1.ckpt.wait()
    assert ckpt.latest_step(str(tmp_path)) == 4

    # run B: fresh process state, auto-resume, finish
    t2 = Trainer(tcfg, step_fn, params, opt_state, data_iter,
                 log_fn=lambda s: None)
    assert t2.restore()
    assert t2.step == 4
    outcome = t2.run()
    assert outcome["step"] == 12

    # run C (oracle): no crash at all
    t3 = Trainer(TrainerConfig(total_steps=12, ckpt_every=100,
                               ckpt_dir=None, log_every=1000),
                 step_fn, params, opt_state, data_iter, log_fn=lambda s: None)
    oracle = t3.run()
    # resumed run must land on the same weights as the uninterrupted run
    assert abs(outcome["last_loss"] - oracle["last_loss"]) < 1e-5


def test_straggler_detection(tmp_path):
    import time as _t
    cfg, params, opt_state, step_fn, data_iter, tcfg = _tiny_setup(
        tmp_path, total_steps=14, ckpt_every=1000)
    tcfg.ckpt_dir = None
    tcfg.straggler_factor = 2.5

    slow_steps = {10}

    def slow_fn(p, o, b):
        out = step_fn(p, o, b)
        jax.block_until_ready(out[2]["loss"])
        return out

    calls = {"i": 0}

    def wrapped(p, o, b):
        if calls["i"] in slow_steps:
            _t.sleep(0.5)
        calls["i"] += 1
        return slow_fn(p, o, b)

    t = Trainer(tcfg, wrapped, params, opt_state, data_iter,
                log_fn=lambda s: None)
    t.ckpt = None
    out = t.run()
    assert out["straggler_events"] >= 1


# ---------------------------------------------------------------------------
# gradient compression with FF error feedback
# ---------------------------------------------------------------------------

def test_grad_compression_error_feedback():
    """int8 quantization + FF error feedback: the INTEGRATED gradient over T
    steps must track the true integral (plain quantization drifts)."""
    from repro.optim.compress import init_feedback, compress, decompress
    rng = np.random.default_rng(0)
    T_steps = 200
    g_true = jnp.asarray(rng.standard_normal(512).astype(np.float32) * 1e-3)
    grads = {"w": g_true}
    state = init_feedback(grads)
    total_fb = np.zeros(512, np.float64)
    total_plain = np.zeros(512, np.float64)
    step = jax.jit(lambda g, s: compress(g, s))
    for _ in range(T_steps):
        q, scales, state = step(grads, state)
        total_fb += np.asarray(decompress(q, scales)["w"], np.float64)
        # plain: no feedback
        s = float(jnp.max(jnp.abs(g_true))) / 127.0
        qp = np.clip(np.round(np.asarray(g_true) / s), -127, 127)
        total_plain += qp * s
    exact = np.asarray(g_true, np.float64) * T_steps
    err_fb = np.abs(total_fb - exact).max()
    err_plain = np.abs(total_plain - exact).max()
    assert err_fb < err_plain / 10          # feedback wins by >=10x
    # integrated error stays at a couple of quantization steps, not T of them
    assert err_fb < 2 * float(jnp.max(jnp.abs(g_true))) / 127.0 * 2


def test_grad_compression_bytes():
    from repro.optim.compress import init_feedback, compress
    g = {"a": jnp.ones((1024,), jnp.float32)}
    q, scales, _ = compress(g, init_feedback(g))
    assert q["a"].dtype == jnp.int8          # 4x wire reduction
