"""The beyond-f64 oracle: exact rounding, classification, residual
ground truths, self-certification — and the guard_probe cross-check
(the PR 7 DAZ finding: a float compare can itself be flushed, so the
census must agree with a bit-level oracle that can't)."""

import math
from fractions import Fraction

import numpy as np
import pytest

from repro.verify import oracle

mpmath = pytest.importorskip("mpmath")


# ---------------------------------------------------------------------------
# exact integer layer
# ---------------------------------------------------------------------------

def test_round_f32_matches_numpy_on_random_f64():
    rng = np.random.default_rng(7)
    xs = (rng.standard_normal(5000)
          * np.exp2(rng.integers(-140, 120, 5000).astype(np.float64)))
    for x in xs:
        want = np.float32(x)
        got = np.float32(oracle.round_f32(Fraction(float(x))))
        assert oracle.f32_bits(want) == oracle.f32_bits(got)


def test_round_f32_ties_to_even():
    a = np.float32(1.0)
    b = np.nextafter(a, np.float32(2.0))
    mid = (oracle.exact(a) + oracle.exact(b)) / 2
    assert oracle.round_f32(mid) == 1.0          # even significand wins
    c = np.nextafter(b, np.float32(2.0))
    mid2 = (oracle.exact(b) + oracle.exact(c)) / 2
    assert oracle.round_f32(mid2) == float(c)    # odd rounds away


def test_round_f32_avoids_double_rounding():
    # an f64 value whose f64->f32 path and exact->f32 path disagree if
    # rounded through f64 first: exactly representable midpoint + epsilon
    lo = np.float32(1.0)
    hi = np.nextafter(lo, np.float32(2.0))
    mid = (oracle.exact(lo) + oracle.exact(hi)) / 2
    v = mid + Fraction(1, 2 ** 60)               # just above the midpoint
    assert oracle.round_f32(v) == float(hi)
    v = mid - Fraction(1, 2 ** 60)
    assert oracle.round_f32(v) == float(lo)


def test_round_f32_subnormals_and_overflow():
    tiny = Fraction(3, 2) * oracle.MIN_SUBNORMAL
    assert oracle.exact(oracle.round_f32(tiny)) == 2 * oracle.MIN_SUBNORMAL
    assert oracle.round_f32(oracle.MIN_SUBNORMAL / 2) == 0.0
    assert math.isinf(oracle.round_f32(Fraction(2) ** 128))
    assert math.isinf(oracle.round_f32(oracle.OVERFLOW_THRESHOLD))
    assert not math.isinf(oracle.round_f32(oracle.OVERFLOW_THRESHOLD - 1))
    assert oracle.round_f32(-(Fraction(2) ** 130)) == -math.inf


def test_classification_is_bitwise():
    cases = {
        0.0: "zero", -0.0: "zero", 1.0: "normal", -2.5e38: "normal",
        1e-40: "subnormal", -1e-44: "subnormal",
        math.inf: "inf", -math.inf: "inf", math.nan: "nan",
    }
    for x, want in cases.items():
        assert oracle.classify_f32(np.float32(x)) == want, x


def test_residual_ground_truths():
    rng = np.random.default_rng(3)
    for _ in range(200):
        a = np.float32(rng.standard_normal() * 2.0 ** rng.integers(-10, 10))
        b = np.float32(rng.standard_normal() * 2.0 ** rng.integers(-10, 10))
        s = np.float32(a + b)
        r = oracle.two_sum_residual(a, b)
        assert oracle.exact(s) + r == oracle.exact(a) + oracle.exact(b)
        # Møller: the residual is itself representable in f32
        assert Fraction(oracle.round_f32(r)) == r
        p = np.float32(a * b)
        rp = oracle.two_prod_residual(a, b)
        assert oracle.exact(p) + rp == oracle.exact(a) * oracle.exact(b)


def test_nearest_ff_is_the_representability_floor():
    v = Fraction(1, 3)
    hi, lo = oracle.nearest_ff(v)
    err = abs(v - Fraction(hi) - Fraction(lo))
    assert err <= Fraction(1, 2) * oracle.ulp32(lo if lo else hi)


# ---------------------------------------------------------------------------
# mpmath layer
# ---------------------------------------------------------------------------

def test_self_check_certifies_beyond_60_bits():
    sc = oracle.self_check(120)
    assert sc["certified_bits"] >= 60
    assert sc["exp1_vs_e_abs"] == 0.0


def test_math_ref_stays_real_on_log_domain_edges():
    assert math.isnan(float(oracle.math_ref("log", -1.0)))
    assert float(oracle.math_ref("log", 0.0)) == -math.inf
    assert math.isnan(float(oracle.math_ref("log1p", -2.0)))
    assert float(oracle.math_ref("log1p", -1.0)) == -math.inf


def test_rel_errors_specials_and_limits():
    xs = np.array([0.5, math.inf, -math.inf, math.nan], np.float32)
    gh = np.array([np.exp(np.float32(0.5)), np.inf, 0.0, np.nan], np.float32)
    gl = np.zeros(4, np.float32)
    errs = oracle.rel_errors("exp", xs, gh, gl)
    assert errs[0] < 1e-7
    assert (errs[1:] == 0.0).all()
    # a wrong limit surfaces as a large error, never a silent pass
    bad = oracle.rel_errors("tanh", np.array([math.inf], np.float32),
                            np.array([0.5], np.float32),
                            np.zeros(1, np.float32))
    assert bad[0] >= 0.5


def test_rel_errors_resolves_beyond_f64():
    # an FF pair 2^-50-close to exp(0.5): f64 cannot see the lo limb's
    # contribution at this scale, the oracle must
    want = oracle.math_ref("exp", 0.5, 200)
    hi = np.float32(float(want))
    lo = np.float32(float(want) - float(hi))
    good = oracle.rel_errors("exp", np.array([0.5], np.float32),
                             np.array([hi]), np.array([lo]))[0]
    flipped = oracle.rel_errors("exp", np.array([0.5], np.float32),
                                np.array([hi]), np.array([-lo]))[0]
    assert good < 2.0 ** -45
    assert flipped > 2.0 ** -30                  # sign flip is visible


# ---------------------------------------------------------------------------
# satellite: guard_probe census vs the DAZ-immune oracle classification
# ---------------------------------------------------------------------------

def _daz_grid() -> np.ndarray:
    """Bit-constructed subnormal/normal/zero mix — built from bit
    patterns so no DAZ-flushed float literal can corrupt the classes."""
    rng = np.random.default_rng(778)
    sub = rng.integers(1, 1 << 23, 64, dtype=np.uint32)          # e = 0
    nrm = ((rng.integers(1, 0xFE, 64, dtype=np.uint32) << 23)
           | rng.integers(0, 1 << 23, 64, dtype=np.uint32))
    zer = np.zeros(16, np.uint32)
    neg = (sub[:32] | np.uint32(0x80000000))
    bits = np.concatenate([sub, nrm, zer, neg]).astype(np.uint32)
    rng.shuffle(bits)
    return bits.view(np.float32)


def test_guard_census_matches_oracle():
    """PR 7 pinned that ``lo != 0`` style float compares are themselves
    flushed on DAZ backends; guard_probe therefore counts denormal lo
    limbs by bit inspection.  The verify oracle classifies by bits too —
    the two independent implementations must agree exactly."""
    from repro.ff.guard import guard_probe

    lo = _daz_grid()
    hi = np.ones_like(lo)                        # normalized, boring hi
    counts = guard_probe(np.asarray(hi), np.asarray(lo))
    census = oracle.count_classes(lo)
    assert int(counts.denormal_lo) == census["subnormal"]
    assert census["subnormal"] == 96             # 64 positive + 32 negative
    assert census["zero"] == 16
    # and the census itself is immune to float compares: every subnormal
    # classified by bits is nonzero as a bit pattern
    nz_bits = int((lo.view(np.uint32) & 0x7FFFFFFF != 0).sum())
    assert nz_bits == census["subnormal"] + census["normal"]
