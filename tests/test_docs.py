"""Docs-consistency gates: the registry-rendered API reference and the
doc tree's cross-links can never silently drift from the code.

``docs/API.md`` embeds a matrix generated FROM the dispatch registry
(``ff.render_api_table``); these tests fail when a newly registered op or
implementation is missing from the document, or the committed matrix is
stale (fix: ``python -m repro.ff.docgen --write docs/API.md``).  The
NUMERICS.md error-contract table is enforced separately — its snippets run
as doctests (``--doctest-glob=NUMERICS.md`` in pyproject).
"""
import os

import repro.ff as ff
from repro.ff import docgen, dispatch

ROOT = os.path.join(os.path.dirname(__file__), "..")
API = os.path.join(ROOT, "docs", "API.md")


def test_api_doc_in_sync_with_registry():
    problems = docgen.check_doc(API)
    assert not problems, "\n".join(problems)


def test_api_matrix_lists_every_impl():
    table = ff.render_api_table()
    for op in dispatch.ops():
        assert f"`ff.{op}`" in table, op
        for impl in dispatch.impls(op):
            assert f"`{impl}`" in table, (op, impl)


def test_api_matrix_is_static_markdown():
    """The matrix must be machine-independent (registration data only):
    rendering twice — and under a different ambient scope — is identical."""
    import jax

    t1 = ff.render_api_table()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with ff.on_mesh(mesh, axis="data"), ff.use(matmul="dot2"):
        t2 = ff.render_api_table()
    assert t1 == t2
    assert t1.startswith(docgen.BEGIN) and t1.endswith(docgen.END)


def test_every_op_has_numerics_or_api_contract():
    """Each registered op appears in the NUMERICS contract tables or (for
    composites whose contract is the cross-impl ulp pin) is named there."""
    with open(os.path.join(ROOT, "docs", "NUMERICS.md")) as f:
        numerics = f.read()
    for op in dispatch.ops():
        if op == "adamw_update":
            # optimizer chain: contract = bitwise jnp/fused equivalence,
            # documented in DESIGN_fusion.md and pinned by test_fusion
            continue
        assert f"ff.{op}" in numerics, f"ff.{op} missing from NUMERICS.md"


def test_readme_links_docs_tier():
    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    for doc in ("docs/API.md", "docs/NUMERICS.md", "docs/VERIFY.md",
                "docs/DESIGN_ozaki.md", "docs/DESIGN_fusion.md",
                "docs/DESIGN_sharded.md", "docs/DESIGN_math.md",
                "docs/DESIGN_robustness.md",
                "docs/DESIGN_observability.md"):
        assert doc in readme, f"README does not link {doc}"
        assert os.path.exists(os.path.join(ROOT, doc)), doc


def test_verify_doc_in_sync_with_contract_registry():
    """docs/VERIFY.md embeds the rendered contracts table between marker
    comments; a registry edit without a doc regen fails here."""
    from repro.verify import contracts

    with open(os.path.join(ROOT, "docs", "VERIFY.md")) as f:
        ok, msg = contracts.check_doc(f.read())
    assert ok, msg


def test_numerics_proof_status_column():
    """Every NUMERICS.md contract row named in NUMERICS_STATUS carries
    exactly the registry's proof status in its table row."""
    from repro.verify import contracts

    with open(os.path.join(ROOT, "docs", "NUMERICS.md")) as f:
        lines = f.read().splitlines()
    for token, status in contracts.NUMERICS_STATUS.items():
        rows = [ln for ln in lines
                if ln.startswith("|") and token + " " in ln]
        assert rows, f"NUMERICS.md has no table row for {token}"
        for ln in rows:
            assert f"**{status}**" in ln, (token, status, ln)
        others = {f"**{s}**" for s in contracts.STATUSES} - {f"**{status}**"}
        for ln in rows:
            assert not any(o in ln for o in others), (token, ln)


def test_readme_verified_contracts_section():
    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    assert "## Verified contracts" in readme
    assert "repro.verify" in readme
