"""Unit + property tests for the FF core (paper §4 theorems).

Oracle: float64.  Every EFT result (pairs with <=48 significand bits) is
exactly representable in f64, so `hi + lo == exact` can be asserted
BIT-EXACTLY — strictly stronger than the paper's sampled Table 5.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    FF, add12, mul12, add22, add22_accurate, add212, mul22, mul212, div22,
    sqrt22, fma22, normalize, two_sum, fast_two_sum, split, split_safe,
    two_prod, two_prod_safe, two_diff, ff_sum, ff_sum_blocked, ff_dot,
    kahan_sum, ff_mean, ff_logsumexp,
    matmul_compensated, matmul_split, matmul_dot2,
)

from conftest import f32_vec


def _f64(x):
    return np.asarray(x).astype(np.float64)


def ff64(x: FF):
    return _f64(x.hi) + _f64(x.lo)


# ---------------------------------------------------------------------------
# EFT exactness (Theorems 2, 3, 4)
# ---------------------------------------------------------------------------

def test_two_sum_exact(rng):
    a, b = f32_vec(rng, 50000), f32_vec(rng, 50000)
    s, r = two_sum(jnp.asarray(a), jnp.asarray(b))
    assert np.array_equal(_f64(s) + _f64(r), _f64(a) + _f64(b))


def test_two_diff_exact(rng):
    a, b = f32_vec(rng, 50000), f32_vec(rng, 50000)
    s, r = two_diff(jnp.asarray(a), jnp.asarray(b))
    assert np.array_equal(_f64(s) + _f64(r), _f64(a) - _f64(b))


def test_fast_two_sum_exact_when_ordered(rng):
    a, b = f32_vec(rng, 50000), f32_vec(rng, 50000)
    hi = np.where(np.abs(a) >= np.abs(b), a, b)
    lo = np.where(np.abs(a) >= np.abs(b), b, a)
    s, r = fast_two_sum(jnp.asarray(hi), jnp.asarray(lo))
    assert np.array_equal(_f64(s) + _f64(r), _f64(a) + _f64(b))


def test_split_theorem(rng):
    """Theorem 3: hi+lo == a, halves fit in 12 bits (products exact)."""
    a = f32_vec(rng, 50000)
    hi, lo = split(jnp.asarray(a))
    hi, lo = np.asarray(hi), np.asarray(lo)
    assert np.array_equal(_f64(hi) + _f64(lo), _f64(a))
    # halves are 12-bit: squaring them is exact in f32
    assert np.array_equal(_f64(np.float32(hi * hi)), _f64(hi) * _f64(hi))
    assert np.array_equal(_f64(np.float32(lo * lo)), _f64(lo) * _f64(lo))


def test_split_safe_large_magnitude():
    a = np.array([3e38, -3e38, 2.0**120, -(2.0**126), 1.5, 0.0], np.float32)
    hi, lo = split_safe(jnp.asarray(a))
    assert np.all(np.isfinite(np.asarray(hi)))
    assert np.array_equal(_f64(hi) + _f64(lo), _f64(a))
    # plain split overflows here
    hi2, _ = split(jnp.asarray(a))
    assert not np.all(np.isfinite(np.asarray(hi2)))


def test_two_prod_exact(rng):
    a, b = f32_vec(rng, 50000), f32_vec(rng, 50000)
    x, y = two_prod(jnp.asarray(a), jnp.asarray(b))
    assert np.array_equal(_f64(x) + _f64(y), _f64(a) * _f64(b))


def test_two_prod_safe_exact_large():
    # magnitudes chosen inside the documented domain [2^-100, 2^115] x safe
    # rescale range: plain split overflows on |a| >= ~2^115, safe split works.
    a = np.array([3e30, 1e36, -2e32], np.float32)
    b = np.array([1e-30, 2e-34, 3e-30], np.float32)
    x, y = two_prod_safe(jnp.asarray(a), jnp.asarray(b))
    assert np.array_equal(_f64(x) + _f64(y), _f64(a) * _f64(b))


def test_add12_mul12_ff(rng):
    a, b = f32_vec(rng, 10000), f32_vec(rng, 10000)
    assert np.array_equal(ff64(add12(jnp.asarray(a), jnp.asarray(b))), _f64(a) + _f64(b))
    assert np.array_equal(ff64(mul12(jnp.asarray(a), jnp.asarray(b))), _f64(a) * _f64(b))


# ---------------------------------------------------------------------------
# Compound operators (Theorems 5, 6) — error-bound tests
# ---------------------------------------------------------------------------

def _rand_ff(rng, n, lo=-5, hi=5):
    v = rng.standard_normal(n) * 10.0 ** rng.uniform(lo, hi, n)
    return FF.from_f64(v)


def test_add22_paper_bound(rng):
    fa, fb = _rand_ff(rng, 20000), _rand_ff(rng, 20000)
    exact = fa.to_f64() + fb.to_f64()
    err = np.abs(ff64(add22(fa, fb)) - exact)
    bound = np.maximum(
        2.0**-24 * np.abs(_f64(fa.lo) + _f64(fb.lo)),
        2.0**-44 * np.abs(exact),
    )
    assert np.all(err <= bound * (1 + 1e-6))


def test_add22_accurate_relative_bound(rng):
    fa, fb = _rand_ff(rng, 20000), _rand_ff(rng, 20000)
    exact = fa.to_f64() + fb.to_f64()
    rel = np.abs(ff64(add22_accurate(fa, fb)) - exact) / np.maximum(np.abs(exact), 1e-300)
    assert rel.max() < 3 * 2.0**-44


def test_mul22_theorem6_bound(rng):
    fa, fb = _rand_ff(rng, 20000), _rand_ff(rng, 20000)
    exact = fa.to_f64() * fb.to_f64()
    rel = np.abs(ff64(mul22(fa, fb)) - exact) / np.abs(exact)
    assert rel.max() <= 2.0**-44 * (1 + 1e-3)


def test_div22_bound(rng):
    fa, fb = _rand_ff(rng, 20000), _rand_ff(rng, 20000)
    exact = fa.to_f64() / fb.to_f64()
    rel = np.abs(ff64(div22(fa, fb)) - exact) / np.abs(exact)
    assert rel.max() < 2.0**-42


def test_sqrt22_bound(rng):
    v = np.abs(rng.standard_normal(20000)) * 10.0 ** rng.uniform(-5, 5, 20000)
    fa = FF.from_f64(v)
    exact = np.sqrt(fa.to_f64())
    rel = np.abs(ff64(sqrt22(fa)) - exact) / exact
    assert rel.max() < 2.0**-42


def test_fma22_bound(rng):
    fa, fb, fc = _rand_ff(rng, 20000), _rand_ff(rng, 20000), _rand_ff(rng, 20000)
    exact = fa.to_f64() * fb.to_f64() + fc.to_f64()
    err = np.abs(ff64(fma22(fa, fb, fc)) - exact)
    mag = np.abs(fa.to_f64() * fb.to_f64()) + np.abs(fc.to_f64())
    assert (err / mag).max() < 2.0**-40


def test_add212_mul212(rng):
    fa = _rand_ff(rng, 10000)
    b = f32_vec(rng, 10000, -5, 5)
    exact = fa.to_f64() + _f64(b)
    err = np.abs(ff64(add212(fa, jnp.asarray(b))) - exact)
    mag = np.abs(fa.to_f64()) + np.abs(_f64(b))
    assert (err / mag).max() < 2.0**-43
    exact = fa.to_f64() * _f64(b)
    rel = np.abs(ff64(mul212(fa, jnp.asarray(b))) - exact) / np.abs(exact)
    assert rel.max() < 2.0**-43


def test_normalize_and_operator_sugar(rng):
    fa, fb = _rand_ff(rng, 100), _rand_ff(rng, 100)
    r = normalize(fa + fb * fa - fb)
    assert np.all(np.abs(np.asarray(r.lo)) <= np.spacing(np.abs(np.asarray(r.hi))))
    exact = (fa.to_f64() + fb.to_f64() * fa.to_f64()) - fb.to_f64()
    got = ff64(r)
    mag = np.abs(fa.to_f64()) + np.abs(fb.to_f64() * fa.to_f64()) + np.abs(fb.to_f64())
    assert (np.abs(got - exact) / mag).max() < 2.0**-40


# ---------------------------------------------------------------------------
# Compensated reductions
# ---------------------------------------------------------------------------

def test_ff_sum_vs_oracle(rng):
    x = f32_vec(rng, 1 << 14, -6, 6)
    exact = np.sum(_f64(x))
    got = ff64(ff_sum(jnp.asarray(x)))
    naive = np.float64(np.float32(np.sum(x)))
    s_abs = np.sum(np.abs(_f64(x)))
    assert abs(got - exact) <= 2.0**-40 * s_abs
    assert abs(got - exact) <= abs(naive - exact) + 2.0**-40 * s_abs


def test_ff_sum_blocked_matches(rng):
    x = f32_vec(rng, 10000, -6, 6)
    a = ff64(ff_sum(jnp.asarray(x)))
    b = ff64(ff_sum_blocked(jnp.asarray(x), block=128))
    exact = np.sum(_f64(x))
    s_abs = np.sum(np.abs(_f64(x)))
    assert abs(a - exact) <= 2.0**-40 * s_abs
    assert abs(b - exact) <= 2.0**-40 * s_abs


def test_ff_sum_axis(rng):
    x = f32_vec(rng, 4 * 33 * 7).reshape(4, 33, 7)
    r = ff_sum(jnp.asarray(x), axis=1)
    assert r.shape == (4, 7)
    exact = np.sum(_f64(x), axis=1)
    s_abs = np.sum(np.abs(_f64(x)), axis=1)
    assert np.all(np.abs(ff64(r) - exact) <= 2.0**-40 * s_abs)


def test_ff_dot_dot2_bound(rng):
    n = 4096
    a, b = f32_vec(rng, n, -3, 3), f32_vec(rng, n, -3, 3)
    exact = np.dot(_f64(a), _f64(b))
    s = np.dot(np.abs(_f64(a)), np.abs(_f64(b)))
    got = ff64(ff_dot(jnp.asarray(a), jnp.asarray(b)))
    u = 2.0**-24
    assert abs(got - exact) <= u * abs(exact) + 2 * n * n * u * u * s


def test_kahan_sum_beats_naive(rng):
    # adversarial: large value plus many tiny ones
    x = np.concatenate([[1e8], np.full(100000, 0.11, np.float32), [-1e8]]).astype(np.float32)
    exact = np.sum(_f64(x))
    k = float(kahan_sum(jnp.asarray(x)))
    naive = float(np.float32(np.sum(x, dtype=np.float32)))
    assert abs(k - exact) < abs(naive - exact)
    assert abs(k - exact) / abs(exact) < 1e-6


def test_ff_logsumexp(rng):
    x = f32_vec(rng, 8 * 512, -1, 2).reshape(8, 512)
    m, s = ff_logsumexp(jnp.asarray(x), axis=-1)
    exact = np.log(np.sum(np.exp(_f64(x) - _f64(m)[:, None]), axis=-1)) + _f64(m)
    got = np.log(ff64(s)) + _f64(m)
    assert np.abs(got - exact).max() < 1e-6


# ---------------------------------------------------------------------------
# FF matmuls
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mk", [(8, 64, 16), (32, 1024, 8), (17, 333, 5)])
def test_matmul_paths_bounds(rng, mk):
    M, K, N = mk
    A = (rng.standard_normal((M, K))).astype(np.float32)
    B = (rng.standard_normal((K, N))).astype(np.float32)
    E = _f64(A) @ _f64(B)
    S = np.abs(_f64(A)) @ np.abs(_f64(B))
    u = 2.0**-24
    # comp/split bound: within-block accumulation may be sequential on the
    # backend -> worst case ~K.u.S; dot2 is Dot2-quality.
    for fn, bound in [
        (matmul_dot2, u * np.abs(E) + 2 * K * K * u * u * S),
        (matmul_compensated, 2 * K * u * S),
        (matmul_split, 2 * K * u * S),
    ]:
        R = fn(jnp.asarray(A), jnp.asarray(B))
        assert np.all(np.abs(ff64(R) - E) <= bound + 1e-30), fn.__name__


def test_matmul_better_than_naive(rng):
    M, K, N = 16, 8192, 16
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    E = _f64(A) @ _f64(B)
    S = np.abs(_f64(A)) @ np.abs(_f64(B))
    naive = _f64(np.asarray(jnp.asarray(A) @ jnp.asarray(B)))
    e_naive = (np.abs(naive - E) / S).max()
    e_dot2 = (np.abs(ff64(matmul_dot2(jnp.asarray(A), jnp.asarray(B))) - E) / S).max()
    assert e_dot2 < e_naive


# ---------------------------------------------------------------------------
# pytree / jit / vmap / scan integration
# ---------------------------------------------------------------------------

def test_ff_pytree_jit(rng):
    """jit vs eager: XLA:CPU contracts a*b+c into FMA under jit, which is
    Dekker-compatible (it computes the residual terms MORE exactly), so the
    hi limb is bit-identical while lo may differ below 2^-44.  Paper §5's
    'forbidden optimizations' (reassociation like (a+b)-a -> b) are NOT
    performed by XLA — asserted by test_jit_preserves_eft below."""
    fa, fb = _rand_ff(rng, 256), _rand_ff(rng, 256)
    f = jax.jit(lambda x, y: mul22(x, y))
    r_eager, r_jit = mul22(fa, fb), f(fa, fb)
    assert np.array_equal(np.asarray(r_eager.hi), np.asarray(r_jit.hi))
    exact = fa.to_f64() * fb.to_f64()
    for r in (r_eager, r_jit):
        rel = np.abs(ff64(r) - exact) / np.abs(exact)
        assert rel.max() <= 2.0**-44 * (1 + 1e-3)


def test_jit_preserves_eft(rng):
    """The EFT exactness guarantees must survive jit compilation (the paper
    had to hand-patch DirectX shaders for this; XLA is safe)."""
    a, b = f32_vec(rng, 20000, -5, 5), f32_vec(rng, 20000, -5, 5)
    s, r = jax.jit(two_sum)(jnp.asarray(a), jnp.asarray(b))
    assert np.array_equal(_f64(s) + _f64(r), _f64(a) + _f64(b))
    x, y = jax.jit(two_prod)(jnp.asarray(a), jnp.asarray(b))
    assert np.array_equal(_f64(x) + _f64(y), _f64(a) * _f64(b))


def test_ff_vmap(rng):
    fa = _rand_ff(rng, 4 * 7).reshape(4, 7)
    fb = _rand_ff(rng, 4 * 7).reshape(4, 7)
    r1 = add22(fa, fb)
    r2 = jax.vmap(add22)(fa, fb)
    assert np.allclose(np.asarray(r1.hi), np.asarray(r2.hi))


def test_ff_scan_carry(rng):
    fa = _rand_ff(rng, 64)
    xs = jnp.asarray(f32_vec(rng, 64, -2, 2))

    def body(c, x):
        return add212(c, x), None

    c0 = FF.zeros(())
    import jax.lax as lax
    c, _ = lax.scan(body, c0, xs)
    exact = np.sum(_f64(np.asarray(xs)))
    assert abs(ff64(c) - exact) < 1e-6 * max(1.0, abs(exact))


# ---------------------------------------------------------------------------
# Toolchain EFT-safety (paper §5 'forbidden optimizations', automated)
# ---------------------------------------------------------------------------

def test_toolchain_eft_safe():
    from repro.core.selfcheck import check_eft_safe
    assert check_eft_safe(), (
        "backend contracts mul+add into FMA across EFT boundaries; "
        "conftest should have set --xla_cpu_max_isa=SSE4_2")


def test_jit_matches_eager_dot_cascade(rng):
    """Regression for the FMA-contraction bug: jitted Dot3 cascade must match
    the op-by-op result bit-for-bit."""
    from repro.core import matmul_dot2
    A = rng.standard_normal((8, 64)).astype(np.float32)
    B = rng.standard_normal((64, 16)).astype(np.float32)
    E = _f64(A) @ _f64(B)
    S = np.abs(_f64(A)) @ np.abs(_f64(B))
    R = matmul_dot2(jnp.asarray(A), jnp.asarray(B))
    u = 2.0**-24
    assert np.all(np.abs(ff64(R) - E) <= u * np.abs(E) + 2 * 64 * 64 * u * u * S)


def test_matmul_ozaki_beyond_ff_precision(rng):
    """Beyond-paper Ozaki matmul: exact slice products + exact in-matmul
    accumulation -> better than the 2^-44 FF target, on MXU ops only."""
    from repro.core import matmul_ozaki
    for K in (300, 2048):
        A = rng.standard_normal((32, K)).astype(np.float32)
        B = rng.standard_normal((K, 16)).astype(np.float32)
        E = _f64(A) @ _f64(B)
        S = np.abs(_f64(A)) @ np.abs(_f64(B))
        R = matmul_ozaki(jnp.asarray(A), jnp.asarray(B))
        assert np.all(np.abs(ff64(R) - E) <= 2.0**-44 * S + 1e-30), K
