"""Mesh-partitioned FF tier tests (``repro.ff.sharded``).

Resolution/scoping/fallback behavior runs in the main process (1 device —
the mesh scope is pure Python state).  Everything that needs an actual
device mesh runs in a SUBPROCESS with 8 simulated host devices, following
the ``test_distributed.py`` pattern (conftest keeps the main process at 1
device by design): sharded matmul (fast + accurate class), ``ff.sum`` /
``ff.dot`` with the compensated tree combine, grad flow through
``custom_vjp``-over-``shard_map``, and a mesh-scoped train step.

The asserted bounds are the DOCUMENTED per-impl contracts from
``docs/NUMERICS.md``: sharded results must match the f64 oracle and the
single-device results within each class's bound, not merely "be close".
"""
import json
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_cpu_max_isa=SSE4_2 "
                        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# main-process: scoping, resolution, fallback (no mesh devices needed)
# ---------------------------------------------------------------------------

def test_on_mesh_resolution():
    import jax
    import repro.ff as ff

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert ff.resolve_name("matmul") != "sharded"
    assert ff.mesh_default("matmul") == "sharded"
    assert ff.mesh_default("sum") == "sharded"
    assert ff.mesh_default("mul") is None
    with ff.on_mesh(mesh, axis="data"):
        assert ff.current_mesh() is not None
        for op in ("matmul", "sum", "dot", "norm_stats"):
            assert ff.resolve_name(op) == "sharded"
        # explicit choices outrank the mesh default
        assert ff.resolve_name("matmul", "dot2") == "dot2"
        with ff.use(matmul="hybrid"):
            assert ff.resolve_name("matmul") == "hybrid"
        with ff.policy(matmul="ozaki"):
            assert ff.resolve_name("matmul") == "ozaki"
        # inner disabler: the sharded impls resolve their per-shard inner
        # op under on_mesh(None) without leaving the outer scope
        with ff.on_mesh(None):
            assert ff.current_mesh() is None
            assert ff.resolve_name("matmul") != "sharded"
        assert ff.resolve_name("matmul") == "sharded"
    assert ff.current_mesh() is None
    assert ff.resolve_name("matmul") != "sharded"


def test_on_mesh_bad_axis():
    import jax
    import repro.ff as ff

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="not in mesh axes"):
        ff.on_mesh(mesh, axis="nonexistent")


def test_sharded_fallback_without_scope_matches_class():
    """Explicit impl="sharded*" outside any on_mesh scope warns and is
    bitwise the single-device impl its class resolves to."""
    import jax.numpy as jnp
    import repro.ff as ff

    rng = np.random.default_rng(3)
    A = jnp.asarray(rng.standard_normal((32, 256)).astype(np.float32))
    B = jnp.asarray(rng.standard_normal((256, 32)).astype(np.float32))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        R = ff.matmul(A, B, impl="sharded")
        assert any("falling back" in str(x.message) for x in w)
    fast = ff.resolve_name("matmul", None, shape=(32, 256, 32))
    R1 = ff.matmul(A, B, impl=fast)
    assert bool(jnp.all(R.hi == R1.hi) & jnp.all(R.lo == R1.lo))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        Ra = ff.matmul(A, B, impl="sharded_accurate")
        assert any("falling back" in str(x.message) for x in w)
    acc = ff.resolve_name("matmul", "tuned_accurate", shape=(32, 256, 32))
    Ra1 = ff.matmul(A, B, impl=acc)
    assert bool(jnp.all(Ra.hi == Ra1.hi) & jnp.all(Ra.lo == Ra1.lo))


def test_tune_never_times_sharded(tmp_path):
    """ff.tune must skip the mesh impls (no mesh in the tuning harness —
    timing them would double-count their single-device fallback)."""
    import repro.ff as ff
    from repro.ff import tuning

    tuning.clear()
    try:
        out = ff.tune("matmul", shapes=[(32, 64, 32)], reps=1,
                      cache=str(tmp_path / "tune.json"), force=True)
        for rec in out["table"].values():
            assert not any(n.startswith("sharded") for n in rec["impls"])
    finally:
        tuning.clear()


# ---------------------------------------------------------------------------
# 8-simulated-device subprocess: accuracy + determinism contracts
# ---------------------------------------------------------------------------

_ACCURACY_CODE = r"""
import json, warnings
import numpy as np
import jax, jax.numpy as jnp
import repro.ff as ff

out = {}
mesh = jax.make_mesh((8,), ("x",))
rng = np.random.default_rng(0)
M, K, N = 128, 2048, 128
A = jnp.asarray(rng.standard_normal((M, K)).astype(np.float32))
B = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32))
E = np.asarray(A, np.float64) @ np.asarray(B, np.float64)
S = np.abs(np.asarray(A, np.float64)) @ np.abs(np.asarray(B, np.float64))

def err(R):
    return float((np.abs(np.asarray(R.to_f64()) - E) / S).max())

R1_fast = jax.jit(lambda a, b: ff.matmul(a, b))(A, B)
R1_acc = jax.jit(lambda a, b: ff.matmul(a, b, impl="tuned_accurate"))(A, B)
with ff.on_mesh(mesh, axis="x"):
    assert ff.resolve_name("matmul") == "sharded"
    Rf = jax.jit(lambda a, b: ff.matmul(a, b))(A, B)
    Ra = jax.jit(lambda a, b: ff.matmul(a, b, impl="sharded_accurate"))(A, B)
    Ra2 = jax.jit(lambda a, b: ff.matmul(a, b, impl="sharded_accurate"))(A, B)
    # explicit psum combine on the accurate inner: documents the fast
    # combine's (weaker) bound independently of the inner impl
    Rp = jax.jit(lambda a, b: ff.matmul(
        a, b, impl="sharded_accurate", combine="psum"))(A, B)
    # non-divisible K falls back to the single-device class impl
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        Rnd = ff.matmul(A[:, :2047], B[:2047])
        out["fallback_warned"] = any("falling back" in str(x.message)
                                     for x in w)
out["fast_oracle"] = err(Rf)
out["acc_oracle"] = err(Ra)
out["psum_acc_oracle"] = err(Rp)
out["fast_vs_single"] = float(
    (np.abs(np.asarray(Rf.to_f64()) - np.asarray(R1_fast.to_f64())) / S).max())
out["acc_vs_single"] = float(
    (np.abs(np.asarray(Ra.to_f64()) - np.asarray(R1_acc.to_f64())) / S).max())
out["tree_deterministic"] = bool(
    jnp.all(Ra.hi == Ra2.hi) & jnp.all(Ra.lo == Ra2.lo))

# reductions: rough-conditioned vector (wide dynamic range)
n = 1 << 16
v = (rng.standard_normal(n) * 10.0 ** rng.uniform(-4, 4, n)).astype(np.float32)
x = jnp.asarray(v)
exact = float(np.sum(v.astype(np.float64)))
with ff.on_mesh(mesh, axis="x"):
    s_tree = jax.jit(lambda u: ff.sum(u))(x)
    s_psum = jax.jit(lambda u: ff.sum(u, combine="psum"))(x)
    d_tree = jax.jit(lambda u, w: ff.dot(u, w))(x, x)
s1 = jax.jit(lambda u: ff.sum(u))(x)
dexact = float(np.sum(v.astype(np.float64) ** 2))
out["sum_tree_rel"] = abs(float(s_tree.to_f64()) - exact) / abs(exact)
out["sum_psum_rel"] = abs(float(s_psum.to_f64()) - exact) / abs(exact)
out["sum_single_rel"] = abs(float(s1.to_f64()) - exact) / abs(exact)
out["dot_tree_rel"] = abs(float(d_tree.to_f64()) - dexact) / abs(dexact)

# norm_stats: row-parallel, bitwise vs single-device
xm = jnp.asarray(rng.standard_normal((64, 256)).astype(np.float32))
with ff.on_mesh(mesh, axis="x"):
    mu, var = jax.jit(lambda u: ff.norm_stats(u))(xm)
mu1, var1 = jax.jit(lambda u: ff.norm_stats(u))(xm)
out["norm_stats_bitwise"] = bool(jnp.all(mu == mu1) & jnp.all(var == var1))

# non-power-of-two mesh axis: the all_gather + ordered-fold combine
mesh6 = jax.sharding.Mesh(np.array(jax.devices()[:6]), ("x",))
A6, B6 = A[:, :1536], B[:1536]
E6 = np.asarray(A6, np.float64) @ np.asarray(B6, np.float64)
S6 = np.abs(np.asarray(A6, np.float64)) @ np.abs(np.asarray(B6, np.float64))
with ff.on_mesh(mesh6, axis="x"):
    R6 = jax.jit(lambda a, b: ff.matmul(a, b, impl="sharded_accurate"))(A6, B6)
out["acc6_oracle"] = float((np.abs(np.asarray(R6.to_f64()) - E6) / S6).max())

# 2-axis mesh: tuple-axis partitioning folds one axis at a time
mesh24 = jax.make_mesh((2, 4), ("a", "b"))
with ff.on_mesh(mesh24, axis=("a", "b")):
    R24 = jax.jit(lambda a, b: ff.matmul(a, b, impl="sharded_accurate"))(A, B)
out["acc24_oracle"] = err(R24)
print(json.dumps(out))
"""


def test_sharded_accuracy_subprocess():
    res = json.loads(_sub(_ACCURACY_CODE).strip().splitlines()[-1])
    # fast class: inner bound (blocked compensated, ~2^-24-relative class)
    # + psum combine slack log2(8)*2^-24 — documented 2^-19 class ceiling
    assert res["fast_oracle"] < 2.0 ** -19, res
    # accurate class: per-op ~2^-44 contract survives the tree combine
    assert res["acc_oracle"] < 2.0 ** -44, res
    assert res["acc6_oracle"] < 2.0 ** -44, res     # non-pow2 gather fold
    assert res["acc24_oracle"] < 2.0 ** -44, res    # tuple-axis butterfly
    # psum combine on an accurate inner: only the combine's
    # log2(P)*2^-24-class error remains — must sit between the classes
    assert res["psum_acc_oracle"] < 2.0 ** -20, res
    assert res["psum_acc_oracle"] > 2.0 ** -44, res
    # cross-checks against the single-device results
    assert res["fast_vs_single"] < 2.0 ** -20, res
    assert res["acc_vs_single"] < 2.0 ** -44, res
    assert res["tree_deterministic"], res
    assert res["fallback_warned"], res
    # reductions: the tree combine preserves the compensated-sum contract
    assert res["sum_tree_rel"] < 2.0 ** -40, res
    assert res["dot_tree_rel"] < 2.0 ** -40, res
    # ... and stays in the single-device ballpark (within 16x)
    assert res["sum_tree_rel"] <= max(res["sum_single_rel"] * 16, 2.0 ** -48), res
    assert res["norm_stats_bitwise"], res


_GRAD_CODE = r"""
import json
import numpy as np
import jax, jax.numpy as jnp
import repro.ff as ff

out = {}
mesh = jax.make_mesh((8,), ("x",))
rng = np.random.default_rng(1)
M, K, N = 64, 1024, 64
A = jnp.asarray(rng.standard_normal((M, K)).astype(np.float32))
B = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32))
W = jnp.asarray(rng.standard_normal((M, N)).astype(np.float32))

def loss(a, b):
    return (ff.matmul(a, b).to_f32() * W).sum()

def loss_acc(a, b):
    return (ff.matmul(a, b, impl="sharded_accurate").to_f32() * W).sum()

ga1, gb1 = jax.jit(jax.grad(loss, argnums=(0, 1)))(A, B)
with ff.on_mesh(mesh, axis="x"):
    ga, gb = jax.jit(jax.grad(loss, argnums=(0, 1)))(A, B)
    gaa, gba = jax.jit(jax.grad(loss_acc, argnums=(0, 1)))(A, B)

def rel(g, g1):
    return float(jnp.max(jnp.abs(g - g1)) / jnp.max(jnp.abs(g1)))

out["ga_rel"] = rel(ga, ga1)
out["gb_rel"] = rel(gb, gb1)
out["gaa_rel"] = rel(gaa, ga1)
out["gba_rel"] = rel(gba, gb1)

# grad through the mesh-partitioned ff.sum: d(sum)/dx == 1
x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
with ff.on_mesh(mesh, axis="x"):
    gs = jax.jit(jax.grad(lambda u: ff.sum(u).to_f32()))(x)
out["sum_grad_ones"] = bool(jnp.all(gs == 1.0))

# mesh-scoped train step on the 8-device mesh: loss/grad reductions
# partitioned, metrics finite, grad-norm matches the single-device step
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import init_params
from repro.optim.adamw import AdamW
from repro.train.train_step import make_train_step

cfg = get_config("granite_3_2b").reduced(num_layers=2, vocab_size=512)
params = init_params(cfg, jax.random.PRNGKey(0))
opt = AdamW(learning_rate=1e-3, ff=True)
data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                              global_batch=8))
batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
mesh2 = jax.make_mesh((8, 1), ("data", "model"))
with ff.policy("ff_reduce"):
    step1 = jax.jit(make_train_step(cfg, None, opt))
    stepm = jax.jit(make_train_step(cfg, None, opt, mesh=mesh2))
s0 = opt.init(params)
p1, s1, m1 = step1(params, s0, batch)
pm, sm, mm = stepm(params, opt.init(params), batch)
out["loss_single"] = float(m1["loss"])
out["loss_mesh"] = float(mm["loss"])
out["gnorm_single"] = float(m1["grad_norm"])
out["gnorm_mesh"] = float(mm["grad_norm"])
p2, s2, m2 = stepm(pm, sm, batch)
out["mesh_second_step_finite"] = bool(np.isfinite(float(m2["loss"])))
print(json.dumps(out))
"""


def test_sharded_grad_and_train_subprocess():
    res = json.loads(_sub(_GRAD_CODE).strip().splitlines()[-1])
    # backward matmuls re-enter the sharded tier; cotangent extraction is
    # f32, so the cross-device combine shows up at the 2^-24-class level
    for k in ("ga_rel", "gb_rel", "gaa_rel", "gba_rel"):
        assert res[k] < 2.0 ** -18, (k, res)
    assert res["sum_grad_ones"], res
    # mesh-scoped step computes the same loss/grad-norm (compensated
    # reductions agree to f32-visible precision)
    assert abs(res["loss_mesh"] - res["loss_single"]) <= \
        2e-5 * abs(res["loss_single"]), res
    assert abs(res["gnorm_mesh"] - res["gnorm_single"]) <= \
        1e-3 * abs(res["gnorm_single"]), res
    assert res["mesh_second_step_finite"], res
