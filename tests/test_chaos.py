"""Chaos tier: deterministic fault injection against the guarded serving
engine (docs/DESIGN_robustness.md).

The contract: under every injected fault, the engine finishes EVERY
submitted request with a documented terminal status — zero unhandled
exceptions — and never silently returns wrong tokens: ``OK`` results are
token-for-token the healthy baseline, ``DEGRADED`` results are
token-for-token the fast-f32-tier baseline, anything unrecoverable is
withheld as ``FAILED``.
"""

import dataclasses
import warnings

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.chaos import ChaosMonkey
from repro.ff import tuning
from repro.ff.guard import FFGuardWarning, FFTuneWarning
from repro.ff.scope import resolve_policy
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.serve import (DEGRADED, FAILED, OK, REJECTED, STATUSES, TIMEOUT,
                         Request, ServeEngine, UnsupportedModelError)
from repro.train.serve_step import greedy_generate

CFG = ModelConfig(name="chaos-test", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=256, max_seq_len=64, compute_dtype="float32",
                  remat=False)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture
def rng():
    """File-local override of the conftest session rng: chaos tests must
    not advance the suite-wide stream — downstream accuracy tests were
    calibrated against its unshifted draw sequence."""
    return np.random.default_rng(777)


def _prompts(rng, n, lo=6, hi=14):
    return [rng.integers(1, CFG.vocab_size, size=int(s)).astype(np.int32)
            for s in rng.integers(lo, hi, size=n)]


def _baseline(params, prompt, max_new, fast=False):
    pol = dataclasses.replace(resolve_policy(None), attention="fast",
                              ff_math=False) if fast else None
    return np.asarray(greedy_generate(
        params, CFG, jnp.asarray(prompt[None]), max_new, cache_len=48,
        policy=pol)[0])


def _assert_contract(params, prompts, res, max_new):
    """Every uid terminated, documented status, and token parity per
    surviving tier — the chaos acceptance contract."""
    assert sorted(res) == list(range(len(prompts)))
    for i, p in enumerate(prompts):
        r = res[i]
        assert r.status in STATUSES, f"uid {i}: undocumented {r.status!r}"
        if r.status == OK:
            assert np.array_equal(r.tokens, _baseline(params, p, max_new))
        elif r.status == DEGRADED:
            assert np.array_equal(
                r.tokens, _baseline(params, p, max_new, fast=True))
        elif r.status == FAILED:
            assert r.tokens.size == 0     # withheld, never wrong


# --------------------------------------------------------------------------
# structured construction-time errors
# --------------------------------------------------------------------------

def test_unsupported_model_error_names_field(params):
    moe = dataclasses.replace(CFG, moe_num_experts=4)
    with pytest.raises(UnsupportedModelError) as ei:
        ServeEngine(params, moe)
    assert ei.value.field == "moe_num_experts" and ei.value.value == 4
    assert "greedy_generate" in str(ei.value)
    assert isinstance(ei.value, NotImplementedError)   # old except: clauses
    with pytest.raises(UnsupportedModelError) as ei:
        ServeEngine(params, dataclasses.replace(CFG, use_mla=True))
    assert ei.value.field == "use_mla"
    with pytest.raises(UnsupportedModelError) as ei:
        ServeEngine(params, dataclasses.replace(CFG, family="mamba2"))
    assert ei.value.field == "family" and "dense" in ei.value.supported


# --------------------------------------------------------------------------
# admission backpressure: rejection + deadlines
# --------------------------------------------------------------------------

def test_submit_rejects_impossible_and_overflow(params, rng):
    p = _prompts(rng, 1, lo=8, hi=9)[0]        # fixed length 8
    eng = ServeEngine(params, CFG, max_batch=1, page_size=4, max_ctx=32,
                      num_pages=4, max_queue=1)
    assert eng.submit(Request(uid=0, prompt=p, max_new=64)) == REJECTED
    assert "max_ctx" in eng.results[0].detail
    # fits max_ctx but can never fit the (deliberately tiny) pool
    assert eng.submit(Request(uid=1, prompt=p, max_new=20)) == REJECTED
    assert "pool" in eng.results[1].detail
    assert eng.submit(Request(uid=2, prompt=p, max_new=4)) == "QUEUED"
    assert eng.submit(Request(uid=3, prompt=p, max_new=4)) == REJECTED
    assert "queue" in eng.results[3].detail
    res = eng.run()
    assert res[2].status == OK
    assert sorted(res) == [0, 1, 2, 3]


def test_deadline_steps_timeout(params, rng):
    """Deterministic deadline: a queued request expires behind a busy
    batch; a running request retires TIMEOUT keeping its partial tokens."""
    prompts = _prompts(rng, 2)
    eng = ServeEngine(params, CFG, max_batch=1, page_size=4, max_ctx=32)
    eng.submit(Request(uid=0, prompt=prompts[0], max_new=8,
                       deadline_steps=3))
    eng.submit(Request(uid=1, prompt=prompts[1], max_new=8,
                       deadline_steps=2))
    res = eng.run()
    assert res[0].status == TIMEOUT
    assert 0 < len(res[0].tokens) < 8          # partial output preserved
    assert np.array_equal(res[0].tokens,
                          _baseline(params, prompts[0], 8)
                          [:len(res[0].tokens)])
    assert res[1].status == TIMEOUT and len(res[1].tokens) == 0
    assert "queued" in res[1].detail


def test_deadline_s_wallclock(params, rng):
    p = _prompts(rng, 1)[0]
    eng = ServeEngine(params, CFG, max_batch=1, page_size=4, max_ctx=32)
    eng.submit(Request(uid=0, prompt=p, max_new=4, deadline_s=3600.0))
    res = eng.run()
    assert res[0].status == OK                 # generous deadline: no-op


# --------------------------------------------------------------------------
# numeric poison -> quarantine -> fast-tier degrade
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["nan", "inf"])
def test_kv_poison_quarantines_and_degrades(params, rng, kind):
    prompts = _prompts(rng, 2)
    eng = ServeEngine(params, CFG, max_batch=2, page_size=4, max_ctx=32,
                      guard="degrade")
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new=6))
    eng.step()
    ChaosMonkey(seed=3).corrupt_kv_limbs(eng.kv, slot=0, kind=kind, n=2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", FFGuardWarning)
        res = eng.run()
    _assert_contract(params, prompts, res, 6)
    assert any(r.status == DEGRADED for r in res.values())
    assert eng.guard_stats["quarantined"] >= 1
    assert eng.guard_stats["flagged_rows"] >= 1


def test_guard_off_does_not_probe(params, rng):
    """mode="off" is the zero-overhead documented escape hatch: poison is
    NOT detected (tokens degrade silently) — the probe really is off."""
    prompts = _prompts(rng, 1)
    eng = ServeEngine(params, CFG, max_batch=1, page_size=4, max_ctx=32,
                      guard="off")
    eng.submit(Request(uid=0, prompt=prompts[0], max_new=6))
    eng.step()
    ChaosMonkey(seed=3).corrupt_kv_limbs(eng.kv, slot=0, kind="nan", n=2)
    res = eng.run()
    assert res[0].status == OK                 # no probe, no quarantine
    assert eng.guard_stats["quarantined"] == 0


def test_denormal_lo_is_hazard_not_violation(params, rng):
    """Subnormal lo limbs in FF pages are flagged by the probe's hazard
    category but never trip quarantine (legal FF pairs can carry them)."""
    from repro.kernels.ff_guard import flag_planes
    prompts = _prompts(rng, 1)
    eng = ServeEngine(params, CFG, max_batch=1, page_size=4, max_ctx=32,
                      kv_mode="ff_bf16", guard="degrade")
    eng.submit(Request(uid=0, prompt=prompts[0], max_new=4))
    eng.step()
    ChaosMonkey(seed=5).corrupt_kv_limbs(eng.kv, slot=0,
                                         kind="denormal_lo", n=3,
                                         base="k", limb="lo")
    dn = flag_planes(eng.kv.planes["k_hi"].astype(jnp.float32),
                     eng.kv.planes["k_lo"].astype(jnp.float32))[2]
    assert int(np.asarray(dn).sum()) >= 1      # detectable by limb bits
    res = eng.run()
    assert res[0].status in (OK, DEGRADED)     # never FAILED for a hazard
    assert eng.guard_stats["quarantined"] == 0


# --------------------------------------------------------------------------
# paging metadata corruption -> audit -> rebuild
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["oob", "free", "dup"])
def test_block_table_corruption_recovers(params, rng, mode):
    prompts = _prompts(rng, 2)
    eng = ServeEngine(params, CFG, max_batch=2, page_size=4, max_ctx=32,
                      guard="degrade")
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new=6))
    eng.step()
    ChaosMonkey(seed=7).flip_block_table(eng.kv, slot=1, mode=mode)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", FFGuardWarning)
        res = eng.run()
    _assert_contract(params, prompts, res, 6)
    assert eng.guard_stats["integrity_rebuilds"] >= 1
    problems, bad = eng.kv.check_integrity()
    assert not problems                        # metadata clean afterwards


# --------------------------------------------------------------------------
# resource exhaustion: preemption, forced failure
# --------------------------------------------------------------------------

def test_pool_exhaustion_preempts_youngest(params, rng):
    """reserve="prompt" on an undersized pool: the youngest row preempts
    (pages freed, request requeued), everything still finishes OK with
    token parity — preemption is invisible in the output."""
    prompts = _prompts(rng, 3, lo=7, hi=9)
    eng = ServeEngine(params, CFG, max_batch=3, page_size=4, max_ctx=32,
                      num_pages=8, reserve="prompt")
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new=8))
    res = eng.run()
    _assert_contract(params, prompts, res, 8)
    assert all(r.status == OK for r in res.values())
    assert eng.guard_stats["preempted"] >= 1


def test_forced_allocation_failure_terminal(params, rng):
    """A stolen pool (chaos) with an empty engine must retire the head
    FAILED — never the old scheduler-stall RuntimeError."""
    p = _prompts(rng, 1)[0]
    eng = ServeEngine(params, CFG, max_batch=1, page_size=4, max_ctx=32,
                      reserve="prompt")
    monkey = ChaosMonkey(seed=9)
    with monkey.exhaust_pool(eng.kv):
        eng.submit(Request(uid=0, prompt=p, max_new=4))
        res = eng.run()
        assert res[0].status == FAILED
        assert "unschedulable" in res[0].detail
    # pool restored: the same request now succeeds
    eng.submit(Request(uid=1, prompt=p, max_new=4))
    res = eng.run()
    assert res[1].status == OK
    assert np.array_equal(res[1].tokens, _baseline(params, p, 4))


def test_exhaust_pool_restores(params):
    from repro.serve import PagedKVCache
    kv = PagedKVCache(1, 1, 4, num_pages=6, page_size=4, max_seqs=2,
                      max_ctx=16)
    before = list(kv.free_pages)
    with ChaosMonkey(seed=1).exhaust_pool(kv, keep=1) as stolen:
        assert len(kv.free_pages) == 1 and len(stolen) == 5
        assert not kv.can_alloc(5)
    assert sorted(kv.free_pages) == sorted(before)


# --------------------------------------------------------------------------
# tuning sidecar corruption (satellite: robust FF_TUNE load)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["truncate", "garbage", "wrong_types"])
def test_mangled_tune_json_warns_and_falls_back(tmp_path, mode):
    path = str(tmp_path / "FF_TUNE.json")
    ChaosMonkey(seed=2).mangle_tune_json(path, mode=mode)
    tuning.clear()
    try:
        with pytest.warns(FFTuneWarning):
            table = tuning.load(path)
        if mode == "wrong_types":
            assert "cpu/add" in table          # valid entries salvaged
            assert "cpu/matmul" not in table   # malformed entry dropped
        # a bad sidecar is read once, not per lookup (no retry storm)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            tuning.lookup("matmul", (64, 64))
    finally:
        tuning.clear()


def test_healthy_tune_json_still_loads(tmp_path):
    path = str(tmp_path / "FF_TUNE.json")
    import json
    with open(path, "w") as f:
        json.dump({"meta": {}, "table": {"cpu/add": {"16x16": {
            "fast": {"impl": "jnp", "opts": {}, "us": 1.0}}}}}, f)
    tuning.clear()
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("error")     # a good file must not warn
            table = tuning.load(path)
        assert "cpu/add" in table
    finally:
        tuning.clear()
